import time

import numpy as np

# machine-readable row registry: every row() lands here too, so the driver
# (benchmarks.run) can emit per-module BENCH_*.json perf-trajectory
# artifacts next to the human CSV on stdout
ROWS: list[dict] = []

# nested (non-row) artifact payloads: a benchmark module deposits JSON-able
# blobs here (e.g. the serving sweep's adaptation traces) and the driver
# embeds them into BENCH_<module>.json top-level keys, clearing between
# modules. Keys must not collide with the driver's own payload fields.
EXTRAS: dict = {}


def timeit(fn, *, repeat=3, number=1):
    """Median wall time per call in microseconds."""
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            fn()
        times.append((time.perf_counter() - t0) / number)
    return float(np.median(times)) * 1e6


def timeit_pcts(fn, *, repeat=5, number=1):
    """Per-call wall-time samples in microseconds: (median, p50, p99).

    Unlike ``timeit`` this keeps the whole sample, so tail behavior is
    reportable next to the median (the serving work makes percentiles the
    headline metric). p99 degrades toward max at small ``repeat`` — use
    enough repeats for the tail to mean something."""
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            fn()
        times.append((time.perf_counter() - t0) / number)
    arr = np.array(times) * 1e6
    return (float(np.median(arr)), float(np.percentile(arr, 50)),
            float(np.percentile(arr, 99)))


def _parse_derived(derived: str) -> dict:
    """``k=v;k=v`` derived columns as a typed dict (numbers where possible)."""
    out = {}
    for part in filter(None, derived.split(";")):
        if "=" not in part:
            out[part] = True
            continue
        key, val = part.split("=", 1)
        try:
            out[key] = int(val)
        except ValueError:
            try:
                out[key] = float(val)
            except ValueError:
                out[key] = val
    return out


def row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
    rec = {"name": name, "us_per_call": round(float(us), 3)}
    if us > 0:
        rec["throughput_per_s"] = round(1e6 / float(us), 3)
    rec.update(_parse_derived(derived))
    ROWS.append(rec)
