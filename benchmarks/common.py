import time

import numpy as np


def timeit(fn, *, repeat=3, number=1):
    """Median wall time per call in microseconds."""
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            fn()
        times.append((time.perf_counter() - t0) / number)
    return float(np.median(times)) * 1e6


def row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
