"""Paper scenario 2: streaming ingest + variable-window queries under
PP / TP / BTP. Reports ingest throughput, window-query latency for small /
medium / large windows, partition counts, and blocks visited — plus the
batched engine (``window_knn_batch``) against the per-query loop at several
concurrent-query batch sizes (the serving-traffic scenario)."""
import numpy as np

from repro.core import StreamConfig, StreamingIndex, SummarizationConfig
from repro.data.synthetic import seismic

from .common import row, timeit

LEN = 128
CFG = SummarizationConfig(series_len=LEN, n_segments=16, card_bits=8)
N_BATCH, BSZ = 50, 600


def main():
    streams = {
        b: seismic(BSZ, LEN, seed=b) for b in range(N_BATCH)
    }
    q = seismic(1, LEN, seed=999)[0]

    for scheme in ("PP", "TP", "BTP"):
        def build():
            idx = StreamingIndex(StreamConfig(scheme=scheme, summarization=CFG,
                                              buffer_entries=4096, growth_factor=4,
                                              block_size=512))
            for b in range(N_BATCH):
                idx.ingest(streams[b], np.full(BSZ, b, np.int64))
            return idx

        us = timeit(build, repeat=1)
        idx = build()
        row(f"streaming/{scheme}_ingest", us / (N_BATCH * BSZ),
            f"partitions={idx.n_partitions};"
            f"io_s={idx.raw.disk.modeled_seconds():.3f}")
        for wname, (t0, t1) in {"small": (47, 49), "mid": (35, 49),
                                "large": (0, 49)}.items():
            us = timeit(lambda: idx.window_knn(q, t0, t1, k=5), repeat=2)
            _, st = idx.window_knn(q, t0, t1, k=5)
            row(f"streaming/{scheme}_window_{wname}", us,
                f"blocks_visited={st.blocks_visited};blocks_pruned={st.blocks_pruned}")

        # batched concurrent window queries vs the per-query loop
        QB = seismic(64, LEN, seed=1234)
        t0, t1 = 35, 49
        for bsz in (8, 64):
            Qb = QB[:bsz]
            us_b = timeit(lambda: idx.window_knn_batch(Qb, t0, t1, k=5), repeat=2)
            us_l = timeit(
                lambda: [idx.window_knn(q2, t0, t1, k=5) for q2 in Qb], repeat=2
            )
            row(f"streaming/{scheme}_window_mid_batch_b{bsz}", us_b / bsz,
                f"speedup_vs_loop={us_l / max(us_b, 1e-9):.2f}")
