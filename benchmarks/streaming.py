"""Paper scenario 2: streaming ingest + variable-window queries under
PP / TP / BTP. Reports ingest throughput, window-query latency for small /
medium / large windows, partition counts, and blocks visited — plus the
batched engine (``window_knn_batch``) against the per-query loop at several
concurrent-query batch sizes (the serving-traffic scenario), the batched
approximate tier (``window_knn_approx_batch``) as batch x n_blocks sweeps
with recall@5 against the exact oracle, the concurrent ingest+query
sweep: serving-loop query latency (p50/p99) while flushes/merges land,
blocking ingest vs the background pipeline — and the storage-backend sweep:
the same mixed ingest+query run under the modeled DiskModel backend vs the
crash-consistent file backend (mmap runs + WAL), reporting the modeled I/O
columns next to the file backend's *measured* byte counters."""
import shutil
import tempfile
import time

import numpy as np

from repro.core import (StreamConfig, StreamingIndex, SummarizationConfig,
                        recall_at_k)
from repro.data.synthetic import seismic

from .common import row, timeit, timeit_pcts

LEN = 128
CFG = SummarizationConfig(series_len=LEN, n_segments=16, card_bits=8)
N_BATCH, BSZ = 50, 600


def concurrent_sweep(smoke: bool = False):
    """Mixed ingest+query serving loop: every turn submits one ingest batch
    and immediately serves one query batch; the recorded latency is the
    serving-loop turnaround (submission -> answers). Under ``ingest="sync"``
    the turn eats any inline flush + cascading merge, so compaction lands in
    the query tail; ``ingest="async"`` moves that work to the pipeline
    worker and the tail collapses — the paper's CLSM overlap claim as a
    p50/p99 row pair. Run counts are checked post-drain so both modes did
    the same compaction work. Async ingest runs with backpressure at 2x
    the flush threshold: an unbounded backlog would grow the brute-force
    dense tail every query must scan, trading the merge stall for
    dense-scan work — bounding the lag keeps the comparison about
    compaction, matching sync's <= 1-buffer steady-state lag."""
    n_batch, bsz = (10, 200) if smoke else (40, 1000)
    buffer_entries = 256 if smoke else 2048
    qb = 8
    Qb = seismic(qb, LEN, seed=777)
    for mode in ("sync", "async"):
        idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                          buffer_entries=buffer_entries,
                                          growth_factor=2, block_size=256,
                                          ingest=mode,
                                          max_lag_entries=2 * buffer_entries))
        lats, lag_peak = [], 0
        for b in range(n_batch):
            x = seismic(bsz, LEN, seed=5000 + b)
            t_sub = time.perf_counter()
            idx.ingest(x, np.full(bsz, b, np.int64))
            if b >= 1:
                idx.window_knn_batch(Qb, max(0, b - 8), b, k=5)
                lats.append(time.perf_counter() - t_sub)
                lag_peak = max(lag_peak, idx.ingest_lag()["lag_entries"])
        idx.drain(flush_buffer=False, timeout=300)
        idx.close()
        arr = np.array(lats) * 1e6
        row(f"streaming/concurrent_{mode}_ingest_query",
            float(arr.mean()),
            f"p50_us={np.percentile(arr, 50):.0f};"
            f"p99_us={np.percentile(arr, 99):.0f};"
            f"max_us={arr.max():.0f};"
            f"peak_lag_entries={lag_peak};"
            f"partitions={idx.n_partitions};"
            f"merges={idx.lsm.n_merges}")


def storage_sweep(smoke: bool = False):
    """Modeled vs measured I/O: one mixed ingest+query run per backend.

    Both rows carry the modeled DiskModel columns (identical accounting on
    either backend — the simulation keeps running under the file backend,
    so trajectories stay comparable); the file row's measured columns are
    the bytes actually pushed through raw.bin / run files / the WAL, plus
    the readahead pool's span count. The WAL is deliberately NOT modeled
    (it is a durability cost the simulation never had), which is exactly
    what the measured-vs-modeled gap is for."""
    n_batch, bsz = (6, 150) if smoke else (20, 600)
    buffer_entries = 256 if smoke else 2048
    qb = 8
    Qb = seismic(qb, LEN, seed=4242)
    for backend in ("model", "file"):
        root = tempfile.mkdtemp(prefix="coconut-bench-store-")
        try:
            idx = StreamingIndex(StreamConfig(
                scheme="BTP", summarization=CFG,
                buffer_entries=buffer_entries, growth_factor=2,
                block_size=256, storage=backend, storage_dir=root))
            t0 = time.perf_counter()
            for b in range(n_batch):
                x = seismic(bsz, LEN, seed=8000 + b)
                idx.ingest(x, np.full(bsz, b, np.int64))
                if b >= 1:
                    idx.window_knn_approx_batch(Qb, max(0, b - 4), b, k=5,
                                                n_blocks=2)
            us = (time.perf_counter() - t0) * 1e6 / n_batch
            d = idx.raw.disk
            m = idx.measured_io()
            mb = 1e6
            row(f"streaming/storage_{backend}_ingest_query", us,
                f"modeled_io_s={d.modeled_seconds():.4f};"
                f"modeled_mb={d.stats.total_bytes / mb:.2f};"
                f"measured_write_mb={(m.get('raw_write_bytes', 0) + m.get('run_write_bytes', 0)) / mb:.2f};"
                f"measured_read_mb={m.get('raw_read_bytes', 0) / mb:.2f};"
                f"wal_mb={m.get('wal_write_bytes', 0) / mb:.2f};"
                f"prefetch_spans={m.get('prefetch_spans', 0)};"
                f"partitions={idx.n_partitions}")
        finally:
            shutil.rmtree(root, ignore_errors=True)


def main(smoke: bool = False):
    n_batch, bsz = (8, 200) if smoke else (N_BATCH, BSZ)
    buffer_entries = 512 if smoke else 4096  # smoke still flushes partitions
    qb_sizes = (4,) if smoke else (8, 64)
    streams = {
        b: seismic(bsz, LEN, seed=b) for b in range(n_batch)
    }
    q = seismic(1, LEN, seed=999)[0]
    windows = {"small": (n_batch - 3, n_batch - 1),
               "mid": (int(n_batch * 0.7), n_batch - 1),
               "large": (0, n_batch - 1)}

    for scheme in ("PP", "TP", "BTP"):
        def build():
            idx = StreamingIndex(StreamConfig(scheme=scheme, summarization=CFG,
                                              buffer_entries=buffer_entries,
                                              growth_factor=4, block_size=512))
            for b in range(n_batch):
                idx.ingest(streams[b], np.full(bsz, b, np.int64))
            return idx

        us = timeit(build, repeat=1)
        idx = build()
        row(f"streaming/{scheme}_ingest", us / (n_batch * bsz),
            f"partitions={idx.n_partitions};"
            f"io_s={idx.raw.disk.modeled_seconds():.3f}")
        for wname, (t0, t1) in windows.items():
            us = timeit(lambda: idx.window_knn(q, t0, t1, k=5), repeat=2)
            _, st = idx.window_knn(q, t0, t1, k=5)
            row(f"streaming/{scheme}_window_{wname}", us,
                f"blocks_visited={st.blocks_visited};blocks_pruned={st.blocks_pruned}")

        # batched concurrent window queries vs the per-query loop
        QB = seismic(max(qb_sizes), LEN, seed=1234)
        t0, t1 = windows["mid"]
        for m in qb_sizes:
            Qb = QB[:m]
            us_b, p50_b, p99_b = timeit_pcts(
                lambda: idx.window_knn_batch(Qb, t0, t1, k=5), repeat=5)
            us_l = timeit(
                lambda: [idx.window_knn(q2, t0, t1, k=5) for q2 in Qb], repeat=2
            )
            d = idx.raw.disk
            d.reset()
            idx.window_knn_batch(Qb, t0, t1, k=5)
            row(f"streaming/{scheme}_window_mid_batch_b{m}", us_b / m,
                f"speedup_vs_loop={us_l / max(us_b, 1e-9):.2f};"
                f"p50_us={p50_b / m:.1f};p99_us={p99_b / m:.1f};"
                f"modeled_io_s={d.modeled_seconds() / m:.5f}")

        # batched approximate tier: batch x n_blocks with recall@5 vs exact
        _, exact_ids, _ = idx.window_knn_batch(QB, t0, t1, k=5)
        for m in qb_sizes:
            Qb = QB[:m]
            for nb in (1, 2):
                us_b, p50_b, p99_b = timeit_pcts(
                    lambda: idx.window_knn_approx_batch(Qb, t0, t1, k=5,
                                                        n_blocks=nb),
                    repeat=5,
                )
                us_l = timeit(
                    lambda: [idx.window_knn(q2, t0, t1, k=5, exact=False,
                                            n_blocks=nb) for q2 in Qb],
                    repeat=2,
                )
                _, approx_ids, _ = idx.window_knn_approx_batch(
                    Qb, t0, t1, k=5, n_blocks=nb
                )
                rec = recall_at_k(approx_ids, exact_ids[:m])
                row(f"streaming/{scheme}_window_mid_approx_batch_b{m}_nb{nb}",
                    us_b / m,
                    f"speedup_vs_loop={us_l / max(us_b, 1e-9):.2f};"
                    f"p50_us={p50_b / m:.1f};p99_us={p99_b / m:.1f};"
                    f"recall_at5={rec:.3f}")

    concurrent_sweep(smoke)
    storage_sweep(smoke)
