"""Kernel microbenches. On this CPU container the Pallas kernels execute in
interpret mode (correctness twin), so wall numbers are NOT TPU numbers; the
derived column reports the analytic FLOPs/bytes each call would execute on
the target (for the roofline narrative) alongside the pure-numpy host path.
"""
import numpy as np

import jax

from repro.core import SummarizationConfig, sax
from repro.core.lower_bounds import ed2
from repro.kernels import ops

from .common import row, timeit

CFG = SummarizationConfig(series_len=256, n_segments=16, card_bits=8)


def main(smoke: bool = False):
    b, m = (256, 4) if smoke else (4096, 16)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((b, 256)).astype(np.float32)
    q = rng.standard_normal((m, 256)).astype(np.float32)

    p = ops.paa(x, CFG)
    jax.block_until_ready(p)
    us = timeit(lambda: jax.block_until_ready(ops.paa(x, CFG)), repeat=3)
    row(f"kernels/paa_interp_{b}x256", us, f"bytes={x.nbytes};mode=interpret")
    us = timeit(lambda: x.reshape(b, 16, 16).mean(-1), repeat=3)
    row("kernels/paa_numpy_host", us, "reference")

    sk = ops.sax_and_keys(p, CFG)
    jax.block_until_ready(sk)
    us = timeit(lambda: jax.block_until_ready(ops.sax_and_keys(p, CFG)), repeat=3)
    row(f"kernels/sax_pack_interp_{b}", us, "mode=interpret")
    us = timeit(lambda: sax(x, CFG), repeat=3)
    row("kernels/sax_numpy_host", us, "reference")

    me = ops.min_ed(q, x)
    jax.block_until_ready(me)
    us = timeit(lambda: jax.block_until_ready(ops.min_ed(q, x)), repeat=3)
    flops = 2 * m * b * 256
    row(f"kernels/min_ed_interp_{m}x{b}", us,
        f"flops={flops};tpu_ideal_us={flops / 197e6:.2f};mode=interpret")
    us = timeit(lambda: np.min(ed2(q[:, None, :], x[None]), axis=1), repeat=3)
    row("kernels/min_ed_numpy_host", us, "reference")
