"""Closed-loop serving benchmark: the dynamic-batching gateway under a
Poisson arrival sweep (offered QPS x deadline x tier mix).

Each config drives ``core.gateway`` with an open-loop Poisson client
stream against a pre-built streaming index and reports the client-observed
latency-vs-throughput point: p50/p95/p99 latency, achieved QPS, shed rate,
the formed-batch histogram, and the engine's post-warm-up retrace count —
which must stay 0: the gateway's ladder rungs are exactly the engine's
prewarmed batch buckets, so steady-state serving never compiles.

The sweep shape is the paper's serving story: at low offered load p99
stays under the deadline-flush bound (one deadline plus one batch service
time); past saturation the gateway degrades gracefully — the shed rate
rises and p99 stays bounded — instead of collapsing into an unbounded
queue.
"""
import time

import numpy as np

from repro.core import (AutoTunerConfig, Gateway, GatewayConfig, Knobs,
                        StreamConfig, StreamingIndex, SummarizationConfig)
from repro.core.verify_engine import get_engine

from .common import EXTRAS, row

LEN = 128
CFG = SummarizationConfig(series_len=LEN, n_segments=16, card_bits=8)
N_BATCH, BSZ = 20, 1000
K = 5
SLO_P99_MS = 60.0
# (offered qps, deadline_ms, mix): a latency-vs-throughput curve at fixed
# deadline, a deadline-sensitivity pair at fixed load, and the tier mixes
CONFIGS = (
    (500, 5.0, "exact"),
    (2000, 5.0, "exact"),
    (8000, 5.0, "exact"),
    (2000, 2.0, "exact"),
    (2000, 10.0, "exact"),
    (2000, 5.0, "mixed"),
    (8000, 5.0, "mixed"),
)
SMOKE_CONFIGS = ((300, 5.0, "exact"), (300, 5.0, "mixed"))


def _mix_kwargs(mix: str, rng, windows):
    """Deterministic tenant mix. ``mixed`` adds recall-targeted requests,
    conflicting recall+latency targets (always shed), and window
    constraints (per-tier/per-window sub-batch splits)."""
    kw = {}
    if mix == "mixed":
        r = rng.random()
        if r < 0.2:
            kw["target_recall"] = 0.9
        elif r < 0.3:
            kw.update(target_recall=0.9, latency_budget_ms=0.05)
        if rng.random() < 0.5:
            kw["window"] = windows
    return kw


def _drive(gw, Q, qps, mix, rng, windows, warmup, engine):
    """Submit ``len(Q)`` requests at Poisson-offered ``qps``; returns the
    measured (post-warm-up) responses, the wall time of the measured
    phase, and the engine retraces during it."""
    tickets = []
    traces0 = None
    t_meas0 = None
    for i in range(Q.shape[0]):
        tickets.append(gw.submit(Q[i], **_mix_kwargs(mix, rng, windows)))
        if i + 1 == warmup:
            for t in tickets:
                t.result(timeout=300)  # drain: warm-up compiles settle
            gw.reset_slo_window()  # compile latencies must not trip the gate
            traces0 = engine.stats["traces"]
            t_meas0 = time.perf_counter()
        time.sleep(rng.exponential(1.0 / qps))
    resps = [t.result(timeout=300) for t in tickets]
    t_meas1 = time.perf_counter()
    retraces = engine.stats["traces"] - (traces0 if traces0 is not None
                                         else engine.stats["traces"])
    return resps[warmup:], (t_meas1 - (t_meas0 or t_meas1)), retraces


def main(smoke: bool = False):
    n_batch, bsz = (6, 200) if smoke else (N_BATCH, BSZ)
    n_req = 60 if smoke else 400
    max_batch = 16 if smoke else 32
    configs = SMOKE_CONFIGS if smoke else CONFIGS
    idx = StreamingIndex(StreamConfig(
        scheme="BTP", summarization=CFG, buffer_entries=1024 if smoke else 4096,
        growth_factor=4, block_size=512))
    for b in range(n_batch):
        rng = np.random.default_rng(100 + b)
        x = np.cumsum(rng.normal(size=(bsz, LEN)), axis=1,
                      dtype=np.float64).astype(np.float32)
        idx.ingest(x, np.full(bsz, b, np.int64))
    engine = get_engine()
    windows = (max(0, n_batch - 6), n_batch - 1)
    caps = sorted({bsz * (b + 1) for b in range(n_batch)})
    warmup = min(n_req // 4, 2 * max_batch)
    for qps, deadline_ms, mix in configs:
        gw = Gateway(idx, GatewayConfig(
            deadline_ms=deadline_ms, slo_p99_ms=SLO_P99_MS,
            max_batch=max_batch, k=K))
        gw.prewarm(caps)
        rng = np.random.default_rng(int(qps * 1000 + deadline_ms * 10))
        Q = np.cumsum(rng.normal(size=(n_req, LEN)), axis=1,
                      dtype=np.float64).astype(np.float32)
        measured, wall_s, retraces = _drive(gw, Q, qps, mix, rng, windows,
                                            warmup, engine)
        gs = gw.snapshot_stats()
        gw.close()
        lat = np.array([r.latency_ms for r in measured])
        shed_rate = float(np.mean([r.shed for r in measured]))
        achieved = len(measured) / max(wall_s, 1e-9)
        bhist = "|".join(f"{s}:{c}" for s, c in
                         sorted(gs["batch_hist"].items()))
        row(f"serving/qps{qps:g}_dl{deadline_ms:g}_{mix}",
            float(lat.mean()) * 1e3,
            f"offered_qps={qps:g};achieved_qps={achieved:.0f};"
            f"p50_ms={np.percentile(lat, 50):.2f};"
            f"p95_ms={np.percentile(lat, 95):.2f};"
            f"p99_ms={np.percentile(lat, 99):.2f};"
            f"shed_rate={shed_rate:.3f};trace_count={retraces};"
            f"served={len(measured)};deadline_ms={deadline_ms:g};"
            f"batches={gs['batches']};"
            f"deadline_flushes={gs['deadline_flushes']};"
            f"full_flushes={gs['full_flushes']};"
            f"batch_hist={bhist}")
    _adaptation_sweep(idx, smoke, n_batch)


# ---------------------------------------------------------------- autotune
# Scenario-diversity sweep for the online autotuner: each scenario drives
# the SAME gateway serving path once with the tuner adapting and once per
# fixed knob setting (AutoTunerConfig(forced=...) pins every decision, so
# fixed arms share the identical batch-formation and recall-probe
# machinery). Convergence claim: the adapted run's p99 lands within 10% of
# the best fixed arm that meets the recall target, at equal-or-better
# measured recall, and the full decision/observation trace goes into
# BENCH_serving.json under `adaptation_traces`.
ADAPT_TARGET = 0.9
FIXED_ARMS = (Knobs("exact"), Knobs("approx", 1), Knobs("approx", 2),
              Knobs("approx", 8))
SMOKE_FIXED_ARMS = (Knobs("exact"), Knobs("approx", 2))


def _adapt_drive(gw, Q, qps, kwfn, warmup, rng, on_submit=None):
    """Submit ``len(Q)`` requests (kwargs from ``kwfn(i)``) at Poisson
    ``qps``; returns measured responses + the tuner-trace index where the
    measured phase starts."""
    tickets, mark = [], 0
    for i in range(Q.shape[0]):
        if on_submit is not None:
            on_submit(i)
        tickets.append(gw.submit(Q[i], **kwfn(i)))
        if i + 1 == warmup:
            for t in tickets:
                t.result(timeout=300)  # drain: warm-up compiles settle
            gw.reset_slo_window()
            mark = len(gw.tuner.trace())
        time.sleep(rng.exponential(1.0 / qps))
    resps = [t.result(timeout=300) for t in tickets]
    return resps[warmup:], mark


def _run_adapt(idx, caps, max_batch, Q, kwfn_for, warmup, seed, tuner_cfg,
               qps, burst=None):
    """One gateway run (adapted or fixed-arm) -> measured metrics dict."""
    # a wide deadline keeps batches large at moderate offered load: the
    # per-batch engine dispatch and the recall probes (one exact shadow
    # query per probed group) amortize over ~qps*deadline requests, and
    # steady-state p99 (~deadline + service) stays under the shed SLO —
    # arms are compared on service cost, not on probe-induced queueing
    # SLO shedding stays disarmed (high gate): the overload sweep above
    # covers shed behavior; here a shed would reroute exact traffic and
    # confound the adapted-vs-fixed-arm comparison with queueing noise
    # deadline 40ms: partial batches form at ~1/deadline regardless of
    # offered load, and each formed batch pays a ~15ms engine dispatch —
    # a wide deadline keeps that batch rate (and so utilization) low
    # enough that p99 measures arm service cost, not queue growth
    gw = Gateway(idx, GatewayConfig(
        deadline_ms=40.0, slo_p99_ms=250.0, max_batch=max_batch, k=K,
        autotune=True, autotune_cfg=tuner_cfg))
    gw.prewarm(caps)
    rng = np.random.default_rng(seed)
    on_submit = None
    if burst is not None:
        burst_at, burst_fn = burst

        def on_submit(i):
            if i in burst_at:
                burst_fn()
    measured, mark = _adapt_drive(gw, Q, qps, kwfn_for(seed), warmup,
                                  rng, on_submit)
    trace = gw.tuner.trace()
    counters = gw.tuner.counters()
    gw.close()
    lat = np.array([r.latency_ms for r in measured])
    # client-facing recall only: served observations (what clients got,
    # probes measuring the served arm, shed overrides) — exploration
    # shadows measure arms no client was served and must not count
    obs = [e["observed_recall"] for e in trace[mark:]
           if e["kind"] == "observe" and e["observed_recall"] is not None
           and e.get("served", True)]
    return {
        "p50_ms": float(np.percentile(lat, 50)),
        "p99_ms": float(np.percentile(lat, 99)),
        "recall": float(np.mean(obs)) if obs else 1.0,
        "trace": trace,
        "counters": counters,
    }


def _adaptation_sweep(idx, smoke: bool, n_batch: int):
    engine = get_engine()  # noqa: F841 — keeps the engine/caches alive
    max_batch = 16 if smoke else 32
    n_req = 80 if smoke else 400
    # offered load BELOW the gateway's saturation point: the convergence
    # comparison is about tier/knob choice, not queueing collapse (the
    # saturation sweep above covers overload; past saturation every run
    # measures queue growth plus probe/shadow overhead, not arm quality)
    qps = 300.0 if smoke else 250.0
    # the measured phase is the CONVERGED half: workload keys fragment by
    # batch rung, so the bandit needs the first half of the run to re-fit
    # every profile's models — the trace still records the whole run, and
    # the convergence row compares post-adaptation behavior
    warmup = n_req // 2
    burst_bsz = 100 if smoke else 500
    arms = SMOKE_FIXED_ARMS if smoke else FIXED_ARMS
    d = int(idx.cfg.summarization.series_len)
    base_rng = np.random.default_rng(777)
    Qwalk = np.cumsum(base_rng.normal(size=(n_req, d)), axis=1,
                      dtype=np.float64).astype(np.float32)
    # skewed keys: most queries are small perturbations of STORED series —
    # the approximate tier's measured recall runs far above its static
    # prior curve, which is exactly the model mismatch the tuner must
    # discover (the static tree would keep over-reading blocks)
    stored = np.cumsum(np.random.default_rng(100).normal(
        size=(n_req, d)), axis=1, dtype=np.float64).astype(np.float32)
    Qskew = (stored + 0.01 * base_rng.normal(size=stored.shape)
             ).astype(np.float32)
    def ingest_burst():
        b = ingest_burst.n
        ingest_burst.n += 1
        x = np.cumsum(np.random.default_rng(5_000 + b).normal(
            size=(burst_bsz, d)), axis=1,
            dtype=np.float64).astype(np.float32)
        idx.ingest(x, np.full(burst_bsz, 1_000 + b, np.int64))
    ingest_burst.n = 0

    def kw_plain(target):
        def mk(seed):
            return lambda i: {"target_recall": target}
        return mk

    def kw_shifting(w1, w2):
        def mk(seed):
            return lambda i: {"target_recall": ADAPT_TARGET,
                              "window": (w1 if i < n_req // 2 else w2)}
        return mk

    def kw_mixed(windows):
        def mk(seed):
            rng = np.random.default_rng(seed + 13)

            def kw(i):
                r = rng.random()
                out = {}
                if r < 0.5:
                    out["target_recall"] = ADAPT_TARGET
                elif r < 0.65:
                    out.update(target_recall=ADAPT_TARGET,
                               latency_budget_ms=0.05)  # conflicting tenant
                if rng.random() < 0.4:
                    out["window"] = windows
                return out
            return kw
        return mk

    t_lo, t_hi = 0, n_batch - 1  # ingest timestamps span 0..n_batch-1
    windows = (max(0, t_hi - 6), t_hi)
    scenarios = [
        ("skewed_keys", Qskew, kw_plain(ADAPT_TARGET), None, ADAPT_TARGET),
    ]
    if not smoke:
        # relaxed tenant: a target low enough that shallow approx arms are
        # genuinely feasible once measured — the converged arm should be
        # an approx depth, not exact (arm diversity across scenarios)
        scenarios += [
            ("relaxed_recall", Qskew, kw_plain(0.45), None, 0.45),
            ("shifting_windows", Qwalk,
             kw_shifting((max(0, t_hi - 3), t_hi), (t_lo, t_hi)), None,
             ADAPT_TARGET),
            ("mixed_tenants", Qwalk, kw_mixed(windows), None, ADAPT_TARGET),
        ]
    # bursty LAST: its ingest permanently grows the shared store, so any
    # scenario after it would run against a slower exact tier
    scenarios += [
        ("bursty_ingest", Qwalk, kw_plain(ADAPT_TARGET),
         ({n_req // 3, (2 * n_req) // 3}, ingest_burst), ADAPT_TARGET),
    ]
    traces: dict = {}
    for name, Q, kwfn_for, burst, target in scenarios:
        # caps must cover every store size the bursts will grow into over
        # ALL of this scenario's runs (fixed arms + adapted, 2 bursts
        # each) — an uncovered arena rung means mid-run compiles
        n_runs = len(arms) + 1
        caps = sorted({int(idx.raw.n) + j * burst_bsz
                       for j in range(2 * n_runs + 1)})
        fixed = {}
        for arm in arms:  # fixed arms first, adapted last: the shared
            # store only ever grows, so the adapted run faces the
            # largest (slowest-exact) index — conservative for the claim
            fixed[arm.label()] = _run_adapt(
                idx, caps, max_batch, Q, kwfn_for, warmup, seed=901,
                tuner_cfg=AutoTunerConfig(forced=arm), qps=qps,
                burst=burst)
        adapted = _run_adapt(
            idx, caps, max_batch, Q, kwfn_for, warmup, seed=901,
            tuner_cfg=AutoTunerConfig(seed=0), qps=qps, burst=burst)
        ok = {a: m for a, m in fixed.items() if m["recall"] >= target}
        if not ok:
            # no fixed arm reaches the target: the fair baseline is the
            # cheapest arm in the max-recall band (the tuner's conflict
            # contract serves max recall), not the cheapest arm outright
            top = max(m["recall"] for m in fixed.values())
            ok = {a: m for a, m in fixed.items()
                  if m["recall"] >= top - 0.02}
        best = min(ok, key=lambda a: ok[a]["p99_ms"])
        ratio = adapted["p99_ms"] / max(fixed[best]["p99_ms"], 1e-9)
        traces[name] = {
            "adapted": adapted["trace"][-800:],
            "counters": adapted["counters"],
            "fixed": {a: {"p99_ms": round(m["p99_ms"], 3),
                          "recall": round(m["recall"], 4)}
                      for a, m in fixed.items()},
        }
        row(f"serving/adapt_{name}", adapted["p50_ms"] * 1e3,
            f"adapted_p99_ms={adapted['p99_ms']:.2f};"
            f"adapted_recall={adapted['recall']:.4f};"
            f"best_fixed={best};"
            f"best_fixed_p99_ms={fixed[best]['p99_ms']:.2f};"
            f"best_fixed_recall={fixed[best]['recall']:.4f};"
            f"p99_vs_best={ratio:.3f};"
            f"explores={adapted['counters']['explores']};"
            f"probes={adapted['counters']['probes']};"
            f"epoch_refits={adapted['counters']['epoch_refits']}")
    EXTRAS["adaptation_traces"] = traces
