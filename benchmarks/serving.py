"""Closed-loop serving benchmark: the dynamic-batching gateway under a
Poisson arrival sweep (offered QPS x deadline x tier mix).

Each config drives ``core.gateway`` with an open-loop Poisson client
stream against a pre-built streaming index and reports the client-observed
latency-vs-throughput point: p50/p95/p99 latency, achieved QPS, shed rate,
the formed-batch histogram, and the engine's post-warm-up retrace count —
which must stay 0: the gateway's ladder rungs are exactly the engine's
prewarmed batch buckets, so steady-state serving never compiles.

The sweep shape is the paper's serving story: at low offered load p99
stays under the deadline-flush bound (one deadline plus one batch service
time); past saturation the gateway degrades gracefully — the shed rate
rises and p99 stays bounded — instead of collapsing into an unbounded
queue.
"""
import time

import numpy as np

from repro.core import (Gateway, GatewayConfig, StreamConfig, StreamingIndex,
                        SummarizationConfig)
from repro.core.verify_engine import get_engine

from .common import row

LEN = 128
CFG = SummarizationConfig(series_len=LEN, n_segments=16, card_bits=8)
N_BATCH, BSZ = 20, 1000
K = 5
SLO_P99_MS = 60.0
# (offered qps, deadline_ms, mix): a latency-vs-throughput curve at fixed
# deadline, a deadline-sensitivity pair at fixed load, and the tier mixes
CONFIGS = (
    (500, 5.0, "exact"),
    (2000, 5.0, "exact"),
    (8000, 5.0, "exact"),
    (2000, 2.0, "exact"),
    (2000, 10.0, "exact"),
    (2000, 5.0, "mixed"),
    (8000, 5.0, "mixed"),
)
SMOKE_CONFIGS = ((300, 5.0, "exact"), (300, 5.0, "mixed"))


def _mix_kwargs(mix: str, rng, windows):
    """Deterministic tenant mix. ``mixed`` adds recall-targeted requests,
    conflicting recall+latency targets (always shed), and window
    constraints (per-tier/per-window sub-batch splits)."""
    kw = {}
    if mix == "mixed":
        r = rng.random()
        if r < 0.2:
            kw["target_recall"] = 0.9
        elif r < 0.3:
            kw.update(target_recall=0.9, latency_budget_ms=0.05)
        if rng.random() < 0.5:
            kw["window"] = windows
    return kw


def _drive(gw, Q, qps, mix, rng, windows, warmup, engine):
    """Submit ``len(Q)`` requests at Poisson-offered ``qps``; returns the
    measured (post-warm-up) responses, the wall time of the measured
    phase, and the engine retraces during it."""
    tickets = []
    traces0 = None
    t_meas0 = None
    for i in range(Q.shape[0]):
        tickets.append(gw.submit(Q[i], **_mix_kwargs(mix, rng, windows)))
        if i + 1 == warmup:
            for t in tickets:
                t.result(timeout=300)  # drain: warm-up compiles settle
            gw.reset_slo_window()  # compile latencies must not trip the gate
            traces0 = engine.stats["traces"]
            t_meas0 = time.perf_counter()
        time.sleep(rng.exponential(1.0 / qps))
    resps = [t.result(timeout=300) for t in tickets]
    t_meas1 = time.perf_counter()
    retraces = engine.stats["traces"] - (traces0 if traces0 is not None
                                         else engine.stats["traces"])
    return resps[warmup:], (t_meas1 - (t_meas0 or t_meas1)), retraces


def main(smoke: bool = False):
    n_batch, bsz = (6, 200) if smoke else (N_BATCH, BSZ)
    n_req = 60 if smoke else 400
    max_batch = 16 if smoke else 32
    configs = SMOKE_CONFIGS if smoke else CONFIGS
    idx = StreamingIndex(StreamConfig(
        scheme="BTP", summarization=CFG, buffer_entries=1024 if smoke else 4096,
        growth_factor=4, block_size=512))
    for b in range(n_batch):
        rng = np.random.default_rng(100 + b)
        x = np.cumsum(rng.normal(size=(bsz, LEN)), axis=1,
                      dtype=np.float64).astype(np.float32)
        idx.ingest(x, np.full(bsz, b, np.int64))
    engine = get_engine()
    windows = (max(0, n_batch - 6), n_batch - 1)
    caps = sorted({bsz * (b + 1) for b in range(n_batch)})
    warmup = min(n_req // 4, 2 * max_batch)
    for qps, deadline_ms, mix in configs:
        gw = Gateway(idx, GatewayConfig(
            deadline_ms=deadline_ms, slo_p99_ms=SLO_P99_MS,
            max_batch=max_batch, k=K))
        gw.prewarm(caps)
        rng = np.random.default_rng(int(qps * 1000 + deadline_ms * 10))
        Q = np.cumsum(rng.normal(size=(n_req, LEN)), axis=1,
                      dtype=np.float64).astype(np.float32)
        measured, wall_s, retraces = _drive(gw, Q, qps, mix, rng, windows,
                                            warmup, engine)
        gs = gw.snapshot_stats()
        gw.close()
        lat = np.array([r.latency_ms for r in measured])
        shed_rate = float(np.mean([r.shed for r in measured]))
        achieved = len(measured) / max(wall_s, 1e-9)
        bhist = "|".join(f"{s}:{c}" for s, c in
                         sorted(gs["batch_hist"].items()))
        row(f"serving/qps{qps:g}_dl{deadline_ms:g}_{mix}",
            float(lat.mean()) * 1e3,
            f"offered_qps={qps:g};achieved_qps={achieved:.0f};"
            f"p50_ms={np.percentile(lat, 50):.2f};"
            f"p95_ms={np.percentile(lat, 95):.2f};"
            f"p99_ms={np.percentile(lat, 99):.2f};"
            f"shed_rate={shed_rate:.3f};trace_count={retraces};"
            f"served={len(measured)};deadline_ms={deadline_ms:g};"
            f"batches={gs['batches']};"
            f"deadline_flushes={gs['deadline_flushes']};"
            f"full_flushes={gs['full_flushes']};"
            f"batch_hist={bhist}")
