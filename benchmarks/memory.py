"""Paper §2 'Better Memory vs. Construction Trade-Offs': build cost as the
memory budget shrinks — two-pass external sort degrades gracefully where
buffered top-down insertion thrashes."""
from repro.core import CTree, CTreeConfig, DiskModel, RawStore, SummarizationConfig
from repro.data.synthetic import random_walk

from .common import row, timeit

N, LEN = 40_000, 128
CFG = SummarizationConfig(series_len=LEN, n_segments=16, card_bits=8)


def main(smoke: bool = False):
    n = 2_000 if smoke else N
    X = random_walk(n, LEN, seed=0)
    for frac in (1.0, 0.05) if smoke else (1.0, 0.25, 0.05, 0.01):
        budget = max(64, int(n * frac))

        def build():
            disk = DiskModel()
            raw = RawStore(LEN, disk)
            ids = raw.append(X)
            ct = CTree(CTreeConfig(summarization=CFG, mem_budget_entries=budget), disk)
            rep = ct.bulk_build(X, ids)
            return disk, rep

        us = timeit(lambda: build(), repeat=2)
        disk, rep = build()
        row(f"memory/budget_{frac}", us,
            f"entries={budget};runs={rep.n_runs};passes={rep.n_passes};"
            f"modeled_io_s={disk.modeled_seconds():.3f}")
