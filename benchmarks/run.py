# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import sys
import traceback


def main() -> None:
    from . import construction, kernels_bench, memory, query, roofline, streaming

    print("name,us_per_call,derived")
    for mod in (construction, query, streaming, memory, kernels_bench, roofline):
        try:
            mod.main()
        except Exception:  # noqa: BLE001 — keep the harness running
            name = mod.__name__.split(".")[-1]
            print(f"{name}/ERROR,0.0,", file=sys.stdout)
            traceback.print_exc()


if __name__ == "__main__":
    main()
