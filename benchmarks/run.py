# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV
# and writes machine-readable BENCH_<module>.json perf-trajectory artifacts
# (throughput, recall, modeled I/O per config) so future changes can diff
# performance against the committed numbers.
#
# ``--smoke`` runs every driver at tiny sizes (<60 s total) and asserts the
# output schema, so CI exercises the benchmark code paths instead of leaving
# them hand-run only (a ``slow``-marked pytest invokes this mode).
import argparse
import contextlib
import io
import json
import os
import re
import sys
import time
import traceback

ROW_RE = re.compile(r"^[^,\s][^,]*,\d+(\.\d+)?,[^,]*(;[^,]*)*$")

# modules whose rows form the tracked perf trajectory
ARTIFACT_MODS = ("query", "streaming", "serving")


def _engine_summary() -> dict:
    """Cumulative verification-engine counters (compile churn + transfer
    volume) for the artifact, so perf diffs can tell compute regressions
    from compile/transfer regressions."""
    from repro.core.verify_engine import get_engine

    out = dict(get_engine().stats)
    # copy the served-batch histogram so the artifact snapshot does not
    # alias the engine's live (still-mutating) counter dict
    out["batch_hist"] = {str(kk): v for kk, v in out["batch_hist"].items()}
    return out


def _write_artifact(name: str, rows: list, extras: dict, out_dir: str,
                    smoke: bool) -> None:
    # smoke artifacts get their own (gitignored) name so CI runs never
    # overwrite the committed perf trajectory
    suffix = ".smoke.json" if smoke else ".json"
    path = os.path.join(out_dir, f"BENCH_{name}{suffix}")
    payload = {
        "benchmark": name,
        "smoke": smoke,  # smoke numbers are schema checks, not perf points
        "unix_time": int(time.time()),
        "verify_engine": _engine_summary(),
        "rows": rows,
    }
    for key, val in extras.items():
        if key in payload:
            raise AssertionError(f"EXTRAS key {key!r} collides with the "
                                 "artifact's own payload fields")
        payload[key] = val
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + output-schema assertions")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names (e.g. query,streaming)")
    ap.add_argument(
        "--out-dir",
        default=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        help="where BENCH_<module>.json artifacts are written (repo root)")
    args = ap.parse_args(argv)

    from . import (common, construction, kernels_bench, memory, query, roofline,
                   serving, streaming)

    mods = [construction, query, streaming, serving, memory, kernels_bench,
            roofline]
    if args.only:
        wanted = set(args.only.split(","))
        mods = [m for m in mods if m.__name__.split(".")[-1] in wanted]

    failures = 0
    print("name,us_per_call,derived")
    for mod in mods:
        name = mod.__name__.split(".")[-1]
        common.ROWS.clear()
        common.EXTRAS.clear()
        try:
            if args.smoke:
                buf = io.StringIO()
                with contextlib.redirect_stdout(buf):
                    mod.main(smoke=True)
                out = buf.getvalue()
                for line in filter(None, out.splitlines()):
                    if not ROW_RE.match(line):
                        raise AssertionError(
                            f"{name}: row violates name,us,derived schema: {line!r}"
                        )
                sys.stdout.write(out)
            else:
                mod.main()
            if name in ARTIFACT_MODS:
                _write_artifact(name, list(common.ROWS), dict(common.EXTRAS),
                                args.out_dir, args.smoke)
        except Exception:  # noqa: BLE001 — keep the harness running
            failures += 1
            print(f"{name}/ERROR,0.0,")
            traceback.print_exc()
    return 1 if (args.smoke and failures) else 0


if __name__ == "__main__":
    sys.exit(main())
