# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
# ``--smoke`` runs every driver at tiny sizes (<60 s total) and asserts the
# output schema, so CI exercises the benchmark code paths instead of leaving
# them hand-run only (a ``slow``-marked pytest invokes this mode).
import argparse
import contextlib
import io
import re
import sys
import traceback

ROW_RE = re.compile(r"^[^,\s][^,]*,\d+(\.\d+)?,[^,]*(;[^,]*)*$")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + output-schema assertions")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names (e.g. query,streaming)")
    args = ap.parse_args(argv)

    from . import construction, kernels_bench, memory, query, roofline, streaming

    mods = [construction, query, streaming, memory, kernels_bench, roofline]
    if args.only:
        wanted = set(args.only.split(","))
        mods = [m for m in mods if m.__name__.split(".")[-1] in wanted]

    failures = 0
    print("name,us_per_call,derived")
    for mod in mods:
        name = mod.__name__.split(".")[-1]
        try:
            if args.smoke:
                buf = io.StringIO()
                with contextlib.redirect_stdout(buf):
                    mod.main(smoke=True)
                out = buf.getvalue()
                for line in filter(None, out.splitlines()):
                    if not ROW_RE.match(line):
                        raise AssertionError(
                            f"{name}: row violates name,us,derived schema: {line!r}"
                        )
                sys.stdout.write(out)
            else:
                mod.main()
        except Exception:  # noqa: BLE001 — keep the harness running
            failures += 1
            print(f"{name}/ERROR,0.0,")
            traceback.print_exc()
    return 1 if (args.smoke and failures) else 0


if __name__ == "__main__":
    sys.exit(main())
