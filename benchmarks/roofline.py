"""Aggregate the dry-run JSONs into the roofline table (EXPERIMENTS.md
§Roofline reads this output). One CSV row per (arch x shape x mesh)."""
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(pattern="*.json"):
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def main(smoke: bool = False):
    cells = load_cells()
    if not cells:
        print("roofline/no_dryrun_results,0.0,run repro.launch.dryrun first")
        return
    for c in cells:
        r = c["roofline_s"]
        dom = c["bottleneck"]
        step_s = max(r.values())
        mfu = r["compute"] / step_s if step_s else 0.0
        derived = (
            f"mesh={c['mesh']};compute_s={r['compute']:.4f};memory_s={r['memory']:.4f};"
            f"collective_s={r['collective']:.4f};bottleneck={dom};"
            f"mem_gb={c['mem_per_device']['total_gb']};roofline_frac={mfu:.3f}"
        )
        if "useful_flops_ratio" in c:
            derived += f";useful_ratio={c['useful_flops_ratio']}"
        variant = c.get("variant", "baseline")
        row = f"roofline/{c['arch']}__{c.get('shape','')}__{c['mesh']}__{variant}"
        print(f"{row},{step_s * 1e6:.0f},{derived}")


if __name__ == "__main__":
    main()
