"""Paper figure: query cost across index variants + the materialization
trade-off (space vs time, paper §2), plus the batched top-k engine sweep:
``knn_batch`` (one shared verification pass per (run, batch)) against the
per-query ``knn_exact`` loop across batch sizes."""
import numpy as np

from repro.core import (
    ADSConfig, ADSIndex, CTree, CTreeConfig, DiskModel, RawStore,
    SummarizationConfig,
)
from repro.data.synthetic import random_walk

from .common import row, timeit

N, LEN, NQ = 40_000, 128, 16
BATCH_SIZES = (1, 8, 64, 256)
CFG = SummarizationConfig(series_len=LEN, n_segments=16, card_bits=8)


def main():
    X = random_walk(N, LEN, seed=0)
    Q = random_walk(NQ, LEN, seed=42)

    variants = {}
    for mat in (False, True):
        disk = DiskModel()
        raw = RawStore(LEN, disk)
        ids = raw.append(X)
        ct = CTree(CTreeConfig(summarization=CFG, block_size=1024,
                               materialized=mat), disk)
        ct.bulk_build(X, ids)
        variants[f"ctree_{'mat' if mat else 'nonmat'}"] = (ct, raw, disk)
    disk = DiskModel()
    raw = RawStore(LEN, disk)
    ids = raw.append(X)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=1024), disk)
    ads.insert_batch(X, ids)
    variants["adsfull"] = (ads, raw, disk)

    for name, (idx, raw, disk) in variants.items():
        def exact():
            for q in Q:
                idx.knn_exact(q, k=10, raw=raw)

        def approx():
            for q in Q:
                idx.knn_approx(q, k=10, raw=raw) if name == "adsfull" else \
                    idx.knn_approx(q, k=10, n_blocks=2, raw=raw)

        disk.reset()
        us = timeit(exact, repeat=2) / NQ
        _, st = idx.knn_exact(Q[0], k=10, raw=raw)
        io = disk.modeled_seconds() / (NQ * 2 + 1)
        row(f"query/{name}_exact", us,
            f"modeled_io_s={io:.4f};blocks_visited={st.blocks_visited};"
            f"verified={st.entries_verified}")
        disk.reset()
        us = timeit(approx, repeat=2) / NQ
        row(f"query/{name}_approx", us,
            f"modeled_io_s={disk.modeled_seconds() / (NQ * 2):.5f}")

    # space: the materialization trade-off
    ct_n = variants["ctree_nonmat"][0].index_bytes()
    ct_m = variants["ctree_mat"][0].index_bytes()
    row("query/index_bytes_nonmat", 0.0, f"bytes={ct_n}")
    row("query/index_bytes_mat", 0.0, f"bytes={ct_m};ratio={ct_m / max(ct_n, 1):.1f}")

    # batched top-k engine: batch-size sweep vs the per-query loop
    QB = random_walk(max(BATCH_SIZES), LEN, seed=7)
    for name in ("ctree_mat", "ctree_nonmat"):
        idx, raw, disk = variants[name]
        idx.knn_batch(QB[:4], k=10, raw=raw)  # warm any jit/caches
        for bsz in BATCH_SIZES:
            Qb = QB[:bsz]
            us_batch = timeit(lambda: idx.knn_batch(Qb, k=10, raw=raw), repeat=2)
            us_loop = timeit(
                lambda: [idx.knn_exact(q, k=10, raw=raw) for q in Qb], repeat=2
            )
            _, _, st = idx.knn_batch(Qb, k=10, raw=raw)
            row(
                f"query/{name}_knn_batch_b{bsz}",
                us_batch / bsz,
                f"speedup_vs_loop={us_loop / max(us_batch, 1e-9):.2f};"
                f"loop_us_per_q={us_loop / bsz:.1f};"
                f"verified={st.entries_verified}",
            )
