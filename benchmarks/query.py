"""Paper figure: query cost across index variants + the materialization
trade-off (space vs time, paper §2), plus the batched top-k engine sweep:
``knn_batch`` (one shared verification pass per (run, batch)) against the
per-query ``knn_exact`` loop across batch sizes, and the batched APPROXIMATE
tier: ``knn_approx_batch`` batch-size x n_blocks sweeps reporting recall@10
against the exact oracle alongside throughput."""
import numpy as np

from repro.core import (
    ADSConfig, ADSIndex, CTree, CTreeConfig, DiskModel, RawStore,
    SummarizationConfig, recall_at_k,
)
from repro.data.synthetic import random_walk

from .common import row, timeit, timeit_pcts

N, LEN, NQ = 40_000, 128, 16
BATCH_SIZES = (1, 8, 64, 256)
# the scalar approx rows above use n_blocks=2 (the repo default); sweep
# around it. n_blocks=1 at batch ~n_blocks*40 is degenerate on this dataset
# (64 random queries need all 40 blocks, so there is nothing to coalesce
# away) and is covered by the parity tests instead.
APPROX_N_BLOCKS = (2, 4)
CFG = SummarizationConfig(series_len=LEN, n_segments=16, card_bits=8)


def main(smoke: bool = False):
    n, nq = (2_000, 4) if smoke else (N, NQ)
    batch_sizes = (1, 8) if smoke else BATCH_SIZES
    approx_nb = (1, 2) if smoke else APPROX_N_BLOCKS
    X = random_walk(n, LEN, seed=0)
    Q = random_walk(nq, LEN, seed=42)

    variants = {}
    for mat in (False, True):
        disk = DiskModel()
        raw = RawStore(LEN, disk)
        ids = raw.append(X)
        ct = CTree(CTreeConfig(summarization=CFG, block_size=1024,
                               materialized=mat), disk)
        ct.bulk_build(X, ids)
        variants[f"ctree_{'mat' if mat else 'nonmat'}"] = (ct, raw, disk)
    disk = DiskModel()
    raw = RawStore(LEN, disk)
    ids = raw.append(X)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=1024), disk)
    ads.insert_batch(X, ids)
    variants["adsfull"] = (ads, raw, disk)

    for name, (idx, raw, disk) in variants.items():
        def exact():
            for q in Q:
                idx.knn_exact(q, k=10, raw=raw)

        def approx():
            for q in Q:
                idx.knn_approx(q, k=10, raw=raw) if name == "adsfull" else \
                    idx.knn_approx(q, k=10, n_blocks=2, raw=raw)

        disk.reset()
        us = timeit(exact, repeat=2) / nq
        _, st = idx.knn_exact(Q[0], k=10, raw=raw)
        io = disk.modeled_seconds() / (nq * 2 + 1)
        row(f"query/{name}_exact", us,
            f"modeled_io_s={io:.4f};blocks_visited={st.blocks_visited};"
            f"verified={st.entries_verified}")
        disk.reset()
        us = timeit(approx, repeat=2) / nq
        row(f"query/{name}_approx", us,
            f"modeled_io_s={disk.modeled_seconds() / (nq * 2):.5f}")

    # space: the materialization trade-off
    ct_n = variants["ctree_nonmat"][0].index_bytes()
    ct_m = variants["ctree_mat"][0].index_bytes()
    row("query/index_bytes_nonmat", 0.0, f"bytes={ct_n}")
    row("query/index_bytes_mat", 0.0, f"bytes={ct_m};ratio={ct_m / max(ct_n, 1):.1f}")

    # batched top-k engine: batch-size sweep vs the per-query loop. Each
    # config also records the verification engine's compile/transfer costs
    # (trace_count + host<->device bytes during the measured calls), so
    # compile-churn or transfer regressions show up in the artifact.
    from repro.core.verify_engine import get_engine

    engine = get_engine()
    QB = random_walk(max(BATCH_SIZES), LEN, seed=7)
    for name in ("ctree_mat", "ctree_nonmat"):
        idx, raw, disk = variants[name]
        for bsz in batch_sizes:  # warm the trace cache across the sweep's
            idx.knn_batch(QB[:bsz], k=10, raw=raw)  # shape buckets
        for bsz in batch_sizes:
            Qb = QB[:bsz]
            # small batches are sub-20ms calls where 2-sample medians drift
            # between the batch and loop windows; more reps stabilize them
            reps = 7 if bsz <= 8 else 3
            es0 = dict(engine.stats)
            us_batch, p50_b, p99_b = timeit_pcts(
                lambda: idx.knn_batch(Qb, k=10, raw=raw), repeat=reps)
            es1 = dict(engine.stats)
            us_loop = timeit(
                lambda: [idx.knn_exact(q, k=10, raw=raw) for q in Qb],
                repeat=reps,
            )
            disk.reset()
            _, _, st = idx.knn_batch(Qb, k=10, raw=raw)
            row(
                f"query/{name}_knn_batch_b{bsz}",
                us_batch / bsz,
                f"speedup_vs_loop={us_loop / max(us_batch, 1e-9):.2f};"
                f"loop_us_per_q={us_loop / bsz:.1f};"
                f"p50_us={p50_b / bsz:.1f};p99_us={p99_b / bsz:.1f};"
                f"verified={st.entries_verified};"
                f"trace_count={es1['traces'] - es0['traces']};"
                f"h2d_bytes={es1['h2d_bytes'] - es0['h2d_bytes']};"
                f"d2h_bytes={es1['d2h_bytes'] - es0['d2h_bytes']};"
                f"modeled_io_s={disk.modeled_seconds() / bsz:.5f}",
            )

    # batched APPROXIMATE tier: batch-size x n_blocks sweep. For each cell:
    # throughput + speedup over the per-query knn_approx loop at equal
    # n_blocks, recall@10 of both paths against the exact oracle (identical
    # by construction — asserted), and the sequential-I/O win.
    for name in ("ctree_mat", "ctree_nonmat"):
        idx, raw, disk = variants[name]
        _, exact_ids, _ = idx.knn_batch(QB, k=10, raw=raw)
        idx.knn_approx_batch(QB[:4], k=10, raw=raw)  # warm the norm caches
        for bsz in batch_sizes:
            Qb = QB[:bsz]
            for nb in approx_nb:
                us_batch, p50_b, p99_b = timeit_pcts(
                    lambda: idx.knn_approx_batch(Qb, k=10, n_blocks=nb, raw=raw),
                    repeat=5,
                )
                us_loop = timeit(
                    lambda: [idx.knn_approx(q, k=10, n_blocks=nb, raw=raw)
                             for q in Qb],
                    repeat=3,
                )
                disk.reset()
                _, batch_ids, st = idx.knn_approx_batch(Qb, k=10, n_blocks=nb,
                                                        raw=raw)
                seq_mb = disk.stats.seq_read_bytes / 1e6
                loop_ids = np.full_like(batch_ids, -1)
                for i, q in enumerate(Qb):
                    res, _ = idx.knn_approx(q, k=10, n_blocks=nb, raw=raw)
                    loop_ids[i, : len(res)] = [g for _, g in res]
                rb = recall_at_k(batch_ids, exact_ids[:bsz])
                rl = recall_at_k(loop_ids, exact_ids[:bsz])
                assert abs(rb - rl) < 1e-9, f"recall drift: batch {rb} loop {rl}"
                row(
                    f"query/{name}_knn_approx_batch_b{bsz}_nb{nb}",
                    us_batch / bsz,
                    f"speedup_vs_loop={us_loop / max(us_batch, 1e-9):.2f};"
                    f"loop_us_per_q={us_loop / bsz:.1f};"
                    f"p50_us={p50_b / bsz:.1f};p99_us={p99_b / bsz:.1f};"
                    f"recall_at10={rb:.3f};loop_recall_at10={rl:.3f};"
                    f"seq_read_mb={seq_mb:.2f};verified={st.entries_verified};"
                    f"modeled_io_s={disk.modeled_seconds() / bsz:.5f}",
                )

    # mixed-precision screen tier: f32 vs bf16 vs int8 device arenas. A
    # fresh non-materialized store per dtype so each sweep uploads its own
    # quantized raw arena; per dtype one row records the arena-build costs
    # (upload h2d bytes, live footprint, and their compression ratio vs the
    # f32 arena — the paper's bandwidth/memory win), and per batch size a
    # row records throughput + certificate fallback rate + recall@10
    # against the host-exact oracle (the exactness contract: recall stays
    # 1.000 at every dtype, quantized or not).
    arena_costs = {}
    dt_variants = {}
    for dt in ("f32", "bf16", "int8"):
        disk = DiskModel()
        raw = RawStore(LEN, disk, screen_dtype=dt)
        ids = raw.append(X)
        ct = CTree(CTreeConfig(summarization=CFG, block_size=1024,
                               materialized=False, screen_dtype=dt), disk)
        ct.bulk_build(X, ids)
        es0 = dict(engine.stats)
        # 16 queries: above the engine's batch floor, so the warm call
        # uploads the arena even at smoke sizes
        ct.knn_batch(QB[:16], k=10, raw=raw)
        es1 = dict(engine.stats)
        arena_costs[dt] = {
            "h2d": es1["h2d_bytes"] - es0["h2d_bytes"],
            "arena": es1["arena_bytes"] - es0["arena_bytes"],
        }
        dt_variants[dt] = (ct, raw, disk)
    for dt, cost in arena_costs.items():
        row(f"query/screen_{dt}_arena", 0.0,
            f"upload_h2d_bytes={cost['h2d']};arena_bytes={cost['arena']};"
            f"h2d_ratio_vs_f32="
            f"{arena_costs['f32']['h2d'] / max(cost['h2d'], 1):.2f};"
            f"arena_ratio_vs_f32="
            f"{arena_costs['f32']['arena'] / max(cost['arena'], 1):.2f}")
    ct_f32, raw_f32, _ = dt_variants["f32"]
    _, oracle_ids, _ = ct_f32.knn_batch(QB, k=10, raw=raw_f32,
                                        backend="numpy")
    for dt, (ct, raw, disk) in dt_variants.items():
        for bsz in batch_sizes:  # warm the trace cache across the sweep
            ct.knn_batch(QB[:bsz], k=10, raw=raw)
        for bsz in batch_sizes:
            Qb = QB[:bsz]
            reps = 7 if bsz <= 8 else 3
            es0 = dict(engine.stats)
            us, p50_b, p99_b = timeit_pcts(
                lambda: ct.knn_batch(Qb, k=10, raw=raw), repeat=reps)
            es1 = dict(engine.stats)
            _, got_ids, _ = ct.knn_batch(Qb, k=10, raw=raw)
            # fallback_rate = fraction of device-screened queries the
            # certificate sent to the host re-screen (a batch can take
            # several fused passes, so `screened` — not reps*bsz — is the
            # denominator)
            fb = es1["fallbacks"] - es0["fallbacks"]
            sc = es1["screened"] - es0["screened"]
            rec = recall_at_k(got_ids, oracle_ids[:bsz])
            assert rec == 1.0, f"screen dtype {dt} broke exactness: {rec}"
            row(f"query/screen_{dt}_knn_batch_b{bsz}", us / bsz,
                f"p50_us={p50_b / bsz:.1f};p99_us={p99_b / bsz:.1f};"
                f"recall_at10={rec:.3f};"
                f"fallback_rate={fb / max(sc, 1):.3f};"
                f"h2d_bytes={es1['h2d_bytes'] - es0['h2d_bytes']};"
                f"d2h_bytes={es1['d2h_bytes'] - es0['d2h_bytes']}")
