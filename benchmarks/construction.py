"""Paper figure: index construction cost — CTree/CLSM (bottom-up, sorted,
sequential I/O) vs ADSFull/ADS+ (top-down inserts, random I/O).

Reports wall time on this host AND the modeled-disk seconds (the paper's
currency: 500 MB/s seq, 10k IOPS random), plus random-op counts.
"""
import numpy as np

from repro.core import (
    ADSConfig, ADSIndex, CLSM, CLSMConfig, CTree, CTreeConfig, DiskModel,
    RawStore, SummarizationConfig,
)
from repro.data.synthetic import random_walk

from .common import row, timeit

N, LEN = 40_000, 128
CFG = SummarizationConfig(series_len=LEN, n_segments=16, card_bits=8)


def main(smoke: bool = False):
    n = 2_000 if smoke else N
    X = random_walk(n, LEN, seed=0)

    def build_ctree(materialized):
        disk = DiskModel()
        raw = RawStore(LEN, disk)
        ids = raw.append(X)
        ct = CTree(CTreeConfig(summarization=CFG, block_size=1024,
                               materialized=materialized,
                               mem_budget_entries=n // 4), disk)
        ct.bulk_build(X, ids)
        return disk

    def build_clsm():
        disk = DiskModel()
        raw = RawStore(LEN, disk)
        lsm = CLSM(CLSMConfig(summarization=CFG, buffer_entries=4096,
                              growth_factor=4, block_size=512), disk)
        for i in range(0, n, 4096):
            c = X[i : i + 4096]
            lsm.insert(c, raw.append(c), np.full(len(c), i, np.int64))
        return disk

    def build_ads(mode, leaf):
        disk = DiskModel()
        raw = RawStore(LEN, disk)
        ids = raw.append(X)
        ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=leaf, mode=mode), disk)
        ads.insert_batch(X, ids)
        return disk

    for name, fn in [
        ("build_ctree_nonmat", lambda: build_ctree(False)),
        ("build_ctree_mat", lambda: build_ctree(True)),
        ("build_clsm_nonmat", build_clsm),
        ("build_adsfull", lambda: build_ads("full", 1024)),
        ("build_adsplus", lambda: build_ads("adaptive", 8192)),
    ]:
        us = timeit(fn, repeat=2)
        disk = fn()
        row(f"construction/{name}", us,
            f"modeled_io_s={disk.modeled_seconds():.3f};rand_ops={disk.stats.rand_ops};"
            f"seq_mb={disk.stats.seq_read_bytes + disk.stats.seq_write_bytes >> 20}")
