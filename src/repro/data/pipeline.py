"""Deterministic, resumable, shardable data pipeline.

Every batch is a pure function of (seed, step) — there is no iterator state
to checkpoint: restoring a run at step N regenerates exactly the batches a
non-interrupted run would have seen (tested bitwise in tests/test_pipeline).
Per-host sharding slices the global batch by host id, matching how a
multi-host pod feeds ``jax.make_array_from_process_local_data``.

The pipeline also exposes a Coconut hook: any 1-D series view of the stream
(raw feature frames, token-embedding traces) can be teed into a
StreamingIndex for windowed nearest-neighbor exploration of the training
stream — the paper's streaming scenario as a framework feature.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..models.transformer import ModelConfig


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class TokenPipeline:
    """Synthetic token stream for LM training (stateless-resumable)."""

    def __init__(self, cfg: PipelineConfig, model_cfg: ModelConfig):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide by n_hosts")
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, self.cfg.host_id])
        )

    def batch(self, step: int) -> dict:
        rng = self._rng(step)
        mc = self.model_cfg
        b, s = self.local_batch, self.cfg.seq_len
        z = rng.zipf(1.3, size=(b, s))
        tokens = np.minimum(z - 1, mc.vocab - 1).astype(np.int32)
        out = {"tokens": tokens}
        if mc.frontend == "vision":
            out["tokens"] = tokens[:, : s - mc.n_vis_tokens]
            out["patches"] = rng.standard_normal(
                (b, mc.n_vis_tokens, mc.d_frontend)
            ).astype(np.float32)
        elif mc.frontend == "audio":
            out = {
                "features": rng.standard_normal((b, s, mc.d_frontend)).astype(np.float32),
                "targets": rng.integers(0, mc.vocab, (b, s)).astype(np.int32),
                "mask": (rng.random((b, s)) < 0.5),
            }
        return out

    def series_view(self, batch: dict, series_len: int) -> Optional[np.ndarray]:
        """A 1-D data-series view of the batch for Coconut indexing (the
        exploration hook): audio frames directly; otherwise token-id traces."""
        if "features" in batch:
            x = batch["features"][..., 0]
        else:
            x = batch["tokens"].astype(np.float32)
        s = x.shape[1]
        if s < series_len:
            return None
        n = s // series_len
        return x[:, : n * series_len].reshape(-1, series_len)
