"""Synthetic data generators.

Data-series generators mirror the paper's demo datasets: random-walk series
("synthetic" in the GUI), astronomy-like periodic mixtures (scenario 1) and
seismic burst streams a la IRIS (scenario 2). Token/feature generators feed
the LM training substrate.
"""
from __future__ import annotations

import numpy as np


def random_walk(n: int, length: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, length)).astype(np.float32).cumsum(axis=1)


def astronomy(n: int, length: int, seed: int = 0) -> np.ndarray:
    """Periodic light-curve-like mixtures: sinusoids + transient dips/bursts."""
    rng = np.random.default_rng(seed)
    t = np.linspace(0, 1, length, dtype=np.float32)
    freq = rng.uniform(1, 12, (n, 1)).astype(np.float32)
    phase = rng.uniform(0, 2 * np.pi, (n, 1)).astype(np.float32)
    amp = rng.uniform(0.5, 2.0, (n, 1)).astype(np.float32)
    base = amp * np.sin(2 * np.pi * freq * t[None, :] + phase)
    # transient events (supernova-like rise/decay) on ~20% of series
    has_event = rng.random(n) < 0.2
    c = rng.uniform(0.2, 0.8, (n, 1)).astype(np.float32)
    wdt = rng.uniform(0.02, 0.1, (n, 1)).astype(np.float32)
    ev = 3.0 * np.exp(-np.square(t[None, :] - c) / (2 * wdt ** 2))
    base = base + has_event[:, None] * ev
    return (base + 0.1 * rng.standard_normal((n, length))).astype(np.float32)


def seismic(n: int, length: int, seed: int = 0, quake_frac: float = 0.1) -> np.ndarray:
    """Seismic-like streams: low noise floor with rare high-energy bursts
    (exponentially decaying oscillation — the 'earthquake' pattern)."""
    rng = np.random.default_rng(seed)
    t = np.arange(length, dtype=np.float32)
    noise = 0.05 * rng.standard_normal((n, length)).astype(np.float32)
    is_q = rng.random(n) < quake_frac
    onset = rng.integers(0, max(1, length // 2), n)
    f = rng.uniform(0.05, 0.25, (n, 1)).astype(np.float32)
    decay = rng.uniform(0.01, 0.05, (n, 1)).astype(np.float32)
    rel = t[None, :] - onset[:, None]
    burst = np.where(
        rel >= 0,
        np.exp(-decay * np.maximum(rel, 0)) * np.sin(2 * np.pi * f * np.maximum(rel, 0)),
        0.0,
    ).astype(np.float32)
    return noise + is_q[:, None] * burst


def token_batch(rng: np.random.Generator, batch: int, seq: int, vocab: int) -> np.ndarray:
    """Zipfian token ids — heavy-tailed like natural text."""
    z = rng.zipf(1.3, size=(batch, seq))
    return np.minimum(z - 1, vocab - 1).astype(np.int32)
