"""Train / prefill / decode step factories with microbatched grad
accumulation, remat, and sharding-friendly loss computation.

The cross-entropy is computed in the "one-hot einsum" form so the vocab
axis can stay sharded over the "model" mesh axis end-to-end (the gather
form would force an all-gather of the logits).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import shardctx
from .transformer import (
    ModelConfig,
    decode_step,
    forward,
    logits_fn,
    prefill,
)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    grad_accum: int = 1
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    lb_loss_weight: float = 0.01  # MoE load-balance aux
    remat: bool = True
    compression: Optional[str] = None  # None | "int8" | "topk"


def _shift_labels(tokens: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Next-token labels + validity mask (last position dropped)."""
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)
    return labels, mask


def _xent(cfg: ModelConfig, logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray):
    """Masked mean cross-entropy; one-hot einsum form (vocab-sharding safe)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    onehot = jax.nn.one_hot(labels, cfg.vocab_padded, dtype=jnp.float32)
    onehot = shardctx.constrain(onehot, shardctx.DP, None, "model")
    ll = jnp.einsum("bsv,bsv->bs", lf, onehot)
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, cfg: ModelConfig, batch: dict, lb_weight: float = 0.01,
            remat: bool = False):
    hidden, lb, _ = forward(params, cfg, batch, remat=remat)
    if cfg.frontend == "audio":
        logits = logits_fn(params, cfg, hidden)
        mask = batch["mask"].astype(jnp.float32)
        loss = _xent(cfg, logits, batch["targets"], mask)
    elif cfg.frontend == "vision":
        # loss only over the text positions (after the n_vis image tokens)
        text_h = hidden[:, cfg.n_vis_tokens :, :]
        logits = logits_fn(params, cfg, text_h)
        labels, mask = _shift_labels(batch["tokens"])
        loss = _xent(cfg, logits, labels, mask)
    else:
        logits = logits_fn(params, cfg, hidden)
        labels, mask = _shift_labels(batch["tokens"])
        loss = _xent(cfg, logits, labels, mask)
    return loss + lb_weight * lb, {"xent": loss, "lb": lb}


def make_loss_and_grad(cfg: ModelConfig, tcfg: TrainConfig):
    def lg(params, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, tcfg.lb_loss_weight, tcfg.remat),
            has_aux=True,
        )(params)
        return loss, aux, grads

    return lg


def microbatched_grads(cfg: ModelConfig, tcfg: TrainConfig, params, batch: dict,
                       param_gather=None, grad_constrain=None):
    """Grad-accumulate over tcfg.grad_accum microbatches with a scan.

    batch arrays are (B, ...); B must divide by grad_accum. Grads in f32.

    ZeRO-1 mode (param_gather + grad_constrain set by the launch layer):
    FSDP-sharded params are all-gathered ONCE before the microbatch scan
    (instead of once per microbatch inside it), and each microbatch's grads
    are immediately constrained back to the sharded layout, so accumulation
    happens post-reduce-scatter — cutting weight all-gather volume by the
    grad_accum factor at the cost of holding one unsharded bf16 weight copy.
    """
    g = tcfg.grad_accum
    lg = make_loss_and_grad(cfg, tcfg)
    pg = param_gather(params) if param_gather is not None else params
    shard_g = grad_constrain if grad_constrain is not None else (lambda t: t)
    if g == 1:
        loss, aux, grads = lg(pg, batch)
        return loss, aux, shard_g(jax.tree.map(lambda x: x.astype(jnp.float32), grads))

    def resh(x):
        b = x.shape[0]
        return x.reshape((g, b // g) + x.shape[1:])

    mbatch = jax.tree.map(resh, batch)
    zero_grads = shard_g(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    ))

    def body(carry, mb):
        acc, loss_acc = carry
        loss, aux, grads = lg(pg, mb)
        grads = shard_g(jax.tree.map(lambda x: x.astype(jnp.float32), grads))
        acc = jax.tree.map(lambda a, gr: a + gr / g, acc, grads)
        return (acc, loss_acc + loss / g), aux

    (grads, loss), auxs = jax.lax.scan(body, (zero_grads, jnp.float32(0.0)), mbatch)
    aux = jax.tree.map(lambda x: x.mean(), auxs)
    return loss, aux, grads


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, optimizer,
                    param_gather=None, grad_constrain=None):
    """optimizer: repro.train.optimizer.AdamW instance."""

    def train_step(params, opt_state, batch, step):
        loss, aux, grads = microbatched_grads(
            cfg, tcfg, params, batch, param_gather, grad_constrain
        )
        params, opt_state, gnorm = optimizer.update(params, grads, opt_state, step)
        metrics = {"loss": loss, "grad_norm": gnorm, **aux}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return prefill(params, cfg, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, token):
        return decode_step(params, cfg, cache, token)

    return serve_step
