"""RG-LRU recurrent block (RecurrentGemma / Griffin).

    r_t = sigmoid(W_a a_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x a_t + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t  (c = 8)
    h_t = exp(log a_t) * h_{t-1} + sqrt(1 - exp(2 log a_t)) * (i_t * a_t)

The elementwise linear recurrence is evaluated with jax.lax.associative_scan
over time (parallel prefix — the TPU-native alternative to the sequential
CUDA linear-recurrence kernel). The block is: in-proj (x + gate branches),
causal depthwise conv1d(width 4), RG-LRU, gated out-proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init

RGLRU_C = 8.0
CONV_W = 4


def rglru_init(keygen, d_model: int, d_rnn: int):
    return {
        "w_in": dense_init(keygen(), (d_model, d_rnn)),
        "w_gate": dense_init(keygen(), (d_model, d_rnn)),
        "conv_w": (jax.random.normal(keygen(), (CONV_W, d_rnn), jnp.float32) * 0.1),
        "conv_b": jnp.zeros((d_rnn,), jnp.float32),
        "w_a": dense_init(keygen(), (d_rnn, d_rnn)),
        "b_a": jnp.zeros((d_rnn,), jnp.float32),
        "w_x": dense_init(keygen(), (d_rnn, d_rnn)),
        "b_x": jnp.zeros((d_rnn,), jnp.float32),
        "lam": jnp.full((d_rnn,), 0.7, jnp.float32),  # softplus^-1 target ~ a=0.95
        "w_out": dense_init(keygen(), (d_rnn, d_model)),
    }


def _causal_conv(x, w, b, tail):
    """Depthwise causal conv1d. x: (B, S, R); tail: (B, CONV_W-1, R) history."""
    xc = jnp.concatenate([tail.astype(x.dtype), x], axis=1)  # (B, S+3, R)
    out = sum(
        xc[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
        for i in range(CONV_W)
    )
    return out + b[None, None, :].astype(x.dtype), xc[:, -(CONV_W - 1) :, :]


def _rglru_gates(p, a):
    af = a.astype(jnp.float32)
    r = jax.nn.sigmoid(af @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(af @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"])[None, None, :] * r
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * af)
    return log_a, gated


def rglru_block(p, x, h0, conv_tail):
    """x: (B, S, D); h0: (B, R) f32; conv_tail: (B, 3, R).

    Returns (out (B, S, D), h_last, new_conv_tail)."""
    a = x @ p["w_in"]  # (B, S, R)
    gate = jax.nn.gelu(x @ p["w_gate"])
    a, new_tail = _causal_conv(a, p["conv_w"], p["conv_b"], conv_tail)
    log_a, gated = _rglru_gates(p, a)

    # h_t = exp(log_a_t) h_{t-1} + gated_t, with h_{-1} = h0:
    # fold h0 into the first element, then associative-scan the recurrence.
    coef = jnp.exp(log_a)  # (B, S, R) f32
    first = gated[:, 0, :] + coef[:, 0, :] * h0.astype(jnp.float32)
    gated = jnp.concatenate([first[:, None], gated[:, 1:]], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (coef, gated), axis=1)
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    return out, h[:, -1, :], new_tail


def rglru_decode(p, x, h0, conv_tail):
    """Single-token step. x: (B, 1, D)."""
    a = x @ p["w_in"]
    gate = jax.nn.gelu(x @ p["w_gate"])
    a, new_tail = _causal_conv(a, p["conv_w"], p["conv_b"], conv_tail)
    log_a, gated = _rglru_gates(p, a)
    h = jnp.exp(log_a[:, 0]) * h0.astype(jnp.float32) + gated[:, 0]
    out = (h[:, None].astype(x.dtype) * gate) @ p["w_out"]
    return out, h, new_tail
