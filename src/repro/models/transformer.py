"""Composable decoder/encoder stack covering all 10 assigned architectures.

A model is a layer *pattern* (e.g. gemma3 = 5x local + 1x global attention;
recurrentgemma = rec, rec, local-attn) repeated over the depth, compiled as
a ``lax.scan`` over pattern *groups* so the HLO stays one-group-sized
regardless of depth. Layers outside a whole number of groups live in
``prefix`` (e.g. DeepSeek-MoE's dense layer 0) and ``tail`` (remainder).

Layer kinds: "attn" (global GQA / MLA), "local" (block-banded sliding
window), "rec" (RG-LRU), "rwkv" (WKV6 chunked). The MLP is dense SwiGLU or
MoE per config. Caches mirror the group structure; see make_cache.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .attention import (
    MLADims,
    decode_attention,
    decode_sliding_attention,
    gqa_attention,
    mla_attention,
    mla_decode,
    mla_init,
    sliding_attention,
)
from .common import COMPUTE_DTYPE, KeyGen, dense_init, embed_init, rms_norm, rope, swiglu
from .moe import MoEDims, moe_init, moe_mlp
from .rglru import CONV_W, rglru_block, rglru_decode, rglru_init
from .rwkv6 import (
    rwkv6_channel_mix,
    rwkv6_init,
    rwkv6_time_mix,
    rwkv6_time_mix_decode,
)
from . import shardctx


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    pattern: tuple = ("attn",)
    window: int = 0  # sliding-window size for "local" layers
    moe: Optional[MoEDims] = None
    first_dense: int = 0  # leading layers with dense MLP (DeepSeek-MoE)
    d_ff_dense: int = 0
    mla: Optional[MLADims] = None
    encoder_only: bool = False
    frontend: str = "none"  # none | vision | audio
    n_vis_tokens: int = 0
    d_frontend: int = 0
    rope_theta: float = 1e4
    d_rnn: int = 0
    norm_eps: float = 1e-6
    attention_impl: str = "auto"  # auto | flash | naive (§Perf comparisons)
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // 128) * 128

    @property
    def layer_kinds(self) -> list:
        """Kind of every layer, prefix layers first."""
        kinds = []
        for i in range(self.n_layers - self.first_dense):
            kinds.append(self.pattern[i % len(self.pattern)])
        return ["attn"] * self.first_dense + kinds

    @property
    def n_groups(self) -> int:
        return (self.n_layers - self.first_dense) // len(self.pattern)

    @property
    def tail_kinds(self) -> tuple:
        rem = (self.n_layers - self.first_dense) % len(self.pattern)
        return self.pattern[:rem]

    def n_params(self) -> int:
        """Total parameter count (for 6ND roofline math)."""
        import math

        tree = jax.eval_shape(lambda: init_params(self, jax.random.PRNGKey(0)))
        return sum(math.prod(l.shape) for l in jax.tree.leaves(tree))

    def n_params_active(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        total = self.n_params()
        if self.moe is None:
            return total
        e, k = self.moe.n_experts, self.moe.top_k
        n_moe_layers = self.n_layers - self.first_dense
        per_expert = 3 * self.d_model * self.moe.d_expert
        return total - n_moe_layers * (e - k) * per_expert


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------
def _mlp_init(kg: KeyGen, cfg: ModelConfig, layer_idx: int):
    if cfg.moe is not None and layer_idx >= cfg.first_dense:
        return {"moe": moe_init(kg, cfg.d_model, cfg.moe)}
    d_ff = cfg.d_ff_dense if (cfg.first_dense and layer_idx < cfg.first_dense) else cfg.d_ff
    return {
        "w1": dense_init(kg(), (cfg.d_model, d_ff)),
        "w3": dense_init(kg(), (cfg.d_model, d_ff)),
        "w2": dense_init(kg(), (d_ff, cfg.d_model)),
    }


def _layer_init(kg: KeyGen, cfg: ModelConfig, kind: str, layer_idx: int):
    d, hd = cfg.d_model, cfg.hd
    if kind == "rwkv":
        return {"rwkv": rwkv6_init(kg, d, hd, cfg.d_ff)}
    p = {"ln1": jnp.zeros((d,), jnp.float32), "ln2": jnp.zeros((d,), jnp.float32)}
    if kind == "rec":
        p["rec"] = rglru_init(kg, d, cfg.d_rnn or d)
    elif cfg.mla is not None:
        p["attn"] = mla_init(kg, d, cfg.n_heads, cfg.mla)
    else:
        p["attn"] = {
            "wq": dense_init(kg(), (d, cfg.n_heads * hd)),
            "wk": dense_init(kg(), (d, cfg.n_kv * hd)),
            "wv": dense_init(kg(), (d, cfg.n_kv * hd)),
            "wo": dense_init(kg(), (cfg.n_heads * hd, d)),
        }
    p["mlp"] = _mlp_init(kg, cfg, layer_idx)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    kg = KeyGen(key)
    kinds = cfg.layer_kinds
    params: dict = {"embed": embed_init(kg(), (cfg.vocab_padded, cfg.d_model))}
    if cfg.frontend == "vision":
        params["w_front"] = dense_init(kg(), (cfg.d_frontend, cfg.d_model))
    elif cfg.frontend == "audio":
        params["w_front"] = dense_init(kg(), (cfg.d_frontend, cfg.d_model))
    params["prefix"] = [
        _layer_init(kg, cfg, kinds[i], i) for i in range(cfg.first_dense)
    ]
    # scan groups: stack the per-group params of each pattern position
    groups = []
    base = cfg.first_dense
    plen = len(cfg.pattern)
    for g in range(cfg.n_groups):
        groups.append(
            [
                _layer_init(kg, cfg, cfg.pattern[j], base + g * plen + j)
                for j in range(plen)
            ]
        )
    if cfg.n_groups:
        params["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    else:
        params["groups"] = None
    tail_base = base + cfg.n_groups * plen
    params["tail"] = [
        _layer_init(kg, cfg, k, tail_base + j) for j, k in enumerate(cfg.tail_kinds)
    ]
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    params["lm_head"] = dense_init(kg(), (cfg.d_model, cfg.vocab_padded))
    return params


# ---------------------------------------------------------------------------
# full-sequence layer forward (training / prefill)
# ---------------------------------------------------------------------------
def _mlp_fwd(p, cfg: ModelConfig, x):
    if "moe" in p:
        out, aux = moe_mlp(p["moe"], x, cfg.moe)
        return out, aux["lb_loss"]
    return swiglu(x, p["w1"], p["w3"], p["w2"]), jnp.float32(0.0)


def _pad_cache_s(arr, cache_len):
    """Pad a (B, S, ...) cache tensor with zeros up to cache_len slots."""
    if cache_len is None or arr.shape[1] >= cache_len:
        return arr
    pad = jnp.zeros((arr.shape[0], cache_len - arr.shape[1]) + arr.shape[2:], arr.dtype)
    return jnp.concatenate([arr, pad], axis=1)


def _layer_fwd(p, cfg: ModelConfig, kind: str, x, positions, want_cache: bool,
               cache_len=None):
    """Returns (x, lb_loss, cache_entry_or_None)."""
    eps = cfg.norm_eps
    cache = None
    if kind == "rwkv":
        rp = p["rwkv"]
        b, s, d = x.shape
        h = d // cfg.hd
        state0 = jnp.zeros((b, h, cfg.hd, cfg.hd), jnp.float32)
        xprev0 = jnp.zeros((b, d), x.dtype)
        tm, state, xtm = rwkv6_time_mix(rp, rms_norm(x, rp["ln_tm"], eps), cfg.hd, state0, xprev0)
        x = x + tm
        cm, xcm = rwkv6_channel_mix(rp, rms_norm(x, rp["ln_cm"], eps), xprev0)
        x = x + cm
        if want_cache:
            cache = {"state": state, "xtm": xtm, "xcm": xcm}
        return x, jnp.float32(0.0), cache

    h_in = rms_norm(x, p["ln1"], eps)
    if kind == "rec":
        b, s, _ = x.shape
        r = cfg.d_rnn or cfg.d_model
        out, h_last, tail = rglru_block(
            p["rec"], h_in, jnp.zeros((b, r), jnp.float32), jnp.zeros((b, CONV_W - 1, r), h_in.dtype)
        )
        x = x + out
        if want_cache:
            cache = {"h": h_last, "tail": tail}
    elif cfg.mla is not None and kind == "attn":
        out, (c_kv, k_rope) = mla_attention(
            p["attn"], h_in, positions, cfg.mla, cfg.n_heads, cfg.rope_theta,
            impl=cfg.attention_impl,
        )
        x = x + out
        if want_cache:
            cache = {
                "ckv": _pad_cache_s(c_kv.astype(COMPUTE_DTYPE), cache_len),
                "krope": _pad_cache_s(k_rope.astype(COMPUTE_DTYPE), cache_len),
            }
    else:
        ap = p["attn"]
        b, s, _ = x.shape
        q = (h_in @ ap["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
        k = (h_in @ ap["wk"]).reshape(b, s, cfg.n_kv, cfg.hd)
        v = (h_in @ ap["wv"]).reshape(b, s, cfg.n_kv, cfg.hd)
        if not cfg.encoder_only:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        if kind == "local":
            o = sliding_attention(q, k, v, cfg.window)
        else:
            o = gqa_attention(
                q, k, v, causal=not cfg.encoder_only, impl=cfg.attention_impl
            )
        x = x + o @ ap["wo"]
        if want_cache:
            if kind == "local":
                w = cfg.window
                # ring-buffer layout: token t at slot t % w; keep last w tokens
                ring_k = jnp.zeros((b, w, cfg.n_kv, cfg.hd), k.dtype)
                ring_v = jnp.zeros_like(ring_k)
                take = min(w, s)
                tpos = jnp.arange(s - take, s)
                ring_k = ring_k.at[:, tpos % w].set(k[:, tpos])
                ring_v = ring_v.at[:, tpos % w].set(v[:, tpos])
                cache = {"k": ring_k, "v": ring_v}
            else:
                cache = {"k": _pad_cache_s(k, cache_len), "v": _pad_cache_s(v, cache_len)}
    m_in = rms_norm(x, p["ln2"], eps)
    mo, lb = _mlp_fwd(p["mlp"], cfg, m_in)
    x = x + mo
    return x, lb, cache


def _embed_inputs(params, cfg: ModelConfig, batch: dict):
    """Token/frontend embedding -> (x (B, S, D), positions (S,))."""
    if cfg.frontend == "audio":
        x = (batch["features"].astype(COMPUTE_DTYPE)) @ params["w_front"]
    elif cfg.frontend == "vision":
        te = params["embed"][batch["tokens"]]
        pe = batch["patches"].astype(COMPUTE_DTYPE) @ params["w_front"]
        x = jnp.concatenate([pe, te], axis=1)
    else:
        x = params["embed"][batch["tokens"]]
    x = x.astype(COMPUTE_DTYPE)
    x = shardctx.constrain(x, shardctx.DP, None, None)
    positions = jnp.arange(x.shape[1])
    return x, positions


def forward(params, cfg: ModelConfig, batch: dict, *, want_cache: bool = False,
            remat: bool = False, cache_len=None):
    """Full-sequence forward. Returns (hidden (B,S,D), lb_loss, cache|None).

    cache_len: total KV-cache slots to allocate when want_cache (must exceed
    the prompt length by the number of decode steps that will follow)."""
    x, positions = _embed_inputs(params, cfg, batch)
    lb_total = jnp.float32(0.0)
    prefix_cache, tail_cache = [], []
    kinds = cfg.layer_kinds
    for i, p in enumerate(params["prefix"]):
        x, lb, c = _layer_fwd(p, cfg, kinds[i], x, positions, want_cache, cache_len)
        lb_total += lb
        prefix_cache.append(c)

    if params["groups"] is not None:
        def body(carry, gp):
            x, lb = carry
            caches = []
            for j, kind in enumerate(cfg.pattern):
                x, lbj, c = _layer_fwd(gp[j], cfg, kind, x, positions, want_cache, cache_len)
                lb += lbj
                caches.append(c)
            return (x, lb), caches if want_cache else 0

        if remat:
            body = jax.checkpoint(body)
        (x, lb_total), group_cache = jax.lax.scan(
            body, (x, lb_total), params["groups"]
        )
    else:
        group_cache = None

    for j, p in enumerate(params["tail"]):
        x, lb, c = _layer_fwd(p, cfg, cfg.tail_kinds[j], x, positions, want_cache, cache_len)
        lb_total += lb
        tail_cache.append(c)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    cache = None
    if want_cache:
        cache = {
            "prefix": prefix_cache,
            "groups": group_cache,
            "tail": tail_cache,
            "pos": jnp.int32(x.shape[1]),
        }
    return x, lb_total, cache


def logits_fn(params, cfg: ModelConfig, hidden) -> jnp.ndarray:
    """LM head with vocab padding masked out. hidden: (..., D) -> (..., Vp)."""
    logits = jnp.dot(hidden, params["lm_head"]).astype(jnp.float32)
    spec = (shardctx.DP,) + (None,) * (logits.ndim - 2) + ("model",)
    logits = shardctx.constrain(logits, *spec)
    if cfg.vocab_padded != cfg.vocab:
        pad_mask = jnp.where(
            jnp.arange(cfg.vocab_padded) < cfg.vocab, 0.0, -1e9
        ).astype(jnp.float32)
        logits = logits + pad_mask
    return logits


# ---------------------------------------------------------------------------
# decode (single token) over a cache
# ---------------------------------------------------------------------------
def make_cache(cfg: ModelConfig, batch_size: int, s_max: int):
    """Zero-initialized cache pytree for decode; mirrors param structure."""
    b, hd, kv = batch_size, cfg.hd, cfg.n_kv

    def entry(kind):
        if kind == "rwkv":
            h = cfg.d_model // hd
            return {
                "state": jnp.zeros((b, h, hd, hd), jnp.float32),
                "xtm": jnp.zeros((b, cfg.d_model), COMPUTE_DTYPE),
                "xcm": jnp.zeros((b, cfg.d_model), COMPUTE_DTYPE),
            }
        if kind == "rec":
            r = cfg.d_rnn or cfg.d_model
            return {
                "h": jnp.zeros((b, r), jnp.float32),
                "tail": jnp.zeros((b, CONV_W - 1, r), COMPUTE_DTYPE),
            }
        if cfg.mla is not None and kind == "attn":
            return {
                "ckv": jnp.zeros((b, s_max, cfg.mla.kv_lora), COMPUTE_DTYPE),
                "krope": jnp.zeros((b, s_max, cfg.mla.rope_dim), COMPUTE_DTYPE),
            }
        w = cfg.window if kind == "local" else s_max
        return {
            "k": jnp.zeros((b, w, kv, hd), COMPUTE_DTYPE),
            "v": jnp.zeros((b, w, kv, hd), COMPUTE_DTYPE),
        }

    kinds = cfg.layer_kinds
    cache = {
        "prefix": [entry(kinds[i]) for i in range(cfg.first_dense)],
        "tail": [entry(k) for k in cfg.tail_kinds],
        "pos": jnp.int32(0),
    }
    if cfg.n_groups:
        per_group = [entry(k) for k in cfg.pattern]
        cache["groups"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_groups,) + x.shape), per_group
        )
    else:
        cache["groups"] = None
    return cache


def _layer_decode(p, cfg: ModelConfig, kind: str, x, cache, pos):
    """One-token layer step. x: (B, 1, D). Returns (x, new_cache_entry)."""
    eps = cfg.norm_eps
    if kind == "rwkv":
        rp = p["rwkv"]
        tm, state, xtm = rwkv6_time_mix_decode(
            rp, rms_norm(x, rp["ln_tm"], eps), cfg.hd, cache["state"], cache["xtm"]
        )
        x = x + tm
        cm_in = rms_norm(x, rp["ln_cm"], eps)
        cm, xcm = rwkv6_channel_mix(rp, cm_in, cache["xcm"])
        x = x + cm
        return x, {"state": state, "xtm": xtm.astype(cache["xtm"].dtype), "xcm": xcm.astype(cache["xcm"].dtype)}

    h_in = rms_norm(x, p["ln1"], eps)
    positions = (pos - 1)[None] if jnp.ndim(pos) == 0 else pos
    if kind == "rec":
        out, h, tail = rglru_decode(p["rec"], h_in, cache["h"], cache["tail"])
        x = x + out
        new_cache = {"h": h, "tail": tail.astype(cache["tail"].dtype)}
    elif cfg.mla is not None and kind == "attn":
        out, ckv, krope = mla_decode(
            p["attn"], h_in, positions, cache["ckv"], cache["krope"], pos,
            cfg.mla, cfg.n_heads, cfg.rope_theta,
        )
        x = x + out
        new_cache = {"ckv": ckv, "krope": krope}
    else:
        ap = p["attn"]
        b = x.shape[0]
        q = (h_in @ ap["wq"]).reshape(b, 1, cfg.n_heads, cfg.hd)
        k = (h_in @ ap["wk"]).reshape(b, 1, cfg.n_kv, cfg.hd)
        v = (h_in @ ap["wv"]).reshape(b, 1, cfg.n_kv, cfg.hd)
        if not cfg.encoder_only:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        if kind == "local":
            w = cfg.window
            slot = jnp.mod(pos - 1, w)
            kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            o = decode_sliding_attention(q, kc, vc, pos, w)
        else:
            kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos - 1, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos - 1, 0, 0))
            o = decode_attention(q, kc, vc, pos)
        x = x + o @ ap["wo"]
        new_cache = {"k": kc, "v": vc}
    m_in = rms_norm(x, p["ln2"], eps)
    mo, _ = _mlp_fwd(p["mlp"], cfg, m_in)
    return x + mo, new_cache


def decode_step(params, cfg: ModelConfig, cache: dict, token: jnp.ndarray):
    """Decode one token. token: (B, 1) int32. Returns (logits (B, Vp), cache)."""
    pos = cache["pos"] + 1  # number of tokens including this one
    x = params["embed"][token].astype(COMPUTE_DTYPE)  # (B, 1, D)
    kinds = cfg.layer_kinds
    new_prefix = []
    for i, p in enumerate(params["prefix"]):
        x, c = _layer_decode(p, cfg, kinds[i], x, cache["prefix"][i], pos)
        new_prefix.append(c)

    new_groups = None
    if params["groups"] is not None:
        def body(x, scanned):
            gp, gc = scanned
            caches = []
            for j, kind in enumerate(cfg.pattern):
                x, c = _layer_decode(gp[j], cfg, kind, x, gc[j], pos)
                caches.append(c)
            return x, caches

        x, new_groups = jax.lax.scan(body, x, (params["groups"], cache["groups"]))

    new_tail = []
    for j, p in enumerate(params["tail"]):
        x, c = _layer_decode(p, cfg, cfg.tail_kinds[j], x, cache["tail"][j], pos)
        new_tail.append(c)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(params, cfg, x[:, 0, :])
    new_cache = {
        "prefix": new_prefix, "groups": new_groups, "tail": new_tail, "pos": pos,
    }
    return logits, new_cache


def prefill(params, cfg: ModelConfig, batch: dict, *, cache_len=None):
    """Process a full prompt; returns (last-token logits, cache).

    cache_len defaults to prompt_len + 64 slots of decode headroom."""
    if cache_len is None:
        s = batch["features"].shape[1] if "features" in batch else batch["tokens"].shape[1]
        if cfg.frontend == "vision":
            s += cfg.n_vis_tokens
        cache_len = s + 64
    hidden, _, cache = forward(params, cfg, batch, want_cache=True, cache_len=cache_len)
    logits = logits_fn(params, cfg, hidden[:, -1, :])
    return logits, cache
