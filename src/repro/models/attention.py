"""Attention variants: GQA (full/causal/bidirectional), sliding-window
(block-banded, sub-quadratic), MLA (latent compressed, with the absorbed
matmul form for decode), and single-token decode paths over KV caches.

Shapes follow (B, S, H, hd); KV caches are (B, S_max, kv, hd) for global
attention and (B, W, kv, hd) ring buffers for sliding windows.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import COMPUTE_DTYPE, dense_init, rms_norm, rope

NEG_INF = -2.0e38


def _gqa_scores(q, k):
    """q: (B, Sq, H, hd), k: (B, Sk, kv, hd) -> (B, kv, H/kv, Sq, Sk)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    q = q.reshape(b, sq, kvh, h // kvh, hd)
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k) / (hd ** 0.5)


def _gqa_out(p, v):
    """p: (B, kv, H/kv, Sq, Sk), v: (B, Sk, kv, hd) -> (B, Sq, H*hd)."""
    b, kvh, g, sq, sk = p.shape
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v)
    return o.reshape(b, sq, kvh * g * v.shape[-1])


def _softmax(s):
    return jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(COMPUTE_DTYPE)


def naive_attention(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """Reference full attention: materializes the (Sq, Sk) score matrix.
    Kept as the §Perf baseline; unusable at 32k (O(S^2) f32 in HBM)."""
    sq, sk = q.shape[1], k.shape[1]
    s = _gqa_scores(q, k)
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask, s, NEG_INF)
    return _gqa_out(_softmax(s), v)


def flash_attention(q, k, v, *, causal: bool = True, q_chunk: int = 1024,
                    k_chunk: int = 1024) -> jnp.ndarray:
    """Chunked attention with running softmax (flash-style, TPU-native).

    Queries are processed in a static python loop of q-chunks; for a causal
    mask, chunk i only reads keys [0, (i+1)*qc) — a *static* slice, so the
    causal FLOPs are exact (no masked-out block compute). Keys stream
    through an inner lax.scan with the (m, l, acc) running-softmax carry,
    so peak memory is O(qc * kc) instead of O(S^2).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    hd_v = v.shape[-1]  # may differ from hd (MLA: qk 96, v 64)
    g = h // kvh
    scale = hd ** -0.5
    qc = min(q_chunk, sq)
    assert sq % qc == 0 and sq == sk, (sq, sk, qc)
    nq = sq // qc

    out_chunks = []
    for i in range(nq):
        qi = q[:, i * qc : (i + 1) * qc].reshape(b, qc, kvh, g, hd)
        klen = (i + 1) * qc if causal else sk
        kc = min(k_chunk, klen)
        nk = klen // kc
        kb = k[:, :klen].reshape(b, nk, kc, kvh, hd)
        vb = v[:, :klen].reshape(b, nk, kc, kvh, hd_v)
        q_pos = i * qc + jnp.arange(qc)

        def body(carry, xs):
            m, l, acc = carry
            kj, vj, j = xs
            s = jnp.einsum("bqkgh,bskh->bkgqs", qi, kj).astype(jnp.float32) * scale
            if causal:
                k_pos = j * kc + jnp.arange(kc)
                mask = k_pos[None, :] <= q_pos[:, None]  # (qc, kc)
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(COMPUTE_DTYPE), vj
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, kvh, g, qc), -jnp.inf, jnp.float32),
            jnp.zeros((b, kvh, g, qc), jnp.float32),
            jnp.zeros((b, kvh, g, qc, hd_v), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            body, init,
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nk)),
        )
        o = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(COMPUTE_DTYPE)
        # (b, kvh, g, qc, hd_v) -> (b, qc, H*hd_v)
        out_chunks.append(jnp.moveaxis(o, 3, 1).reshape(b, qc, h * hd_v))
    return jnp.concatenate(out_chunks, axis=1)


FLASH_MIN_SEQ = 2048


def gqa_attention(q, k, v, *, causal: bool = True, impl: str = "auto") -> jnp.ndarray:
    """Full attention; bidirectional when causal=False. impl: auto routes
    long sequences through the chunked flash path (exact same math)."""
    sq, sk = q.shape[1], k.shape[1]
    use_flash = (
        impl == "flash"
        or (impl == "auto" and sq == sk and sq >= FLASH_MIN_SEQ and sq % 1024 == 0)
    )
    if use_flash:
        return flash_attention(q, k, v, causal=causal)
    return naive_attention(q, k, v, causal=causal)


def sliding_attention(q, k, v, window: int) -> jnp.ndarray:
    """Causal sliding-window attention, block-banded formulation.

    Token t attends to keys in (t - window, t]. Sequences are chunked into
    window-sized blocks; each query block attends to its own block (causal)
    and the previous block (banded) — 2*W*S score work instead of S^2.
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    w = window
    pad = (-s) % w
    if pad:
        zq = jnp.zeros((b, pad, h, hd), q.dtype)
        zk = jnp.zeros((b, pad, kvh, hd), k.dtype)
        q = jnp.concatenate([q, zq], 1)
        k = jnp.concatenate([k, zk], 1)
        v = jnp.concatenate([v, zk], 1)
    sp = s + pad
    nb = sp // w
    qb = q.reshape(b, nb, w, h, hd)
    kb = k.reshape(b, nb, w, kvh, hd)
    vb = v.reshape(b, nb, w, kvh, hd)
    # keys for block i: [block i-1, block i]
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    kcat = jnp.concatenate([k_prev, kb], axis=2)  # (b, nb, 2w, kv, hd)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    vcat = jnp.concatenate([v_prev, vb], axis=2)
    qg = qb.reshape(b, nb, w, kvh, h // kvh, hd)
    scores = jnp.einsum("bnqkgh,bnskh->bnkgqs", qg, kcat) / (hd ** 0.5)
    # mask: query local pos i (global w*n + i) sees key local pos j
    # (global w*(n-1) + j): need 0 < (w + i - j) <= window  [strict causal]
    qi = jnp.arange(w)[:, None]
    kj = jnp.arange(2 * w)[None, :]
    rel = qi + w - kj  # how far key is behind query (0 = self)
    mask = (rel >= 0) & (rel < w)
    # first block's "previous block" is padding: mask out j < w at n == 0
    nidx = jnp.arange(nb)[:, None, None]
    valid_prev = (nidx > 0) | (kj[None] >= w)
    full_mask = mask[None] & valid_prev  # (nb, w, 2w)
    scores = jnp.where(full_mask[None, :, None, None], scores, NEG_INF)
    p = _softmax(scores)
    o = jnp.einsum("bnkgqs,bnskh->bnqkgh", p, vcat)
    o = o.reshape(b, sp, h * hd)
    return o[:, :s]


def decode_attention(q, k_cache, v_cache, pos) -> jnp.ndarray:
    """One-token decode over a (B, S_max, kv, hd) cache; pos = #valid tokens
    *after* writing the current token (attends to [0, pos))."""
    s = _gqa_scores(q, k_cache)  # (B, kv, g, 1, S_max)
    valid = jnp.arange(k_cache.shape[1])[None, None, None, None, :] < pos
    s = jnp.where(valid, s, NEG_INF)
    return _gqa_out(_softmax(s), v_cache)


def decode_sliding_attention(q, k_ring, v_ring, pos, window: int) -> jnp.ndarray:
    """One-token decode over a (B, W, kv, hd) ring buffer (slot = t % W)."""
    s = _gqa_scores(q, k_ring)  # (B, kv, g, 1, W)
    slot_t = jnp.arange(window)
    # global time of ring slot j given current count `pos` (token t = pos-1
    # lives at slot (pos-1) % W): time = pos-1 - ((pos-1 - j) % W)
    t_of_slot = (pos - 1) - jnp.mod(pos - 1 - slot_t, window)
    valid = (t_of_slot >= 0) & (t_of_slot >= pos - window)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    return _gqa_out(_softmax(s), v_ring)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MLADims:
    q_lora: int = 768
    kv_lora: int = 256
    rope_dim: int = 32
    nope_dim: int = 64
    v_dim: int = 64


def mla_init(keygen, d_model: int, n_heads: int, dims: MLADims):
    h = n_heads
    return {
        "q_down": dense_init(keygen(), (d_model, dims.q_lora)),
        "q_norm": jnp.zeros((dims.q_lora,), jnp.float32),
        "q_up": dense_init(keygen(), (dims.q_lora, h * (dims.nope_dim + dims.rope_dim))),
        "kv_down": dense_init(keygen(), (d_model, dims.kv_lora + dims.rope_dim)),
        "kv_norm": jnp.zeros((dims.kv_lora,), jnp.float32),
        "kv_up": dense_init(keygen(), (dims.kv_lora, h * (dims.nope_dim + dims.v_dim))),
        "wo": dense_init(keygen(), (h * dims.v_dim, d_model)),
    }


def mla_qkv(p, x, positions, dims: MLADims, n_heads: int, theta: float):
    """Project x -> (q_nope, q_rope, c_kv, k_rope). Shapes:
    q_*: (B, S, H, *), c_kv: (B, S, kv_lora), k_rope: (B, S, rope_dim)."""
    b, s, _ = x.shape
    h = n_heads
    q = rms_norm(jnp.dot(x, p["q_down"]), p["q_norm"])
    q = jnp.dot(q, p["q_up"]).reshape(b, s, h, dims.nope_dim + dims.rope_dim)
    q_nope, q_rope = q[..., : dims.nope_dim], q[..., dims.nope_dim:]
    q_rope = rope(q_rope, positions, theta)
    ckv = jnp.dot(x, p["kv_down"])
    c_kv, k_rope = ckv[..., : dims.kv_lora], ckv[..., dims.kv_lora:]
    c_kv = rms_norm(c_kv, p["kv_norm"])
    k_rope = rope(k_rope[:, :, None, :], positions, theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(p, x, positions, dims: MLADims, n_heads: int, theta: float,
                  impl: str = "auto"):
    """Training/prefill MLA (non-absorbed: materialize k, v per head)."""
    b, s, _ = x.shape
    h = n_heads
    q_nope, q_rope, c_kv, k_rope = mla_qkv(p, x, positions, dims, n_heads, theta)
    kv = jnp.dot(c_kv, p["kv_up"]).reshape(b, s, h, dims.nope_dim + dims.v_dim)
    k_nope, v = kv[..., : dims.nope_dim], kv[..., dims.nope_dim:]
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dims.rope_dim))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope_h], -1)
    o = gqa_attention(q, k, v, causal=True, impl=impl)  # kv == h heads here
    return jnp.dot(o, p["wo"]), (c_kv, k_rope)


def mla_decode(p, x, positions, cache_ckv, cache_krope, pos, dims: MLADims,
               n_heads: int, theta: float):
    """Absorbed-form decode: attention runs in the compressed kv_lora space,
    so the cache is (B, S, kv_lora) + (B, S, rope_dim) — the MLA memory win.

    scores = q_nope @ W_uk . c_kv  +  q_rope . k_rope
    ctx    = softmax @ c_kv ; out = (ctx @ W_uv) @ wo
    """
    b, s1, _ = x.shape  # s1 == 1
    h = n_heads
    q_nope, q_rope, c_kv_new, k_rope_new = mla_qkv(p, x, positions, dims, n_heads, theta)
    # write the new token into the caches at pos-1
    cache_ckv = jax.lax.dynamic_update_slice(
        cache_ckv, c_kv_new.astype(cache_ckv.dtype), (0, pos - 1, 0)
    )
    cache_krope = jax.lax.dynamic_update_slice(
        cache_krope, k_rope_new.astype(cache_krope.dtype), (0, pos - 1, 0)
    )
    # kv_up columns are head-major [nope | v] blocks: reshape before splitting
    w_u = p["kv_up"].reshape(dims.kv_lora, h, dims.nope_dim + dims.v_dim)
    w_uk = w_u[..., : dims.nope_dim]
    w_uv = w_u[..., dims.nope_dim :]
    q_abs = jnp.einsum("bqhn,chn->bqhc", q_nope, w_uk)  # (B, 1, H, kv_lora)
    s_nope = jnp.einsum("bqhc,bsc->bhqs", q_abs, cache_ckv)
    s_rope = jnp.einsum("bqhr,bsr->bhqs", q_rope, cache_krope)
    scale = (dims.nope_dim + dims.rope_dim) ** -0.5
    scores = (s_nope + s_rope) * scale  # (B, H, 1, S)
    valid = jnp.arange(cache_ckv.shape[1])[None, None, None, :] < pos
    scores = jnp.where(valid, scores, NEG_INF)
    pr = _softmax(scores)
    ctx = jnp.einsum("bhqs,bsc->bqhc", pr, cache_ckv)  # (B, 1, H, kv_lora)
    o = jnp.einsum("bqhc,chv->bqhv", ctx, w_uv).reshape(b, s1, h * dims.v_dim)
    return jnp.dot(o, p["wo"]), cache_ckv, cache_krope
