"""Activation-sharding context.

The launch layer installs (mesh, dp-axes) here before tracing; model code
then pins the shardings of the few activations GSPMD mis-infers (embedding
gather output, logits, one-hot loss terms) with with_sharding_constraint.
When no context is installed (unit tests, single-device smoke) every
constrain() is a no-op, so model code stays mesh-agnostic.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: Optional[dict] = None

DP = "__dp__"  # placeholder resolved to the data-parallel axis tuple


def set_ctx(mesh, dp_axes: tuple) -> None:
    global _CTX
    _CTX = {"mesh": mesh, "dp": tuple(dp_axes)}


def clear_ctx() -> None:
    global _CTX
    _CTX = None


@contextlib.contextmanager
def ctx(mesh, dp_axes: tuple):
    set_ctx(mesh, dp_axes)
    try:
        yield
    finally:
        clear_ctx()


def constrain(x, *spec):
    """Pin x's sharding (DP placeholder -> dp axes). No-op without context,
    and axes referring to dims that don't divide are dropped leaf-wise."""
    if _CTX is None:
        return x
    mesh = _CTX["mesh"]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    resolved = []
    for dim, s in enumerate(spec):
        if s == DP:
            s = _CTX["dp"]
        if s is None:
            resolved.append(None)
            continue
        axes = (s,) if isinstance(s, str) else tuple(s)
        total = 1
        for a in axes:
            total *= sizes[a]
        resolved.append(s if x.shape[dim] % total == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved))
    )
