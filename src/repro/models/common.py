"""Shared model building blocks: norms, RoPE, init, dtype policy."""
from __future__ import annotations

import jax
import jax.numpy as jnp

PARAM_DTYPE = jnp.bfloat16
COMPUTE_DTYPE = jnp.bfloat16


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    # variance in f32 for stability, but the normalize/scale multiplies stay
    # in x.dtype so backward cotangents remain bf16 — keeping every
    # activation collective in the backward pass at half volume (§Perf).
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e6) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, hd) with hd even; positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, half)
        ang = ang[None, :, None, :]  # (1, S, 1, half)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=PARAM_DTYPE) -> jnp.ndarray:
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=PARAM_DTYPE) -> jnp.ndarray:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros(shape, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.zeros(shape, dtype)


class KeyGen:
    """Deterministic PRNG key splitter for param init."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


def swiglu(x: jnp.ndarray, w1, w3, w2) -> jnp.ndarray:
    """SwiGLU MLP: (x@w1).silu * (x@w3) @ w2."""
    h = jax.nn.silu(jnp.dot(x, w1)) * jnp.dot(x, w3)
    return jnp.dot(h, w2)
