"""RWKV6 "Finch" — attention-free time mixing with data-dependent decay.

TPU-native *chunked* formulation: the per-token recurrence

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

is evaluated in chunks of length C: within a chunk the pairwise decay
factorizes per channel, exp(cum_{i-1} - cum_j) = exp(cum_{i-1}) * exp(-cum_j),
so intra-chunk work becomes two (C x C x hd) matmuls on the MXU, and the
inter-chunk state propagates with a lax.scan of (hd x hd) updates. Log-decay
is clamped to >= LOG_DECAY_MIN per step so exp(-cum_j) stays inside float32
at C=16 (|cum| <= 56 < 88); a documented numerical simplification vs the
exact CUDA kernel.

Simplifications vs the reference implementation (documented in DESIGN.md):
static token-shift mixing coefficients (no ddlerp LoRA on the mix weights);
decay LoRA retained (the data-dependent part that defines Finch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init

CHUNK = 16
LOG_DECAY_MIN = -3.5
DECAY_LORA = 64


def rwkv6_init(keygen, d_model: int, head_dim: int, d_ff: int):
    h = d_model // head_dim
    return {
        "ln_tm": jnp.zeros((d_model,), jnp.float32),
        "mu": (jax.random.uniform(keygen(), (5, d_model), jnp.float32) * 0.1).astype(jnp.float32),
        "wr": dense_init(keygen(), (d_model, d_model)),
        "wk": dense_init(keygen(), (d_model, d_model)),
        "wv": dense_init(keygen(), (d_model, d_model)),
        "wg": dense_init(keygen(), (d_model, d_model)),
        "w0": jnp.zeros((d_model,), jnp.float32) - 0.6,  # base log-log decay
        "w_lora_a": dense_init(keygen(), (d_model, DECAY_LORA), dtype=jnp.float32),
        "w_lora_b": (jax.random.normal(keygen(), (DECAY_LORA, d_model), jnp.float32) * 0.01),
        "u": jnp.zeros((h, head_dim), jnp.float32),
        "gn_scale": jnp.zeros((d_model,), jnp.float32),
        "wo": dense_init(keygen(), (d_model, d_model)),
        "ln_cm": jnp.zeros((d_model,), jnp.float32),
        "mu_cm": (jax.random.uniform(keygen(), (2, d_model), jnp.float32) * 0.1).astype(jnp.float32),
        "cm_k": dense_init(keygen(), (d_model, d_ff)),
        "cm_v": dense_init(keygen(), (d_ff, d_model)),
        "cm_r": dense_init(keygen(), (d_model, d_model)),
    }


def _token_shift(x, x_prev):
    """x: (B, S, D); x_prev: (B, D) last token of the previous segment."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _log_decay(p, xw):
    ld = -jnp.exp(
        p["w0"].astype(jnp.float32)
        + jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    )
    return jnp.maximum(ld, LOG_DECAY_MIN)  # (B, S, D) in (LOG_DECAY_MIN, 0)


def _group_norm(x, scale, h):
    """Per-head RMS norm of the (B, S, H, hd) wkv output, flattened scale."""
    b, s, hh, hd = x.shape
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + 1e-6)
    return (out.reshape(b, s, hh * hd) * (1.0 + scale)).astype(x.dtype)


def rwkv6_time_mix(p, x, head_dim: int, state, x_prev):
    """Chunked WKV6. x: (B, S, D); state: (B, H, hd, hd) f32; x_prev: (B, D).

    Returns (out (B, S, D), new_state, new_x_prev)."""
    b, s, d = x.shape
    h = d // head_dim
    xs = _token_shift(x, x_prev)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + mu[i][None, None, :] * (xs - x) for i in range(5))
    r = (xr @ p["wr"]).reshape(b, s, h, head_dim)
    k = (xk @ p["wk"]).reshape(b, s, h, head_dim)
    v = (xv @ p["wv"]).reshape(b, s, h, head_dim)
    g = xg @ p["wg"]
    ld = _log_decay(p, xw).reshape(b, s, h, head_dim)  # log decay per channel

    # pad S to a chunk multiple
    pad = (-s) % CHUNK
    if pad:
        zpad = lambda a: jnp.concatenate(
            [a, jnp.zeros((b, pad) + a.shape[2:], a.dtype)], axis=1
        )
        r, k, v = zpad(r), zpad(k), zpad(v)
        ld = jnp.concatenate([ld, jnp.zeros((b, pad, h, head_dim), ld.dtype)], axis=1)
    sp = s + pad
    nb = sp // CHUNK
    rc = r.reshape(b, nb, CHUNK, h, head_dim).astype(jnp.float32)
    kc = k.reshape(b, nb, CHUNK, h, head_dim).astype(jnp.float32)
    vc = v.reshape(b, nb, CHUNK, h, head_dim).astype(jnp.float32)
    ldc = ld.reshape(b, nb, CHUNK, h, head_dim)

    cum = jnp.cumsum(ldc, axis=2)  # inclusive per-chunk cumulative log decay
    cum_prev = cum - ldc  # exclusive
    r_t = rc * jnp.exp(cum_prev)  # r~_i = r_i * exp(cum_{i-1})
    k_t = kc * jnp.exp(-cum)  # k~_j = k_j * exp(-cum_j)
    # intra-chunk scores: A_ij = r~_i . k~_j for j < i, diag via bonus u
    scores = jnp.einsum("bnihd,bnjhd->bnhij", r_t, k_t)
    tri = jnp.tril(jnp.ones((CHUNK, CHUNK), bool), k=-1)
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    diag = jnp.einsum("bnihd,bnihd->bnhi", rc * p["u"][None, None], kc)
    scores = scores + jnp.eye(CHUNK)[None, None, None] * diag[..., :, None]
    intra = jnp.einsum("bnhij,bnjhd->bnihd", scores, vc)

    # inter-chunk: scan the (hd x hd) state across chunks
    decay_all = jnp.exp(cum[:, :, -1])  # (b, nb, h, hd) total chunk decay
    k_hat = kc * jnp.exp(cum[:, :, -1:, :, :] - cum)  # decay from j to chunk end

    def step(carry, inp):
        s0 = carry  # (b, h, hd, hd)
        rt, kh, vch, dec = inp
        contrib = jnp.einsum("bihd,bhde->bihe", rt, s0)  # r~ @ S0
        s_new = dec[..., None] * s0 + jnp.einsum("bjhd,bjhe->bhde", kh, vch)
        return s_new, contrib

    xs_scan = (
        jnp.moveaxis(r_t, 1, 0),
        jnp.moveaxis(k_hat, 1, 0),
        jnp.moveaxis(vc, 1, 0),
        jnp.moveaxis(decay_all, 1, 0),
    )
    state_f = state.astype(jnp.float32)
    new_state, inter = jax.lax.scan(step, state_f, xs_scan)
    inter = jnp.moveaxis(inter, 0, 1)  # (b, nb, C, h, hd)

    wkv = (intra + inter).reshape(b, sp, h, head_dim)[:, :s]
    out = _group_norm(wkv, p["gn_scale"], h) * jax.nn.silu(g)
    return (out @ p["wo"]).astype(x.dtype), new_state, x[:, -1, :]


def rwkv6_time_mix_decode(p, x, head_dim: int, state, x_prev):
    """Single-token WKV6 step. x: (B, 1, D)."""
    b, _, d = x.shape
    h = d // head_dim
    mu = p["mu"].astype(x.dtype)
    xs = x_prev[:, None, :]
    xr, xk, xv, xw, xg = (x + mu[i][None, None, :] * (xs - x) for i in range(5))
    r = (xr @ p["wr"]).reshape(b, h, head_dim).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(b, h, head_dim).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(b, h, head_dim).astype(jnp.float32)
    g = xg @ p["wg"]
    w = jnp.exp(_log_decay(p, xw)[:, 0].reshape(b, h, head_dim))
    sf = state.astype(jnp.float32)
    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    o = jnp.einsum("bhd,bhde->bhe", r, sf + p["u"][None, :, :, None] * kv)
    new_state = w[..., None] * sf + kv
    o = o[:, None].reshape(b, 1, h, head_dim)
    out = _group_norm(o, p["gn_scale"], h) * jax.nn.silu(g)
    return (out @ p["wo"]).astype(x.dtype), new_state, x[:, -1, :]


def rwkv6_channel_mix(p, x, x_prev):
    """RWKV channel mix (the FFN). x: (B, S, D); x_prev: (B, D)."""
    xs = _token_shift(x, x_prev)
    mu = p["mu_cm"].astype(x.dtype)
    xk = x + mu[0][None, None] * (xs - x)
    xr = x + mu[1][None, None] * (xs - x)
    k = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    return jax.nn.sigmoid(xr @ p["cm_r"]) * (k @ p["cm_v"]), x[:, -1, :]
