"""Mixture-of-Experts MLP with capacity-based dispatch (expert-parallel).

Router: softmax top-k with renormalized gates. Dispatch: tokens are sorted
by expert id, each expert processes up to C = ceil(T*K/E * capacity_factor)
tokens (overflow dropped — counted in aux), computed as one grouped einsum
(E, C, D) x (E, D, F) that shards cleanly with experts on the "model" mesh
axis. Optional shared experts (DeepSeek-MoE) run densely on every token.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .common import dense_init


@dataclasses.dataclass(frozen=True)
class MoEDims:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.5


def moe_init(keygen, d_model: int, dims: MoEDims):
    e, fe = dims.n_experts, dims.d_expert
    p = {
        "router": dense_init(keygen(), (d_model, e), dtype=jnp.float32),
        "w1": dense_init(keygen(), (e, d_model, fe)),
        "w3": dense_init(keygen(), (e, d_model, fe)),
        "w2": dense_init(keygen(), (e, fe, d_model)),
    }
    if dims.n_shared:
        fs = dims.n_shared * fe
        p["shared_w1"] = dense_init(keygen(), (d_model, fs))
        p["shared_w3"] = dense_init(keygen(), (d_model, fs))
        p["shared_w2"] = dense_init(keygen(), (fs, d_model))
    return p


def moe_mlp(p, x: jnp.ndarray, dims: MoEDims) -> tuple[jnp.ndarray, dict]:
    """x: (B, S, D) -> (B, S, D). Returns (out, aux) with load-balance loss."""
    b, s, d = x.shape
    t = b * s
    e, k = dims.n_experts, dims.top_k
    xf = x.reshape(t, d)
    logits = jnp.dot(xf.astype(jnp.float32), p["router"])  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (T, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # capacity floor min(t, 8) keeps tiny decode batches drop-free
    cap = max(math.ceil(t * k / e * dims.capacity_factor), min(t, 8))
    # flatten (token, k) assignments and sort by expert
    flat_e = gate_idx.reshape(-1)  # (T*K,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sg = flat_e[order], flat_t[order], flat_g[order]
    start = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos = jnp.arange(t * k) - start[se]
    keep = pos < cap
    slot = jnp.minimum(pos, cap)  # slot `cap` is trash
    # dispatch indices (E, C): token feeding each expert slot (t = dummy row)
    disp = jnp.full((e, cap + 1), t, jnp.int32)
    disp = disp.at[se, slot].set(jnp.where(keep, st_, t).astype(jnp.int32))[:, :cap]
    gates = jnp.zeros((e, cap + 1), jnp.float32)
    gates = gates.at[se, slot].set(jnp.where(keep, sg, 0.0))[:, :cap]

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xin = xpad[disp]  # (E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w1"])) * jnp.einsum(
        "ecd,edf->ecf", xin, p["w3"]
    )
    eo = jnp.einsum("ecf,efd->ecd", h, p["w2"])  # (E, C, D)
    eo = eo * gates[..., None].astype(eo.dtype)
    # combine: scatter-add expert outputs back to tokens
    out = jnp.zeros((t + 1, d), eo.dtype).at[disp.reshape(-1)].add(
        eo.reshape(e * cap, d)
    )[:t]

    if dims.n_shared:
        sh = jax.nn.silu(jnp.dot(xf, p["shared_w1"])) * jnp.dot(xf, p["shared_w3"])
        out = out + jnp.dot(sh, p["shared_w2"])

    # load-balance aux (Switch-style) + overflow fraction
    me = probs.mean(0)  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (t * k)
    aux = {
        "lb_loss": e * jnp.sum(me * ce),
        "overflow_frac": 1.0 - keep.mean(),
    }
    return out.reshape(b, s, d), aux
