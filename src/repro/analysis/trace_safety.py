"""trace-safety — code reachable from traced call sites stays pure.

The zero-retrace serving story (PR 4) assumes the jitted fused passes are
pure functions of their arguments: a traced function body runs ONCE per
shape bucket, at trace time — any lock acquisition, DiskModel accounting,
host RNG draw, ``time.*`` call, or nonlocal-state mutation inside it
either silently happens once instead of per call, or (locks) can deadlock
under the tracer. This checker finds every function reachable from a
``jax.jit`` / ``shard_map`` / ``pallas_call`` root — decorator or call
site, unwrapping ``functools.partial`` — by walking the project-local
call graph, then flags host side effects inside the reachable set.

Known deliberate exception in this repo: the ``_TRACES[0] += 1`` retrace
counter *wants* exactly trace-time-only execution — it carries an
``# palmlint: ignore[trace-safety]`` annotation at the site.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .base import (
    Checker, Finding, FunctionInfo, Module, Project, attr_chain, call_name,
    register,
)

JIT_NAMES = {"jax.jit", "jit"}
ROOT_CALLEES = {"jit", "shard_map", "pallas_call"}

#: DiskModel accounting mutators — I/O charged from inside a trace runs
#: once per compile, not once per call, silently corrupting the cost model
DISK_ACCOUNTING = {"read_seq", "read_rand", "write_seq", "write_rand",
                   "read_seq_ranges", "reset"}

#: host RNG chains (jax.random is functional and explicitly allowed)
_RNG_PREFIXES = ("np.random.", "numpy.random.", "random.")

_LOCKISH = {"_lock", "_cond"}


def _unwrap_partial(node: ast.AST) -> ast.AST:
    """``functools.partial(f, …)`` -> ``f`` (recursively)."""
    while isinstance(node, ast.Call) and call_name(node) == "partial":
        if not node.args:
            break
        node = node.args[0]
    return node


def _jit_decorated(fn) -> bool:
    for dec in fn.decorator_list:
        chain = attr_chain(dec)
        if chain in JIT_NAMES:
            return True
        if isinstance(dec, ast.Call):
            fchain = attr_chain(dec.func)
            if fchain in JIT_NAMES:  # @jax.jit(static_argnames=…)
                return True
            if fchain in {"functools.partial", "partial"} and dec.args:
                if attr_chain(dec.args[0]) in JIT_NAMES:
                    return True
    return False


def _resolve_edge(project: Project, node: ast.Call, mod: Module,
                  class_name: Optional[str]) -> Optional[FunctionInfo]:
    """Call-graph edge resolution, stricter than ``Project.resolve_call``:
    generic method names (``append``, ``scan``, ``build``) collide with
    list/dict/jax APIs, and a fabricated edge drags whole subsystems into
    the reachable set. So: bare-name calls resolve to *functions* (never
    methods), local-first; ``self.f()`` resolves within the caller's own
    class; any other attribute call resolves only when the name maps to
    exactly one definition project-wide and that definition is a plain
    function (the ``kops.screen_select`` case)."""
    f = node.func
    if isinstance(f, ast.Name):
        cands = [c for c in project.functions.get(f.id, [])
                 if c.class_name is None]
        local = [c for c in cands if c.module is mod]
        if len(local) == 1:
            return local[0]
        if len(cands) == 1:
            return cands[0]
        return None
    if isinstance(f, ast.Attribute):
        all_cands = project.functions.get(f.attr, [])
        if attr_chain(f.value) == "self" and class_name is not None:
            own = [c for c in all_cands if c.class_name == class_name]
            if len(own) == 1:
                return own[0]
            return None
        if len(all_cands) == 1 and all_cands[0].class_name is None:
            return all_cands[0]
    return None


def _resolve_root_target(project: Project, target: ast.AST,
                         mod: Module) -> Optional[FunctionInfo]:
    """Resolve the function argument of a jit/shard_map/pallas_call site.
    Name targets prefer same-module definitions (nested closures
    included); attribute targets need a project-wide unique name."""
    name = None
    if isinstance(target, ast.Name):
        name = target.id
    elif isinstance(target, ast.Attribute):
        name = target.attr
    if not name:
        return None
    cands = project.functions.get(name, [])
    if isinstance(target, ast.Name):
        local = [c for c in cands if c.module is mod]
        if len(local) == 1:
            return local[0]
    if len(cands) == 1:
        return cands[0]
    return None


def _local_bindings(fn: ast.AST) -> Set[str]:
    """Names bound inside ``fn``: parameters + every bare-name store
    (assignments, for targets, with-as, comprehension vars). A write whose
    root is NOT in this set mutates closure/module state."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.arg):
            out.add(node.arg)
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            out.add(node.id)
    return out


@register
class TraceSafetyChecker(Checker):
    name = "trace-safety"
    description = ("functions reachable from jax.jit / shard_map / "
                   "pallas_call must not touch locks, DiskModel "
                   "accounting, host RNG, time.*, or nonlocal Python "
                   "state (they run at trace time, not per call)")

    def check(self, project: Project) -> Iterable[Finding]:
        roots = self._find_roots(project)
        reachable = self._reach(project, roots)
        seen: Set[Tuple[str, int, int]] = set()
        for (info, root_name) in reachable:
            for f in self._scan(info, root_name):
                key = (f.path, f.line, f.col)
                if key not in seen:
                    seen.add(key)
                    yield f

    # --------------------------------------------------------------- roots
    def _find_roots(self, project: Project) -> List[Tuple[FunctionInfo, str]]:
        roots: List[Tuple[FunctionInfo, str]] = []
        for mod in project.modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and _jit_decorated(node):
                    roots.append((FunctionInfo(mod, node, node.name,
                                               node.name), node.name))
                elif isinstance(node, ast.Call) and \
                        call_name(node) in ROOT_CALLEES and node.args:
                    target = _unwrap_partial(node.args[0])
                    if isinstance(target, ast.Lambda):
                        roots.append((FunctionInfo(
                            mod, target, "<lambda>",
                            f"<lambda>@{mod.path}:{target.lineno}"),
                            f"{call_name(node)} lambda"))
                    else:
                        fi = _resolve_root_target(project, target, mod)
                        if fi is not None:
                            roots.append((fi, fi.qualname))
        return roots

    # --------------------------------------------------------- reachability
    def _reach(self, project: Project,
               roots: List[Tuple[FunctionInfo, str]]
               ) -> List[Tuple[FunctionInfo, str]]:
        seen: Dict[int, Tuple[FunctionInfo, str]] = {}
        queue = list(roots)
        while queue:
            info, root = queue.pop()
            if id(info.node) in seen:
                continue
            seen[id(info.node)] = (info, root)
            for node in ast.walk(info.node):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node)
                if not name or name in ROOT_CALLEES:
                    continue
                callee = _resolve_edge(project, node, info.module,
                                       info.class_name)
                if callee is not None and id(callee.node) not in seen:
                    queue.append((callee, root))
        return list(seen.values())

    # ------------------------------------------------------------ the scan
    def _scan(self, info: FunctionInfo, root: str) -> Iterable[Finding]:
        mod = info.module
        fn = info.node
        where = (f"`{info.qualname}` (reachable from traced root "
                 f"`{root}`)")
        local = _local_bindings(fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.With):
                for item in node.items:
                    chain = attr_chain(item.context_expr)
                    if chain and chain.split(".")[-1] in _LOCKISH:
                        yield Finding(
                            mod.path, item.context_expr.lineno,
                            item.context_expr.col_offset, self.name,
                            f"{where} acquires `{chain}` — traced code "
                            f"must not take locks (runs at trace time; "
                            f"can deadlock under the tracer)")
            elif isinstance(node, ast.Call):
                chain = attr_chain(node.func) or ""
                name = call_name(node)
                if name in {"acquire", "release"} and any(
                        part in _LOCKISH for part in chain.split(".")):
                    yield Finding(
                        mod.path, node.lineno, node.col_offset, self.name,
                        f"{where} calls `{chain}()` — traced code must "
                        f"not touch locks")
                elif name in DISK_ACCOUNTING:
                    yield Finding(
                        mod.path, node.lineno, node.col_offset, self.name,
                        f"{where} charges DiskModel accounting "
                        f"(`{chain or name}`) — traced code runs once per "
                        f"compile, so the I/O figures would be wrong")
                elif chain.startswith(_RNG_PREFIXES) or \
                        name == "default_rng":
                    yield Finding(
                        mod.path, node.lineno, node.col_offset, self.name,
                        f"{where} draws host RNG (`{chain or name}`) — "
                        f"use jax.random with an explicit key")
                elif chain.startswith("time."):
                    yield Finding(
                        mod.path, node.lineno, node.col_offset, self.name,
                        f"{where} calls `{chain}()` — trace-time "
                        f"timestamps are compile-time constants")
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                yield Finding(
                    mod.path, node.lineno, node.col_offset, self.name,
                    f"{where} declares "
                    f"{'global' if isinstance(node, ast.Global) else 'nonlocal'}"
                    f" {', '.join(node.names)} — traced code must not "
                    f"rebind outer state")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    root_node = tgt
                    while isinstance(root_node, (ast.Subscript,
                                                 ast.Attribute)):
                        root_node = root_node.value
                    if isinstance(root_node, ast.Name) and \
                            root_node.id not in local and \
                            root_node is not tgt:
                        yield Finding(
                            mod.path, tgt.lineno, tgt.col_offset, self.name,
                            f"{where} mutates nonlocal Python state "
                            f"(`{root_node.id}`) — runs once at trace "
                            f"time, not per call")

    # re-exported for tests / doc tooling
    @staticmethod
    def describe_roots(project: Project) -> List[str]:
        c = TraceSafetyChecker()
        return sorted({r for _, r in c._reach(project, c._find_roots(project))})
