"""snapshot-immutability — published snapshots and plans are read-only.

The epoch machinery (PR 5) only works because a published ``RunSet`` —
and everything a query derives from it: the ``QueryPlan``, its
``*Source`` entries — is immutable. A reader holding epoch N must see
epoch N forever; the PR 3 PP hack (temporarily overwriting ``t_min`` /
``t_max`` on runs inside a pinned snapshot) is exactly the bug class this
rule exists to keep dead.

Flags, outside the owning class's constructors:

* attribute assignment on a value known to be a protected type
  (``snap.epoch = …``, ``plan.k = …``);
* in-place container mutation on a protected value's fields
  (``plan.sources.append(…)``, ``snap.levels[0] = …``);
* attribute assignment on loop variables drawn *out of* a protected
  value's containers (``for run in snap.levels[i]: run.t_min = …`` —
  snapshot contents are as frozen as the snapshot);
* ``object.__setattr__`` frozen-dataclass bypasses on protected values;
* a protected class declared as a dataclass without ``frozen=True`` when
  the catalog says it must be frozen (``RunSet``).

Type inference is deliberately local and conservative: parameter
annotations, ``x: RunSet`` annotated assigns, direct constructor calls
(``x = QueryPlan(…)``), and a small producer map of registry/index
methods known to return snapshots or plans. A value the checker cannot
type is never flagged.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, Optional

from .base import (
    Checker, Finding, Module, Project, attr_chain, call_name,
    iter_functions, register,
)

#: type names whose instances must never mutate after construction.
#: PR 10 grows the set with the decision-record family: the autotuner's
#: feedback loop and the BENCH adaptation traces assume published
#: decisions never change after the fact.
def _is_protected_type(name: str) -> bool:
    return (name in {"RunSet", "QueryPlan", "SourceOps",
                     "Recommendation", "TierDecision", "RationaleEntry",
                     "DecisionRecord", "Knobs", "WorkloadKey",
                     "GatewayStats"}
            or name.endswith("Source"))


#: methods whose return value is a protected type (producer map)
PRODUCERS: Dict[str, str] = {
    "current": "RunSet",   # RunRegistry.current()
    "pin": "RunSet",       # RunRegistry.pin() -> pinned snapshot
    "plan": "QueryPlan",   # CLSM.plan()
}

#: container methods that mutate their receiver in place
MUTATOR_METHODS = {
    "append", "extend", "insert", "remove", "pop", "clear", "sort",
    "reverse", "update", "setdefault", "popitem", "add", "discard",
}

CONSTRUCTORS = {"__init__", "__post_init__", "__new__"}

#: classes the catalog requires to be frozen dataclasses
MUST_BE_FROZEN = {"RunSet", "Recommendation", "TierDecision",
                  "RationaleEntry", "DecisionRecord", "Knobs",
                  "WorkloadKey", "GatewayStats"}


def _dataclass_frozen(cls: ast.ClassDef) -> Optional[bool]:
    """True/False if ``cls`` is a dataclass (frozen or not); None if it is
    not decorated as a dataclass at all."""
    for dec in cls.decorator_list:
        chain = attr_chain(dec.func if isinstance(dec, ast.Call) else dec)
        if chain not in {"dataclass", "dataclasses.dataclass"}:
            continue
        if not isinstance(dec, ast.Call):
            return False
        for kw in dec.keywords:
            if kw.arg == "frozen":
                return isinstance(kw.value, ast.Constant) and \
                    kw.value.value is True
        return False
    return None


class _FnScope:
    """Per-function type environment: var name -> protected type name, and
    var name -> 'contents of <type>' for values drawn out of snapshots."""

    def __init__(self):
        self.types: Dict[str, str] = {}
        self.contents: Dict[str, str] = {}

    def learn(self, name: str, type_name: Optional[str]):
        if type_name and _is_protected_type(type_name):
            self.types[name] = type_name
        else:
            # reassignment to an untyped value clears the binding
            self.types.pop(name, None)
            self.contents.pop(name, None)


def _outer_annotation(node: Optional[ast.AST]) -> Optional[str]:
    """The *outermost* type name of an annotation, unwrapping string
    annotations and ``Optional[X]`` / ``Final[X]``. Containers OF a
    protected type (``List[RationaleEntry]``, ``Dict[Knobs, _Arm]``) stay
    untyped on purpose: the container is mutable even when its elements
    are frozen — only a value whose own type is protected is guarded."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        try:
            node = ast.parse(node.value, mode="eval").body
        except SyntaxError:
            return None
    if isinstance(node, ast.Subscript):
        head = attr_chain(node.value)
        if head and head.split(".")[-1] in {"Optional", "Final"}:
            return _outer_annotation(node.slice)
        return None
    if isinstance(node, (ast.Name, ast.Attribute)):
        chain = attr_chain(node)
        return chain.split(".")[-1] if chain else None
    return None


def _infer_value_type(value: ast.AST) -> Optional[str]:
    """Protected type name of an expression, if statically knowable."""
    if isinstance(value, ast.Call):
        name = call_name(value)
        if name and _is_protected_type(name):
            return name
        if name in PRODUCERS:
            return PRODUCERS[name]
        if name == "replace":  # dataclasses.replace(snap, …) keeps the type
            if value.args:
                return _infer_value_type(value.args[0])
    elif isinstance(value, ast.Name):
        return None  # handled via the scope env by the caller
    return None


@register
class SnapshotImmutabilityChecker(Checker):
    name = "snapshot-immutability"
    description = ("RunSet / QueryPlan / *Source values (and snapshot "
                   "contents) must not be mutated outside their "
                   "constructors; declared-frozen dataclasses stay frozen")

    def check(self, project: Project) -> Iterable[Finding]:
        for mod in project.modules:
            yield from self._check_frozen_decls(mod)
            for fn, class_name in iter_functions(mod.tree):
                yield from self._check_function(mod, fn, class_name)

    # ------------------------------------------------- class declarations
    def _check_frozen_decls(self, mod: Module):
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) and node.name in MUST_BE_FROZEN:
                frozen = _dataclass_frozen(node)
                if frozen is False:
                    yield Finding(
                        mod.path, node.lineno, node.col_offset, self.name,
                        f"{node.name} must be declared "
                        f"@dataclass(frozen=True) — published snapshots "
                        f"are immutable by contract")

    # ------------------------------------------------------ function body
    def _check_function(self, mod: Module, fn, class_name: Optional[str]):
        in_ctor = (class_name is not None
                   and _is_protected_type(class_name)
                   and fn.name in CONSTRUCTORS)
        scope = _FnScope()
        # parameters: annotations type them; `self` in a protected class's
        # non-constructor methods is itself protected
        args = fn.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            name = _outer_annotation(a.annotation)
            if name is not None and _is_protected_type(name):
                scope.types[a.arg] = name
        if class_name is not None and _is_protected_type(class_name) \
                and not in_ctor:
            scope.types["self"] = class_name
        yield from self._walk(mod, fn.body, scope)

    def _walk(self, mod: Module, stmts, scope: _FnScope):
        for stmt in stmts:
            yield from self._check_stmt(mod, stmt, scope)
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, field, None)
                if sub and not isinstance(stmt, (ast.FunctionDef,
                                                 ast.AsyncFunctionDef)):
                    yield from self._walk(mod, sub, scope)
            for h in getattr(stmt, "handlers", []) or []:
                yield from self._walk(mod, h.body, scope)

    def _root_binding(self, node: ast.AST, scope: _FnScope):
        """(root var name, protected type, via) for an expression rooted at
        a typed variable; via='contents' when the var holds snapshot
        contents rather than the snapshot itself."""
        while isinstance(node, (ast.Subscript, ast.Attribute)):
            node = node.value
        if isinstance(node, ast.Name):
            if node.id in scope.types:
                return node.id, scope.types[node.id], "value"
            if node.id in scope.contents:
                return node.id, scope.contents[node.id], "contents"
        return None

    def _check_stmt(self, mod: Module, stmt: ast.stmt, scope: _FnScope):
        # --- learn types from assignments / for-loops first -------------
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                          ast.Name):
            name = _outer_annotation(stmt.annotation)
            prot = name if (name is not None
                            and _is_protected_type(name)) else None
            scope.learn(stmt.target.id, prot)
        elif isinstance(stmt, ast.Assign):
            t = _infer_value_type(stmt.value)
            if t is None and isinstance(stmt.value, ast.Name):
                t = scope.types.get(stmt.value.id)  # alias
            for tgt in stmt.targets:
                if isinstance(tgt, ast.Name):
                    scope.learn(tgt.id, t)
        elif isinstance(stmt, ast.For):
            # for run in snap.levels[i] / plan.sources: run is CONTENTS
            binding = self._root_binding(stmt.iter, scope)
            if binding and isinstance(stmt.target, ast.Name):
                scope.contents[stmt.target.id] = binding[1]

        # --- flag mutations ---------------------------------------------
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        for tgt in targets:
            elts = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) else [tgt]
            for el in elts:
                if not isinstance(el, (ast.Attribute, ast.Subscript)):
                    continue
                binding = self._root_binding(el, scope)
                if binding is None:
                    continue
                var, tname, via = binding
                # idempotent lazy caches on snapshot CONTENTS (`run._norms2`,
                # `run._dev_view`) are the one sanctioned write: underscore
                # attrs, same-value-on-race memoization
                if via == "contents" and isinstance(el, ast.Attribute) \
                        and el.attr.startswith("_"):
                    continue
                what = (f"contents of a pinned {tname} snapshot"
                        if via == "contents" else f"a {tname}")
                yield Finding(
                    mod.path, el.lineno, el.col_offset, self.name,
                    f"mutation of {what} (`{var}`) outside its "
                    f"constructor — published snapshots/plans are "
                    f"immutable; build a new object instead")
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            call = stmt.value
            chain = attr_chain(call.func)
            # object.__setattr__(snap, …): frozen-dataclass bypass
            if chain == "object.__setattr__" and call.args:
                arg0 = call.args[0]
                if isinstance(arg0, ast.Name) and arg0.id in scope.types:
                    yield Finding(
                        mod.path, call.lineno, call.col_offset, self.name,
                        f"object.__setattr__ on a "
                        f"{scope.types[arg0.id]} (`{arg0.id}`) bypasses "
                        f"the frozen-dataclass contract")
            f = call.func
            if isinstance(f, ast.Attribute) and f.attr in MUTATOR_METHODS:
                binding = self._root_binding(f.value, scope)
                if binding is not None:
                    var, tname, via = binding
                    what = (f"contents of a pinned {tname} snapshot"
                            if via == "contents" else f"a {tname}")
                    yield Finding(
                        mod.path, call.lineno, call.col_offset, self.name,
                        f"in-place .{f.attr}() on {what} (`{var}`) — "
                        f"published snapshots/plans are immutable")
        if isinstance(stmt, ast.Delete):
            for tgt in stmt.targets:
                if isinstance(tgt, (ast.Attribute, ast.Subscript)):
                    binding = self._root_binding(tgt, scope)
                    if binding is not None:
                        var, tname, _ = binding
                        yield Finding(
                            mod.path, tgt.lineno, tgt.col_offset, self.name,
                            f"del on a {tname} (`{var}`) — published "
                            f"snapshots/plans are immutable")
