"""lock-discipline — guarded classes mutate their state only under lock.

The concurrency story (PR 5) is: every class shared between the serving
threads and the ingest worker serializes its mutable state behind one
instance lock. This checker makes that lexical: inside the guarded
classes, any write to ``self.*`` (attribute assignment, augmented
assignment, subscript store like ``self.stats["hits"] += 1``, or a
mutating container call like ``self.log.append(...)``) must sit inside a
``with self._lock:`` / ``with self._cond:`` block.

Exemptions, matching the repo's real conventions:

* ``__init__`` / ``__post_init__`` / ``__new__`` — construction happens
  before the object is shared.
* methods whose name ends in ``_locked`` — the caller-holds-lock
  convention (``RunRegistry._reap_locked`` and friends). The caller's own
  ``with self._lock`` is still checked at the call site's scope.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from .base import Checker, Finding, Module, Project, attr_chain, register

#: classes whose instances are shared across threads behind an instance lock
GUARDED_CLASSES = {
    "RunRegistry", "IngestPipeline", "VerifyEngine", "DiskModel", "RawStore",
    "FileStore", "WriteAheadLog", "StorageEngine", "ReadaheadPool", "Gateway",
    "AutoTuner",
}

#: lock attributes whose ``with`` blocks count as holding the lock
LOCK_ATTRS = {"_lock", "_cond"}

#: container methods that mutate their receiver in place
MUTATOR_METHODS = {
    "append", "extend", "insert", "remove", "pop", "clear", "sort",
    "reverse", "update", "setdefault", "popitem", "add", "discard",
}

CONSTRUCTORS = {"__init__", "__post_init__", "__new__"}


def _self_root(node: ast.AST) -> str | None:
    """Dotted ``self.…`` chain of a write target, unwrapping subscripts:
    ``self.stats["hits"]`` -> ``self.stats``; returns None for non-self."""
    while isinstance(node, ast.Subscript):
        node = node.value
    chain = attr_chain(node)
    if chain and chain.startswith("self."):
        return chain
    return None


def _is_lock_with(stmt: ast.With) -> bool:
    for item in stmt.items:
        chain = attr_chain(item.context_expr)
        if chain and chain.startswith("self.") and \
                chain.split(".")[-1] in LOCK_ATTRS:
            return True
    return False


@register
class LockDisciplineChecker(Checker):
    name = "lock-discipline"
    description = ("writes to RunRegistry/IngestPipeline/VerifyEngine/"
                   "DiskModel/RawStore state must happen under `with "
                   "self._lock` (or in a `*_locked` caller-holds-lock "
                   "helper / constructor)")

    def check(self, project: Project) -> Iterable[Finding]:
        for mod in project.modules:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.ClassDef) and \
                        node.name in GUARDED_CLASSES:
                    yield from self._check_class(mod, node)

    def _check_class(self, mod: Module, cls: ast.ClassDef):
        for item in cls.body:
            if not isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if item.name in CONSTRUCTORS or item.name.endswith("_locked"):
                continue
            yield from self._check_body(mod, cls, item, item.body,
                                        locked=False)

    def _check_body(self, mod: Module, cls: ast.ClassDef, fn,
                    stmts: List[ast.stmt], locked: bool):
        for stmt in stmts:
            if isinstance(stmt, ast.With):
                inner = locked or _is_lock_with(stmt)
                yield from self._check_body(mod, cls, fn, stmt.body, inner)
                continue
            if not locked:
                yield from self._check_stmt(mod, cls, fn, stmt)
            # recurse into compound statements, preserving lock state
            for field in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, field, None)
                if sub:
                    yield from self._check_body(mod, cls, fn, sub, locked)
            for h in getattr(stmt, "handlers", []) or []:
                yield from self._check_body(mod, cls, fn, h.body, locked)

    def _check_stmt(self, mod: Module, cls: ast.ClassDef, fn, stmt: ast.stmt):
        targets: List[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        for tgt in targets:
            # tuple unpacking: a, self.x = ... checks each element
            elts = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) else [tgt]
            for el in elts:
                chain = _self_root(el)
                if chain is None:
                    continue
                yield Finding(
                    mod.path, el.lineno, el.col_offset, self.name,
                    f"{cls.name}.{fn.name} writes `{chain}` outside "
                    f"`with self._lock` (guarded class state)")
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            call = stmt.value
            f = call.func
            if isinstance(f, ast.Attribute) and f.attr in MUTATOR_METHODS:
                chain = _self_root(f.value)
                if chain is not None:
                    yield Finding(
                        mod.path, call.lineno, call.col_offset, self.name,
                        f"{cls.name}.{fn.name} mutates `{chain}` via "
                        f".{f.attr}() outside `with self._lock` "
                        f"(guarded class state)")
        if isinstance(stmt, ast.Delete):
            for tgt in stmt.targets:
                chain = _self_root(tgt)
                if chain is not None:
                    yield Finding(
                        mod.path, tgt.lineno, tgt.col_offset, self.name,
                        f"{cls.name}.{fn.name} deletes from `{chain}` "
                        f"outside `with self._lock` (guarded class state)")
