"""precision-discipline — keep the f32-screen / f64-certify split honest.

The exactness argument (PR 4) is a precision contract: the device screen
runs in f32 (fast, error bounded by the ``4 n u |q||x|`` matmul term) and
everything on the certify/re-rank side runs in f64 (the diff form, immune
to cancellation). Three statically checkable rules protect it:

1. **f64 into a screen matmul** — a value cast to float64 flowing into a
   matmul/einsum inside a ``*screen*`` function silently doubles the
   screen's bandwidth and defeats the f32 kernel path.
2. **f32 reaching certify/re-rank without an explicit cast** — every
   matmul/einsum inside a ``*rerank*``/``*certify*`` function must make
   its precision explicit: a ``.astype(…float64…)`` on an operand or a
   ``dtype=…float64`` kwarg on the reduction itself. An einsum that
   silently inherits f32 inputs is exactly the cancellation bug the diff
   form exists to avoid.
3. **dtype-less array constructors in ``core/``/``kernels/``** — bare
   ``jnp.zeros/ones/arange/empty/full`` default to the x64-flag-dependent
   dtype, so the same code builds f32 on one host and f64 on another;
   hot-path modules must spell the dtype.

The mixed-precision storage tier (bf16/int8 arenas) adds two more:

4. **un-upcast low-precision operands in certify/re-rank matmuls** — a
   value derived from bf16/int8 storage that reaches a
   ``*rerank*``/``*certify*`` matmul without an explicit float64 upcast
   poisons the exact side with quantization error the certificate cannot
   see. Storage dtype and compute dtype are separate contracts: quantized
   values may only enter the f32 screen, never the f64 re-rank.
5. **dtype-less casts in quantization helpers** — inside ``*quant*``
   functions, ``.astype(...)`` must spell a concrete dtype
   (``np.int8``, ``jnp.bfloat16``, …). A cast that inherits a dtype
   dynamically (``x.astype(dt)``, ``x.astype(y.dtype)``) makes the
   stored precision — and therefore the certificate's error term —
   depend on runtime state the bound derivation never sees.
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional, Set

from .base import (
    Checker, Finding, Module, Project, attr_chain, call_name, iter_functions,
    register,
)

#: jnp constructors that must carry an explicit dtype in core/ and kernels/
DTYPE_REQUIRED = {"zeros", "ones", "arange", "empty", "full"}
_ARRAY_MODULES = {"jnp", "jax.numpy"}

MATMUL_CALLEES = {"dot", "matmul", "einsum", "dot_general", "tensordot"}

_SCREEN_MARKERS = ("screen",)
_CERTIFY_MARKERS = ("rerank", "re_rank", "certify")
_QUANT_MARKERS = ("quant",)

#: storage dtypes of the mixed-precision arena tier — values tainted by
#: these must be explicitly upcast before the f64 certify/re-rank side
_LOWP_TOKENS = ("bfloat16", "int8")

#: concrete dtype spellings accepted as the argument of an ``.astype`` in
#: a quantization helper (rule 5) — anything else is a dynamic dtype
_DTYPE_TOKENS = {
    "float16", "float32", "float64", "bfloat16",
    "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool_",
}


def _expr_mentions_f64(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "float64":
            return True
        if isinstance(sub, ast.Name) and sub.id == "float64":
            return True
        if isinstance(sub, ast.Constant) and sub.value == "float64":
            return True
    return False


def _f64_locals(fn: ast.AST) -> Set[str]:
    """Names assigned from expressions that mention float64 (casts,
    f64 constructors) — the checker's one-function dataflow."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and _expr_mentions_f64(node.value):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out.add(tgt.id)
    return out


def _expr_mentions_lowp(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _LOWP_TOKENS:
            return True
        if isinstance(sub, ast.Name) and sub.id in _LOWP_TOKENS:
            return True
        if isinstance(sub, ast.Constant) and sub.value in _LOWP_TOKENS:
            return True
    return False


def _lowp_locals(fn: ast.AST) -> Set[str]:
    """Names assigned from expressions mentioning bf16/int8 — values that
    carry quantization error into whatever consumes them."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and _expr_mentions_lowp(node.value):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out.add(tgt.id)
    return out


def _operands(node: ast.AST):
    """Matmul operand expressions of a call or ``@`` binop."""
    if isinstance(node, ast.BinOp):
        return [node.left, node.right]
    if isinstance(node, ast.Call):
        return list(node.args)
    return []


def _operand_is_f64(expr: ast.AST, f64_names: Set[str]) -> bool:
    if _expr_mentions_f64(expr):
        return True
    root = expr
    while isinstance(root, (ast.Attribute, ast.Subscript)):
        root = root.value
    return isinstance(root, ast.Name) and root.id in f64_names


def _operand_is_lowp(expr: ast.AST, lowp_names: Set[str]) -> bool:
    if _expr_mentions_lowp(expr):
        return True
    root = expr
    while isinstance(root, (ast.Attribute, ast.Subscript)):
        root = root.value
    return isinstance(root, ast.Name) and root.id in lowp_names


def _dtype_kwarg_f64(node: ast.AST) -> bool:
    if isinstance(node, ast.Call):
        for kw in node.keywords:
            if kw.arg == "dtype" and _expr_mentions_f64(kw.value):
                return True
    return False


def _marked(name: str, markers) -> bool:
    low = name.lower()
    return any(m in low for m in markers)


@register
class PrecisionChecker(Checker):
    name = "precision-discipline"
    description = ("no f64 into screen-side matmuls, explicit f64 casts on "
                   "the certify/re-rank path (and no un-upcast bf16/int8 "
                   "reaching it), explicit dtypes on jnp constructors in "
                   "core/ and kernels/ and on casts in quant helpers")

    def check(self, project: Project) -> Iterable[Finding]:
        for mod in project.modules:
            if mod.is_core or mod.is_kernels:
                yield from self._check_constructors(mod)
            for fn, _cls in iter_functions(mod.tree):
                if _marked(fn.name, _SCREEN_MARKERS) and \
                        not _marked(fn.name, _CERTIFY_MARKERS):
                    yield from self._check_screen(mod, fn)
                if _marked(fn.name, _CERTIFY_MARKERS):
                    yield from self._check_certify(mod, fn)
                if _marked(fn.name, _QUANT_MARKERS):
                    yield from self._check_quant_casts(mod, fn)

    # ------------------------------------------------- rule 3: bare dtypes
    def _check_constructors(self, mod: Module):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if not (isinstance(f, ast.Attribute)
                    and f.attr in DTYPE_REQUIRED):
                continue
            owner = attr_chain(f.value)
            if owner not in _ARRAY_MODULES:
                continue
            has_dtype = any(kw.arg == "dtype" for kw in node.keywords)
            # full(shape, fill) / zeros(shape, dt): a positional beyond the
            # shape/fill slots is a dtype
            min_args = 2 if f.attr == "full" else 1
            if f.attr == "arange":
                min_args = 3  # arange(start, stop, step, dtype)
            if not has_dtype and len(node.args) <= min_args:
                yield Finding(
                    mod.path, node.lineno, node.col_offset, self.name,
                    f"dtype-less jnp.{f.attr}(…) in a hot-path module — "
                    f"the default dtype follows the x64 flag; spell it "
                    f"(e.g. dtype=jnp.float32)")

    # ---------------------------------------- rule 1: f64 into the screen
    def _check_screen(self, mod: Module, fn):
        f64_names = _f64_locals(fn)
        for node in ast.walk(fn):
            mm = None
            if isinstance(node, ast.BinOp) and isinstance(node.op,
                                                          ast.MatMult):
                mm = node
            elif isinstance(node, ast.Call) and \
                    call_name(node) in MATMUL_CALLEES:
                mm = node
            if mm is None:
                continue
            if _dtype_kwarg_f64(mm):
                continue  # einsum(…, dtype=f64) is the certify side's idiom
            if isinstance(mm, ast.Call):
                owner = attr_chain(mm.func.value) if isinstance(
                    mm.func, ast.Attribute) else None
                if owner in {"np", "numpy"}:
                    # host-side screens ARE the provably exact fallback —
                    # np matmuls in f64 are their whole point; the f32
                    # contract governs the device (jnp) screen
                    continue
            for op in _operands(mm):
                if isinstance(op, ast.Constant):
                    continue  # einsum subscript strings
                if _operand_is_f64(op, f64_names):
                    yield Finding(
                        mod.path, op.lineno, op.col_offset, self.name,
                        f"float64 operand in a screen-side matmul "
                        f"(`{fn.name}`) — the screen runs in f32; f64 "
                        f"doubles bandwidth and defeats the kernel path")

    # -------------------------------- rule 2: implicit f32 into certify
    # ------------------------- rule 4: un-upcast bf16/int8 into certify
    def _check_certify(self, mod: Module, fn):
        f64_names = _f64_locals(fn)
        lowp_names = _lowp_locals(fn)
        for node in ast.walk(fn):
            mm = None
            if isinstance(node, ast.BinOp) and isinstance(node.op,
                                                          ast.MatMult):
                mm = node
            elif isinstance(node, ast.Call) and \
                    call_name(node) in MATMUL_CALLEES:
                mm = node
            if mm is None:
                continue
            if _dtype_kwarg_f64(mm):
                continue  # dtype=f64 upcasts every input before reducing
            ops = [op for op in _operands(mm)
                   if not isinstance(op, ast.Constant)]
            if ops and not any(_operand_is_f64(op, f64_names)
                               for op in ops):
                yield Finding(
                    mod.path, mm.lineno, mm.col_offset, self.name,
                    f"matmul on the certify/re-rank path (`{fn.name}`) "
                    f"with no explicit float64 cast — f32 accumulation "
                    f"here is the cancellation bug the f64 re-rank "
                    f"exists to prevent")
            for op in ops:
                if _operand_is_lowp(op, lowp_names) and \
                        not _operand_is_f64(op, f64_names):
                    yield Finding(
                        mod.path, op.lineno, op.col_offset, self.name,
                        f"bf16/int8 operand in a certify/re-rank matmul "
                        f"(`{fn.name}`) without a float64 upcast — "
                        f"quantized storage may feed the screen, never "
                        f"the exact side; re-rank from the f32 host "
                        f"mirror and upcast explicitly")

    # --------------------- rule 5: dynamic dtypes in quantization casts
    def _check_quant_casts(self, mod: Module, fn):
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype" and node.args):
                continue
            arg = node.args[0]
            spelled = any(
                (isinstance(sub, ast.Attribute)
                 and sub.attr in _DTYPE_TOKENS)
                or (isinstance(sub, ast.Name) and sub.id in _DTYPE_TOKENS)
                or (isinstance(sub, ast.Constant)
                    and sub.value in _DTYPE_TOKENS)
                for sub in ast.walk(arg))
            if not spelled:
                yield Finding(
                    mod.path, node.lineno, node.col_offset, self.name,
                    f"dtype-less cast in a quantization helper "
                    f"(`{fn.name}`) — `.astype(…)` here must spell a "
                    f"concrete dtype (np.int8, jnp.bfloat16, …); a "
                    f"dynamic dtype makes the stored precision, and the "
                    f"certificate's error term, runtime-dependent")
