"""palmlint CLI — ``python -m repro.analysis [paths…]`` / ``palmlint``.

Exit codes: 0 clean (suppressed findings allowed), 1 unannotated findings,
2 usage error. ``--format github`` emits GitHub Actions error
annotations so CI findings land on the PR diff.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from . import checkers  # noqa: F401  (registers the rule families)
from .base import CHECKERS, RULES, Finding, Module, Project, parse_module, run_project

_SKIP_DIRS = {".git", "__pycache__", ".venv", "node_modules",
              "palmlint_fixtures"}


def collect_files(paths: Sequence[str]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            files.extend(sorted(
                f for f in path.rglob("*.py")
                if not (set(f.parts) & _SKIP_DIRS)))
        elif path.suffix == ".py":
            files.append(path)
    return files


def build_project(files: Sequence[Path],
                  root: Optional[Path] = None
                  ) -> tuple[Project, List[Finding]]:
    root = root or Path.cwd()
    modules: List[Module] = []
    errors: List[Finding] = []
    for f in files:
        try:
            rel = f.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = f.as_posix()
        mod, err = parse_module(f, rel)
        if err is not None:
            errors.append(err)
        else:
            modules.append(mod)
    return Project(modules), errors


def lint_source(source: str, path: str = "<string>",
                select: Optional[Sequence[str]] = None,
                extra_modules: Sequence[Module] = ()) -> List[Finding]:
    """Lint a raw source string (the seeded-regression test entry point).
    Returns only LIVE findings; ``# palmlint: ignore`` still applies."""
    import ast as _ast
    tree = _ast.parse(source, filename=path)
    from .base import _parse_ignores
    mod = Module(path=path, source=source, tree=tree,
                 ignores=_parse_ignores(source))
    project = Project([mod, *extra_modules])
    live, _ = run_project(project, select)
    return [f for f in live if f.path == path]


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="palmlint",
        description="repo-specific invariant checks (concurrency, "
                    "snapshot immutability, trace safety, precision)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--select", action="append", default=None,
                    metavar="RULE", help="run only these rules "
                    "(repeatable); default: all")
    ap.add_argument("--format", choices=["text", "github"], default="text")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print findings silenced by "
                         "`# palmlint: ignore[rule]` annotations")
    args = ap.parse_args(argv)

    if args.list_rules:
        width = max(len(n) for n in RULES)
        for name in sorted(RULES):
            print(f"{name:<{width}}  {RULES[name]}")
        return 0

    files = collect_files(args.paths or ["src"])
    if not files:
        print(f"palmlint: no python files under {args.paths}",
              file=sys.stderr)
        return 2
    project, parse_errors = build_project(files)
    try:
        live, suppressed = run_project(project, args.select)
    except ValueError as e:
        print(f"palmlint: {e}", file=sys.stderr)
        return 2
    live = sorted(parse_errors + live)

    for f in live:
        print(f.render(args.format))
    if args.show_suppressed:
        for f in suppressed:
            print(f"suppressed: {f.render('text')}")
    n_rules = len(args.select) if args.select else len(CHECKERS)
    print(f"palmlint: {len(files)} files, {n_rules} rules, "
          f"{len(live)} finding(s), {len(suppressed)} suppressed",
          file=sys.stderr)
    return 1 if live else 0


if __name__ == "__main__":
    sys.exit(main())
