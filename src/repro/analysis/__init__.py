"""palmlint — repo-specific static analysis + runtime sanitizer.

Static side (stdlib-only, runs in CI's bare lint job):

    python -m repro.analysis src          # lint, exit 1 on findings
    python -m repro.analysis --list-rules

Runtime side (jax/numpy land, opt-in):

    REPRO_SANITIZE=1 pytest -m slow       # lock-order + snapshot tripwires

The static entry points are re-exported here; :mod:`.sanitize` is NOT
imported eagerly because it touches ``repro.core`` (numpy/jax) and the
lint gate must work without either installed.
"""
from .base import CHECKERS, RULES, Finding, Module, Project, run_project
from .cli import build_project, collect_files, lint_source, main

__all__ = [
    "CHECKERS", "RULES", "Finding", "Module", "Project", "run_project",
    "build_project", "collect_files", "lint_source", "main",
]
