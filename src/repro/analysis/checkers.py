"""Import-side-effect module: loading it registers every rule family.

Split out so ``base`` stays import-cycle-free and adding a checker is one
import line here plus its module.
"""
from . import lock_discipline  # noqa: F401
from . import precision  # noqa: F401
from . import snapshot_immutability  # noqa: F401
from . import trace_safety  # noqa: F401
