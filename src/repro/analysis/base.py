"""palmlint core — the repo-specific static-analysis framework.

The engine's correctness rests on invariants that used to live only in
prose (CONTRIBUTING.md "Concurrency invariants"): registry state mutates
only under the registry lock, published snapshots are immutable, jitted
screen passes stay pure, and the f32-screen / f64-certify precision split
is the whole exactness argument. This package machine-checks them.

Architecture:

* :class:`Module` — one parsed source file: AST, line table, and the
  per-line ``# palmlint: ignore[rule]`` annotations.
* :class:`Project` — every module under analysis plus cross-module
  indexes (functions by name, classes by name) so checkers that walk the
  call graph (trace-safety) resolve callees beyond file boundaries.
* :class:`Checker` — one rule family. Checkers register themselves in
  :data:`CHECKERS` via :func:`register` and implement
  ``check(project) -> Iterable[Finding]``.
* :func:`run_project` — runs the selected checkers and splits the
  results into live findings and annotation-suppressed ones.

Everything here is stdlib-only (``ast`` + ``re``): the lint gate must run
in CI's bare lint job, before any numpy/jax install.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Type

#: rule id -> one-line description (the ``--list-rules`` catalog)
RULES: Dict[str, str] = {}

#: ``# palmlint: ignore[rule]`` / ``ignore[rule-a, rule-b]`` / ``ignore[*]``
_IGNORE_RE = re.compile(r"#\s*palmlint:\s*ignore\[([^\]]+)\]")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a source location."""

    path: str  # project-relative posix path
    line: int  # 1-based
    col: int  # 0-based (ast convention)
    rule: str
    message: str

    def render(self, fmt: str = "text") -> str:
        if fmt == "github":  # GitHub Actions error annotation
            return (f"::error file={self.path},line={self.line},"
                    f"col={self.col + 1},title=palmlint[{self.rule}]::"
                    f"{self.message}")
        return f"{self.path}:{self.line}:{self.col + 1}: [{self.rule}] {self.message}"


@dataclasses.dataclass
class Module:
    """One parsed source file plus its ignore annotations."""

    path: str  # project-relative posix path
    source: str
    tree: ast.Module
    #: line (1-based) -> set of suppressed rule ids ("*" = all)
    ignores: Dict[int, set]

    @property
    def is_core(self) -> bool:
        return "/core/" in f"/{self.path}"

    @property
    def is_kernels(self) -> bool:
        return "/kernels/" in f"/{self.path}"


@dataclasses.dataclass
class FunctionInfo:
    """One function/method definition, indexed for call-graph walks."""

    module: Module
    node: ast.AST  # FunctionDef | AsyncFunctionDef | Lambda
    name: str  # bare name ("<lambda>" for lambdas)
    qualname: str  # Class.method or module-level name
    class_name: Optional[str] = None


def _parse_ignores(source: str) -> Dict[int, set]:
    out: Dict[int, set] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _IGNORE_RE.search(line)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        if rules:
            out[i] = rules
    return out


def parse_module(path: Path, rel: str) -> Tuple[Optional[Module], Optional[Finding]]:
    """Parse one file; a syntax error becomes a ``parse-error`` finding
    (the gate must fail loudly on unparseable sources, not skip them)."""
    source = path.read_text(encoding="utf-8")
    try:
        tree = ast.parse(source, filename=rel)
    except SyntaxError as e:
        return None, Finding(rel, e.lineno or 1, (e.offset or 1) - 1,
                             "parse-error", f"cannot parse: {e.msg}")
    return Module(path=rel, source=source, tree=tree,
                  ignores=_parse_ignores(source)), None


class Project:
    """Every module under analysis + cross-module lookup indexes."""

    def __init__(self, modules: Sequence[Module]):
        self.modules = list(modules)
        #: bare function/method name -> [FunctionInfo, ...]
        self.functions: Dict[str, List[FunctionInfo]] = {}
        #: class name -> (module, ClassDef) of the FIRST definition
        self.classes: Dict[str, Tuple[Module, ast.ClassDef]] = {}
        for mod in self.modules:
            self._index(mod)

    def _index(self, mod: Module) -> None:
        # every def is indexed — including functions nested inside other
        # functions (dryrun's local `build`/`query` closures are jit roots);
        # class_name is set only for direct class-body methods
        def visit(node: ast.AST, class_ctx: Optional[str]) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.ClassDef):
                    self.classes.setdefault(child.name, (mod, child))
                    visit(child, child.name)
                elif isinstance(child, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    qual = (f"{class_ctx}.{child.name}" if class_ctx
                            else child.name)
                    self.functions.setdefault(child.name, []).append(
                        FunctionInfo(mod, child, child.name, qual,
                                     class_ctx))
                    visit(child, None)  # defs nested in a method are plain
                else:
                    visit(child, class_ctx)

        visit(mod.tree, None)

    def resolve_call(self, name: str, mod: Module,
                     class_name: Optional[str] = None) -> Optional[FunctionInfo]:
        """Best-effort callee resolution by bare name.

        Preference order: a method of the caller's own class, a definition
        in the caller's own module, then a project-wide UNIQUE definition.
        Ambiguous names resolve to nothing — a missed edge only weakens
        the check, a wrong edge fabricates findings."""
        cands = self.functions.get(name, [])
        if not cands:
            return None
        if class_name is not None:
            own = [f for f in cands if f.class_name == class_name]
            if len(own) == 1:
                return own[0]
        local = [f for f in cands if f.module is mod]
        if len(local) == 1:
            return local[0]
        if len(cands) == 1:
            return cands[0]
        return None


class Checker:
    """Base class for one rule family; subclasses self-register."""

    name: str = ""
    description: str = ""

    def check(self, project: Project) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


CHECKERS: Dict[str, Type[Checker]] = {}


def register(cls: Type[Checker]) -> Type[Checker]:
    assert cls.name, "checker must define a rule name"
    CHECKERS[cls.name] = cls
    RULES[cls.name] = cls.description
    return cls


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------
def attr_chain(node: ast.AST) -> Optional[str]:
    """Dotted name of an attribute/name chain: ``self._lock``,
    ``np.random.default_rng`` — None when the chain has non-name parts
    (calls, subscripts) in the middle."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    """The final callee name of a call: ``f`` for both ``f(x)`` and
    ``a.b.f(x)``."""
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def annotation_names(node: Optional[ast.AST]) -> set:
    """Every bare name appearing in an annotation (handles Optional[X],
    string annotations, subscripts)."""
    out: set = set()
    if node is None:
        return out
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        try:
            node = ast.parse(node.value, mode="eval").body
        except SyntaxError:
            return out
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.add(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.add(sub.attr)
    return out


def iter_functions(tree: ast.Module):
    """Yield (node, class_name) for every function/method def (one level
    of class nesting; nested defs yield with their enclosing class)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield item, node.name
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, None


def suppressed(finding: Finding, module: Module) -> bool:
    rules = module.ignores.get(finding.line)
    return bool(rules) and ("*" in rules or finding.rule in rules)


def run_project(project: Project,
                select: Optional[Sequence[str]] = None
                ) -> Tuple[List[Finding], List[Finding]]:
    """Run the (selected) checkers; returns (live, suppressed) findings,
    both sorted by location."""
    names = list(select) if select else sorted(CHECKERS)
    unknown = [n for n in names if n not in CHECKERS]
    if unknown:
        raise ValueError(f"unknown rule(s): {', '.join(unknown)}; "
                         f"known: {', '.join(sorted(CHECKERS))}")
    by_path = {m.path: m for m in project.modules}
    live: List[Finding] = []
    quiet: List[Finding] = []
    for name in names:
        for f in CHECKERS[name]().check(project):
            (quiet if suppressed(f, by_path[f.path]) else live).append(f)
    return sorted(live), sorted(quiet)
