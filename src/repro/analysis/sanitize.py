"""Runtime sanitizer — ``REPRO_SANITIZE=1`` turns invariants into traps.

The static checkers prove what is lexically visible; this module catches
what only shows up with real threads interleaving:

* **Ranked locks** — the registry lock (rank 0) and the engine lock
  (rank 1) have one legal order: registry → engine (``pin``'s epilogue
  reaps retired runs under the registry lock and calls
  ``VerifyEngine.release_view``). A thread acquiring rank 0 while holding
  rank 1 is one scheduler tick from deadlock; the wrapper raises at the
  acquisition site instead. Each wrapper also records its owning thread,
  so failures name who held what.
* **Snapshot seals** — ``SortedRun`` / ``QueryPlan`` / ``*Source`` objects
  get a ``__setattr__`` tripwire armed when ``__init__`` returns: any
  later public-attribute write raises immediately at the mutation site
  (underscore attributes stay writable — ``run._norms2`` and
  ``run._dev_view`` are idempotent lazy caches). ``RunSet`` is a frozen
  dataclass already; its tripwire just rebrands the failure so stress
  logs say *snapshot mutated* instead of a bare ``FrozenInstanceError``.

Imported lazily (this module touches ``repro.core``, i.e. numpy/jax —
the static lint gate must not pull it in). ``repro.core`` auto-installs
it at import when ``REPRO_SANITIZE=1``; tests call
:func:`install` / :func:`uninstall` directly.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

_tls = threading.local()


class SanitizerError(RuntimeError):
    """An invariant violation caught by the runtime sanitizer."""


def _held() -> List["RankedLock"]:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


class RankedLock:
    """RLock wrapper asserting a global acquisition order by rank."""

    def __init__(self, rank: int, name: str):
        self.rank = rank
        self.name = name
        self.owner: Optional[str] = None  # owning thread name (debugging)
        self._inner = threading.RLock()
        self._depth = 0

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        stack = _held()
        worst = next((h for h in stack
                      if h is not self and h.rank > self.rank), None)
        if worst is not None:
            raise SanitizerError(
                f"lock-order inversion: thread "
                f"{threading.current_thread().name!r} acquires "
                f"{self.name!r} (rank {self.rank}) while holding "
                f"{worst.name!r} (rank {worst.rank}) — the legal order "
                f"is registry -> engine, never the reverse")
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            stack.append(self)
            self._depth += 1
            self.owner = threading.current_thread().name
        return ok

    def release(self) -> None:
        stack = _held()
        # drop the most recent entry for this lock (re-entrant holds)
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is self:
                del stack[i]
                break
        self._depth -= 1
        if self._depth == 0:
            self.owner = None
        self._inner.release()

    def __enter__(self) -> "RankedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


_SEALED_FLAG = "_palm_sealed"


def _seal_class(cls) -> Dict[str, object]:
    """Arm a post-``__init__`` mutation tripwire on ``cls``. Returns the
    originals needed to disarm it."""
    orig_init = cls.__init__
    orig_setattr = cls.__setattr__

    def init(self, *a, **kw):
        orig_init(self, *a, **kw)
        object.__setattr__(self, _SEALED_FLAG, True)

    def setattr_(self, name, value):
        if getattr(self, _SEALED_FLAG, False) and not name.startswith("_"):
            raise SanitizerError(
                f"sanitizer: mutation of sealed {cls.__name__}.{name} "
                f"after construction — published snapshots/plans are "
                f"immutable (build a new object; see "
                f"CONTRIBUTING.md 'Invariants are machine-checked')")
        orig_setattr(self, name, value)

    cls.__init__ = init
    cls.__setattr__ = setattr_
    return {"init": orig_init, "setattr": orig_setattr}


def _brand_frozen(cls) -> Dict[str, object]:
    """Rebrand a frozen dataclass's mutation error as a sanitizer trap."""
    orig_setattr = cls.__setattr__

    def setattr_(self, name, value):
        raise SanitizerError(
            f"sanitizer: mutation of {cls.__name__}.{name} — published "
            f"snapshots are immutable (frozen dataclass); a reader "
            f"pinned at this epoch must see it unchanged forever")

    cls.__setattr__ = setattr_
    return {"setattr": orig_setattr}


_state: Optional[dict] = None


def install() -> None:
    """Arm the sanitizer (idempotent). Wraps the registry/engine locks of
    new AND already-existing instances, and seals the snapshot types."""
    global _state
    if _state is not None:
        return
    from ..core import ctree, plan, run_registry, verify_engine

    st: dict = {"inits": {}, "seals": {}}

    def _ranked_init(cls, rank: int, name: str):
        orig = cls.__init__

        def init(self, *a, **kw):
            orig(self, *a, **kw)
            self._lock = RankedLock(rank, name)

        cls.__init__ = init
        st["inits"][cls] = orig

    _ranked_init(run_registry.RunRegistry, 0, "RunRegistry._lock")
    _ranked_init(verify_engine.VerifyEngine, 1, "VerifyEngine._lock")
    # the engine is a process-wide singleton that may predate install()
    if verify_engine._ENGINE is not None:
        verify_engine._ENGINE._lock = RankedLock(1, "VerifyEngine._lock")

    for cls in (ctree.SortedRun, plan.QueryPlan, plan.SourceOps,
                plan.DenseSource, plan.BlockSource, plan.RangeSource,
                plan.GroupSource):
        st["seals"][cls] = _seal_class(cls)
    st["seals"][run_registry.RunSet] = _brand_frozen(run_registry.RunSet)
    _state = st


def uninstall() -> None:
    """Disarm the sanitizer and restore the original classes. Locks
    already swapped onto live instances keep working (a RankedLock is a
    superset of an RLock), they just stop asserting new inversions on
    classes restored here."""
    global _state
    if _state is None:
        return
    for cls, orig in _state["inits"].items():
        cls.__init__ = orig
    for cls, saved in _state["seals"].items():
        if "init" in saved:
            cls.__init__ = saved["init"]
        cls.__setattr__ = saved["setattr"]
    _state = None


def installed() -> bool:
    return _state is not None
