"""``python -m repro.analysis`` — the palmlint CI gate."""
import sys

from .cli import main

sys.exit(main())
