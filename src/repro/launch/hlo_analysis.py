"""Honest cost accounting for scanned programs.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
by probe: scan(n=16) reports the same flops as scan(n=1)), which would make
every scanned transformer look 10-500x cheaper than it is. Two fixes:

* **FLOPs** are counted on the *jaxpr* (pre-SPMD, global): exact
  2*B*M*N*K for every dot_general / conv, recursing into scan bodies
  multiplied by their static trip count, plus 1 flop/element for
  elementwise work. Per-device = global / n_devices (the SPMD partitioner
  divides dense work evenly under our shardings).

* **Collective + HBM traffic bytes** are parsed from the partitioned HLO
  per *computation*, then multiplied by each computation's execution
  multiplicity, derived from the while-op call graph (trip counts are
  recovered from the loop-condition constants that jax's scan lowering
  emits). Traffic model: every top-level op's output buffer is written
  once and read once (2x output bytes); entry parameters read once.
"""
from __future__ import annotations

import math
import re

from jax.extend import core as jcore

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")


# ---------------------------------------------------------------------------
# jaxpr FLOP counting (global, exact matmuls, scan-aware)
# ---------------------------------------------------------------------------
def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = math.prod(lhs.shape[i] for i in lb) if lb else 1
    k = math.prod(lhs.shape[i] for i in lc) if lc else 1
    m = math.prod(
        lhs.shape[i] for i in range(len(lhs.shape)) if i not in lc and i not in lb
    )
    n = math.prod(
        rhs.shape[i] for i in range(len(rhs.shape)) if i not in rc and i not in rb
    )
    return 2.0 * batch * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # 2 * output elements * kernel reduction size
    red = math.prod(rhs.shape[:-1]) if rhs.shape else 1
    return 2.0 * math.prod(out.shape) * red


_VIEW_PRIMS = {
    # fused/aliased in practice: no HBM round trip of their own
    "broadcast_in_dim", "convert_element_type", "reshape", "squeeze",
    "expand_dims", "bitcast_convert_type", "copy", "stop_gradient",
    "tuple", "get_tuple_element", "pvary",
}


def _aval_bytes(aval) -> float:
    if not hasattr(aval, "shape") or not hasattr(aval, "dtype"):
        return 0.0
    return float(math.prod(aval.shape)) * aval.dtype.itemsize


def _jaxpr_stores(jaxpr) -> float:
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    stores = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "scan":
            stores += eqn.params["length"] * _jaxpr_stores(eqn.params["jaxpr"])
            continue
        if prim == "while":
            stores += _jaxpr_stores(eqn.params["body_jaxpr"])
            continue
        if prim == "cond":
            stores += max(_jaxpr_stores(b) for b in eqn.params["branches"])
            continue
        subs = list(_sub_jaxprs(eqn.params))
        if subs:
            stores += sum(_jaxpr_stores(s) for s in subs)
            continue
        if prim in _VIEW_PRIMS:
            continue
        if prim in ("dynamic_update_slice", "scatter", "scatter-add", "scatter_add"):
            stores += _aval_bytes(eqn.invars[1].aval)
            continue
        stores += sum(_aval_bytes(v.aval) for v in eqn.outvars)
    return stores


def count_jaxpr_bytes(jaxpr) -> float:
    """Analytic HBM traffic of a (Closed)Jaxpr, scan-trip-aware.

    Model: every primitive writes its outputs once (views/casts are fused
    and free; dynamic_update_slice and scatter write only their update
    operand). Total HBM traffic = 2x stores (every tensor written once is
    read once downstream) + arguments read once.
    """
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    return 2.0 * _jaxpr_stores(jaxpr) + sum(
        _aval_bytes(v.aval) for v in jaxpr.invars
    )


def _sub_jaxprs(params: dict):
    """All Jaxpr/ClosedJaxpr values nested in an eqn's params."""
    for v in params.values():
        if isinstance(v, (jcore.Jaxpr, jcore.ClosedJaxpr)):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, (jcore.Jaxpr, jcore.ClosedJaxpr)):
                    yield x


def count_jaxpr_flops(jaxpr) -> float:
    """Global FLOPs of a (Closed)Jaxpr, scan trip counts included."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += _dot_flops(eqn)
        elif prim == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif prim == "scan":
            total += eqn.params["length"] * count_jaxpr_flops(eqn.params["jaxpr"])
        elif prim == "while":
            total += count_jaxpr_flops(eqn.params["body_jaxpr"])  # lower bound
        elif prim == "cond":
            total += max(count_jaxpr_flops(b) for b in eqn.params["branches"])
        else:
            subs = list(_sub_jaxprs(eqn.params))
            if subs:  # pjit / remat2 / custom_vjp / shard_map / ...
                total += sum(count_jaxpr_flops(s) for s in subs)
            else:
                outs = sum(
                    math.prod(v.aval.shape) for v in eqn.outvars
                    if hasattr(v.aval, "shape")
                )
                total += float(outs)  # ~1 flop per output element
    return total


# ---------------------------------------------------------------------------
# partitioned-HLO traffic / collective analysis with loop multiplicity
# ---------------------------------------------------------------------------
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\{\s*$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)"
)
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r"known_trip_count[^}]*?\"n\"\s*:\s*\"(\d+)\"")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    entry = None
    for line in text.splitlines():
        stripped = line.strip()
        m = _COMP_RE.match(line if line.startswith(("ENTRY", "%")) else stripped)
        if m and (line.startswith(("ENTRY", "%")) or stripped.endswith("{")):
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    comps["__entry__"] = [entry or ""]
    return comps


def analyze_hlo(text: str) -> dict:
    comps = _split_computations(text)
    entry = comps.pop("__entry__")[0]

    # per-computation while edges: (cond, body, trip_count)
    edges: dict[str, list[tuple[str, int]]] = {}
    for name, lines in comps.items():
        edges[name] = []
        for ln in lines:
            m = _WHILE_RE.search(ln)
            if m:
                cond, body = m.group(1), m.group(2)
                tm = _TRIP_RE.search(ln)  # XLA's known_trip_count annotation
                if tm:
                    trip = int(tm.group(1))
                else:
                    consts = _CONST_RE.findall(" ".join(comps.get(cond, [])))
                    trip = max((int(c) for c in consts), default=1)
                edges[name].append((body, trip))
                edges[name].append((cond, trip + 1))

    # multiplicity via DFS from entry through while edges only (fusion
    # bodies are accounted at their call sites, not walked)
    mult: dict[str, float] = {}

    def visit(name: str, m: float):
        mult[name] = mult.get(name, 0.0) + m
        for child, trip in edges.get(name, []):
            visit(child, m * trip)

    if entry:
        visit(entry, 1.0)

    op_re = re.compile(
        r"^(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
        r"([a-z][\w\-]*)\("
    )
    no_traffic = {
        "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
        "after-all", "iota",
    }
    colls: dict[str, dict] = {}
    traffic = 0.0
    param_bytes = 0.0
    for name, m in mult.items():
        for ln in comps.get(name, []):
            om = op_re.match(ln)
            if not om:
                continue
            out_b = _type_bytes(om.group(1))
            opname = om.group(2)
            for coll in _COLL_OPS:
                if opname.startswith(coll):
                    d = colls.setdefault(coll, {"count": 0, "bytes": 0.0})
                    d["count"] += int(m)
                    d["bytes"] += out_b * m
            if opname == "parameter":
                if name == entry:
                    param_bytes += out_b
                continue
            if opname in no_traffic:
                continue
            traffic += 2.0 * out_b * m  # write + read
    coll_bytes = sum(v["bytes"] for v in colls.values())
    return {
        "collectives": {k: {"count": v["count"], "bytes": float(v["bytes"])}
                        for k, v in colls.items()},
        "collective_bytes": float(coll_bytes),
        "traffic_bytes": float(traffic + param_bytes),
        "n_computations": len(comps),
    }
