"""Sharding rules: parameter / optimizer-state / activation / cache
PartitionSpecs for the production meshes.

Strategy (see DESIGN.md): FSDP over "data" (every large weight's first core
dim), TP over "model" (heads / ff / vocab / experts), DP over
("pod","data") for the batch. Optimizer moments mirror the param specs, so
state is fully ZeRO-sharded. Dims that don't divide the mesh axis are left
unsharded (e.g. rwkv6's 40 heads vs the 16-way model axis falls back to
sharding head_dim).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..compat import tree_flatten_with_path
from .mesh import dp_axes


def _div(n: int, size: int) -> bool:
    return n % size == 0


def _axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def leaf_spec(path_names: list[str], shape: tuple, mesh) -> P:
    """Sharding rule for one parameter leaf."""
    sz = _axis_sizes(mesh)
    dm, dd = sz["model"], sz["data"]
    stacked = "groups" in path_names
    core = shape[1:] if stacked else shape
    name = path_names[-1]

    def build(core_spec: tuple) -> P:
        return P(*((None,) + core_spec if stacked else core_spec))

    if len(core) <= 1:
        # norms / biases / small vectors: shard if cleanly divisible by model
        if len(core) == 1 and core[0] >= 1024 and _div(core[0], dm):
            return build(("model",))
        return build((None,) * len(core))

    if name == "embed":  # (Vp, D): vocab over model only — keeping D
        # unsharded lets GSPMD lower the token gather as a local masked
        # gather + all-reduce instead of a full rematerialization.
        return build(("model" if _div(core[0], dm) else None, None))
    if name in ("w1", "w3") and len(core) == 3:  # MoE (E, D, Fe): EP on model
        return build(
            ("model" if _div(core[0], dm) else None,
             "data" if _div(core[1], dd) else None, None)
        )
    if name == "w2" and len(core) == 3:  # MoE (E, Fe, D)
        return build(
            ("model" if _div(core[0], dm) else None, None,
             "data" if _div(core[2], dd) else None)
        )
    # output projections (X, D): model x data (reduce dim sharded over model)
    if name in ("wo", "w2", "w_out", "cm_v", "lm_head") and len(core) == 2:
        if name == "lm_head":  # (D, Vp): data x model
            return build(
                ("data" if _div(core[0], dd) else None,
                 "model" if _div(core[1], dm) else None)
            )
        return build(
            ("model" if _div(core[0], dm) else None,
             "data" if _div(core[1], dd) else None)
        )
    if len(core) == 2:  # generic input projection (D, X): data x model
        return build(
            ("data" if _div(core[0], dd) else None,
             "model" if _div(core[1], dm) else None)
        )
    return build((None,) * len(core))


def param_specs(abstract_params, mesh):
    """PartitionSpec pytree matching the (abstract) param tree."""
    flat, treedef = tree_flatten_with_path(abstract_params)
    specs = []
    for path, leaf in flat:
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        specs.append(leaf_spec(names, leaf.shape, mesh))
    return jax.tree.unflatten(treedef, specs)


def opt_specs(abstract_opt_state, pspecs):
    """Optimizer state mirrors params per moment tree ({'m','v',['err']})."""
    return {k: pspecs for k in abstract_opt_state}


def batch_specs(abstract_batch, mesh, multi_pod: bool):
    """Batch-dim data parallel where divisible; replicate otherwise."""
    dp = dp_axes(multi_pod)
    dp_size = 1
    sz = _axis_sizes(mesh)
    for a in dp:
        dp_size *= sz[a]

    def one(leaf):
        b = leaf.shape[0]
        lead = dp if _div(b, dp_size) else None
        return P(*((lead,) + (None,) * (leaf.ndim - 1)))

    return jax.tree.map(one, abstract_batch)


def cache_specs(abstract_cache, mesh, multi_pod: bool):
    """KV-cache / recurrent-state shardings: batch over dp; the kv-head dim
    (or head_dim / state width) over "model" when divisible."""
    dp = dp_axes(multi_pod)
    sz = _axis_sizes(mesh)
    dm = sz["model"]
    dp_size = 1
    for a in dp:
        dp_size *= sz[a]

    def one(path, leaf):
        if leaf.ndim == 0:  # pos scalar
            return P()
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        stacked = "groups" in names
        core = list(leaf.shape[1:] if stacked else leaf.shape)
        spec: list = [None] * len(core)
        b = core[0]
        if _div(b, dp_size):
            spec[0] = dp
        # context-parallel decode: prefer sharding the SEQUENCE dim (dim 1 of
        # (B, S, ...) KV / latent caches) over the model axis — attention
        # scores then stay local per shard and only tiny softmax-stat /
        # context partial-sums cross the ICI, instead of GSPMD all-gathering
        # the whole cache per layer (§Perf iteration 4).
        if len(core) >= 3 and _div(core[1], dm) and core[1] >= dm:
            spec[1] = "model"
        else:
            # fall back: widest trailing dim that divides the model axis
            for d in range(len(core) - 1, 0, -1):
                if _div(core[d], dm) and core[d] >= dm:
                    spec[d] = "model"
                    break
        if stacked:
            spec = [None] + spec
        return P(*spec)

    flat, treedef = tree_flatten_with_path(abstract_cache)
    return jax.tree.unflatten(treedef, [one(p, l) for p, l in flat])


def drop_axis_specs(spec_tree, axis: str = "data"):
    """Remove one mesh axis from every PartitionSpec in a tree (e.g. turn
    FSDP+TP param specs into TP-only for serving / ZeRO-1 gathers)."""

    def drop_entry(e):
        if e == axis:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a != axis)
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return e

    def one(spec):
        return P(*(drop_entry(e) for e in spec))

    return jax.tree.map(one, spec_tree, is_leaf=lambda x: isinstance(x, P))


def constrain_tree(tree, spec_tree, mesh):
    """with_sharding_constraint over a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
        tree,
        spec_tree,
    )


def to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
