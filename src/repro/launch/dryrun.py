import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production meshes, prove memory fit, and extract the
roofline terms (FLOPs / bytes from cost_analysis, collective bytes parsed
from the compiled HLO).

MUST be invoked as its own process (the XLA_FLAGS line above runs before
any jax import): ``PYTHONPATH=src python -m repro.launch.dryrun --arch all
--shape all --mesh both --out results/dryrun``.
"""

import argparse
import json
import re
import time

import jax
import jax.numpy as jnp

from ..compat import shard_map
from ..configs import ARCH_IDS, SHAPES, cell_is_skipped, get_config
from ..core.distributed import DistBuildConfig, build_local, query_local
from ..core.summarization import SummarizationConfig
from ..models import shardctx
from ..models.steps import TrainConfig, make_decode_step, make_prefill_step, make_train_step
from ..models.transformer import ModelConfig, init_params, make_cache
from ..train.optimizer import AdamW, AdamWConfig
from .hlo_analysis import analyze_hlo, count_jaxpr_bytes, count_jaxpr_flops
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, dp_axes, make_production_mesh
from .specs import (
    batch_specs,
    cache_specs,
    constrain_tree,
    drop_axis_specs,
    param_specs,
    to_shardings,
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device bytes and op counts of every collective in the module."""
    out: dict = {}
    for type_str, op in _COLL_RE.findall(hlo_text):
        b = _shape_bytes(type_str)
        d = out.setdefault(op, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    return out


def model_flops(cfg: ModelConfig, shape, n_params_active: int) -> float:
    """Analytic MODEL_FLOPS for the useful-compute ratio (see DESIGN.md):
    matmul params x 2 per token (x3 for train), plus attention context and
    recurrent-state terms."""
    kinds = cfg.layer_kinds
    hd, h = cfg.hd, cfg.n_heads
    s = shape.seq_len
    per_tok_attn = 0.0
    for k in kinds:
        if k == "attn":
            ctx = s if shape.kind == "decode" else s / 2
            per_tok_attn += 4 * ctx * h * hd
        elif k == "local":
            ctx = min(cfg.window, s)
            per_tok_attn += 4 * ctx * h * hd
        elif k == "rwkv":
            per_tok_attn += 4 * cfg.d_model * hd  # state outer-products
        elif k == "rec":
            r = cfg.d_rnn or cfg.d_model
            per_tok_attn += 6 * r  # elementwise recurrence
    if shape.kind == "decode":
        tokens = shape.global_batch  # one new token per sequence
    else:
        tokens = shape.global_batch * s
    mult = 3.0 if shape.kind == "train" else 1.0
    return mult * tokens * (2 * n_params_active + per_tok_attn)


def abstract_batch(cfg: ModelConfig, shape) -> dict:
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "audio":
        return {
            "features": sds((b, s, cfg.d_frontend), jnp.float32),
            "targets": sds((b, s), jnp.int32),
            "mask": sds((b, s), jnp.bool_),
        }
    if cfg.frontend == "vision":
        return {
            "tokens": sds((b, s - cfg.n_vis_tokens), jnp.int32),
            "patches": sds((b, cfg.n_vis_tokens, cfg.d_frontend), jnp.float32),
        }
    return {"tokens": sds((b, s), jnp.int32)}


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    batch = abstract_batch(cfg, shape)
    if shape.kind == "decode":
        cache = jax.eval_shape(
            lambda: make_cache(cfg, shape.global_batch, shape.seq_len)
        )
        token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        return {"cache": cache, "token": token}
    return {"batch": batch}


def _grad_accum_for(cfg: ModelConfig, shape) -> int:
    """Bound per-microbatch tokens so rematted activations fit HBM."""
    tokens = shape.global_batch * shape.seq_len
    target = 131072  # tokens per microbatch (global)
    g = max(1, tokens // target)
    while shape.global_batch % g:
        g -= 1
    return g


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "baseline") -> dict:
    with shardctx.ctx(make_production_mesh(multi_pod=multi_pod), dp_axes(multi_pod)):
        return _lower_cell(arch, shape_name, multi_pod, variant)


def _pad_heads(cfg: ModelConfig, tp: int) -> ModelConfig:
    """TPU adaptation (§Perf): pad the attention head count up to a multiple
    of the TP axis so head-sharded layouts are even. Ragged head counts
    (llava 56H, minicpm 40H vs 16-way TP) force GSPMD into "involuntary full
    rematerialization" gathers and score-matrix partial-sum all-reduces;
    padding trades a few % extra attention FLOPs for their removal."""
    import dataclasses as dc

    h = cfg.n_heads
    hp = -(-h // tp) * tp
    if hp == h or not any(k in ("attn", "local") for k in cfg.layer_kinds):
        return cfg
    if cfg.mla is not None:
        return dc.replace(cfg, n_heads=hp, n_kv=hp, head_dim=cfg.hd)
    if hp % cfg.n_kv:
        return cfg  # GQA grouping wouldn't stay integral; keep as is
    return dc.replace(cfg, n_heads=hp, head_dim=cfg.hd)


def _lower_cell(arch: str, shape_name: str, multi_pod: bool,
                variant: str = "baseline") -> dict:
    """variant: "baseline" = paper-faithful framework defaults (FSDP+TP
    everywhere); "opt" = beyond-baseline §Perf schedule: ZeRO-1 gather-once
    weights for train, TP-only param sharding for serving steps, and
    TP-even head padding."""
    cfg = get_config(arch)
    if variant == "opt":
        cfg = _pad_heads(cfg, 16)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size

    params_abs = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = param_specs(params_abs, mesh)
    psh = to_shardings(pspecs, mesh)
    opt_variant = variant == "opt"
    if opt_variant and shape.kind == "decode":
        # decode re-reads every weight per token -> TP-only params (no FSDP
        # re-gathers). Prefill keeps FSDP: each weight is used once per
        # prompt, and TP-only regressed fine-grained MoE prefill (§Perf).
        pspecs = drop_axis_specs(pspecs, "data")
        psh = to_shardings(pspecs, mesh)

    t0 = time.time()
    if shape.kind == "train":
        opt = AdamW(AdamWConfig())
        ostate_abs = jax.eval_shape(opt.init, params_abs)
        osh = to_shardings({k: pspecs for k in ostate_abs}, mesh)
        tcfg = TrainConfig(grad_accum=_grad_accum_for(cfg, shape), remat=True)
        param_gather = grad_constrain = None
        if opt_variant:
            # ZeRO-1 gather-once, but ONLY for dense (<=3-D incl. the layer
            # stack dim) weights: gathering stacked MoE expert tensors blew
            # the dispatch all-to-all up 70x (refuted iteration, §Perf) —
            # experts stay FSDP-sharded.
            gathered_all = drop_axis_specs(pspecs, "data")
            gathered = jax.tree.map(
                lambda leaf, g_spec, spec: g_spec if leaf.ndim <= 3 else spec,
                params_abs, gathered_all, pspecs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
            )
            param_gather = lambda p: constrain_tree(p, gathered, mesh)
            grad_constrain = lambda g: constrain_tree(g, pspecs, mesh)
        step = make_train_step(cfg, tcfg, opt, param_gather, grad_constrain)
        batch_abs = abstract_batch(cfg, shape)
        bsh = to_shardings(batch_specs(batch_abs, mesh, multi_pod), mesh)
        lowered = jax.jit(
            step,
            in_shardings=(psh, osh, bsh, None),
            out_shardings=(psh, osh, None),
            donate_argnums=(0, 1),
        ).lower(params_abs, ostate_abs, batch_abs, jax.ShapeDtypeStruct((), jnp.int32))
        jaxpr_of = jax.make_jaxpr(step)(
            params_abs, ostate_abs, batch_abs, jax.ShapeDtypeStruct((), jnp.int32)
        )
        extra = {"grad_accum": tcfg.grad_accum}
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        batch_abs = abstract_batch(cfg, shape)
        bsh = to_shardings(batch_specs(batch_abs, mesh, multi_pod), mesh)
        lowered = jax.jit(step, in_shardings=(psh, bsh)).lower(params_abs, batch_abs)
        jaxpr_of = jax.make_jaxpr(step)(params_abs, batch_abs)
        extra = {}
    else:  # decode
        step = make_decode_step(cfg)
        spec = input_specs(arch, shape_name)
        cache_abs, token_abs = spec["cache"], spec["token"]
        csh = to_shardings(cache_specs(cache_abs, mesh, multi_pod), mesh)
        tsh = to_shardings(
            batch_specs({"t": token_abs}, mesh, multi_pod)["t"], mesh
        )
        lowered = jax.jit(
            step, in_shardings=(psh, csh, tsh), out_shardings=(None, csh),
            donate_argnums=(1,),
        ).lower(params_abs, cache_abs, token_abs)
        jaxpr_of = jax.make_jaxpr(step)(params_abs, cache_abs, token_abs)
        extra = {}
    extra["variant"] = variant
    t_lower = time.time() - t0

    # global FLOPs + HBM traffic from the jaxpr (scan-trip-aware)
    jaxpr_flops = count_jaxpr_flops(jaxpr_of)
    jaxpr_bytes = count_jaxpr_bytes(jaxpr_of)

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = analyze_hlo(compiled.as_text())
    colls = hlo["collectives"]
    coll_bytes = hlo["collective_bytes"]

    n_act = cfg.n_params_active()
    n_tot = cfg.n_params()
    mf = model_flops(cfg, shape, n_act)
    flops_dev = jaxpr_flops / n_dev
    bytes_dev = jaxpr_bytes / n_dev

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "n_params": n_tot,
        "n_params_active": n_act,
        "mem_per_device": {
            "args_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "total_gb": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 1e9, 3),
        },
        "cost_per_device": {"flops": flops_dev, "bytes": bytes_dev},
        "flops_global_jaxpr": jaxpr_flops,
        "collectives": colls,
        "collective_bytes_per_device": coll_bytes,
        "roofline_s": {
            "compute": flops_dev / PEAK_FLOPS_BF16,
            "memory": bytes_dev / HBM_BW,
            "collective": coll_bytes / ICI_BW,
        },
        "model_flops_total": mf,
        "useful_flops_ratio": round(mf / max(jaxpr_flops, 1.0), 4),
        **extra,
    }
    terms = result["roofline_s"]
    result["bottleneck"] = max(terms, key=terms.get)
    return result


# ---------------------------------------------------------------------------
# Coconut cells: the paper's own pipeline on the production mesh
# ---------------------------------------------------------------------------
COCONUT_CELLS = {
    "coconut-build": {"n_series": 1 << 26, "series_len": 256},
    # §Perf iteration: exchange summaries+ids only (non-materialized), raw
    # series stay put — queries fetch verified candidates by id instead.
    "coconut-build-nonmat": {"n_series": 1 << 26, "series_len": 256,
                             "materialized": False},
    "coconut-query": {"n_series": 1 << 26, "series_len": 256, "m": 16, "k": 10,
                      "verify_budget": 256},
}


def lower_coconut(cell: str, multi_pod: bool) -> dict:
    import functools

    from jax.sharding import PartitionSpec as P

    spec = COCONUT_CELLS[cell]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh.axis_names  # shard the index over ALL axes (one flat range)
    n_dev = mesh.devices.size
    scfg = SummarizationConfig(series_len=spec["series_len"], n_segments=16, card_bits=8)
    dcfg = DistBuildConfig(summarization=scfg, capacity_slack=2.0,
                           materialized=spec.get("materialized", True))
    n, sl = spec["n_series"], spec["series_len"]
    sds = jax.ShapeDtypeStruct
    sh = lambda s: jax.NamedSharding(mesh, s)

    t0 = time.time()
    if cell.startswith("coconut-build"):
        out_specs = {
            "invalid": P(axes), "keys": P(axes), "ids": P(axes),
            "sym": P(axes), "n_valid": P(axes), "overflow": P(),
        }
        if dcfg.materialized:
            out_specs["series"] = P(axes)

        def build(series, ids):
            f = shard_map(
                functools.partial(build_local, cfg=dcfg, axis_names=tuple(axes)),
                mesh=mesh, in_specs=(P(axes), P(axes)),
                out_specs=out_specs,
            )
            return f(series, ids)

        lowered = jax.jit(build, in_shardings=(sh(P(axes)), sh(P(axes)))).lower(
            sds((n, sl), jnp.float32), sds((n,), jnp.int32)
        )
        jaxpr_of = jax.make_jaxpr(build)(sds((n, sl), jnp.float32), sds((n,), jnp.int32))
    else:
        ln = n // n_dev
        cap = int(ln / n_dev * dcfg.capacity_slack)
        rn = n_dev * cap * n_dev  # global rows of the exchanged index

        def query(index, queries):
            f = shard_map(
                functools.partial(
                    query_local, cfg=dcfg, axis_names=tuple(axes),
                    k=spec["k"], verify_budget=spec["verify_budget"],
                ),
                mesh=mesh,
                in_specs=({"invalid": P(axes), "keys": P(axes), "ids": P(axes),
                           "sym": P(axes), "n_valid": P(axes), "overflow": P(),
                           "series": P(axes)}, P()),
                out_specs=(P(), P()), check_vma=False,
            )
            return f(index, queries)

        index_abs = {
            "invalid": sds((rn,), jnp.int32), "keys": sds((rn, 4), jnp.uint32),
            "ids": sds((rn,), jnp.int32), "sym": sds((rn, 16), jnp.int32),
            "n_valid": sds((n_dev,), jnp.int32), "overflow": sds((), jnp.int32),
            "series": sds((rn, sl), jnp.float32),
        }
        ish = jax.tree.map(
            lambda l: sh(P(axes)) if l.ndim else sh(P()), index_abs)
        ish["overflow"] = sh(P())
        lowered = jax.jit(query, in_shardings=(ish, sh(P()))).lower(
            index_abs, sds((spec["m"], sl), jnp.float32)
        )
        jaxpr_of = jax.make_jaxpr(query)(index_abs, sds((spec["m"], sl), jnp.float32))
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    hlo = analyze_hlo(compiled.as_text())
    colls = hlo["collectives"]
    coll_bytes = hlo["collective_bytes"]
    flops_dev = count_jaxpr_flops(jaxpr_of) / n_dev
    bytes_dev = count_jaxpr_bytes(jaxpr_of) / n_dev
    result = {
        "arch": cell, "shape": f"{n>>20}M x {sl}",
        "mesh": "2x16x16" if multi_pod else "16x16", "n_devices": n_dev,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "mem_per_device": {
            "args_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "total_gb": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 1e9, 3),
        },
        "cost_per_device": {"flops": flops_dev, "bytes": bytes_dev},
        "collectives": colls,
        "collective_bytes_per_device": coll_bytes,
        "roofline_s": {
            "compute": flops_dev / PEAK_FLOPS_BF16,
            "memory": bytes_dev / HBM_BW,
            "collective": coll_bytes / ICI_BW,
        },
    }
    result["bottleneck"] = max(result["roofline_s"], key=result["roofline_s"].get)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--coconut", action="store_true", help="also run coconut cells")
    ap.add_argument("--variant", default="baseline", choices=["baseline", "opt"])
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    cells = []
    for a in archs:
        for s in shapes:
            reason = cell_is_skipped(a, s)
            if reason:
                print(f"SKIP {a} x {s}: {reason}")
                continue
            cells.append((a, s))
    if args.list:
        for a, s in cells:
            print(f"{a} x {s}")
        return

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for multi_pod in meshes:
        mesh_tag = "multi" if multi_pod else "single"
        if args.variant != "baseline":
            mesh_tag += f"_{args.variant}"
        for a, s in cells:
            tag = f"{a}__{s}__{mesh_tag}"
            try:
                res = lower_cell(a, s, multi_pod, args.variant)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=1)
                r = res["roofline_s"]
                print(
                    f"OK {tag}: compile={res['compile_s']}s "
                    f"mem={res['mem_per_device']['total_gb']}GB/dev "
                    f"compute={r['compute']:.4f}s memory={r['memory']:.4f}s "
                    f"coll={r['collective']:.4f}s -> {res['bottleneck']}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 — report and continue
                failures += 1
                print(f"FAIL {tag}: {type(e).__name__}: {str(e)[:300]}", flush=True)
        if args.coconut:
            for cell in COCONUT_CELLS:
                tag = f"{cell}__{mesh_tag}"
                try:
                    res = lower_coconut(cell, multi_pod)
                    with open(os.path.join(args.out, tag + ".json"), "w") as f:
                        json.dump(res, f, indent=1)
                    print(f"OK {tag}: compile={res['compile_s']}s "
                          f"mem={res['mem_per_device']['total_gb']}GB/dev", flush=True)
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    print(f"FAIL {tag}: {type(e).__name__}: {str(e)[:300]}", flush=True)
    print(f"dry-run complete; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
