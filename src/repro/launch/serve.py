"""Serving driver — the end-to-end application, matching the paper's kind
(an exploration/query system): serve batched nearest-neighbor requests over
a live Coconut index while the stream keeps ingesting.

    PYTHONPATH=src python -m repro.launch.serve --scheme BTP --batches 40 \
        --batch-size 500 --query-batch 32

Also supports --mode lm for a toy LM decode-serving loop (smoke config) to
exercise the transformer serving path on this host.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from ..core import (
    StreamConfig, StreamingIndex, SummarizationConfig, recall_at_k,
    render_heatmap,
)
from ..data.synthetic import seismic


def serve_coconut(args):
    """Serve batched kNN traffic over a live stream.

    ``--tier exact`` answers through the batched exact engine
    (``window_knn_batch``); ``--tier approx`` through the batched
    approximate tier (``window_knn_approx_batch``): one vectorized key seek
    plus coalesced sequential block reads per (run, batch). ``--n-blocks``
    is the approximate tier's recall knob — more adjacent blocks read
    sequentially per query raise recall@k toward exact at sequential-I/O
    prices. Approximate recall@k vs the exact oracle is measured on every
    served batch.

    ``--shard mesh`` executes the exact tier on the local device mesh: the
    query batch sharded over one mesh axis and the live runs over the
    other (queries x runs 2-D ``shard_map``), per-shard top-k states
    folded with one all_gather — answers are identical to the
    single-device engine (host f64 re-rank).

    ``--ingest async`` moves flush/merge work onto the background ingest
    pipeline: ingest submissions return immediately, queries serve from
    pinned epoch snapshots while compactions publish concurrently, and the
    per-batch log line reports the freshness lag (entries not yet in a
    published run, runs awaiting merge, snapshot age).

    Verification runs on the device engine by default: at startup the
    compile cache is pre-warmed with one dummy pass per (arena capacity,
    candidate bucket) the configured stream can produce, so steady-state
    serving executes from cached traces with zero retraces; every per-batch
    log line reports the engine's cumulative ``traces``/``hits`` so compile
    churn is visible immediately. ``--no-prewarm`` skips the warm-up (the
    first batches then pay the compiles)."""
    from ..core.verify_engine import get_engine

    tier = "approx" if args.approx else args.tier
    shard = args.shard if args.shard != "none" else None
    scfg = SummarizationConfig(series_len=args.series_len, n_segments=16,
                               card_bits=8)
    idx = StreamingIndex(StreamConfig(
        scheme=args.scheme, summarization=scfg, buffer_entries=4096,
        growth_factor=4, block_size=512, ingest=args.ingest,
        # getattr: programmatic callers (tests) build partial Namespaces
        storage=getattr(args, "storage", "auto"),
        storage_dir=getattr(args, "storage_dir", None),
        screen_dtype=getattr(args, "screen_dtype", None)))
    if idx.storage is not None:
        print(f"[serve] file storage backend at {idx.storage.root} "
              "(WAL + manifest, crash-consistent)", flush=True)
    idx.raw.disk.keep_log = True
    engine = get_engine()
    if args.prewarm:
        # the non-materialized stream verifies against the RawStore arena,
        # whose capacity walks the bucket ladder as ingest grows it — warm
        # every table size the stream will reach (prewarm dedupes them onto
        # the ladder's actual capacity rungs)
        sizes = sorted({args.batch_size * (b + 1) for b in range(args.batches)})
        t0 = time.time()
        n = engine.prewarm(args.series_len, args.query_batch, args.k, sizes,
                           dtype=getattr(args, "screen_dtype", None))
        print(f"[serve] prewarmed {n} verification traces "
              f"({time.time()-t0:.1f}s) for stores up to {sizes[-1]} entries",
              flush=True)
    lat, recalls, lags = [], [], []
    for b in range(args.batches):
        x = seismic(args.batch_size, args.series_len, seed=b)
        idx.ingest(x, np.full(args.batch_size, b, np.int64))
        if (b + 1) % 5 == 0:  # serve a query batch every 5 ingest batches
            qs = seismic(args.query_batch, args.series_len, seed=10_000 + b)
            t0b, t1b = max(0, b - args.window), b
            t0 = time.time()
            if tier == "approx":
                _, got_ids, _ = idx.window_knn_approx_batch(
                    qs, t0b, t1b, k=args.k, n_blocks=args.n_blocks)
            else:
                _, got_ids, _ = idx.window_knn_batch(qs, t0b, t1b, k=args.k,
                                                     shard=shard)
            dt = (time.time() - t0) / args.query_batch
            lat.append(dt)
            es = engine.stats
            lag = idx.ingest_lag()
            lags.append(lag["lag_entries"])
            bhist = ",".join(f"{mb}:{c}" for mb, c in
                             sorted(es["batch_hist"].items()))
            line = (f"[serve] batch {b+1}: {args.query_batch} queries "
                    f"({tier}{'+mesh' if shard == 'mesh' else ''}), "
                    f"{dt*1e3:.2f} ms/query, "
                    f"partitions={idx.n_partitions}, "
                    f"traces={es['traces']}, hits={es['hits']}, "
                    f"batch_hist={bhist or '-'}, "
                    f"epoch={lag['epoch']}, lag={lag['lag_entries']}, "
                    f"pending_merge={lag['runs_pending_merge']}, "
                    f"snap_age={lag['snapshot_age_s']:.2f}s")
            if tier == "approx":
                # score recall without letting the oracle's reads pollute
                # the approx tier's modeled-I/O figures and access heat
                # map: accounting is suspended for THIS thread only, so a
                # background ingest worker's concurrent flush/merge I/O
                # keeps landing in the shared stats untouched (the old
                # save/restore of d.stats mutated state the worker was
                # accounting into — the reason async+approx used to be
                # rejected)
                with idx.raw.disk.unaccounted():
                    _, exact_ids, _ = idx.window_knn_batch(qs, t0b, t1b,
                                                           k=args.k)
                recalls.append(recall_at_k(got_ids, exact_ids))
                line += f", recall@{args.k}={recalls[-1]:.3f}"
            print(line, flush=True)
    if args.ingest == "async":
        t0 = time.time()
        idx.drain(timeout=300)
        idx.close()
        print(f"[serve] drained ingest backlog in {time.time()-t0:.2f}s "
              f"(max observed lag {max(lags or [0])} entries)")
    lat = np.array(lat) * 1e3
    print(f"[serve] latency ms p50={np.percentile(lat,50):.2f} "
          f"p95={np.percentile(lat,95):.2f} max={lat.max():.2f}")
    if recalls:
        print(f"[serve] approx tier n_blocks={args.n_blocks}: "
              f"mean recall@{args.k}={np.mean(recalls):.3f} "
              f"min={np.min(recalls):.3f}")
    print(f"[serve] ingested {args.batches*args.batch_size} series, "
          f"{idx.n_partitions} partitions, "
          f"index={idx.index_bytes()>>20} MiB, modeled io={idx.raw.disk.modeled_seconds():.2f}s")
    m = idx.measured_io()
    if m:
        print(f"[serve] measured io: wrote "
              f"{(m['raw_write_bytes']+m['run_write_bytes']+m['wal_write_bytes'])/1e6:.1f} MB "
              f"(raw {m['raw_write_bytes']/1e6:.1f}, runs {m['run_write_bytes']/1e6:.1f}, "
              f"wal {m['wal_write_bytes']/1e6:.1f}), read {m['raw_read_bytes']/1e6:.1f} MB, "
              f"{m['manifest_commits']} manifest commits, "
              f"{m['prefetch_spans']} readahead spans")
    print("[serve] access heat map:", render_heatmap(idx.raw.disk.heatmap()))


def serve_gateway(args):
    """Serve an *arrival stream* of independent single-query clients through
    the dynamic-batching gateway (``core.gateway``) while background ingest
    keeps publishing epochs.

    A Poisson generator submits ``--requests`` single queries at
    ``--arrival-rate`` QPS with a deterministic tenant mix (plain exact /
    recall-targeted / conflicting recall+latency targets; half of each with
    a recent-window constraint). The gateway coalesces them into
    ladder-rung batches under ``--deadline-ms``, splits mixed batches into
    per-tier sub-batches against one pinned epoch each, and sheds
    sheddable exact traffic to the approximate tier when the rolling p99
    passes ``--slo-p99-ms``. The summary reports client-observed latency
    percentiles, shed rate, the formed-batch histogram, and the engine's
    post-warm-up retrace count (zero when prewarmed)."""
    import threading

    from ..core import Gateway, GatewayConfig
    from ..core.verify_engine import get_engine

    scfg = SummarizationConfig(series_len=args.series_len, n_segments=16,
                               card_bits=8)
    idx = StreamingIndex(StreamConfig(
        scheme=args.scheme, summarization=scfg, buffer_entries=4096,
        growth_factor=4, block_size=512, ingest="async",
        storage=getattr(args, "storage", "auto"),
        storage_dir=getattr(args, "storage_dir", None),
        screen_dtype=getattr(args, "screen_dtype", None)))
    pre = max(1, (2 * args.batches) // 3)
    for b in range(pre):
        x = seismic(args.batch_size, args.series_len, seed=b)
        idx.ingest(x, np.full(args.batch_size, b, np.int64))
    idx.drain(timeout=300)
    gw = Gateway(idx, GatewayConfig(
        deadline_ms=args.deadline_ms, slo_p99_ms=args.slo_p99_ms,
        max_batch=max(8, args.query_batch), k=args.k,
        autotune=getattr(args, "autotune", False)))
    engine = get_engine()
    if args.prewarm:
        sizes = sorted({args.batch_size * (b + 1) for b in range(args.batches)})
        t0 = time.time()
        n = gw.prewarm(sizes, dtype=getattr(args, "screen_dtype", None))
        print(f"[gateway] prewarmed {n} traces ({time.time()-t0:.1f}s) "
              f"for stores up to {sizes[-1]} entries", flush=True)

    stop = threading.Event()

    def background_ingest():
        for b in range(pre, args.batches):
            if stop.is_set():
                return
            x = seismic(args.batch_size, args.series_len, seed=b)
            idx.ingest(x, np.full(args.batch_size, b, np.int64))
            time.sleep(0.01)

    ingester = threading.Thread(target=background_ingest, daemon=True)
    ingester.start()
    rng = np.random.default_rng(12345)
    Q = seismic(args.requests, args.series_len, seed=77_000)
    warmup = min(args.requests // 4, 2 * max(8, args.query_batch))
    tickets, kinds = [], []
    traces_after_warmup = None
    for i in range(args.requests):
        r = rng.random()
        kw = {}
        if r < 0.2:
            kw["target_recall"] = 0.9
        elif r < 0.3:
            kw.update(target_recall=0.9, latency_budget_ms=0.05)
        if rng.random() < 0.5:
            kw["window"] = (max(0, pre - args.window), pre - 1)
        tickets.append(gw.submit(Q[i], **kw))
        kinds.append("exact" if not kw.get("target_recall") else "approx")
        if i + 1 == warmup:
            for t in tickets:  # drain the warm-up phase before measuring
                t.result(timeout=120)
            gw.reset_slo_window()  # compile latencies must not trip the gate
            traces_after_warmup = engine.stats["traces"]
        if gw.tuner is not None and (i + 1) % 64 == 0:
            st = gw.snapshot()
            print(f"[autotune] req {i+1}: decisions={st.tuner_decisions} "
                  f"explores={st.tuner_explores} "
                  f"observations={st.tuner_observations} "
                  f"probes={st.tuner_probes} batches={st.batches} "
                  f"p99={st.p99_ms:.2f} ms", flush=True)
        time.sleep(rng.exponential(1.0 / max(args.arrival_rate, 1e-6)))
    resps = [t.result(timeout=120) for t in tickets]
    stop.set()
    ingester.join(timeout=30)
    idx.drain(timeout=300)
    measured = resps[warmup:]
    lat = np.array([r.latency_ms for r in measured])
    waits = np.array([r.queue_wait_ms for r in measured])
    shed_rate = float(np.mean([r.shed for r in measured]))
    gs = gw.snapshot_stats()
    retraces = engine.stats["traces"] - (traces_after_warmup
                                         if traces_after_warmup is not None
                                         else engine.stats["traces"])
    bhist = ",".join(f"{s}:{c}" for s, c in sorted(gs["batch_hist"].items()))
    print(f"[gateway] {len(measured)} measured requests @ "
          f"{args.arrival_rate:.0f} QPS offered: "
          f"p50={np.percentile(lat, 50):.2f} ms "
          f"p95={np.percentile(lat, 95):.2f} ms "
          f"p99={np.percentile(lat, 99):.2f} ms "
          f"(queue wait p99={np.percentile(waits, 99):.2f} ms)")
    print(f"[gateway] shed_rate={shed_rate:.3f} shedding={gs['shedding']} "
          f"conflicts={gs['conflicts']} batches={gs['batches']} "
          f"deadline_flushes={gs['deadline_flushes']} "
          f"full_flushes={gs['full_flushes']} batch_hist={bhist}")
    print(f"[gateway] post-warm-up retraces={retraces} "
          f"(traces={engine.stats['traces']}, hits={engine.stats['hits']})")
    if gw.tuner is not None:
        snap = gw.tuner.snapshot()
        for label, arms in snap["profiles"].items():
            fitted = " ".join(
                f"{arm}:p99={est['p99_ms']:.2f}ms,rec={est['recall']:.3f}"
                for arm, est in sorted(arms.items())
                if not arm.startswith("_"))
            print(f"[autotune] profile {label} "
                  f"({arms['_decisions']} decisions, "
                  f"epoch {arms['_last_epoch']}): {fitted}", flush=True)
        for entry in gw.tuner.advise_global(idx.ingest_lag(),
                                            n_series=int(idx.raw.n)):
            print(f"[autotune] [{entry.node_id}] {entry.text}", flush=True)
    gw.close()
    idx.close()


def serve_lm(args):
    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models.transformer import decode_step, init_params, prefill

    cfg = get_config(args.arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, P = args.query_batch, 32
    toks = rng.integers(0, cfg.vocab, (B, P)).astype(np.int32)
    logits, cache = prefill(params, cfg, {"tokens": jnp.asarray(toks)},
                            cache_len=P + args.decode_tokens)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.decode_tokens):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"[serve-lm] {args.decode_tokens} tokens x batch {B}: "
          f"{dt/args.decode_tokens*1e3:.1f} ms/step, "
          f"{B*args.decode_tokens/dt:.0f} tok/s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="coconut", choices=["coconut", "lm"])
    ap.add_argument("--scheme", default="BTP", choices=["PP", "TP", "BTP"])
    ap.add_argument("--batches", type=int, default=40)
    ap.add_argument("--batch-size", type=int, default=500)
    ap.add_argument("--series-len", type=int, default=128)
    ap.add_argument("--query-batch", type=int, default=16)
    ap.add_argument("--window", type=int, default=5)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--tier", default="exact", choices=["exact", "approx"],
                    help="serving tier: exact engine or the approximate "
                         "(key-seek + sequential-block-read) tier")
    ap.add_argument("--n-blocks", type=int, default=2,
                    help="approx tier: adjacent blocks read per (query, run) "
                         "— the recall vs I/O knob")
    ap.add_argument("--shard", default="none", choices=["none", "mesh"],
                    help="exact tier execution: single-device or the device "
                         "mesh (queries x runs 2-D shard_map)")
    ap.add_argument("--ingest", default="sync", choices=["sync", "async"],
                    help="sync: flush/merge inline on the serving thread; "
                         "async: background ingest pipeline (queries never "
                         "block on compaction, freshness lag is logged)")
    ap.add_argument("--storage", default="auto",
                    choices=["auto", "model", "file"],
                    help="storage backend: model (DiskModel simulation), "
                         "file (crash-consistent mmap runs + WAL), or auto "
                         "(the REPRO_STORAGE env var, default model)")
    ap.add_argument("--storage-dir", default=None,
                    help="file backend root directory (default: a fresh "
                         "temp dir); reopening the same dir recovers the "
                         "durable index state")
    ap.add_argument("--screen-dtype", default=None,
                    choices=["f32", "bf16", "int8", "auto"],
                    help="device-arena storage dtype for the screen tier: "
                         "bf16 halves / int8 quarters h2d traffic and "
                         "arena footprint; answers stay exact via the "
                         "widened certificate + f64 re-rank (default: the "
                         "REPRO_SCREEN_DTYPE env var, f32)")
    ap.add_argument("--gateway", action="store_true",
                    help="serve a Poisson arrival stream of independent "
                         "single-query clients through the dynamic-batching "
                         "admission gateway (deadline flush + SLO shedding) "
                         "instead of pre-formed query batches")
    ap.add_argument("--arrival-rate", type=float, default=500.0,
                    help="gateway mode: offered load in queries/second "
                         "(Poisson arrivals)")
    ap.add_argument("--deadline-ms", type=float, default=5.0,
                    help="gateway mode: max in-queue wait before a partial "
                         "batch is flushed (padded to the ladder rung)")
    ap.add_argument("--slo-p99-ms", type=float, default=50.0,
                    help="gateway mode: rolling-p99 latency target; past it "
                         "sheddable exact traffic serves on the approx tier "
                         "until p99 recovers (hysteresis)")
    ap.add_argument("--requests", type=int, default=400,
                    help="gateway mode: total client requests to submit")
    ap.add_argument("--autotune", action="store_true",
                    help="gateway mode: per-request tier selection via the "
                         "online autotuner (measured-feedback bandit over "
                         "the tier/n_blocks grid) instead of the static "
                         "recommender rule; adaptation state is logged "
                         "every 64 requests")
    ap.add_argument("--approx", action="store_true",
                    help="deprecated alias for --tier approx")
    ap.add_argument("--no-prewarm", dest="prewarm", action="store_false",
                    help="skip the verification-engine compile-cache "
                         "warm-up (first batches pay the compiles)")
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--decode-tokens", type=int, default=32)
    args = ap.parse_args()
    # reject impossible flag combinations at parse time, not mid-batch
    if args.shard == "mesh" and (args.approx or args.tier == "approx"):
        ap.error("--shard mesh serves the exact tier only (the approx "
                 "tier's seek/coalesce I/O model is host-side)")
    if args.mode != "coconut":
        serve_lm(args)
    elif args.gateway:
        serve_gateway(args)
    else:
        serve_coconut(args)


if __name__ == "__main__":
    main()
