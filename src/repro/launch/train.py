"""Training driver.

On real hardware this runs under the production mesh; on this host it runs
the reduced (smoke) configs with whatever local devices exist. Demonstrates
the full fault-tolerance loop: atomic checkpoints, auto-resume, deterministic
data (restart-exact), optional gradient compression, and a --crash-at flag
that kills the process mid-run to prove recovery.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --steps 200 --global-batch 8 --seq-len 64 --ckpt-dir /tmp/ck --ckpt-every 50
"""
from __future__ import annotations

import argparse
import time


import jax
import jax.numpy as jnp

from ..configs import get_config
from ..data.pipeline import PipelineConfig, TokenPipeline
from ..models.steps import TrainConfig, make_train_step
from ..models.transformer import init_params
from ..train import checkpoint as ckpt
from ..train.optimizer import AdamW, AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--compression", default=None, choices=[None, "int8", "topk"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="simulate a node failure at this step (exit 17)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    pipe = TokenPipeline(
        PipelineConfig(global_batch=args.global_batch, seq_len=args.seq_len,
                       seed=args.seed), cfg)
    opt = AdamW(AdamWConfig(learning_rate=args.lr, warmup_steps=args.warmup,
                            total_steps=args.steps, compression=args.compression))
    tcfg = TrainConfig(grad_accum=args.grad_accum, remat=True,
                       compression=args.compression)
    step_fn = jax.jit(make_train_step(cfg, tcfg, opt), donate_argnums=(0, 1))

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    state = opt.init(params)
    start = 0
    if args.ckpt_dir:
        hit = ckpt.restore_latest(
            args.ckpt_dir,
            {"params": jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
             "opt": jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)},
        )
        if hit:
            start, tree, _ = hit
            params, state = tree["params"], tree["opt"]
            print(f"[train] resumed from step {start}")

    t0 = time.time()
    for s in range(start, args.steps):
        if s == args.crash_at:
            print(f"[train] simulating node failure at step {s}")
            raise SystemExit(17)
        batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
        params, state, metrics = step_fn(params, state, batch, jnp.int32(s))
        if (s + 1) % args.log_every == 0 or s == start:
            loss = float(metrics["loss"])
            gn = float(metrics["grad_norm"])
            tok_s = args.global_batch * args.seq_len * (s + 1 - start) / (time.time() - t0)
            print(f"[train] step {s+1}/{args.steps} loss={loss:.4f} "
                  f"gnorm={gn:.3f} tok/s={tok_s:.0f}", flush=True)
        if args.ckpt_dir and (s + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, s + 1, {"params": params, "opt": state},
                      extra={"arch": args.arch}, async_write=False)
            print(f"[train] checkpoint @ {s+1}")
    print(f"[train] done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
