"""Production mesh definitions.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis joins "data" for batch sharding, so gradient all-reduce crosses
the inter-pod links (DCI), proving the pod axis actually shards.

Defined as functions (not module constants) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n: int | None = None, name: str = "data"):
    """A 1-D mesh over whatever devices exist (tests / CPU smoke)."""
    n = n or jax.device_count()
    return make_mesh((n,), (name,))


def dp_axes(multi_pod: bool) -> tuple:
    return ("pod", "data") if multi_pod else ("data",)


# TPU v5e hardware constants for the roofline terms
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
