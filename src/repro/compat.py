"""Version-compatibility shims over the moving parts of the jax API.

The repro targets the modern jax surface (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.tree.flatten_with_path``,
``lax.axis_size``) but must also run on older runtimes (this container ships
jax 0.4.x) where those names live elsewhere or do not exist. Every call site
in the repo goes through this module instead of feature-detecting locally,
so the support matrix is defined in exactly one place.

Nothing here changes semantics: each shim resolves to the native API when it
exists and otherwise maps onto the equivalent older spelling.
"""
from __future__ import annotations

import functools

import jax
from jax import lax

__all__ = [
    "AXIS_TYPE_AUTO",
    "axis_size",
    "make_mesh",
    "shard_map",
    "tree_flatten_with_path",
    "tree_unflatten",
]

# jax.sharding.AxisType (Auto/Explicit sharding modes) only exists on newer
# jax; older meshes are implicitly "auto" so None is a faithful stand-in.
try:  # pragma: no cover - depends on installed jax
    from jax.sharding import AxisType as _AxisType

    AXIS_TYPE_AUTO = _AxisType.Auto
except ImportError:  # jax < 0.5
    AXIS_TYPE_AUTO = None


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis types where the kwarg exists."""
    if AXIS_TYPE_AUTO is not None:
        return jax.make_mesh(
            axis_shapes,
            axis_names,
            axis_types=(AXIS_TYPE_AUTO,) * len(axis_names),
            devices=devices,
        )
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """``jax.shard_map``; falls back to the experimental version, where the
    replication checker kwarg is spelled ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def axis_size(axis_names) -> int:
    """Static size of one mapped axis name (or product over a tuple)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_names)
    # psum of the literal 1 is folded statically to the axis size
    return lax.psum(1, axis_names)


def tree_flatten_with_path(tree):
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)


def tree_unflatten(treedef, leaves):
    return jax.tree.unflatten(treedef, leaves)


@functools.lru_cache(maxsize=1)
def jax_version() -> tuple:
    return tuple(int(p) for p in jax.__version__.split(".")[:3])
