"""Gradient compression with error feedback — bandwidth relief for the
cross-pod gradient all-reduce at 1000+ node scale.

* int8: per-tensor symmetric quantization. The all-reduce then moves 1/4 of
  the bytes; the quantization error is fed back into the next step's
  gradient (error-feedback a la 1-bit SGD), which keeps convergence.
* topk: keep the largest `frac` fraction of entries per tensor (magnitude),
  accumulate the rest in the error buffer.

Both are pure functions grads -> (decompressed grads, new error state), so
they compose with any optimizer and stay inside the jit'd train step. On a
real pod the quantized representation is what crosses the ICI; here the
compress->decompress round trip models the information loss faithfully.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp


def _int8_roundtrip(g: jnp.ndarray) -> jnp.ndarray:
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_roundtrip(g: jnp.ndarray, frac: float = 0.1) -> jnp.ndarray:
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def make_compressor(kind: Optional[str]) -> Optional[Callable]:
    if kind is None:
        return None

    if kind == "int8":
        rt = _int8_roundtrip
    elif kind == "topk":
        rt = _topk_roundtrip
    else:
        raise ValueError(f"unknown compression {kind}")

    def compress(grads, err):
        def one(g, e):
            gf = g.astype(jnp.float32) + e
            out = rt(gf)
            return out, gf - out

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(err)
        outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (
            jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]),
        )

    return compress
