"""AdamW with warmup-cosine schedule, global-norm clipping, and optional
gradient compression hooks. Implemented from scratch (no optax dependency);
moment states are f32 and inherit the parameter shardings, so with FSDP
param sharding this is ZeRO-sharded optimizer state for free.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .compression import make_compressor


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    compression: Optional[str] = None  # None | "int8" | "topk"


class AdamW:
    def __init__(self, cfg: AdamWConfig):
        self.cfg = cfg
        self.compressor = make_compressor(cfg.compression)

    def init(self, params):
        state = {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }
        if self.compressor is not None:
            state["err"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
        return state

    def schedule(self, step):
        c = self.cfg
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = jnp.minimum(step / jnp.maximum(c.warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (step - c.warmup_steps) / jnp.maximum(c.total_steps - c.warmup_steps, 1),
            0.0,
            1.0,
        )
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return c.learning_rate * warm * (c.min_lr_frac + (1 - c.min_lr_frac) * cos)

    def update(self, params, grads, state, step):
        """Returns (new_params, new_state, grad_norm)."""
        c = self.cfg
        if self.compressor is not None:
            grads, new_err = self.compressor(grads, state["err"])
        # global-norm clip
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, c.grad_clip / (gnorm + 1e-9))
        lr = self.schedule(step)
        t = (step + 1).astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step + 1)
        bc1 = 1.0 - c.beta1 ** t
        bc2 = 1.0 - c.beta2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = c.beta1 * m + (1 - c.beta1) * g
            v = c.beta2 * v + (1 - c.beta2) * g * g
            mh = m / bc1
            vh = v / bc2
            step_ = lr * (mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * p.astype(jnp.float32))
            return (p.astype(jnp.float32) - step_).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_state = {
            "m": jax.tree.unflatten(tdef, [o[1] for o in out]),
            "v": jax.tree.unflatten(tdef, [o[2] for o in out]),
        }
        if self.compressor is not None:
            new_state["err"] = new_err
        return new_params, new_state, gnorm
