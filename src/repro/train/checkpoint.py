"""Sharded, atomic, elastic checkpointing.

Layout: <dir>/step_<N>/ with one .npy per pytree leaf plus manifest.json
(tree structure, shapes, dtypes, step, mesh shape at save time). Writes go
to a temp dir that is atomically renamed, so a crash mid-save never corrupts
the latest checkpoint; `latest_step` only sees complete directories.

Elastic restore: leaves are loaded as full host arrays and re-placed with
``jax.device_put`` under the *current* mesh/sharding — restoring a run onto
a different mesh shape (scale up/down) works out of the box. An async mode
hands the host copy to a writer thread so the training loop does not stall.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import ml_dtypes
import numpy as np

import jax

from ..compat import tree_flatten_with_path, tree_unflatten

# numpy can't save/cast bfloat16 natively; store as uint16 bit patterns
_WIDE = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _to_storable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _WIDE:
        return arr.view(_WIDE[name][1]), name
    return arr, name


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _WIDE:
        return arr.view(_WIDE[dtype_name][0])
    return arr


def _flatten_with_paths(tree):
    flat, treedef = tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "_".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, extra: Optional[dict] = None,
         async_write: bool = False):
    """Save a pytree checkpoint. Blocks unless async_write."""
    leaves, _ = _flatten_with_paths(tree)
    host = []
    for name, leaf in leaves:
        arr, dtype_name = _to_storable(np.asarray(jax.device_get(leaf)))
        host.append((name, arr, dtype_name))
    manifest = {
        "step": int(step),
        "leaves": [
            {"name": n, "shape": list(a.shape), "dtype": d}
            for n, a, d in host
        ],
        "n_devices": jax.device_count(),
        "extra": extra or {},
    }

    def write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        for name, arr, _ in host:
            np.save(os.path.join(tmp, f"{name}.npy"), arr)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                steps.append(int(d[5:]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, *, shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``like``. If ``shardings`` (a pytree of
    NamedSharding matching ``like``) is given, leaves are placed sharded —
    use this to restore onto a *different* mesh than the one that saved.

    Returns (tree, manifest_extra)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten_with_paths(like)
    shard_leaves = (
        jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves)
    )
    dtype_by_name = {l["name"]: l["dtype"] for l in manifest["leaves"]}
    out = []
    for (name, ref_leaf), sh in zip(leaves, shard_leaves):
        arr = _from_storable(np.load(os.path.join(d, f"{name}.npy")),
                             dtype_by_name[name])
        if list(arr.shape) != list(ref_leaf.shape):
            raise ValueError(
                f"checkpoint leaf {name} shape {arr.shape} != expected {ref_leaf.shape}"
            )
        arr = arr.astype(ref_leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    return tree_unflatten(treedef, out), manifest.get("extra", {})


def restore_latest(ckpt_dir: str, like: Any, *, shardings: Any = None):
    """Returns (step, tree, extra) or None when no checkpoint exists."""
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    tree, extra = restore(ckpt_dir, step, like, shardings=shardings)
    return step, tree, extra
