"""Epoch-based run registry — snapshot-consistent run sets for concurrent
ingest and query (the CLSM write path's analogue of MVCC).

The streaming index used to mutate ``CLSM.levels`` in place: a query planned
mid-merge saw whatever the dict happened to contain, and moving flush/merge
work off the query path was impossible without racing the planner. This
module makes the run set an immutable value:

* :class:`RunSet` — one immutable snapshot of the whole ingest state: the
  per-level sorted runs, the in-memory write buffer (as chunks), and the
  chunks currently being flushed (taken from the buffer, run not yet
  published). Every snapshot carries an ``epoch`` number.
* :class:`RunRegistry` — the single mutable cell holding the current
  :class:`RunSet`. Every mutation (buffer append, flush take/publish, merge
  publish) builds a NEW snapshot and swaps it in under the registry lock
  with one epoch bump — the CAS-style double-buffer swap: a merge builds
  its output run entirely off to the side, then one ``publish_merge``
  retires the inputs and installs the output atomically. Readers never
  block: ``current()`` is a reference read, and a plan built from a
  snapshot sees a frozen world however many flushes/merges land while it
  executes.
* **Epoch pinning + deferred retirement** — :meth:`RunRegistry.pin` hands a
  query a snapshot and records its epoch. Runs that a merge replaces are
  not released immediately: they are parked on a retirement list tagged
  with the epoch that superseded them, and their device arenas
  (:mod:`repro.core.verify_engine`) are only released once every pinned
  epoch has advanced past that tag — so an in-flight plan's sources stay
  alive (and stay warm on the device) for exactly as long as any query can
  still verify against them.

Invariant: every ingested entry is, at every epoch, in exactly ONE of the
snapshot's three places (buffer chunk, flushing chunk, or published run) —
``take_for_flush`` moves entries buffer->flushing and ``publish_flush``
moves them flushing->run in single atomic swaps, so a pinned query never
sees an entry twice or not at all.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True, eq=False)  # identity eq: ndarray fields
class BufferChunk:
    """One immutable ingest batch: (B, n) series + aligned ids/timestamps."""

    series: np.ndarray
    ids: np.ndarray
    ts: Optional[np.ndarray] = None

    @property
    def n(self) -> int:
        return int(self.series.shape[0])


@dataclasses.dataclass(frozen=True)
class RunSet:
    """An immutable snapshot of the whole ingest state at one epoch."""

    epoch: int
    # ascending (level, runs) pairs; runs in insertion (oldest-first) order
    levels: Tuple[Tuple[int, Tuple[object, ...]], ...] = ()
    buffer: Tuple[BufferChunk, ...] = ()  # unflushed ingest, oldest first
    flushing: Tuple[BufferChunk, ...] = ()  # taken for flush, run not published

    # ------------------------------------------------------------- helpers
    def level_runs(self, level: int) -> Tuple[object, ...]:
        for lv, runs in self.levels:
            if lv == level:
                return runs
        return ()

    def level_dict(self) -> Dict[int, List[object]]:
        """The historical ``CLSM.levels`` mapping (a fresh mutable copy)."""
        return {lv: list(runs) for lv, runs in self.levels}

    def runs_newest_first(self) -> List[object]:
        out: List[object] = []
        for _, runs in self.levels:  # levels ascend: small/recent first
            out.extend(reversed(runs))
        return out

    def dense_chunks(self) -> Tuple[BufferChunk, ...]:
        """Entries not yet in any run (buffer + in-flight flushes), newest
        first — the plan's brute-force dense tail. Flushing chunks were
        taken from the buffer earlier, so they are older than anything
        still buffered."""
        return tuple(reversed(self.flushing + self.buffer))

    @property
    def buffer_n(self) -> int:
        return sum(c.n for c in self.buffer)

    @property
    def flushing_n(self) -> int:
        return sum(c.n for c in self.flushing)

    @property
    def n_runs(self) -> int:
        return sum(len(runs) for _, runs in self.levels)

    # ------------------------------------------------------- constructors
    def _with(self, **kw) -> "RunSet":
        kw.setdefault("epoch", self.epoch + 1)
        return dataclasses.replace(self, **kw)

    def _levels_with(self, level: int, runs: Sequence[object]) -> Tuple:
        """The levels tuple with one level replaced (dropped if empty)."""
        out = [(lv, rs) for lv, rs in self.levels if lv != level]
        if runs:
            out.append((level, tuple(runs)))
        out.sort(key=lambda p: p[0])
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class _Retired:
    """A run superseded by a merge, awaiting its last pinned reader."""

    run: object
    epoch: int  # the epoch whose snapshot no longer contains the run


class RunRegistry:
    """The mutable cell: current :class:`RunSet` + pins + retirement."""

    def __init__(self):
        self._lock = threading.RLock()
        self._current = RunSet(epoch=0)
        self._pins: Dict[int, int] = {}  # epoch -> pin count
        self._retired: List[_Retired] = []
        self.publish_time = time.time()  # wall time of the last epoch bump
        self.released_runs = 0  # retired runs whose resources were released

    # ------------------------------------------------------------ reading
    def current(self) -> RunSet:
        return self._current  # one reference read: never blocks on writers

    @contextlib.contextmanager
    def pin(self) -> Iterator[RunSet]:
        """Pin the current snapshot for the duration of a query: retired
        runs it references stay unreleased until the pin drops."""
        with self._lock:
            snap = self._current
            self._pins[snap.epoch] = self._pins.get(snap.epoch, 0) + 1
        try:
            yield snap
        finally:
            with self._lock:
                left = self._pins[snap.epoch] - 1
                if left:
                    self._pins[snap.epoch] = left
                else:
                    del self._pins[snap.epoch]
                self._reap_locked()

    @property
    def pinned_epochs(self) -> List[int]:
        with self._lock:
            return sorted(self._pins)

    @property
    def retired_pending(self) -> int:
        with self._lock:
            return len(self._retired)

    # ----------------------------------------------------------- mutation
    def _install_locked(self, snap: RunSet) -> RunSet:
        self._current = snap
        self.publish_time = time.time()
        return snap

    def append_buffer(self, chunk: BufferChunk) -> RunSet:
        """Publish one ingest batch into the write buffer (epoch bump)."""
        with self._lock:
            cur = self._current
            return self._install_locked(cur._with(buffer=cur.buffer + (chunk,)))

    def restore(self, levels: Sequence[Tuple[int, Sequence[object]]],
                buffer: Sequence[BufferChunk]) -> RunSet:
        """Install a recovered state (storage-engine crash recovery) in ONE
        epoch bump: the manifest's runs plus the replayed WAL chunks become
        the current snapshot atomically — a query planned before the bump
        sees the (empty) pre-recovery world, one planned after sees all of
        it, nobody sees a half-restored set. Only valid before any ingest
        (the registry must still be empty)."""
        with self._lock:
            cur = self._current
            if cur.levels or cur.buffer or cur.flushing:
                raise ValueError("restore() into a non-empty registry")
            lv = tuple(sorted(((int(l), tuple(rs)) for l, rs in levels
                               if rs), key=lambda p: p[0]))
            return self._install_locked(
                cur._with(levels=lv, buffer=tuple(buffer), flushing=()))

    def take_for_flush(self, n: int) -> Tuple[Optional[BufferChunk], RunSet]:
        """Atomically move the oldest ``n`` buffered entries into the
        in-flight ``flushing`` set. Returns the taken chunk (None when the
        buffer is empty) — the token ``publish_flush`` later retires."""
        with self._lock:
            cur = self._current
            avail = cur.buffer_n
            n = min(n, avail)
            if n <= 0:
                return None, cur
            series = np.concatenate([c.series for c in cur.buffer])
            ids = np.concatenate([c.ids for c in cur.buffer])
            ts = None
            if all(c.ts is not None for c in cur.buffer):
                ts = np.concatenate([c.ts for c in cur.buffer])
            taken = BufferChunk(series[:n], ids[:n],
                                None if ts is None else ts[:n])
            rest: Tuple[BufferChunk, ...] = ()
            if n < avail:
                rest = (BufferChunk(series[n:], ids[n:],
                                    None if ts is None else ts[n:]),)
            snap = self._install_locked(cur._with(buffer=rest,
                                           flushing=cur.flushing + (taken,)))
            return taken, snap

    def publish_flush(self, chunk: BufferChunk, run: object,
                      level: int = 0) -> RunSet:
        """Swap an in-flight chunk for its freshly built run: one epoch bump
        removes the chunk from ``flushing`` and appends the run to the
        level — a query pinned before the bump sees the chunk, one pinned
        after sees the run, nobody sees both."""
        with self._lock:
            cur = self._current
            if not any(c is chunk for c in cur.flushing):  # pragma: no cover
                raise ValueError("publish_flush: chunk was not taken for flush")
            flushing = tuple(c for c in cur.flushing if c is not chunk)
            levels = cur._levels_with(level, cur.level_runs(level) + (run,))
            return self._install_locked(cur._with(levels=levels, flushing=flushing))

    def publish_merge(self, level: int, victims: Sequence[object],
                      merged: object) -> RunSet:
        """The double-buffered merge commit: the merged run (built entirely
        off to the side) replaces its inputs in ONE epoch bump. The inputs
        are parked for deferred retirement, not released."""
        with self._lock:
            cur = self._current
            runs = list(cur.level_runs(level))
            for v in victims:  # identity removal: runs hold ndarray fields
                for i, r in enumerate(runs):
                    if r is v:
                        del runs[i]
                        break
                else:  # pragma: no cover - merge raced another merge
                    raise ValueError("publish_merge: victim not in level")
            levels = cur._levels_with(level, runs)
            # a second level changes in the same swap: splice the target in
            nxt = ()
            for lv, rs in levels:
                if lv == level + 1:
                    nxt = rs
            levels = tuple((lv, rs) for lv, rs in levels if lv != level + 1)
            levels = tuple(sorted(levels + ((level + 1, nxt + (merged,)),),
                                  key=lambda p: p[0]))
            snap = self._install_locked(cur._with(levels=levels))
            for v in victims:
                self._retired.append(_Retired(run=v, epoch=snap.epoch))
            self._reap_locked()
            return snap

    # --------------------------------------------------------- retirement
    def _reap_locked(self) -> None:
        """Release retired runs no pinned epoch can still reference: a run
        retired at epoch E was last visible at E-1, so it is reclaimable
        once every live pin is >= E (future pins only ever see >= E)."""
        if not self._retired:
            return
        floor = min(self._pins) if self._pins else self._current.epoch
        keep: List[_Retired] = []
        for r in self._retired:
            if r.epoch <= floor:
                release = getattr(r.run, "release_device_view", None)
                if release is not None:
                    release()
                release_storage = getattr(r.run, "release_storage", None)
                if release_storage is not None:
                    release_storage()
                self.released_runs += 1
            else:
                keep.append(r)
        self._retired = keep
