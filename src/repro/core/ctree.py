"""CoconutTree — the compact & contiguous read-optimized sorted index.

A CTree is a single :class:`SortedRun`: entries sorted by the bit-interleaved
sortable key, stored contiguously in fixed-size blocks with per-block zone
maps (min/max SAX symbol per segment) for block-level lower-bound pruning.
It is built bottom-up with a memory-budgeted external sort (sequential I/O
only) — the paper's headline capability.

Variants (paper §2):
  * materialized:     raw series stored inline in sorted order (bigger,
                      slower to build, fastest to query);
  * non-materialized: only summaries + ids; verification fetches raw series
                      from the RawStore (random I/O at query time).
  * fill_factor < 1:  leaves leave gaps so point inserts can be absorbed
                      without rebuilding (read/write trade-off knob).

``SortedRun`` is shared with CoconutLSM (a CLSM level run is the same
structure plus a time range).

Queries come in two shapes: the scalar per-query path (``knn_exact`` /
``knn_approx``, best-first heap loops) and the batched top-k engine
(``knn_batch``), which answers a whole (m, n) query batch with shared
dense verification passes — the host twin of the ``topk_ed`` Pallas kernel
(``backend="kernel"`` launches the kernel itself, one launch per (run,
batch, pass)). Batched results are ((m, k) distances, (m, k) ids) arrays
padded with (inf, -1).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from .external_sort import SortReport, external_sort_order
from .io_model import DiskModel, coalesce_ranges
from .lower_bounds import ed2, mindist_paa_sax2, mindist_region2, topk_ed2
from .sortable import interleave, searchsorted_keys, searchsorted_keys_batch
from .summarization import SummarizationConfig, paa, sax_from_paa


@dataclasses.dataclass
class QueryStats:
    blocks_pruned: int = 0
    blocks_visited: int = 0
    entries_pruned: int = 0
    entries_verified: int = 0

    def merge(self, o: "QueryStats") -> "QueryStats":
        return QueryStats(
            self.blocks_pruned + o.blocks_pruned,
            self.blocks_visited + o.blocks_visited,
            self.entries_pruned + o.entries_pruned,
            self.entries_verified + o.entries_verified,
        )


class RawStore:
    """The raw data-series file. Append-only; random reads are accounted."""

    def __init__(self, series_len: int, disk: Optional[DiskModel] = None):
        self.series_len = series_len
        self.disk = disk or DiskModel()
        self._chunks: list[np.ndarray] = []
        self._data: Optional[np.ndarray] = None
        self._norms2: Optional[np.ndarray] = None
        self.n = 0

    def append(self, series: np.ndarray) -> np.ndarray:
        """Append (B, n) series; returns their ids. Sequential write."""
        series = np.asarray(series, dtype=np.float32)
        ids = np.arange(self.n, self.n + series.shape[0], dtype=np.int64)
        self._chunks.append(series)
        self._data = None
        self.n += series.shape[0]
        self.disk.write_seq(series.nbytes, offset=int(ids[0]) * self.series_len * 4)
        return ids

    def _all(self) -> np.ndarray:
        if self._data is None:
            self._data = (
                np.concatenate(self._chunks, axis=0)
                if self._chunks
                else np.zeros((0, self.series_len), np.float32)
            )
        return self._data

    def fetch(self, ids: np.ndarray) -> np.ndarray:
        """Random fetch by id (the non-materialized query path)."""
        ids = np.asarray(ids)
        row = self.series_len * 4
        if self.disk.keep_log and ids.size:
            for i in ids:  # scattered page touches for the heat map
                self.disk.read_rand(row, offset=int(i) * row)
        else:
            self.disk.read_rand(ids.size * row)
        return self._all()[ids]

    def scan(self) -> np.ndarray:
        """Full sequential scan (used by builds)."""
        data = self._all()
        self.disk.read_seq(data.nbytes)
        return data

    def norms2(self, ids: np.ndarray) -> np.ndarray:
        """Cached squared norms by id (derived data, no modeled I/O): the
        batched verify screens only need |x|^2, not another pass over x.
        The store is append-only, so the cache extends incrementally — a
        growing stream never pays a full-store recompute per query batch."""
        if self._norms2 is None or self._norms2.shape[0] < self.n:
            a = self._all()
            done = 0 if self._norms2 is None else self._norms2.shape[0]
            new = np.einsum("ij,ij->i", a[done:], a[done:])
            self._norms2 = new if done == 0 else np.concatenate([self._norms2, new])
        return self._norms2[ids]


@dataclasses.dataclass
class SortedRun:
    """A contiguous sorted-by-key array of summarized entries + zone maps."""

    cfg: SummarizationConfig
    keys: np.ndarray  # (N, nw) uint32, lexicographically sorted
    sax: np.ndarray  # (N, w) uint8
    ids: np.ndarray  # (N,) int64 position in RawStore
    block_size: int
    bmin: np.ndarray  # (nb, w) uint8 zone maps
    bmax: np.ndarray  # (nb, w) uint8
    series: Optional[np.ndarray] = None  # (N, n) f32 if materialized
    ts: Optional[np.ndarray] = None  # (N,) int64 timestamps
    t_min: int = 0
    t_max: int = 0
    _norms2: Optional[np.ndarray] = None  # lazy |x|^2 cache (materialized runs)

    @property
    def n(self) -> int:
        return self.keys.shape[0]

    @property
    def n_blocks(self) -> int:
        return self.bmin.shape[0]

    @property
    def materialized(self) -> bool:
        return self.series is not None

    def index_bytes(self) -> int:
        b = self.keys.nbytes + self.sax.nbytes + self.ids.nbytes
        b += self.bmin.nbytes + self.bmax.nbytes
        if self.series is not None:
            b += self.series.nbytes
        if self.ts is not None:
            b += self.ts.nbytes
        return b

    # ------------------------------------------------------------------ build
    @staticmethod
    def from_arrays(
        cfg: SummarizationConfig,
        sax_syms: np.ndarray,
        ids: np.ndarray,
        *,
        block_size: int = 1024,
        series: Optional[np.ndarray] = None,
        ts: Optional[np.ndarray] = None,
        disk: Optional[DiskModel] = None,
        mem_budget_entries: Optional[int] = None,
        presorted: bool = False,
    ) -> tuple["SortedRun", SortReport]:
        """Build a run from unsorted summarized entries via external sort."""
        keys = interleave(sax_syms.astype(np.int32), cfg).reshape(-1, cfg.key_words)
        n = keys.shape[0]
        payload = cfg.series_len * 4 if series is not None else 0
        if presorted:
            order = np.arange(n)
            report = SortReport(n, 1, 0, n or 1)
        else:
            order, report = external_sort_order(
                keys, mem_budget_entries or max(1, n), disk, payload_bytes_per_entry=payload
            )
        keys = keys[order]
        sax_sorted = sax_syms[order].astype(np.uint8)
        run = SortedRun(
            cfg=cfg,
            keys=keys,
            sax=sax_sorted,
            ids=np.asarray(ids)[order].astype(np.int64),
            block_size=block_size,
            bmin=np.zeros((0, cfg.n_segments), np.uint8),
            bmax=np.zeros((0, cfg.n_segments), np.uint8),
            series=None if series is None else np.asarray(series, np.float32)[order],
            ts=None if ts is None else np.asarray(ts, np.int64)[order],
        )
        run._rebuild_zone_maps()
        if run.ts is not None and run.n:
            run.t_min = int(run.ts.min())
            run.t_max = int(run.ts.max())
        return run, report

    @staticmethod
    def build(
        series: np.ndarray,
        ids: np.ndarray,
        cfg: SummarizationConfig,
        *,
        block_size: int = 1024,
        materialized: bool = False,
        ts: Optional[np.ndarray] = None,
        disk: Optional[DiskModel] = None,
        mem_budget_entries: Optional[int] = None,
    ) -> tuple["SortedRun", SortReport]:
        p = paa(np.asarray(series, np.float32), cfg)
        syms = sax_from_paa(p, cfg)
        return SortedRun.from_arrays(
            cfg,
            syms,
            ids,
            block_size=block_size,
            series=series if materialized else None,
            ts=ts,
            disk=disk,
            mem_budget_entries=mem_budget_entries,
        )

    def _rebuild_zone_maps(self) -> None:
        n, w = self.n, self.cfg.n_segments
        bs = self.block_size
        nb = max(1, -(-n // bs)) if n else 0
        bmin = np.full((nb, w), 255, np.uint8)
        bmax = np.zeros((nb, w), np.uint8)
        for b in range(nb):
            blk = self.sax[b * bs : (b + 1) * bs]
            bmin[b] = blk.min(axis=0)
            bmax[b] = blk.max(axis=0)
        self.bmin, self.bmax = bmin, bmax

    def entry_norms2(self) -> np.ndarray:
        """Cached (N,) squared norms of the materialized entries (runs are
        immutable after build, so this never invalidates)."""
        assert self.series is not None
        if self._norms2 is None:
            self._norms2 = np.einsum("ij,ij->i", self.series, self.series)
        return self._norms2

    # ------------------------------------------------------------------ query
    def _entry_bytes(self) -> int:
        per = self.cfg.key_words * 4 + self.cfg.n_segments + 8
        if self.materialized:
            per += self.cfg.series_len * 4
        if self.ts is not None:
            per += 8
        return per

    def _fetch_entries(
        self,
        idx: np.ndarray,
        raw: Optional[RawStore],
        disk: Optional[DiskModel],
        sequential: bool,
    ) -> np.ndarray:
        """Raw series for entries at positions ``idx`` (I/O accounted)."""
        if self.materialized:
            data = self.series[idx]
            if disk is not None:
                nbytes = idx.size * self.cfg.series_len * 4
                (disk.read_seq if sequential else disk.read_rand)(nbytes)
        else:
            if raw is None:
                raise ValueError("non-materialized run queried without a RawStore")
            data = raw.fetch(self.ids[idx])
        return data

    def _verify_entries(
        self,
        idx: np.ndarray,
        q: np.ndarray,
        raw: Optional[RawStore],
        disk: Optional[DiskModel],
        sequential: bool,
    ) -> np.ndarray:
        """True squared ED for entries at positions ``idx``."""
        if idx.size == 0:
            return np.zeros((0,), np.float32)
        data = self._fetch_entries(idx, raw, disk, sequential)
        return ed2(q, data).astype(np.float32)

    def knn_exact(
        self,
        q: np.ndarray,
        k: int = 1,
        *,
        raw: Optional[RawStore] = None,
        disk: Optional[DiskModel] = None,
        bsf: Optional[list] = None,
        window: Optional[tuple[int, int]] = None,
        stats: Optional[QueryStats] = None,
    ) -> tuple[list, QueryStats]:
        """Exact kNN within this run, sharing a best-so-far heap across runs.

        ``bsf`` is a max-heap of (-dist2, id) of current best k. Returns the
        updated heap. ``window=(t0, t1)`` filters by timestamp (inclusive).
        """
        stats = stats or QueryStats()
        bsf = bsf if bsf is not None else []
        if self.n == 0:
            return bsf, stats
        if window is not None and self.ts is not None:
            if self.t_max < window[0] or self.t_min > window[1]:
                stats.blocks_pruned += self.n_blocks
                return bsf, stats
        qp = np.asarray(paa(np.asarray(q, np.float32), self.cfg))

        # block-level lower bounds from zone maps (vectorized)
        blb = mindist_region2(qp, self.bmin.astype(np.int64), self.bmax.astype(np.int64), self.cfg)
        order = np.argsort(blb, kind="stable")
        bs = self.block_size
        for oi, b in enumerate(order):
            worst = -bsf[0][0] if len(bsf) >= k else np.inf
            if blb[b] >= worst:
                stats.blocks_pruned += len(order) - oi
                break
            stats.blocks_visited += 1
            lo, hi = b * bs, min(self.n, (b + 1) * bs)
            sl = slice(lo, hi)
            if disk is not None:
                disk.read_rand(
                    (hi - lo) * (self.cfg.key_words * 4 + self.cfg.n_segments),
                    offset=lo * self._entry_bytes(),
                )
            mask = np.ones(hi - lo, bool)
            if window is not None and self.ts is not None:
                mask &= (self.ts[sl] >= window[0]) & (self.ts[sl] <= window[1])
            elb = mindist_paa_sax2(qp, self.sax[sl].astype(np.int64), self.cfg)
            keep = mask & (elb < worst)
            stats.entries_pruned += int((~keep).sum())
            cand = np.nonzero(keep)[0]
            if cand.size == 0:
                continue
            d2 = self._verify_entries(cand + lo, q, raw, disk, sequential=self.materialized)
            stats.entries_verified += cand.size
            for dist, pos in zip(d2, cand + lo):
                item = (-float(dist), int(self.ids[pos]))
                if len(bsf) < k:
                    heapq.heappush(bsf, item)
                elif item[0] > bsf[0][0]:
                    heapq.heapreplace(bsf, item)
        return bsf, stats

    def knn_batch(
        self,
        Q: np.ndarray,
        k: int = 1,
        *,
        raw: Optional[RawStore] = None,
        disk: Optional[DiskModel] = None,
        window: Optional[tuple[int, int]] = None,
        state: Optional[tuple[np.ndarray, np.ndarray]] = None,
        stats: Optional[QueryStats] = None,
        blocks_per_round: int = 32,
        backend: str = "numpy",
        time_skip: bool = True,
    ) -> tuple[tuple[np.ndarray, np.ndarray], QueryStats]:
        """Exact kNN for a whole query batch in one pass over this run.

        The batched replacement for per-query ``knn_exact`` heap loops.
        Block lower bounds are computed for the full (m, n_blocks) cross
        product at once, then verification runs in shared passes over block
        unions instead of per-(query, block) Python work:

        1. a seed pass over each query's best-bounded block tightens every
           radius cheaply;
        2. bounded passes cover the union of blocks any query still needs —
           each pass is ONE dense evaluation of the whole batch against the
           pass's entries (``backend="kernel"``: a single ``topk_ed`` Pallas
           launch per (run, batch, pass); ``backend="numpy"``: the host twin
           — one shared f64 GEMM + per-query top-k).

        Like the dense ED scan kernel, this trades per-entry early
        abandoning (a disk/CPU scalar idiom) for large regular passes whose
        extra (query, entry) pairs only ever tighten other queries' radii;
        every entry of a pass is fetched and evaluated once for the whole
        batch. Blocks no query needs are never touched.

        ``state`` is the batched best-so-far — ((m, k) distances ascending,
        (m, k) global ids, inf/-1 padded) — shared across runs the way the
        ``bsf`` heap is in ``knn_exact``. Returns the updated state.
        ``time_skip=False`` disables the run-level time-range skip while
        keeping per-entry window filtering (the PP scheme's semantics).

        Stats semantics under batching: ``blocks_visited``/``blocks_pruned``
        count per-(query, block) logical work (comparable to summed
        ``knn_exact`` stats); ``entries_verified`` counts physical fetches
        (shared per batch); ``entries_pruned`` counts window filtering.
        """
        if backend not in ("numpy", "kernel"):
            raise ValueError(f"unknown batch verify backend {backend!r}")
        Q = np.asarray(Q, np.float32)
        m = Q.shape[0]
        stats = stats if stats is not None else QueryStats()
        vals, ids = state if state is not None else empty_topk_state(m, k)
        if self.n == 0 or m == 0:
            return (vals, ids), stats
        if time_skip and window is not None and self.ts is not None:
            if self.t_max < window[0] or self.t_min > window[1]:
                stats.blocks_pruned += self.n_blocks * m  # per-query semantics
                return (vals, ids), stats
        qp = np.asarray(paa(Q, self.cfg))  # (m, w)
        blb = mindist_region2(
            qp[:, None, :], self.bmin.astype(np.int64), self.bmax.astype(np.int64), self.cfg
        )  # (m, nb)
        nb, bs = self.n_blocks, self.block_size
        done = np.zeros(nb, bool)  # verified blocks (against the whole batch)

        def verify_blocks(blocks: np.ndarray) -> None:
            """Verify ``blocks`` against every query in one shared pass."""
            nonlocal vals, ids
            done[blocks] = True
            pos = (blocks[:, None] * bs + np.arange(bs)[None, :]).reshape(-1)
            pos = pos[pos < self.n]
            if disk is not None:
                disk.read_rand(
                    pos.size * (self.cfg.key_words * 4 + self.cfg.n_segments)
                )
            if window is not None and self.ts is not None:
                in_win = (self.ts[pos] >= window[0]) & (self.ts[pos] <= window[1])
                stats.entries_pruned += int((~in_win).sum())
                pos = pos[in_win]
            if pos.size == 0:
                return
            data_u = self._fetch_entries(
                pos, raw, disk, sequential=self.materialized
            )  # (U, n)
            stats.entries_verified += int(pos.size)
            if backend == "kernel":
                # ONE all-pairs topk_ed Pallas launch per (run, batch, pass)
                nv, ni = _kernel_topk_dists(Q, data_u, k)
            else:
                # host twin of the kernel: screen with one shared f32 sgemm,
                # then exactly re-rank the provably sufficient tail. The
                # screen's only error source is the f32 cross product, whose
                # classical bound (2 n u |q||x|) widens the kth-best radius;
                # everything inside the widened radius is recomputed in f64,
                # so the result is exact while the sgemm does ~all the work.
                u = data_u.shape[0]
                kk = min(k, u)
                x32 = np.ascontiguousarray(data_u, np.float32)
                g = x32 @ Q.T  # (U, m) f32 sgemm — the shared heavy pass
                xsq = np.einsum("un,un->u", x32, x32, dtype=np.float64)
                qsq = np.einsum("mn,mn->m", Q, Q, dtype=np.float64)
                d2a = qsq[:, None] + xsq[None, :] - 2.0 * g.T  # (m, U) f64-ish
                if kk < u:
                    part = np.argpartition(d2a, kk - 1, axis=1)[:, :kk]
                else:
                    part = np.broadcast_to(np.arange(kk), (m, kk)).copy()
                kth = np.take_along_axis(d2a, part, axis=1).max(axis=1)  # (m,)
                qn = np.sqrt(qsq)
                xn_max = float(np.sqrt(xsq.max()))
                bound = 4.0 * data_u.shape[1] * np.finfo(np.float32).eps * qn * xn_max
                cand = d2a <= (kth + 2.0 * bound)[:, None]  # (m, U)
                sel = np.nonzero(cand.any(axis=0))[0]  # (S,) small tail
                x64 = data_u[sel].astype(np.float64)
                d2e = (
                    qsq[:, None]
                    + np.einsum("sn,sn->s", x64, x64)[None, :]
                    - 2.0 * (Q.astype(np.float64) @ x64.T)
                )  # (m, S) exact
                d2e = np.maximum(d2e, 0.0).astype(np.float32)
                kks = min(kk, d2e.shape[1])
                if kks < d2e.shape[1]:
                    p2 = np.argpartition(d2e, kks - 1, axis=1)[:, :kks]
                else:
                    p2 = np.broadcast_to(np.arange(kks), (m, kks)).copy()
                nv = np.take_along_axis(d2e, p2, axis=1)
                o = np.argsort(nv, axis=1, kind="stable")
                nv = np.take_along_axis(nv, o, axis=1)
                ni = sel[np.take_along_axis(p2, o, axis=1)]
            gids = np.where(ni >= 0, self.ids[pos][np.maximum(ni, 0)], -1)
            vals, ids = merge_topk_state(vals, ids, nv, gids)

        # pass 1 (seed): every query's single best-bounded block — tightens
        # all radii with one small shared verification
        seed = np.unique(np.argmin(blb, axis=1))
        verify_blocks(seed)
        # pass 2: the union of blocks any query still needs. Extra (query,
        # block) pairs in the shared pass only tighten other queries' radii,
        # so — like the dense ED scan kernel — batching trades per-entry
        # early abandoning for one large regular pass. Blocks no query needs
        # are pruned for the whole batch.
        worst = vals[:, -1]  # (m,) kth-best after seeding
        need = (blb < worst[:, None]) & ~done[None, :]  # (m, nb)
        todo = np.nonzero(need.any(axis=0))[0]
        # best-bounded blocks first, so earlier passes tighten later ones
        todo = todo[np.argsort(blb[:, todo].min(axis=0), kind="stable")]
        for start in range(0, todo.size, blocks_per_round):
            # bounded passes: radii keep tightening between them
            worst = vals[:, -1]
            chunk = todo[start : start + blocks_per_round]
            chunk = chunk[(blb[:, chunk] < worst[:, None]).any(axis=0)]
            if chunk.size:
                verify_blocks(chunk)
        # per-query logical accounting, comparable to summed knn_exact stats
        worst = vals[:, -1]
        visited_q = (done[None, :] & (blb < worst[:, None])).sum(axis=1)
        stats.blocks_visited += int(visited_q.sum())
        stats.blocks_pruned += int((nb - visited_q).sum())
        return (vals, ids), stats

    def knn_approx(
        self,
        q: np.ndarray,
        k: int = 1,
        *,
        n_blocks: int = 1,
        raw: Optional[RawStore] = None,
        disk: Optional[DiskModel] = None,
        window: Optional[tuple[int, int]] = None,
    ) -> tuple[list, QueryStats]:
        """Approximate kNN: verify only the blocks adjacent to the query key
        position (one sequential read — the sortable-summarization payoff)."""
        stats = QueryStats()
        if self.n == 0:
            return [], stats
        qp = np.asarray(paa(np.asarray(q, np.float32), self.cfg))
        qsym = sax_from_paa(qp, self.cfg).astype(np.int32)
        qkey = interleave(qsym, self.cfg).reshape(-1)
        pos = searchsorted_keys(self.keys, qkey)
        bs = self.block_size
        # clamp: a key above every stored key (pos == n) still probes the
        # tail block instead of an empty range
        bc = min(pos, self.n - 1) // bs
        b0 = max(0, bc - (n_blocks - 1) // 2)
        b1 = min(self.n_blocks, b0 + n_blocks)
        lo, hi = b0 * bs, min(self.n, b1 * bs)
        stats.blocks_visited += b1 - b0
        if disk is not None:
            disk.read_seq((hi - lo) * self._entry_bytes(), offset=lo * self._entry_bytes())
        idx = np.arange(lo, hi)
        if window is not None and self.ts is not None:
            idx = idx[(self.ts[idx] >= window[0]) & (self.ts[idx] <= window[1])]
        d2 = self._verify_entries(idx, q, raw, disk, sequential=True)
        stats.entries_verified += idx.size
        bsf: list = []
        for dist, pos_i in zip(d2, idx):
            item = (-float(dist), int(self.ids[pos_i]))
            if len(bsf) < k:
                heapq.heappush(bsf, item)
            elif item[0] > bsf[0][0]:
                heapq.heapreplace(bsf, item)
        return bsf, stats

    def _query_keys_batch(self, Q: np.ndarray, backend: str) -> np.ndarray:
        """Sortable keys for a query batch: (m, n) series -> (m, nw) uint32.

        ``backend="kernel"`` produces PAA, symbols and interleaved keys in
        one fused device pass (``kernels.ops.summarize`` — a single Pallas
        launch per pipeline stage); ``"numpy"`` is the host twin."""
        if backend == "kernel":
            from ..kernels import ops as kernel_ops  # lazy: host engine stays jax-free

            _, _, keys = kernel_ops.summarize(Q, self.cfg)
            return np.asarray(keys).reshape(-1, self.cfg.key_words)
        qp = paa(Q, self.cfg)
        qsym = sax_from_paa(qp, self.cfg).astype(np.int32)
        return interleave(qsym, self.cfg).reshape(-1, self.cfg.key_words)

    def knn_approx_batch(
        self,
        Q: np.ndarray,
        k: int = 1,
        *,
        n_blocks: int = 1,
        raw: Optional[RawStore] = None,
        disk: Optional[DiskModel] = None,
        window: Optional[tuple[int, int]] = None,
        state: Optional[tuple[np.ndarray, np.ndarray]] = None,
        stats: Optional[QueryStats] = None,
        backend: str = "numpy",
    ) -> tuple[tuple[np.ndarray, np.ndarray], QueryStats]:
        """Approximate kNN for a whole query batch — the batched form of
        ``knn_approx`` (same per-query answers, shared physical work).

        Each query is answered from the ``n_blocks`` blocks adjacent to its
        sortable-key position, exactly as in the scalar path, but the whole
        batch shares one pipeline: query keys are produced in one batched
        summarization pass (``backend="kernel"``: one Pallas launch chain
        via ``kernels.ops.summarize``), all m key seeks run as ONE
        vectorized lexicographic binary search (``searchsorted_keys_batch``
        — O(log N) fancy-indexed probes for the batch), and the per-query
        block ranges are coalesced into deduplicated sequential reads before
        verification, so overlapping queries touch each block once and the
        DiskModel sees few long sequential reads instead of m seeks.

        Recall semantics: results are a subset of the exact answer — only
        candidates inside a query's adjacent blocks are considered, so
        recall@k grows with ``n_blocks`` (more sequential bytes per query)
        and equals the per-query ``knn_approx`` at the same ``n_blocks`` by
        construction. ``state``/``stats`` thread across runs exactly like
        ``knn_batch`` (CLSM folds one state over all levels).

        Stats semantics mirror ``knn_batch``: ``blocks_visited`` counts
        per-(query, block) logical work, ``entries_verified`` physical
        fetches (shared per batch), ``entries_pruned`` window filtering.
        """
        if backend not in ("numpy", "kernel"):
            raise ValueError(f"unknown batch verify backend {backend!r}")
        Q = np.asarray(Q, np.float32)
        m = Q.shape[0]
        stats = stats if stats is not None else QueryStats()
        if state is not None:  # copy: group merges below write rows in place
            vals, ids = state[0].copy(), state[1].copy()
        else:
            vals, ids = empty_topk_state(m, k)
        if self.n == 0 or m == 0:
            return (vals, ids), stats
        qkeys = self._query_keys_batch(Q, backend)
        pos = searchsorted_keys_batch(self.keys, qkeys)  # (m,) one batched seek
        bs = self.block_size
        # clamp: keys above every stored key still probe the tail block
        bc = np.minimum(pos, self.n - 1) // bs
        b0 = np.maximum(0, bc - (n_blocks - 1) // 2)
        b1 = np.minimum(self.n_blocks, b0 + n_blocks)
        lo = b0 * bs
        hi = np.minimum(self.n, b1 * bs)
        stats.blocks_visited += int(np.maximum(0, b1 - b0).sum())
        # coalesce the per-query [lo, hi) entry ranges: overlapping queries
        # collapse into few long sequential index reads
        ranges = coalesce_ranges(zip(lo.tolist(), hi.tolist()))
        if disk is not None:
            disk.read_seq_ranges(ranges, unit_bytes=self._entry_bytes())
        if not ranges:
            return (vals, ids), stats
        upos = np.concatenate([np.arange(r0, r1) for r0, r1 in ranges])
        if window is not None and self.ts is not None:
            in_win = (self.ts[upos] >= window[0]) & (self.ts[upos] <= window[1])
            stats.entries_pruned += int((~in_win).sum())
            upos = upos[in_win]
        if upos.size == 0:
            return (vals, ids), stats
        stats.entries_verified += int(upos.size)
        if self.materialized and upos.size == sum(r1 - r0 for r0, r1 in ranges):
            # contiguous materialized ranges: slice views per group below —
            # no 10s-of-MB union gather; only the I/O accounting happens here
            data_u = None
            gid_u = None
            if disk is not None:
                disk.read_seq_ranges(ranges, unit_bytes=self.cfg.series_len * 4)
        else:
            data_u = self._fetch_entries(upos, raw, disk, sequential=True)  # (U, n)
            gid_u = self.ids[upos]
        # one shared top-k pass per DISTINCT block range: queries that seek
        # into the same neighborhood share a pass (one topk_ed Pallas launch
        # under backend="kernel", one f64 matmul-form GEMM under "numpy"),
        # and disjoint ranges never multiply each other's distance work —
        # total compute equals the per-query loop's, batched into GEMMs
        spans, inv = np.unique(np.stack([lo, hi], axis=1), axis=0,
                               return_inverse=True)
        if backend != "kernel":
            # cached squared norms (nothing union-sized is recomputed or
            # cast to f64 — the slate re-rank below is tiny)
            if self.materialized:
                all_n2 = self.entry_norms2()
                xsq = None if data_u is None else all_n2[upos]
            else:
                xsq = raw.norms2(self.ids[upos])
            q64 = Q.astype(np.float64)
        for g, (glo, ghi) in enumerate(spans):
            qidx = np.nonzero(inv == g)[0]
            j0, j1 = np.searchsorted(upos, (glo, ghi))
            if j0 == j1:
                continue
            if data_u is None:  # contiguous materialized range: a view
                sub = self.series[glo:ghi]
                gid = self.ids[glo:ghi]
            else:
                sub = data_u[j0:j1]
                gid = gid_u[j0:j1]
            if backend == "kernel":
                nv, ni = _kernel_topk_dists(Q[qidx], sub, k)
                gi = np.where(ni >= 0, gid[np.maximum(ni, 0)], -1)
            else:
                # f32 sgemm screen with a +8 slack, then exact f64 re-rank
                # of the selected slate — the host twin of the kernel path.
                # |q|^2 is constant per row so the screen ranks by
                # |x|^2 - 2<q, x> only; the re-rank restores true distances.
                xsq_g = all_n2[glo:ghi] if xsq is None else xsq[j0:j1]
                d2a = Q[qidx] @ sub.T  # (|g|, U) f32 sgemm — the heavy pass
                np.multiply(d2a, -2.0, out=d2a)
                np.add(d2a, xsq_g[None, :], out=d2a)
                u = sub.shape[0]
                ksel = min(k + 8, u)  # slack absorbs f32 near-tie reordering
                if ksel < u:
                    part = np.argpartition(d2a, ksel - 1, axis=1)[:, :ksel]
                else:
                    part = np.broadcast_to(np.arange(u), (len(qidx), u)).copy()
                diff = sub[part].astype(np.float64) - q64[qidx][:, None, :]
                d2e = np.einsum("mkn,mkn->mk", diff, diff).astype(np.float32)
                kk = min(k, u)
                o = np.argsort(d2e, axis=1, kind="stable")[:, :kk]
                nv = np.take_along_axis(d2e, o, axis=1)
                gi = gid[np.take_along_axis(part, o, axis=1)]
            mv, mi = merge_topk_state(vals[qidx], ids[qidx], nv, gi)
            vals[qidx], ids[qidx] = mv, mi
        return (vals, ids), stats


def heap_to_sorted(bsf: list) -> list[tuple[float, int]]:
    """Convert a (-d2, id) max-heap into [(d2, id)] ascending by distance."""
    return sorted(((-nd, i) for nd, i in bsf))


# ---------------------------------------------------------------------------
# batched top-k state: the array analogue of the per-query bsf heap
# ---------------------------------------------------------------------------
def empty_topk_state(m: int, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Fresh batched best-so-far: ((m, k) inf distances, (m, k) -1 ids)."""
    return np.full((m, k), np.inf, np.float32), np.full((m, k), -1, np.int64)


def merge_topk_state(
    vals: np.ndarray, ids: np.ndarray, new_vals: np.ndarray, new_ids: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Row-wise merge of a (m, k) running top-k with (m, j) new candidates.

    Stable sort keeps existing entries ahead on distance ties. Callers must
    not feed an id twice (each index entry is verified at most once per
    batch, so this holds by construction)."""
    cv = np.concatenate([vals, new_vals.astype(vals.dtype)], axis=1)
    ci = np.concatenate([ids, new_ids.astype(ids.dtype)], axis=1)
    order = np.argsort(cv, axis=1, kind="stable")[:, : vals.shape[1]]
    return np.take_along_axis(cv, order, axis=1), np.take_along_axis(ci, order, axis=1)


def recall_at_k(approx_ids: np.ndarray, exact_ids: np.ndarray) -> float:
    """Micro-averaged recall of a batched approximate answer against the
    exact oracle: |approx ∩ exact| / |exact| over all queries, ignoring
    (-1) pad slots. Both args are (m, k) id arrays."""
    hits = sum(
        len(set(map(int, a[a >= 0])) & set(map(int, e[e >= 0])))
        for a, e in zip(approx_ids, exact_ids)
    )
    return hits / max(1, sum(int((e >= 0).sum()) for e in exact_ids))


def _kernel_topk_dists(
    Q: np.ndarray, data: np.ndarray, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Top-k distances of Q (m, n) against data (E, n) via one ``topk_ed``
    Pallas launch, with the candidate count padded up to a power of two so
    jit sees a handful of stable shapes.

    The kernel selects candidates at device (f32 matmul-form) precision
    with a +8 slack, then the selected slate is re-ranked exactly in f64 —
    so returned distances are exact and the best-so-far radius they feed is
    never underestimated. Returns ((m, kk) d2 ascending, (m, kk) rows into
    ``data``), kk = min(k, E), unfillable slots (inf, -1)."""
    from ..kernels import ops as kernel_ops  # lazy: keeps the host engine jax-free

    e = data.shape[0]
    data = np.asarray(data, np.float32)
    bucket = 1 << max(6, (e - 1).bit_length())
    if bucket > e:
        pad = np.full((bucket - e, data.shape[1]), 1e15, np.float32)
        data = np.concatenate([data, pad])
    ksel = min(k + 8, e)  # slack absorbs f32 near-tie reordering
    v, i = kernel_ops.topk_ed(Q, data, ksel)
    i = np.asarray(i).astype(np.int64)
    invalid = i >= e  # shape-padding rows can only surface when E < ksel
    # exact f64 re-rank of the selected slate
    sel = np.where(invalid, 0, i)
    diff = data[sel].astype(np.float64) - Q[:, None, :].astype(np.float64)
    d2 = np.einsum("mkn,mkn->mk", diff, diff)
    d2 = np.where(invalid, np.inf, d2.astype(np.float32))
    i = np.where(invalid, -1, i)
    kk = min(k, e)
    o = np.argsort(d2, axis=1, kind="stable")[:, :kk]
    return np.take_along_axis(d2, o, axis=1), np.take_along_axis(i, o, axis=1)


@dataclasses.dataclass
class CTreeConfig:
    summarization: SummarizationConfig = dataclasses.field(default_factory=SummarizationConfig)
    block_size: int = 1024
    materialized: bool = False
    fill_factor: float = 1.0  # <1 leaves insert gaps (update-tolerant)
    mem_budget_entries: int = 1 << 20


class CTree:
    """The read-optimized Coconut index: one SortedRun + insert gaps."""

    def __init__(self, cfg: CTreeConfig, disk: Optional[DiskModel] = None):
        self.cfg = cfg
        self.disk = disk or DiskModel()
        self.run: Optional[SortedRun] = None
        # overflow entries absorbed by gaps (kept summarized + optionally raw)
        self._pending: list[tuple[np.ndarray, np.ndarray, Optional[np.ndarray], Optional[np.ndarray]]] = []
        self._pending_n = 0
        self.build_report: Optional[SortReport] = None

    # ---------------------------------------------------------------- build
    def bulk_build(
        self,
        series: np.ndarray,
        ids: np.ndarray,
        ts: Optional[np.ndarray] = None,
    ) -> SortReport:
        scfg = self.cfg.summarization
        eff_block = max(8, int(self.cfg.block_size * self.cfg.fill_factor))
        self.run, report = SortedRun.build(
            series,
            ids,
            scfg,
            block_size=eff_block,
            materialized=self.cfg.materialized,
            ts=ts,
            disk=self.disk,
            mem_budget_entries=self.cfg.mem_budget_entries,
        )
        self.build_report = report
        return report

    @property
    def gap_capacity(self) -> int:
        if self.run is None:
            return 0
        full = self.cfg.block_size
        eff = self.run.block_size
        return (full - eff) * self.run.n_blocks

    def insert(
        self,
        series: np.ndarray,
        ids: np.ndarray,
        ts: Optional[np.ndarray] = None,
    ) -> bool:
        """Absorb inserts into leaf gaps (random writes); returns True if a
        rebuild was triggered (gaps exhausted)."""
        series = np.asarray(series, np.float32)
        scfg = self.cfg.summarization
        syms = sax_from_paa(paa(series, scfg), scfg).astype(np.uint8)
        self._pending.append((syms, np.asarray(ids, np.int64), series if self.cfg.materialized else None, ts))
        self._pending_n += series.shape[0]
        # each absorbed insert costs one random page read + write (find leaf, write gap)
        self.disk.read_rand(series.shape[0] * self.disk.page_bytes)
        self.disk.write_rand(series.shape[0] * self.disk.page_bytes)
        if self._pending_n > self.gap_capacity:
            self._rebuild_with_pending()
            return True
        return False

    def _rebuild_with_pending(self) -> None:
        assert self.run is not None
        scfg = self.cfg.summarization
        syms = np.concatenate([self.run.sax] + [p[0] for p in self._pending])
        ids = np.concatenate([self.run.ids] + [p[1] for p in self._pending])
        series = None
        if self.cfg.materialized:
            series = np.concatenate([self.run.series] + [p[2] for p in self._pending])
        ts = None
        if self.run.ts is not None:
            ts = np.concatenate(
                [self.run.ts] + [p[3] if p[3] is not None else np.zeros(len(p[1]), np.int64) for p in self._pending]
            )
        eff_block = max(8, int(self.cfg.block_size * self.cfg.fill_factor))
        self.run, self.build_report = SortedRun.from_arrays(
            scfg,
            syms,
            ids,
            block_size=eff_block,
            series=series,
            ts=ts,
            disk=self.disk,
            mem_budget_entries=self.cfg.mem_budget_entries,
        )
        self._pending, self._pending_n = [], 0

    # ---------------------------------------------------------------- query
    def _pending_scan(self, q, k, bsf, raw, window):
        """Brute-force the (small) gap-absorbed set."""
        scfg = self.cfg.summarization
        for syms, ids, series, ts in self._pending:
            if window is not None and ts is not None:
                m = (ts >= window[0]) & (ts <= window[1])
            else:
                m = np.ones(len(ids), bool)
            if not m.any():
                continue
            data = series[m] if series is not None else raw.fetch(ids[m])
            d2 = ed2(np.asarray(q, np.float32), data)
            for dist, i in zip(d2, ids[m]):
                item = (-float(dist), int(i))
                if len(bsf) < k:
                    heapq.heappush(bsf, item)
                elif item[0] > bsf[0][0]:
                    heapq.heapreplace(bsf, item)
        return bsf

    def _pending_scan_batch(self, Q, k, state, raw, window):
        """Batched brute force over the (small) gap-absorbed set."""
        vals, ids = state
        for syms, pids, series, ts in self._pending:
            m = np.ones(len(pids), bool)
            if window is not None and ts is not None:
                m = (ts >= window[0]) & (ts <= window[1])
            if not m.any():
                continue
            data = series[m] if series is not None else raw.fetch(pids[m])
            nv, ni = topk_ed2(Q, data, k)
            vals, ids = merge_topk_state(vals, ids, nv, pids[m][ni])
        return vals, ids

    def knn_exact(self, q, k=1, *, raw=None, window=None):
        if self.run is None:
            return [], QueryStats()
        bsf, stats = self.run.knn_exact(q, k, raw=raw, disk=self.disk, window=window)
        bsf = self._pending_scan(q, k, bsf, raw, window)
        return heap_to_sorted(bsf), stats

    def knn_batch(self, Q, k=1, *, raw=None, window=None, backend="numpy"):
        """Batched exact kNN: ((m, k) d2 ascending, (m, k) ids), stats.

        Unfilled slots (fewer than k in-window entries) are (inf, -1)."""
        Q = np.asarray(Q, np.float32)
        if self.run is None:
            vals, ids = empty_topk_state(Q.shape[0], k)
            return vals, ids, QueryStats()
        state, stats = self.run.knn_batch(
            Q, k, raw=raw, disk=self.disk, window=window, backend=backend
        )
        vals, ids = self._pending_scan_batch(Q, k, state, raw, window)
        return vals, ids, stats

    def knn_approx(self, q, k=1, *, n_blocks=1, raw=None, window=None):
        if self.run is None:
            return [], QueryStats()
        bsf, stats = self.run.knn_approx(q, k, n_blocks=n_blocks, raw=raw, disk=self.disk, window=window)
        bsf = self._pending_scan(q, k, bsf, raw, window)
        return heap_to_sorted(bsf), stats

    def knn_approx_batch(self, Q, k=1, *, n_blocks=1, raw=None, window=None,
                         backend="numpy"):
        """Batched approximate kNN: ((m, k) d2 ascending, (m, k) ids), stats.

        Per-query answers match a loop of ``knn_approx`` at the same
        ``n_blocks``; physically the batch shares one key-summarization
        pass, one vectorized key seek and coalesced sequential block reads
        (see ``SortedRun.knn_approx_batch``). Results are a subset of the
        exact ``knn_batch`` answer — only each query's ``n_blocks`` adjacent
        blocks are verified, so ``n_blocks`` trades sequential bytes read
        for recall@k. Unfilled slots are (inf, -1)."""
        Q = np.asarray(Q, np.float32)
        if self.run is None:
            vals, ids = empty_topk_state(Q.shape[0], k)
            return vals, ids, QueryStats()
        state, stats = self.run.knn_approx_batch(
            Q, k, n_blocks=n_blocks, raw=raw, disk=self.disk, window=window,
            backend=backend,
        )
        vals, ids = self._pending_scan_batch(Q, k, state, raw, window)
        return vals, ids, stats

    def index_bytes(self) -> int:
        return 0 if self.run is None else self.run.index_bytes()
