"""CoconutTree — the compact & contiguous read-optimized sorted index.

A CTree is a single :class:`SortedRun`: entries sorted by the bit-interleaved
sortable key, stored contiguously in fixed-size blocks with per-block zone
maps (min/max SAX symbol per segment) for block-level lower-bound pruning.
It is built bottom-up with a memory-budgeted external sort (sequential I/O
only) — the paper's headline capability.

Variants (paper §2):
  * materialized:     raw series stored inline in sorted order (bigger,
                      slower to build, fastest to query);
  * non-materialized: only summaries + ids; verification fetches raw series
                      from the RawStore (random I/O at query time).
  * fill_factor < 1:  leaves leave gaps so point inserts can be absorbed
                      without rebuilding (read/write trade-off knob).

``SortedRun`` is shared with CoconutLSM (a CLSM level run is the same
structure plus a time range).

Queries go through the plan/execute split (:mod:`repro.core.plan`,
:mod:`repro.core.execute`): a run *plans* its candidates — block lower
bounds from zone maps for the exact tier (``plan_exact``), per-query
sortable-key-seek entry spans for the approximate tier (``plan_approx``) —
and the shared executor performs the traversal, coalesced reads and
verification passes. The scalar ``knn_exact``/``knn_approx`` entry points
are batch-of-1 wrappers over the same engine; batched results are
((m, k) distances, (m, k) ids) arrays padded with (inf, -1).
"""
from __future__ import annotations

import dataclasses
import heapq
import threading
from typing import Optional

import numpy as np

from .execute import (
    BACKENDS,
    empty_topk_state,
    execute,
    heap_to_sorted,
    merge_topk_state,
    recall_at_k,
    state_to_list,
)
from .external_sort import SortReport, external_sort_order
from .io_model import DiskModel
from .lower_bounds import mindist_region2
from .plan import (
    BlockSource,
    DenseSource,
    QueryPlan,
    QueryStats,
    RangeSource,
    SourceOps,
    run_time_skipped,
)
from .sortable import interleave, searchsorted_keys_batch
from .summarization import SummarizationConfig, paa, sax_from_paa

__all__ = [
    "CTree", "CTreeConfig", "QueryStats", "RawStore", "SortedRun",
    "empty_topk_state", "heap_to_sorted", "merge_topk_state", "recall_at_k",
]


class RawStore:
    """The raw data-series file. Append-only; random reads are accounted."""

    def __init__(self, series_len: int, disk: Optional[DiskModel] = None,
                 screen_dtype: Optional[str] = None):
        self.series_len = series_len
        self.disk = disk or DiskModel()
        # arena storage dtype for the device screen tier (f32|bf16|int8;
        # None -> the engine default / REPRO_SCREEN_DTYPE)
        self.screen_dtype = screen_dtype
        # guards _chunks/_data/_norms2/_dev_view/n: the serving loop appends
        # from the ingest thread while query threads fetch concurrently
        self._lock = threading.RLock()
        self._chunks: list[np.ndarray] = []
        self._data: Optional[np.ndarray] = None
        self._norms2: Optional[np.ndarray] = None
        self._dev_view = None  # device arena over the whole store (lazy)
        self.n = 0

    def append(self, series: np.ndarray) -> np.ndarray:
        """Append (B, n) series; returns their ids. Sequential write."""
        series = np.asarray(series, dtype=np.float32)
        with self._lock:
            ids = np.arange(self.n, self.n + series.shape[0], dtype=np.int64)
            self._chunks.append(series)
            self._data = None
            self.n += series.shape[0]
        self.disk.write_seq(series.nbytes, offset=int(ids[0]) * self.series_len * 4)
        return ids

    def _all(self) -> np.ndarray:
        with self._lock:
            if self._data is None:
                self._data = (
                    np.concatenate(self._chunks, axis=0)
                    if self._chunks
                    else np.zeros((0, self.series_len), np.float32)
                )
            return self._data

    def fetch(self, ids: np.ndarray) -> np.ndarray:
        """Random fetch by id (the non-materialized query path)."""
        ids = np.asarray(ids)
        self.account_fetch(ids)
        return self._all()[ids]

    def account_fetch(self, ids: np.ndarray) -> None:
        """The modeled I/O of :meth:`fetch` without the gather — the device
        verification path reads its arena but pays the same modeled I/O."""
        ids = np.asarray(ids)
        row = self.series_len * 4
        if self.disk.keep_log and ids.size:
            for i in ids:  # scattered page touches for the heat map
                self.disk.read_rand(row, offset=int(i) * row)
        else:
            self.disk.read_rand(ids.size * row)

    def device_view(self):
        """Device arena over the whole store (raw row == global id), built
        once and extended in place as the append-only store grows."""
        from .verify_engine import get_engine  # lazy: keeps numpy paths jax-free

        eng = get_engine()
        with self._lock:  # one thread builds/extends; others reuse
            if self._dev_view is None:
                self._dev_view = eng.build_view(self._all(),
                                                dtype=self.screen_dtype)
            elif self._dev_view.n < self.n:
                self._dev_view = eng.extend_view(self._dev_view, self._all())
            return self._dev_view

    def scan(self) -> np.ndarray:
        """Full sequential scan (used by builds)."""
        data = self._all()
        self.disk.read_seq(data.nbytes)
        return data

    def norms2(self, ids: np.ndarray) -> np.ndarray:
        """Cached squared norms by id (derived data, no modeled I/O): the
        batched verify screens only need |x|^2, not another pass over x.
        The store is append-only, so the cache extends incrementally — a
        growing stream never pays a full-store recompute per query batch."""
        with self._lock:
            if self._norms2 is None or self._norms2.shape[0] < self.n:
                a = self._all()
                done = 0 if self._norms2 is None else self._norms2.shape[0]
                new = np.einsum("ij,ij->i", a[done:], a[done:])
                self._norms2 = (new if done == 0
                                else np.concatenate([self._norms2, new]))
            return self._norms2[ids]


def _zone_maps(sax_sorted: np.ndarray, block_size: int,
               w: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-block (bmin, bmax) zone maps of key-sorted SAX rows.

    One vectorized reduction over (nb, bs, w) instead of a Python loop per
    block: pad the tail block by replicating its last row (already a
    member, so block min/max are unchanged) — merges on the background
    ingest worker spend less time holding the GIL. A free function so a
    :class:`SortedRun` is constructed complete instead of patched after
    ``__init__`` (published runs are immutable)."""
    n = sax_sorted.shape[0]
    nb = max(1, -(-n // block_size)) if n else 0
    if nb == 0:
        return np.full((0, w), 255, np.uint8), np.zeros((0, w), np.uint8)
    pad = nb * block_size - n
    sax_p = sax_sorted
    if pad:
        sax_p = np.concatenate(
            [sax_sorted, np.broadcast_to(sax_sorted[-1:], (pad, w))])
    blocks = sax_p.reshape(nb, block_size, w)
    return blocks.min(axis=1), blocks.max(axis=1)


@dataclasses.dataclass
class SortedRun:
    """A contiguous sorted-by-key array of summarized entries + zone maps."""

    cfg: SummarizationConfig
    keys: np.ndarray  # (N, nw) uint32, lexicographically sorted
    sax: np.ndarray  # (N, w) uint8
    ids: np.ndarray  # (N,) int64 position in RawStore
    block_size: int
    bmin: np.ndarray  # (nb, w) uint8 zone maps
    bmax: np.ndarray  # (nb, w) uint8
    series: Optional[np.ndarray] = None  # (N, n) f32 if materialized
    ts: Optional[np.ndarray] = None  # (N,) int64 timestamps
    t_min: int = 0
    t_max: int = 0
    screen_dtype: Optional[str] = None  # arena storage dtype (None = engine default)
    _norms2: Optional[np.ndarray] = None  # lazy |x|^2 cache (materialized runs)
    _dev_view: Optional[object] = None  # lazy device arena (materialized runs)
    _storage: Optional[object] = None  # on-disk home when file-backed (RunFiles)

    @property
    def n(self) -> int:
        return self.keys.shape[0]

    @property
    def n_blocks(self) -> int:
        return self.bmin.shape[0]

    @property
    def materialized(self) -> bool:
        return self.series is not None

    def index_bytes(self) -> int:
        b = self.keys.nbytes + self.sax.nbytes + self.ids.nbytes
        b += self.bmin.nbytes + self.bmax.nbytes
        if self.series is not None:
            b += self.series.nbytes
        if self.ts is not None:
            b += self.ts.nbytes
        return b

    # ------------------------------------------------------------------ build
    @staticmethod
    def from_arrays(
        cfg: SummarizationConfig,
        sax_syms: np.ndarray,
        ids: np.ndarray,
        *,
        block_size: int = 1024,
        series: Optional[np.ndarray] = None,
        ts: Optional[np.ndarray] = None,
        disk: Optional[DiskModel] = None,
        mem_budget_entries: Optional[int] = None,
        presorted: bool = False,
        screen_dtype: Optional[str] = None,
    ) -> tuple["SortedRun", SortReport]:
        """Build a run from unsorted summarized entries via external sort."""
        keys = interleave(sax_syms.astype(np.int32), cfg).reshape(-1, cfg.key_words)
        n = keys.shape[0]
        payload = cfg.series_len * 4 if series is not None else 0
        if presorted:
            order = np.arange(n)
            report = SortReport(n, 1, 0, n or 1)
        else:
            order, report = external_sort_order(
                keys, mem_budget_entries or max(1, n), disk, payload_bytes_per_entry=payload
            )
        keys = keys[order]
        sax_sorted = sax_syms[order].astype(np.uint8)
        ts_sorted = None if ts is None else np.asarray(ts, np.int64)[order]
        bmin, bmax = _zone_maps(sax_sorted, block_size, cfg.n_segments)
        # the run is fully formed at construction: published runs are
        # immutable (the sanitizer's seal tripwire enforces it), so every
        # derived field is computed before __init__, never patched after
        run = SortedRun(
            cfg=cfg,
            keys=keys,
            sax=sax_sorted,
            ids=np.asarray(ids)[order].astype(np.int64),
            block_size=block_size,
            bmin=bmin,
            bmax=bmax,
            series=None if series is None else np.asarray(series, np.float32)[order],
            ts=ts_sorted,
            t_min=int(ts_sorted.min()) if ts_sorted is not None and n else 0,
            t_max=int(ts_sorted.max()) if ts_sorted is not None and n else 0,
            screen_dtype=screen_dtype,
        )
        return run, report

    @staticmethod
    def build(
        series: np.ndarray,
        ids: np.ndarray,
        cfg: SummarizationConfig,
        *,
        block_size: int = 1024,
        materialized: bool = False,
        ts: Optional[np.ndarray] = None,
        disk: Optional[DiskModel] = None,
        mem_budget_entries: Optional[int] = None,
        screen_dtype: Optional[str] = None,
    ) -> tuple["SortedRun", SortReport]:
        p = paa(np.asarray(series, np.float32), cfg)
        syms = sax_from_paa(p, cfg)
        return SortedRun.from_arrays(
            cfg,
            syms,
            ids,
            block_size=block_size,
            series=series if materialized else None,
            ts=ts,
            disk=disk,
            mem_budget_entries=mem_budget_entries,
            screen_dtype=screen_dtype,
        )

    def entry_norms2(self) -> np.ndarray:
        """Cached (N,) squared norms of the materialized entries (runs are
        immutable after build, so this never invalidates)."""
        assert self.series is not None
        if self._norms2 is None:
            self._norms2 = np.einsum("ij,ij->i", self.series, self.series)
        return self._norms2

    def device_view(self):
        """Device arena over the materialized entries (uploaded once — runs
        are immutable after build, so the view never invalidates)."""
        assert self.series is not None
        if self._dev_view is None:
            from .verify_engine import get_engine  # lazy: numpy paths stay jax-free

            self._dev_view = get_engine().build_view(
                self.series, dtype=self.screen_dtype)
        return self._dev_view

    def release_device_view(self) -> None:
        """Retire this run's device arena (called by the run registry once
        no pinned epoch can still plan against the run — in-flight passes
        keep the buffers alive through their own references). Safe to call
        on runs that never built an arena; a later ``device_view`` would
        lazily rebuild."""
        if self._dev_view is not None:
            from .verify_engine import get_engine  # lazy: numpy paths stay jax-free

            get_engine().release_view(self._dev_view)
            self._dev_view = None

    def release_storage(self) -> None:
        """Drop the storage handle of a file-persisted run (deferred
        retirement, like the device view). File deletion is owned by the
        storage engine's manifest diff — a merged-away run's files were
        already unlinked at the merge's manifest commit, and the open
        memmaps kept the data alive for pinned queries until now."""
        self._storage = None

    # ------------------------------------------------------------------ query
    def _entry_bytes(self) -> int:
        per = self.cfg.key_words * 4 + self.cfg.n_segments + 8
        if self.materialized:
            per += self.cfg.series_len * 4
        if self.ts is not None:
            per += 8
        return per

    def _fetch_entries(
        self,
        idx: np.ndarray,
        raw: Optional[RawStore],
        disk: Optional[DiskModel],
        sequential: bool,
    ) -> np.ndarray:
        """Raw series for entries at positions ``idx`` (I/O accounted)."""
        if self.materialized:
            data = self.series[idx]
            if disk is not None:
                nbytes = idx.size * self.cfg.series_len * 4
                (disk.read_seq if sequential else disk.read_rand)(nbytes)
        else:
            if raw is None:
                raise ValueError("non-materialized run queried without a RawStore")
            data = raw.fetch(self.ids[idx])
        return data

    def _account_entries(
        self, idx: np.ndarray, disk: Optional[DiskModel], sequential: bool
    ) -> None:
        """The modeled I/O of :meth:`_fetch_entries` for a materialized run
        without the host gather (the device path reads its arena)."""
        if disk is not None:
            nbytes = idx.size * self.cfg.series_len * 4
            (disk.read_seq if sequential else disk.read_rand)(nbytes)

    def _ops(self, raw: Optional[RawStore], disk: Optional[DiskModel],
             *, sequential: bool, screen: bool) -> SourceOps:
        """Physical accessor bundle for the executor (all I/O accounted)."""
        norms2 = None
        if self.materialized:
            norms2 = lambda p: self.entry_norms2()[p]
        elif raw is not None:
            norms2 = lambda p: raw.norms2(self.ids[p])
        index_read = None
        if disk is not None:
            per = self.cfg.key_words * 4 + self.cfg.n_segments
            index_read = lambda p: disk.read_rand(p.size * per)
        # device arena accessors: materialized runs own their arena (table
        # row == entry position); non-materialized runs verify against the
        # RawStore's arena (table row == global id)
        screen_dtype = None
        if self.materialized:
            device_view = self.device_view
            table_rows = None  # identity
            table_ids = lambda r: self.ids[r]
            fetch_account = lambda p: self._account_entries(p, disk, sequential)
            screen_dtype = self.screen_dtype
        elif raw is not None:
            device_view = raw.device_view
            table_rows = lambda p: self.ids[p]
            table_ids = lambda r: r  # raw rows ARE global ids
            fetch_account = lambda p: raw.account_fetch(self.ids[p])
            screen_dtype = raw.screen_dtype
        else:
            device_view = table_rows = table_ids = fetch_account = None
        prefetch_ranges = None
        if self._storage is not None:
            # file-backed run: hand the executor's coalesced row spans to
            # the readahead pool so the mmap pages are faulting in while
            # the lower-bound screen decides what to verify
            from .storage.prefetch import get_pool  # lazy: no storage dep otherwise

            arrays = [a for a in (self.series, self.sax, self.keys)
                      if a is not None]
            pool = get_pool()
            prefetch_ranges = lambda ranges: pool.prefetch(arrays, ranges)
        return SourceOps(
            ids=self.ids,
            ts=self.ts,
            fetch=lambda p: self._fetch_entries(p, raw, disk, sequential=sequential),
            index_read=index_read,
            sax=self.sax if screen else None,
            scfg=self.cfg,
            norms2=norms2,
            series=self.series,
            device_view=device_view,
            table_rows=table_rows,
            table_ids=table_ids,
            fetch_account=fetch_account,
            prefetch_ranges=prefetch_ranges,
            screen_dtype=screen_dtype,
        )

    def plan_exact(
        self,
        Q: np.ndarray,
        *,
        raw: Optional[RawStore] = None,
        disk: Optional[DiskModel] = None,
    ) -> BlockSource:
        """Exact-tier candidate generation: per-(query, block) lower bounds
        from the zone maps; the executor's adaptive traversal does the rest."""
        Q = np.asarray(Q, np.float32)
        qp = np.asarray(paa(Q, self.cfg))  # (m, w)
        blb = mindist_region2(
            qp[:, None, :], self.bmin.astype(np.int64), self.bmax.astype(np.int64),
            self.cfg,
        )  # (m, nb)
        bs = self.block_size
        blocks = [
            np.arange(b * bs, min(self.n, (b + 1) * bs))
            for b in range(self.n_blocks)
        ]
        return BlockSource(
            ops=self._ops(raw, disk, sequential=self.materialized, screen=True),
            lb=blb,
            blocks=blocks,
        )

    def _query_keys_batch(self, Q: np.ndarray, backend: str) -> np.ndarray:
        """Sortable keys for a query batch: (m, n) series -> (m, nw) uint32.

        ``backend="kernel"`` produces PAA, symbols and interleaved keys in
        one fused device pass (``kernels.ops.summarize`` — a single Pallas
        launch per pipeline stage); ``"numpy"`` is the host twin."""
        if backend == "kernel":
            from ..kernels import ops as kernel_ops  # lazy: host engine stays jax-free

            _, _, keys = kernel_ops.summarize(Q, self.cfg)
            return np.asarray(keys).reshape(-1, self.cfg.key_words)
        qp = paa(Q, self.cfg)
        qsym = sax_from_paa(qp, self.cfg).astype(np.int32)
        return interleave(qsym, self.cfg).reshape(-1, self.cfg.key_words)

    def plan_approx(
        self,
        Q: np.ndarray,
        *,
        n_blocks: int = 1,
        raw: Optional[RawStore] = None,
        disk: Optional[DiskModel] = None,
        backend: str = "device",
    ) -> RangeSource:
        """Approximate-tier candidate generation: each query is answered
        from the ``n_blocks`` blocks adjacent to its sortable-key position.

        The whole batch shares one pipeline: query keys are produced in one
        batched summarization pass (``backend="kernel"``: one Pallas launch
        chain via ``kernels.ops.summarize``), all m key seeks run as ONE
        vectorized lexicographic binary search (``searchsorted_keys_batch``
        — O(log N) fancy-indexed probes for the batch), and the resulting
        per-query entry spans go to the executor, which coalesces them into
        deduplicated sequential reads. Results are a subset of the exact
        answer — recall@k grows with ``n_blocks`` (more sequential bytes
        per query)."""
        Q = np.asarray(Q, np.float32)
        qkeys = self._query_keys_batch(Q, backend)
        pos = searchsorted_keys_batch(self.keys, qkeys)  # (m,) one batched seek
        bs = self.block_size
        # clamp: keys above every stored key still probe the tail block
        bc = np.minimum(pos, self.n - 1) // bs
        b0 = np.maximum(0, bc - (n_blocks - 1) // 2)
        b1 = np.minimum(self.n_blocks, b0 + n_blocks)
        spans = np.stack([b0 * bs, np.minimum(self.n, b1 * bs)], axis=1)
        eb = self._entry_bytes()
        read_index = read_payload = None
        if disk is not None:
            read_index = lambda rs: disk.read_seq_ranges(rs, unit_bytes=eb)
            read_payload = lambda rs: disk.read_seq_ranges(
                rs, unit_bytes=self.cfg.series_len * 4
            )
        return RangeSource(
            ops=self._ops(raw, disk, sequential=True, screen=False),
            spans=spans,
            logical_blocks=int(np.maximum(0, b1 - b0).sum()),
            read_index_ranges=read_index,
            read_payload_ranges=read_payload,
        )

    def knn_exact(
        self,
        q: np.ndarray,
        k: int = 1,
        *,
        raw: Optional[RawStore] = None,
        disk: Optional[DiskModel] = None,
        bsf: Optional[list] = None,
        window: Optional[tuple[int, int]] = None,
        stats: Optional[QueryStats] = None,
    ) -> tuple[list, QueryStats]:
        """Exact kNN within this run, sharing a best-so-far heap across runs.

        A batch-of-1 plan through the shared executor. ``bsf`` is a
        max-heap of (-dist2, id) of current best k; returns the updated
        heap. ``window=(t0, t1)`` filters by timestamp (inclusive).
        """
        stats = stats or QueryStats()
        bsf = bsf if bsf is not None else []
        if self.n == 0:
            return bsf, stats
        if run_time_skipped(self.t_min, self.t_max, window, self.ts is not None):
            stats.blocks_pruned += self.n_blocks
            return bsf, stats
        Q = np.asarray(q, np.float32).reshape(1, -1)
        plan = QueryPlan(m=1, sources=[self.plan_exact(Q, raw=raw, disk=disk)],
                         window=window)
        (vals, ids), stats = execute(plan, Q, k, state=_heap_to_state(bsf, k),
                                     stats=stats)
        return _state_to_heap(vals[0], ids[0]), stats

    def knn_batch(
        self,
        Q: np.ndarray,
        k: int = 1,
        *,
        raw: Optional[RawStore] = None,
        disk: Optional[DiskModel] = None,
        window: Optional[tuple[int, int]] = None,
        state: Optional[tuple[np.ndarray, np.ndarray]] = None,
        stats: Optional[QueryStats] = None,
        blocks_per_round: int = 32,
        backend: str = "device",
        time_skip: bool = True,
    ) -> tuple[tuple[np.ndarray, np.ndarray], QueryStats]:
        """Exact kNN for a whole query batch in one pass over this run.

        Plans this run's blocks (``plan_exact``) and hands the traversal to
        the shared executor; see :func:`repro.core.execute.execute` for the
        pass structure and stats semantics. ``state`` is the batched
        best-so-far — ((m, k) distances ascending, (m, k) global ids,
        inf/-1 padded) — shared across runs the way the ``bsf`` heap is in
        ``knn_exact``. ``time_skip=False`` disables the run-level time
        range skip while keeping per-entry window filtering (PP semantics).
        """
        if backend not in BACKENDS:
            raise ValueError(f"unknown batch verify backend {backend!r}")
        Q = np.asarray(Q, np.float32)
        m = Q.shape[0]
        stats = stats if stats is not None else QueryStats()
        if state is None:
            state = empty_topk_state(m, k)
        if self.n == 0 or m == 0:
            return state, stats
        if run_time_skipped(self.t_min, self.t_max, window,
                            time_skip and self.ts is not None):
            stats.blocks_pruned += self.n_blocks * m  # per-query semantics
            return state, stats
        plan = QueryPlan(m=m, sources=[self.plan_exact(Q, raw=raw, disk=disk)],
                         window=window, time_skip=time_skip)
        return execute(plan, Q, k, state=state, stats=stats, backend=backend,
                       blocks_per_round=blocks_per_round)

    def knn_approx(
        self,
        q: np.ndarray,
        k: int = 1,
        *,
        n_blocks: int = 1,
        raw: Optional[RawStore] = None,
        disk: Optional[DiskModel] = None,
        window: Optional[tuple[int, int]] = None,
    ) -> tuple[list, QueryStats]:
        """Approximate kNN: verify only the blocks adjacent to the query key
        position (one sequential read — the sortable-summarization payoff).
        Batch-of-1 over the shared executor; returns a (-d2, id) heap."""
        stats = QueryStats()
        if self.n == 0:
            return [], stats
        Q = np.asarray(q, np.float32).reshape(1, -1)
        plan = QueryPlan(
            m=1,
            sources=[self.plan_approx(Q, n_blocks=n_blocks, raw=raw, disk=disk)],
            window=window,
        )
        (vals, ids), stats = execute(plan, Q, k, stats=stats)
        return _state_to_heap(vals[0], ids[0]), stats

    def knn_approx_batch(
        self,
        Q: np.ndarray,
        k: int = 1,
        *,
        n_blocks: int = 1,
        raw: Optional[RawStore] = None,
        disk: Optional[DiskModel] = None,
        window: Optional[tuple[int, int]] = None,
        state: Optional[tuple[np.ndarray, np.ndarray]] = None,
        stats: Optional[QueryStats] = None,
        backend: str = "device",
    ) -> tuple[tuple[np.ndarray, np.ndarray], QueryStats]:
        """Approximate kNN for a whole query batch — the batched form of
        ``knn_approx`` (same per-query answers, shared physical work).

        Plans the per-query adjacent-block spans (``plan_approx``) and lets
        the executor coalesce them into deduplicated sequential reads with
        one shared top-k pass per distinct span. ``state``/``stats`` thread
        across runs exactly like ``knn_batch`` (CLSM folds one state over
        all levels)."""
        if backend not in BACKENDS:
            raise ValueError(f"unknown batch verify backend {backend!r}")
        Q = np.asarray(Q, np.float32)
        m = Q.shape[0]
        stats = stats if stats is not None else QueryStats()
        if self.n == 0 or m == 0:
            if state is not None:
                return (state[0].copy(), state[1].copy()), stats
            return empty_topk_state(m, k), stats
        plan = QueryPlan(
            m=m,
            sources=[self.plan_approx(Q, n_blocks=n_blocks, raw=raw, disk=disk,
                                      backend=backend)],
            window=window,
        )
        return execute(plan, Q, k, state=state, stats=stats, backend=backend)


def _heap_to_state(bsf: list, k: int) -> tuple[np.ndarray, np.ndarray]:
    """A scalar (-d2, id) heap as a (1, k) batched best-so-far state."""
    vals, ids = empty_topk_state(1, k)
    for j, (d, i) in enumerate(sorted((-nd, i) for nd, i in bsf)[:k]):
        vals[0, j] = d
        ids[0, j] = i
    return vals, ids


def _state_to_heap(vals_row: np.ndarray, ids_row: np.ndarray) -> list:
    """One (k,) state row back into the scalar (-d2, id) heap form."""
    h = [(-float(v), int(g)) for v, g in zip(vals_row, ids_row) if g >= 0]
    heapq.heapify(h)
    return h


@dataclasses.dataclass
class CTreeConfig:
    summarization: SummarizationConfig = dataclasses.field(default_factory=SummarizationConfig)
    block_size: int = 1024
    materialized: bool = False
    fill_factor: float = 1.0  # <1 leaves insert gaps (update-tolerant)
    mem_budget_entries: int = 1 << 20
    # device-arena storage dtype for the screen tier (f32|bf16|int8; None
    # resolves the engine default / REPRO_SCREEN_DTYPE)
    screen_dtype: Optional[str] = None


class CTree:
    """The read-optimized Coconut index: one SortedRun + insert gaps."""

    def __init__(self, cfg: CTreeConfig, disk: Optional[DiskModel] = None,
                 storage=None):
        self.cfg = cfg
        self.disk = disk or DiskModel()
        # optional file backend: built/rebuilt runs are persisted and served
        # from mmaps (the static index has no WAL — a bulk build is re-runnable)
        self.storage = storage
        self.run: Optional[SortedRun] = None
        # overflow entries absorbed by gaps (kept summarized + optionally raw)
        self._pending: list[tuple[np.ndarray, np.ndarray, Optional[np.ndarray], Optional[np.ndarray]]] = []
        self._pending_n = 0
        self.build_report: Optional[SortReport] = None

    # ---------------------------------------------------------------- build
    def bulk_build(
        self,
        series: np.ndarray,
        ids: np.ndarray,
        ts: Optional[np.ndarray] = None,
    ) -> SortReport:
        scfg = self.cfg.summarization
        eff_block = max(8, int(self.cfg.block_size * self.cfg.fill_factor))
        old = self.run
        self.run, report = SortedRun.build(
            series,
            ids,
            scfg,
            block_size=eff_block,
            materialized=self.cfg.materialized,
            ts=ts,
            disk=self.disk,
            mem_budget_entries=self.cfg.mem_budget_entries,
            screen_dtype=self.cfg.screen_dtype,
        )
        if self.storage is not None:
            self.run = self.storage.persist_run(self.run)
            if old is not None and old._storage is not None:
                self.storage.drop_run(old)
        self.build_report = report
        return report

    @property
    def gap_capacity(self) -> int:
        if self.run is None:
            return 0
        full = self.cfg.block_size
        eff = self.run.block_size
        return (full - eff) * self.run.n_blocks

    def insert(
        self,
        series: np.ndarray,
        ids: np.ndarray,
        ts: Optional[np.ndarray] = None,
    ) -> bool:
        """Absorb inserts into leaf gaps (random writes); returns True if a
        rebuild was triggered (gaps exhausted)."""
        series = np.asarray(series, np.float32)
        scfg = self.cfg.summarization
        syms = sax_from_paa(paa(series, scfg), scfg).astype(np.uint8)
        self._pending.append((syms, np.asarray(ids, np.int64), series if self.cfg.materialized else None, ts))
        self._pending_n += series.shape[0]
        # each absorbed insert costs one random page read + write (find leaf, write gap)
        self.disk.read_rand(series.shape[0] * self.disk.page_bytes)
        self.disk.write_rand(series.shape[0] * self.disk.page_bytes)
        if self._pending_n > self.gap_capacity:
            self._rebuild_with_pending()
            return True
        return False

    def _rebuild_with_pending(self) -> None:
        assert self.run is not None
        scfg = self.cfg.summarization
        syms = np.concatenate([self.run.sax] + [p[0] for p in self._pending])
        ids = np.concatenate([self.run.ids] + [p[1] for p in self._pending])
        series = None
        if self.cfg.materialized:
            series = np.concatenate([self.run.series] + [p[2] for p in self._pending])
        ts = None
        if self.run.ts is not None:
            ts = np.concatenate(
                [self.run.ts] + [p[3] if p[3] is not None else np.zeros(len(p[1]), np.int64) for p in self._pending]
            )
        eff_block = max(8, int(self.cfg.block_size * self.cfg.fill_factor))
        old = self.run
        self.run, self.build_report = SortedRun.from_arrays(
            scfg,
            syms,
            ids,
            block_size=eff_block,
            series=series,
            ts=ts,
            disk=self.disk,
            mem_budget_entries=self.cfg.mem_budget_entries,
            screen_dtype=self.cfg.screen_dtype,
        )
        if self.storage is not None:
            self.run = self.storage.persist_run(self.run)
            if old._storage is not None:
                self.storage.drop_run(old)
        self._pending, self._pending_n = [], 0

    # ---------------------------------------------------------------- query
    def _pending_sources(self, raw: Optional[RawStore]) -> list[DenseSource]:
        """The (small) gap-absorbed set as brute-force plan sources."""
        out = []
        for _syms, pids, series, ts in self._pending:
            if series is not None:
                fetch = lambda p, s=series: s[p]
            else:
                fetch = lambda p, i=pids: raw.fetch(i[p])
            out.append(DenseSource(ops=SourceOps(ids=pids, ts=ts, fetch=fetch),
                                   n=len(pids)))
        return out

    def plan(
        self,
        Q: np.ndarray,
        *,
        tier: str = "exact",
        n_blocks: int = 1,
        raw: Optional[RawStore] = None,
        window: Optional[tuple[int, int]] = None,
        backend: str = "device",
    ) -> QueryPlan:
        """Compile a query batch into a declarative plan: the sorted run's
        candidate source (exact blocks or approximate spans) plus one dense
        source per pending gap-absorbed chunk."""
        sources: list = []
        pruned = 0
        if self.run is not None and self.run.n:
            r = self.run
            if tier == "exact":
                if run_time_skipped(r.t_min, r.t_max, window, r.ts is not None):
                    pruned += r.n_blocks
                else:
                    sources.append(r.plan_exact(Q, raw=raw, disk=self.disk))
            else:
                sources.append(r.plan_approx(Q, n_blocks=n_blocks, raw=raw,
                                             disk=self.disk, backend=backend))
        sources.extend(self._pending_sources(raw))
        return QueryPlan(m=len(Q), sources=sources, window=window,
                         pruned_blocks=pruned)

    def knn_exact(self, q, k=1, *, raw=None, window=None):
        """Scalar exact kNN — a batch-of-1 plan through the shared executor.
        Returns ([(d2, id)] ascending, stats)."""
        vals, gids, stats = self.knn_batch(
            np.asarray(q, np.float32).reshape(1, -1), k, raw=raw, window=window
        )
        return state_to_list(vals[0], gids[0]), stats

    def knn_batch(self, Q, k=1, *, raw=None, window=None, backend="device",
                  shard=None, mesh=None):
        """Batched exact kNN: ((m, k) d2 ascending, (m, k) ids), stats.

        Unfilled slots (fewer than k in-window entries) are (inf, -1).
        ``shard="mesh"`` executes on the device mesh (queries x runs 2-D
        ``shard_map``) with host f64 re-ranking — same answers."""
        Q = np.asarray(Q, np.float32)
        plan = self.plan(Q, tier="exact", raw=raw, window=window)
        (vals, gids), stats = execute(plan, Q, k, backend=backend, shard=shard,
                                      mesh=mesh)
        return vals, gids, stats

    def knn_approx(self, q, k=1, *, n_blocks=1, raw=None, window=None):
        """Scalar approximate kNN — a batch-of-1 plan through the executor.
        Returns ([(d2, id)] ascending, stats)."""
        vals, gids, stats = self.knn_approx_batch(
            np.asarray(q, np.float32).reshape(1, -1), k, n_blocks=n_blocks,
            raw=raw, window=window,
        )
        return state_to_list(vals[0], gids[0]), stats

    def knn_approx_batch(self, Q, k=1, *, n_blocks=1, raw=None, window=None,
                         backend="device"):
        """Batched approximate kNN: ((m, k) d2 ascending, (m, k) ids), stats.

        Per-query answers match a loop of ``knn_approx`` at the same
        ``n_blocks``; physically the batch shares one key-summarization
        pass, one vectorized key seek and coalesced sequential block reads
        (see ``SortedRun.plan_approx`` + the executor). Results are a
        subset of the exact ``knn_batch`` answer — only each query's
        ``n_blocks`` adjacent blocks are verified, so ``n_blocks`` trades
        sequential bytes read for recall@k. Unfilled slots are (inf, -1)."""
        if backend not in BACKENDS:
            raise ValueError(f"unknown batch verify backend {backend!r}")
        Q = np.asarray(Q, np.float32)
        plan = self.plan(Q, tier="approx", n_blocks=n_blocks, raw=raw,
                         window=window, backend=backend)
        (vals, gids), stats = execute(plan, Q, k, backend=backend)
        return vals, gids, stats

    def index_bytes(self) -> int:
        return 0 if self.run is None else self.run.index_bytes()
