"""Online autotuning: the recommender's decision tree, closed-loop.

The static recommender (``core.recommender``) prices the serving tiers with
hard-coded cost constants and a calibrated recall curve. The serving stack
measures the real thing on every formed batch: per-sub-batch service
latency, recall@k vs the exact oracle (shadow probes), the registry epoch,
ingest lag. This module turns those observations into the decision:

* **Workload profiles** (:class:`WorkloadKey` -> per-arm fitted models) are
  keyed by request shape — tier targets, ``k``, the window-width bucket,
  the serving batch rung. A misbehaving tenant only ever updates its own
  profile, so one tenant's pathology cannot skew another's fitted model.
* **Online models with exponential forgetting**: each (profile, knob) arm
  holds an exponentially-forgotten latency estimate (mean + mean absolute
  deviation -> a p99 proxy) and a recall estimate. The static model's
  numbers enter as priors with ``prior_weight`` pseudo-observations;
  measurements wash them out at rate ``forget``.
* **A contextual bandit over the discrete knob grid** (epsilon-greedy by
  default, UCB optional): pick the feasible arm — fitted recall clears the
  target — with the lowest fitted p99 that fits the latency budget;
  explore with probability ``epsilon``. Decisions adapt **per registry
  epoch**: when the pinned epoch advances past a profile's last-seen
  epoch, that profile's evidence weights decay by ``epoch_forget`` (the
  data changed; old measurements say less).
* **Versioned decision records**: every decision and observation appends a
  frozen, schema-versioned record to a bounded trace — the BENCH
  adaptation artifacts and CI schema gates consume exactly this stream.

The gateway (``core.gateway``) is the production consumer: per-request
tier selection calls :meth:`AutoTuner.decide` instead of the frozen rule
node, and :meth:`AutoTuner.observe` feeds back after each formed batch.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from .recommender import (
    RationaleEntry, _approx_cost_ms, _approx_recall_model, _exact_cost_ms,
)

#: version of the decision/observation trace records; bump on field changes
DECISION_SCHEMA = 1

#: default discrete grid of the approximate tier's recall knob
N_BLOCKS_GRID = (1, 2, 4, 8, 16)


@dataclasses.dataclass(frozen=True)
class Knobs:
    """One point of the discrete knob grid the bandit navigates.

    ``tier``/``n_blocks`` are per-request knobs the gateway acts on;
    ``shard`` and ``ingest`` are deployment-scoped knobs carried for the
    global advice channel (:meth:`AutoTuner.advise_global`) — a gateway
    cannot flip them per request."""
    tier: str  # "exact" | "approx"
    n_blocks: int = 0  # approx tier recall knob (0 for exact)
    shard: Optional[str] = None  # None | "mesh"
    ingest: str = "sync"  # "sync" | "async"

    def label(self) -> str:
        return self.tier if self.tier == "exact" else f"approx{self.n_blocks}"


def knob_grid(n_blocks_grid: Tuple[int, ...] = N_BLOCKS_GRID,
              shard: Optional[str] = None,
              ingest: str = "sync") -> Tuple[Knobs, ...]:
    """The per-request arm set: exact plus one approx arm per grid point."""
    arms = [Knobs("exact", 0, shard, ingest)]
    arms += [Knobs("approx", nb, shard, ingest) for nb in n_blocks_grid]
    return tuple(arms)


@dataclasses.dataclass(frozen=True)
class WorkloadKey:
    """Request-shape profile key. Continuous inputs are bucketed so the
    profile table stays small and decisions stay stable."""
    target_recall: Optional[float]
    latency_budget_ms: Optional[float]
    k: int
    window_bucket: int  # -1 whole history, else pow2 bucket of window width
    batch_rung: int


def workload_key(*, target_recall: Optional[float] = None,
                 latency_budget_ms: Optional[float] = None, k: int,
                 window: Optional[tuple] = None,
                 batch_rung: int) -> WorkloadKey:
    wb = -1
    if window is not None:
        width = max(1, int(window[1]) - int(window[0]) + 1)
        wb = 1 << (width - 1).bit_length()
    tr = None if target_recall is None else round(float(target_recall), 3)
    lb = (None if latency_budget_ms is None
          else round(float(latency_budget_ms), 4))
    return WorkloadKey(tr, lb, int(k), wb, int(batch_rung))


@dataclasses.dataclass(frozen=True)
class DecisionRecord:
    """One frozen, versioned decision: what was chosen for which workload,
    under which epoch, and what the fitted models predicted at choice
    time. The gateway stamps responses from these; the trace stream is the
    BENCH adaptation artifact.

    ``shadow`` carries the bandit's exploration off the client path: the
    client is always served ``knobs`` (the greedy pick), and when
    ``shadow`` is set the gateway additionally measures that arm on the
    same sub-batch AFTER answers are resolved — exploration never inflates
    the explored request's (or its co-batched neighbors') latency."""
    schema: int
    seq: int
    epoch: int
    key: WorkloadKey
    knobs: Knobs
    explore: bool
    conflict: bool
    predicted_recall: float
    predicted_p99_ms: float
    shadow: Optional[Knobs] = None


class _Arm:
    """Mutable fitted state of one (profile, knob) arm. Exponential
    forgetting: value = (value*w*g + x) / (w*g + 1), w = w*g + 1 — the
    steady-state weight is 1/(1-g), so priors with weight ``prior_weight``
    wash out after a handful of measurements."""

    __slots__ = ("lat_ms", "lat_dev_ms", "recall", "lat_w", "recall_w")

    def __init__(self, lat_ms: float, recall: float, prior_weight: float):
        self.lat_ms = float(lat_ms)
        self.lat_dev_ms = 0.25 * float(lat_ms)  # wide prior tail
        self.recall = float(recall)
        self.lat_w = float(prior_weight)
        self.recall_w = float(prior_weight)

    @property
    def p99_ms(self) -> float:
        # mean + 3 deviations: a cheap, monotone tail proxy that only has
        # to RANK arms, not report calibrated percentiles
        return self.lat_ms + 3.0 * self.lat_dev_ms

    def observe_latency(self, x: float, g: float) -> None:
        w = self.lat_w * g
        self.lat_dev_ms = (self.lat_dev_ms * w + abs(x - self.lat_ms)) / (w + 1)
        self.lat_ms = (self.lat_ms * w + x) / (w + 1)
        self.lat_w = w + 1

    def observe_recall(self, x: float, g: float) -> None:
        w = self.recall_w * g
        self.recall = (self.recall * w + x) / (w + 1)
        self.recall_w = w + 1

    def decay(self, f: float) -> None:
        self.lat_w *= f
        self.recall_w *= f


class _Profile:
    """Per-workload fitted state: one ``_Arm`` per knob + bookkeeping."""

    __slots__ = ("arms", "last_epoch", "decisions")

    def __init__(self):
        self.arms: Dict[Knobs, _Arm] = {}
        self.last_epoch = -1
        self.decisions = 0


@dataclasses.dataclass(frozen=True)
class AutoTunerConfig:
    policy: str = "egreedy"  # "egreedy" | "ucb"
    epsilon: float = 0.05  # egreedy (shadow) exploration rate
    ucb_c: float = 1.0  # UCB optimism scale
    forget: float = 0.9  # per-observation exponential forgetting
    epoch_forget: float = 0.5  # evidence-weight decay when the epoch moves
    prior_weight: float = 2.0  # pseudo-observations behind the static priors
    recall_slack: float = 0.02  # fitted recall may undershoot target by this
    explore_bonus: float = 0.35  # optimism (/sqrt(evidence)) in the explore
    # guard: keeps arms whose fitted recall is still prior-dragged
    # explorable instead of freezing them out below target forever
    probe_frac: float = 0.25  # fraction of servings shadow-probed for recall
    probe_min_weight: float = 8.0  # always probe arms with less evidence
    seed: int = 0
    n_blocks_grid: Tuple[int, ...] = N_BLOCKS_GRID
    series_len: int = 128  # prior cost model input
    max_trace: int = 4096  # bounded decision/observation trace
    forced: Optional[Knobs] = None  # pin every decision (fixed-arm baselines)


class AutoTuner:
    """Measured-feedback knob controller over per-workload profiles.

    Thread-shared state (profiles, trace, RNG, counters) is guarded by
    ``self._lock`` — palmlint's lock-discipline checker enforces it.
    Strictly-exact workloads (no targets, or ``target_recall >= 1.0``) are
    contractually outside the bandit: they always get the exact tier."""

    def __init__(self, cfg: Optional[AutoTunerConfig] = None):
        self.cfg = cfg or AutoTunerConfig()
        self._lock = threading.Lock()
        self._rng = np.random.default_rng(self.cfg.seed)
        self._profiles: Dict[WorkloadKey, _Profile] = {}
        self._arms = knob_grid(self.cfg.n_blocks_grid)
        self._trace: deque = deque(maxlen=self.cfg.max_trace)
        self._seq = 0
        self.stats = {
            "decisions": 0,  # decide() calls (bandit + strict + forced)
            "explores": 0,  # decisions taken by the exploration branch
            "observations": 0,  # observe() calls folded into the models
            "probes": 0,  # should_probe() -> True (shadow recall measures)
            "epoch_refits": 0,  # profile evidence decays on epoch advance
        }

    # ----------------------------------------------------------- internals
    def _priors(self, key: WorkloadKey, n_series: int) -> Dict[Knobs, _Arm]:
        """Seed every arm from the static cost/recall model — the frozen
        rule tree's constants, demoted to priors."""
        n = max(1024, int(n_series))
        arms: Dict[Knobs, _Arm] = {}
        for kn in self._arms:
            if kn.tier == "exact":
                lat = _exact_cost_ms(n, key.batch_rung)
                rec = 1.0
            else:
                lat = _approx_cost_ms(kn.n_blocks, self.cfg.series_len)
                rec = _approx_recall_model(kn.n_blocks)
            arms[kn] = _Arm(lat, rec, self.cfg.prior_weight)
        return arms

    def _profile_locked(self, key: WorkloadKey, n_series: int) -> _Profile:
        prof = self._profiles.get(key)
        if prof is None:
            prof = _Profile()
            prof.arms = self._priors(key, n_series)
            self._profiles[key] = prof
        return prof

    def _refit_epoch_locked(self, prof: _Profile, epoch: int) -> None:
        """Epoch advanced -> the run set changed -> decay this profile's
        evidence weights so fresh measurements re-fit the models faster.
        The estimates themselves persist (the best guess until data says
        otherwise); only their certainty drops."""
        if prof.last_epoch >= 0 and epoch > prof.last_epoch:
            for arm in prof.arms.values():
                arm.decay(self.cfg.epoch_forget)
            self.stats["epoch_refits"] += 1
        prof.last_epoch = max(prof.last_epoch, epoch)

    @staticmethod
    def _is_strict(key: WorkloadKey) -> bool:
        if key.target_recall is None and key.latency_budget_ms is None:
            return True
        return key.target_recall is not None and key.target_recall >= 1.0

    def _pick_locked(self, prof: _Profile, key: WorkloadKey):
        """(knobs, shadow, explore, conflict) from the fitted models —
        ``knobs`` is always the greedy pick (the arm the client is
        served); ``shadow`` is the arm to measure off the client path when
        the exploration coin fires."""
        cfg = self.cfg
        arms = list(prof.arms.items())
        target = (key.target_recall if key.target_recall is not None else 0.9)
        budget = key.latency_budget_ms
        if cfg.policy == "ucb":
            # optimism in the face of uncertainty, both dimensions: recall
            # gets an upper bond, latency a lower one, scaled by evidence
            total = max(2.0, float(prof.decisions) + 1.0)

            def rec_hat(a: _Arm) -> float:
                return a.recall + cfg.ucb_c * math.sqrt(
                    math.log(total) / (a.recall_w + 1.0))

            def p99_hat(a: _Arm) -> float:
                bonus = cfg.ucb_c * a.lat_dev_ms * math.sqrt(
                    math.log(total) / (a.lat_w + 1.0))
                return max(0.0, a.p99_ms - bonus)
            explore = False
        else:
            def rec_hat(a: _Arm) -> float:
                return a.recall

            def p99_hat(a: _Arm) -> float:
                return a.p99_ms
            explore = bool(self._rng.random() < cfg.epsilon)
        feas = [(kn, a) for kn, a in arms
                if rec_hat(a) + cfg.recall_slack >= target]
        if not feas:
            # nothing clears the recall target: serve the best recall we
            # have and say so — the caller sheds/flags on conflict
            kn_g, _ = max(arms, key=lambda it: (rec_hat(it[1]),
                                                -p99_hat(it[1])))
            conflict = True
        elif budget is not None:
            in_budget = [(kn, a) for kn, a in feas if p99_hat(a) <= budget]
            if in_budget:
                kn_g, _ = min(in_budget, key=lambda it: p99_hat(it[1]))
                conflict = False
            else:
                # recall is reachable but not inside the budget: keep the
                # recall contract, flag the conflict (as the static tree)
                kn_g, _ = min(feas, key=lambda it: p99_hat(it[1]))
                conflict = True
        else:
            kn_g, _ = min(feas, key=lambda it: p99_hat(it[1]))
            conflict = False
        if explore:
            # GUARDED shadow exploration: the explored arm runs off the
            # client path, but it still occupies the dispatcher, so only
            # arms that could plausibly dethrone the greedy pick are worth
            # paying for — fitted p99 within 2x of it (or inside the
            # budget), and either already near the recall target or still
            # evidence-thin (epoch decay re-opens arms for re-exploration
            # after the data shifts)
            cap = 2.0 * p99_hat(prof.arms[kn_g])
            if budget is not None:
                cap = max(cap, budget)
            cands = [kn for kn, a in arms
                     if kn != kn_g and p99_hat(a) <= cap
                     and (rec_hat(a) + cfg.recall_slack
                          + cfg.explore_bonus
                          / math.sqrt(max(a.recall_w, 1.0)) >= target
                          or a.recall_w < cfg.probe_min_weight)]
            if cands:
                kn = cands[int(self._rng.integers(len(cands)))]
                return kn_g, kn, True, conflict
        return kn_g, None, False, conflict

    def _trace_locked(self, kind: str, epoch: int, key: WorkloadKey,
                      knobs: Knobs, **extra) -> int:
        seq = self._seq
        self._seq += 1
        entry = {
            "schema": DECISION_SCHEMA, "seq": seq, "kind": kind,
            "epoch": int(epoch), "tier": knobs.tier,
            "n_blocks": int(knobs.n_blocks),
            "key": {
                "target_recall": key.target_recall,
                "latency_budget_ms": key.latency_budget_ms,
                "k": key.k, "window_bucket": key.window_bucket,
                "batch_rung": key.batch_rung,
            },
        }
        entry.update(extra)
        self._trace.append(entry)
        return seq

    # ------------------------------------------------------------- deciding
    def decide(self, key: WorkloadKey, *, epoch: int,
               n_series: int) -> DecisionRecord:
        """Choose knobs for one request of shape ``key`` under registry
        ``epoch`` with ``n_series`` live entries (prior input only)."""
        with self._lock:
            prof = self._profile_locked(key, n_series)
            self._refit_epoch_locked(prof, epoch)
            prof.decisions += 1
            self.stats["decisions"] += 1
            if self.cfg.forced is not None:
                knobs, shadow, explore, conflict = (self.cfg.forced, None,
                                                    False, False)
                if knobs not in prof.arms:
                    prof.arms[knobs] = _Arm(1.0, 1.0 if knobs.tier == "exact"
                                            else _approx_recall_model(
                                                max(1, knobs.n_blocks)),
                                            self.cfg.prior_weight)
            elif self._is_strict(key):
                knobs, shadow, explore, conflict = (self._arms[0], None,
                                                    False, False)
            else:
                knobs, shadow, explore, conflict = self._pick_locked(prof,
                                                                     key)
            if explore:
                self.stats["explores"] += 1
            arm = prof.arms[knobs]
            extra = {}
            if shadow is not None:
                extra = {"shadow_tier": shadow.tier,
                         "shadow_n_blocks": shadow.n_blocks}
            seq = self._trace_locked(
                "decide", epoch, key, knobs, explore=explore,
                conflict=conflict,
                predicted_recall=round(arm.recall, 4),
                predicted_p99_ms=round(arm.p99_ms, 4), **extra)
            return DecisionRecord(
                DECISION_SCHEMA, seq, int(epoch), key, knobs, explore,
                conflict, arm.recall, arm.p99_ms, shadow)

    # ------------------------------------------------------------ observing
    def observe(self, key: WorkloadKey, knobs: Knobs, *, lat_ms: float,
                epoch: int, recall: Optional[float] = None,
                n_series: int = 0, served: bool = True) -> None:
        """Fold one measured outcome into ``key``'s model for ``knobs``.

        ``lat_ms`` is the sub-batch service latency; ``recall`` is the
        shadow-probed recall@k vs exact (None when unprobed — only the
        latency model updates). ``served=False`` marks shadow-exploration
        measurements of arms the client was *not* served — they train the
        model identically but are excluded when consumers score
        client-facing quality from the trace. Arms outside the configured
        grid (e.g. the gateway's SLO-shed override) are admitted lazily
        with priors."""
        with self._lock:
            prof = self._profile_locked(key, n_series)
            self._refit_epoch_locked(prof, epoch)
            arm = prof.arms.get(knobs)
            if arm is None:
                rec0 = (1.0 if knobs.tier == "exact"
                        else _approx_recall_model(max(1, knobs.n_blocks)))
                arm = prof.arms[knobs] = _Arm(max(lat_ms, 1e-3), rec0,
                                              self.cfg.prior_weight)
            arm.observe_latency(float(lat_ms), self.cfg.forget)
            if recall is not None:
                arm.observe_recall(float(np.clip(recall, 0.0, 1.0)),
                                   self.cfg.forget)
            self.stats["observations"] += 1
            self._trace_locked(
                "observe", epoch, key, knobs,
                observed_lat_ms=round(float(lat_ms), 4),
                observed_recall=(None if recall is None
                                 else round(float(np.clip(recall, 0.0, 1.0)),
                                            4)),
                served=bool(served))

    def should_probe(self, key: WorkloadKey, knobs: Knobs) -> bool:
        """Whether this serving should pay an exact shadow query to measure
        recall: always while the arm's recall evidence is thin, then a
        seeded ``probe_frac`` coin."""
        with self._lock:
            prof = self._profiles.get(key)
            arm = prof.arms.get(knobs) if prof is not None else None
            if arm is None or arm.recall_w < self.cfg.probe_min_weight:
                probe = True
            else:
                probe = bool(self._rng.random() < self.cfg.probe_frac)
            if probe:
                self.stats["probes"] += 1
            return probe

    # ------------------------------------------------------------ reporting
    def trace(self) -> List[dict]:
        """Copy of the bounded decision/observation trace (oldest first).
        Schema: see ``DECISION_SCHEMA`` and CONTRIBUTING 'Recommender &
        autotuning' — CI asserts monotone seq/epoch, legal knob values,
        observed recall in [0, 1]."""
        with self._lock:
            return [dict(e) for e in self._trace]

    def counters(self) -> dict:
        with self._lock:
            return dict(self.stats)

    def snapshot(self) -> dict:
        """Fitted-model snapshot: counters + per-profile per-arm estimates
        (JSON-able, for serve logs and BENCH artifacts)."""
        with self._lock:
            profiles = {}
            for key, prof in self._profiles.items():
                label = (f"tr={key.target_recall},lb={key.latency_budget_ms},"
                         f"k={key.k},w={key.window_bucket},b={key.batch_rung}")
                profiles[label] = {
                    kn.label(): {
                        "lat_ms": round(a.lat_ms, 4),
                        "p99_ms": round(a.p99_ms, 4),
                        "recall": round(a.recall, 4),
                        "lat_w": round(a.lat_w, 2),
                        "recall_w": round(a.recall_w, 2),
                    }
                    for kn, a in prof.arms.items()
                }
                profiles[label]["_decisions"] = prof.decisions
                profiles[label]["_last_epoch"] = prof.last_epoch
            return {**self.stats, "profiles": profiles}

    def advise_global(self, lag: Optional[dict] = None, *,
                      n_series: int = 0) -> Tuple[RationaleEntry, ...]:
        """Deployment-scoped knob advice (``ingest`` mode, ``shard``) from
        the live telemetry the per-request bandit cannot act on. Advisory
        only: these knobs need a restart/config change, so the tuner
        surfaces structured rationale instead of flipping them."""
        out: List[RationaleEntry] = []
        if lag:
            lagging = (lag.get("lag_entries", 0) > 0
                       and lag.get("runs_pending_merge", 0) > 0)
            if lagging:
                out.append(RationaleEntry(
                    "advise/ingest-async",
                    f"ingest lag {lag.get('lag_entries', 0)} entries with "
                    f"{lag.get('runs_pending_merge', 0)} runs pending merge "
                    "-> run ingest=async so compaction leaves the serving "
                    "thread"))
            else:
                out.append(RationaleEntry(
                    "advise/ingest-ok",
                    "ingest keeps up with the stream; sync ingest avoids "
                    "the background worker"))
        if n_series >= 1 << 20:
            out.append(RationaleEntry(
                "advise/shard-mesh",
                f"{n_series} live entries -> exact-tier scans benefit from "
                "shard='mesh' (queries x runs shard_map, answers bitwise "
                "equal)"))
        return tuple(out)
