"""Background ingest pipeline — flush/merge work off the query path.

The paper's streaming headline is that the sortable format lets an
LSM-style index absorb new series with sequential writes *while* continuing
to answer queries — the classic LSM write/read overlap (O'Neil et al.).
:class:`IngestPipeline` supplies the "while": ingest submission becomes a
buffer append plus a worker wake-up, and the expensive half of ingestion —
external-sorting a flush into a level-0 run, cascading tiered merges — runs
on a single background worker that publishes every new or merged run
through the CLSM's :class:`repro.core.run_registry.RunRegistry`. Queries
keep planning from the previous snapshot and flip to the new one at the
next epoch read; nothing on the query path ever waits for compaction.

Single-writer discipline: exactly one worker mutates the run set (plus the
caller thread's buffer appends, which are registry-atomic), so flushes and
merges never race each other and ``publish_merge`` victims are always
present. Queries are pure snapshot readers.

Worker failures are latched and re-raised on the submitting thread at the
next ``insert``/``drain``/``close`` so they cannot vanish silently.
"""
from __future__ import annotations

import threading
from typing import Optional

import numpy as np

from .clsm import CLSM
from .run_registry import BufferChunk


class IngestPipeline:
    """Moves a CLSM's flush/external-sort/merge work onto a worker thread.

    ``insert`` is cheap (one registry buffer append); the worker drains the
    buffer into level-0 runs and runs the cascading merges, publishing each
    step atomically. ``max_lag_entries`` is the backpressure knob: when the
    unflushed backlog (buffer + in-flight flushes) exceeds it, ``insert``
    blocks until the worker catches up — bounding memory without ever
    blocking *queries*."""

    def __init__(self, lsm: CLSM, *, max_lag_entries: Optional[int] = None):
        if (max_lag_entries is not None
                and max_lag_entries < lsm.cfg.buffer_entries):
            # below the flush threshold the worker would never flush while
            # insert() waits for a backlog it cannot shrink: a deadlock
            raise ValueError(
                f"max_lag_entries ({max_lag_entries}) must be >= "
                f"buffer_entries ({lsm.cfg.buffer_entries}): backpressure "
                "can only release once the worker's flush threshold is "
                "reachable")
        self.lsm = lsm
        self.max_lag_entries = max_lag_entries
        self._cond = threading.Condition()
        self._stop = False
        self._busy = False  # worker is mid-flush (entries in flight)
        self._done = False  # worker has exited (nothing will flush anymore)
        self._flush_all = False
        self._error: Optional[BaseException] = None
        self._worker = threading.Thread(target=self._run, name="coconut-ingest",
                                        daemon=True)
        self._worker.start()

    # ---------------------------------------------------------- submitting
    def _raise_pending(self) -> None:
        # Condition's default lock is an RLock, so this nests safely under
        # callers (drain) that already hold the cond
        with self._cond:
            if self._error is None:
                return
            err, self._error = self._error, None
        raise RuntimeError("ingest worker failed") from err

    def insert(self, series: np.ndarray, ids: np.ndarray,
               ts: np.ndarray) -> None:
        """Submit one ingest batch: append to the registry buffer and wake
        the worker. Returns as soon as the batch is query-visible. Raises
        once the pipeline is closed or its worker has died — data must not
        silently pile into a buffer nothing will ever flush."""
        self._raise_pending()
        if self._stop:
            raise RuntimeError("ingest pipeline is closed (no worker will "
                               "flush this data)")
        chunk = BufferChunk(
            series=np.asarray(series, np.float32),
            ids=np.asarray(ids, np.int64),
            ts=np.asarray(ts, np.int64),
        )
        self.lsm.append_chunk(chunk)
        with self._cond:
            self._cond.notify_all()
            if self.max_lag_entries is not None:
                # a close() mid-wait still drains: wake on _done (worker
                # exited), not on _stop alone, so a closing worker gets to
                # shrink the backlog before we judge it stranded
                self._cond.wait_for(
                    lambda: self._done or self._error is not None
                    or self._backlog() <= self.max_lag_entries)
                if (self._error is None and self._done
                        and self._backlog() > self.max_lag_entries):
                    # the worker exited while this insert waited on
                    # backpressure: its data sits in a buffer nothing will
                    # ever flush — fail loudly instead of returning success
                    raise RuntimeError(
                        "ingest pipeline is closed (no worker will flush "
                        "this data)")
        self._raise_pending()

    def _backlog(self) -> int:
        snap = self.lsm.registry.current()
        return snap.buffer_n + snap.flushing_n

    def _work_available(self) -> bool:
        snap = self.lsm.registry.current()
        pending = snap.buffer_n >= self.lsm.cfg.buffer_entries
        return pending or (self._flush_all and snap.buffer_n > 0)

    # ------------------------------------------------------------- worker
    def _run(self) -> None:
        while True:
            with self._cond:
                self._cond.wait_for(lambda: self._stop or self._work_available())
                if self._stop and not self._work_available():
                    self._done = True
                    self._cond.notify_all()
                    return
                self._busy = True
            try:
                # one flush (+ its cascading merges) per loop turn so stop/
                # drain requests are observed between publishes
                self.lsm._flush()
            except BaseException as e:  # noqa: BLE001 - latched for callers
                with self._cond:
                    self._error = e
                    self._stop = True
                    self._busy = False
                    self._done = True
                    self._cond.notify_all()
                return
            with self._cond:
                self._busy = False
                self._cond.notify_all()  # backpressure + drain waiters

    # ----------------------------------------------------------- draining
    def drain(self, *, flush_buffer: bool = False,
              timeout: Optional[float] = None) -> bool:
        """Block until the worker has no pending work. With
        ``flush_buffer=True`` the remaining (sub-threshold) buffer is
        flushed too, so every ingested entry ends up in a published run.
        Returns False on timeout."""
        with self._cond:
            self._raise_pending()
            if flush_buffer:
                self._flush_all = True
                self._cond.notify_all()

            def _settled() -> bool:
                if self._error is not None:
                    return True
                if self._work_available() or self._busy:
                    return False
                # a flush_buffer drain is only done once the buffer really
                # emptied — the idle gap between worker turns is not enough
                return not (flush_buffer
                            and self.lsm.registry.current().buffer_n > 0)

            ok = self._cond.wait_for(_settled, timeout=timeout)
            # only the drain that requested the full flush may clear the
            # flag, and only once it was honored — a concurrent plain
            # drain() clearing it would strand this one's request
            if flush_buffer and ok and self._error is None:
                self._flush_all = False
            self._raise_pending()
            return bool(ok)

    def close(self, *, timeout: Optional[float] = 30.0) -> None:
        """Drain pending work and stop the worker (idempotent).

        "Drain" includes the sub-threshold buffer remainder: ``_flush_all``
        is raised together with ``_stop``, so the worker flushes everything
        still buffered before exiting — no ingested entry is stranded in a
        buffer nothing will ever flush."""
        with self._cond:
            self._flush_all = True
            self._stop = True
            self._cond.notify_all()
        self._worker.join(timeout=timeout)
        self._raise_pending()

    @property
    def running(self) -> bool:
        return self._worker.is_alive()
