"""Declarative query plans — candidate generation separated from execution.

Every Coconut index variant answers every query through the same physical
recipe (the paper's sortable-summarization claim): seek into sorted keys,
read sequential block ranges, verify candidates against a best-so-far
radius. This module makes that recipe explicit: each index *plans* a query
(which entries could matter, at what lower bound, under which window
predicate) and :mod:`repro.core.execute` *runs* the plan (coalesced reads,
the shared f32-screen + f64 re-rank verification passes, (m, k) state
folding). Adding a new index or serving tier means writing a plan builder,
not a fifth copy of the traversal loop.

A :class:`QueryPlan` is an ordered list of candidate sources (newest first,
so verified distances from recent data prune older sources) plus the
window predicate and run-level skip semantics as data:

* :class:`DenseSource`  — verify everything (in-memory buffers, pending
  gap inserts). No pruning structure, no stats/IO accounting by design.
* :class:`BlockSource`  — block-structured exact traversal: per-(query,
  block) lower bounds from zone maps, adaptive best-first verification,
  optional :attr:`BlockSource.refine` for ADS+'s query-time leaf splits.
* :class:`RangeSource`  — the approximate tier on a sorted run: per-query
  contiguous entry spans around the sortable-key seek position, coalesced
  into deduplicated sequential reads.
* :class:`GroupSource`  — the approximate tier on a leaf-partitioned tree
  (ADS+): explicit (query-group, candidate-positions) pairs, one shared
  verification per distinct leaf.

PP / TP / BTP map onto plan flags instead of run mutation: ``time_skip``
decides at *plan build* whether a run whose [t_min, t_max] misses the
window is dropped (TP/BTP) or planned anyway with entry-level filtering
(PP). Skipped runs are recorded in :attr:`QueryPlan.pruned_blocks` so the
executor can keep the per-query logical accounting.

Physical access is abstracted behind :class:`SourceOps` closures so the
executor stays storage-agnostic: ``fetch`` returns raw series for entry
positions (modeled I/O accounted by the closure), ``index_read`` accounts
index-entry reads, ``norms2`` serves cached squared norms for the
screen-without-recompute fast path. The device accessors
(``device_view``/``table_rows``/``table_ids``/``fetch_account``) expose
the source's table to the default device verification backend
(:mod:`repro.core.verify_engine`) without the executor ever touching jax:
the arena handle, the position->table-row map, the row->global-id map,
and modeled-I/O accounting for passes that never materialize on the host.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import numpy as np

from .summarization import SummarizationConfig


@dataclasses.dataclass
class QueryStats:
    blocks_pruned: int = 0
    blocks_visited: int = 0
    entries_pruned: int = 0
    entries_verified: int = 0

    def merge(self, o: "QueryStats") -> "QueryStats":
        return QueryStats(
            self.blocks_pruned + o.blocks_pruned,
            self.blocks_visited + o.blocks_visited,
            self.entries_pruned + o.entries_pruned,
            self.entries_verified + o.entries_verified,
        )


@dataclasses.dataclass
class SourceOps:
    """Physical accessors for one candidate source (all I/O accounted by
    the closures, so the executor never sees a DiskModel)."""

    ids: np.ndarray  # (N,) global ids, aligned with entry positions
    ts: Optional[np.ndarray] = None  # (N,) timestamps (window filtering)
    # positions -> (U, series_len) f32 raw series; models its own I/O
    fetch: Optional[Callable[[np.ndarray], np.ndarray]] = None
    # account reading the index entries (keys+sax) at these positions
    index_read: Optional[Callable[[np.ndarray], None]] = None
    # entry-level lower-bound screen inputs (exact traversal)
    sax: Optional[np.ndarray] = None  # (N, w) SAX symbols
    scfg: Optional[SummarizationConfig] = None
    # cached |x|^2 per position (approximate-tier screen fast path)
    norms2: Optional[Callable[[np.ndarray], np.ndarray]] = None
    # contiguous materialized storage: zero-copy views for dense spans
    series: Optional[np.ndarray] = None
    # --- device-resident verification (the executor's "device" backend) ---
    # lazy handle to the source's device arena (a verify_engine.DeviceView,
    # cached by the data owner so the table uploads once per lifetime)
    device_view: Optional[Callable[[], object]] = None
    # entry positions -> row indices into the arena's table (identity for
    # materialized runs; the raw-store id map for non-materialized ones)
    table_rows: Optional[Callable[[np.ndarray], np.ndarray]] = None
    # arena table rows -> global series ids (the inverse answer mapping)
    table_ids: Optional[Callable[[np.ndarray], np.ndarray]] = None
    # modeled-I/O accounting of fetching these positions WITHOUT the host
    # gather — the device path reads the arena, not the store, but pays
    # the same modeled I/O as the host engine so stats stay comparable
    fetch_account: Optional[Callable[[np.ndarray], None]] = None
    # async readahead of coalesced [lo, hi) row spans (file-backed runs
    # hand them to the readahead pool); advisory — answers never depend on it
    prefetch_ranges: Optional[Callable[[List[Tuple[int, int]]], None]] = None
    # the storage dtype of the arena behind device_view (f32|bf16|int8;
    # None = the engine default). Informational: the arena itself carries
    # the authoritative dtype, this mirrors it into plans for introspection
    screen_dtype: Optional[str] = None


@dataclasses.dataclass
class DenseSource:
    """Brute-force a small entry set (write buffer, gap-absorbed inserts).

    Mirrors the pre-plan ``_buffer_scan``/``_pending_scan`` semantics:
    no stats and no modeled I/O beyond what ``fetch`` itself accounts."""

    ops: SourceOps
    n: int


@dataclasses.dataclass
class BlockSource:
    """Exact adaptive traversal over lower-bounded entry blocks."""

    ops: SourceOps
    lb: np.ndarray  # (m, nb) per-(query, block) lower bounds
    blocks: List[np.ndarray]  # per-block entry positions
    # adaptive refinement (ADS+): called when block b is selected for
    # verification; returns replacement [(lb_col (m,), positions), ...] or
    # None to verify the block as-is. Replaced blocks are never verified.
    refine: Optional[Callable[[int], Optional[List[Tuple[np.ndarray, np.ndarray]]]]] = None


@dataclasses.dataclass
class RangeSource:
    """Approximate tier over a sorted run: per-query contiguous spans."""

    ops: SourceOps
    spans: np.ndarray  # (m, 2) per-query [lo, hi) entry spans
    logical_blocks: int = 0  # per-(query, block) logical work for stats
    # account the coalesced sequential index read / materialized payload
    read_index_ranges: Optional[Callable[[List[Tuple[int, int]]], None]] = None
    read_payload_ranges: Optional[Callable[[List[Tuple[int, int]]], None]] = None


@dataclasses.dataclass
class GroupSource:
    """Approximate tier over a leaf-partitioned tree (ADS+)."""

    ops: SourceOps
    groups: List[Tuple[np.ndarray, np.ndarray]]  # (query rows, positions)
    group_reads: Optional[List[Callable[[], None]]] = None  # per-group leaf read
    pre_read: Optional[Callable[[], None]] = None  # tree-descent page touches


@dataclasses.dataclass
class QueryPlan:
    """An ordered, declarative description of one (batched) query."""

    m: int  # query batch size
    sources: list  # newest-first: Dense/Block/Range/Group sources
    window: Optional[Tuple[int, int]] = None  # inclusive [t0, t1] predicate
    time_skip: bool = True  # run-level temporal skip applied at build (TP/BTP)
    pruned_blocks: int = 0  # blocks of runs skipped at plan time (per query)
    # the run-registry epoch the plan was built against (None = the source
    # index is not registry-backed). Sources resolve against that pinned
    # snapshot, so the plan stays well-defined under concurrent ingest.
    epoch: Optional[int] = None


def window_mask(ts: Optional[np.ndarray], window: Optional[Tuple[int, int]],
                positions: np.ndarray) -> Optional[np.ndarray]:
    """Boolean in-window mask for entry ``positions`` (None = keep all)."""
    if window is None or ts is None:
        return None
    t = ts[positions]
    return (t >= window[0]) & (t <= window[1])


def run_time_skipped(t_min: int, t_max: int,
                     window: Optional[Tuple[int, int]],
                     time_skip: bool) -> bool:
    """Run-level temporal skip decision — the plan-flag form of PP/TP/BTP:
    under PP (``time_skip=False``) a run is never skipped, only its entries
    are filtered; under TP/BTP a run whose time range misses the window
    drops out of the plan entirely."""
    return bool(time_skip and window is not None
                and (t_max < window[0] or t_min > window[1]))
