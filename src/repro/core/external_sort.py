"""Memory-budgeted two-pass external sort — how Coconut builds indexes.

Pass 1 splits the input into memory-budget-sized chunks, sorts each with an
in-memory sort and writes a sorted run (all sequential I/O). Pass 2 merges
the runs with k open sequential cursors into the final sorted order (again
sequential). Contrast with top-down insertion (ADS+ baseline): one random
page read+write per insert.

The byte/pass accounting follows the real streaming algorithm; the in-memory
``np.lexsort`` over run keys stands in for the k-way cursor merge (keys are
16 bytes/entry, so even a billion-entry merge holds keys in RAM — the paper
budget concerns the 1KB series payloads, which here are only *moved* in run
order, i.e. sequentially per run).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .io_model import DiskModel
from .sortable import lexsort_keys


@dataclasses.dataclass
class SortReport:
    n_entries: int
    n_runs: int
    n_passes: int
    mem_budget_entries: int


def external_sort_order(
    keys: np.ndarray,
    mem_budget_entries: int,
    disk: DiskModel | None = None,
    payload_bytes_per_entry: int = 0,
) -> tuple[np.ndarray, SortReport]:
    """Return the permutation sorting ``keys`` (N, n_words uint32) lexico-
    graphically, with I/O accounted for a two-pass external sort under the
    given memory budget (entries)."""
    n = keys.shape[0]
    m = max(1, int(mem_budget_entries))
    n_runs = max(1, math.ceil(n / m))
    entry_bytes = keys.shape[1] * 4 + payload_bytes_per_entry

    orders = []
    for r in range(n_runs):
        lo, hi = r * m, min(n, (r + 1) * m)
        o = lexsort_keys(keys[lo:hi])
        orders.append(o + lo)
        if disk is not None:
            disk.read_seq((hi - lo) * entry_bytes, offset=lo * entry_bytes)
            disk.write_seq((hi - lo) * entry_bytes, offset=lo * entry_bytes)

    if n_runs == 1:
        return orders[0], SortReport(n, 1, 1, m)

    # merge pass: k-way sequential merge of the sorted runs
    run_order = np.concatenate(orders)
    merged = lexsort_keys(keys[run_order])
    if disk is not None:
        disk.read_seq(n * entry_bytes)
        disk.write_seq(n * entry_bytes)
    return run_order[merged], SortReport(n, n_runs, 2, m)
