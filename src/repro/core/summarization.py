"""PAA + SAX summarization of data series.

The paper's substrate: every series of length ``n`` is summarized by
Piecewise Aggregate Approximation (PAA) into ``w`` segment means, then each
segment mean is quantized into a 2**c-ary SAX symbol using breakpoints that
equi-partition the standard normal distribution (the iSAX convention).

All functions are pure and have both a numpy path (host storage engine) and
a jnp path (device / Pallas-backed); numpy is the default inside the index
structures, jnp inside ``core.distributed`` and ``kernels``.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax.numpy as jnp


def _ndtri(p: np.ndarray) -> np.ndarray:
    """Inverse standard-normal CDF (Acklam's rational approximation, ~1e-9
    relative error — ample for SAX breakpoints). Pure numpy so breakpoint
    tables stay concrete even when requested inside a jit trace."""
    a = [-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
         1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00]
    b = [-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
         6.680131188771972e01, -1.328068155288572e01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
         -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
         3.754408661907416e00]
    p = np.asarray(p, dtype=np.float64)
    x = np.empty_like(p)
    plow, phigh = 0.02425, 1 - 0.02425
    lo = p < plow
    hi = p > phigh
    mid = ~(lo | hi)
    if lo.any():
        q = np.sqrt(-2 * np.log(p[lo]))
        x[lo] = (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / (
            (((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1
        )
    if hi.any():
        q = np.sqrt(-2 * np.log(1 - p[hi]))
        x[hi] = -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / (
            (((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1
        )
    if mid.any():
        q = p[mid] - 0.5
        r = q * q
        x[mid] = (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q / (
            ((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1
        )
    return x


@dataclasses.dataclass(frozen=True)
class SummarizationConfig:
    """Configuration of the PAA/SAX summarization.

    series_len: length n of each data series (must be divisible by n_segments)
    n_segments: number of PAA segments w
    card_bits:  bits per SAX symbol c (cardinality 2**c)
    znorm:      z-normalize each series before summarizing (iSAX convention)
    """

    series_len: int = 256
    n_segments: int = 16
    card_bits: int = 8
    znorm: bool = False

    def __post_init__(self):
        if self.series_len % self.n_segments != 0:
            raise ValueError(
                f"series_len {self.series_len} not divisible by n_segments {self.n_segments}"
            )
        if not (1 <= self.card_bits <= 8):
            raise ValueError("card_bits must be in [1, 8]")

    @property
    def cardinality(self) -> int:
        return 1 << self.card_bits

    @property
    def segment_len(self) -> int:
        return self.series_len // self.n_segments

    @property
    def key_bits(self) -> int:
        return self.n_segments * self.card_bits

    @property
    def key_words(self) -> int:
        """Number of uint32 words per sortable key."""
        return (self.key_bits + 31) // 32


@functools.lru_cache(maxsize=32)
def breakpoints(card_bits: int) -> np.ndarray:
    """The 2**c - 1 breakpoints equi-partitioning N(0, 1).

    Symbol s covers the region [bp[s-1], bp[s]) with bp[-1] = -inf and
    bp[2**c - 1] = +inf.
    """
    card = 1 << card_bits
    qs = np.arange(1, card) / card
    return _ndtri(qs).astype(np.float32)


def znormalize(x: np.ndarray, eps: float = 1e-6) -> np.ndarray:
    mu = x.mean(axis=-1, keepdims=True)
    sd = x.std(axis=-1, keepdims=True)
    return (x - mu) / (sd + eps)


def paa(x: np.ndarray, cfg: SummarizationConfig) -> np.ndarray:
    """PAA segment means. x: (..., n) -> (..., w)."""
    xp = jnp if isinstance(x, jnp.ndarray) else np
    if cfg.znorm:
        x = znormalize(x) if xp is np else (x - x.mean(-1, keepdims=True)) / (
            x.std(-1, keepdims=True) + 1e-6
        )
    shape = x.shape[:-1] + (cfg.n_segments, cfg.segment_len)
    return x.reshape(shape).mean(axis=-1)


def sax_from_paa(p: np.ndarray, cfg: SummarizationConfig) -> np.ndarray:
    """Quantize PAA values into SAX symbols in [0, 2**c). p: (..., w)."""
    bps = breakpoints(cfg.card_bits)
    if isinstance(p, jnp.ndarray):
        # symbol = number of breakpoints <= value
        return jnp.sum(p[..., None] >= jnp.asarray(bps), axis=-1).astype(jnp.int32)
    return np.searchsorted(bps, p, side="right").astype(np.int32)


def sax(x: np.ndarray, cfg: SummarizationConfig) -> np.ndarray:
    """Full pipeline: series (..., n) -> SAX symbols (..., w)."""
    return sax_from_paa(paa(x, cfg), cfg)


def sax_region(sym: np.ndarray, cfg: SummarizationConfig):
    """Region [lb, ub] per SAX symbol. sym: (..., w) int -> (lb, ub) float32.

    Uses +-1e30 instead of inf so downstream squared arithmetic stays finite
    after the max(0, .) clamp.
    """
    bps = breakpoints(cfg.card_bits)
    big = np.float32(1e30)
    lo = np.concatenate([[-big], bps]).astype(np.float32)
    hi = np.concatenate([bps, [big]]).astype(np.float32)
    if isinstance(sym, jnp.ndarray):
        lo, hi = jnp.asarray(lo), jnp.asarray(hi)
        return lo[sym], hi[sym]
    return lo[sym], hi[sym]
