"""The device-resident verification engine — the executor's default backend.

Candidate verification used to be host-bound: every pass re-screened its
candidates with NumPy einsums and ``argpartition`` and round-tripped the
gathered series between host and device. This module keeps the heavy half
of verification resident on the accelerator, the way hardware-conscious
exact-search engines (ParIS+/MESSI) keep their distance/select pipeline on
the compute units:

* **Device arenas** (:class:`DeviceView`): each verifiable table (a
  materialized run, the raw store, an ADS+ leaf space) is uploaded ONCE —
  centered by its mean (squared ED is translation-invariant, and centering
  kills the ``|x|^2 - 2<q, x>`` f32 cancellation) — together with cached
  centered squared norms. Capacities are power-of-two buckets with a
  sentinel tail, so growing stores extend in place with one donated
  ``dynamic_update_slice`` instead of a re-upload, and gather shapes stay
  stable.
* **Mixed-precision storage tier**: an arena's *storage* dtype is
  independent of its *compute* dtype. Tables are optionally quantized to
  **bf16** (half the h2d traffic and footprint) or **int8 with per-row
  scales** (a quarter), selected per view (``build_view(dtype=...)``), per
  engine (``VerifyEngine(dtype=...)``), or process-wide via the
  ``REPRO_SCREEN_DTYPE`` env var. Screens always upcast tiles to f32
  in-register; the host mirror keeps the original f32 rows, so the f64
  re-rank — and therefore the answers — never see quantized data.
* **Fused screen+select**: a verification pass is one jitted call — device
  gather of the pass's candidate rows, f32 matmul-form screen against the
  cached norms, in-kernel top-k slate selection, and the error-bound
  certificate terms — dispatched to the :func:`screen_select_pallas`
  kernel on TPU and to its XLA twin elsewhere (the same compiled/interpret
  split as ``kernels.ops``; interpret-mode Pallas is a validation tool,
  not a serving path). Only the tiny slate crosses back to the host.
* **Shape-bucketed compile cache**: candidate counts and query-batch sizes
  pad to power-of-two buckets, so steady-state serving executes from a
  handful of cached traces with ZERO retraces after warm-up. The engine
  counts traces/hits, host<->device transfer bytes, and the live arena
  footprint/storage dtype (:attr:`VerifyEngine.stats`), and
  :meth:`VerifyEngine.prewarm` compiles the bucket ladder up front.

Exactness contract: the f32 screen's only error sources are the matmul
cross-product, bounded by the classical ``4 n u |q||x|`` term, and — for
quantized arenas — the storage rounding ``x_stored = x + e`` with
``|e| <= qerr`` (the measured worst per-row quantization residual), which
can move a screened distance by at most ``2 (|q| + |x|) qerr``. After the
host re-ranks the slate in f64 (the diff form, immune to cancellation,
always against the exact f32 mirror), a query is *certified* iff its kth
exact distance clears the slate's worst screen distance by twice the
summed bound — anything the screen could have mis-ranked out of the slate
provably cannot beat the kth answer. Queries that fail certification
(adversarially conditioned data, or quantization-coarse arenas) fall back
to the provably exact host screen, so the device path returns the same
answers as the retained host engine on every input and every storage
dtype. This is the same certify-or-fallback pattern as PRs 3/4.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..kernels import ops as kops

# passes smaller than this verify on the host: below the floor the launch
# overhead rivals the whole NumPy screen, so the device path would lose
# (the same trade the entry-level MINDIST screen makes). Answers are
# identical either way — both tails are exact.
MIN_DEVICE_CANDIDATES = 1024

# batches at or below this stay on the host tail: measured on this class of
# hardware, the BLAS sgemv screen beats the fused device pass until the
# batch amortizes the launch — the same m <= 8 boundary where the executor
# already switches traversal policy (entry-level MINDIST screen, one-block
# seed rounds). Small-batch serving amortizes via adaptive multi-block
# rounds instead.
MIN_DEVICE_BATCH = 9

_SLACK = 8  # slate slack beyond k: absorbs f32 near-tie reordering

# large query batches screen in chunks of this many rows: the (chunk, B)
# distance tile then stays cache-resident instead of streaming a
# batch-sized matrix through memory — measured ~1.8x on the big union
# passes — and caps the batch-bucket ladder at one trace per chunk shape
_CHUNK_M = 64

# traced-once counter: the increment runs while jax traces the fused call,
# so it counts actual retraces — not python-side cache bookkeeping
_TRACES = [0]

# ----------------------------------------------------------- storage dtypes
# canonical storage-dtype names -> the numpy/jax dtype the arena holds.
# bf16 rides on jax's ml_dtypes-backed bfloat16 (a registered numpy dtype),
# so no extra dependency; int8 carries a per-row f32 scale alongside.
_SCREEN_DTYPES = {
    "f32": np.float32,
    "bf16": jnp.bfloat16,
    "int8": np.int8,
}
_DTYPE_ALIASES = {
    "f32": "f32", "float32": "f32", "fp32": "f32",
    "bf16": "bf16", "bfloat16": "bf16",
    "int8": "int8", "i8": "int8",
}


def resolve_screen_dtype(name: Optional[str] = None) -> str:
    """Canonicalize a storage-dtype selector.

    ``None``/``""``/``"auto"`` resolve through the ``REPRO_SCREEN_DTYPE``
    env var (default ``f32``) — the same env-flip pattern as
    ``REPRO_STORAGE``, so one CI leg re-runs the whole suite quantized."""
    if name in (None, "", "auto"):
        name = os.environ.get("REPRO_SCREEN_DTYPE", "f32") or "f32"
    canon = _DTYPE_ALIASES.get(str(name).lower())
    if canon is None:
        raise ValueError(
            f"unknown screen dtype {name!r}: expected f32 | bf16 | int8")
    return canon


def _quantize_rows(rows: np.ndarray, dtype: str):
    """Quantize centered f32 rows for arena storage.

    Returns ``(stored, scale, xn2, qerr)``: the stored array in the target
    dtype, the per-row f32 scales (int8 only, else ``None``), the squared
    norms of the *stored* values as f32 (so the screen is self-consistent
    with what the device actually holds), and ``qerr`` — the worst per-row
    L2 distance between stored and original values, measured exactly in
    f64. ``qerr`` is the certificate's quantization term; it is 0.0 for
    f32. Scales are per row (the finest "block" granularity) so the
    bucket-ladder extend path re-uses existing scales untouched."""
    r = rows.shape[0]
    if dtype == "f32":
        return rows, None, np.einsum("nd,nd->n", rows, rows), 0.0
    if dtype == "bf16":
        stored = rows.astype(jnp.bfloat16)
        scale = None
        deq = stored.astype(np.float64)
    else:  # int8: symmetric per-row scale, zero rows get scale 1
        amax = np.max(np.abs(rows), axis=1) if r else np.zeros(0, np.float32)
        scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
        stored = np.clip(
            np.rint(rows / scale[:, None]), -127, 127).astype(np.int8)
        deq = stored.astype(np.float64) * scale[:, None].astype(np.float64)
    xn2 = np.einsum("nd,nd->n", deq, deq).astype(np.float32)
    err = deq - rows.astype(np.float64)
    err2 = np.einsum("nd,nd->n", err, err)
    qerr = float(np.sqrt(err2.max())) if r else 0.0
    return stored, scale, xn2, qerr


@dataclasses.dataclass
class DeviceView:
    """One table's device arena: centered series + cached norms, bucketed
    capacity with a sentinel tail (row ``n`` is always a valid pad target).
    The stored table may be quantized (``dtype``); ``host`` is always the
    original f32 mirror the exact re-rank reads."""

    host: np.ndarray  # (N, d) original host mirror (exact re-rank source)
    mu: np.ndarray  # (d,) f32 centering offset (fixed for the arena's life)
    table: jax.Array  # (cap, d) centered, storage dtype; rows >= n are zero
    xn2: jax.Array  # (cap,) f32 stored |x|^2; rows >= n carry BIG_NORM2
    n: int  # valid rows
    cap: int  # power-of-two capacity, always >= n + 1
    xn2max: float  # max stored |x|^2 over valid rows (certificate term)
    dtype: str = "f32"  # arena storage dtype: f32 | bf16 | int8
    scale: Optional[jax.Array] = None  # (cap,) f32 per-row scales (int8)
    qerr: float = 0.0  # worst per-row quantization L2 error (certificate)
    nbytes: int = 0  # device footprint: table + norms + scales


# donation lets the extend update arenas in place; the CPU backend does not
# support donation and would warn on every call, so only donate off-host
_DONATE = () if jax.default_backend() == "cpu" else (0, 1)
_DONATE_Q = () if jax.default_backend() == "cpu" else (0, 1, 2)


@functools.partial(jax.jit, donate_argnums=_DONATE)
def _arena_extend(table, xn2, new_rows, new_xn2, start):
    """Write freshly appended (centered) rows into a donated arena. The
    update is dtype-generic: ``new_rows`` arrive pre-quantized in the
    arena's storage dtype (f32 or bf16)."""
    table = jax.lax.dynamic_update_slice(table, new_rows, (start, 0))
    xn2 = jax.lax.dynamic_update_slice(xn2, new_xn2, (start,))
    return table, xn2


@functools.partial(jax.jit, donate_argnums=_DONATE_Q)
def _arena_extend_quant(table, xn2, scale, new_rows, new_xn2, new_scale,
                        start):
    """The int8 extend: one donated update per buffer. Only the appended
    rows' scales are written — existing rows keep their scales (per-row
    granularity makes scale re-use trivial across bucket-ladder growth)."""
    table = jax.lax.dynamic_update_slice(table, new_rows, (start, 0))
    xn2 = jax.lax.dynamic_update_slice(xn2, new_xn2, (start,))
    scale = jax.lax.dynamic_update_slice(scale, new_scale, (start,))
    return table, xn2, scale


def _bucket_rows(n: int, lo: int = 64) -> int:
    """Candidate/row-count bucket: the {2^k, 3*2^(k-1)} ladder (min ``lo``).

    Half-octave steps cap the padded-work overhead at 33% (a pure
    power-of-two ladder wastes up to 2x on the big union passes) while
    keeping the trace count bounded — two shapes per octave."""
    n = max(lo, n)
    p2 = kops.candidate_bucket(n, lo)
    mid = 3 * (p2 // 4)
    return mid if n <= mid else p2


def _bucket_batch(m: int) -> int:
    """Power-of-two bucket (min 8) for query-batch sizes."""
    return kops.candidate_bucket(m, 8)


def _screen_core(sub, n2, qc, s, scale=None):
    """Shared screen+select: the fused Pallas kernel on TPU, its XLA twin
    elsewhere (interpret-mode Pallas is for kernel validation, not the
    serving hot path). ``sub`` may be f32/bf16/int8 — tiles upcast to f32
    in-register; int8 carries per-row ``scale`` applied after the matmul.
    Returns (slate vals, local rows). The kernel's f32 |q|^2 output is for
    TPU-resident consumers; the certificate's |q| term is recomputed
    host-side in f64 (the bound needs the precision)."""
    if not kops.INTERPRET:
        # TPU: ONE fused launch (screen + in-kernel top-k)
        if scale is None:
            vals, pidx, _ = kops.screen_select(qc, sub, n2, s)
        else:
            vals, pidx, _ = kops.screen_select_quant(qc, sub, scale, n2, s)
        return vals, pidx
    qn2 = jnp.sum(qc * qc, axis=1)
    g = qc @ sub.astype(jnp.float32).T  # in-register upcast: compute is f32
    if scale is not None:
        g = g * scale[None, :]  # dequantize the cross term per table row
    d2 = qn2[:, None] + n2[None, :] - 2.0 * g
    negv, pidx = jax.lax.top_k(-d2, s)  # ties -> lower candidate index
    return -negv, pidx


@functools.partial(jax.jit, static_argnames=("s",))
def _fused_screen(table, xn2, scale, rows, qc, s):
    """ONE device call per verification pass: gather the pass's candidate
    rows from the arena, screen them in f32 matmul form against the cached
    norms, and select the top-s slate in-kernel. Pad rows (index = the
    sentinel row) carry BIG_NORM2 and never enter a slate."""
    # trace-time-only execution is the POINT: the increment runs once per
    # retrace, which is exactly what the counter measures
    _TRACES[0] += 1  # palmlint: ignore[trace-safety] — deliberate retrace counter
    sub = jnp.take(table, rows, axis=0)  # (B, d) device gather
    n2 = jnp.take(xn2, rows)  # (B,) cached |x - mu|^2
    sc = None if scale is None else jnp.take(scale, rows)
    vals, pidx = _screen_core(sub, n2, qc, s, sc)
    return vals, jnp.take(rows, jnp.maximum(pidx, 0)), pidx < 0


@functools.partial(jax.jit, static_argnames=("s",))
def _fused_screen_full(table, xn2, scale, mask, qc, s):
    """The full-coverage variant: when a pass verifies (nearly) the whole
    table, screening the RESIDENT table beats gathering it — the matmul
    streams the arena directly and a (cap,) candidate mask (masked-out and
    sentinel rows get BIG_NORM2) replaces the 10s-of-MB row gather."""
    # trace-time-only execution is the POINT: the increment runs once per
    # retrace, which is exactly what the counter measures
    _TRACES[0] += 1  # palmlint: ignore[trace-safety] — deliberate retrace counter
    n2 = jnp.where(mask, xn2, kops.BIG_NORM2)
    vals, pidx = _screen_core(table, n2, qc, s, scale)
    return vals, pidx, pidx < 0


class VerifyEngine:
    """Process-wide verification engine: arenas + bucketed compile cache.

    ``dtype`` sets the default storage dtype for arenas built through this
    engine (``None`` resolves ``REPRO_SCREEN_DTYPE``); individual views can
    override it via ``build_view(dtype=...)``."""

    def __init__(self, dtype: Optional[str] = None):
        # serializes fused-pass bookkeeping (and the passes themselves)
        # across query threads: concurrent ingest serving may verify from a
        # thread pool, and the before/after _TRACES hit accounting is only
        # meaningful if launches do not interleave
        self._lock = threading.RLock()
        self.dtype = resolve_screen_dtype(dtype)
        self.stats = {
            "calls": 0,  # fused verification passes launched
            "screened": 0,  # queries through the device screen (per pass)
            "traces": 0,  # jit retraces of the fused pass (compile churn)
            "hits": 0,  # passes served from an already-compiled trace
            "h2d_bytes": 0,  # host->device: arena uploads + rows + queries
            "d2h_bytes": 0,  # device->host: downloaded slates
            "uploads": 0,  # arena builds/extends
            "fallbacks": 0,  # queries re-screened on host (cert failures)
            "released_arenas": 0,  # arenas retired by the run registry
            "released_bytes": 0,  # device bytes those arenas held
            "arena_bytes": 0,  # live device arena footprint (all dtypes)
            "arena_dtype": self.dtype,  # the engine's default storage dtype
            "batch_hist": {},  # served batch bucket -> pass count (monotonic)
        }

    # ------------------------------------------------------------- arenas
    def build_view(self, host_table: np.ndarray,
                   dtype: Optional[str] = None) -> DeviceView:
        """Upload a table into a fresh bucketed arena (one h2d copy),
        optionally quantized to the requested storage dtype."""
        sd = self.dtype if dtype in (None, "") else resolve_screen_dtype(dtype)
        host_table = np.ascontiguousarray(host_table, np.float32)
        n, d = host_table.shape
        cap = _bucket_rows(n + 1)
        mu = host_table.mean(axis=0).astype(np.float32) if n else np.zeros(
            d, np.float32)
        buf = np.zeros((cap, d), np.float32)
        np.subtract(host_table, mu[None, :], out=buf[:n])
        stored, rscale, vxn2, qerr = _quantize_rows(buf[:n], sd)
        if sd == "f32":
            tbl = buf  # zero tail already in place, no copy
        else:
            tbl = np.zeros((cap, d), _SCREEN_DTYPES[sd])
            tbl[:n] = stored
        xn2 = np.full(cap, kops.BIG_NORM2, np.float32)
        xn2[:n] = vxn2
        scale = None
        if rscale is not None:
            scale = np.ones(cap, np.float32)  # sentinel/pad rows: scale 1
            scale[:n] = rscale
        nbytes = tbl.nbytes + xn2.nbytes + (scale.nbytes if scale is not None
                                            else 0)
        view = DeviceView(
            host=host_table,
            mu=mu,
            table=jax.device_put(tbl),
            xn2=jax.device_put(xn2),
            n=n,
            cap=cap,
            xn2max=float(vxn2.max()) if n else 0.0,
            dtype=sd,
            scale=None if scale is None else jax.device_put(scale),
            qerr=qerr,
            nbytes=nbytes,
        )
        with self._lock:
            self.stats["uploads"] += 1
            self.stats["h2d_bytes"] += nbytes
            self.stats["arena_bytes"] += nbytes
        return view

    def extend_view(self, view: DeviceView, host_table: np.ndarray) -> DeviceView:
        """Grow an arena to cover an append-only table's new rows.

        While the new rows fit the bucketed capacity the old buffers are
        donated and updated in place (one small h2d copy of just the new
        rows, quantized to the arena's storage dtype and bucket-padded so
        steady streaming reuses one trace); overflowing arenas rebuild at
        the next bucket. Existing rows' int8 scales are never rewritten."""
        n_new = host_table.shape[0]
        if n_new <= view.n:
            return view
        grow = n_new - view.n
        pad = _bucket_rows(grow) - grow  # bucket the chunk: stable traces
        if n_new + pad + 1 > view.cap:
            nv = self.build_view(host_table, dtype=view.dtype)
            with self._lock:  # the overflowing arena is being replaced
                self.stats["arena_bytes"] -= view.nbytes
            return nv
        chunk = np.zeros((grow + pad, host_table.shape[1]), np.float32)
        np.subtract(host_table[view.n:], view.mu[None, :], out=chunk[:grow])
        stored, rscale, vxn2, cqerr = _quantize_rows(chunk[:grow], view.dtype)
        if view.dtype == "f32":
            payload = chunk
        else:
            payload = np.zeros(chunk.shape, _SCREEN_DTYPES[view.dtype])
            payload[:grow] = stored
        cn2 = np.full(grow + pad, kops.BIG_NORM2, np.float32)
        cn2[:grow] = vxn2
        h2d = payload.nbytes + cn2.nbytes
        if view.dtype == "int8":
            cs = np.ones(grow + pad, np.float32)
            cs[:grow] = rscale
            h2d += cs.nbytes
            table, xn2, scale = _arena_extend_quant(
                view.table, view.xn2, view.scale, jnp.asarray(payload),
                jnp.asarray(cn2), jnp.asarray(cs), np.int64(view.n))
        else:
            table, xn2 = _arena_extend(
                view.table, view.xn2, jnp.asarray(payload), jnp.asarray(cn2),
                np.int64(view.n))
            scale = view.scale
        with self._lock:
            self.stats["uploads"] += 1
            self.stats["h2d_bytes"] += h2d
        return DeviceView(
            host=np.ascontiguousarray(host_table, np.float32),
            mu=view.mu,
            table=table,
            xn2=xn2,
            n=n_new,
            cap=view.cap,
            xn2max=max(view.xn2max, float(vxn2.max())),
            dtype=view.dtype,
            scale=scale,
            qerr=max(view.qerr, cqerr),
            nbytes=view.nbytes,  # in-place: capacity (and footprint) fixed
        )

    def release_view(self, view: DeviceView) -> None:
        """Retire an arena: the registry calls this once no pinned epoch
        can still verify against the table (deferred retirement). The
        device buffers are freed when the last in-flight pass drops its
        reference — releasing is accounting plus dropping the owner's
        handle, never a forced deallocation under a live reader."""
        with self._lock:
            self.stats["released_arenas"] += 1
            self.stats["released_bytes"] += view.nbytes
            self.stats["arena_bytes"] -= view.nbytes

    # ----------------------------------------------------- the fused pass
    def _launch(self, view: DeviceView, trows: np.ndarray, Qc: np.ndarray,
                s: int):
        """Bucket-pad rows and queries, launch the fused pass, download the
        slate. Returns host (vals (m, s) f32, rows (m, s) int64, -1 padded).
        Dispatch and trace/hit accounting are serialized under the engine
        lock (the before/after _TRACES hit attribution needs launches not
        to interleave); the expensive part — blocking on the device result
        — happens OUTSIDE the lock, so concurrent query threads overlap
        their device work."""
        m = Qc.shape[0]
        mb = _bucket_batch(m)
        qpad = np.zeros((mb, Qc.shape[1]), np.float32)
        qpad[:m] = Qc
        with self._lock:
            self.stats["calls"] += 1
            self.stats["screened"] += m
            hist = self.stats["batch_hist"]
            hist[mb] = hist.get(mb, 0) + 1
            before = _TRACES[0]
            bb = max(_bucket_rows(trows.size), _bucket_rows(s, 8))
            if bb >= view.cap:
                # full-coverage pass: the gathered bucket would be
                # table-sized anyway, so screen the resident table through a
                # candidate mask instead of materializing a table-sized
                # gather
                mask = np.zeros(view.cap, bool)
                mask[trows] = True
                self.stats["h2d_bytes"] += mask.nbytes + qpad.nbytes
                vals, srows, invalid = _fused_screen_full(
                    view.table, view.xn2, view.scale, jnp.asarray(mask),
                    jnp.asarray(qpad), s)
            else:
                rows = np.full(bb, view.n, np.int32)  # pad: the sentinel row
                rows[: trows.size] = trows
                self.stats["h2d_bytes"] += rows.nbytes + qpad.nbytes
                vals, srows, invalid = _fused_screen(
                    view.table, view.xn2, view.scale, jnp.asarray(rows),
                    jnp.asarray(qpad), s)
            if _TRACES[0] == before:  # served from an already-compiled trace
                self.stats["hits"] += 1
            self.stats["traces"] = _TRACES[0]
        # jax dispatch is asynchronous: np.asarray blocks on the result, so
        # it must not run under the lock
        vals = np.asarray(vals)[:m]
        srows = np.asarray(srows)[:m].astype(np.int64)
        invalid = np.asarray(invalid)[:m]
        with self._lock:
            self.stats["d2h_bytes"] += (vals.nbytes + srows.nbytes
                                        + invalid.nbytes)
        # sentinel/masked-out rows surface only when the slate outsizes
        # the candidates; their BIG screen value or row index flags them
        srows = np.where(invalid | (srows >= view.n) | (vals >= 1e29), -1,
                         srows)
        return vals, srows

    def screen_topk(
        self,
        view: DeviceView,
        trows: np.ndarray,
        Q: np.ndarray,
        k: int,
        *,
        exact: bool = True,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Exact top-k of ``Q`` against the table rows ``trows``.

        One fused device pass selects a k+slack slate; the host re-ranks it
        in f64 (diff form — immune to cancellation, against the exact f32
        mirror) and, for the exact tier, certifies every query against the
        screen error bound — the classical f32 matmul term plus, for
        quantized arenas, the storage-rounding term — falling back to the
        provably exact host screen where certification fails. Returns
        ((m, kk) d2 ascending f32, (m, kk) rows into ``view.host``, -1
        padded), kk = min(k, |trows|) — the same contract as the host
        screens."""
        from .execute import _rerank_slate, _screen_topk_exact  # lazy: no cycle

        trows = np.ascontiguousarray(trows, np.int64)
        m = Q.shape[0]
        if m > _CHUNK_M:  # cache-resident query tiles (answers unchanged:
            parts = [  # every query's slate is independent)
                self.screen_topk(view, trows, Q[i : i + _CHUNK_M], k,
                                 exact=exact)
                for i in range(0, m, _CHUNK_M)
            ]
            return (np.concatenate([p[0] for p in parts]),
                    np.concatenate([p[1] for p in parts]))
        u = trows.size
        s = min(k + _SLACK, u)
        Qc = np.asarray(Q, np.float32) - view.mu[None, :]
        v_screen, srows = self._launch(view, trows, Qc, s)
        nv, nrows = _rerank_slate(Q, view.host, srows, k)
        if s >= u:
            return nv, nrows  # the slate IS the candidate set: always exact
        # certificate: anything screened out of the slate has screen d2 >=
        # the slate's worst, hence true d2 >= worst - 2*bound; a query whose
        # exact kth distance clears that margin provably lost nothing. For
        # quantized arenas the screen ranks x_stored = x + e, |e| <= qerr,
        # which moves a distance by at most 2(|q| + |x|)|e| — widen the
        # bound by that term (qerr = 0 keeps the pure-f32 certificate).
        qn = np.sqrt(np.einsum("mn,mn->m", Qc, Qc, dtype=np.float64))
        xnmax = np.sqrt(max(view.xn2max, 0.0))
        bound = (4.0 * Q.shape[1] * np.finfo(np.float32).eps * qn * xnmax)
        if view.qerr > 0.0:
            bound = bound + 2.0 * (qn + xnmax) * view.qerr
        kk = min(k, u)
        kth = nv[:, kk - 1] if nv.shape[1] >= kk else np.full(m, np.inf)
        certified = (srows >= 0).all(axis=1) & (
            np.where(np.isfinite(kth), kth, 0.0) <= v_screen[:, -1] - 2.0 * bound
        )
        bad = np.nonzero(~certified)[0]
        if bad.size:
            with self._lock:
                self.stats["fallbacks"] += int(bad.size)
            if exact:
                ev, er = _screen_topk_exact(Q[bad], view.host[trows], k)
            else:  # approximate tiers keep their slack-screen semantics
                from .execute import _screen_topk_slack

                ev, er = _screen_topk_slack(Q[bad], view.host[trows], k)
            pad = nv.shape[1] - ev.shape[1]
            if pad > 0:
                ev = np.concatenate(
                    [ev, np.full((bad.size, pad), np.inf, ev.dtype)], axis=1)
                er = np.concatenate(
                    [er, np.full((bad.size, pad), -1, er.dtype)], axis=1)
            nv[bad] = ev
            nrows[bad] = np.where(er >= 0, trows[np.maximum(er, 0)], -1)
        return nv, nrows

    # ------------------------------------------------------------ warm-up
    def prewarm(self, d: int, m: int, k: int, caps: list[int],
                dtype: Optional[str] = None) -> int:
        """Compile the bucket ladder up front: one dummy fused pass per
        (arena capacity, candidate bucket) at the serving batch/k shape and
        storage dtype, so steady-state traffic starts at zero retraces.
        Returns the number of traces compiled."""
        sd = self.dtype if dtype in (None, "") else resolve_screen_dtype(dtype)
        before = _TRACES[0]
        s = k + _SLACK
        mb = _bucket_batch(min(m, _CHUNK_M))
        for cap in sorted({_bucket_rows(c + 1) for c in caps}):
            table = jnp.zeros((cap, d), _SCREEN_DTYPES[sd])
            xn2 = jnp.full((cap,), kops.BIG_NORM2, jnp.float32)
            scale = (jnp.ones((cap,), jnp.float32) if sd == "int8" else None)
            qc = jnp.zeros((mb, d), jnp.float32)
            b = _bucket_rows(min(s, cap))
            while b < cap:  # the gather ladder below full coverage
                rows = jnp.zeros((b,), jnp.int32)
                jax.block_until_ready(
                    _fused_screen(table, xn2, scale, rows, qc, min(s, b)))
                b = _bucket_rows(b + 1)
            mask = jnp.zeros((cap,), bool)  # the full-coverage variant
            jax.block_until_ready(
                _fused_screen_full(table, xn2, scale, mask, qc, s))
        with self._lock:
            self.stats["traces"] = _TRACES[0]
        return _TRACES[0] - before

_ENGINE: Optional[VerifyEngine] = None


def get_engine() -> VerifyEngine:
    """The process-wide engine (arenas are cached on the data owners; the
    engine owns the compile cache + stats)."""
    global _ENGINE
    if _ENGINE is None:
        _ENGINE = VerifyEngine()
    return _ENGINE
