"""Write-ahead log — ingest batches are durable before they are visible.

Each :class:`repro.core.run_registry.BufferChunk` submitted to the index
becomes one checksummed WAL record, appended and fsync'd *before* the
chunk is published into the registry buffer. A crash at any later point
(mid-flush, mid-merge, before a manifest commit) loses no acknowledged
entry: recovery replays the surviving records back into buffer chunks.

Record layout (little-endian)::

    magic u32 | n u32 | series_len u32 | flags u32 | crc32(payload) u32
    payload = series f32 (n * series_len) + ids i64 (n) [+ ts i64 (n)]

Torn tails are expected, not errors: a crash mid-append leaves a partial
record (or a complete record with a bad checksum) at the end of the log;
replay stops at the first record that does not parse and truncates the
file back to the good prefix — everything before it is intact because
every append ends in one fsync.

Truncation of the flushed prefix is log *rotation*: once a flush made the
oldest ``n`` entries durable inside a published run, the surviving
entries are rewritten into ``wal-<id+1>.log`` (splitting a partially
flushed record if the flush boundary landed inside one) and the manifest
commit flips the active ``log_id``. The old log is deleted only after
that commit — a crash between rotation and commit recovers from the old
log and simply re-flushes.

The unflushed entries are mirrored in memory (they are exactly the
registry's buffer + flushing chunks), so rotation never re-reads the log
file on the hot path; the file is read only at recovery.
"""
from __future__ import annotations

import os
import struct
import threading
import zlib
from typing import List, Optional, Tuple

import numpy as np

from ..run_registry import BufferChunk

_MAGIC = 0xC0C0A105
_HEADER = struct.Struct("<IIIII")  # magic, n, series_len, flags, payload crc32
_F_HAS_TS = 1


def _encode(chunk: BufferChunk, series_len: int) -> bytes:
    series = np.ascontiguousarray(chunk.series, dtype=np.float32)
    ids = np.ascontiguousarray(chunk.ids, dtype=np.int64)
    payload = series.tobytes() + ids.tobytes()
    flags = 0
    if chunk.ts is not None:
        flags |= _F_HAS_TS
        payload += np.ascontiguousarray(chunk.ts, dtype=np.int64).tobytes()
    head = _HEADER.pack(_MAGIC, chunk.n, series_len, flags,
                        zlib.crc32(payload) & 0xFFFFFFFF)
    return head + payload


def replay_file(path: str, series_len: int) -> Tuple[List[BufferChunk], int]:
    """Parse a WAL file into chunks, tolerating a torn/corrupt tail.

    Returns ``(chunks, good_bytes)`` — replay stops at the first record
    whose header, length, or checksum does not check out; ``good_bytes``
    is the offset of the intact prefix (callers truncate the file there).
    """
    chunks: List[BufferChunk] = []
    good = 0
    if not os.path.exists(path):
        return chunks, good
    with open(path, "rb") as f:
        data = f.read()
    off = 0
    while off + _HEADER.size <= len(data):
        magic, n, slen, flags, crc = _HEADER.unpack_from(data, off)
        if magic != _MAGIC or slen != series_len or n == 0:
            break
        size = n * slen * 4 + n * 8 + (n * 8 if flags & _F_HAS_TS else 0)
        start = off + _HEADER.size
        if start + size > len(data):
            break  # torn tail: the record never finished writing
        payload = data[start:start + size]
        if (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
            break  # corrupt record: drop it and everything after
        series = np.frombuffer(payload, np.float32,
                               count=n * slen).reshape(n, slen).copy()
        p = n * slen * 4
        ids = np.frombuffer(payload, np.int64, count=n, offset=p).copy()
        ts = None
        if flags & _F_HAS_TS:
            ts = np.frombuffer(payload, np.int64, count=n,
                               offset=p + n * 8).copy()
        chunks.append(BufferChunk(series=series, ids=ids, ts=ts))
        off = start + size
        good = off
    return chunks, good


class WriteAheadLog:
    """Checksummed, fsync'd record log with rotation-based truncation."""

    def __init__(self, root: str, series_len: int):
        self.root = root
        self.series_len = series_len
        self._lock = threading.RLock()
        self.log_id = 0
        self.records = 0
        self.appended_bytes = 0
        self._f = None
        self._mirror: List[BufferChunk] = []  # unflushed entries, FIFO
        os.makedirs(root, exist_ok=True)

    def path(self, log_id: Optional[int] = None) -> str:
        lid = self.log_id if log_id is None else log_id
        return os.path.join(self.root, f"wal-{lid:08d}.log")

    # ------------------------------------------------------------- lifecycle
    def open(self, log_id: int) -> List[BufferChunk]:
        """Activate log ``log_id``: replay its surviving records into the
        in-memory mirror (truncating any torn tail in the file itself) and
        open it for appending. Returns the replayed chunks."""
        with self._lock:
            if self._f is not None:
                self._f.close()
            self.log_id = log_id
            path = self.path()
            chunks, good = replay_file(path, self.series_len)
            if os.path.exists(path) and good < os.path.getsize(path):
                with open(path, "r+b") as f:
                    f.truncate(good)
            self._mirror = list(chunks)
            self._f = open(path, "ab")
            return list(chunks)

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None

    # --------------------------------------------------------------- writes
    def append(self, chunk: BufferChunk) -> None:
        """Append + fsync one record: the chunk is durable on return."""
        rec = _encode(chunk, self.series_len)
        with self._lock:
            if self._f is None:
                self._f = open(self.path(), "ab")
            self._f.write(rec)
            self._f.flush()
            os.fsync(self._f.fileno())
            self._mirror.append(chunk)
            self.records += 1
            self.appended_bytes += len(rec)

    def truncate_front(self, n: int) -> Optional[str]:
        """Drop the oldest ``n`` entries by rotating to a fresh log that
        holds only the survivors (a partially flushed record is split).
        Returns the old log's path — the caller deletes it only after the
        manifest commit that records the new ``log_id``."""
        with self._lock:
            survivors: List[BufferChunk] = []
            left = n
            for c in self._mirror:
                if left >= c.n:
                    left -= c.n
                    continue
                if left > 0:
                    c = BufferChunk(series=c.series[left:], ids=c.ids[left:],
                                    ts=None if c.ts is None else c.ts[left:])
                    left = 0
                survivors.append(c)
            old_path = self.path()
            if self._f is not None:
                self._f.close()
            self.log_id += 1
            new_path = self.path()
            with open(new_path, "wb") as f:
                for c in survivors:
                    f.write(_encode(c, self.series_len))
                f.flush()
                os.fsync(f.fileno())
            self._mirror = survivors
            self._f = open(new_path, "ab")
            return old_path

    # ---------------------------------------------------------------- reads
    def chunks(self) -> List[BufferChunk]:
        """The unflushed entries as chunks (oldest first)."""
        with self._lock:
            return list(self._mirror)

    @property
    def entries(self) -> int:
        with self._lock:
            return sum(c.n for c in self._mirror)
