"""File-backed storage: mmap'd runs, a write-ahead log, crash recovery.

The default backend stays the :class:`repro.core.io_model.DiskModel`
simulation; this package is the ``REPRO_STORAGE=file`` twin — same store
surface, real files, measured I/O counters next to the modeled ones, and
a WAL + manifest protocol that makes streaming ingest crash-consistent.
"""
from .backend import (  # noqa: F401
    BACKENDS,
    RunFiles,
    SimulatedCrash,
    StorageBackend,
    StorageEngine,
    resolve_backend,
)
from .file_store import FileStore  # noqa: F401
from .prefetch import ReadaheadPool, get_pool  # noqa: F401
from .wal import WriteAheadLog, replay_file  # noqa: F401

__all__ = [
    "BACKENDS",
    "FileStore",
    "ReadaheadPool",
    "RunFiles",
    "SimulatedCrash",
    "StorageBackend",
    "StorageEngine",
    "WriteAheadLog",
    "get_pool",
    "replay_file",
    "resolve_backend",
]
