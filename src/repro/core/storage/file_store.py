"""FileStore — the raw data-series file as an actual file.

Drop-in for :class:`repro.core.ctree.RawStore` (same fetch/append/norms/
device-view surface, same modeled :class:`DiskModel` accounting so BENCH
trajectories stay comparable across backends), but rows live in
``raw.bin`` and reads go through a read-only ``np.memmap`` — fancy
indexing on the mmap gathers straight off the page cache, so a store
much larger than RAM is served by the kernel instead of simulated by
held arrays.

On top of the modeled figures the store keeps *measured* counters
(``measured_write_bytes`` / ``measured_read_bytes``): the bytes the
process actually pushed to / pulled from the backing file, which the
benchmarks report next to the modeled columns.

Recovery hooks (used by :class:`repro.core.storage.backend.StorageEngine`):
``truncate`` drops a non-durable tail (rows appended but never WAL'd
before a crash), ``overlay`` rewrites row ranges from replayed WAL
records (idempotent positional writes — the WAL is the source of truth
for unflushed rows), ``fsync`` is the durability point a manifest commit
takes before publishing flushed runs.
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np

from ..ctree import RawStore
from ..io_model import DiskModel


class FileStore(RawStore):
    """Append-only raw series file with mmap reads and measured I/O."""

    def __init__(self, series_len: int, root: str,
                 disk: Optional[DiskModel] = None):
        super().__init__(series_len, disk)
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.path = os.path.join(root, "raw.bin")
        self._row_bytes = series_len * 4
        # r+b (not append mode): overlay() uses pwrite, whose offset an
        # O_APPEND descriptor would ignore
        if not os.path.exists(self.path):
            open(self.path, "xb").close()
        self._f = open(self.path, "r+b")
        self._f.seek(0, os.SEEK_END)
        self.n = self._f.tell() // self._row_bytes
        self.measured_write_bytes = 0
        self.measured_read_bytes = 0

    # --------------------------------------------------------------- writes
    def append(self, series: np.ndarray) -> np.ndarray:
        """Append (B, n) series to the backing file; returns their ids.

        Durability is the WAL's job (every ingest batch is WAL'd before it
        is query-visible), so the append flushes but does not fsync —
        ``fsync`` runs once per manifest commit instead of once per batch.
        """
        series = np.ascontiguousarray(series, dtype=np.float32)
        with self._lock:
            ids = np.arange(self.n, self.n + series.shape[0], dtype=np.int64)
            self._f.seek(0, os.SEEK_END)
            self._f.write(series.tobytes())
            self._f.flush()
            self.n += series.shape[0]
            self._data = None
            self.measured_write_bytes += series.nbytes
        self.disk.write_seq(series.nbytes,
                            offset=int(ids[0]) * self._row_bytes if ids.size else 0)
        return ids

    def fsync(self) -> None:
        """Make every appended row durable (the pre-manifest barrier)."""
        with self._lock:
            self._f.flush()
            os.fsync(self._f.fileno())

    # ---------------------------------------------------------------- reads
    def _all(self) -> np.ndarray:
        with self._lock:
            if self._data is None or self._data.shape[0] != self.n:
                if self.n == 0:
                    self._data = np.zeros((0, self.series_len), np.float32)
                else:
                    self._data = np.memmap(self.path, dtype=np.float32,
                                           mode="r",
                                           shape=(self.n, self.series_len))
            return self._data

    def fetch(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids)
        with self._lock:
            self.measured_read_bytes += int(ids.size) * self._row_bytes
        # fancy indexing on the mmap copies the gathered rows out — the
        # modeled random-read accounting happens in account_fetch (super)
        return super().fetch(ids)

    def scan(self) -> np.ndarray:
        data = self._all()
        with self._lock:
            self.measured_read_bytes += int(data.nbytes)
        self.disk.read_seq(data.nbytes)
        return data

    # ------------------------------------------------------------- recovery
    def truncate(self, n: int) -> None:
        """Drop rows >= ``n`` (a crash's non-durable tail) and reset every
        derived cache. Recovery-time only — never races queries."""
        with self._lock:
            self._f.truncate(n * self._row_bytes)
            self._f.flush()
            self.n = int(n)
            self._data = None
            self._norms2 = None
            self._chunks = []

    def overlay(self, row0: int, series: np.ndarray) -> None:
        """Rewrite rows [row0, row0 + B) from a replayed WAL record. The
        rows must already be inside the truncated extent."""
        series = np.ascontiguousarray(series, dtype=np.float32)
        with self._lock:
            if row0 + series.shape[0] > self.n:
                raise ValueError("overlay beyond the durable extent")
            self._f.flush()
            os.pwrite(self._f.fileno(), series.tobytes(),
                      row0 * self._row_bytes)
            self._data = None
            self._norms2 = None
