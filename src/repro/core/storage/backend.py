"""Storage backend selection + the crash-consistent storage engine.

Two backends implement the same store surface (the ``StorageBackend``
protocol — what :class:`repro.core.ctree.RawStore` already exposes):

* ``model`` — the default: in-memory arrays + :class:`DiskModel`
  accounting (the simulation the repo grew up on; BENCH trajectories are
  recorded against it).
* ``file`` — :class:`repro.core.storage.file_store.FileStore` raw rows +
  mmap'd sorted-run files + a write-ahead log, orchestrated by
  :class:`StorageEngine`. Modeled accounting still runs (same DiskModel,
  comparable figures); *measured* byte counters ride alongside.

Selection: ``StreamConfig.storage`` is ``"auto"`` by default, which
resolves through the ``REPRO_STORAGE`` env var (CI's file-backend leg
sets ``REPRO_STORAGE=file``) and falls back to ``model``.

Durability protocol (single writer — the flush/merge thread):

1. every ingest batch is WAL-appended (fsync) *before* it becomes
   query-visible (``CLSM.append_chunk``);
2. a flush persists its run files, publishes the run in-memory, then
   commits: rotate the WAL past the flushed entries, fsync the raw file,
   write ``MANIFEST.json`` atomically (tmp + fsync + rename + dir fsync);
3. a merge persists the merged run, publishes in-memory, then commits a
   manifest naming the merged run instead of its victims. Victim files
   are unlinked only after that commit (open mmaps keep the data alive
   for pinned queries — POSIX unlink semantics).

The manifest is the single commit point: recovery loads exactly the runs
it names, deletes every run directory and WAL segment it does not, and
replays the active WAL (torn tails truncated) back into buffer chunks —
so a crash at ANY point between a WAL append and a manifest commit
recovers to the same durable entry set, merely placed differently
(buffer vs run), and query answers are bitwise identical either way.

Fault injection: tests set ``engine.crash_after = "<point>"`` and the
engine raises :class:`SimulatedCrash` at that named point; the test then
abandons the index objects and recovers from the directory, which is
exactly what a process kill exercises (minus the fds, which POSIX closes
for us either way).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from ..ctree import SortedRun, _zone_maps
from ..io_model import DiskModel
from ..run_registry import BufferChunk
from ..summarization import SummarizationConfig
from .file_store import FileStore
from .wal import WriteAheadLog

MANIFEST = "MANIFEST.json"
BACKENDS = ("model", "file")


def resolve_backend(name: str) -> str:
    """``auto`` resolves through ``REPRO_STORAGE`` (default ``model``)."""
    if name == "auto":
        name = os.environ.get("REPRO_STORAGE", "model")
    if name not in BACKENDS:
        raise ValueError(f"unknown storage backend {name!r} "
                         f"(expected one of {BACKENDS} or 'auto')")
    return name


class SimulatedCrash(BaseException):
    """Raised by the fault-injection hook; inherits BaseException so no
    recovery-under-test accidentally swallows it as an ordinary error."""


class StorageBackend(Protocol):
    """The store surface both backends serve (``RawStore``'s contract)."""

    series_len: int
    disk: DiskModel
    n: int

    def append(self, series: np.ndarray) -> np.ndarray: ...
    def fetch(self, ids: np.ndarray) -> np.ndarray: ...
    def account_fetch(self, ids: np.ndarray) -> None: ...
    def scan(self) -> np.ndarray: ...
    def norms2(self, ids: np.ndarray) -> np.ndarray: ...
    def device_view(self) -> object: ...


@dataclasses.dataclass
class RunFiles:
    """A persisted run's on-disk location (the ``SortedRun._storage``
    handle). File deletion is owned by the engine's manifest diff, not by
    this handle — releasing it only drops the mmap references."""

    dir: str


class StorageEngine:
    """Crash-consistent file storage: raw rows + run files + WAL + manifest."""

    def __init__(self, root: str, scfg: SummarizationConfig,
                 disk: Optional[DiskModel] = None):
        self.root = root
        self.scfg = scfg
        self.runs_dir = os.path.join(root, "runs")
        os.makedirs(self.runs_dir, exist_ok=True)
        self._lock = threading.RLock()
        self.disk = disk or DiskModel()
        self.raw = FileStore(scfg.series_len, root, disk=self.disk)
        self.wal = WriteAheadLog(os.path.join(root, "wal"), scfg.series_len)
        self.crash_after: Optional[str] = None
        self.run_seq = 0
        self.run_write_bytes = 0
        self.manifest_commits = 0
        self._referenced: set = set()
        self._recovered = False

    # ----------------------------------------------------- fault injection
    def maybe_crash(self, point: str) -> None:
        if self.crash_after == point:
            raise SimulatedCrash(point)

    # ----------------------------------------------------------------- WAL
    def append_wal(self, chunk: BufferChunk) -> None:
        """Durability point of one ingest batch (fsync'd on return)."""
        with self._lock:
            self.wal.append(chunk)
        self.maybe_crash("wal-append")

    # ---------------------------------------------------------- run files
    def persist_run(self, run: SortedRun) -> SortedRun:
        """Write a freshly built run's arrays to a new run directory and
        return an equivalent run whose arrays are read-only memmaps of
        those files (zone maps stay in memory — they are derived data).
        Empty runs are returned unchanged (nothing to persist)."""
        if run.n == 0:
            return run
        with self._lock:
            name = f"run-{self.run_seq:08d}"
            self.run_seq += 1
        d = os.path.join(self.runs_dir, name)
        os.makedirs(d)
        written = 0
        arrays = {"keys.bin": run.keys, "sax.bin": run.sax, "ids.bin": run.ids}
        if run.series is not None:
            arrays["series.bin"] = run.series
        if run.ts is not None:
            arrays["ts.bin"] = run.ts
        for fname, arr in arrays.items():
            path = os.path.join(d, fname)
            with open(path, "wb") as f:
                f.write(np.ascontiguousarray(arr).tobytes())
                f.flush()
                os.fsync(f.fileno())
            written += int(arr.nbytes)
        meta = {
            "n": int(run.n),
            "block_size": int(run.block_size),
            "t_min": int(run.t_min),
            "t_max": int(run.t_max),
            "has_series": run.series is not None,
            "has_ts": run.ts is not None,
            "series_len": int(run.cfg.series_len),
            "n_segments": int(run.cfg.n_segments),
            "card_bits": int(run.cfg.card_bits),
            "znorm": bool(run.cfg.znorm),
            "key_words": int(run.cfg.key_words),
            # arena storage dtype survives persistence AND recovery: a
            # recovered run screens at the same precision it was built with
            "screen_dtype": run.screen_dtype,
        }
        mpath = os.path.join(d, "meta.json")
        with open(mpath, "w") as f:
            json.dump(meta, f, indent=1, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        dfd = os.open(d, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
        with self._lock:
            self.run_write_bytes += written
        self.disk.write_seq(written)  # modeled twin of the measured write
        self.maybe_crash("run-persisted")
        return self._map_run(d, meta, bmin=run.bmin, bmax=run.bmax)

    def _map_run(self, d: str, meta: dict, bmin=None, bmax=None) -> SortedRun:
        cfg = SummarizationConfig(series_len=meta["series_len"],
                                  n_segments=meta["n_segments"],
                                  card_bits=meta["card_bits"],
                                  znorm=meta["znorm"])
        n = meta["n"]
        mm = lambda f, dt, shape: np.memmap(os.path.join(d, f), dtype=dt,
                                            mode="r", shape=shape)
        keys = mm("keys.bin", np.uint32, (n, meta["key_words"]))
        sax = mm("sax.bin", np.uint8, (n, meta["n_segments"]))
        ids = mm("ids.bin", np.int64, (n,))
        series = (mm("series.bin", np.float32, (n, meta["series_len"]))
                  if meta["has_series"] else None)
        ts = mm("ts.bin", np.int64, (n,)) if meta["has_ts"] else None
        if bmin is None or bmax is None:
            bmin, bmax = _zone_maps(np.asarray(sax), meta["block_size"],
                                    meta["n_segments"])
        return SortedRun(cfg=cfg, keys=keys, sax=sax, ids=ids,
                         block_size=meta["block_size"], bmin=bmin, bmax=bmax,
                         series=series, ts=ts, t_min=meta["t_min"],
                         t_max=meta["t_max"],
                         screen_dtype=meta.get("screen_dtype"),
                         _storage=RunFiles(dir=d))

    def drop_run(self, run: SortedRun) -> None:
        """Delete an unreferenced run's files (e.g. a CTree rebuild's old
        run). Manifest-referenced runs are never dropped here — their
        lifetime is the manifest diff's."""
        handle = run._storage
        if handle is None:
            return
        with self._lock:
            if os.path.basename(handle.dir) in self._referenced:
                return
        shutil.rmtree(handle.dir, ignore_errors=True)
        run.release_storage()

    # ------------------------------------------------------------ manifest
    def _manifest_path(self) -> str:
        return os.path.join(self.root, MANIFEST)

    def _write_manifest_locked(self, levels: Sequence[Tuple[int, tuple]]) -> None:
        names: List[List[object]] = []
        referenced: set = set()
        for lv, runs in levels:
            row = [int(lv), [os.path.basename(r._storage.dir) for r in runs
                             if r._storage is not None and r.n]]
            if row[1]:
                names.append(row)
                referenced.update(row[1])
        man = {"log_id": self.wal.log_id, "run_seq": self.run_seq,
               "levels": names}
        tmp = self._manifest_path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump(man, f, indent=1, sort_keys=True)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._manifest_path())
        dfd = os.open(self.root, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
        # the commit is durable: files the new manifest no longer names
        # can go (open mmaps of pinned queries keep the inodes alive)
        for name in self._referenced - referenced:
            shutil.rmtree(os.path.join(self.runs_dir, name),
                          ignore_errors=True)
        self._referenced = referenced
        self.manifest_commits += 1

    def commit_flush(self, n_entries: int, snapshot) -> None:
        """The flush commit: rotate the WAL past the ``n_entries`` now
        living in a published run, fsync the raw rows those entries map
        to, and commit a manifest of the post-flush run set."""
        self.maybe_crash("pre-manifest")
        with self._lock:
            old_log = self.wal.truncate_front(n_entries)
            self.raw.fsync()
            self._write_manifest_locked(snapshot.levels)
            if old_log and os.path.exists(old_log):
                os.unlink(old_log)
        self.maybe_crash("post-manifest")

    def commit_merge(self, snapshot) -> None:
        """The merge commit: one manifest naming the merged run instead of
        its victims (no WAL change — merges move no entries)."""
        self.maybe_crash("merge-pre-manifest")
        with self._lock:
            self._write_manifest_locked(snapshot.levels)
        self.maybe_crash("merge-post-manifest")

    # ------------------------------------------------------------ recovery
    def recover(self) -> Tuple[List[Tuple[int, list]], List[BufferChunk]]:
        """Load the durable state: the manifest's runs (as memmaps) plus
        the active WAL's surviving records (as buffer chunks), after
        deleting everything the manifest does not name. Idempotent; a
        fresh directory recovers to the empty state."""
        with self._lock:
            man = {"log_id": 0, "run_seq": 0, "levels": []}
            if os.path.exists(self._manifest_path()):
                with open(self._manifest_path()) as f:
                    man = json.load(f)
            self.run_seq = max(self.run_seq, int(man["run_seq"]))
            referenced = {name for _, names in man["levels"] for name in names}
            for entry in os.listdir(self.runs_dir):
                if entry not in referenced:
                    shutil.rmtree(os.path.join(self.runs_dir, entry),
                                  ignore_errors=True)
            active = os.path.basename(self.wal.path(int(man["log_id"])))
            for entry in os.listdir(self.wal.root):
                if entry != active:
                    os.unlink(os.path.join(self.wal.root, entry))
            chunks = self.wal.open(int(man["log_id"]))
            levels: List[Tuple[int, list]] = []
            run_n = 0
            for lv, names in man["levels"]:
                runs = []
                for name in names:
                    d = os.path.join(self.runs_dir, name)
                    with open(os.path.join(d, "meta.json")) as f:
                        meta = json.load(f)
                    runs.append(self._map_run(d, meta))
                    run_n += meta["n"]
                levels.append((int(lv), runs))
            # the durable extent: every entry a run or WAL record covers.
            # Raw rows beyond it were appended but never WAL'd (a crash in
            # the ingest submission window) — never acknowledged, dropped.
            durable = run_n + sum(c.n for c in chunks)
            self.raw.truncate(durable)
            for c in chunks:
                if c.n == 0:
                    continue
                ids = np.asarray(c.ids)
                if not np.array_equal(ids, np.arange(ids[0], ids[0] + c.n)):
                    raise ValueError("WAL chunk ids are not contiguous")
                # unflushed rows re-materialize from the WAL record itself:
                # the raw append may not have been durable, the WAL was
                self.raw.overlay(int(ids[0]), c.series)
            self._referenced = referenced
            self._recovered = True
            return levels, list(chunks)

    # ------------------------------------------------------------ counters
    def measured(self) -> Dict[str, int]:
        """Measured (not modeled) I/O: bytes actually moved through the
        backing files, plus the process-wide readahead pool's counters."""
        from .prefetch import get_pool

        with self._lock:
            out = {
                "raw_write_bytes": self.raw.measured_write_bytes,
                "raw_read_bytes": self.raw.measured_read_bytes,
                "run_write_bytes": self.run_write_bytes,
                "wal_write_bytes": self.wal.appended_bytes,
                "wal_records": self.wal.records,
                "manifest_commits": self.manifest_commits,
            }
        out.update(get_pool().stats())
        return out
