"""Async readahead over the executor's coalesced spans.

The approximate tier already deduplicates every query batch's block reads
into disjoint ascending [lo, hi) spans (``coalesce_ranges``); when a run
is file-backed those spans are mmap page ranges the verification pass is
about to fault in one by one. :class:`ReadaheadPool` takes the coalesced
span list the moment the executor produces it and touches the pages on a
small thread pool, so the page cache is warm (or the faults are at least
in flight) by the time verification reads the same rows.

Prefetching is strictly advisory: it reads immutable published runs, it
swallows its own errors, and query answers are identical with the pool
disabled — only the fault timing changes. ``drain()`` exists for tests
and counters, not correctness.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import List, Optional, Sequence, Tuple

import numpy as np


class ReadaheadPool:
    """Touches file-backed array spans ahead of the verification pass."""

    def __init__(self, workers: int = 2):
        self._lock = threading.Lock()
        self._pool = ThreadPoolExecutor(max_workers=workers,
                                        thread_name_prefix="coconut-readahead")
        self._pending: List[object] = []
        self.spans = 0
        self.bytes = 0
        self.errors = 0

    def prefetch(self, arrays: Sequence[np.ndarray],
                 ranges: List[Tuple[int, int]]) -> None:
        """Queue a readahead of ``arrays[lo:hi]`` for every coalesced
        [lo, hi) row range. Returns immediately."""
        if not ranges:
            return
        arrays = [a for a in arrays if a is not None]
        if not arrays:
            return
        fut = self._pool.submit(self._touch, arrays, list(ranges))
        with self._lock:
            self._pending.append(fut)
            if len(self._pending) > 64:  # keep the bookkeeping bounded
                self._pending = [f for f in self._pending if not f.done()]

    def _touch(self, arrays, ranges) -> None:
        nbytes = nspans = 0
        try:
            for lo, hi in ranges:
                for a in arrays:
                    seg = a[lo:hi]
                    if seg.size == 0:
                        continue
                    # one element per 4 KiB page faults the whole span in
                    step = max(1, 4096 // int(seg.itemsize))
                    float(np.asarray(seg).reshape(-1)[::step].sum())
                    nbytes += int(seg.nbytes)
                nspans += 1
        except Exception:  # noqa: BLE001 — readahead must never break a query
            with self._lock:
                self.errors += 1
            return
        with self._lock:
            self.spans += nspans
            self.bytes += nbytes

    def drain(self, timeout: Optional[float] = 30.0) -> None:
        """Wait for every queued readahead (tests/counters only)."""
        with self._lock:
            pending, self._pending = self._pending, []
        for f in pending:
            f.result(timeout=timeout)

    def stats(self) -> dict:
        with self._lock:
            return {"prefetch_spans": self.spans,
                    "prefetch_bytes": self.bytes,
                    "prefetch_errors": self.errors}


_POOL: Optional[ReadaheadPool] = None
_POOL_LOCK = threading.Lock()


def get_pool() -> ReadaheadPool:
    """The process-wide readahead pool (lazy; daemon worker threads)."""
    global _POOL
    with _POOL_LOCK:
        if _POOL is None:
            _POOL = ReadaheadPool()
        return _POOL
