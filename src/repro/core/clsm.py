"""CoconutLSM — the write-optimized log-structured Coconut index.

Incoming series accumulate in an in-memory buffer; each flush external-sorts
the buffer into a level-0 :class:`SortedRun` (sequential write). When a level
collects ``growth_factor`` runs they are sort-merged into one run at the next
level (tiering). Every run carries its time range, which is contiguous in
stream order — this is exactly what Bounded Temporal Partitioning (BTP)
needs: newer data in small recent runs, older data in large merged runs, and
window queries skip runs whose time range misses the window.

The ``growth_factor`` knob trades writes (merge work) against reads (number
of runs a query must probe) — paper §2 "Better Read vs. Write Trade-Offs".

Queries compile to one :class:`repro.core.plan.QueryPlan` — the in-memory
buffer as a dense source plus one source per live run, newest first — and
the shared executor folds a single (m, k) state across them, so distances
verified against recent runs prune blocks of the older, larger runs for
the whole batch. The PP/TP/BTP run-level skip is the plan's ``time_skip``
flag, decided per run at plan build (no run metadata is ever touched).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .ctree import QueryStats, RawStore, SortedRun, state_to_list
from .execute import execute
from .io_model import DiskModel
from .plan import DenseSource, QueryPlan, SourceOps, run_time_skipped
from .summarization import SummarizationConfig


@dataclasses.dataclass
class CLSMConfig:
    summarization: SummarizationConfig = dataclasses.field(default_factory=SummarizationConfig)
    buffer_entries: int = 4096
    growth_factor: int = 4
    block_size: int = 512
    materialized: bool = False
    merge: bool = True  # False => TP (flush-only temporal partitions)


class CLSM:
    def __init__(self, cfg: CLSMConfig, disk: Optional[DiskModel] = None):
        self.cfg = cfg
        self.disk = disk or DiskModel()
        self.levels: dict[int, list[SortedRun]] = {}
        self._buf_series: list[np.ndarray] = []
        self._buf_ids: list[np.ndarray] = []
        self._buf_ts: list[np.ndarray] = []
        self._buf_n = 0
        self.n_flushes = 0
        self.n_merges = 0
        self.merged_bytes = 0

    # ---------------------------------------------------------------- ingest
    def insert(self, series: np.ndarray, ids: np.ndarray, ts: np.ndarray) -> None:
        series = np.asarray(series, np.float32)
        self._buf_series.append(series)
        self._buf_ids.append(np.asarray(ids, np.int64))
        self._buf_ts.append(np.asarray(ts, np.int64))
        self._buf_n += series.shape[0]
        while self._buf_n >= self.cfg.buffer_entries:
            self._flush()

    def _take_buffer(self, n: int):
        series = np.concatenate(self._buf_series)
        ids = np.concatenate(self._buf_ids)
        ts = np.concatenate(self._buf_ts)
        take = slice(0, n)
        rest = slice(n, None)
        out = (series[take], ids[take], ts[take])
        self._buf_series = [series[rest]] if series.shape[0] > n else []
        self._buf_ids = [ids[rest]] if series.shape[0] > n else []
        self._buf_ts = [ts[rest]] if series.shape[0] > n else []
        self._buf_n = max(0, self._buf_n - n)
        return out

    def _flush(self) -> None:
        n = min(self.cfg.buffer_entries, self._buf_n)
        if n == 0:
            return
        series, ids, ts = self._take_buffer(n)
        run, _ = SortedRun.build(
            series,
            ids,
            self.cfg.summarization,
            block_size=self.cfg.block_size,
            materialized=self.cfg.materialized,
            ts=ts,
            disk=self.disk,
            mem_budget_entries=self.cfg.buffer_entries,
        )
        self.levels.setdefault(0, []).append(run)
        self.n_flushes += 1
        if self.cfg.merge:
            self._maybe_merge(0)

    def flush_all(self) -> None:
        while self._buf_n > 0:
            self._flush()

    def _maybe_merge(self, level: int) -> None:
        runs = self.levels.get(level, [])
        while len(runs) >= self.cfg.growth_factor:
            merged = self._merge_runs(runs[: self.cfg.growth_factor])
            del runs[: self.cfg.growth_factor]
            self.levels.setdefault(level + 1, []).append(merged)
            self._maybe_merge(level + 1)
            runs = self.levels.get(level, [])

    def _merge_runs(self, runs: list[SortedRun]) -> SortedRun:
        """Sort-merge runs (sequential read of inputs + sequential write)."""
        scfg = self.cfg.summarization
        syms = np.concatenate([r.sax for r in runs])
        ids = np.concatenate([r.ids for r in runs])
        ts = np.concatenate([r.ts for r in runs]) if runs[0].ts is not None else None
        series = (
            np.concatenate([r.series for r in runs]) if runs[0].materialized else None
        )
        in_bytes = sum(r.index_bytes() for r in runs)
        self.disk.read_seq(in_bytes)
        merged, _ = SortedRun.from_arrays(
            scfg,
            syms,
            ids,
            block_size=self.cfg.block_size,
            series=series,
            ts=ts,
            disk=None,  # accounted below as one sequential write
            mem_budget_entries=max(1, self.cfg.buffer_entries),
        )
        self.disk.write_seq(merged.index_bytes())
        self.n_merges += 1
        self.merged_bytes += in_bytes
        return merged

    # ---------------------------------------------------------------- query
    def runs_newest_first(self) -> list[SortedRun]:
        out: list[SortedRun] = []
        for level in sorted(self.levels):
            out.extend(reversed(self.levels[level]))
        return out

    def _buffer_source(self) -> Optional[DenseSource]:
        """The in-memory write buffer as a brute-force plan source."""
        if self._buf_n == 0:
            return None
        series = np.concatenate(self._buf_series)
        ids = np.concatenate(self._buf_ids)
        ts = np.concatenate(self._buf_ts)
        return DenseSource(
            ops=SourceOps(ids=ids, ts=ts, fetch=lambda p, s=series: s[p]),
            n=series.shape[0],
        )

    def plan(
        self,
        Q: np.ndarray,
        *,
        tier: str = "exact",
        n_blocks: int = 1,
        raw: Optional[RawStore] = None,
        window: Optional[tuple[int, int]] = None,
        time_skip: bool = True,
        backend: str = "device",
    ) -> QueryPlan:
        """Compile a query batch into one plan over buffer + live runs.

        Runs go in newest-first so the executor's folded state prunes the
        older, larger runs hardest. ``time_skip`` is the PP/TP/BTP flag:
        False (PP) plans every run and relies on entry-level window
        filtering; True (TP/BTP) drops runs whose [t_min, t_max] misses the
        window at plan build — side-effect-free either way."""
        sources: list = []
        pruned = 0
        buf = self._buffer_source()
        if buf is not None:
            sources.append(buf)
        for run in self.runs_newest_first():
            if run.n == 0:
                continue
            skip = run_time_skipped(run.t_min, run.t_max, window,
                                    time_skip and run.ts is not None)
            if tier == "exact":
                if skip:
                    pruned += run.n_blocks
                    continue
                sources.append(run.plan_exact(Q, raw=raw, disk=self.disk))
            else:
                if skip:
                    continue
                sources.append(run.plan_approx(Q, n_blocks=n_blocks, raw=raw,
                                               disk=self.disk, backend=backend))
        return QueryPlan(m=len(Q), sources=sources, window=window,
                         time_skip=time_skip, pruned_blocks=pruned)

    def knn_exact(self, q, k=1, *, raw: Optional[RawStore] = None, window=None,
                  time_skip=True):
        """Scalar exact kNN over buffer + runs — a batch-of-1 plan through
        the shared executor. Returns ([(d2, id)] ascending, stats)."""
        vals, gids, stats = self.knn_batch(
            np.asarray(q, np.float32).reshape(1, -1), k, raw=raw, window=window,
            time_skip=time_skip,
        )
        return state_to_list(vals[0], gids[0]), stats

    def knn_batch(self, Q, k=1, *, raw: Optional[RawStore] = None, window=None,
                  backend="device", time_skip=True, shard=None, mesh=None):
        """Batched exact kNN across buffer + every live run.

        The batched best-so-far state threads through the runs newest-first
        (exactly like the bsf heap did), so distances verified against
        recent runs prune blocks of the older, larger runs for the whole
        batch at once. ``time_skip=False`` keeps entry-level window
        filtering but probes every run (PP). ``shard="mesh"`` executes the
        plan on the device mesh (queries x runs 2-D ``shard_map``).
        Returns ((m, k) d2, (m, k) ids, stats)."""
        Q = np.asarray(Q, np.float32)
        plan = self.plan(Q, tier="exact", raw=raw, window=window,
                         time_skip=time_skip)
        (vals, gids), stats = execute(plan, Q, k, backend=backend, shard=shard,
                                      mesh=mesh)
        return vals, gids, stats

    def knn_approx(self, q, k=1, *, n_blocks=1, raw=None, window=None,
                   time_skip=True):
        """Scalar approximate kNN: probe the adjacent blocks of every live
        run (BTP bounds the run count, so this is a bounded number of
        I/Os). Batch-of-1 plan; returns ([(d2, id)] ascending, stats)."""
        vals, gids, stats = self.knn_approx_batch(
            np.asarray(q, np.float32).reshape(1, -1), k, n_blocks=n_blocks,
            raw=raw, window=window, time_skip=time_skip,
        )
        return state_to_list(vals[0], gids[0]), stats

    def knn_approx_batch(self, Q, k=1, *, n_blocks=1, raw=None, window=None,
                         backend="device", time_skip=True):
        """Batched approximate kNN across buffer + every live run.

        The (m, k) best-so-far state folds over the runs newest-first — the
        batched analogue of the per-run heap merge. Each run contributes
        one vectorized key seek plus one coalesced sequential block read
        for the whole batch (BTP bounds the run count, so the I/O stays
        bounded). Results are a subset of the exact answer: every query
        sees only its ``n_blocks`` adjacent blocks per run, so ``n_blocks``
        trades sequential bytes for recall@k. ``time_skip=False`` probes
        every run while keeping entry-level window filtering (PP
        semantics). Returns ((m, k) d2, (m, k) ids, stats)."""
        Q = np.asarray(Q, np.float32)
        plan = self.plan(Q, tier="approx", n_blocks=n_blocks, raw=raw,
                         window=window, time_skip=time_skip, backend=backend)
        (vals, gids), stats = execute(plan, Q, k, backend=backend)
        return vals, gids, stats

    @property
    def n_runs(self) -> int:
        return sum(len(v) for v in self.levels.values())

    def index_bytes(self) -> int:
        return sum(r.index_bytes() for rs in self.levels.values() for r in rs)
