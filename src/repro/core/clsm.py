"""CoconutLSM — the write-optimized log-structured Coconut index.

Incoming series accumulate in an in-memory buffer; each flush external-sorts
the buffer into a level-0 :class:`SortedRun` (sequential write). When a level
collects ``growth_factor`` runs they are sort-merged into one run at the next
level (tiering). Every run carries its time range, which is contiguous in
stream order — this is exactly what Bounded Temporal Partitioning (BTP)
needs: newer data in small recent runs, older data in large merged runs, and
window queries skip runs whose time range misses the window.

The ``growth_factor`` knob trades writes (merge work) against reads (number
of runs a query must probe) — paper §2 "Better Read vs. Write Trade-Offs".

Batched traffic uses ``knn_batch``: the (m, k) best-so-far state threads
through buffer + runs newest-first exactly like the scalar bsf heap, with
one shared verification pass per (run, batch) — see ``SortedRun.knn_batch``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .ctree import (
    QueryStats,
    RawStore,
    SortedRun,
    empty_topk_state,
    heap_to_sorted,
    merge_topk_state,
)
from .io_model import DiskModel
from .lower_bounds import topk_ed2
from .summarization import SummarizationConfig, paa, sax_from_paa


@dataclasses.dataclass
class CLSMConfig:
    summarization: SummarizationConfig = dataclasses.field(default_factory=SummarizationConfig)
    buffer_entries: int = 4096
    growth_factor: int = 4
    block_size: int = 512
    materialized: bool = False
    merge: bool = True  # False => TP (flush-only temporal partitions)


class CLSM:
    def __init__(self, cfg: CLSMConfig, disk: Optional[DiskModel] = None):
        self.cfg = cfg
        self.disk = disk or DiskModel()
        self.levels: dict[int, list[SortedRun]] = {}
        self._buf_series: list[np.ndarray] = []
        self._buf_ids: list[np.ndarray] = []
        self._buf_ts: list[np.ndarray] = []
        self._buf_n = 0
        self.n_flushes = 0
        self.n_merges = 0
        self.merged_bytes = 0

    # ---------------------------------------------------------------- ingest
    def insert(self, series: np.ndarray, ids: np.ndarray, ts: np.ndarray) -> None:
        series = np.asarray(series, np.float32)
        self._buf_series.append(series)
        self._buf_ids.append(np.asarray(ids, np.int64))
        self._buf_ts.append(np.asarray(ts, np.int64))
        self._buf_n += series.shape[0]
        while self._buf_n >= self.cfg.buffer_entries:
            self._flush()

    def _take_buffer(self, n: int):
        series = np.concatenate(self._buf_series)
        ids = np.concatenate(self._buf_ids)
        ts = np.concatenate(self._buf_ts)
        take = slice(0, n)
        rest = slice(n, None)
        out = (series[take], ids[take], ts[take])
        self._buf_series = [series[rest]] if series.shape[0] > n else []
        self._buf_ids = [ids[rest]] if series.shape[0] > n else []
        self._buf_ts = [ts[rest]] if series.shape[0] > n else []
        self._buf_n = max(0, self._buf_n - n)
        return out

    def _flush(self) -> None:
        n = min(self.cfg.buffer_entries, self._buf_n)
        if n == 0:
            return
        series, ids, ts = self._take_buffer(n)
        run, _ = SortedRun.build(
            series,
            ids,
            self.cfg.summarization,
            block_size=self.cfg.block_size,
            materialized=self.cfg.materialized,
            ts=ts,
            disk=self.disk,
            mem_budget_entries=self.cfg.buffer_entries,
        )
        self.levels.setdefault(0, []).append(run)
        self.n_flushes += 1
        if self.cfg.merge:
            self._maybe_merge(0)

    def flush_all(self) -> None:
        while self._buf_n > 0:
            self._flush()

    def _maybe_merge(self, level: int) -> None:
        runs = self.levels.get(level, [])
        while len(runs) >= self.cfg.growth_factor:
            merged = self._merge_runs(runs[: self.cfg.growth_factor])
            del runs[: self.cfg.growth_factor]
            self.levels.setdefault(level + 1, []).append(merged)
            self._maybe_merge(level + 1)
            runs = self.levels.get(level, [])

    def _merge_runs(self, runs: list[SortedRun]) -> SortedRun:
        """Sort-merge runs (sequential read of inputs + sequential write)."""
        scfg = self.cfg.summarization
        syms = np.concatenate([r.sax for r in runs])
        ids = np.concatenate([r.ids for r in runs])
        ts = np.concatenate([r.ts for r in runs]) if runs[0].ts is not None else None
        series = (
            np.concatenate([r.series for r in runs]) if runs[0].materialized else None
        )
        in_bytes = sum(r.index_bytes() for r in runs)
        self.disk.read_seq(in_bytes)
        merged, _ = SortedRun.from_arrays(
            scfg,
            syms,
            ids,
            block_size=self.cfg.block_size,
            series=series,
            ts=ts,
            disk=None,  # accounted below as one sequential write
            mem_budget_entries=max(1, self.cfg.buffer_entries),
        )
        self.disk.write_seq(merged.index_bytes())
        self.n_merges += 1
        self.merged_bytes += in_bytes
        return merged

    # ---------------------------------------------------------------- query
    def runs_newest_first(self) -> list[SortedRun]:
        out: list[SortedRun] = []
        for level in sorted(self.levels):
            out.extend(reversed(self.levels[level]))
        return out

    def _buffer_scan(self, q, k, bsf, window):
        import heapq

        from .lower_bounds import ed2

        if self._buf_n == 0:
            return bsf
        series = np.concatenate(self._buf_series)
        ids = np.concatenate(self._buf_ids)
        ts = np.concatenate(self._buf_ts)
        m = np.ones(series.shape[0], bool)
        if window is not None:
            m = (ts >= window[0]) & (ts <= window[1])
        if m.any():
            d2 = ed2(np.asarray(q, np.float32), series[m])
            for dist, i in zip(d2, ids[m]):
                item = (-float(dist), int(i))
                if len(bsf) < k:
                    heapq.heappush(bsf, item)
                elif item[0] > bsf[0][0]:
                    heapq.heapreplace(bsf, item)
        return bsf

    def _buffer_scan_batch(self, Q, k, state, window):
        """Batched brute force over the in-memory write buffer."""
        if self._buf_n == 0:
            return state
        series = np.concatenate(self._buf_series)
        ids = np.concatenate(self._buf_ids)
        ts = np.concatenate(self._buf_ts)
        m = np.ones(series.shape[0], bool)
        if window is not None:
            m = (ts >= window[0]) & (ts <= window[1])
        if not m.any():
            return state
        vals, sids = state
        nv, ni = topk_ed2(Q, series[m], k)
        return merge_topk_state(vals, sids, nv, ids[m][ni])

    def knn_exact(self, q, k=1, *, raw: Optional[RawStore] = None, window=None):
        bsf: list = []
        stats = QueryStats()
        bsf = self._buffer_scan(q, k, bsf, window)
        for run in self.runs_newest_first():
            bsf, stats = run.knn_exact(
                q, k, raw=raw, disk=self.disk, bsf=bsf, window=window, stats=stats
            )
        return heap_to_sorted(bsf), stats

    def knn_batch(self, Q, k=1, *, raw: Optional[RawStore] = None, window=None,
                  backend="numpy", time_skip=True):
        """Batched exact kNN across buffer + every live run.

        The batched best-so-far state threads through the runs newest-first
        (exactly like the bsf heap in ``knn_exact``), so distances verified
        against recent runs prune blocks of the older, larger runs for the
        whole batch at once. ``time_skip=False`` keeps entry-level window
        filtering but probes every run (PP). Returns ((m, k) d2, (m, k)
        ids, stats)."""
        Q = np.asarray(Q, np.float32)
        stats = QueryStats()
        state = self._buffer_scan_batch(Q, k, empty_topk_state(Q.shape[0], k), window)
        for run in self.runs_newest_first():
            state, stats = run.knn_batch(
                Q, k, raw=raw, disk=self.disk, window=window, state=state,
                stats=stats, backend=backend, time_skip=time_skip,
            )
        return state[0], state[1], stats

    def knn_approx(self, q, k=1, *, n_blocks=1, raw=None, window=None):
        """Approximate search probes the adjacent blocks of every live run
        (BTP bounds the run count, so this is a bounded number of I/Os)."""
        import heapq

        bsf: list = []
        stats = QueryStats()
        bsf = self._buffer_scan(q, k, bsf, window)
        for run in self.runs_newest_first():
            if window is not None and run.ts is not None and (
                run.t_max < window[0] or run.t_min > window[1]
            ):
                continue
            part, st = run.knn_approx(
                q, k, n_blocks=n_blocks, raw=raw, disk=self.disk, window=window
            )
            stats = stats.merge(st)
            for nd, i in part:
                item = (nd, i)
                if len(bsf) < k:
                    heapq.heappush(bsf, item)
                elif item[0] > bsf[0][0]:
                    heapq.heapreplace(bsf, item)
        return heap_to_sorted(bsf), stats

    def knn_approx_batch(self, Q, k=1, *, n_blocks=1, raw=None, window=None,
                         backend="numpy", time_skip=True):
        """Batched approximate kNN across buffer + every live run.

        The (m, k) best-so-far state folds over the runs newest-first via
        ``merge_topk_state`` — the batched analogue of the per-run heap
        merge in ``knn_approx``. Each run contributes one vectorized key
        seek plus one coalesced sequential block read for the whole batch
        (BTP bounds the run count, so the I/O stays bounded). Results are a
        subset of the exact answer: every query sees only its ``n_blocks``
        adjacent blocks per run, so ``n_blocks`` trades sequential bytes
        for recall@k. ``time_skip=False`` probes every run while keeping
        entry-level window filtering (PP semantics). Returns ((m, k) d2,
        (m, k) ids, stats)."""
        Q = np.asarray(Q, np.float32)
        stats = QueryStats()
        state = self._buffer_scan_batch(Q, k, empty_topk_state(Q.shape[0], k), window)
        for run in self.runs_newest_first():
            if time_skip and window is not None and run.ts is not None and (
                run.t_max < window[0] or run.t_min > window[1]
            ):
                continue
            state, stats = run.knn_approx_batch(
                Q, k, n_blocks=n_blocks, raw=raw, disk=self.disk, window=window,
                state=state, stats=stats, backend=backend,
            )
        return state[0], state[1], stats

    @property
    def n_runs(self) -> int:
        return sum(len(v) for v in self.levels.values())

    def index_bytes(self) -> int:
        return sum(r.index_bytes() for rs in self.levels.values() for r in rs)
