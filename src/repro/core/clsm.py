"""CoconutLSM — the write-optimized log-structured Coconut index.

Incoming series accumulate in an in-memory buffer; each flush external-sorts
the buffer into a level-0 :class:`SortedRun` (sequential write). When a level
collects ``growth_factor`` runs they are sort-merged into one run at the next
level (tiering). Every run carries its time range, which is contiguous in
stream order — this is exactly what Bounded Temporal Partitioning (BTP)
needs: newer data in small recent runs, older data in large merged runs, and
window queries skip runs whose time range misses the window.

The ``growth_factor`` knob trades writes (merge work) against reads (number
of runs a query must probe) — paper §2 "Better Read vs. Write Trade-Offs".

The whole ingest state lives in an epoch-based
:class:`repro.core.run_registry.RunRegistry`: the buffer, in-flight flushes
and per-level runs are one immutable :class:`RunSet` snapshot, and every
flush/merge publishes a NEW snapshot atomically (double-buffered — the
merged run is built off to the side, then one epoch bump swaps it in).
Queries compile a pinned snapshot into one :class:`repro.core.plan.QueryPlan`
— the unflushed entries as a dense source plus one source per live run,
newest first — so a query planned mid-merge keeps verifying against the
runs its epoch saw, while :class:`repro.core.ingest.IngestPipeline` can run
the flush/merge work on a background worker without ever blocking the query
path. The PP/TP/BTP run-level skip is the plan's ``time_skip`` flag, decided
per run at plan build (no run metadata is ever touched).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import numpy as np

from .ctree import RawStore, SortedRun, state_to_list
from .execute import execute
from .io_model import DiskModel
from .plan import DenseSource, QueryPlan, SourceOps, run_time_skipped
from .run_registry import BufferChunk, RunRegistry, RunSet
from .summarization import SummarizationConfig


@dataclasses.dataclass
class CLSMConfig:
    summarization: SummarizationConfig = dataclasses.field(default_factory=SummarizationConfig)
    buffer_entries: int = 4096
    growth_factor: int = 4
    block_size: int = 512
    materialized: bool = False
    merge: bool = True  # False => TP (flush-only temporal partitions)
    # device-arena storage dtype for flushed/merged runs (f32|bf16|int8;
    # None resolves the engine default / REPRO_SCREEN_DTYPE)
    screen_dtype: Optional[str] = None


class CLSM:
    def __init__(self, cfg: CLSMConfig, disk: Optional[DiskModel] = None,
                 storage=None):
        self.cfg = cfg
        self.disk = disk or DiskModel()
        self.registry = RunRegistry()
        # optional crash-consistent file backend
        # (:class:`repro.core.storage.backend.StorageEngine`): WAL-first
        # ingest publication, persisted runs, manifest commits
        self.storage = storage
        self.n_flushes = 0
        self.n_merges = 0
        self.merged_bytes = 0

    # ------------------------------------------------- registry-backed views
    @property
    def levels(self) -> dict[int, list[SortedRun]]:
        """The historical level->runs mapping (a copy of the current
        snapshot — mutate the index through flush/merge publishes, not here)."""
        return self.registry.current().level_dict()

    @property
    def _buf_n(self) -> int:
        return self.registry.current().buffer_n

    # ---------------------------------------------------------------- ingest
    def append_chunk(self, chunk: BufferChunk) -> RunSet:
        """Publish one ingest batch into the buffer — WAL-first when a
        storage engine is attached: the chunk is durable (fsync'd WAL
        record) *before* it becomes query-visible, so an acknowledged batch
        survives a crash at any later point."""
        if self.storage is not None:
            self.storage.append_wal(chunk)
        return self.registry.append_buffer(chunk)

    def insert(self, series: np.ndarray, ids: np.ndarray, ts: np.ndarray) -> None:
        """Synchronous ingest: buffer the batch, flush (and merge) inline
        once the buffer fills. For ingest that must not block the caller on
        compaction, wrap the index in an
        :class:`repro.core.ingest.IngestPipeline` instead."""
        chunk = BufferChunk(
            series=np.asarray(series, np.float32),
            ids=np.asarray(ids, np.int64),
            ts=np.asarray(ts, np.int64),
        )
        self.append_chunk(chunk)
        while self.registry.current().buffer_n >= self.cfg.buffer_entries:
            self._flush()

    def _flush(self) -> None:
        """One flush: take a buffer's worth of entries, external-sort them
        into a level-0 run, publish it, then run any cascading merges.
        Single-writer: only the ingesting thread (or the one pipeline
        worker) calls this — queries are pure snapshot readers."""
        n = min(self.cfg.buffer_entries, self.registry.current().buffer_n)
        if n == 0:
            return
        chunk, _ = self.registry.take_for_flush(n)
        if chunk is None:
            return
        st = self.storage
        if st is not None:
            st.maybe_crash("flush-taken")
        run, _ = SortedRun.build(
            chunk.series,
            chunk.ids,
            self.cfg.summarization,
            block_size=self.cfg.block_size,
            materialized=self.cfg.materialized,
            ts=chunk.ts,
            disk=self.disk,
            mem_budget_entries=self.cfg.buffer_entries,
            screen_dtype=self.cfg.screen_dtype,
        )
        if st is not None:
            # persist BEFORE publish: once queries can route to the run its
            # files exist; the manifest commit below makes them the durable
            # home of these entries (until then the WAL still covers them)
            run = st.persist_run(run)
        # queries planned while the run was sorting saw the chunk as a dense
        # source; this single swap makes later plans see the run instead
        snap = self.registry.publish_flush(chunk, run)
        if st is not None:
            st.commit_flush(chunk.n, snap)
        self.n_flushes += 1
        if self.cfg.merge:
            self._maybe_merge(0)

    def flush_all(self) -> None:
        while self.registry.current().buffer_n > 0:
            self._flush()

    def _maybe_merge(self, level: int) -> None:
        """Cascading tiered merges, iteratively (a worklist, not recursion:
        a deep cascade must not scale the Python stack with the level
        count). Each merge builds its output off to the side and commits
        with one ``publish_merge`` epoch bump; the replaced runs go to
        deferred retirement so pinned queries keep their sources."""
        gf = self.cfg.growth_factor
        pending = [level]
        while pending:
            lv = pending.pop()
            runs = self.registry.current().level_runs(lv)
            if len(runs) < gf:
                continue
            victims = list(runs[:gf])
            merged = self._merge_runs(victims)
            st = self.storage
            if st is not None:
                merged = st.persist_run(merged)
            snap = self.registry.publish_merge(lv, victims, merged)
            if st is not None:
                st.commit_merge(snap)
            # the target level may now overflow, and this one may still
            # hold >= gf runs — re-check both (next level first, matching
            # the old recursive order)
            pending.extend([lv, lv + 1])

    def _merge_runs(self, runs: list[SortedRun]) -> SortedRun:
        """Sort-merge runs (sequential read of inputs + sequential write)."""
        scfg = self.cfg.summarization
        syms = np.concatenate([r.sax for r in runs])
        ids = np.concatenate([r.ids for r in runs])
        ts = np.concatenate([r.ts for r in runs]) if runs[0].ts is not None else None
        series = (
            np.concatenate([r.series for r in runs]) if runs[0].materialized else None
        )
        in_bytes = sum(r.index_bytes() for r in runs)
        self.disk.read_seq(in_bytes)
        merged, _ = SortedRun.from_arrays(
            scfg,
            syms,
            ids,
            block_size=self.cfg.block_size,
            series=series,
            ts=ts,
            disk=None,  # accounted below as one sequential write
            mem_budget_entries=max(1, self.cfg.buffer_entries),
            screen_dtype=self.cfg.screen_dtype,
        )
        self.disk.write_seq(merged.index_bytes())
        self.n_merges += 1
        self.merged_bytes += in_bytes
        return merged

    # ---------------------------------------------------------------- query
    def _pinned(self, snapshot: Optional[RunSet]):
        """The query-side snapshot context: pin a fresh epoch, or pass an
        explicitly provided snapshot through (the caller pinned it)."""
        if snapshot is not None:
            return contextlib.nullcontext(snapshot)
        return self.registry.pin()

    def runs_newest_first(self, snapshot: Optional[RunSet] = None) -> list[SortedRun]:
        return (snapshot or self.registry.current()).runs_newest_first()

    def _buffer_source(self, snapshot: RunSet) -> Optional[DenseSource]:
        """The snapshot's unflushed entries (write buffer + chunks whose
        flush is still in flight) as one brute-force plan source."""
        chunks = snapshot.dense_chunks()
        if not chunks:
            return None
        series = np.concatenate([c.series for c in chunks])
        ids = np.concatenate([c.ids for c in chunks])
        ts = None
        if all(c.ts is not None for c in chunks):
            ts = np.concatenate([c.ts for c in chunks])
        return DenseSource(
            ops=SourceOps(ids=ids, ts=ts, fetch=lambda p, s=series: s[p]),
            n=series.shape[0],
        )

    def plan(
        self,
        Q: np.ndarray,
        *,
        tier: str = "exact",
        n_blocks: int = 1,
        raw: Optional[RawStore] = None,
        window: Optional[tuple[int, int]] = None,
        time_skip: bool = True,
        backend: str = "device",
        snapshot: Optional[RunSet] = None,
    ) -> QueryPlan:
        """Compile a query batch into one plan over buffer + live runs.

        The plan is built against ONE immutable :class:`RunSet` snapshot
        (``snapshot``, or the registry's current one) and records its epoch:
        every source closure resolves against that snapshot's runs, so the
        plan stays well-defined while background flushes/merges publish new
        epochs. Runs go in newest-first so the executor's folded state
        prunes the older, larger runs hardest. ``time_skip`` is the
        PP/TP/BTP flag: False (PP) plans every run and relies on
        entry-level window filtering; True (TP/BTP) drops runs whose
        [t_min, t_max] misses the window at plan build — side-effect-free
        either way."""
        snapshot = snapshot or self.registry.current()
        sources: list = []
        pruned = 0
        buf = self._buffer_source(snapshot)
        if buf is not None:
            sources.append(buf)
        for run in snapshot.runs_newest_first():
            if run.n == 0:
                continue
            skip = run_time_skipped(run.t_min, run.t_max, window,
                                    time_skip and run.ts is not None)
            if tier == "exact":
                if skip:
                    pruned += run.n_blocks
                    continue
                sources.append(run.plan_exact(Q, raw=raw, disk=self.disk))
            else:
                if skip:
                    continue
                sources.append(run.plan_approx(Q, n_blocks=n_blocks, raw=raw,
                                               disk=self.disk, backend=backend))
        return QueryPlan(m=len(Q), sources=sources, window=window,
                         time_skip=time_skip, pruned_blocks=pruned,
                         epoch=snapshot.epoch)

    def knn_exact(self, q, k=1, *, raw: Optional[RawStore] = None, window=None,
                  time_skip=True):
        """Scalar exact kNN over buffer + runs — a batch-of-1 plan through
        the shared executor. Returns ([(d2, id)] ascending, stats)."""
        vals, gids, stats = self.knn_batch(
            np.asarray(q, np.float32).reshape(1, -1), k, raw=raw, window=window,
            time_skip=time_skip,
        )
        return state_to_list(vals[0], gids[0]), stats

    def knn_batch(self, Q, k=1, *, raw: Optional[RawStore] = None, window=None,
                  backend="device", time_skip=True, shard=None, mesh=None,
                  snapshot=None):
        """Batched exact kNN across buffer + every live run.

        The batched best-so-far state threads through the runs newest-first
        (exactly like the bsf heap did), so distances verified against
        recent runs prune blocks of the older, larger runs for the whole
        batch at once. The query pins its registry epoch for its duration:
        concurrently merged-away runs stay alive (and their device arenas
        warm) until the pin drops, and the answers are snapshot-consistent
        — brute force over the pinned epoch's entries, whatever ingest
        publishes meanwhile. ``time_skip=False`` keeps entry-level window
        filtering but probes every run (PP). ``shard="mesh"`` executes the
        plan on the device mesh (queries x runs 2-D ``shard_map``).
        Returns ((m, k) d2, (m, k) ids, stats)."""
        Q = np.asarray(Q, np.float32)
        with self._pinned(snapshot) as snap:
            plan = self.plan(Q, tier="exact", raw=raw, window=window,
                             time_skip=time_skip, snapshot=snap)
            (vals, gids), stats = execute(plan, Q, k, backend=backend,
                                          shard=shard, mesh=mesh)
        return vals, gids, stats

    def knn_approx(self, q, k=1, *, n_blocks=1, raw=None, window=None,
                   time_skip=True):
        """Scalar approximate kNN: probe the adjacent blocks of every live
        run (BTP bounds the run count, so this is a bounded number of
        I/Os). Batch-of-1 plan; returns ([(d2, id)] ascending, stats)."""
        vals, gids, stats = self.knn_approx_batch(
            np.asarray(q, np.float32).reshape(1, -1), k, n_blocks=n_blocks,
            raw=raw, window=window, time_skip=time_skip,
        )
        return state_to_list(vals[0], gids[0]), stats

    def knn_approx_batch(self, Q, k=1, *, n_blocks=1, raw=None, window=None,
                         backend="device", time_skip=True, snapshot=None):
        """Batched approximate kNN across buffer + every live run.

        The (m, k) best-so-far state folds over the runs newest-first — the
        batched analogue of the per-run heap merge. Each run contributes
        one vectorized key seek plus one coalesced sequential block read
        for the whole batch (BTP bounds the run count, so the I/O stays
        bounded). Results are a subset of the exact answer: every query
        sees only its ``n_blocks`` adjacent blocks per run, so ``n_blocks``
        trades sequential bytes for recall@k. Pins its registry epoch like
        ``knn_batch``. ``time_skip=False`` probes every run while keeping
        entry-level window filtering (PP semantics). Returns ((m, k) d2,
        (m, k) ids, stats)."""
        Q = np.asarray(Q, np.float32)
        with self._pinned(snapshot) as snap:
            plan = self.plan(Q, tier="approx", n_blocks=n_blocks, raw=raw,
                             window=window, time_skip=time_skip,
                             backend=backend, snapshot=snap)
            (vals, gids), stats = execute(plan, Q, k, backend=backend)
        return vals, gids, stats

    @property
    def n_runs(self) -> int:
        return self.registry.current().n_runs

    def index_bytes(self) -> int:
        return sum(r.index_bytes()
                   for r in self.registry.current().runs_newest_first())
