"""Distributed Coconut — the paper's pipeline mapped onto a TPU pod mesh.

The paper's two-pass *external sort* (RAM budget vs disk bandwidth) becomes
a *sample-sort* across the mesh (HBM budget vs ICI bisection):

  1. local summarize + sortable-key (the Pallas ingest kernels);
  2. sample local keys, ``all_gather`` the samples, derive range splitters;
  3. bucket every entry by splitter range and exchange buckets with one
     ``all_to_all`` (fixed capacity + sentinel padding — SPMD-friendly);
  4. local bitonic ``lax.sort`` on the received bucket.

The result is a *globally sorted, contiguously sharded* index: shard i holds
a contiguous key range that precedes shard i+1's — exactly the compact &
contiguous layout the paper builds on disk, with the "pod" axis simply the
outermost segment of the range. Bucketing uses the most-significant key word
only, so equal-word ties stay on one shard and global order is preserved.

Queries follow the paper's prune-then-verify plan: replicate the query
batch, compute MINDIST lower bounds against every local entry (VPU), keep
the top-V candidates per query by bound, verify true distances (MXU matmul
form), and reduce a global top-k with one small ``all_gather``. With fixed
verification budget V this is the SPMD analogue of best-first search; V >=
true rank makes it exact (property-tested at small scale).

All functions are written to be used inside ``jax.shard_map`` over an
arbitrary mesh-axis tuple, so the same code runs on the (16,16) single-pod
and (2,16,16) multi-pod production meshes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from .summarization import SummarizationConfig, breakpoints
from ..compat import axis_size as _compat_axis_size, shard_map
from ..kernels import ref

_SENTINEL = jnp.uint32(0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class DistBuildConfig:
    summarization: SummarizationConfig
    samples_per_shard: int = 64
    capacity_slack: float = 2.0  # bucket capacity = local_n/n_shards * slack
    materialized: bool = True  # carry raw series through the exchange


def _axis_size(axis_names) -> int:
    if isinstance(axis_names, str):
        return _compat_axis_size(axis_names)
    size = 1
    for a in axis_names:
        size *= _compat_axis_size(a)
    return size


def _summarize_local(series: jnp.ndarray, cfg: SummarizationConfig):
    """Device-side summarize path (ref semantics == the Pallas kernels;
    the compiled TPU build swaps in kernels.ops.summarize)."""
    p = ref.paa_ref(series, cfg.n_segments)
    bps = jnp.asarray(breakpoints(cfg.card_bits))
    sym = ref.sax_ref(p, bps)
    keys = ref.pack_keys_ref(sym, cfg.card_bits, cfg.key_words)
    return p, sym, keys


def build_local(series, ids, cfg: DistBuildConfig, axis_names):
    """shard_map body: sample-sort build. series (ln, n) local shard.

    Returns dict of local sorted arrays + diagnostics; the concatenation of
    shard outputs (shard order) is globally key-sorted.
    """
    scfg = cfg.summarization
    ln = series.shape[0]
    nsh = _axis_size(axis_names)
    _, sym, keys = _summarize_local(series, scfg)
    w0 = keys[:, 0]

    # --- splitters from gathered samples (pass 1 of the "external sort")
    stride = max(1, ln // cfg.samples_per_shard)
    samp = lax.dynamic_slice_in_dim(w0[::stride], 0, min(cfg.samples_per_shard, ln))
    allsamp = lax.all_gather(samp, axis_names, tiled=True)
    ssorted = jnp.sort(allsamp)
    qidx = (jnp.arange(1, nsh) * allsamp.shape[0]) // nsh
    splitters = ssorted[qidx]  # (nsh-1,) uint32

    # --- bucket by most-significant key word (ties stay together)
    bucket = jnp.searchsorted(splitters, w0, side="right").astype(jnp.int32)
    cap = max(1, int(ln / nsh * cfg.capacity_slack))
    order = jnp.argsort(bucket, stable=True)
    sbucket = bucket[order]
    start = jnp.searchsorted(sbucket, jnp.arange(nsh, dtype=jnp.int32), side="left")
    pos = jnp.arange(ln, dtype=jnp.int32) - start[sbucket]
    overflow = jnp.sum(pos >= cap)
    slot = jnp.minimum(pos, cap)  # slot `cap` is the shared trash slot

    def scatter(payload, fill):
        buf = jnp.full((nsh, cap + 1) + payload.shape[1:], fill, payload.dtype)
        buf = buf.at[sbucket, slot].set(payload[order])
        return buf[:, :cap]

    send_keys = scatter(keys, _SENTINEL)
    send_ids = scatter(ids.astype(jnp.int32), jnp.int32(-1))
    send_sym = scatter(sym.astype(jnp.int32), jnp.int32(0))
    send_inval = scatter(jnp.zeros((ln,), jnp.int32), jnp.int32(1))
    parts = [send_keys, send_ids, send_sym, send_inval]
    if cfg.materialized:
        parts.append(scatter(series.astype(jnp.float32), jnp.float32(0)))

    # --- one all_to_all bucket exchange (pass 2: the "merge" traffic)
    recv = [
        lax.all_to_all(pt, axis_names, split_axis=0, concat_axis=0, tiled=False)
        for pt in parts
    ]
    rkeys, rids, rsym, rinval = (r.reshape((nsh * cap,) + r.shape[2:]) for r in recv[:4])
    rseries = recv[4].reshape(nsh * cap, -1) if cfg.materialized else None

    # --- local sort; invalid-flag first key pushes sentinels to the end.
    # Sort a permutation (rank-1 operands only), then gather the payloads.
    rn = nsh * cap
    iota = jnp.arange(rn, dtype=jnp.int32)
    operands = (rinval,) + tuple(rkeys[:, i] for i in range(rkeys.shape[1])) + (iota,)
    sorted_all = lax.sort(operands, num_keys=1 + rkeys.shape[1], dimension=0)
    perm = sorted_all[-1]
    nw = rkeys.shape[1]
    out = {
        "invalid": sorted_all[0],
        "keys": jnp.stack(sorted_all[1 : 1 + nw], axis=1),
        "ids": rids[perm],
        "sym": rsym[perm],
        "n_valid": jnp.sum(rinval == 0).astype(jnp.int32)[None],
        "overflow": lax.psum(overflow, axis_names),
    }
    if cfg.materialized:
        out["series"] = rseries[perm]
    return out


def query_local(
    index: dict,
    queries: jnp.ndarray,
    cfg: DistBuildConfig,
    axis_names,
    *,
    k: int = 10,
    verify_budget: int = 128,
):
    """shard_map body: prune-by-LB then verify-top-V then global top-k.

    index: the local shard produced by :func:`build_local` (materialized).
    queries: (m, n) replicated. Returns ((m, k) d2, (m, k) global ids),
    identical on every shard.
    """
    scfg = cfg.summarization
    qp = ref.paa_ref(queries, scfg.n_segments)  # (m, w)
    bps = jnp.asarray(breakpoints(scfg.card_bits))
    big = jnp.float32(1e30)
    lo_e = jnp.concatenate([jnp.array([-big]), bps])
    hi_e = jnp.concatenate([bps, jnp.array([big])])
    sym = index["sym"]
    lo = lo_e[sym]  # (ln, w)
    hi = hi_e[sym]
    inval = index["invalid"].astype(bool)

    below = jnp.maximum(lo[None] - qp[:, None, :], 0.0)
    above = jnp.maximum(qp[:, None, :] - hi[None], 0.0)
    dseg = jnp.maximum(below, above)
    lb2 = scfg.segment_len * jnp.sum(dseg * dseg, axis=-1)  # (m, ln)
    lb2 = jnp.where(inval[None, :], jnp.inf, lb2)

    v = min(verify_budget, sym.shape[0])
    _, cand = lax.top_k(-lb2, v)  # (m, v) local candidate positions
    cseries = index["series"][cand]  # (m, v, n)
    diff = cseries - queries[:, None, :]
    d2 = jnp.sum(diff * diff, axis=-1)  # (m, v)
    d2 = jnp.where(inval[cand], jnp.inf, d2)
    kk = min(k, v)
    nd2, nidx = lax.top_k(-d2, kk)
    local_d2 = -nd2  # (m, kk) ascending? top_k gives descending of -d2 => ascending d2
    local_ids = jnp.take_along_axis(index["ids"][cand], nidx, axis=1)

    # global reduce: gather every shard's top-k and re-select
    gd2 = lax.all_gather(local_d2, axis_names, tiled=False)  # (nsh, m, kk)
    gids = lax.all_gather(local_ids, axis_names, tiled=False)
    nsh = gd2.shape[0]
    gd2 = jnp.moveaxis(gd2, 0, 1).reshape(qp.shape[0], nsh * kk)
    gids = jnp.moveaxis(gids, 0, 1).reshape(qp.shape[0], nsh * kk)
    fd2, fidx = lax.top_k(-gd2, min(k, nsh * kk))
    return -fd2, jnp.take_along_axis(gids, fidx, axis=1)


# --------------------------------------------------------------------------
# jit entry points over a mesh (used by launch/dryrun.py and tests)
# --------------------------------------------------------------------------
def make_build_fn(mesh, axes: Sequence[str], cfg: DistBuildConfig):
    """Returns jit(build) with series/ids sharded over ``axes`` (flattened)."""
    spec_in = P(tuple(axes))
    out_specs = {
        "invalid": spec_in, "keys": spec_in, "ids": spec_in, "sym": spec_in,
        "n_valid": spec_in, "overflow": P(),
    }
    if cfg.materialized:
        out_specs["series"] = spec_in

    @jax.jit
    def build(series, ids):
        f = shard_map(
            functools.partial(build_local, cfg=cfg, axis_names=tuple(axes)),
            mesh=mesh,
            in_specs=(spec_in, spec_in),
            out_specs=out_specs,
        )
        return f(series, ids)

    return build


def make_query_fn(mesh, axes: Sequence[str], cfg: DistBuildConfig, *, k=10, verify_budget=128):
    spec_sh = P(tuple(axes))
    in_specs = (
        {"invalid": spec_sh, "keys": spec_sh, "ids": spec_sh, "sym": spec_sh,
         "n_valid": spec_sh, "overflow": P(), "series": spec_sh},
        P(),  # queries replicated
    )

    @jax.jit
    def query(index, queries):
        f = shard_map(
            functools.partial(
                query_local, cfg=cfg, axis_names=tuple(axes), k=k,
                verify_budget=verify_budget,
            ),
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(), P()),
            # outputs are all_gather-reduced, i.e. bitwise-identical on every
            # shard; the static replication checker cannot infer that.
            check_vma=False,
        )
        return f(index, queries)

    return query
