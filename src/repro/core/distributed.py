"""Distributed Coconut — the paper's pipeline mapped onto a TPU pod mesh.

The paper's two-pass *external sort* (RAM budget vs disk bandwidth) becomes
a *sample-sort* across the mesh (HBM budget vs ICI bisection):

  1. local summarize + sortable-key (the Pallas ingest kernels);
  2. sample local keys, ``all_gather`` the samples, derive range splitters;
  3. bucket every entry by splitter range and exchange buckets with one
     ``all_to_all`` (fixed capacity + sentinel padding — SPMD-friendly);
  4. local bitonic ``lax.sort`` on the received bucket.

The result is a *globally sorted, contiguously sharded* index: shard i holds
a contiguous key range that precedes shard i+1's — exactly the compact &
contiguous layout the paper builds on disk, with the "pod" axis simply the
outermost segment of the range. Bucketing uses the most-significant key word
only, so equal-word ties stay on one shard and global order is preserved.

Queries follow the paper's prune-then-verify plan: replicate the query
batch, compute MINDIST lower bounds against every local entry (VPU), keep
the top-V candidates per query by bound, verify true distances (MXU matmul
form), and reduce a global top-k with one small ``all_gather``. With fixed
verification budget V this is the SPMD analogue of best-first search; V >=
true rank makes it exact (property-tested at small scale).

All functions are written to be used inside ``jax.shard_map`` over an
arbitrary mesh-axis tuple, so the same code runs on the (16,16) single-pod
and (2,16,16) multi-pod production meshes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .summarization import SummarizationConfig, breakpoints
from ..compat import axis_size as _compat_axis_size, make_mesh, shard_map
from ..kernels import ref

_SENTINEL = jnp.uint32(0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class DistBuildConfig:
    summarization: SummarizationConfig
    samples_per_shard: int = 64
    capacity_slack: float = 2.0  # bucket capacity = local_n/n_shards * slack
    materialized: bool = True  # carry raw series through the exchange


def _axis_size(axis_names) -> int:
    if isinstance(axis_names, str):
        return _compat_axis_size(axis_names)
    size = 1
    for a in axis_names:
        size *= _compat_axis_size(a)
    return size


def _summarize_local(series: jnp.ndarray, cfg: SummarizationConfig):
    """Device-side summarize path (ref semantics == the Pallas kernels;
    the compiled TPU build swaps in kernels.ops.summarize)."""
    p = ref.paa_ref(series, cfg.n_segments)
    bps = jnp.asarray(breakpoints(cfg.card_bits))
    sym = ref.sax_ref(p, bps)
    keys = ref.pack_keys_ref(sym, cfg.card_bits, cfg.key_words)
    return p, sym, keys


def build_local(series, ids, cfg: DistBuildConfig, axis_names):
    """shard_map body: sample-sort build. series (ln, n) local shard.

    Returns dict of local sorted arrays + diagnostics; the concatenation of
    shard outputs (shard order) is globally key-sorted.
    """
    scfg = cfg.summarization
    ln = series.shape[0]
    nsh = _axis_size(axis_names)
    _, sym, keys = _summarize_local(series, scfg)
    w0 = keys[:, 0]

    # --- splitters from gathered samples (pass 1 of the "external sort")
    stride = max(1, ln // cfg.samples_per_shard)
    samp = lax.dynamic_slice_in_dim(w0[::stride], 0, min(cfg.samples_per_shard, ln))
    allsamp = lax.all_gather(samp, axis_names, tiled=True)
    ssorted = jnp.sort(allsamp)
    qidx = (jnp.arange(1, nsh, dtype=jnp.int32) * allsamp.shape[0]) // nsh
    splitters = ssorted[qidx]  # (nsh-1,) uint32

    # --- bucket by most-significant key word (ties stay together)
    bucket = jnp.searchsorted(splitters, w0, side="right").astype(jnp.int32)
    cap = max(1, int(ln / nsh * cfg.capacity_slack))
    order = jnp.argsort(bucket, stable=True)
    sbucket = bucket[order]
    start = jnp.searchsorted(sbucket, jnp.arange(nsh, dtype=jnp.int32), side="left")
    pos = jnp.arange(ln, dtype=jnp.int32) - start[sbucket]
    overflow = jnp.sum(pos >= cap)
    slot = jnp.minimum(pos, cap)  # slot `cap` is the shared trash slot

    def scatter(payload, fill):
        buf = jnp.full((nsh, cap + 1) + payload.shape[1:], fill, payload.dtype)
        buf = buf.at[sbucket, slot].set(payload[order])
        return buf[:, :cap]

    send_keys = scatter(keys, _SENTINEL)
    send_ids = scatter(ids.astype(jnp.int32), jnp.int32(-1))
    send_sym = scatter(sym.astype(jnp.int32), jnp.int32(0))
    send_inval = scatter(jnp.zeros((ln,), jnp.int32), jnp.int32(1))
    parts = [send_keys, send_ids, send_sym, send_inval]
    if cfg.materialized:
        parts.append(scatter(series.astype(jnp.float32), jnp.float32(0)))

    # --- one all_to_all bucket exchange (pass 2: the "merge" traffic)
    recv = [
        lax.all_to_all(pt, axis_names, split_axis=0, concat_axis=0, tiled=False)
        for pt in parts
    ]
    rkeys, rids, rsym, rinval = (r.reshape((nsh * cap,) + r.shape[2:]) for r in recv[:4])
    rseries = recv[4].reshape(nsh * cap, -1) if cfg.materialized else None

    # --- local sort; invalid-flag first key pushes sentinels to the end.
    # Sort a permutation (rank-1 operands only), then gather the payloads.
    rn = nsh * cap
    iota = jnp.arange(rn, dtype=jnp.int32)
    operands = (rinval,) + tuple(rkeys[:, i] for i in range(rkeys.shape[1])) + (iota,)
    sorted_all = lax.sort(operands, num_keys=1 + rkeys.shape[1], dimension=0)
    perm = sorted_all[-1]
    nw = rkeys.shape[1]
    out = {
        "invalid": sorted_all[0],
        "keys": jnp.stack(sorted_all[1 : 1 + nw], axis=1),
        "ids": rids[perm],
        "sym": rsym[perm],
        "n_valid": jnp.sum(rinval == 0).astype(jnp.int32)[None],
        "overflow": lax.psum(overflow, axis_names),
    }
    if cfg.materialized:
        out["series"] = rseries[perm]
    return out


def query_local(
    index: dict,
    queries: jnp.ndarray,
    cfg: DistBuildConfig,
    axis_names,
    *,
    k: int = 10,
    verify_budget: int = 128,
):
    """shard_map body: prune-by-LB then verify-top-V then global top-k.

    index: the local shard produced by :func:`build_local` (materialized).
    queries: (m, n) replicated. Returns ((m, k) d2, (m, k) global ids),
    identical on every shard.
    """
    scfg = cfg.summarization
    qp = ref.paa_ref(queries, scfg.n_segments)  # (m, w)
    bps = jnp.asarray(breakpoints(scfg.card_bits))
    big = jnp.float32(1e30)
    lo_e = jnp.concatenate([jnp.array([-big]), bps])
    hi_e = jnp.concatenate([bps, jnp.array([big])])
    sym = index["sym"]
    lo = lo_e[sym]  # (ln, w)
    hi = hi_e[sym]
    inval = index["invalid"].astype(bool)

    below = jnp.maximum(lo[None] - qp[:, None, :], 0.0)
    above = jnp.maximum(qp[:, None, :] - hi[None], 0.0)
    dseg = jnp.maximum(below, above)
    lb2 = scfg.segment_len * jnp.sum(dseg * dseg, axis=-1)  # (m, ln)
    lb2 = jnp.where(inval[None, :], jnp.inf, lb2)

    v = min(verify_budget, sym.shape[0])
    _, cand = lax.top_k(-lb2, v)  # (m, v) local candidate positions
    cseries = index["series"][cand]  # (m, v, n)
    diff = cseries - queries[:, None, :]
    d2 = jnp.sum(diff * diff, axis=-1)  # (m, v)
    d2 = jnp.where(inval[cand], jnp.inf, d2)
    kk = min(k, v)
    nd2, nidx = lax.top_k(-d2, kk)
    local_d2 = -nd2  # (m, kk) ascending? top_k gives descending of -d2 => ascending d2
    local_ids = jnp.take_along_axis(index["ids"][cand], nidx, axis=1)

    # global reduce: gather every shard's top-k and re-select
    gd2 = lax.all_gather(local_d2, axis_names, tiled=False)  # (nsh, m, kk)
    gids = lax.all_gather(local_ids, axis_names, tiled=False)
    nsh = gd2.shape[0]
    gd2 = jnp.moveaxis(gd2, 0, 1).reshape(qp.shape[0], nsh * kk)
    gids = jnp.moveaxis(gids, 0, 1).reshape(qp.shape[0], nsh * kk)
    fd2, fidx = lax.top_k(-gd2, min(k, nsh * kk))
    return -fd2, jnp.take_along_axis(gids, fidx, axis=1)


# --------------------------------------------------------------------------
# jit entry points over a mesh (used by launch/dryrun.py and tests)
# --------------------------------------------------------------------------
def make_build_fn(mesh, axes: Sequence[str], cfg: DistBuildConfig):
    """Returns jit(build) with series/ids sharded over ``axes`` (flattened)."""
    spec_in = P(tuple(axes))
    out_specs = {
        "invalid": spec_in, "keys": spec_in, "ids": spec_in, "sym": spec_in,
        "n_valid": spec_in, "overflow": P(),
    }
    if cfg.materialized:
        out_specs["series"] = spec_in

    @jax.jit
    def build(series, ids):
        f = shard_map(
            functools.partial(build_local, cfg=cfg, axis_names=tuple(axes)),
            mesh=mesh,
            in_specs=(spec_in, spec_in),
            out_specs=out_specs,
        )
        return f(series, ids)

    return build


# --------------------------------------------------------------------------
# mesh-sharded batch serving: queries x runs 2-D screening for the executor
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=1)
def default_batch_mesh():
    """The default (queries, runs) serving mesh over every local device:
    the query axis gets the largest power-of-two <= sqrt(n_devices) that
    divides the device count, the runs axis the rest."""
    n = jax.device_count()
    qs = 1
    while (qs * 2) * (qs * 2) <= n and n % (qs * 2) == 0:
        qs *= 2
    return make_mesh((qs, n // qs), ("q", "r"))


_mesh_topk_cache: dict = {}


def _mesh_topk_fn(mesh, ksel: int):
    """jit'd shard_map: query rows sharded over the first mesh axis, the
    stacked candidate groups over the remaining axes. Each device screens
    its (query shard, candidate shard) tile with one f32 matmul-form
    distance pass and a local top-ksel; the per-shard slates fold with ONE
    ``all_gather`` over the runs axes plus a re-select — the device-side
    analogue of :func:`repro.core.execute.merge_topk_state`."""
    key = (mesh, ksel)
    fn = _mesh_topk_cache.get(key)
    if fn is not None:
        return fn
    axes = mesh.axis_names
    axis_q, axes_r = axes[0], tuple(axes[1:])

    def body(q, x):
        xl = x.reshape(-1, x.shape[-1])  # (E_local, n)
        g = q @ xl.T  # f32 matmul-form screen — the MXU pass
        qsq = jnp.sum(q * q, axis=1)
        xsq = jnp.sum(xl * xl, axis=1)
        d2 = qsq[:, None] + xsq[None, :] - 2.0 * g
        kk = min(ksel, xl.shape[0])
        nv, ni = lax.top_k(-d2, kk)  # (mq, kk) of -d2, local rows
        if not axes_r:
            return -nv, ni.astype(jnp.int32)
        ridx = jnp.int32(0)
        for a in axes_r:  # flatten the runs axes into one shard index
            ridx = ridx * _compat_axis_size(a) + lax.axis_index(a)
        gi = ni.astype(jnp.int32) + ridx * xl.shape[0]
        av = lax.all_gather(nv, axes_r, tiled=False)  # (nr, mq, kk)
        ai = lax.all_gather(gi, axes_r, tiled=False)
        nr = av.shape[0]
        mq = q.shape[0]
        av = jnp.moveaxis(av, 0, 1).reshape(mq, nr * kk)
        ai = jnp.moveaxis(ai, 0, 1).reshape(mq, nr * kk)
        fv, fi = lax.top_k(av, min(ksel, nr * kk))  # fold the shard slates
        return -fv, jnp.take_along_axis(ai, fi, axis=1)

    x_spec = P(axes_r) if axes_r else P()
    f = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis_q), x_spec),
        out_specs=(P(axis_q), P(axis_q)),
        # runs-axis outputs are all_gather-reduced (identical on every r
        # shard); the static replication checker cannot infer that.
        check_vma=False,
    )
    fn = jax.jit(f)
    _mesh_topk_cache[key] = fn
    return fn


def mesh_topk_candidates(Q, X, ksel: int, *, mesh=None):
    """Screen a query batch against a candidate table on the device mesh.

    Q (m, n) f32 queries, X (C, n) f32 candidates. The query batch is
    sharded over the mesh's first axis and the candidates over the rest
    (queries x runs 2-D parallelism); each device computes f32 matmul-form
    distances for its tile, and per-shard top-ksel slates fold with one
    ``all_gather``. Returns ((m, ksel) d2 f32, (m, ksel) rows into X,
    -1 = invalid) as host arrays — callers re-rank the slate exactly in
    f64 (see ``execute._rerank_slate``), so the f32 screen never decides
    final distances.

    Candidate rows are padded to a power-of-two-per-shard grid with +large
    sentinels so jit sees a handful of stable shapes across serving
    batches."""
    mesh = mesh if mesh is not None else default_batch_mesh()
    axes = mesh.axis_names
    qs = mesh.shape[axes[0]]
    rs = 1
    for a in axes[1:]:
        rs *= mesh.shape[a]
    Q = np.asarray(Q, np.float32)
    X = np.asarray(X, np.float32)
    m, n = Q.shape
    c = X.shape[0]
    if m == 0 or c == 0:
        return np.zeros((m, 0), np.float32), np.full((m, 0), -1, np.int64)
    ksel = min(ksel, c)
    e = -(-c // rs)
    e = max(8, 1 << (e - 1).bit_length())  # pow2 bucket: few jit shapes
    xp = np.full((rs * e, n), 1e15, np.float32)
    xp[:c] = X
    mp = -(-m // qs) * qs
    qp = np.zeros((mp, n), np.float32)
    qp[:m] = Q
    d2, rows = _mesh_topk_fn(mesh, ksel)(jnp.asarray(qp), xp.reshape(rs, e, n))
    d2 = np.asarray(d2)[:m]
    rows = np.asarray(rows).astype(np.int64)[:m]
    return d2, np.where(rows >= c, -1, rows)


def valid_entries(index: dict) -> tuple[np.ndarray, np.ndarray]:
    """Host-side extraction of the valid (non-sentinel) entries of a
    sample-sorted build, in global key order — the bridge from the
    distributed build to the mesh batch executor: the returned (series,
    ids) feed :func:`mesh_topk_candidates` directly, with each build
    shard's contiguous key range landing on one runs-axis shard."""
    inval = np.asarray(index["invalid"]).astype(bool)
    return (
        np.asarray(index["series"])[~inval],
        np.asarray(index["ids"])[~inval].astype(np.int64),
    )


def make_query_fn(mesh, axes: Sequence[str], cfg: DistBuildConfig, *, k=10, verify_budget=128):
    spec_sh = P(tuple(axes))
    in_specs = (
        {"invalid": spec_sh, "keys": spec_sh, "ids": spec_sh, "sym": spec_sh,
         "n_valid": spec_sh, "overflow": P(), "series": spec_sh},
        P(),  # queries replicated
    )

    @jax.jit
    def query(index, queries):
        f = shard_map(
            functools.partial(
                query_local, cfg=cfg, axis_names=tuple(axes), k=k,
                verify_budget=verify_budget,
            ),
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(), P()),
            # outputs are all_gather-reduced, i.e. bitwise-identical on every
            # shard; the static replication checker cannot infer that.
            check_vma=False,
        )
        return f(index, queries)

    return query
