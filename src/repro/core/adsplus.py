"""ADSFull / ADS+ baseline — the state of the art the paper demos against.

A top-down-inserted iSAX tree: the root fans out on the first bit of every
segment; an overflowing leaf splits by promoting the cardinality of one
segment (round-robin). Every insert descends to a leaf — one random page
read + one random page write per entry (the cost profile Coconut removes).

Modes:
  * ``full``      — ADSFull: leaves store the raw series (materialized).
  * ``adaptive``  — ADS+: construction stores only summarizations with a
    large leaf threshold (fast, skeletal build); queries adaptively split
    the leaves they touch down to ``query_leaf_size`` and fetch raw series
    lazily from the RawStore (random reads at query time).

Implementation note: inserts are batched and partitioned vectorially for
host speed, but the I/O accounting matches per-entry top-down insertion.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np

from .ctree import (
    QueryStats,
    RawStore,
    empty_topk_state,
    heap_to_sorted,
    merge_topk_state,
)
from .io_model import DiskModel
from .lower_bounds import ed2, mindist_paa_sax2, mindist_region2, topk_ed2
from .summarization import SummarizationConfig, paa, sax_from_paa


@dataclasses.dataclass
class ADSConfig:
    summarization: SummarizationConfig = dataclasses.field(default_factory=SummarizationConfig)
    leaf_size: int = 1024
    mode: str = "full"  # full | adaptive
    query_leaf_size: int = 128  # adaptive-split target during queries


class _Node:
    __slots__ = ("card", "prefix", "children", "split_seg", "sax", "ids", "ts", "series", "n")

    def __init__(self, card: np.ndarray, prefix: np.ndarray):
        self.card = card  # (w,) bits used per segment at this node
        self.prefix = prefix  # (w,) symbol prefix (card bits per segment)
        self.children: Optional[dict] = None  # split bit -> node
        self.split_seg: int = -1
        self.sax: Optional[np.ndarray] = None
        self.ids: Optional[np.ndarray] = None
        self.ts: Optional[np.ndarray] = None
        self.series: Optional[np.ndarray] = None
        self.n = 0

    @property
    def is_leaf(self) -> bool:
        return self.children is None


class ADSIndex:
    def __init__(self, cfg: ADSConfig, disk: Optional[DiskModel] = None):
        self.cfg = cfg
        self.disk = disk or DiskModel()
        w = cfg.summarization.n_segments
        self.root_children: dict[tuple, _Node] = {}
        self._w = w
        self._c = cfg.summarization.card_bits
        self.n = 0
        self.n_splits = 0

    # ---------------------------------------------------------------- build
    def insert_batch(
        self,
        series: np.ndarray,
        ids: np.ndarray,
        ts: Optional[np.ndarray] = None,
    ) -> None:
        scfg = self.cfg.summarization
        series = np.asarray(series, np.float32)
        syms = sax_from_paa(paa(series, scfg), scfg).astype(np.int16)
        ids = np.asarray(ids, np.int64)
        ts = np.asarray(ts, np.int64) if ts is not None else np.zeros(len(ids), np.int64)
        keep_series = series if self.cfg.mode == "full" else None
        # per-entry top-down insertion cost: descend (read) + leaf write
        self.disk.read_rand(len(ids) * self.disk.page_bytes)
        self.disk.write_rand(len(ids) * self.disk.page_bytes)
        # root fan-out on the MSB of each segment
        msb = (syms >> (self._c - 1)).astype(np.int8)  # (B, w) in {0,1}
        groups: dict[tuple, np.ndarray] = {}
        view = [tuple(row) for row in msb]
        for i, key in enumerate(view):
            groups.setdefault(key, []).append(i)
        for key, idxs in groups.items():
            idxs = np.asarray(idxs)
            node = self.root_children.get(key)
            if node is None:
                card = np.ones(self._w, np.int8)
                prefix = np.asarray(key, np.int16)
                node = _Node(card, prefix)
                self.root_children[key] = node
            self._node_insert(
                node,
                syms[idxs],
                ids[idxs],
                ts[idxs],
                keep_series[idxs] if keep_series is not None else None,
            )
        self.n += len(ids)

    def _leaf_limit(self) -> int:
        return self.cfg.leaf_size

    def _node_insert(self, node: _Node, syms, ids, ts, series) -> None:
        if node.is_leaf:
            node.sax = syms if node.sax is None else np.concatenate([node.sax, syms])
            node.ids = ids if node.ids is None else np.concatenate([node.ids, ids])
            node.ts = ts if node.ts is None else np.concatenate([node.ts, ts])
            if series is not None:
                node.series = (
                    series if node.series is None else np.concatenate([node.series, series])
                )
            node.n = len(node.ids)
            if node.n > self._leaf_limit():
                self._split(node)
            return
        self._route_to_children(node, syms, ids, ts, series)

    def _route_to_children(self, node: _Node, syms, ids, ts, series) -> None:
        seg = node.split_seg
        depth = int(node.card[seg]) + 1  # bit position (1-based from MSB) used by children
        bit = (syms[:, seg] >> (self._c - depth)) & 1
        for b in (0, 1):
            m = bit == b
            if not m.any():
                continue
            child = node.children[b]
            self._node_insert(
                child, syms[m], ids[m], ts[m], series[m] if series is not None else None
            )

    def _split(self, node: _Node) -> None:
        # choose split segment round-robin: least-used cardinality first
        cands = np.where(node.card < self._c)[0]
        if cands.size == 0:
            return  # cannot split further; oversized leaf allowed
        seg = int(cands[np.argmin(node.card[cands])])
        node.split_seg = seg
        node.children = {}
        newbits = int(node.card[seg]) + 1
        for b in (0, 1):
            card = node.card.copy()
            card[seg] = newbits
            prefix = node.prefix.copy()
            prefix[seg] = (prefix[seg] << 1) | b
            node.children[b] = _Node(card, prefix)
        syms, ids, ts, series = node.sax, node.ids, node.ts, node.series
        node.sax = node.ids = node.ts = node.series = None
        node.n = 0
        self.n_splits += 1
        # split rewrites both child pages
        self.disk.read_rand(self.disk.page_bytes)
        self.disk.write_rand(2 * self.disk.page_bytes)
        self._route_to_children(node, syms, ids, ts, series)

    # ---------------------------------------------------------------- query
    def _node_bounds(self, node: _Node):
        """(min_sym, max_sym) full-cardinality range covered by the node."""
        shift = self._c - node.card.astype(np.int32)
        min_sym = (node.prefix.astype(np.int32) << shift)
        max_sym = ((node.prefix.astype(np.int32) + 1) << shift) - 1
        return min_sym, max_sym

    def _leaf_verify(self, node: _Node, q, qp, k, bsf, raw, window, stats, worst_fn):
        stats.blocks_visited += 1
        self.disk.read_rand(max(1, node.n) * (self._w + 8))
        elb = mindist_paa_sax2(qp, node.sax.astype(np.int64), self.cfg.summarization)
        mask = elb < worst_fn()
        if window is not None:
            mask &= (node.ts >= window[0]) & (node.ts <= window[1])
        stats.entries_pruned += int((~mask).sum())
        cand = np.nonzero(mask)[0]
        if cand.size == 0:
            return bsf
        if node.series is not None:
            data = node.series[cand]
            self.disk.read_rand(data.nbytes)
        else:
            if raw is None:
                raise ValueError("adaptive ADS+ requires a RawStore")
            data = raw.fetch(node.ids[cand])
        d2 = ed2(np.asarray(q, np.float32), data)
        stats.entries_verified += cand.size
        for dist, pos in zip(d2, cand):
            item = (-float(dist), int(node.ids[pos]))
            if len(bsf) < k:
                heapq.heappush(bsf, item)
            elif item[0] > bsf[0][0]:
                heapq.heapreplace(bsf, item)
        return bsf

    def _maybe_adaptive_split(self, node: _Node) -> None:
        """ADS+ hardening: split a touched oversized leaf once; the PQ search
        re-pushes its children, which re-split on pop until within target."""
        if self.cfg.mode != "adaptive":
            return
        if node.is_leaf and node.n > self.cfg.query_leaf_size:
            self._split(node)

    def knn_exact(self, q, k=1, *, raw: Optional[RawStore] = None, window=None):
        scfg = self.cfg.summarization
        qp = np.asarray(paa(np.asarray(q, np.float32), scfg))
        stats = QueryStats()
        bsf: list = []

        def worst():
            return -bsf[0][0] if len(bsf) >= k else np.inf

        pq: list = []
        counter = 0
        for node in self.root_children.values():
            mn, mx = self._node_bounds(node)
            lb = float(mindist_region2(qp, mn, mx, scfg))
            counter += 1
            heapq.heappush(pq, (lb, counter, node))
        while pq:
            lb, _, node = heapq.heappop(pq)
            if lb >= worst():
                stats.blocks_pruned += 1 + len(pq)
                break
            self.disk.read_rand(self.disk.page_bytes)  # node page touch
            if node.is_leaf:
                if node.n == 0:
                    continue
                if self.cfg.mode == "adaptive" and node.n > self.cfg.query_leaf_size:
                    self._maybe_adaptive_split(node)
                    if not node.is_leaf:
                        for child in node.children.values():
                            mn, mx = self._node_bounds(child)
                            clb = float(mindist_region2(qp, mn, mx, scfg))
                            counter += 1
                            heapq.heappush(pq, (clb, counter, child))
                        continue
                bsf = self._leaf_verify(node, q, qp, k, bsf, raw, window, stats, worst)
            else:
                for child in node.children.values():
                    mn, mx = self._node_bounds(child)
                    clb = float(mindist_region2(qp, mn, mx, scfg))
                    counter += 1
                    heapq.heappush(pq, (clb, counter, child))
        return heap_to_sorted(bsf), stats

    def knn_approx(self, q, k=1, *, raw=None, window=None):
        """Descend to the single leaf the query maps to and verify it."""
        scfg = self.cfg.summarization
        qp = np.asarray(paa(np.asarray(q, np.float32), scfg))
        qsym = sax_from_paa(qp, scfg).astype(np.int16)
        stats = QueryStats()
        bsf: list = []
        key = tuple((qsym >> (self._c - 1)).tolist())
        node = self.root_children.get(key)
        while node is not None and not node.is_leaf:
            self.disk.read_rand(self.disk.page_bytes)
            depth = int(node.card[node.split_seg]) + 1
            b = int((qsym[node.split_seg] >> (self._c - depth)) & 1)
            node = node.children[b]
        if node is None or node.n == 0:
            return [], stats
        bsf = self._leaf_verify(node, q, qp, k, bsf, raw, window, stats, lambda: np.inf)
        return heap_to_sorted(bsf), stats

    def knn_approx_batch(self, Q, k=1, *, raw: Optional[RawStore] = None,
                         window=None):
        """Batched approximate kNN: descend every query to its leaf, then
        verify each DISTINCT leaf once against its whole query group.

        Per-query answers match a loop of ``knn_approx``; physically the
        batch deduplicates leaf verifications — queries landing in the same
        leaf (the common case for clustered workloads) share one leaf read
        and one batched top-k pass. Results are a subset of the exact
        answer (only the single mapped leaf is verified), so recall@k
        depends on how much of the true neighborhood the leaf captures.
        Returns ((m, k) d2 ascending, (m, k) ids, stats); unfilled slots
        are (inf, -1). Stats follow the batched convention: logical
        per-query ``blocks_visited``, physical shared ``entries_verified``.
        """
        scfg = self.cfg.summarization
        Q = np.asarray(Q, np.float32)
        m = Q.shape[0]
        vals, ids = empty_topk_state(m, k)
        stats = QueryStats()
        if m == 0 or self.n == 0:
            return vals, ids, stats
        qsym = sax_from_paa(np.asarray(paa(Q, scfg)), scfg).astype(np.int16)
        groups: dict[int, list[int]] = {}
        leaves: dict[int, _Node] = {}
        node_touches = 0
        for i in range(m):
            key = tuple((qsym[i] >> (self._c - 1)).tolist())
            node = self.root_children.get(key)
            while node is not None and not node.is_leaf:
                node_touches += 1
                depth = int(node.card[node.split_seg]) + 1
                b = int((qsym[i, node.split_seg] >> (self._c - depth)) & 1)
                node = node.children[b]
            if node is None or node.n == 0:
                continue
            leaves[id(node)] = node
            groups.setdefault(id(node), []).append(i)
        if node_touches:
            self.disk.read_rand(node_touches * self.disk.page_bytes)
        for nid, qlist in groups.items():
            node = leaves[nid]
            qidx = np.asarray(qlist)
            stats.blocks_visited += qidx.size  # per-query logical accounting
            self.disk.read_rand(max(1, node.n) * (self._w + 8))  # one shared leaf read
            mask = np.ones(node.n, bool)
            if window is not None:
                mask &= (node.ts >= window[0]) & (node.ts <= window[1])
            stats.entries_pruned += int((~mask).sum())
            cand = np.nonzero(mask)[0]
            if cand.size == 0:
                continue
            if node.series is not None:
                data = node.series[cand]
                self.disk.read_rand(data.nbytes)
            else:
                if raw is None:
                    raise ValueError("adaptive ADS+ requires a RawStore")
                data = raw.fetch(node.ids[cand])
            stats.entries_verified += cand.size
            nv, ni = topk_ed2(Q[qidx], data, k)
            mv, mi = merge_topk_state(vals[qidx], ids[qidx], nv, node.ids[cand][ni])
            vals[qidx], ids[qidx] = mv, mi
        return vals, ids, stats

    def index_bytes(self) -> int:
        total = 0
        stack = list(self.root_children.values())
        while stack:
            node = stack.pop()
            if node.is_leaf:
                if node.sax is not None:
                    total += node.sax.nbytes + node.ids.nbytes + node.ts.nbytes
                    if node.series is not None:
                        total += node.series.nbytes
            else:
                stack.extend(node.children.values())
        return total
