"""ADSFull / ADS+ baseline — the state of the art the paper demos against.

A top-down-inserted iSAX tree: the root fans out on the first bit of every
segment; an overflowing leaf splits by promoting the cardinality of one
segment (round-robin). Every insert descends to a leaf — one random page
read + one random page write per entry (the cost profile Coconut removes).

Modes:
  * ``full``      — ADSFull: leaves store the raw series (materialized).
  * ``adaptive``  — ADS+: construction stores only summarizations with a
    large leaf threshold (fast, skeletal build); queries adaptively split
    the leaves they touch down to ``query_leaf_size`` and fetch raw series
    lazily from the RawStore (random reads at query time).

Queries compile to the shared plan/execute engine: the tree's non-empty
leaves become the blocks of a :class:`repro.core.plan.BlockSource` (their
iSAX node regions are the zone maps), and ADS+'s query-time adaptive
splitting is the plan's ``refine`` hook — when the executor selects an
oversized leaf for verification, the leaf splits and its children re-enter
the traversal with their own (tighter) bounds, exactly the lazy refinement
of the scalar algorithm. This gives ADS+ the full batched exact tier
(``knn_batch``) through the same executor as every Coconut index.

Implementation note: inserts are batched and partitioned vectorially for
host speed, but the I/O accounting matches per-entry top-down insertion.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .ctree import RawStore, state_to_list
from .execute import execute
from .io_model import DiskModel
from .lower_bounds import mindist_region2
from .plan import BlockSource, GroupSource, QueryPlan, SourceOps
from .summarization import SummarizationConfig, paa, sax_from_paa


@dataclasses.dataclass
class ADSConfig:
    summarization: SummarizationConfig = dataclasses.field(default_factory=SummarizationConfig)
    leaf_size: int = 1024
    mode: str = "full"  # full | adaptive
    query_leaf_size: int = 128  # adaptive-split target during queries
    # device-arena storage dtype for the screen tier (f32|bf16|int8; None
    # resolves the engine default / REPRO_SCREEN_DTYPE)
    screen_dtype: Optional[str] = None


class _Node:
    __slots__ = ("card", "prefix", "children", "split_seg", "sax", "ids", "ts", "series", "n")

    def __init__(self, card: np.ndarray, prefix: np.ndarray):
        self.card = card  # (w,) bits used per segment at this node
        self.prefix = prefix  # (w,) symbol prefix (card bits per segment)
        self.children: Optional[dict] = None  # split bit -> node
        self.split_seg: int = -1
        self.sax: Optional[np.ndarray] = None
        self.ids: Optional[np.ndarray] = None
        self.ts: Optional[np.ndarray] = None
        self.series: Optional[np.ndarray] = None
        self.n = 0

    @property
    def is_leaf(self) -> bool:
        return self.children is None


class ADSIndex:
    def __init__(self, cfg: ADSConfig, disk: Optional[DiskModel] = None):
        self.cfg = cfg
        self.disk = disk or DiskModel()
        w = cfg.summarization.n_segments
        self.root_children: dict[tuple, _Node] = {}
        self._w = w
        self._c = cfg.summarization.card_bits
        self.n = 0
        self.n_splits = 0
        self._flat_cache: Optional[dict] = None  # flattened leaf view

    # ---------------------------------------------------------------- build
    def insert_batch(
        self,
        series: np.ndarray,
        ids: np.ndarray,
        ts: Optional[np.ndarray] = None,
    ) -> None:
        scfg = self.cfg.summarization
        series = np.asarray(series, np.float32)
        syms = sax_from_paa(paa(series, scfg), scfg).astype(np.int16)
        ids = np.asarray(ids, np.int64)
        ts = np.asarray(ts, np.int64) if ts is not None else np.zeros(len(ids), np.int64)
        keep_series = series if self.cfg.mode == "full" else None
        # per-entry top-down insertion cost: descend (read) + leaf write
        self.disk.read_rand(len(ids) * self.disk.page_bytes)
        self.disk.write_rand(len(ids) * self.disk.page_bytes)
        self._flat_cache = None
        # root fan-out on the MSB of each segment
        msb = (syms >> (self._c - 1)).astype(np.int8)  # (B, w) in {0,1}
        groups: dict[tuple, np.ndarray] = {}
        view = [tuple(row) for row in msb]
        for i, key in enumerate(view):
            groups.setdefault(key, []).append(i)
        for key, idxs in groups.items():
            idxs = np.asarray(idxs)
            node = self.root_children.get(key)
            if node is None:
                card = np.ones(self._w, np.int8)
                prefix = np.asarray(key, np.int16)
                node = _Node(card, prefix)
                self.root_children[key] = node
            self._node_insert(
                node,
                syms[idxs],
                ids[idxs],
                ts[idxs],
                keep_series[idxs] if keep_series is not None else None,
            )
        self.n += len(ids)

    def _leaf_limit(self) -> int:
        return self.cfg.leaf_size

    def _node_insert(self, node: _Node, syms, ids, ts, series) -> None:
        if node.is_leaf:
            node.sax = syms if node.sax is None else np.concatenate([node.sax, syms])
            node.ids = ids if node.ids is None else np.concatenate([node.ids, ids])
            node.ts = ts if node.ts is None else np.concatenate([node.ts, ts])
            if series is not None:
                node.series = (
                    series if node.series is None else np.concatenate([node.series, series])
                )
            node.n = len(node.ids)
            if node.n > self._leaf_limit():
                self._split(node)
            return
        self._route_to_children(node, syms, ids, ts, series)

    def _route_to_children(self, node: _Node, syms, ids, ts, series) -> None:
        seg = node.split_seg
        depth = int(node.card[seg]) + 1  # bit position (1-based from MSB) used by children
        bit = (syms[:, seg] >> (self._c - depth)) & 1
        for b in (0, 1):
            m = bit == b
            if not m.any():
                continue
            child = node.children[b]
            self._node_insert(
                child, syms[m], ids[m], ts[m], series[m] if series is not None else None
            )

    def _split(self, node: _Node) -> None:
        # choose split segment round-robin: least-used cardinality first
        cands = np.where(node.card < self._c)[0]
        if cands.size == 0:
            return  # cannot split further; oversized leaf allowed
        seg = int(cands[np.argmin(node.card[cands])])
        node.split_seg = seg
        node.children = {}
        newbits = int(node.card[seg]) + 1
        for b in (0, 1):
            card = node.card.copy()
            card[seg] = newbits
            prefix = node.prefix.copy()
            prefix[seg] = (prefix[seg] << 1) | b
            node.children[b] = _Node(card, prefix)
        syms, ids, ts, series = node.sax, node.ids, node.ts, node.series
        node.sax = node.ids = node.ts = node.series = None
        node.n = 0
        self.n_splits += 1
        self._flat_cache = None
        # split rewrites both child pages
        self.disk.read_rand(self.disk.page_bytes)
        self.disk.write_rand(2 * self.disk.page_bytes)
        self._route_to_children(node, syms, ids, ts, series)

    # ---------------------------------------------------------------- query
    def _node_bounds(self, node: _Node):
        """(min_sym, max_sym) full-cardinality range covered by the node."""
        shift = self._c - node.card.astype(np.int32)
        min_sym = (node.prefix.astype(np.int32) << shift)
        max_sym = ((node.prefix.astype(np.int32) + 1) << shift) - 1
        return min_sym, max_sym

    def _flat(self) -> dict:
        """Lazily flattened view of the non-empty leaves: one contiguous
        position space for the planner. The entry arrays are copies keyed
        to the leaves at build time, so query-time adaptive splits never
        invalidate positions (``fetch``/``index_read`` keep resolving
        through the original ``offsets``/``series`` refs). The evolving
        leaf partition lives in ``blocks`` — ``[node, positions]`` cells
        that the refine hook patches in place (split parents nulled,
        children appended), so a split costs O(children), not an O(N)
        rebuild on the next query. Inserts rebuild from the real tree."""
        if self._flat_cache is None:
            leaves: list[_Node] = []
            stack = list(self.root_children.values())
            while stack:
                node = stack.pop()
                if node.is_leaf:
                    if node.n:
                        leaves.append(node)
                else:
                    stack.extend(node.children.values())
            offsets = np.cumsum([0] + [lf.n for lf in leaves])
            if leaves:
                sax = np.concatenate([lf.sax for lf in leaves])
                ids = np.concatenate([lf.ids for lf in leaves])
                ts = np.concatenate([lf.ts for lf in leaves])
            else:
                sax = np.zeros((0, self._w), np.int16)
                ids = np.zeros((0,), np.int64)
                ts = np.zeros((0,), np.int64)
            self._flat_cache = {
                "offsets": offsets,
                "sax": sax,
                "ids": ids,
                "ts": ts,
                "series": [lf.series for lf in leaves],  # refs survive splits
                "blocks": [
                    [lf, np.arange(offsets[i], offsets[i + 1])]
                    for i, lf in enumerate(leaves)
                ],
            }
        return self._flat_cache

    def _flat_blocks(self, flat: dict) -> list:
        """The live (node, positions) leaf partition — split parents drop."""
        return [e for e in flat["blocks"] if e[0] is not None]

    def _flat_device_view(self, flat: dict):
        """Device arena over the flattened leaf space (full mode): the
        per-leaf series concatenate once into the flat position space and
        upload once per flat cache generation (inserts rebuild the cache;
        query-time splits keep positions stable, so the arena survives)."""
        if flat.get("_dev_view") is None:
            from .verify_engine import get_engine  # lazy: numpy paths stay jax-free

            L = self.cfg.summarization.series_len
            table = (
                np.concatenate(flat["series"])
                if flat["series"]
                else np.zeros((0, L), np.float32)
            )
            flat["_dev_view"] = get_engine().build_view(
                table, dtype=self.cfg.screen_dtype)
        return flat["_dev_view"]

    def _flat_ops(self, flat: dict, raw: Optional[RawStore], *,
                  screen: bool) -> SourceOps:
        """Executor accessors over the flattened leaf space (I/O accounted
        per leaf, matching the top-down tree's random-read cost profile)."""
        offsets = flat["offsets"]
        L = self.cfg.summarization.series_len

        def fetch(pos: np.ndarray) -> np.ndarray:
            if self.cfg.mode != "full":
                if raw is None:
                    raise ValueError("adaptive ADS+ requires a RawStore")
                return raw.fetch(flat["ids"][pos])
            out = np.empty((pos.size, L), np.float32)
            leaf_of = np.searchsorted(offsets, pos, side="right") - 1
            for li in np.unique(leaf_of):
                sel = leaf_of == li
                data = flat["series"][li][pos[sel] - offsets[li]]
                self.disk.read_rand(data.nbytes)
                out[sel] = data
            return out

        def fetch_account(pos: np.ndarray) -> None:
            # the modeled I/O of ``fetch`` without the gather (device path)
            if self.cfg.mode != "full":
                raw.account_fetch(flat["ids"][pos])
                return
            leaf_of = np.searchsorted(offsets, pos, side="right") - 1
            for _, cnt in zip(*np.unique(leaf_of, return_counts=True)):
                self.disk.read_rand(int(cnt) * L * 4)

        def index_read(pos: np.ndarray) -> None:
            # one node-page touch + one summarization read per leaf visited
            leaf_of = np.searchsorted(offsets, pos, side="right") - 1
            for li, cnt in zip(*np.unique(leaf_of, return_counts=True)):
                self.disk.read_rand(self.disk.page_bytes)
                self.disk.read_rand(int(max(1, cnt)) * (self._w + 8))

        # device arena: full mode owns the flat table (row == flat position);
        # adaptive mode verifies against the RawStore arena (row == global id)
        screen_dtype = None
        if self.cfg.mode == "full":
            device_view = lambda: self._flat_device_view(flat)
            table_rows = None  # identity
            table_ids = lambda r: flat["ids"][r]
            screen_dtype = self.cfg.screen_dtype
        elif raw is not None:
            device_view = raw.device_view
            table_rows = lambda p: flat["ids"][p]
            table_ids = lambda r: r  # raw rows ARE global ids
            screen_dtype = raw.screen_dtype
        else:
            device_view = table_rows = table_ids = None
            fetch_account = None

        return SourceOps(
            ids=flat["ids"],
            ts=flat["ts"],
            fetch=fetch,
            index_read=index_read,
            sax=flat["sax"] if screen else None,
            scfg=self.cfg.summarization,
            device_view=device_view,
            table_rows=table_rows,
            table_ids=table_ids,
            fetch_account=fetch_account,
            screen_dtype=screen_dtype,
        )

    def _make_refine(self, flat: dict, blocks_tbl: list, qp: np.ndarray):
        """The adaptive-split plan hook: when the executor selects an
        oversized leaf, split it (same tree mutation + I/O accounting as
        the scalar path) and hand back the children as new blocks with
        their own bounds. Children re-split on re-selection until within
        ``query_leaf_size`` — the PQ re-push of the old best-first loop.
        Splits patch the shared ``flat["blocks"]`` partition in place, so
        later queries start from the refined leaves without an O(N)
        cache rebuild."""
        if self.cfg.mode != "adaptive":
            return None
        scfg = self.cfg.summarization
        local: list = list(blocks_tbl)  # executor block index -> shared cell

        def refine(b: int):
            entry = local[b]
            node = entry[0]
            if not (node.is_leaf and node.n > self.cfg.query_leaf_size):
                return None
            self._split(node)  # nulls _flat_cache (general safety) ...
            self._flat_cache = flat  # ... but the flat arrays are copies:
            # reinstate the cache and patch its partition instead
            if node.is_leaf:  # could not split further (cardinality exhausted)
                return None
            pos = entry[1]
            entry[0] = None  # parent replaced in the shared partition
            seg = node.split_seg
            depth = int(node.card[seg]) + 1
            bit = (flat["sax"][pos][:, seg].astype(np.int32) >> (self._c - depth)) & 1
            out = []
            for bval in (0, 1):
                child = node.children[bval]
                cpos = pos[bit == bval]
                mn, mx = self._node_bounds(child)
                col = mindist_region2(qp, mn, mx, scfg)  # (m,)
                cell = [child, cpos]
                local.append(cell)
                if cpos.size:
                    flat["blocks"].append(cell)
                out.append((col, cpos))
            return out

        return refine

    def plan(
        self,
        Q: np.ndarray,
        *,
        tier: str = "exact",
        raw: Optional[RawStore] = None,
        window: Optional[tuple[int, int]] = None,
    ) -> QueryPlan:
        """Compile a query batch into a plan over the tree's leaves.

        ``tier="exact"``: every non-empty leaf is a lower-bounded block
        (its iSAX region is the zone map) with the adaptive-split refine
        hook. ``tier="approx"``: descend every query to its mapped leaf
        and verify each DISTINCT leaf once against its query group."""
        Q = np.asarray(Q, np.float32)
        m = Q.shape[0]
        flat = self._flat()
        blocks_tbl = self._flat_blocks(flat)
        scfg = self.cfg.summarization
        if not blocks_tbl or m == 0:
            return QueryPlan(m=m, sources=[], window=window)
        if tier == "exact":
            qp = np.asarray(paa(Q, scfg))  # (m, w)
            mn = np.stack([self._node_bounds(n)[0] for n, _ in blocks_tbl])
            mx = np.stack([self._node_bounds(n)[1] for n, _ in blocks_tbl])
            lb = mindist_region2(qp[:, None, :], mn, mx, scfg)  # (m, n_leaves)
            src = BlockSource(
                ops=self._flat_ops(flat, raw, screen=True),
                lb=lb,
                blocks=[pos for _, pos in blocks_tbl],
                refine=self._make_refine(flat, blocks_tbl, qp),
            )
            return QueryPlan(m=m, sources=[src], window=window)
        # approximate tier: per-query leaf descent, deduplicated by leaf
        qsym = sax_from_paa(np.asarray(paa(Q, scfg)), scfg).astype(np.int16)
        leaf_index = {id(n): i for i, (n, _) in enumerate(blocks_tbl)}
        groups: dict[int, list[int]] = {}
        node_touches = 0
        for i in range(m):
            key = tuple((qsym[i] >> (self._c - 1)).tolist())
            node = self.root_children.get(key)
            while node is not None and not node.is_leaf:
                node_touches += 1
                depth = int(node.card[node.split_seg]) + 1
                b = int((qsym[i, node.split_seg] >> (self._c - depth)) & 1)
                node = node.children[b]
            if node is None or node.n == 0:
                continue
            groups.setdefault(leaf_index[id(node)], []).append(i)
        group_list = [
            (np.asarray(qlist), blocks_tbl[li][1])
            for li, qlist in groups.items()
        ]
        group_reads = [
            (lambda n=blocks_tbl[li][0].n: self.disk.read_rand(
                max(1, n) * (self._w + 8)))
            for li in groups
        ]
        pre_read = None
        if node_touches:
            pre_read = lambda t=node_touches: self.disk.read_rand(
                t * self.disk.page_bytes)
        src = GroupSource(
            ops=self._flat_ops(flat, raw, screen=False),
            groups=group_list,
            group_reads=group_reads,
            pre_read=pre_read,
        )
        return QueryPlan(m=m, sources=[src], window=window)

    def knn_exact(self, q, k=1, *, raw: Optional[RawStore] = None, window=None):
        """Scalar exact kNN — a batch-of-1 plan through the shared executor
        (adaptive leaves still split lazily via the plan's refine hook).
        Returns ([(d2, id)] ascending, stats)."""
        vals, gids, stats = self.knn_batch(
            np.asarray(q, np.float32).reshape(1, -1), k, raw=raw, window=window
        )
        return state_to_list(vals[0], gids[0]), stats

    def knn_batch(self, Q, k=1, *, raw: Optional[RawStore] = None, window=None,
                  backend="device", shard=None, mesh=None):
        """Batched exact kNN: ((m, k) d2 ascending, (m, k) ids), stats.

        The iSAX leaves traverse through the same executor as every
        Coconut run — shared verification passes for the whole batch, with
        adaptive leaves splitting on first touch (``refine``). Unfilled
        slots are (inf, -1). ``shard="mesh"`` executes the plan on the
        device mesh."""
        Q = np.asarray(Q, np.float32)
        plan = self.plan(Q, tier="exact", raw=raw, window=window)
        (vals, gids), stats = execute(plan, Q, k, backend=backend, shard=shard,
                                      mesh=mesh)
        return vals, gids, stats

    def knn_approx(self, q, k=1, *, raw=None, window=None):
        """Descend to the single leaf the query maps to and verify it.
        Batch-of-1 plan; returns ([(d2, id)] ascending, stats)."""
        vals, gids, stats = self.knn_approx_batch(
            np.asarray(q, np.float32).reshape(1, -1), k, raw=raw, window=window
        )
        return state_to_list(vals[0], gids[0]), stats

    def knn_approx_batch(self, Q, k=1, *, raw: Optional[RawStore] = None,
                         window=None, backend="device"):
        """Batched approximate kNN: descend every query to its leaf, then
        verify each DISTINCT leaf once against its whole query group.

        Per-query answers match a loop of ``knn_approx``; physically the
        batch deduplicates leaf verifications — queries landing in the same
        leaf (the common case for clustered workloads) share one leaf read
        and one batched top-k pass. Results are a subset of the exact
        answer (only the single mapped leaf is verified), so recall@k
        depends on how much of the true neighborhood the leaf captures.
        Returns ((m, k) d2 ascending, (m, k) ids, stats); unfilled slots
        are (inf, -1). Stats follow the batched convention: logical
        per-query ``blocks_visited``, physical shared ``entries_verified``.
        """
        Q = np.asarray(Q, np.float32)
        plan = self.plan(Q, tier="approx", raw=raw, window=window)
        (vals, gids), stats = execute(plan, Q, k, backend=backend)
        return vals, gids, stats

    def index_bytes(self) -> int:
        total = 0
        stack = list(self.root_children.values())
        while stack:
            node = stack.pop()
            if node.is_leaf:
                if node.sax is not None:
                    total += node.sax.nbytes + node.ids.nbytes + node.ts.nbytes
                    if node.series is not None:
                        total += node.series.nbytes
            else:
                stack.extend(node.children.values())
        return total
