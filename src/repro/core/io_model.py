"""Explicit I/O accounting — the paper's cost currency and its "heat map".

Every index operation reports its storage accesses here. The demo paper's
heat-map visualization of query access patterns becomes a machine-readable
access log that benchmarks and examples aggregate (and render as ASCII).

Cost model defaults approximate a 2018-era SATA SSD (the paper's setting):
sequential ~500 MB/s, random 4K ~ 10k IOPS. They are configurable so the
same accounting can model NVMe or HBM-resident runs.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import List, Tuple


@dataclasses.dataclass
class IOStats:
    seq_read_bytes: int = 0
    rand_read_bytes: int = 0
    seq_write_bytes: int = 0
    rand_write_bytes: int = 0
    seq_ops: int = 0
    rand_ops: int = 0

    def merge(self, other: "IOStats") -> "IOStats":
        return IOStats(
            self.seq_read_bytes + other.seq_read_bytes,
            self.rand_read_bytes + other.rand_read_bytes,
            self.seq_write_bytes + other.seq_write_bytes,
            self.rand_write_bytes + other.rand_write_bytes,
            self.seq_ops + other.seq_ops,
            self.rand_ops + other.rand_ops,
        )

    @property
    def total_bytes(self) -> int:
        return (
            self.seq_read_bytes
            + self.rand_read_bytes
            + self.seq_write_bytes
            + self.rand_write_bytes
        )


@dataclasses.dataclass
class DiskModel:
    """Accounting + cost estimation for a modeled storage device."""

    seq_mbps: float = 500.0
    rand_iops: float = 10_000.0
    page_bytes: int = 4096
    stats: IOStats = dataclasses.field(default_factory=IOStats)
    # access log for the heat map: (offset_pages, n_pages, kind)
    log: List[Tuple[int, int, str]] = dataclasses.field(default_factory=list)
    keep_log: bool = False
    # background ingest accounts flush/merge I/O from the worker thread
    # while queries account reads concurrently — counter updates are
    # read-modify-write, so they serialize here
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)
    # per-thread accounting suspension depth (see :meth:`unaccounted`)
    _tls: threading.local = dataclasses.field(
        default_factory=threading.local, repr=False, compare=False)

    def _suspended(self) -> bool:
        return getattr(self._tls, "suspend", 0) > 0

    @contextlib.contextmanager
    def unaccounted(self):
        """Suspend accounting for I/O issued by the CALLING thread only.

        For measurement-side reads that must not pollute the modeled cost
        figures — e.g. the serving loop's recall oracle re-running a query
        through the exact tier. Unlike the old save/restore of ``stats``
        (a racy in-place mutation of state a concurrent ingest worker is
        accounting into), this is a thread-local depth counter: the
        worker's flush/merge I/O keeps landing in the shared stats
        untouched while the oracle's own reads vanish. Re-entrant."""
        # thread-local state: only ever touched by its own thread, so the
        # instance lock is deliberately not taken
        self._tls.suspend = getattr(self._tls, "suspend", 0) + 1  # palmlint: ignore[lock-discipline]
        try:
            yield self
        finally:
            self._tls.suspend -= 1  # palmlint: ignore[lock-discipline] — thread-local

    def reset(self) -> None:
        with self._lock:
            self.stats = IOStats()
            self.log = []

    def read_seq(self, nbytes: int, offset: int = 0) -> None:
        if self._suspended():
            return
        with self._lock:
            self.stats.seq_read_bytes += int(nbytes)
            self.stats.seq_ops += 1
            if self.keep_log and nbytes:
                # ceil-divide like the random paths: a 4097-byte read
                # touches 2 pages, not 1
                pages = max(1, (int(nbytes) + self.page_bytes - 1) // self.page_bytes)
                self.log.append((offset // self.page_bytes, pages, "rs"))

    def read_rand(self, nbytes: int, offset: int = 0) -> None:
        if self._suspended():
            return
        with self._lock:
            self.stats.rand_read_bytes += int(nbytes)
            pages = max(1, (int(nbytes) + self.page_bytes - 1) // self.page_bytes)
            self.stats.rand_ops += pages
            if self.keep_log and nbytes:
                self.log.append((offset // self.page_bytes, pages, "rr"))

    def write_seq(self, nbytes: int, offset: int = 0) -> None:
        if self._suspended():
            return
        with self._lock:
            self.stats.seq_write_bytes += int(nbytes)
            self.stats.seq_ops += 1
            if self.keep_log and nbytes:
                # ceil-divide like the random paths (page parity with reads)
                pages = max(1, (int(nbytes) + self.page_bytes - 1) // self.page_bytes)
                self.log.append((offset // self.page_bytes, pages, "ws"))

    def write_rand(self, nbytes: int, offset: int = 0) -> None:
        if self._suspended():
            return
        with self._lock:
            self.stats.rand_write_bytes += int(nbytes)
            pages = max(1, (int(nbytes) + self.page_bytes - 1) // self.page_bytes)
            self.stats.rand_ops += pages
            if self.keep_log and nbytes:
                self.log.append((offset // self.page_bytes, pages, "wr"))

    def read_seq_ranges(self, ranges, unit_bytes: int = 1) -> None:
        """One sequential read per [lo, hi) range (in ``unit_bytes`` units).
        ``ranges`` must already be disjoint and ascending — the output of
        :func:`coalesce_ranges`. The batched approximate tier funnels every
        query's block range through here so overlapping seeks collapse into
        few long sequential reads — the accounting form of the paper's
        one-seek-plus-one-sequential-read claim."""
        for lo, hi in ranges:
            self.read_seq((hi - lo) * unit_bytes, offset=lo * unit_bytes)

    def modeled_seconds(self) -> float:
        """Estimated wall time of the recorded I/O pattern on the modeled device."""
        s = self.stats
        seq = (s.seq_read_bytes + s.seq_write_bytes) / (self.seq_mbps * 1e6)
        rand = s.rand_ops / self.rand_iops
        return seq + rand

    def heatmap(self, n_bins: int = 64, max_page: int | None = None) -> List[int]:
        """Aggregate the access log into n_bins page-range bins (the demo's
        heat map). Returns access counts per bin."""
        if not self.log:
            return [0] * n_bins
        mp = max_page or max(off + n for off, n, _ in self.log) or 1
        bins = [0] * n_bins
        for off, n, _ in self.log:
            b0 = min(n_bins - 1, off * n_bins // mp)
            # page ranges are half-open: the last page touched is
            # off + n - 1, so an access ending exactly on a bin boundary
            # must not bleed a count into the next bin
            b1 = min(n_bins - 1, (off + n - 1) * n_bins // mp)
            for b in range(b0, b1 + 1):
                bins[b] += 1
        return bins


def coalesce_ranges(ranges) -> List[Tuple[int, int]]:
    """Merge half-open [lo, hi) ranges into sorted disjoint ranges.

    Overlapping and back-to-back ranges fuse, empty ranges drop out. Used to
    deduplicate the per-query block reads of a batched approximate query
    into the minimal set of sequential reads."""
    spans = sorted((int(lo), int(hi)) for lo, hi in ranges if hi > lo)
    out: List[Tuple[int, int]] = []
    for lo, hi in spans:
        if out and lo <= out[-1][1]:
            if hi > out[-1][1]:
                out[-1] = (out[-1][0], hi)
        else:
            out.append((lo, hi))
    return out


def render_heatmap(bins: List[int], width: int = 64) -> str:
    """ASCII rendering of the access heat map (dark = hot)."""
    shades = " .:-=+*#%@"
    mx = max(bins) or 1
    return "".join(shades[min(len(shades) - 1, v * (len(shades) - 1) // mx)] for v in bins[:width])
