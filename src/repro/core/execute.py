"""The shared query executor — one physical engine for every index & tier.

Runs the declarative :class:`repro.core.plan.QueryPlan` that each index
variant's candidate generation produces. All the physical work that used to
be copied into every ``knn_*`` method lives here exactly once:

* coalesced sequential reads for the approximate tier's entry ranges;
* the adaptive best-first block traversal of the exact tier (seed pass +
  bounded rounds, entry-level MINDIST screening, ADS+'s query-time leaf
  refinement as a plan hook);
* candidate verification as one fused DEVICE pass per round (the default
  ``backend="device"``): the source's table lives in a device arena
  (:mod:`repro.core.verify_engine`), each pass gathers the round's rows on
  device, screens them in f32 matmul form against cached norms, selects a
  top-k slate in-kernel, and only the tiny certified slate crosses back for
  the exact f64 re-rank — one launch instead of einsum + argpartition +
  host gather, with shape-bucketed traces so steady-state serving never
  retraces. ``backend="numpy"`` is the retained host twin (one f32-sgemm
  screen + exact f64 re-rank per pass; also the fallback below the device
  size floor and for sources without arenas); ``backend="kernel"`` launches
  the ``topk_ed`` Pallas kernel per pass (the pre-engine opt-in path);
* folding of the batched (m, k) best-so-far state across sources with
  :func:`merge_topk_state` — the array analogue of the per-query bsf heap.

Scalar queries are batch-of-1 plans: ``knn_exact``/``knn_approx`` on every
index build the same plan as their batched twins and convert the (1, k)
state row to the historical [(d2, id)] list.

``shard="mesh"`` executes the exact tier on a device mesh: the query batch
is sharded over one mesh axis and the planned sources (runs) over the
other (queries x runs 2-D parallelism via ``shard_map``), each device
screens its (query shard, run shard) tile, per-shard (m, k) states fold
with one ``all_gather``, and the host re-ranks the gathered slate in f64 so
mesh answers match the single-device engine. The same path serves the
sample-sorted shards of ``core.distributed`` (see
``distributed.valid_entries``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .io_model import coalesce_ranges
from .lower_bounds import mindist_paa_sax2
from .plan import (
    BlockSource,
    DenseSource,
    GroupSource,
    QueryPlan,
    QueryStats,
    RangeSource,
    window_mask,
)
from .summarization import paa

BACKENDS = ("device", "numpy", "kernel")


# ---------------------------------------------------------------------------
# batched top-k state: the array analogue of the per-query bsf heap
# ---------------------------------------------------------------------------
def empty_topk_state(m: int, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Fresh batched best-so-far: ((m, k) inf distances, (m, k) -1 ids)."""
    return np.full((m, k), np.inf, np.float32), np.full((m, k), -1, np.int64)


def merge_topk_state(
    vals: np.ndarray, ids: np.ndarray, new_vals: np.ndarray, new_ids: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Row-wise merge of a (m, k) running top-k with (m, j) new candidates.

    Stable sort keeps existing entries ahead on distance ties. Callers must
    not feed an id twice (each index entry is verified at most once per
    batch, so this holds by construction)."""
    cv = np.concatenate([vals, new_vals.astype(vals.dtype)], axis=1)
    ci = np.concatenate([ids, new_ids.astype(ids.dtype)], axis=1)
    order = np.argsort(cv, axis=1, kind="stable")[:, : vals.shape[1]]
    return np.take_along_axis(cv, order, axis=1), np.take_along_axis(ci, order, axis=1)


def state_to_list(vals: np.ndarray, ids: np.ndarray) -> list[tuple[float, int]]:
    """One (k,) state row -> the scalar API's [(d2, id)] ascending list."""
    return [(float(v), int(g)) for v, g in zip(vals, ids) if g >= 0]


def heap_to_sorted(bsf: list) -> list[tuple[float, int]]:
    """Convert a (-d2, id) max-heap into [(d2, id)] ascending by distance."""
    return sorted(((-nd, i) for nd, i in bsf))


def recall_at_k(approx_ids: np.ndarray, exact_ids: np.ndarray) -> float:
    """Micro-averaged recall of a batched approximate answer against the
    exact oracle: |approx ∩ exact| / |exact| over all queries, ignoring
    (-1) pad slots. Both args are (m, k) id arrays."""
    hits = sum(
        len(set(map(int, a[a >= 0])) & set(map(int, e[e >= 0])))
        for a, e in zip(approx_ids, exact_ids)
    )
    return hits / max(1, sum(int((e >= 0).sum()) for e in exact_ids))


# ---------------------------------------------------------------------------
# candidate verification: one screen + exact re-rank, three backends
# ---------------------------------------------------------------------------
def _rerank_slate(
    Q: np.ndarray, X: np.ndarray, rows: np.ndarray, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Exact f64 re-rank of per-query candidate slates.

    ``rows`` is (m, s) row indices into ``X`` (negative = invalid slot).
    Returns ((m, kk) d2 ascending f32, (m, kk) rows, -1 padded), kk =
    min(k, |X|) — the common tail of every screening backend, so returned
    distances are exact however the slate was selected."""
    invalid = rows < 0
    sel = np.where(invalid, 0, rows)
    diff = X[sel].astype(np.float64) - Q[:, None, :].astype(np.float64)
    d2 = np.einsum("mkn,mkn->mk", diff, diff)
    d2 = np.where(invalid, np.inf, d2.astype(np.float32))
    kk = min(k, X.shape[0])
    o = np.argsort(d2, axis=1, kind="stable")[:, :kk]
    return (
        np.take_along_axis(d2, o, axis=1),
        np.take_along_axis(np.where(invalid, -1, rows), o, axis=1),
    )


def _kernel_topk_dists(
    Q: np.ndarray, data: np.ndarray, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Top-k distances of Q (m, n) against data (E, n) via one ``topk_ed``
    Pallas launch (power-of-two candidate bucketing so jit sees a handful
    of stable shapes), slack-8 slate + exact f64 re-rank."""
    from ..kernels import ops as kernel_ops  # lazy: keeps the host engine jax-free

    data = np.asarray(data, np.float32)
    ksel = min(k + 8, data.shape[0])  # slack absorbs f32 near-tie reordering
    _, rows = kernel_ops.topk_ed_bucketed(Q, data, ksel)
    return _rerank_slate(Q, data, np.asarray(rows).astype(np.int64), k)


def _screen_topk_exact(
    Q: np.ndarray, data: np.ndarray, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Provably exact top-k: one shared f32 sgemm screen, then f64 re-rank
    of everything inside the error-bound-widened kth radius.

    The screen's only error source is the f32 cross product, whose
    classical bound (2 n u |q||x|) widens the kth-best radius — selection
    stays provably sufficient however ill-conditioned the data. The f64
    re-rank of the selected tail is centered by the tail mean (squared ED
    is translation-invariant), so the matmul form stays accurate even
    under catastrophic cancellation (a common offset much larger than the
    spread); the centering is tail-sized, i.e. free."""
    m = Q.shape[0]
    u = data.shape[0]
    kk = min(k, u)
    x32 = np.ascontiguousarray(data, np.float32)
    g = x32 @ Q.T  # (U, m) f32 sgemm — the shared heavy pass
    xsq = np.einsum("un,un->u", x32, x32, dtype=np.float64)
    qsq = np.einsum("mn,mn->m", Q, Q, dtype=np.float64)
    d2a = qsq[:, None] + xsq[None, :] - 2.0 * g.T  # (m, U) f64-ish
    if kk < u:
        part = np.argpartition(d2a, kk - 1, axis=1)[:, :kk]
    else:
        part = np.broadcast_to(np.arange(kk), (m, kk)).copy()
    kth = np.take_along_axis(d2a, part, axis=1).max(axis=1)  # (m,)
    qn = np.sqrt(qsq)
    xn_max = float(np.sqrt(xsq.max()))
    bound = 4.0 * data.shape[1] * np.finfo(np.float32).eps * qn * xn_max
    cand = d2a <= (kth + 2.0 * bound)[:, None]  # (m, U)
    sel = np.nonzero(cand.any(axis=0))[0]  # (S,) small tail
    x64 = data[sel].astype(np.float64)
    mu = x64.mean(axis=0) if sel.size else 0.0  # tail-sized centering
    x64 -= mu
    q64 = Q.astype(np.float64) - mu
    d2e = (
        np.einsum("mn,mn->m", q64, q64)[:, None]
        + np.einsum("sn,sn->s", x64, x64)[None, :]
        # this matmul IS the exact f64 re-rank tail, not the f32 screen
        - 2.0 * (q64 @ x64.T)  # palmlint: ignore[precision-discipline]
    )  # (m, S) exact (centered, so the matmul form cannot cancel)
    d2e = np.maximum(d2e, 0.0).astype(np.float32)
    kks = min(kk, d2e.shape[1])
    if kks < d2e.shape[1]:
        p2 = np.argpartition(d2e, kks - 1, axis=1)[:, :kks]
    else:
        p2 = np.broadcast_to(np.arange(kks), (m, kks)).copy()
    nv = np.take_along_axis(d2e, p2, axis=1)
    o = np.argsort(nv, axis=1, kind="stable")
    return (
        np.take_along_axis(nv, o, axis=1),
        sel[np.take_along_axis(p2, o, axis=1)].astype(np.int64),
    )


def _screen_topk_slack(
    Q: np.ndarray,
    data: np.ndarray,
    k: int,
    xsq: Optional[np.ndarray] = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Slack-8 top-k: rank by one f32 sgemm screen (|q|^2 is constant per
    row, so the screen orders by |x|^2 - 2<q, x> only), then exactly
    re-rank the k+8 slate in f64 — the host twin of the kernel path, with
    cached squared norms (``xsq``) so nothing union-sized is recomputed."""
    m = Q.shape[0]
    u = data.shape[0]
    if xsq is None:
        x32 = np.asarray(data, np.float32)
        xsq = np.einsum("un,un->u", x32, x32)
    d2a = Q @ data.T  # (m, U) f32 sgemm — the heavy pass
    np.multiply(d2a, -2.0, out=d2a)
    np.add(d2a, xsq[None, :], out=d2a)
    ksel = min(k + 8, u)  # slack absorbs f32 near-tie reordering
    if ksel < u:
        part = np.argpartition(d2a, ksel - 1, axis=1)[:, :ksel]
    else:
        part = np.broadcast_to(np.arange(u), (m, u)).copy()
    diff = data[part].astype(np.float64) - Q.astype(np.float64)[:, None, :]
    d2e = np.einsum("mkn,mkn->mk", diff, diff).astype(np.float32)
    kk = min(k, u)
    o = np.argsort(d2e, axis=1, kind="stable")[:, :kk]
    return (
        np.take_along_axis(d2e, o, axis=1),
        np.take_along_axis(part, o, axis=1).astype(np.int64),
    )


# ---------------------------------------------------------------------------
# the device verification path (the default backend)
# ---------------------------------------------------------------------------
def _device_ready(ops, n_candidates: int, backend: str, m: int) -> bool:
    """Route this pass to the device engine? Requires the source to expose
    an arena and the pass to clear the candidate/batch size floors — below
    them the launch overhead rivals the whole host screen, so the host
    tail runs instead (answers are identical either way)."""
    if backend != "device" or ops.device_view is None:
        return False
    from .verify_engine import (  # lazy: host path stays jax-free
        MIN_DEVICE_BATCH,
        MIN_DEVICE_CANDIDATES,
    )

    return n_candidates >= MIN_DEVICE_CANDIDATES and m >= MIN_DEVICE_BATCH


def _account_fetch(ops, pos: np.ndarray) -> None:
    """Modeled-I/O accounting for a device-verified pass: the engine reads
    the arena, not the store, but serving still pays the host engine's
    modeled I/O so stats and heat maps stay comparable."""
    if ops.fetch_account is not None:
        ops.fetch_account(pos)
    elif ops.fetch is not None:  # pragma: no cover - plumbing gap fallback
        ops.fetch(pos)


def _device_topk(
    Q: np.ndarray, ops, pos: np.ndarray, k: int, *, exact: bool
) -> tuple[np.ndarray, np.ndarray]:
    """One fused device pass over the entries at ``pos``: arena gather +
    f32-compute screen + in-kernel slate selection, host f64 re-rank of
    the slate, error-bound certification with host fallback. The arena
    may STORE quantized rows (``ops.screen_dtype``: bf16/int8 with per-row
    scales) — the screen upcasts in-register and the certificate is
    widened by the quantization term, so answers are exact for every
    storage dtype. Returns ((m, kk) exact d2, (m, kk) GLOBAL ids, -1
    padded)."""
    from .verify_engine import get_engine  # lazy: host path stays jax-free

    view = ops.device_view()
    trows = ops.table_rows(pos) if ops.table_rows is not None else pos
    nv, nrows = get_engine().screen_topk(view, trows, Q, k, exact=exact)
    if ops.table_ids is not None:
        gids = np.where(nrows >= 0, ops.table_ids(np.maximum(nrows, 0)), -1)
    else:
        gids = nrows
    return nv, gids


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------
def execute(
    plan: QueryPlan,
    Q: np.ndarray,
    k: int = 1,
    *,
    state: Optional[tuple[np.ndarray, np.ndarray]] = None,
    stats: Optional[QueryStats] = None,
    backend: str = "device",
    blocks_per_round: int = 32,
    shard: Optional[str] = None,
    mesh=None,
) -> tuple[tuple[np.ndarray, np.ndarray], QueryStats]:
    """Run a :class:`QueryPlan` for a query batch, folding one (m, k) state.

    Sources execute in plan order (newest first), so distances verified
    against recent data prune blocks of older, larger sources for the
    whole batch — exactly how the per-query bsf heap threaded through the
    runs before the refactor. ``state``/``stats`` thread across calls the
    same way (an index with several plans per query folds one state).

    Stats semantics under batching: ``blocks_visited``/``blocks_pruned``
    count per-(query, block) logical work (comparable to summed scalar
    stats); ``entries_verified`` counts physical fetches (shared per
    batch); ``entries_pruned`` counts window filtering + the entry-level
    MINDIST screen.

    ``shard="mesh"``: execute the exact tier as a dense device-mesh scan
    (queries x runs 2-D ``shard_map``), host-re-ranked to match the
    single-device engine; requires block/dense sources only.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown batch verify backend {backend!r}")
    if shard not in (None, "none", "mesh"):
        raise ValueError(f"unknown shard mode {shard!r}")
    Q = np.asarray(Q, np.float32)
    m = Q.shape[0]
    stats = stats if stats is not None else QueryStats()
    if state is not None:  # copy: group merges below write rows in place
        vals, ids = state[0].copy(), state[1].copy()
    else:
        vals, ids = empty_topk_state(m, k)
    stats.blocks_pruned += plan.pruned_blocks * m  # run-level temporal skips
    if m == 0:
        return (vals, ids), stats
    if shard == "mesh":
        return _execute_mesh(plan, Q, k, vals, ids, stats, mesh)
    for src in plan.sources:
        if isinstance(src, DenseSource):
            vals, ids = _exec_dense(src, plan, Q, k, vals, ids)
        elif isinstance(src, BlockSource):
            vals, ids = _exec_blocks(
                src, plan, Q, k, vals, ids, stats, backend, blocks_per_round
            )
        elif isinstance(src, RangeSource):
            vals, ids = _exec_range(src, plan, Q, k, vals, ids, stats, backend)
        elif isinstance(src, GroupSource):
            vals, ids = _exec_group(src, plan, Q, k, vals, ids, stats, backend)
        else:  # pragma: no cover - plan builder bug
            raise TypeError(f"unknown plan source {type(src).__name__}")
    return (vals, ids), stats


def _exec_dense(src: DenseSource, plan, Q, k, vals, ids):
    """Brute-force a small set (buffers / pending inserts): window filter,
    fetch, one exact screen. Dense sources serve the EXACT tier (the write
    buffer is part of every index's ground truth), so they use the
    error-bound screen — the slack-8 form can mis-rank under f32
    cancellation (large common offsets). By long-standing convention these
    in-memory scans contribute neither stats nor modeled I/O beyond their
    fetch."""
    if src.n == 0:
        return vals, ids
    pos = np.arange(src.n)
    win = window_mask(src.ops.ts, plan.window, pos)
    if win is not None:
        pos = pos[win]
    if pos.size == 0:
        return vals, ids
    data = src.ops.fetch(pos)
    nv, ni = _screen_topk_exact(Q, data, k)
    return merge_topk_state(vals, ids, nv, src.ops.ids[pos][ni])


def _exec_blocks(src: BlockSource, plan, Q, k, vals, ids, stats, backend,
                 blocks_per_round):
    """Adaptive best-first exact traversal over lower-bounded blocks.

    1. a seed pass over each active query's best-bounded block tightens
       every radius cheaply;
    2. bounded rounds cover the union of blocks any query still needs —
       each round is ONE shared verification of the whole batch against the
       round's entries, with an entry-level MINDIST screen against the
       current per-query radii (the batched form of the scalar path's
       per-entry pruning).

    Like the dense ED scan kernel, this trades per-entry early abandoning
    for large regular passes whose extra (query, entry) pairs only ever
    tighten other queries' radii. ``src.refine`` (ADS+ adaptive splits) is
    consulted before a block is verified; replaced blocks re-enter the
    traversal as their children and are never verified themselves.
    """
    ops = src.ops
    m = Q.shape[0]
    lb = np.asarray(src.lb, np.float32).reshape(m, -1)
    blocks = list(src.blocks)
    done = np.zeros(lb.shape[1], bool)
    replaced = np.zeros(lb.shape[1], bool)
    # The entry-level MINDIST screen only pays off when per-query radii are
    # tight — small batches (the scalar wrappers above all). At large batch
    # sizes the union radius is loose, so the screen prunes little while
    # its (m, u, w) bound evaluation rivals the sgemm it tries to avoid;
    # there the shared dense pass alone is the right trade (the ED-scan
    # kernel argument). Small batches also step one block per round so the
    # radius re-checks before every block, exactly like the pre-plan
    # scalar loop.
    qp = None
    if ops.sax is not None and m <= 8:
        qp = np.asarray(paa(Q, ops.scfg))  # (m, w) for the entry screen
    # Small batches start at ONE block per round — the radius re-checks
    # before every block, exactly like the pre-plan scalar loop — then the
    # round size doubles: once the seed + first rounds have tightened the
    # radii, remaining blocks mostly prune, and grouping what survives
    # amortizes per-round overhead (and device launches) instead of paying
    # it per block. Verifying a few extra blocks per round can only confirm
    # the exact answer, so answers are invariant to the round structure.
    round_cap = 1 if m <= 8 else blocks_per_round

    def try_refine(sel: np.ndarray) -> bool:
        nonlocal lb, done, replaced
        if src.refine is None:
            return False
        changed = False
        for b in sel:
            rep = src.refine(int(b))
            if rep is None:
                continue
            changed = True
            done[b] = True
            replaced[b] = True
            lb[:, b] = np.inf
            for col, pos in rep:
                lb = np.concatenate(
                    [lb, np.asarray(col, np.float32).reshape(m, 1)], axis=1
                )
                blocks.append(np.asarray(pos, np.int64))
                done = np.append(done, False)
                replaced = np.append(replaced, False)
        return changed

    def verify(sel: np.ndarray) -> None:
        nonlocal vals, ids
        done[sel] = True
        pos = np.concatenate([blocks[b] for b in sel])
        if ops.index_read is not None:
            ops.index_read(pos)
        win = window_mask(ops.ts, plan.window, pos)
        if win is not None:
            stats.entries_pruned += int((~win).sum())
            pos = pos[win]
        if pos.size and qp is not None:
            # entry-level MINDIST screen vs every query's current radius:
            # an entry is fetched only if it could still improve someone
            elb = mindist_paa_sax2(
                qp[:, None, :], ops.sax[pos].astype(np.int64), ops.scfg
            )  # (m, u)
            keep = (elb < vals[:, -1][:, None]).any(axis=0)
            stats.entries_pruned += int((~keep).sum())
            pos = pos[keep]
        if pos.size == 0:
            return
        stats.entries_verified += int(pos.size)
        if _device_ready(ops, pos.size, backend, Q.shape[0]):
            # ONE fused arena pass (gather + screen + in-kernel select);
            # only the certified slate comes back for the f64 re-rank
            _account_fetch(ops, pos)
            nv, gids = _device_topk(Q, ops, pos, k, exact=True)
        else:
            data = ops.fetch(pos)
            if backend == "kernel":
                # ONE all-pairs topk_ed Pallas launch per (source, batch, pass)
                nv, ni = _kernel_topk_dists(Q, data, k)
            else:
                nv, ni = _screen_topk_exact(Q, data, k)
            gids = np.where(ni >= 0, ops.ids[pos][np.maximum(ni, 0)], -1)
        vals, ids = merge_topk_state(vals, ids, nv, gids)

    # seed pass: every active query's single best-bounded block — tightens
    # all radii with one small shared verification
    while True:
        worst = vals[:, -1]
        best = np.argmin(lb, axis=1)
        active = lb[np.arange(m), best] < worst
        seed = np.unique(best[active])
        seed = seed[~done[seed]]
        if seed.size == 0:
            break
        if try_refine(seed):
            continue
        verify(seed)
        break

    # bounded rounds: the union of blocks any query still needs, best
    # bounds first so earlier rounds tighten later ones. Blocks no query
    # needs are pruned for the whole batch.
    while True:
        worst = vals[:, -1]
        need = (lb < worst[:, None]) & ~done[None, :]
        todo = np.nonzero(need.any(axis=0))[0]
        if todo.size == 0:
            break
        todo = todo[np.argsort(lb[:, todo].min(axis=0), kind="stable")]
        chunk = todo[:round_cap]
        if try_refine(chunk):
            continue
        verify(chunk)
        round_cap = min(round_cap * 2, blocks_per_round)  # adaptive growth

    # per-query logical accounting, comparable to summed scalar stats
    worst = vals[:, -1]
    live = ~replaced
    visited_q = (done[None, :] & live[None, :] & (lb < worst[:, None])).sum(axis=1)
    stats.blocks_visited += int(visited_q.sum())
    stats.blocks_pruned += int((int(live.sum()) - visited_q).sum())
    return vals, ids


def _exec_range(src: RangeSource, plan, Q, k, vals, ids, stats, backend):
    """The approximate tier on a sorted run: coalesce the per-query entry
    spans into deduplicated sequential reads, then one shared top-k pass
    per DISTINCT span — queries that seek into the same neighborhood share
    a pass, and disjoint spans never multiply each other's distance work."""
    ops = src.ops
    lo, hi = src.spans[:, 0], src.spans[:, 1]
    stats.blocks_visited += src.logical_blocks
    # coalesce the per-query [lo, hi) entry ranges: overlapping queries
    # collapse into few long sequential index reads
    ranges = coalesce_ranges(zip(lo.tolist(), hi.tolist()))
    if ops.prefetch_ranges is not None:
        # kick the mmap page faults off now; the verify pass below reads
        # the same rows once the window filter has had its say
        ops.prefetch_ranges(ranges)
    if src.read_index_ranges is not None:
        src.read_index_ranges(ranges)
    if not ranges:
        return vals, ids
    upos = np.concatenate([np.arange(r0, r1) for r0, r1 in ranges])
    win = window_mask(ops.ts, plan.window, upos)
    if win is not None:
        stats.entries_pruned += int((~win).sum())
        upos = upos[win]
    if upos.size == 0:
        return vals, ids
    stats.entries_verified += int(upos.size)
    spans_u, inv = np.unique(np.stack([lo, hi], axis=1), axis=0, return_inverse=True)
    n_groups = spans_u.shape[0]
    qidx_g = [np.nonzero(inv == g)[0] for g in range(n_groups)]
    # each group's slice of the (sorted, window-filtered) union positions
    j01 = np.stack([np.searchsorted(upos, spans_u[:, 0]),
                    np.searchsorted(upos, spans_u[:, 1])], axis=1)
    contiguous = (ops.series is not None
                  and upos.size == sum(r1 - r0 for r0, r1 in ranges))
    # Route PER GROUP: a group takes the no-fetch device route only when it
    # clears the engine's floors ITSELF. Routing the whole pass on "any
    # group is device-ready" used to strand every small group on a
    # per-group gather from the arena's host mirror — dozens of fancy
    # gathers plus tiny device launches instead of one shared fetch (the
    # b64/nb2 throughput collapse in BENCH_streaming).
    dev = np.zeros(n_groups, bool)
    if backend == "device" and ops.device_view is not None:
        for g in range(n_groups):
            dev[g] = _device_ready(ops, int(j01[g, 1] - j01[g, 0]), backend,
                                   qidx_g[g].size)
    data_h = gid_h = xsq_h = None
    hmap = None  # upos index -> row in the shared host fetch
    if contiguous:
        # contiguous materialized ranges: slice views per group below — no
        # 10s-of-MB union gather; only the I/O accounting happens here
        if src.read_payload_ranges is not None:
            src.read_payload_ranges(ranges)
    else:
        # ONE shared fetch of exactly the rows the host-tail groups need
        # (overlapping groups share rows); device groups account the
        # modeled I/O of their remaining rows without materializing them
        hsel = np.zeros(upos.size, bool)
        for g in np.nonzero(~dev)[0]:
            hsel[j01[g, 0]:j01[g, 1]] = True
        if hsel.any():
            hmap = np.full(upos.size, -1, np.int64)
            hmap[hsel] = np.arange(int(hsel.sum()))
            hpos = upos[hsel]
            data_h = ops.fetch(hpos)
            gid_h = ops.ids[hpos]
            if backend != "kernel" and ops.norms2 is not None:
                xsq_h = ops.norms2(hpos)  # cached |x|^2: fetched once
        if dev.any():
            dsel = np.zeros(upos.size, bool)
            for g in np.nonzero(dev)[0]:
                dsel[j01[g, 0]:j01[g, 1]] = True
            dacct = dsel & ~hsel  # rows the host fetch already accounted
            if dacct.any():
                _account_fetch(ops, upos[dacct])
    for g in range(n_groups):
        qidx = qidx_g[g]
        j0, j1 = int(j01[g, 0]), int(j01[g, 1])
        if j0 == j1:
            continue
        if dev[g]:
            # fused arena pass for this distinct span's query group; the
            # approx tier keeps its slack-screen fallback semantics
            nv, gi = _device_topk(Q[qidx], ops, upos[j0:j1], k, exact=False)
            mv, mi = merge_topk_state(vals[qidx], ids[qidx], nv, gi)
            vals[qidx], ids[qidx] = mv, mi
            continue
        if contiguous:
            glo, ghi = int(spans_u[g, 0]), int(spans_u[g, 1])
            sub = ops.series[glo:ghi]  # contiguous materialized: a view
            gid = ops.ids[glo:ghi]
        else:
            rows = hmap[j0:j1]
            sub = data_h[rows]
            gid = gid_h[rows]
        if backend == "kernel":
            nv, ni = _kernel_topk_dists(Q[qidx], sub, k)
            gi = np.where(ni >= 0, gid[np.maximum(ni, 0)], -1)
        else:
            if contiguous:
                xsq_g = (ops.norms2(np.arange(glo, ghi))
                         if ops.norms2 is not None else None)
            else:
                xsq_g = None if xsq_h is None else xsq_h[rows]
            nv, ni = _screen_topk_slack(Q[qidx], sub, k, xsq=xsq_g)
            gi = gid[ni]
        mv, mi = merge_topk_state(vals[qidx], ids[qidx], nv, gi)
        vals[qidx], ids[qidx] = mv, mi
    return vals, ids


def _exec_group(src: GroupSource, plan, Q, k, vals, ids, stats, backend):
    """The approximate tier on a leaf-partitioned tree: verify each
    DISTINCT leaf once against its whole query group."""
    ops = src.ops
    if src.pre_read is not None:
        src.pre_read()
    for gnum, (qidx, pos) in enumerate(src.groups):
        qidx = np.asarray(qidx)
        stats.blocks_visited += int(qidx.size)  # per-query logical accounting
        if src.group_reads is not None:
            src.group_reads[gnum]()  # one shared leaf read
        win = window_mask(ops.ts, plan.window, pos)
        if win is not None:
            stats.entries_pruned += int((~win).sum())
            pos = pos[win]
        if pos.size == 0:
            continue
        stats.entries_verified += int(pos.size)
        if _device_ready(ops, pos.size, backend, qidx.size):
            _account_fetch(ops, pos)
            nv, gi = _device_topk(Q[qidx], ops, pos, k, exact=False)
        else:  # small leaf groups take the host tail (same answers)
            data = ops.fetch(pos)
            if backend == "kernel":
                nv, ni = _kernel_topk_dists(Q[qidx], data, k)
                gi = np.where(ni >= 0, ops.ids[pos][np.maximum(ni, 0)], -1)
            else:
                nv, ni = _screen_topk_slack(Q[qidx], data, k)
                gi = ops.ids[pos][ni]
        mv, mi = merge_topk_state(vals[qidx], ids[qidx], nv, gi)
        vals[qidx], ids[qidx] = mv, mi
    return vals, ids


# ---------------------------------------------------------------------------
# mesh-sharded execution (queries x runs 2-D parallelism)
# ---------------------------------------------------------------------------
def _execute_mesh(plan, Q, k, vals, ids, stats, mesh):
    """Exact batched kNN as a dense device-mesh scan over the plan.

    Every planned source's in-window entries are gathered (fetch closures
    account the modeled I/O of the scan) and screened on the mesh — the
    query batch sharded over the first mesh axis, the source entries over
    the remaining axes — then the per-shard slates fold with one
    ``all_gather`` and the host re-ranks the survivors in f64, so results
    match the single-device executor. Assumes HBM-resident runs (the
    ROADMAP's serving posture); the approximate tier stays host-side where
    the seek/coalesce I/O model is meaningful.
    """
    from .distributed import mesh_topk_candidates  # lazy: host engine stays jax-free

    m = Q.shape[0]
    chunks_data, chunks_ids = [], []
    for src in plan.sources:
        if isinstance(src, DenseSource):
            pos = np.arange(src.n)
        elif isinstance(src, BlockSource):
            pos = (
                np.concatenate(src.blocks)
                if src.blocks
                else np.zeros((0,), np.int64)
            )
            stats.blocks_visited += len(src.blocks) * m
        else:
            raise ValueError(
                "shard='mesh' executes the exact tier only (block/dense sources)"
            )
        win = window_mask(src.ops.ts, plan.window, pos)
        if win is not None:
            stats.entries_pruned += int((~win).sum())
            pos = pos[win]
        if pos.size == 0:
            continue
        chunks_data.append(src.ops.fetch(pos))
        chunks_ids.append(src.ops.ids[pos])
        stats.entries_verified += int(pos.size)
    if not chunks_data:
        return (vals, ids), stats
    X = np.concatenate(chunks_data)
    gids_all = np.concatenate(chunks_ids)
    c = X.shape[0]
    ksel = min(k + 8, c)  # slack absorbs f32 near-tie reordering
    # Center the table before the f32 device screen: squared ED is
    # translation-invariant, and removing the common offset kills the
    # |x|^2 - 2<q, x> cancellation that would otherwise scramble the f32
    # ranking for large-magnitude series.
    mu = X.mean(axis=0)
    d2s, rows = mesh_topk_candidates(Q - mu, X - mu, ksel, mesh=mesh)
    nv, nrows = _rerank_slate(Q, X, rows, k)
    # Certify the screen: any candidate outside the slate has f32 screen
    # distance >= the slate's worst, hence true distance >= worst - 2*bound
    # (classical f32 matmul error, the _screen_topk_exact bound). Queries
    # whose f64-re-ranked kth distance does not clear that margin — or with
    # unfillable slate slots — fall back to the provably exact host screen
    # over the gathered table, so mesh answers match the single-device
    # engine on every input, not just well-conditioned ones.
    if ksel < c:
        qn = np.sqrt(np.einsum("mn,mn->m", Q - mu, Q - mu, dtype=np.float64))
        xn_max = float(np.sqrt(np.einsum("cn,cn->c", X - mu, X - mu,
                                         dtype=np.float64).max()))
        bound = 4.0 * X.shape[1] * np.finfo(np.float32).eps * qn * xn_max
        kth = nv[:, min(k, nv.shape[1]) - 1] if nv.shape[1] else np.zeros(m)
        certified = (rows >= 0).all(axis=1) & (
            np.where(np.isfinite(kth), kth, 0.0)
            <= d2s[:, -1] - 2.0 * bound
        )
        bad = np.nonzero(~certified)[0]
        if bad.size:
            ev, er = _screen_topk_exact(Q[bad], X, k)
            pad = nv.shape[1] - ev.shape[1]
            if pad > 0:
                ev = np.concatenate(
                    [ev, np.full((bad.size, pad), np.inf, ev.dtype)], axis=1)
                er = np.concatenate(
                    [er, np.full((bad.size, pad), -1, er.dtype)], axis=1)
            nv[bad], nrows[bad] = ev, er
    gi = np.where(nrows >= 0, gids_all[np.maximum(nrows, 0)], -1)
    vals, ids = merge_topk_state(vals, ids, nv, gi)
    return (vals, ids), stats
