"""Lower-bound distances used for pruning in exact search.

MINDIST_PAA_SAX(q, x) <= ED(q, x): the classic iSAX guarantee chain —
PAA lower-bounds ED (Keogh), and the SAX region of x contains paa(x), so the
point-to-region distance lower-bounds the PAA distance.

Everything here is numpy (host search engine); the device twin lives in
``kernels/ref.py`` and ``kernels/lb_kernel.py``.
"""
from __future__ import annotations

import numpy as np

from .summarization import SummarizationConfig, breakpoints, paa, sax_region


def ed2(q: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Squared Euclidean distance. q: (n,) or (m, n); x: (..., n)."""
    d = x - q
    return np.sum(d * d, axis=-1)


def topk_ed2(q: np.ndarray, x: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Batched k smallest squared EDs per query — the host twin of the
    ``kernels.ops.topk_ed`` device path (same matmul form, float64
    accumulation so it keeps the scalar ``ed2`` path's accuracy).

    q: (m, n), x: (N, n) -> ((m, kk) f32 ascending, (m, kk) int64 candidate
    rows) with kk = min(k, N)."""
    q64 = np.asarray(q, np.float64)
    x64 = np.asarray(x, np.float64)
    d2 = (
        np.sum(q64 * q64, axis=-1)[:, None]
        + np.sum(x64 * x64, axis=-1)[None, :]
        - 2.0 * q64 @ x64.T
    )
    d2 = np.maximum(d2, 0.0).astype(np.float32)  # (m, N)
    kk = min(k, x64.shape[0])
    part = np.argpartition(d2, kk - 1, axis=1)[:, :kk] if kk < d2.shape[1] else (
        np.broadcast_to(np.arange(kk), (d2.shape[0], kk))
    )
    pv = np.take_along_axis(d2, part, axis=1)
    o = np.argsort(pv, axis=1, kind="stable")
    return (
        np.take_along_axis(pv, o, axis=1),
        np.take_along_axis(part, o, axis=1).astype(np.int64),
    )


def mindist_paa_sax2(q_paa: np.ndarray, sym: np.ndarray, cfg: SummarizationConfig) -> np.ndarray:
    """Squared MINDIST between a query's PAA and candidates' SAX regions.

    q_paa: (w,) or (m, 1, w) broadcastable against sym's leading dims
    sym:   (..., w) int SAX symbols
    returns squared lower bound on ED (same leading shape as sym/broadcast).
    """
    lo, hi = sax_region(sym, cfg)
    below = np.maximum(lo - q_paa, 0.0)
    above = np.maximum(q_paa - hi, 0.0)
    d = np.maximum(below, above)
    return cfg.segment_len * np.sum(d * d, axis=-1, dtype=np.float64).astype(np.float32)


def mindist_region2(
    q_paa: np.ndarray,
    min_sym: np.ndarray,
    max_sym: np.ndarray,
    cfg: SummarizationConfig,
) -> np.ndarray:
    """Squared MINDIST between a query's PAA and a *range* of SAX symbols
    (zone map of a block / LSM run / iSAX subtree node).

    The region per segment is [region_lo(min_sym), region_hi(max_sym)], which
    contains every entry's region, so this lower-bounds every entry's
    MINDIST and hence every entry's true ED.
    """
    bps = breakpoints(cfg.card_bits)
    big = np.float32(1e30)
    lo_edges = np.concatenate([[-big], bps]).astype(np.float32)
    hi_edges = np.concatenate([bps, [big]]).astype(np.float32)
    lo = lo_edges[min_sym]
    hi = hi_edges[max_sym]
    below = np.maximum(lo - q_paa, 0.0)
    above = np.maximum(q_paa - hi, 0.0)
    d = np.maximum(below, above)
    return cfg.segment_len * np.sum(d * d, axis=-1, dtype=np.float64).astype(np.float32)


def query_paa(q: np.ndarray, cfg: SummarizationConfig) -> np.ndarray:
    """PAA of a query (convenience; honors cfg.znorm)."""
    return np.asarray(paa(q, cfg))
