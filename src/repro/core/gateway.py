"""SLO-aware dynamic-batching serving gateway.

The engine serves *batches*; interactive traffic arrives as independent
single queries. This module is the admission-control layer between them
(the shape production inference stacks call continuous batching):

* an admission queue coalesces arriving queries into the largest batch
  rung available — the rungs are exactly the verify engine's query-batch
  buckets (``_bucket_batch``: powers of two, min 8), so a prewarmed
  gateway never compiles at serve time;
* a **deadline flush** guarantees no query waits more than
  ``deadline_ms`` in queue: when the oldest request's deadline expires the
  batch is flushed as-is and padded up to the rung floor with copies of
  real queries (padding rows are sliced off before results are returned —
  prewarmed shapes make the padding compile-free, and per-query answers
  are independent of batch composition, so padding never changes them);
* **per-request tier selection** routes each request through the
  recommender's serving-tier node (``target_recall`` /
  ``latency_budget_ms`` per request): one formed batch fans out into
  per-(tier, n_blocks, k, window) sub-batches, all answered against ONE
  pinned epoch snapshot;
* with ``GatewayConfig(autotune=True)`` tier selection consults the
  online :class:`~repro.core.autotune.AutoTuner` instead of the frozen
  rule node: each sub-batch's measured service latency (and, on probed
  servings, shadow-measured recall@k vs exact) feeds the per-workload
  fitted models back after every formed batch;
* **backpressure sheds to the approximate tier** — not into an unbounded
  queue: the admission queue is bounded (``max_queue``; ``submit``
  blocks), and when the measured rolling p99 drifts past ``slo_p99_ms``
  the gateway starts answering sheddable exact-tier requests on the
  approximate tier instead, with hysteresis (``shed_exit_frac``) so it
  recovers. Requests with ``target_recall >= 1.0`` are contractually
  exact and are never shed; a recommender ``conflict`` (the latency cap
  makes the recall target unreachable) is itself a shed signal.

Every response carries provenance: ``tier_served``, ``shed``,
``conflict``, ``queue_wait_ms``, the formed/padded batch shape, and the
epoch the answer was pinned to.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional

import numpy as np

from .autotune import AutoTuner, AutoTunerConfig, Knobs, workload_key
from .execute import recall_at_k
from .recommender import Scenario, TierDecision, serving_tier
from .verify_engine import _CHUNK_M, _bucket_batch, get_engine


@dataclasses.dataclass(frozen=True)
class GatewayConfig:
    deadline_ms: float = 5.0  # max in-queue wait before a flush
    slo_p99_ms: float = 50.0  # rolling-p99 target that triggers shedding
    max_batch: int = 64  # largest formed batch (top ladder rung)
    k: int = 5  # default neighbors per query
    max_queue: int = 4096  # admission bound; submit() blocks beyond it
    lat_window: int = 256  # completions in the rolling percentile window
    min_shed_samples: int = 32  # completions before shedding may engage
    shed_exit_frac: float = 0.7  # recover when p99 < frac * slo (hysteresis)
    shed_n_blocks: int = 2  # approx recall knob for shed serves
    autotune: bool = False  # tier selection via the online AutoTuner
    autotune_cfg: Optional[AutoTunerConfig] = None  # tuner knobs


@dataclasses.dataclass(frozen=True)
class GatewayStats:
    """Typed point-in-time gateway snapshot.

    The counter vocabulary lines up with ``VerifyEngine.stats`` where the
    concepts overlap (histograms as value->count dicts, byte/event
    counters as plain ints) so BENCH emitters and the autotuner consume
    one documented schema; ``snapshot_stats()`` keeps returning the same
    keys as a dict view for existing callers."""
    submitted: int  # submit() admissions
    served: int  # resolved responses
    shed_served: int  # answers downgraded to approx (or conflicted)
    conflicts: int  # recommender recall/latency conflicts seen
    batches: int  # formed batches dispatched
    deadline_flushes: int  # batches flushed below the top rung
    full_flushes: int  # batches formed at the top rung
    shed_transitions: int  # enter/exit events of the shed state
    batch_hist: dict  # formed (real) batch size -> count
    queue_depth: int  # requests waiting at snapshot time
    shedding: bool  # shed state at snapshot time
    p50_ms: float  # rolling window median latency
    p99_ms: float  # rolling window tail latency (the SLO gate input)
    autotune: bool  # online tuner active
    tuner_decisions: int  # AutoTuner.decide() calls
    tuner_explores: int  # decisions taken by the exploration branch
    tuner_observations: int  # measured outcomes folded into the models
    tuner_probes: int  # shadow exact recall measurements paid


@dataclasses.dataclass
class Response:
    """One client answer + its serving provenance."""
    vals: np.ndarray  # (k,) f64 squared distances, ascending
    ids: np.ndarray  # (k,) int64 global ids (-1 padded)
    tier_served: str  # "exact" | "approx"
    n_blocks: int  # approx tier recall knob used (0 for exact)
    shed: bool  # True when SLO pressure / a conflict downgraded the tier
    conflict: bool  # recommender: latency cap made recall unreachable
    queue_wait_ms: float  # admission -> batch dispatch
    latency_ms: float  # admission -> answer
    batch_size: int  # real queries in the formed batch
    padded_to: int  # ladder rung the sub-batch was padded to
    epoch: int  # pinned snapshot the whole formed batch answered against


@dataclasses.dataclass
class _Request:
    q: np.ndarray
    k: int
    window: Optional[tuple]
    target_recall: Optional[float]
    latency_budget_ms: Optional[float]
    t_arrive: float
    ticket: "Ticket"


class Ticket:
    """Handle returned by ``Gateway.submit``; ``result()`` blocks until the
    dispatcher resolves it."""

    __slots__ = ("_ev", "_resp", "_err")

    def __init__(self):
        self._ev = threading.Event()
        self._resp: Optional[Response] = None
        self._err: Optional[BaseException] = None

    def _resolve(self, resp: Optional[Response] = None,
                 err: Optional[BaseException] = None) -> None:
        self._resp, self._err = resp, err
        self._ev.set()

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None) -> Response:
        if not self._ev.wait(timeout):
            raise TimeoutError("gateway response pending")
        if self._err is not None:
            raise self._err
        return self._resp


def ladder(max_batch: int) -> tuple:
    """The gateway's batch rungs: the engine's query-batch buckets (pow2,
    min 8) up to ``max_batch`` — shared so prewarm covers exactly the
    shapes the dispatcher can form."""
    rungs, m = [], 8
    while m < max_batch:
        rungs.append(m)
        m *= 2
    rungs.append(max_batch)
    return tuple(rungs)


class Gateway:
    """Admission queue + dispatcher thread over a ``StreamingIndex``.

    Thread-shared state (queue, rolling latencies, shed flag, stats,
    tier-decision cache) is guarded by ``self._cond`` — palmlint's
    lock-discipline checker enforces it. Device work (the engine passes)
    runs OUTSIDE the lock so clients keep submitting while a batch
    serves."""

    def __init__(self, index, cfg: Optional[GatewayConfig] = None):
        self._idx = index
        self.cfg = cfg or GatewayConfig()
        if self.cfg.max_batch > _CHUNK_M:
            raise ValueError(
                f"max_batch {self.cfg.max_batch} exceeds the engine's query "
                f"chunk {_CHUNK_M}; larger formed batches would split into "
                "multiple passes and defeat the ladder accounting")
        self._cond = threading.Condition()
        self._queue: deque = deque()
        self._lat_ms: deque = deque(maxlen=self.cfg.lat_window)
        self._shedding = False
        self._closed = False
        self._tier_cache: dict = {}
        self.tuner: Optional[AutoTuner] = None
        if self.cfg.autotune:
            self.tuner = AutoTuner(self.cfg.autotune_cfg)
        self.stats = {
            "submitted": 0,
            "served": 0,
            "shed_served": 0,  # answers downgraded to approx (or conflicted)
            "conflicts": 0,  # recommender recall/latency conflicts seen
            "batches": 0,  # formed batches dispatched
            "deadline_flushes": 0,  # batches flushed below the top rung
            "full_flushes": 0,  # batches formed at the top rung
            "batch_hist": {},  # formed (real) batch size -> count
            "shed_transitions": 0,  # enter/exit events of the shed state
        }
        self._thread = threading.Thread(target=self._dispatch_loop,
                                        name="gateway-dispatch", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------ client API
    def submit(self, q, *, k: Optional[int] = None,
               window: Optional[tuple] = None,
               target_recall: Optional[float] = None,
               latency_budget_ms: Optional[float] = None) -> Ticket:
        """Enqueue one query; returns immediately with a ``Ticket`` unless
        the bounded admission queue is full (then blocks — backpressure)."""
        q = np.asarray(q, np.float32).reshape(-1)
        req = _Request(q=q, k=int(k if k is not None else self.cfg.k),
                       window=None if window is None else
                       (int(window[0]), int(window[1])),
                       target_recall=target_recall,
                       latency_budget_ms=latency_budget_ms,
                       t_arrive=time.perf_counter(), ticket=Ticket())
        with self._cond:
            while len(self._queue) >= self.cfg.max_queue and not self._closed:
                self._cond.wait(0.01)
            if self._closed:
                raise RuntimeError("gateway is closed")
            self._queue.append(req)
            self.stats["submitted"] += 1
            self._cond.notify_all()
        return req.ticket

    def prewarm(self, caps, *, dtype: Optional[str] = None) -> int:
        """Compile every (batch rung x table bucket) verification shape the
        dispatcher can form, so steady-state serving runs with zero
        retraces. ``caps`` — table sizes the stream will reach (the engine
        dedupes them onto its capacity rungs)."""
        eng = get_engine()
        d = int(self._idx.cfg.summarization.series_len)
        n = 0
        for rung in ladder(self.cfg.max_batch):
            n += eng.prewarm(d, rung, self.cfg.k, list(caps), dtype=dtype)
        return n

    def snapshot(self) -> GatewayStats:
        """Typed point-in-time snapshot of the gateway counters, rolling
        percentiles, and (when autotuning) the tuner's loop counters."""
        # gather tuner counters BEFORE taking self._cond: the tuner has
        # its own lock and must never nest inside the gateway's
        tc = self.tuner.counters() if self.tuner is not None else {}
        with self._cond:
            st = self.stats
            lat = np.array(self._lat_ms, np.float64)
            return GatewayStats(
                submitted=st["submitted"], served=st["served"],
                shed_served=st["shed_served"], conflicts=st["conflicts"],
                batches=st["batches"],
                deadline_flushes=st["deadline_flushes"],
                full_flushes=st["full_flushes"],
                shed_transitions=st["shed_transitions"],
                batch_hist=dict(st["batch_hist"]),
                queue_depth=len(self._queue), shedding=self._shedding,
                p50_ms=float(np.percentile(lat, 50)) if lat.size else 0.0,
                p99_ms=float(np.percentile(lat, 99)) if lat.size else 0.0,
                autotune=self.tuner is not None,
                tuner_decisions=tc.get("decisions", 0),
                tuner_explores=tc.get("explores", 0),
                tuner_observations=tc.get("observations", 0),
                tuner_probes=tc.get("probes", 0))

    def snapshot_stats(self) -> dict:
        """Dict view of :meth:`snapshot` (back-compat for existing
        callers; same keys, ``batch_hist`` keeps its int keys)."""
        return dataclasses.asdict(self.snapshot())

    def reset_slo_window(self) -> None:
        """Drop the rolling latency window and leave the shed state.

        Warm-up traffic pays one-time compiles whose multi-second
        latencies would otherwise sit in the p99 window (``lat_window``
        completions) and keep the shed gate engaged long into steady
        state — at low arrival rates the window can take the whole run to
        wash out. Harnesses that measure steady state (the serving
        benchmark, ``serve.py --gateway``) call this once after draining
        their warm-up requests."""
        with self._cond:
            self._lat_ms.clear()
            if self._shedding:
                self._shedding = False
                self.stats["shed_transitions"] += 1

    def close(self, timeout: float = 30.0) -> None:
        """Stop admitting, drain the queue, stop the dispatcher."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._thread.join(timeout)

    # ------------------------------------------------------------ dispatcher
    def _dispatch_loop(self) -> None:
        while True:
            formed = self._form_batch()
            if formed is None:
                return
            batch, shed_now = formed
            if not batch:
                continue
            try:
                self._serve_batch(batch, shed_now)
            except BaseException as e:  # resolve, or clients hang forever
                for req in batch:
                    req.ticket._resolve(err=e)

    def _form_batch(self):
        """Block until a batch is ready: either the top rung fills or the
        oldest request's deadline expires (then flush whatever is queued).
        Returns None when closed and drained."""
        cfg = self.cfg
        with self._cond:
            while not self._queue and not self._closed:
                self._cond.wait()
            if not self._queue:
                return None  # closed and drained
            deadline = self._queue[0].t_arrive + cfg.deadline_ms / 1e3
            while len(self._queue) < cfg.max_batch and not self._closed:
                rem = deadline - time.perf_counter()
                if rem <= 0:
                    break
                self._cond.wait(rem)
                if not self._queue:
                    return None if self._closed else ([], False)
            take = min(len(self._queue), cfg.max_batch)
            batch = [self._queue.popleft() for _ in range(take)]
            self.stats["batches"] += 1
            key = "full_flushes" if take >= cfg.max_batch else "deadline_flushes"
            self.stats[key] += 1
            hist = self.stats["batch_hist"]
            hist[take] = hist.get(take, 0) + 1
            shed_now = self._shedding
            self._cond.notify_all()  # free space for blocked submitters
        return batch, shed_now

    def _route(self, req: _Request, shed_now: bool, *, epoch: int,
               n_series: int):
        """(tier, n_blocks, shed, conflict, tune) for one request —
        ``tune`` is the ``(WorkloadKey, Knobs)`` pair to feed back to the
        tuner after serving (None on the static path). Strictly-exact
        requests (target_recall >= 1.0) are never shed; a conflict (the
        latency cap makes the recall target unreachable) marks the answer
        shed even when not under SLO pressure — it already cost the
        client its recall target."""
        tr, lb = req.target_recall, req.latency_budget_ms
        strict = tr is not None and tr >= 1.0
        tune = None
        if tr is None and lb is None:
            tier, nb, conflict = "exact", 0, False
        elif self.tuner is not None:
            wkey = workload_key(
                target_recall=tr, latency_budget_ms=lb, k=req.k,
                window=req.window, batch_rung=self.cfg.max_batch)
            rec = self.tuner.decide(wkey, epoch=epoch, n_series=n_series)
            tier, nb, conflict = rec.knobs.tier, rec.knobs.n_blocks, \
                rec.conflict
            tune = (wkey, rec.knobs, rec.shadow)
        else:
            dec = self._tier_decision(tr, lb)
            tier, nb, conflict = dec.tier, dec.n_blocks, dec.conflict
        shed = conflict
        if shed_now and tier == "exact" and not strict:
            tier, nb, shed = "approx", self.cfg.shed_n_blocks, True
            if tune is not None:
                # observations must credit the arm actually served; the
                # shed serve preempts any exploration shadow
                tune = (tune[0], Knobs("approx", nb), None)
        return tier, nb, shed, conflict, tune

    def _tier_decision(self, tr, lb) -> TierDecision:
        """Cached recommender serving-tier call. The live entry count is
        quantized to its power-of-two bucket so the cache stays small and
        decisions stay stable while ingest grows the store."""
        n_live = max(1024, int(self._idx.raw.n))
        n_q = 1 << (n_live - 1).bit_length()
        key = (tr, lb, n_q)
        with self._cond:
            dec = self._tier_cache.get(key)
        if dec is None:
            dec = serving_tier(Scenario(
                streaming=True, n_series=n_q,
                series_len=int(self._idx.cfg.summarization.series_len),
                uses_windows=True, target_recall=tr, latency_budget_ms=lb,
                query_batch=self.cfg.max_batch))
            with self._cond:
                self._tier_cache[key] = dec
        return dec

    def _query_group(self, tier: str, nb: int, Qg, kk: int, window, snap):
        """One engine pass for a padded sub-batch -> (vals, gids)."""
        if tier == "approx":
            if window is None:
                vals, gids, _ = self._idx.knn_approx_batch(
                    Qg, k=kk, n_blocks=max(nb, 1), snapshot=snap)
            else:
                vals, gids, _ = self._idx.window_knn_approx_batch(
                    Qg, window[0], window[1], k=kk, n_blocks=max(nb, 1),
                    snapshot=snap)
        elif window is None:
            vals, gids, _ = self._idx.knn_batch(Qg, k=kk, snapshot=snap)
        else:
            vals, gids, _ = self._idx.window_knn_batch(
                Qg, window[0], window[1], k=kk, snapshot=snap)
        return vals, gids

    def _serve_batch(self, batch, shed_now: bool) -> None:
        t_dispatch = time.perf_counter()
        # ONE pinned epoch for the whole formed batch: every sub-batch
        # answers against the same immutable snapshot even while background
        # ingest publishes new epochs mid-serve. Routing happens INSIDE the
        # pin so tuner decisions are stamped with the epoch they serve.
        with self._idx.pin() as snap:
            epoch = int(snap.epoch)
            n_series = max(1024, int(self._idx.raw.n))
            groups: dict = {}
            routed = []
            for i, req in enumerate(batch):
                tier, nb, shed, conflict, tune = self._route(
                    req, shed_now, epoch=epoch, n_series=n_series)
                routed.append((tier, nb, shed, conflict, tune))
                groups.setdefault((tier, nb, req.k, req.window),
                                  []).append(i)
            n_shed = n_conflict = 0
            lat_done = []
            served = []  # (key, idxs, Qg, gids, dt_ms) for shadow work
            # deterministic sub-batch order: mixed-tenant batches always
            # split and serve the same way for the same inputs
            for key in sorted(groups, key=lambda t: (t[0], t[1], t[2],
                                                     t[3] or (-1, -1))):
                tier, nb, kk, window = key
                idxs = groups[key]
                Qg = np.stack([batch[i].q for i in idxs])
                rung = _bucket_batch(len(idxs))
                if rung > len(idxs):
                    # pad to the rung floor with copies of a real query;
                    # prewarmed shapes make this compile-free and the rows
                    # are sliced off below — padding never leaks
                    Qg = np.concatenate(
                        [Qg, np.repeat(Qg[:1], rung - len(idxs), axis=0)])
                t0 = time.perf_counter()
                vals, gids = self._query_group(tier, nb, Qg, kk, window,
                                               snap)
                t_grp = time.perf_counter()
                dt_ms = (t_grp - t0) * 1e3
                # resolve this sub-batch's tickets NOW: a slower later
                # group — or the shadow probe/exploration work below —
                # never inflates these clients' latency
                for row_, i in enumerate(idxs):
                    req = batch[i]
                    shed, conflict = routed[i][2], routed[i][3]
                    n_shed += int(shed)
                    n_conflict += int(conflict)
                    lat = (t_grp - req.t_arrive) * 1e3
                    lat_done.append(lat)
                    req.ticket._resolve(Response(
                        vals=vals[row_], ids=gids[row_], tier_served=tier,
                        n_blocks=nb, shed=shed, conflict=conflict,
                        queue_wait_ms=(t_dispatch - req.t_arrive) * 1e3,
                        latency_ms=lat, batch_size=len(batch),
                        padded_to=rung, epoch=epoch))
                served.append((key, idxs, Qg, gids, dt_ms))
            feedback = self._shadow_work(served, routed, batch, snap) \
                if self.tuner is not None else []
        # feed outcomes back OUTSIDE the pin (and outside self._cond): the
        # tuner has its own lock
        for wkey, knobs, lat_ms, recall, was_served in feedback:
            self.tuner.observe(wkey, knobs, lat_ms=lat_ms, epoch=epoch,
                               recall=recall, n_series=n_series,
                               served=was_served)
        with self._cond:
            self.stats["served"] += len(batch)
            self.stats["shed_served"] += n_shed
            self.stats["conflicts"] += n_conflict
            self._lat_ms.extend(lat_done)
            self._update_shed_locked()

    def _shadow_work(self, served, routed, batch, snap):
        """Post-resolution tuner measurements for one formed batch ->
        ``(wkey, knobs, lat_ms, recall, served)`` observations —
        ``served`` is False for exploration shadows (arms the client was
        not served), so trace consumers can score client-facing quality.

        Runs AFTER every client ticket is resolved, still inside the pin:
        recall probes (shadow exact on probed approx sub-batches) and
        exploration shadows (the bandit's explored arm re-served on the
        same padded sub-batch, timed, never returned to a client). All
        shadow I/O runs unaccounted so the cost model only ever charges
        work a client's answer needed. Padding rows are excluded from
        every recall average."""
        feedback = []
        for key, idxs, Qg, gids, dt_ms in served:
            tier, nb, kk, window = key
            tuned = [routed[i][4] for i in idxs if routed[i][4] is not None]
            if not tuned:
                continue
            n_real = len(idxs)
            exact_gids = gids if tier == "exact" else None
            recall = 1.0 if tier == "exact" else None
            if tier == "approx" and self.tuner.should_probe(tuned[0][0],
                                                            tuned[0][1]):
                with self._idx.raw.disk.unaccounted():
                    _, exact_gids = self._query_group("exact", 0, Qg, kk,
                                                      window, snap)
                recall = float(recall_at_k(gids[:n_real],
                                           exact_gids[:n_real]))
            for wkey, knobs, _shadow in tuned:
                feedback.append((wkey, knobs, dt_ms, recall, True))
            # exploration shadows: measure each explored arm on the same
            # padded sub-batch (prewarmed shapes keep it compile-free);
            # recall is scored when an exact reference is already in hand
            for wkey, _knobs, shadow in tuned:
                if shadow is None:
                    continue
                t0 = time.perf_counter()
                with self._idx.raw.disk.unaccounted():
                    _, s_gids = self._query_group(
                        shadow.tier, shadow.n_blocks, Qg, kk, window, snap)
                s_dt_ms = (time.perf_counter() - t0) * 1e3
                if shadow.tier == "exact":
                    s_recall = 1.0
                elif exact_gids is not None:
                    s_recall = float(recall_at_k(s_gids[:n_real],
                                                 exact_gids[:n_real]))
                else:
                    s_recall = None
                feedback.append((wkey, shadow, s_dt_ms, s_recall, False))
        return feedback

    def _update_shed_locked(self) -> None:
        """Recompute the shed state from the rolling p99 (caller holds the
        lock). Hysteresis: enter above ``slo_p99_ms``, exit only below
        ``shed_exit_frac * slo_p99_ms`` so the state does not flap."""
        if len(self._lat_ms) < self.cfg.min_shed_samples:
            return
        p99 = float(np.percentile(np.array(self._lat_ms, np.float64), 99))
        if not self._shedding and p99 > self.cfg.slo_p99_ms:
            self._shedding = True
            self.stats["shed_transitions"] += 1
        elif self._shedding and p99 < self.cfg.shed_exit_frac * self.cfg.slo_p99_ms:
            self._shedding = False
            self.stats["shed_transitions"] += 1
