"""SLO-aware dynamic-batching serving gateway.

The engine serves *batches*; interactive traffic arrives as independent
single queries. This module is the admission-control layer between them
(the shape production inference stacks call continuous batching):

* an admission queue coalesces arriving queries into the largest batch
  rung available — the rungs are exactly the verify engine's query-batch
  buckets (``_bucket_batch``: powers of two, min 8), so a prewarmed
  gateway never compiles at serve time;
* a **deadline flush** guarantees no query waits more than
  ``deadline_ms`` in queue: when the oldest request's deadline expires the
  batch is flushed as-is and padded up to the rung floor with copies of
  real queries (padding rows are sliced off before results are returned —
  prewarmed shapes make the padding compile-free, and per-query answers
  are independent of batch composition, so padding never changes them);
* **per-request tier selection** routes each request through the
  recommender's serving-tier node (``target_recall`` /
  ``latency_budget_ms`` per request): one formed batch fans out into
  per-(tier, n_blocks, k, window) sub-batches, all answered against ONE
  pinned epoch snapshot;
* **backpressure sheds to the approximate tier** — not into an unbounded
  queue: the admission queue is bounded (``max_queue``; ``submit``
  blocks), and when the measured rolling p99 drifts past ``slo_p99_ms``
  the gateway starts answering sheddable exact-tier requests on the
  approximate tier instead, with hysteresis (``shed_exit_frac``) so it
  recovers. Requests with ``target_recall >= 1.0`` are contractually
  exact and are never shed; a recommender ``conflict`` (the latency cap
  makes the recall target unreachable) is itself a shed signal.

Every response carries provenance: ``tier_served``, ``shed``,
``conflict``, ``queue_wait_ms``, the formed/padded batch shape, and the
epoch the answer was pinned to.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional

import numpy as np

from .recommender import Scenario, TierDecision, serving_tier
from .verify_engine import _CHUNK_M, _bucket_batch, get_engine


@dataclasses.dataclass(frozen=True)
class GatewayConfig:
    deadline_ms: float = 5.0  # max in-queue wait before a flush
    slo_p99_ms: float = 50.0  # rolling-p99 target that triggers shedding
    max_batch: int = 64  # largest formed batch (top ladder rung)
    k: int = 5  # default neighbors per query
    max_queue: int = 4096  # admission bound; submit() blocks beyond it
    lat_window: int = 256  # completions in the rolling percentile window
    min_shed_samples: int = 32  # completions before shedding may engage
    shed_exit_frac: float = 0.7  # recover when p99 < frac * slo (hysteresis)
    shed_n_blocks: int = 2  # approx recall knob for shed serves


@dataclasses.dataclass
class Response:
    """One client answer + its serving provenance."""
    vals: np.ndarray  # (k,) f64 squared distances, ascending
    ids: np.ndarray  # (k,) int64 global ids (-1 padded)
    tier_served: str  # "exact" | "approx"
    n_blocks: int  # approx tier recall knob used (0 for exact)
    shed: bool  # True when SLO pressure / a conflict downgraded the tier
    conflict: bool  # recommender: latency cap made recall unreachable
    queue_wait_ms: float  # admission -> batch dispatch
    latency_ms: float  # admission -> answer
    batch_size: int  # real queries in the formed batch
    padded_to: int  # ladder rung the sub-batch was padded to
    epoch: int  # pinned snapshot the whole formed batch answered against


@dataclasses.dataclass
class _Request:
    q: np.ndarray
    k: int
    window: Optional[tuple]
    target_recall: Optional[float]
    latency_budget_ms: Optional[float]
    t_arrive: float
    ticket: "Ticket"


class Ticket:
    """Handle returned by ``Gateway.submit``; ``result()`` blocks until the
    dispatcher resolves it."""

    __slots__ = ("_ev", "_resp", "_err")

    def __init__(self):
        self._ev = threading.Event()
        self._resp: Optional[Response] = None
        self._err: Optional[BaseException] = None

    def _resolve(self, resp: Optional[Response] = None,
                 err: Optional[BaseException] = None) -> None:
        self._resp, self._err = resp, err
        self._ev.set()

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None) -> Response:
        if not self._ev.wait(timeout):
            raise TimeoutError("gateway response pending")
        if self._err is not None:
            raise self._err
        return self._resp


def ladder(max_batch: int) -> tuple:
    """The gateway's batch rungs: the engine's query-batch buckets (pow2,
    min 8) up to ``max_batch`` — shared so prewarm covers exactly the
    shapes the dispatcher can form."""
    rungs, m = [], 8
    while m < max_batch:
        rungs.append(m)
        m *= 2
    rungs.append(max_batch)
    return tuple(rungs)


class Gateway:
    """Admission queue + dispatcher thread over a ``StreamingIndex``.

    Thread-shared state (queue, rolling latencies, shed flag, stats,
    tier-decision cache) is guarded by ``self._cond`` — palmlint's
    lock-discipline checker enforces it. Device work (the engine passes)
    runs OUTSIDE the lock so clients keep submitting while a batch
    serves."""

    def __init__(self, index, cfg: Optional[GatewayConfig] = None):
        self._idx = index
        self.cfg = cfg or GatewayConfig()
        if self.cfg.max_batch > _CHUNK_M:
            raise ValueError(
                f"max_batch {self.cfg.max_batch} exceeds the engine's query "
                f"chunk {_CHUNK_M}; larger formed batches would split into "
                "multiple passes and defeat the ladder accounting")
        self._cond = threading.Condition()
        self._queue: deque = deque()
        self._lat_ms: deque = deque(maxlen=self.cfg.lat_window)
        self._shedding = False
        self._closed = False
        self._tier_cache: dict = {}
        self.stats = {
            "submitted": 0,
            "served": 0,
            "shed_served": 0,  # answers downgraded to approx (or conflicted)
            "conflicts": 0,  # recommender recall/latency conflicts seen
            "batches": 0,  # formed batches dispatched
            "deadline_flushes": 0,  # batches flushed below the top rung
            "full_flushes": 0,  # batches formed at the top rung
            "batch_hist": {},  # formed (real) batch size -> count
            "shed_transitions": 0,  # enter/exit events of the shed state
        }
        self._thread = threading.Thread(target=self._dispatch_loop,
                                        name="gateway-dispatch", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------ client API
    def submit(self, q, *, k: Optional[int] = None,
               window: Optional[tuple] = None,
               target_recall: Optional[float] = None,
               latency_budget_ms: Optional[float] = None) -> Ticket:
        """Enqueue one query; returns immediately with a ``Ticket`` unless
        the bounded admission queue is full (then blocks — backpressure)."""
        q = np.asarray(q, np.float32).reshape(-1)
        req = _Request(q=q, k=int(k if k is not None else self.cfg.k),
                       window=None if window is None else
                       (int(window[0]), int(window[1])),
                       target_recall=target_recall,
                       latency_budget_ms=latency_budget_ms,
                       t_arrive=time.perf_counter(), ticket=Ticket())
        with self._cond:
            while len(self._queue) >= self.cfg.max_queue and not self._closed:
                self._cond.wait(0.01)
            if self._closed:
                raise RuntimeError("gateway is closed")
            self._queue.append(req)
            self.stats["submitted"] += 1
            self._cond.notify_all()
        return req.ticket

    def prewarm(self, caps, *, dtype: Optional[str] = None) -> int:
        """Compile every (batch rung x table bucket) verification shape the
        dispatcher can form, so steady-state serving runs with zero
        retraces. ``caps`` — table sizes the stream will reach (the engine
        dedupes them onto its capacity rungs)."""
        eng = get_engine()
        d = int(self._idx.cfg.summarization.series_len)
        n = 0
        for rung in ladder(self.cfg.max_batch):
            n += eng.prewarm(d, rung, self.cfg.k, list(caps), dtype=dtype)
        return n

    def snapshot_stats(self) -> dict:
        """Point-in-time copy of the gateway counters + rolling percentiles."""
        with self._cond:
            out = dict(self.stats)
            out["batch_hist"] = dict(self.stats["batch_hist"])
            lat = np.array(self._lat_ms, np.float64)
            out["queue_depth"] = len(self._queue)
            out["shedding"] = self._shedding
            out["p50_ms"] = float(np.percentile(lat, 50)) if lat.size else 0.0
            out["p99_ms"] = float(np.percentile(lat, 99)) if lat.size else 0.0
            return out

    def reset_slo_window(self) -> None:
        """Drop the rolling latency window and leave the shed state.

        Warm-up traffic pays one-time compiles whose multi-second
        latencies would otherwise sit in the p99 window (``lat_window``
        completions) and keep the shed gate engaged long into steady
        state — at low arrival rates the window can take the whole run to
        wash out. Harnesses that measure steady state (the serving
        benchmark, ``serve.py --gateway``) call this once after draining
        their warm-up requests."""
        with self._cond:
            self._lat_ms.clear()
            if self._shedding:
                self._shedding = False
                self.stats["shed_transitions"] += 1

    def close(self, timeout: float = 30.0) -> None:
        """Stop admitting, drain the queue, stop the dispatcher."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._thread.join(timeout)

    # ------------------------------------------------------------ dispatcher
    def _dispatch_loop(self) -> None:
        while True:
            formed = self._form_batch()
            if formed is None:
                return
            batch, shed_now = formed
            if not batch:
                continue
            try:
                self._serve_batch(batch, shed_now)
            except BaseException as e:  # resolve, or clients hang forever
                for req in batch:
                    req.ticket._resolve(err=e)

    def _form_batch(self):
        """Block until a batch is ready: either the top rung fills or the
        oldest request's deadline expires (then flush whatever is queued).
        Returns None when closed and drained."""
        cfg = self.cfg
        with self._cond:
            while not self._queue and not self._closed:
                self._cond.wait()
            if not self._queue:
                return None  # closed and drained
            deadline = self._queue[0].t_arrive + cfg.deadline_ms / 1e3
            while len(self._queue) < cfg.max_batch and not self._closed:
                rem = deadline - time.perf_counter()
                if rem <= 0:
                    break
                self._cond.wait(rem)
                if not self._queue:
                    return None if self._closed else ([], False)
            take = min(len(self._queue), cfg.max_batch)
            batch = [self._queue.popleft() for _ in range(take)]
            self.stats["batches"] += 1
            key = "full_flushes" if take >= cfg.max_batch else "deadline_flushes"
            self.stats[key] += 1
            hist = self.stats["batch_hist"]
            hist[take] = hist.get(take, 0) + 1
            shed_now = self._shedding
            self._cond.notify_all()  # free space for blocked submitters
        return batch, shed_now

    def _route(self, req: _Request, shed_now: bool):
        """(tier, n_blocks, shed, conflict) for one request. Strictly-exact
        requests (target_recall >= 1.0) are never shed; a recommender
        conflict marks the answer shed even when not under SLO pressure —
        the latency cap already cost the client its recall target."""
        tr, lb = req.target_recall, req.latency_budget_ms
        strict = tr is not None and tr >= 1.0
        if tr is None and lb is None:
            tier, nb, conflict = "exact", 0, False
        else:
            dec = self._tier_decision(tr, lb)
            tier, nb, conflict = dec.tier, dec.n_blocks, dec.conflict
        shed = conflict
        if shed_now and tier == "exact" and not strict:
            tier, nb, shed = "approx", self.cfg.shed_n_blocks, True
        return tier, nb, shed, conflict

    def _tier_decision(self, tr, lb) -> TierDecision:
        """Cached recommender serving-tier call. The live entry count is
        quantized to its power-of-two bucket so the cache stays small and
        decisions stay stable while ingest grows the store."""
        n_live = max(1024, int(self._idx.raw.n))
        n_q = 1 << (n_live - 1).bit_length()
        key = (tr, lb, n_q)
        with self._cond:
            dec = self._tier_cache.get(key)
        if dec is None:
            dec = serving_tier(Scenario(
                streaming=True, n_series=n_q,
                series_len=int(self._idx.cfg.summarization.series_len),
                uses_windows=True, target_recall=tr, latency_budget_ms=lb,
                query_batch=self.cfg.max_batch))
            with self._cond:
                self._tier_cache[key] = dec
        return dec

    def _serve_batch(self, batch, shed_now: bool) -> None:
        t_dispatch = time.perf_counter()
        groups: dict = {}
        routed = []
        for i, req in enumerate(batch):
            tier, nb, shed, conflict = self._route(req, shed_now)
            routed.append((tier, nb, shed, conflict))
            groups.setdefault((tier, nb, req.k, req.window), []).append(i)
        answers: dict = {}
        # ONE pinned epoch for the whole formed batch: every sub-batch
        # answers against the same immutable snapshot even while background
        # ingest publishes new epochs mid-serve
        with self._idx.pin() as snap:
            epoch = int(snap.epoch)
            # deterministic sub-batch order: mixed-tenant batches always
            # split and serve the same way for the same inputs
            for key in sorted(groups, key=lambda t: (t[0], t[1], t[2],
                                                     t[3] or (-1, -1))):
                tier, nb, kk, window = key
                idxs = groups[key]
                Qg = np.stack([batch[i].q for i in idxs])
                rung = _bucket_batch(len(idxs))
                if rung > len(idxs):
                    # pad to the rung floor with copies of a real query;
                    # prewarmed shapes make this compile-free and the rows
                    # are sliced off below — padding never leaks
                    Qg = np.concatenate(
                        [Qg, np.repeat(Qg[:1], rung - len(idxs), axis=0)])
                if tier == "approx":
                    if window is None:
                        vals, gids, _ = self._idx.knn_approx_batch(
                            Qg, k=kk, n_blocks=max(nb, 1), snapshot=snap)
                    else:
                        vals, gids, _ = self._idx.window_knn_approx_batch(
                            Qg, window[0], window[1], k=kk,
                            n_blocks=max(nb, 1), snapshot=snap)
                else:
                    if window is None:
                        vals, gids, _ = self._idx.knn_batch(Qg, k=kk,
                                                            snapshot=snap)
                    else:
                        vals, gids, _ = self._idx.window_knn_batch(
                            Qg, window[0], window[1], k=kk, snapshot=snap)
                for row_, i in enumerate(idxs):
                    answers[i] = (vals[row_], gids[row_], rung)
        t_done = time.perf_counter()
        n_shed = n_conflict = 0
        for i, req in enumerate(batch):
            tier, nb, shed, conflict = routed[i]
            vals, gids, rung = answers[i]
            n_shed += int(shed)
            n_conflict += int(conflict)
            req.ticket._resolve(Response(
                vals=vals, ids=gids, tier_served=tier, n_blocks=nb,
                shed=shed, conflict=conflict,
                queue_wait_ms=(t_dispatch - req.t_arrive) * 1e3,
                latency_ms=(t_done - req.t_arrive) * 1e3,
                batch_size=len(batch), padded_to=rung, epoch=epoch))
        with self._cond:
            self.stats["served"] += len(batch)
            self.stats["shed_served"] += n_shed
            self.stats["conflicts"] += n_conflict
            for req in batch:
                self._lat_ms.append((t_done - req.t_arrive) * 1e3)
            self._update_shed_locked()

    def _update_shed_locked(self) -> None:
        """Recompute the shed state from the rolling p99 (caller holds the
        lock). Hysteresis: enter above ``slo_p99_ms``, exit only below
        ``shed_exit_frac * slo_p99_ms`` so the state does not flap."""
        if len(self._lat_ms) < self.cfg.min_shed_samples:
            return
        p99 = float(np.percentile(np.array(self._lat_ms, np.float64), 99))
        if not self._shedding and p99 > self.cfg.slo_p99_ms:
            self._shedding = True
            self.stats["shed_transitions"] += 1
        elif self._shedding and p99 < self.cfg.shed_exit_frac * self.cfg.slo_p99_ms:
            self._shedding = False
            self.stats["shed_transitions"] += 1
