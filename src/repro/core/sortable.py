"""Sortable summarizations — the paper's core contribution.

Plain SAX words sort by segment 0 first, so sorting scatters series that are
similar overall but differ in their first segment. Interleaving the bits of
all segments MSB-first produces a z-order key: lexicographic order on the
interleaved key keeps series that are similar in *all* segments adjacent.

Keys are fixed-width bit strings of w*c bits packed big-endian into uint32
words (TPU-friendly: no 64-bit integer ops needed; multi-word keys sort
lexicographically with ``lax.sort(num_keys=n_words)`` or ``np.lexsort``).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from .summarization import SummarizationConfig


def _bit_positions(cfg: SummarizationConfig) -> np.ndarray:
    """Key bit index (0 = MSB of the key) for (bit b of symbol, segment s).

    Interleaved layout: key bit p = b * w + s, i.e. the MSBs of all segments
    come first (segment order), then the second bits, etc.
    """
    w, c = cfg.n_segments, cfg.card_bits
    b = np.arange(c)[:, None]  # bit index within symbol, 0 = MSB
    s = np.arange(w)[None, :]
    return (b * w + s).reshape(-1)  # (c*w,) in (b-major, s-minor) order


def interleave(sym, cfg: SummarizationConfig):
    """Bit-interleave SAX symbols into sortable keys.

    sym: (..., w) int32 symbols in [0, 2**c)
    returns: (..., n_words) uint32 key words, word 0 most significant,
             bit 31 of each word most significant. Unused low bits are 0.
    """
    xp = jnp if isinstance(sym, jnp.ndarray) else np
    w, c = cfg.n_segments, cfg.card_bits
    nw = cfg.key_words
    # bits of each symbol, MSB first: (..., c, w)
    shifts = xp.arange(c - 1, -1, -1, dtype=sym.dtype)
    bits = (sym[..., None, :] >> shifts[:, None]) & 1  # (..., c, w)
    flat = bits.reshape(sym.shape[:-1] + (c * w,))  # already p = b*w + s order
    # pad to nw*32 bits
    pad = nw * 32 - c * w
    if pad:
        zeros = xp.zeros(sym.shape[:-1] + (pad,), dtype=flat.dtype)
        flat = xp.concatenate([flat, zeros], axis=-1)
    words = flat.reshape(sym.shape[:-1] + (nw, 32))
    weights = (xp.uint32(1) << xp.arange(31, -1, -1, dtype=xp.uint32))
    return (words.astype(xp.uint32) * weights).sum(axis=-1).astype(xp.uint32)


def deinterleave(keys, cfg: SummarizationConfig):
    """Inverse of :func:`interleave`. keys: (..., n_words) uint32 -> (..., w) int32."""
    xp = jnp if isinstance(keys, jnp.ndarray) else np
    w, c = cfg.n_segments, cfg.card_bits
    nw = cfg.key_words
    shifts = xp.arange(31, -1, -1, dtype=xp.uint32)
    bits = (keys[..., :, None] >> shifts) & xp.uint32(1)  # (..., nw, 32)
    flat = bits.reshape(keys.shape[:-1] + (nw * 32,))[..., : c * w]
    bw = flat.reshape(keys.shape[:-1] + (c, w)).astype(xp.int32)
    weights = (1 << xp.arange(c - 1, -1, -1)).astype(xp.int32)
    return (bw * weights[:, None]).sum(axis=-2)


def pack_u64(keys: np.ndarray) -> np.ndarray:
    """Pack (N, n_words) uint32 key words into (N, ceil(n_words/2)) uint64
    columns (big-endian order preserved): lexicographic order is unchanged
    but host sorts compare half as many columns (~2x faster; §Perf)."""
    n, nw = keys.shape
    if nw % 2:
        keys = np.concatenate([keys, np.zeros((n, 1), np.uint32)], axis=1)
        nw += 1
    k64 = keys.astype(np.uint64)
    return (k64[:, 0::2] << np.uint64(32)) | k64[:, 1::2]


def lexsort_keys(keys: np.ndarray) -> np.ndarray:
    """Stable ascending argsort of multi-word keys via the packed-u64 path."""
    packed = pack_u64(keys)
    if packed.shape[1] == 1:
        return np.argsort(packed[:, 0], kind="stable")
    return np.lexsort(tuple(packed[:, i] for i in range(packed.shape[1] - 1, -1, -1)))


def sort_by_keys(keys: np.ndarray, *payloads: np.ndarray):
    """Stable sort rows of ``keys`` (N, n_words) lexicographically; returns
    (sorted_keys, sorted_payloads..., order). numpy path."""
    order = lexsort_keys(keys)
    return (keys[order],) + tuple(p[order] for p in payloads) + (order,)


def keys_less_equal(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Elementwise lexicographic a <= b for (..., n_words) uint32 keys."""
    nw = a.shape[-1]
    le = np.ones(np.broadcast_shapes(a.shape[:-1], b.shape[:-1]), dtype=bool)
    decided = np.zeros_like(le)
    for i in range(nw):
        lt = a[..., i] < b[..., i]
        gt = a[..., i] > b[..., i]
        le = np.where(~decided & lt, True, le)
        le = np.where(~decided & gt, False, le)
        decided |= lt | gt
    return le


def keys_less(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Elementwise lexicographic a < b for (..., n_words) unsigned keys."""
    return ~keys_less_equal(b, a)  # total order: a < b == not (b <= a)


def searchsorted_keys(sorted_keys: np.ndarray, query_key: np.ndarray) -> int:
    """Binary search for the insertion point of ``query_key`` (n_words,) in
    lexicographically sorted ``sorted_keys`` (N, n_words)."""
    lo, hi = 0, sorted_keys.shape[0]
    qt = tuple(int(x) for x in query_key)
    while lo < hi:
        mid = (lo + hi) // 2
        if tuple(int(x) for x in sorted_keys[mid]) < qt:
            lo = mid + 1
        else:
            hi = mid
    return lo


def searchsorted_keys_batch(
    sorted_keys: np.ndarray, query_keys: np.ndarray
) -> np.ndarray:
    """Vectorized :func:`searchsorted_keys` for a whole query batch.

    Left insertion points of ``query_keys`` (m, n_words) into the
    lexicographically sorted ``sorted_keys`` (N, n_words), returned as an
    (m,) int64 array. All m binary searches advance in lockstep as pure
    array ops: each probe is one fancy-indexed gather of the m midpoints
    plus one vectorized lexicographic compare (on u64-packed columns, so
    half the word comparisons), O(log N) probes total — the batched gate
    of the approximate serving tier."""
    sorted_keys = np.asarray(sorted_keys)
    query_keys = np.asarray(query_keys)
    n = int(sorted_keys.shape[0])
    m = int(query_keys.shape[0])
    lo = np.zeros(m, np.int64)
    if n == 0 or m == 0:
        return lo
    hi = np.full(m, n, np.int64)
    sk = pack_u64(sorted_keys)
    qk = pack_u64(query_keys)
    while True:
        active = lo < hi
        if not active.any():
            break
        mid = np.where(active, (lo + hi) >> 1, 0)  # finished lanes gather row 0
        less = keys_less(sk[mid], qk)  # sorted[mid] < query, elementwise
        lo = np.where(active & less, mid + 1, lo)
        hi = np.where(active & ~less, mid, hi)
    return lo
