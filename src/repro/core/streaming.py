"""Streaming window-query schemes: PP, TP, BTP (paper §3).

All three answer ``window_knn(q, t0, t1, k)`` — nearest neighbors among
series whose timestamp falls in [t0, t1] — over a continuously ingested
stream. They differ in how the temporal dimension is physically organized:

* **PP (Post-Processing)** — one aggressively-merged index; every entry's
  timestamp is examined during verification and out-of-window entries are
  discarded. No partition can be skipped by time.
* **TP (Temporal Partitioning)** — a new immutable partition per buffer
  flush, never merged. Window queries only touch partitions whose creation
  range intersects the window, but partition count grows without bound and
  small partitions prune poorly.
* **BTP (Bounded Temporal Partitioning)** — the paper's contribution,
  enabled by sortable summarizations: flushed partitions are sort-merged
  with similar-sized ones (LSM tiering), so newer data lives in small runs
  and older data in large contiguous runs. Small windows skip big runs (like
  TP); large windows benefit from the strong spatial pruning of big sorted
  runs (like PP); the number of partitions any query touches is bounded by
  growth_factor * log(N).

The scheme maps onto the query plan's ``time_skip`` flag (see
``repro.core.plan``): TP/BTP drop runs whose time range misses the window
at plan build; PP plans every run and filters entries — no run metadata is
ever mutated, so concurrent PP queries are side-effect-free (the old
save/restore t_min/t_max hack is gone).

Scalar ``window_knn`` is a batch-of-1 plan; concurrent traffic goes
through ``window_knn_batch`` / ``window_knn_approx_batch``, which answer a
whole (m, n) query batch with one shared verification pass per (run,
batch) and return ((m, k) distances, (m, k) ids, stats). Exact batches
accept ``shard="mesh"`` for device-mesh execution.

``ingest="async"`` moves the flush/external-sort/merge work onto a
background :class:`repro.core.ingest.IngestPipeline` worker: ``ingest``
returns as soon as the batch is registry-visible, queries keep serving
from the previous epoch snapshot while compactions publish new ones, and
answers stay snapshot-consistent (brute-force-equal over the pinned
epoch's entries). ``drain()`` waits the backlog out; ``ingest_lag()``
reports freshness (pending entries, mergeable runs, snapshot age).
"""
from __future__ import annotations

import dataclasses
import tempfile
import time
from typing import Optional

import numpy as np

from .clsm import CLSM, CLSMConfig
from .ctree import RawStore, state_to_list
from .summarization import SummarizationConfig


@dataclasses.dataclass
class StreamConfig:
    scheme: str = "BTP"  # PP | TP | BTP
    summarization: SummarizationConfig = dataclasses.field(default_factory=SummarizationConfig)
    buffer_entries: int = 4096
    growth_factor: int = 4
    block_size: int = 512
    materialized: bool = False
    ingest: str = "sync"  # sync (flush/merge inline) | async (worker)
    # async backpressure: block ingest() while this many entries are
    # unflushed (None = unbounded backlog, queries still never block).
    # Must be >= buffer_entries — below the flush threshold the worker
    # could never shrink the backlog (IngestPipeline validates this)
    max_lag_entries: Optional[int] = None
    # storage backend: "model" (DiskModel simulation, the default),
    # "file" (crash-consistent mmap runs + WAL —
    # :mod:`repro.core.storage`), or "auto" (resolve through the
    # REPRO_STORAGE env var, default model)
    storage: str = "auto"
    # file backend root; None -> a fresh temp directory per index
    storage_dir: Optional[str] = None
    # device-arena storage dtype for the screen tier, inherited by the
    # raw store and every flushed/merged run (f32|bf16|int8; None
    # resolves the engine default / REPRO_SCREEN_DTYPE)
    screen_dtype: Optional[str] = None


class StreamingIndex:
    """A streaming Coconut index with a pluggable temporal scheme."""

    def __init__(self, cfg: StreamConfig, raw: Optional[RawStore] = None):
        if cfg.scheme not in ("PP", "TP", "BTP"):
            raise ValueError(f"unknown scheme {cfg.scheme}")
        if cfg.ingest not in ("sync", "async"):
            raise ValueError(f"unknown ingest mode {cfg.ingest}")
        self.cfg = cfg
        from .storage.backend import resolve_backend  # storage pkg is optional-at-use

        self.storage = None
        if resolve_backend(cfg.storage) == "file" and raw is None:
            # an explicitly provided RawStore keeps its own backing; the
            # file backend only engages when it owns the raw rows too
            from .storage.backend import StorageEngine

            root = cfg.storage_dir or tempfile.mkdtemp(prefix="coconut-store-")
            self.storage = StorageEngine(root, cfg.summarization)
            raw = self.storage.raw
        self.raw = raw or RawStore(cfg.summarization.series_len,
                                   screen_dtype=cfg.screen_dtype)
        if cfg.screen_dtype is not None and self.raw.screen_dtype is None:
            # storage-backend-owned (or caller-supplied) stores inherit the
            # stream's dtype unless they already chose one
            self.raw.screen_dtype = cfg.screen_dtype
        lsm_cfg = CLSMConfig(
            summarization=cfg.summarization,
            buffer_entries=cfg.buffer_entries,
            # PP merges eagerly into one big structure (growth factor 2 keeps
            # run count minimal); TP never merges; BTP uses the tunable factor.
            growth_factor=2 if cfg.scheme == "PP" else cfg.growth_factor,
            block_size=cfg.block_size,
            materialized=cfg.materialized,
            merge=cfg.scheme != "TP",
            screen_dtype=cfg.screen_dtype,
        )
        self.lsm = CLSM(lsm_cfg, disk=self.raw.disk, storage=self.storage)
        if self.storage is not None:
            # load whatever a previous process made durable: the manifest's
            # runs plus the replayed WAL chunks, installed in one epoch bump
            levels, buffer = self.storage.recover()
            if levels or buffer:
                self.lsm.registry.restore(levels, buffer)
        # the PP/TP/BTP plan flag: PP never skips runs by time, it only
        # filters entries during verification
        self._window_skip = cfg.scheme in ("TP", "BTP")
        self.pipeline = None
        if cfg.ingest == "async":
            from .ingest import IngestPipeline  # lazy: sync path stays thread-free

            self.pipeline = IngestPipeline(
                self.lsm, max_lag_entries=cfg.max_lag_entries)

    @classmethod
    def recover(cls, cfg: StreamConfig, storage_dir: str) -> "StreamingIndex":
        """Reopen a file-backed index from its storage directory: the
        durable runs and WAL entries come back queryable, ids keep
        ascending from the durable extent, and ingest may continue."""
        cfg = dataclasses.replace(cfg, storage="file", storage_dir=storage_dir)
        return cls(cfg)

    # ---------------------------------------------------------------- ingest
    def ingest(self, series: np.ndarray, ts: np.ndarray) -> np.ndarray:
        """Append a stream batch; returns assigned ids.

        Sync mode flushes/merges inline; async mode returns once the batch
        is registry-visible and leaves compaction to the pipeline worker —
        concurrent queries keep answering from their pinned snapshots."""
        ids = self.raw.append(series)
        if self.pipeline is not None:
            self.pipeline.insert(series, ids, ts)
        else:
            self.lsm.insert(series, ids, ts)
        return ids

    def drain(self, *, flush_buffer: bool = False,
              timeout: Optional[float] = None) -> bool:
        """Wait out the async ingest backlog (no-op in sync mode)."""
        if self.pipeline is None:
            if flush_buffer:
                self.lsm.flush_all()
            return True
        return self.pipeline.drain(flush_buffer=flush_buffer, timeout=timeout)

    def close(self) -> None:
        """Stop the async ingest worker (no-op in sync mode)."""
        if self.pipeline is not None:
            self.pipeline.close()

    def ingest_lag(self) -> dict:
        """Freshness of the queryable state vs the ingested stream:
        ``lag_entries`` (ingested but not yet in a published run),
        ``runs_pending_merge`` (published runs a level already has enough
        of to merge), ``epoch`` and ``snapshot_age_s`` (time since the
        last publish)."""
        reg = self.lsm.registry
        snap = reg.current()
        gf = self.lsm.cfg.growth_factor
        mergeable = 0
        if self.lsm.cfg.merge:
            mergeable = sum((len(runs) // gf) * gf
                            for _, runs in snap.levels if len(runs) >= gf)
        return {
            "epoch": snap.epoch,
            "lag_entries": snap.buffer_n + snap.flushing_n,
            "runs_pending_merge": mergeable,
            "retired_pending": reg.retired_pending,
            "snapshot_age_s": max(0.0, time.time() - reg.publish_time),
        }

    # ---------------------------------------------------------------- query
    def window_knn(self, q, t0: int, t1: int, k: int = 1, exact: bool = True,
                   n_blocks: int = 1):
        """Scalar window query — a batch-of-1 plan with the scheme's
        ``time_skip`` flag (side-effect-free under every scheme).
        Returns ([(d2, id)] ascending, stats)."""
        Q = np.asarray(q, np.float32).reshape(1, -1)
        if exact:
            vals, gids, stats = self.window_knn_batch(Q, t0, t1, k=k)
        else:
            vals, gids, stats = self.window_knn_approx_batch(
                Q, t0, t1, k=k, n_blocks=n_blocks)
        return state_to_list(vals[0], gids[0]), stats

    def window_knn_batch(self, Q, t0: int, t1: int, k: int = 1, *,
                         backend: str = "device", shard=None, mesh=None,
                         snapshot=None):
        """Batched exact window query: ((m, k) d2, (m, k) ids, stats).

        One batched pass per live run (see ``CLSM.knn_batch``); under PP
        run-level temporal skipping is disabled (``time_skip=False``) while
        per-entry timestamp filtering stays on. ``snapshot`` pins the query
        to a caller-held epoch (see ``pin``)."""
        window = (int(t0), int(t1))
        return self.lsm.knn_batch(Q, k, raw=self.raw, window=window,
                                  backend=backend,
                                  time_skip=self._window_skip,
                                  shard=shard, mesh=mesh, snapshot=snapshot)

    def knn_batch(self, Q, k: int = 1, *, backend: str = "device", shard=None,
                  mesh=None, snapshot=None):
        """Batched whole-history exact query: ((m, k) d2, (m, k) ids, stats)."""
        return self.lsm.knn_batch(Q, k, raw=self.raw, backend=backend,
                                  shard=shard, mesh=mesh, snapshot=snapshot)

    def window_knn_approx_batch(self, Q, t0: int, t1: int, k: int = 1, *,
                                n_blocks: int = 1, backend: str = "device",
                                snapshot=None):
        """Batched approximate window query — the approximate serving tier.

        Every run the window admits contributes one vectorized key seek and
        one coalesced sequential block read for the whole batch (see
        ``CLSM.knn_approx_batch``). Results are a subset of the exact
        ``window_knn_batch`` answer; ``n_blocks`` trades sequential bytes
        per (query, run) for recall@k. Under PP, run-level temporal
        skipping is disabled while per-entry filtering stays on. Returns
        ((m, k) d2, (m, k) ids, stats)."""
        window = (int(t0), int(t1))
        return self.lsm.knn_approx_batch(Q, k, n_blocks=n_blocks, raw=self.raw,
                                         window=window, backend=backend,
                                         time_skip=self._window_skip,
                                         snapshot=snapshot)

    def knn_approx_batch(self, Q, k: int = 1, *, n_blocks: int = 1,
                         backend: str = "device", snapshot=None):
        """Batched whole-history approximate query: ((m, k) d2, ids, stats)."""
        return self.lsm.knn_approx_batch(Q, k, n_blocks=n_blocks, raw=self.raw,
                                         backend=backend, snapshot=snapshot)

    def pin(self):
        """Context manager pinning the current epoch: yields an immutable
        RunSet snapshot that every ``snapshot=``-taking query method accepts,
        so a multi-query exchange (e.g. one gateway-formed batch fanned into
        per-tier sub-batches) answers against ONE epoch while ingest keeps
        publishing new ones."""
        return self.lsm.registry.pin()

    def knn(self, q, k: int = 1, exact: bool = True, n_blocks: int = 1):
        """Whole-history query (no window)."""
        if exact:
            return self.lsm.knn_exact(q, k, raw=self.raw)
        return self.lsm.knn_approx(q, k, n_blocks=n_blocks, raw=self.raw)

    # ---------------------------------------------------------------- stats
    @property
    def n_partitions(self) -> int:
        return self.lsm.n_runs

    def io_stats(self):
        return self.raw.disk.stats

    def measured_io(self) -> dict:
        """Measured byte counters of the file backend (empty dict under the
        modeled backend — there is nothing real to measure)."""
        if self.storage is None:
            return {}
        return self.storage.measured()

    def index_bytes(self) -> int:
        return self.lsm.index_bytes()
