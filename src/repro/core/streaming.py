"""Streaming window-query schemes: PP, TP, BTP (paper §3).

All three answer ``window_knn(q, t0, t1, k)`` — nearest neighbors among
series whose timestamp falls in [t0, t1] — over a continuously ingested
stream. They differ in how the temporal dimension is physically organized:

* **PP (Post-Processing)** — one aggressively-merged index; every entry's
  timestamp is examined during verification and out-of-window entries are
  discarded. No partition can be skipped by time.
* **TP (Temporal Partitioning)** — a new immutable partition per buffer
  flush, never merged. Window queries only touch partitions whose creation
  range intersects the window, but partition count grows without bound and
  small partitions prune poorly.
* **BTP (Bounded Temporal Partitioning)** — the paper's contribution,
  enabled by sortable summarizations: flushed partitions are sort-merged
  with similar-sized ones (LSM tiering), so newer data lives in small runs
  and older data in large contiguous runs. Small windows skip big runs (like
  TP); large windows benefit from the strong spatial pruning of big sorted
  runs (like PP); the number of partitions any query touches is bounded by
  growth_factor * log(N).

The scheme maps onto the query plan's ``time_skip`` flag (see
``repro.core.plan``): TP/BTP drop runs whose time range misses the window
at plan build; PP plans every run and filters entries — no run metadata is
ever mutated, so concurrent PP queries are side-effect-free (the old
save/restore t_min/t_max hack is gone).

Scalar ``window_knn`` is a batch-of-1 plan; concurrent traffic goes
through ``window_knn_batch`` / ``window_knn_approx_batch``, which answer a
whole (m, n) query batch with one shared verification pass per (run,
batch) and return ((m, k) distances, (m, k) ids, stats). Exact batches
accept ``shard="mesh"`` for device-mesh execution.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .clsm import CLSM, CLSMConfig
from .ctree import QueryStats, RawStore, state_to_list
from .summarization import SummarizationConfig


@dataclasses.dataclass
class StreamConfig:
    scheme: str = "BTP"  # PP | TP | BTP
    summarization: SummarizationConfig = dataclasses.field(default_factory=SummarizationConfig)
    buffer_entries: int = 4096
    growth_factor: int = 4
    block_size: int = 512
    materialized: bool = False


class StreamingIndex:
    """A streaming Coconut index with a pluggable temporal scheme."""

    def __init__(self, cfg: StreamConfig, raw: Optional[RawStore] = None):
        if cfg.scheme not in ("PP", "TP", "BTP"):
            raise ValueError(f"unknown scheme {cfg.scheme}")
        self.cfg = cfg
        self.raw = raw or RawStore(cfg.summarization.series_len)
        lsm_cfg = CLSMConfig(
            summarization=cfg.summarization,
            buffer_entries=cfg.buffer_entries,
            # PP merges eagerly into one big structure (growth factor 2 keeps
            # run count minimal); TP never merges; BTP uses the tunable factor.
            growth_factor=2 if cfg.scheme == "PP" else cfg.growth_factor,
            block_size=cfg.block_size,
            materialized=cfg.materialized,
            merge=cfg.scheme != "TP",
        )
        self.lsm = CLSM(lsm_cfg, disk=self.raw.disk)
        # the PP/TP/BTP plan flag: PP never skips runs by time, it only
        # filters entries during verification
        self._window_skip = cfg.scheme in ("TP", "BTP")

    # ---------------------------------------------------------------- ingest
    def ingest(self, series: np.ndarray, ts: np.ndarray) -> np.ndarray:
        """Append a stream batch; returns assigned ids."""
        ids = self.raw.append(series)
        self.lsm.insert(series, ids, ts)
        return ids

    # ---------------------------------------------------------------- query
    def window_knn(self, q, t0: int, t1: int, k: int = 1, exact: bool = True,
                   n_blocks: int = 1):
        """Scalar window query — a batch-of-1 plan with the scheme's
        ``time_skip`` flag (side-effect-free under every scheme).
        Returns ([(d2, id)] ascending, stats)."""
        Q = np.asarray(q, np.float32).reshape(1, -1)
        if exact:
            vals, gids, stats = self.window_knn_batch(Q, t0, t1, k=k)
        else:
            vals, gids, stats = self.window_knn_approx_batch(
                Q, t0, t1, k=k, n_blocks=n_blocks)
        return state_to_list(vals[0], gids[0]), stats

    def window_knn_batch(self, Q, t0: int, t1: int, k: int = 1, *,
                         backend: str = "device", shard=None, mesh=None):
        """Batched exact window query: ((m, k) d2, (m, k) ids, stats).

        One batched pass per live run (see ``CLSM.knn_batch``); under PP
        run-level temporal skipping is disabled (``time_skip=False``) while
        per-entry timestamp filtering stays on."""
        window = (int(t0), int(t1))
        return self.lsm.knn_batch(Q, k, raw=self.raw, window=window,
                                  backend=backend,
                                  time_skip=self._window_skip,
                                  shard=shard, mesh=mesh)

    def knn_batch(self, Q, k: int = 1, *, backend: str = "device", shard=None,
                  mesh=None):
        """Batched whole-history exact query: ((m, k) d2, (m, k) ids, stats)."""
        return self.lsm.knn_batch(Q, k, raw=self.raw, backend=backend,
                                  shard=shard, mesh=mesh)

    def window_knn_approx_batch(self, Q, t0: int, t1: int, k: int = 1, *,
                                n_blocks: int = 1, backend: str = "device"):
        """Batched approximate window query — the approximate serving tier.

        Every run the window admits contributes one vectorized key seek and
        one coalesced sequential block read for the whole batch (see
        ``CLSM.knn_approx_batch``). Results are a subset of the exact
        ``window_knn_batch`` answer; ``n_blocks`` trades sequential bytes
        per (query, run) for recall@k. Under PP, run-level temporal
        skipping is disabled while per-entry filtering stays on. Returns
        ((m, k) d2, (m, k) ids, stats)."""
        window = (int(t0), int(t1))
        return self.lsm.knn_approx_batch(Q, k, n_blocks=n_blocks, raw=self.raw,
                                         window=window, backend=backend,
                                         time_skip=self._window_skip)

    def knn_approx_batch(self, Q, k: int = 1, *, n_blocks: int = 1,
                         backend: str = "device"):
        """Batched whole-history approximate query: ((m, k) d2, ids, stats)."""
        return self.lsm.knn_approx_batch(Q, k, n_blocks=n_blocks, raw=self.raw,
                                         backend=backend)

    def knn(self, q, k: int = 1, exact: bool = True, n_blocks: int = 1):
        """Whole-history query (no window)."""
        if exact:
            return self.lsm.knn_exact(q, k, raw=self.raw)
        return self.lsm.knn_approx(q, k, n_blocks=n_blocks, raw=self.raw)

    # ---------------------------------------------------------------- stats
    @property
    def n_partitions(self) -> int:
        return self.lsm.n_runs

    def io_stats(self):
        return self.raw.disk.stats

    def index_bytes(self) -> int:
        return self.lsm.index_bytes()
