# Coconut — sortable data-series summarizations + compact/contiguous indexes.
# The paper's primary contribution lives here; `distributed` maps it onto a
# TPU pod mesh (sample-sort build, broadcast-prune-reduce queries).
from .summarization import SummarizationConfig, breakpoints, paa, sax, sax_from_paa
from .sortable import (
    interleave, deinterleave, sort_by_keys, searchsorted_keys,
    searchsorted_keys_batch,
)
from .lower_bounds import ed2, mindist_paa_sax2, mindist_region2, topk_ed2
from .io_model import DiskModel, IOStats, coalesce_ranges, render_heatmap
from .external_sort import external_sort_order
from .plan import (
    BlockSource, DenseSource, GroupSource, QueryPlan, QueryStats, RangeSource,
    SourceOps,
)
from .execute import (
    execute, empty_topk_state, heap_to_sorted, merge_topk_state, recall_at_k,
    state_to_list,
)
from .ctree import CTree, CTreeConfig, RawStore, SortedRun
from .run_registry import BufferChunk, RunRegistry, RunSet
from .clsm import CLSM, CLSMConfig
from .ingest import IngestPipeline
from .storage import (
    FileStore, SimulatedCrash, StorageEngine, WriteAheadLog, resolve_backend,
)
from .streaming import StreamConfig, StreamingIndex
from .adsplus import ADSConfig, ADSIndex
from .recommender import (
    RationaleEntry, Scenario, Recommendation, TierDecision, recommend,
    serving_tier,
)
from .autotune import (
    AutoTuner, AutoTunerConfig, DecisionRecord, Knobs, WorkloadKey,
    knob_grid, workload_key,
)
from .gateway import Gateway, GatewayConfig, GatewayStats, Response, Ticket

__all__ = [
    "SummarizationConfig", "breakpoints", "paa", "sax", "sax_from_paa",
    "interleave", "deinterleave", "sort_by_keys", "searchsorted_keys",
    "searchsorted_keys_batch",
    "ed2", "mindist_paa_sax2", "mindist_region2", "topk_ed2",
    "DiskModel", "IOStats", "coalesce_ranges", "render_heatmap",
    "external_sort_order",
    "BlockSource", "DenseSource", "GroupSource", "QueryPlan", "QueryStats",
    "RangeSource", "SourceOps", "execute", "state_to_list",
    "CTree", "CTreeConfig", "RawStore", "SortedRun", "heap_to_sorted",
    "empty_topk_state", "merge_topk_state", "recall_at_k",
    "CLSM", "CLSMConfig", "StreamConfig", "StreamingIndex",
    "BufferChunk", "RunRegistry", "RunSet", "IngestPipeline",
    "FileStore", "SimulatedCrash", "StorageEngine", "WriteAheadLog",
    "resolve_backend",
    "ADSConfig", "ADSIndex", "Scenario", "Recommendation", "TierDecision",
    "RationaleEntry", "recommend", "serving_tier",
    "AutoTuner", "AutoTunerConfig", "DecisionRecord", "Knobs",
    "WorkloadKey", "knob_grid", "workload_key",
    "Gateway", "GatewayConfig", "GatewayStats", "Response", "Ticket",
]

# Runtime sanitizer (lock-order assertions + snapshot seals): opt-in via
# env var so the slow-tier stress tests can run with invariants armed
# while production imports stay untouched. See repro.analysis.sanitize.
import os as _os

if _os.environ.get("REPRO_SANITIZE") == "1":
    from ..analysis.sanitize import install as _sanitize_install

    _sanitize_install()
