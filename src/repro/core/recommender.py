"""The Coconut Palm recommender — a decision tree over application scenarios.

Mirrors the demo's tool: given a scenario description (static vs streaming,
data volume, expected query count, memory budget, window sizes) it picks an
index structure + materialization + temporal scheme and, because it is a
decision tree, returns the *rationale chain* of every decision it took
(paper §4: "designed as a decision tree to be able to provide users with the
rationale for its advice").

The thresholds encode the paper's demo narratives:
  * Scenario 1 (static, few queries)  -> non-materialized CTree + PP
  * Scenario 1 (static, many queries) -> materialized CTree
  * Scenario 2 (streaming)            -> non-materialized CLSM + BTP

Decision surface (one frozen record family — the autotuner's feedback loop
consumes these, so they are structured and immutable, not free-form):

* :class:`RationaleEntry` — one ``(node_id, text)`` step of the decision
  tree. ``node_id`` is the stable machine key ("serve/latency-cap"); the
  text is the human narrative. ``in`` / ``str()`` keep the old bare-string
  reading working for one release.
* :class:`TierDecision` — the serving-tier verdict (tier, n_blocks,
  conflict) with its rationale chain.
* :class:`Recommendation` — the full-tree verdict; it *embeds* its
  ``TierDecision`` (``rec.decision``) and keeps ``tier`` / ``n_blocks`` /
  ``conflict`` as thin back-compat read-only properties for one release.

The cost-model constants below are the *priors* of the online autotuner
(``core.autotune``): a live serving stack re-fits the latency and recall
models from measured batches and only falls back to these numbers before
any observations exist.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Scenario:
    streaming: bool
    n_series: int
    series_len: int = 256
    expected_queries: int = 100
    memory_budget_bytes: int = 1 << 30
    uses_windows: bool = False
    ingest_rate: float = 0.0  # series/sec arriving (streaming)
    read_heavy: Optional[bool] = None  # override read/write balance
    # serving-tier inputs (None = exact answers required)
    target_recall: Optional[float] = None  # acceptable recall@k vs exact
    latency_budget_ms: Optional[float] = None  # per-query modeled I/O budget
    query_batch: int = 1  # concurrent queries per serving batch


@dataclasses.dataclass(frozen=True)
class RationaleEntry:
    """One decision-tree step: a stable node id + the human narrative.

    Back-compat (one release): the old surface was a bare string, so
    ``"WARNING" in entry`` and ``str(entry)`` keep reading the text."""
    node_id: str
    text: str

    def __contains__(self, item: str) -> bool:
        return item in self.text

    def __str__(self) -> str:
        return self.text


@dataclasses.dataclass(frozen=True)
class TierDecision:
    """Structured serving-tier verdict for one request profile.

    ``conflict`` is the machine-readable form of the "latency cap makes the
    recall target unreachable" warning: admission layers (the serving
    gateway) must treat it as a shed signal instead of relying on a string
    buried in the rationale chain."""
    tier: str  # "exact" | "approx"
    n_blocks: int  # approx tier: adjacent blocks per (query, run)
    conflict: bool
    rationale: Tuple[RationaleEntry, ...]


@dataclasses.dataclass(frozen=True)
class Recommendation:
    """Full decision-tree verdict: index layout + the embedded serving-tier
    decision. Frozen — downstream layers (gateway routing, the autotuner's
    decision records, BENCH emitters) hold references to these; a published
    recommendation must never mutate under them."""
    index: str  # "ctree" | "clsm"
    materialized: bool
    scheme: str  # "PP" | "TP" | "BTP" | "-"
    growth_factor: int
    fill_factor: float
    mem_budget_entries: int
    decision: TierDecision
    rationale: Tuple[RationaleEntry, ...] = ()

    # -- thin back-compat properties (one release): old callers read the
    # serving-tier fields directly off the recommendation
    @property
    def tier(self) -> str:
        return self.decision.tier

    @property
    def n_blocks(self) -> int:
        return self.decision.n_blocks

    @property
    def conflict(self) -> bool:
        return self.decision.conflict

    def describe(self) -> str:
        mat = "materialized" if self.materialized else "non-materialized"
        head = f"{mat} {self.index.upper()}" + (f" with {self.scheme}" if self.scheme != "-" else "")
        if self.tier == "approx":
            head += f", approx tier (n_blocks={self.n_blocks})"
        return head + "\n  because:\n" + "\n".join(
            f"  - [{e.node_id}] {e.text}" for e in self.rationale)


# cost-model constants used by the break-even analysis (bytes). These are
# the PRIORS of core.autotune's online models — live serving re-fits them.
_RAW_BYTES = 4
_BLOCK_ENTRIES = 1024  # nominal entries per sequential block read
_SEQ_MBPS = 500.0  # modeled disk (io_model.DiskModel defaults)
_RAND_IOPS = 10_000.0
_EXACT_VERIFIED_FRAC = 0.002  # fraction of N verified per exact query


def _approx_recall_model(n_blocks: int) -> float:
    """Modeled recall@k of the approximate tier at ``n_blocks`` adjacent
    blocks per (query, run). Sortable keys keep a query's true neighbors
    clustered around its seek position, with coverage saturating roughly
    geometrically as the window widens — calibrated against the repo's
    recall-validation harness on the random-walk datasets (n_blocks=1 ~0.5,
    2 ~0.7, 8 ~0.95)."""
    return 1.0 - 0.55 * (0.72 ** (n_blocks - 1))


def _exact_cost_ms(n_series: int, query_batch: int) -> float:
    """Modeled per-query exact cost: LB-surviving random fetches (amortized
    ~linearly by batching, which shares verification passes)."""
    batch_amort = max(1.0, min(float(query_batch), 8.0))
    return n_series * _EXACT_VERIFIED_FRAC / batch_amort / _RAND_IOPS * 1e3


def _approx_cost_ms(n_blocks: int, series_len: int) -> float:
    """Modeled per-query approximate cost: ``n_blocks`` sequential block
    reads per (query, run)."""
    entry_bytes = series_len * _RAW_BYTES
    return n_blocks * _BLOCK_ENTRIES * entry_bytes / (_SEQ_MBPS * 1e6) * 1e3


def serving_tier(s: Scenario) -> TierDecision:
    """Per-request tier selection: the serving-tier node of the decision
    tree, standalone, with the recall/latency conflict surfaced as a flag.
    Deterministic in ``s`` (``Scenario`` is frozen), so callers may cache
    decisions per request profile."""
    r: List[RationaleEntry] = []
    tier, n_blocks, conflict = _serving_tier(s, r)
    return TierDecision(tier, n_blocks, conflict, tuple(r))


def _say(r: List[RationaleEntry], node_id: str, text: str) -> None:
    r.append(RationaleEntry(node_id, text))


def _serving_tier(s: Scenario, r: List[RationaleEntry]) -> tuple:
    """Decision-tree node: pick the serving tier + its recall knob from the
    target recall and per-query latency budget. Returns (tier, n_blocks,
    conflict) where ``conflict`` is True when the latency cap forced
    n_blocks below what the recall target needs."""
    exact_ms = _exact_cost_ms(s.n_series, s.query_batch)
    if s.target_recall is None and s.latency_budget_ms is None:
        return "exact", 0, False
    if s.target_recall is not None and s.target_recall >= 1.0:
        _say(r, "serve/strict-recall",
             "target recall 1.0 -> only the exact tier guarantees it; "
             "the approximate tier is a strict subset of the exact answer")
        return "exact", 0, False
    if s.latency_budget_ms is not None and exact_ms <= s.latency_budget_ms:
        # exact satisfies BOTH constraints: recall 1.0 clears any target and
        # the modeled cost fits the budget — a relaxed recall target is a
        # floor, not a request for weaker answers
        _say(r, "serve/exact-fits-budget",
             f"modeled exact query I/O ~{exact_ms:.2f} ms fits the "
             f"{s.latency_budget_ms:.2f} ms budget at batch {s.query_batch}"
             + (" and recall 1.0 clears the "
                f"{s.target_recall:.2f} target" if s.target_recall is not None
                else "") + " -> keep exact answers")
        return "exact", 0, False
    # approximate tier: choose the smallest n_blocks whose modeled recall
    # clears the target and whose sequential bytes fit the budget
    target = s.target_recall if s.target_recall is not None else 0.9
    nb = 1
    while nb < 64 and _approx_recall_model(nb) < target:
        nb *= 2
    seq_ms = _approx_cost_ms(nb, s.series_len)
    _say(r, "serve/approx-depth",
         f"target recall@k {target:.2f} < 1 -> approximate tier: one key "
         f"seek + {nb} adjacent block(s) read sequentially per (query, run) "
         f"(modeled recall ~{_approx_recall_model(nb):.2f})")
    conflict = False
    if s.latency_budget_ms is not None:
        uncapped = nb
        while nb > 1 and seq_ms > s.latency_budget_ms:
            nb //= 2
            seq_ms = _approx_cost_ms(nb, s.series_len)
        _say(r, "serve/latency-cap",
             f"latency budget {s.latency_budget_ms:.2f} ms/query caps the "
             f"sequential read at n_blocks={nb} (~{seq_ms:.2f} ms modeled); "
             f"exact would cost ~{exact_ms:.2f} ms")
        if nb < uncapped and _approx_recall_model(nb) < target:
            conflict = True
            _say(r, "serve/conflict",
                 f"WARNING: at the capped n_blocks={nb} the modeled recall "
                 f"drops to ~{_approx_recall_model(nb):.2f}, below the "
                 f"{target:.2f} target — the recall and latency goals "
                 "conflict; relax one of them")
    if s.query_batch > 1:
        _say(r, "serve/batch-amortization",
             f"batch of {s.query_batch} concurrent queries shares one "
             "vectorized key seek and coalesced sequential reads per run, so "
             "the per-query seek cost amortizes toward zero")
    return "approx", nb, conflict


def recommend(s: Scenario) -> Recommendation:
    r: List[RationaleEntry] = []
    entry_bytes = s.series_len * _RAW_BYTES
    data_bytes = s.n_series * entry_bytes
    mem_entries = max(1024, s.memory_budget_bytes // max(1, entry_bytes))

    # --- node 1: ingestion pattern ------------------------------------------
    if s.streaming:
        index = "clsm"
        _say(r, "ingest/streaming",
             "data arrives continuously -> log-structured merges ingest with "
             "sequential writes only (CLSM); a CTree would need top-down "
             "updates or full rebuilds")
        # node 1a: temporal scheme
        if s.uses_windows:
            scheme = "BTP"
            _say(r, "temporal/btp",
                 "window queries benefit from temporal partitions; bounded "
                 "merging (BTP) keeps recent data in small skippable runs while "
                 "large merged runs keep strong spatial pruning for wide windows")
        else:
            scheme = "PP"
            _say(r, "temporal/pp",
                 "no window constraints -> pure post-filtering (PP) on the "
                 "fully merged structure; temporal partitions would add probes "
                 "without enabling skips")
        # node 1b: read/write balance -> growth factor
        qps = s.expected_queries
        write_heavy = s.read_heavy is False or (
            s.read_heavy is None and s.ingest_rate > max(1.0, qps)
        )
        growth = 8 if write_heavy else 3
        _say(r, "merge/growth-factor",
             ("ingest rate dominates queries -> large growth factor (%d) defers merge work"
              if write_heavy
              else "queries dominate ingest -> small growth factor (%d) keeps few runs per probe")
             % growth)
        # node 1c: materialization under ingest pressure
        materialized = False
        _say(r, "materialize/streaming",
             "streaming ingest + merges rewrite data repeatedly -> keep runs "
             "non-materialized; verification reads fetch from the raw log")
        # node 1d: serving tier from the recall/latency targets
        n0 = len(r)
        tier, n_blocks, conflict = _serving_tier(s, r)
        decision = TierDecision(tier, n_blocks, conflict, tuple(r[n0:]))
        return Recommendation(index, materialized, scheme, growth, 1.0,
                              mem_entries, decision, tuple(r))

    # --- static data ----------------------------------------------------------
    index = "ctree"
    _say(r, "ingest/static",
         "static collection -> bulk-build once with a two-pass external sort; "
         "the read-optimized contiguous CTree gives the fastest scans")
    scheme = "PP" if s.uses_windows else "-"
    if s.uses_windows:
        _say(r, "temporal/static-pp",
             "static data has no flush-time partitions; window constraints are "
             "post-filtered on timestamps (PP)")

    # node 2: materialization break-even.
    # Non-materialized build writes only summaries (~w+key bytes/entry);
    # materialized also rewrites the raw data (entry_bytes). Each exact query
    # on a non-materialized index pays ~verified_frac random fetches.
    verified_frac = 0.002  # fraction of N fetched per exact query (post-LB)
    extra_build = s.n_series * entry_bytes  # extra sequential bytes if materialized
    per_query_penalty = s.n_series * verified_frac * entry_bytes  # random bytes
    # random I/O ~20x more expensive per byte than sequential on the modeled disk
    break_even_queries = max(1, int(extra_build / (20.0 * max(per_query_penalty, 1))))
    if s.expected_queries > break_even_queries:
        materialized = True
        _say(r, "materialize/break-even",
             f"expected {s.expected_queries} queries > break-even {break_even_queries}: "
             "the one-off cost of materializing raw series in sorted order is "
             "amortized by removing random fetches from every query")
    else:
        materialized = False
        _say(r, "materialize/break-even",
             f"expected {s.expected_queries} queries <= break-even {break_even_queries}: "
             "build the skeletal (summaries-only) index — faster to build, "
             "smaller on storage; queries fetch raw series on demand")

    # node 3: memory budget -> external-sort passes
    if s.memory_budget_bytes < data_bytes:
        _say(r, "build/external-sort",
             f"memory budget {s.memory_budget_bytes >> 20} MiB < data "
             f"{data_bytes >> 20} MiB -> two-pass external sort with "
             f"{mem_entries} entry chunks (still sequential I/O only)")
    else:
        _say(r, "build/in-memory",
             "data fits in memory -> single in-memory sort pass")

    # node 4: update tolerance -> fill factor
    fill = 1.0 if s.ingest_rate == 0 else 0.8
    if fill < 1.0:
        _say(r, "build/fill-factor",
             "occasional updates expected -> leaf fill factor 0.8 leaves gaps")

    # node 5: serving tier from the recall/latency targets
    n0 = len(r)
    tier, n_blocks, conflict = _serving_tier(s, r)
    decision = TierDecision(tier, n_blocks, conflict, tuple(r[n0:]))
    return Recommendation(index, materialized, scheme, 3, fill, mem_entries,
                          decision, tuple(r))
