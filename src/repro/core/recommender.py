"""The Coconut Palm recommender — a decision tree over application scenarios.

Mirrors the demo's tool: given a scenario description (static vs streaming,
data volume, expected query count, memory budget, window sizes) it picks an
index structure + materialization + temporal scheme and, because it is a
decision tree, returns the *rationale chain* of every decision it took
(paper §4: "designed as a decision tree to be able to provide users with the
rationale for its advice").

The thresholds encode the paper's demo narratives:
  * Scenario 1 (static, few queries)  -> non-materialized CTree + PP
  * Scenario 1 (static, many queries) -> materialized CTree
  * Scenario 2 (streaming)            -> non-materialized CLSM + BTP
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Scenario:
    streaming: bool
    n_series: int
    series_len: int = 256
    expected_queries: int = 100
    memory_budget_bytes: int = 1 << 30
    uses_windows: bool = False
    ingest_rate: float = 0.0  # series/sec arriving (streaming)
    read_heavy: Optional[bool] = None  # override read/write balance


@dataclasses.dataclass
class Recommendation:
    index: str  # "ctree" | "clsm"
    materialized: bool
    scheme: str  # "PP" | "TP" | "BTP" | "-"
    growth_factor: int
    fill_factor: float
    mem_budget_entries: int
    rationale: list[str] = dataclasses.field(default_factory=list)

    def describe(self) -> str:
        mat = "materialized" if self.materialized else "non-materialized"
        head = f"{mat} {self.index.upper()}" + (f" with {self.scheme}" if self.scheme != "-" else "")
        return head + "\n  because:\n" + "\n".join(f"  - {r}" for r in self.rationale)


# cost-model constants used by the break-even analysis (bytes)
_RAW_BYTES = 4


def recommend(s: Scenario) -> Recommendation:
    r: list[str] = []
    entry_bytes = s.series_len * _RAW_BYTES
    data_bytes = s.n_series * entry_bytes
    mem_entries = max(1024, s.memory_budget_bytes // max(1, entry_bytes))

    # --- node 1: ingestion pattern ------------------------------------------
    if s.streaming:
        index = "clsm"
        r.append(
            "data arrives continuously -> log-structured merges ingest with "
            "sequential writes only (CLSM); a CTree would need top-down "
            "updates or full rebuilds"
        )
        # node 1a: temporal scheme
        if s.uses_windows:
            scheme = "BTP"
            r.append(
                "window queries benefit from temporal partitions; bounded "
                "merging (BTP) keeps recent data in small skippable runs while "
                "large merged runs keep strong spatial pruning for wide windows"
            )
        else:
            scheme = "PP"
            r.append(
                "no window constraints -> pure post-filtering (PP) on the "
                "fully merged structure; temporal partitions would add probes "
                "without enabling skips"
            )
        # node 1b: read/write balance -> growth factor
        qps = s.expected_queries
        write_heavy = s.read_heavy is False or (
            s.read_heavy is None and s.ingest_rate > max(1.0, qps)
        )
        growth = 8 if write_heavy else 3
        r.append(
            ("ingest rate dominates queries -> large growth factor (%d) defers merge work"
             if write_heavy
             else "queries dominate ingest -> small growth factor (%d) keeps few runs per probe")
            % growth
        )
        # node 1c: materialization under ingest pressure
        materialized = False
        r.append(
            "streaming ingest + merges rewrite data repeatedly -> keep runs "
            "non-materialized; verification reads fetch from the raw log"
        )
        return Recommendation(index, materialized, scheme, growth, 1.0, mem_entries, r)

    # --- static data ----------------------------------------------------------
    index = "ctree"
    r.append(
        "static collection -> bulk-build once with a two-pass external sort; "
        "the read-optimized contiguous CTree gives the fastest scans"
    )
    scheme = "PP" if s.uses_windows else "-"
    if s.uses_windows:
        r.append(
            "static data has no flush-time partitions; window constraints are "
            "post-filtered on timestamps (PP)"
        )

    # node 2: materialization break-even.
    # Non-materialized build writes only summaries (~w+key bytes/entry);
    # materialized also rewrites the raw data (entry_bytes). Each exact query
    # on a non-materialized index pays ~verified_frac random fetches.
    verified_frac = 0.002  # fraction of N fetched per exact query (post-LB)
    extra_build = s.n_series * entry_bytes  # extra sequential bytes if materialized
    per_query_penalty = s.n_series * verified_frac * entry_bytes  # random bytes
    # random I/O ~20x more expensive per byte than sequential on the modeled disk
    break_even_queries = max(1, int(extra_build / (20.0 * max(per_query_penalty, 1))))
    if s.expected_queries > break_even_queries:
        materialized = True
        r.append(
            f"expected {s.expected_queries} queries > break-even {break_even_queries}: "
            "the one-off cost of materializing raw series in sorted order is "
            "amortized by removing random fetches from every query"
        )
    else:
        materialized = False
        r.append(
            f"expected {s.expected_queries} queries <= break-even {break_even_queries}: "
            "build the skeletal (summaries-only) index — faster to build, "
            "smaller on storage; queries fetch raw series on demand"
        )

    # node 3: memory budget -> external-sort passes
    if s.memory_budget_bytes < data_bytes:
        r.append(
            f"memory budget {s.memory_budget_bytes >> 20} MiB < data "
            f"{data_bytes >> 20} MiB -> two-pass external sort with "
            f"{mem_entries} entry chunks (still sequential I/O only)"
        )
    else:
        r.append("data fits in memory -> single in-memory sort pass")

    # node 4: update tolerance -> fill factor
    fill = 1.0 if s.ingest_rate == 0 else 0.8
    if fill < 1.0:
        r.append("occasional updates expected -> leaf fill factor 0.8 leaves gaps")
    return Recommendation(index, materialized, scheme, 3, fill, mem_entries, r)
