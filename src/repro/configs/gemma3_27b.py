"""gemma3-27b [dense]: 5 local (sliding 1024) : 1 global pattern, 128k
context. 62 layers = 10 groups of 6 + 2 trailing local.
[hf:google/gemma-3-1b-pt; unverified]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv=16, d_ff=21504, vocab=262144,
    head_dim=168, window=1024,
    pattern=("local", "local", "local", "local", "local", "attn"),
    rope_theta=1e6,
    notes="long_500k SKIPPED: every 6th layer is full global attention -> "
          "unbounded KV at 524288; not sub-quadratic (see DESIGN.md)",
)

SMOKE = ModelConfig(
    arch_id="gemma3-27b-smoke", family="dense",
    n_layers=8, d_model=48, n_heads=4, n_kv=2, d_ff=96, vocab=512,
    head_dim=12, window=16,
    pattern=("local", "local", "local", "local", "local", "attn"),
)
