"""hubert-xlarge [audio]: encoder-only bidirectional transformer (w2v2
arch); frame-embedding frontend STUBBED; masked prediction over 504
codebook targets. decode shapes SKIPPED (no autoregressive step exists).
[arXiv:2106.07447; unverified]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv=16, d_ff=5120, vocab=504,
    pattern=("attn",), encoder_only=True,
    frontend="audio", d_frontend=512,
    notes="vocab 504 padded to 512; encoder-only -> no decode cells",
)

SMOKE = ModelConfig(
    arch_id="hubert-xlarge-smoke", family="audio",
    n_layers=3, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=56,
    pattern=("attn",), encoder_only=True, frontend="audio", d_frontend=24,
)
