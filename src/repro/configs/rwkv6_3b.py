"""rwkv6-3b [ssm] "Finch": attention-free, data-dependent decay WKV6,
chunked/block-parallel formulation. heads = d_model/64. [arXiv:2404.05892]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv=40, d_ff=8960, vocab=65536,
    head_dim=64, pattern=("rwkv",),
    notes="sub-quadratic: O(1) recurrent state; runs long_500k",
)

SMOKE = ModelConfig(
    arch_id="rwkv6-3b-smoke", family="ssm",
    n_layers=3, d_model=64, n_heads=4, n_kv=4, d_ff=224, vocab=512,
    head_dim=16, pattern=("rwkv",),
)
