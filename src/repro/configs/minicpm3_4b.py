"""minicpm3-4b [dense]: MLA (multi-head latent attention) with q_lora 768 /
kv_lora 256, rope 32 + nope 64 head split. [hf:openbmb/MiniCPM3-4B; hf]"""
from ..models.attention import MLADims
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv=40, d_ff=6400, vocab=73448,
    pattern=("attn",),
    mla=MLADims(q_lora=768, kv_lora=256, rope_dim=32, nope_dim=64, v_dim=64),
    rope_theta=1e4,
    notes="decode uses the absorbed MLA form: cache = compressed c_kv+k_rope",
)

SMOKE = ModelConfig(
    arch_id="minicpm3-4b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=512,
    pattern=("attn",),
    mla=MLADims(q_lora=32, kv_lora=16, rope_dim=8, nope_dim=16, v_dim=16),
)
