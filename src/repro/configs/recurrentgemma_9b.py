"""recurrentgemma-9b [hybrid]: (rec, rec, local-attn) pattern; RG-LRU via
associative scan + conv1d(4); MQA local attention window 2048.
38 layers = 12 groups of 3 + 2 trailing rec. [arXiv:2402.19427; unverified]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, d_ff=12288, vocab=256000,
    head_dim=256, window=2048, d_rnn=4096,
    pattern=("rec", "rec", "local"),
    notes="sub-quadratic: RG-LRU state + bounded local window; runs long_500k",
)

SMOKE = ModelConfig(
    arch_id="recurrentgemma-9b-smoke", family="hybrid",
    n_layers=5, d_model=48, n_heads=4, n_kv=1, d_ff=96, vocab=512,
    head_dim=12, window=16, d_rnn=48,
    pattern=("rec", "rec", "local"),
)
