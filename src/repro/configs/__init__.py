"""Architecture registry: --arch <id> resolves here.

Each module exports CONFIG (the exact assigned configuration) and SMOKE
(a reduced same-family twin used by CPU smoke tests)."""
from . import (
    deepseek_moe_16b,
    gemma3_27b,
    granite_20b,
    granite_moe_1b_a400m,
    hubert_xlarge,
    llava_next_34b,
    minicpm3_4b,
    recurrentgemma_9b,
    rwkv6_3b,
    smollm_360m,
)
from .shapes import SHAPES, SMOKE_SHAPES, Shape

__all__ = [
    "SHAPES", "SMOKE_SHAPES", "Shape", "ARCH_IDS", "get_config",
    "cell_is_skipped",
]

_MODULES = {
    "llava-next-34b": llava_next_34b,
    "rwkv6-3b": rwkv6_3b,
    "smollm-360m": smollm_360m,
    "gemma3-27b": gemma3_27b,
    "minicpm3-4b": minicpm3_4b,
    "granite-20b": granite_20b,
    "granite-moe-1b-a400m": granite_moe_1b_a400m,
    "deepseek-moe-16b": deepseek_moe_16b,
    "recurrentgemma-9b": recurrentgemma_9b,
    "hubert-xlarge": hubert_xlarge,
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str, smoke: bool = False):
    mod = _MODULES[arch_id]
    return mod.SMOKE if smoke else mod.CONFIG


# (arch, shape) skips mandated by the pool rules; see DESIGN.md
SUBQUADRATIC = {"rwkv6-3b", "recurrentgemma-9b"}
ENCODER_ONLY = {"hubert-xlarge"}


def cell_is_skipped(arch_id: str, shape_name: str) -> str | None:
    """Returns a skip reason or None if the (arch, shape) cell runs."""
    if arch_id in ENCODER_ONLY and shape_name in ("decode_32k", "long_500k"):
        return "encoder-only: no autoregressive decode step"
    if shape_name == "long_500k" and arch_id not in SUBQUADRATIC:
        return "full-attention arch: 500k decode requires sub-quadratic attention"
    return None
