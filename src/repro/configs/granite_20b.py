"""granite-20b [dense]: llama-arch code model, MQA (kv=1).
[arXiv:2405.04324; hf]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv=1, d_ff=24576, vocab=49152,
    pattern=("attn",), rope_theta=1e4,
)

SMOKE = ModelConfig(
    arch_id="granite-20b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv=1, d_ff=128, vocab=512,
    pattern=("attn",),
)
