"""deepseek-moe-16b [moe]: 2 shared + 64 routed top-6 fine-grained experts
(d_expert 1408); layer 0 is a dense MLP (d_ff 10944). [arXiv:2401.06066]"""
from ..models.moe import MoEDims
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv=16, d_ff=1408, vocab=102400,
    pattern=("attn",), first_dense=1, d_ff_dense=10944,
    moe=MoEDims(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    arch_id="deepseek-moe-16b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv=4, d_ff=48, vocab=512,
    pattern=("attn",), first_dense=1, d_ff_dense=128,
    moe=MoEDims(n_experts=8, top_k=2, d_expert=48, n_shared=1, capacity_factor=8.0),
)
