"""smollm-360m [dense]: llama-arch small, GQA kv=5.
[hf:HuggingFaceTB/SmolLM-135M; hf]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv=5, d_ff=2560, vocab=49152,
    pattern=("attn",), rope_theta=1e4,
)

SMOKE = ModelConfig(
    arch_id="smollm-360m-smoke", family="dense",
    n_layers=3, d_model=60, n_heads=3, n_kv=1, d_ff=160, vocab=512,
    pattern=("attn",),
)
