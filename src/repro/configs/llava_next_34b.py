"""llava-next-34b [vlm]: mistral-style decoder backbone, anyres vision
frontend STUBBED (input_specs feeds precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv=8, d_ff=20480, vocab=64000,
    pattern=("attn",), rope_theta=1e6,
    frontend="vision", n_vis_tokens=576, d_frontend=1152,
    notes="anyres tiling stub: 576 base-image patch embeddings prepended",
)

SMOKE = ModelConfig(
    arch_id="llava-next-34b-smoke", family="vlm",
    n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=512,
    pattern=("attn",), frontend="vision", n_vis_tokens=8, d_frontend=24,
)
