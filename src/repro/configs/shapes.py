"""The assigned input-shape set. Every LM arch pairs with all four shapes
(minus documented skips): train_4k lowers train_step; prefill_32k lowers
prefill_step; decode_32k / long_500k lower serve_step (one new token against
a KV cache of seq_len)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

# smoke-test (reduced) twins used by tests: same code paths, tiny sizes
SMOKE_SHAPES = {
    "train_4k": Shape("train_4k", 64, 4, "train"),
    "prefill_32k": Shape("prefill_32k", 96, 2, "prefill"),
    "decode_32k": Shape("decode_32k", 96, 2, "decode"),
    "long_500k": Shape("long_500k", 128, 1, "decode"),
}
