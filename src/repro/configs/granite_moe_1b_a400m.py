"""granite-moe-1b-a400m [moe]: 32 experts top-8, fine-grained d_expert 512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from ..models.moe import MoEDims
from ..models.transformer import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv=8, d_ff=512, vocab=49155,
    pattern=("attn",),
    moe=MoEDims(n_experts=32, top_k=8, d_expert=512, n_shared=0),
    notes="vocab 49155 padded to 49280 for the 16-way vocab shard",
)

SMOKE = ModelConfig(
    arch_id="granite-moe-1b-a400m-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv=2, d_ff=32, vocab=515,
    pattern=("attn",),
    moe=MoEDims(n_experts=8, top_k=2, d_expert=32, n_shared=0, capacity_factor=8.0),
)
