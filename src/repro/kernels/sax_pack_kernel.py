"""Pallas kernel: SAX quantization + bit-interleave into sortable keys.

The paper's core operation — making summarizations *sortable* — as a single
fused VPU kernel: a (block_b, w) tile of PAA values is quantized against the
2**c - 1 normal-quantile breakpoints (vectorized compare-and-count, no
gather) and the resulting symbols are bit-interleaved MSB-first across
segments into big-endian uint32 key words, all in registers/VMEM.

Pure 32-bit integer shifts/ors — no 64-bit integer ops (TPU-friendly) and
no data-dependent control flow. The static unroll is c*w <= 128 vector ops.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sax_pack_body(p_ref, bps_ref, sym_ref, key_ref, *, card_bits: int, n_words: int):
    p = p_ref[...].astype(jnp.float32)  # (bb, w)
    bps = bps_ref[...].astype(jnp.float32)  # (n_bps,)
    bb, w = p.shape
    c = card_bits
    # quantize: symbol = #breakpoints <= value  (compare-and-count, VPU)
    sym = jnp.sum(p[:, :, None] >= bps[None, None, :], axis=-1).astype(jnp.int32)
    sym_ref[...] = sym
    # interleave: key bit index p_bit = b*w + s  (b: 0 = MSB of symbol)
    words = [jnp.zeros((bb,), jnp.uint32) for _ in range(n_words)]
    for b in range(c):
        bitvals = ((sym >> (c - 1 - b)) & 1).astype(jnp.uint32)  # (bb, w)
        for s in range(w):
            pos = b * w + s
            word_i, bit_i = pos // 32, pos % 32
            words[word_i] = words[word_i] | (bitvals[:, s] << (31 - bit_i))
    key_ref[...] = jnp.stack(words, axis=-1)


@functools.partial(
    jax.jit, static_argnames=("card_bits", "n_words", "block_b", "interpret")
)
def sax_pack_pallas(
    p: jnp.ndarray,
    bps: jnp.ndarray,
    card_bits: int,
    *,
    n_words: int = 4,
    block_b: int = 256,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """p: (B, w) PAA values, bps: (2**c - 1,) sorted breakpoints.

    Returns (sym (B, w) int32, keys (B, n_words) uint32)."""
    b, w = p.shape
    assert b % block_b == 0, (b, block_b)
    assert card_bits * w <= n_words * 32
    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_sax_pack_body, card_bits=card_bits, n_words=n_words),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, w), lambda i: (i, 0)),
            pl.BlockSpec((bps.shape[0],), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, w), lambda i: (i, 0)),
            pl.BlockSpec((block_b, n_words), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, w), jnp.int32),
            jax.ShapeDtypeStruct((b, n_words), jnp.uint32),
        ],
        interpret=interpret,
    )(p, bps)
