"""Pallas kernel: blocked MINDIST_PAA_SAX lower-bound filter.

The pruning front of exact search: for one query PAA vector and a contiguous
range of candidate SAX regions (lo/hi per segment, produced from zone maps
or per-entry symbols), compute the squared lower bound per candidate. Pure
VPU elementwise work on (block_b, w) tiles; fused with the comparison
against the best-so-far radius so the output can directly drive a
compact/verify step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lb_body(q_ref, lo_ref, hi_ref, lb_ref, *, seg_len: int):
    q = q_ref[...].astype(jnp.float32)  # (1, w)
    lo = lo_ref[...].astype(jnp.float32)  # (bb, w)
    hi = hi_ref[...].astype(jnp.float32)
    below = jnp.maximum(lo - q, 0.0)
    above = jnp.maximum(q - hi, 0.0)
    dseg = jnp.maximum(below, above)
    lb_ref[...] = seg_len * jnp.sum(dseg * dseg, axis=-1)


@functools.partial(jax.jit, static_argnames=("seg_len", "block_b", "interpret"))
def mindist_pallas(
    q_paa: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    seg_len: int,
    *,
    block_b: int = 1024,
    interpret: bool = False,
) -> jnp.ndarray:
    """q_paa: (w,), lo/hi: (B, w) region bounds; B % block_b == 0 -> (B,) f32."""
    b, w = lo.shape
    assert b % block_b == 0, (b, block_b)
    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_lb_body, seg_len=seg_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, w), lambda i: (0, 0)),
            pl.BlockSpec((block_b, w), lambda i: (i, 0)),
            pl.BlockSpec((block_b, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(q_paa[None, :], lo, hi)
