"""Pallas kernel: PAA segment means, (B, n) -> (B, w).

The summarization front of the Coconut ingest path. One grid step loads a
(block_b, n) tile of raw series into VMEM, reduces each of the w segments
with a reshape-mean (VPU), and writes the (block_b, w) summary tile.

Tiling: n is the series length (<= 1024 in practice); block_b is chosen so
the tile fits comfortably in VMEM (block_b * n * 4B <= ~2 MiB), with the
lane dimension n a multiple of 128 for clean vector layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _paa_body(x_ref, o_ref, *, n_segments: int):
    x = x_ref[...].astype(jnp.float32)  # (bb, n)
    bb, n = x.shape
    seg = n // n_segments
    o_ref[...] = x.reshape(bb, n_segments, seg).mean(axis=-1)


@functools.partial(jax.jit, static_argnames=("n_segments", "block_b", "interpret"))
def paa_pallas(
    x: jnp.ndarray,
    n_segments: int,
    *,
    block_b: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """x: (B, n) with B % block_b == 0 and n % n_segments == 0 -> (B, w) f32."""
    b, n = x.shape
    assert b % block_b == 0, (b, block_b)
    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_paa_body, n_segments=n_segments),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, n_segments), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_segments), jnp.float32),
        interpret=interpret,
    )(x)
