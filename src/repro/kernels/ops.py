"""jit'd public wrappers around the Pallas kernels.

Handles shape padding to block multiples, breakpoint tables, and backend
dispatch: on TPU the kernels run compiled; elsewhere (this CPU container)
they run in interpret mode, executing the same kernel bodies in Python —
the validation mode mandated for this repro.
"""
from __future__ import annotations

import os

import numpy as np

import jax
import jax.numpy as jnp

from ..core.summarization import SummarizationConfig, breakpoints
from .ed_scan_kernel import (
    min_ed_pallas,
    screen_select_pallas,
    screen_select_quant_pallas,
    topk_ed_pallas,
)
from .lb_kernel import mindist_pallas
from .paa_kernel import paa_pallas
from .sax_pack_kernel import sax_pack_pallas

# Compiled on TPU, interpret mode elsewhere. REPRO_PALLAS_COMPILED=1 is the
# compiled-mode validation escape: it forces interpret=False even off-TPU so
# the kernels' real Mosaic lowering is exercised wherever an accelerator is
# attached; tests/test_pallas_compiled.py wraps it with a graceful skip on
# backends that cannot compile the kernels (the CI leg is allowed to skip).
INTERPRET = (jax.default_backend() != "tpu"
             and os.environ.get("REPRO_PALLAS_COMPILED") != "1")

# sentinel |x|^2 for pad candidates: dominates any real screened distance
# without overflowing the f32 d2 arithmetic (see screen_select)
BIG_NORM2 = 1e30


def _pad_rows(x: jnp.ndarray, mult: int, fill=0.0) -> tuple[jnp.ndarray, int]:
    b = x.shape[0]
    pad = (-b) % mult
    if pad:
        x = jnp.concatenate(
            [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)], axis=0
        )
    return x, b


def paa(x: jnp.ndarray, cfg: SummarizationConfig, *, block_b: int = 256) -> jnp.ndarray:
    """(B, n) -> (B, w) PAA summaries via the Pallas kernel."""
    x = jnp.asarray(x, jnp.float32)
    if x.shape[0] == 0:  # empty batch: no kernel launch, no row padding
        return jnp.zeros((0, cfg.n_segments), jnp.float32)
    block_b = min(block_b, max(8, x.shape[0]))
    xp, b = _pad_rows(x, block_b)
    out = paa_pallas(xp, cfg.n_segments, block_b=block_b, interpret=INTERPRET)
    return out[:b]


def sax_and_keys(
    p: jnp.ndarray, cfg: SummarizationConfig, *, block_b: int = 256
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """PAA (B, w) -> (symbols (B, w) int32, sortable keys (B, nw) uint32)."""
    p = jnp.asarray(p, jnp.float32)
    if p.shape[0] == 0:  # empty batch: no kernel launch, no row padding
        return (
            jnp.zeros((0, cfg.n_segments), jnp.int32),
            jnp.zeros((0, cfg.key_words), jnp.uint32),
        )
    block_b = min(block_b, max(8, p.shape[0]))
    pp, b = _pad_rows(p, block_b)
    bps = jnp.asarray(breakpoints(cfg.card_bits))
    sym, keys = sax_pack_pallas(
        pp, bps, cfg.card_bits, n_words=cfg.key_words, block_b=block_b,
        interpret=INTERPRET,
    )
    return sym[:b], keys[:b]


def summarize(
    x: jnp.ndarray, cfg: SummarizationConfig
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full device ingest front: series -> (paa, symbols, sortable keys)."""
    p = paa(x, cfg)
    sym, keys = sax_and_keys(p, cfg)
    return p, sym, keys


def min_ed(
    q: jnp.ndarray,
    x: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 512,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query (min squared ED, argmin) over candidate series.

    q: (m, d), x: (n, d). Pads m/n with sentinels; d to a lane multiple."""
    q = jnp.asarray(q, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    m, d = q.shape
    n = x.shape[0]
    if m == 0:  # empty query batch
        return jnp.zeros((0,), jnp.float32), jnp.zeros((0,), jnp.int32)
    if n == 0:  # no candidates: nothing to win the min
        return jnp.full((m,), jnp.inf, jnp.float32), jnp.full((m,), -1, jnp.int32)
    block_m = min(block_m, max(8, m))
    block_n = min(block_n, max(8, n))
    dp = (-d) % 128
    if dp:  # zero-pad the contraction dim: adds 0 to every distance
        q = jnp.concatenate([q, jnp.zeros((m, dp), q.dtype)], axis=1)
        x = jnp.concatenate([x, jnp.zeros((n, dp), x.dtype)], axis=1)
    qp, _ = _pad_rows(q, block_m)
    # pad candidates with +large rows so they never win the min
    xp, _ = _pad_rows(x, block_n, fill=1e15)
    md, am = min_ed_pallas(qp, xp, block_m=block_m, block_n=block_n, interpret=INTERPRET)
    return md[:m], am[:m]


def topk_ed(
    q: jnp.ndarray,
    x: jnp.ndarray,
    k: int,
    *,
    block_m: int = 128,
    block_n: int = 512,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query k smallest squared EDs + candidate rows, ascending.

    q: (m, d), x: (n, d) -> ((m, k) f32, (m, k) int32). Pads m/n/d to block
    multiples (candidate pads get a +large sentinel fill) and always returns
    k columns: when n < k the tail is (inf, -1). Ties break toward the
    smaller candidate index (the kernel's lexicographic semantics)."""
    q = jnp.asarray(q, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    m, d = q.shape
    n = x.shape[0]
    if m == 0:  # empty query batch
        return jnp.zeros((0, k), jnp.float32), jnp.zeros((0, k), jnp.int32)
    if n == 0:  # no candidates: every requested slot is explicit padding
        return (
            jnp.full((m, k), jnp.inf, jnp.float32),
            jnp.full((m, k), -1, jnp.int32),
        )
    kk = max(1, min(k, n))
    block_m = min(block_m, max(8, m))
    block_n = min(block_n, max(8, n))
    dp = (-d) % 128
    if dp:  # zero-pad the contraction dim: adds 0 to every distance
        q = jnp.concatenate([q, jnp.zeros((m, dp), q.dtype)], axis=1)
        x = jnp.concatenate([x, jnp.zeros((n, dp), x.dtype)], axis=1)
    qp, _ = _pad_rows(q, block_m)
    # pad candidates with +large rows; they only surface when n < kk + pad,
    # and are mapped to (inf, -1) below via their out-of-range index
    xp, _ = _pad_rows(x, block_n, fill=1e15)
    vals, idxs = topk_ed_pallas(
        qp, xp, kk, block_m=block_m, block_n=block_n, interpret=INTERPRET
    )
    vals, idxs = vals[:m], idxs[:m]
    invalid = idxs >= n  # row-pad candidates and never-filled (inf) slots
    vals = jnp.where(invalid, jnp.inf, vals)
    idxs = jnp.where(invalid, -1, idxs)
    if kk < k:  # fewer candidates than requested neighbors
        fill_v = jnp.full((m, k - kk), jnp.inf, vals.dtype)
        fill_i = jnp.full((m, k - kk), -1, idxs.dtype)
        vals = jnp.concatenate([vals, fill_v], axis=1)
        idxs = jnp.concatenate([idxs, fill_i], axis=1)
    return vals, idxs


def candidate_bucket(e: int, min_bucket: int = 64) -> int:
    """The power-of-two candidate bucket (min ``min_bucket``) ``e`` pads to
    — the shared shape discipline of every bucketed launcher, so steady
    state serving hits a handful of cached traces."""
    return 1 << max(min_bucket.bit_length() - 1, (max(1, e) - 1).bit_length())


def topk_ed_bucketed(
    q: jnp.ndarray, x: jnp.ndarray, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """``topk_ed`` with the candidate count padded up to a power-of-two
    bucket (min 64) so jit sees a handful of stable shapes across serving
    passes — the launcher used by the shared query executor.

    Bucket-padding rows carry a +large sentinel; any that surface (only
    possible when the true candidate count < k) are mapped to (inf, -1),
    so results are indistinguishable from an unpadded launch. When the
    caller already padded to the bucket (``e == bucket``) the table is
    passed through without the concat copy — the fast path arenas rely on.
    Returns host ((m, kk) f32 d2, (m, kk) int64 rows into ``x``), kk =
    min(k, |x|)."""
    m = np.asarray(q).shape[0]
    e = x.shape[0]
    if e == 0:  # no candidates: every requested slot is explicit padding
        return (
            np.full((m, k), np.inf, np.float32),
            np.full((m, k), -1, np.int64),
        )
    x = jnp.asarray(x, jnp.float32)
    bucket = candidate_bucket(e)
    if bucket != e:  # fast path: already bucket-sized tables skip the copy
        pad = jnp.full((bucket - e, x.shape[1]), 1e15, jnp.float32)
        x = jnp.concatenate([x, pad])
    v, i = topk_ed(q, x, min(k, e))
    i = np.asarray(i).astype(np.int64)
    v = np.asarray(v)
    invalid = (i < 0) | (i >= e)  # bucket padding / never-filled slots
    return np.where(invalid, np.inf, v), np.where(invalid, -1, i)


def screen_select(
    q: jnp.ndarray,
    x: jnp.ndarray,
    xn2: jnp.ndarray,
    k: int,
    *,
    block_m: int = 128,
    block_n: int = 512,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One fused verification launch: f32 matmul-form screen over candidates
    with PRECOMPUTED squared norms, in-kernel top-k slate selection, and the
    per-query |q|^2 certificate term.

    q: (m, d), x: (n, d), xn2: (n,) -> ((m, k) f32 d2 ascending, (m, k)
    int32 rows, (m,) f32 |q|^2). Pads m/n/d to block multiples; candidate
    pads get zero rows with a :data:`BIG_NORM2` sentinel norm (the screen
    uses ``xn2``, not the rows, for the |x|^2 term, so the sentinel keeps
    pads out of every slate without f32 overflow) and surface as (inf, -1).
    Ties break toward the smaller candidate index (lexicographic (d2, index)
    — the ``screen_select_ref`` oracle semantics).

    ``x`` may arrive in bf16 (a quantized arena): the storage dtype is
    preserved through padding — halving the kernel's HBM traffic — and the
    kernel body upcasts each tile to f32 in-register, so compute precision
    is unchanged. Anything else is cast to f32 up front."""
    q = jnp.asarray(q, jnp.float32)
    x = jnp.asarray(x)
    if x.dtype != jnp.bfloat16:
        x = x.astype(jnp.float32)
    xn2 = jnp.asarray(xn2, jnp.float32)
    m, d = q.shape
    n = x.shape[0]
    if m == 0:  # empty query batch
        return (
            jnp.zeros((0, k), jnp.float32),
            jnp.zeros((0, k), jnp.int32),
            jnp.zeros((0,), jnp.float32),
        )
    if n == 0:  # no candidates: every requested slot is explicit padding
        return (
            jnp.full((m, k), jnp.inf, jnp.float32),
            jnp.full((m, k), -1, jnp.int32),
            jnp.sum(q * q, axis=-1),
        )
    kk = max(1, min(k, n))
    block_m = min(block_m, max(8, m))
    block_n = min(block_n, max(8, n))
    dp = (-d) % 128
    if dp:  # zero-pad the contraction dim: adds 0 to every distance
        q = jnp.concatenate([q, jnp.zeros((m, dp), q.dtype)], axis=1)
        x = jnp.concatenate([x, jnp.zeros((n, dp), x.dtype)], axis=1)
    qp, _ = _pad_rows(q, block_m)
    xp, _ = _pad_rows(x, block_n)  # zero rows; the sentinel lives in xn2
    xn2p, _ = _pad_rows(xn2, block_n, fill=BIG_NORM2)
    vals, idxs, qn2 = screen_select_pallas(
        qp, xp, xn2p, kk, block_m=block_m, block_n=block_n, interpret=INTERPRET
    )
    vals, idxs, qn2 = vals[:m], idxs[:m], qn2[:m]
    invalid = idxs >= n  # row-pad candidates and never-filled (inf) slots
    vals = jnp.where(invalid, jnp.inf, vals)
    idxs = jnp.where(invalid, -1, idxs)
    if kk < k:  # fewer candidates than requested slate slots
        vals = jnp.concatenate(
            [vals, jnp.full((m, k - kk), jnp.inf, vals.dtype)], axis=1)
        idxs = jnp.concatenate(
            [idxs, jnp.full((m, k - kk), -1, idxs.dtype)], axis=1)
    return vals, idxs, qn2


def screen_select_quant(
    q: jnp.ndarray,
    x: jnp.ndarray,
    scale: jnp.ndarray,
    xn2: jnp.ndarray,
    k: int,
    *,
    block_m: int = 128,
    block_n: int = 512,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """:func:`screen_select` over an int8 arena with per-row f32 scales.

    q: (m, d) f32, x: (n, d) int8, scale: (n,) f32, xn2: (n,) f32 — the
    squared norms of the DEQUANTIZED rows, so the screen is self-consistent
    with the stored values. The kernel upcasts each int8 tile to f32
    in-register and applies the scale after the MXU contraction (the cross
    term ``<q, s*v> = s * <q, v>``), quartering HBM/h2d traffic while
    keeping compute in f32. Same padding, sentinel, and tie semantics as
    :func:`screen_select`; pad rows get scale 1 (their sentinel lives in
    ``xn2``)."""
    q = jnp.asarray(q, jnp.float32)
    x = jnp.asarray(x, jnp.int8)
    scale = jnp.asarray(scale, jnp.float32)
    xn2 = jnp.asarray(xn2, jnp.float32)
    m, d = q.shape
    n = x.shape[0]
    if m == 0:  # empty query batch
        return (
            jnp.zeros((0, k), jnp.float32),
            jnp.zeros((0, k), jnp.int32),
            jnp.zeros((0,), jnp.float32),
        )
    if n == 0:  # no candidates: every requested slot is explicit padding
        return (
            jnp.full((m, k), jnp.inf, jnp.float32),
            jnp.full((m, k), -1, jnp.int32),
            jnp.sum(q * q, axis=-1),
        )
    kk = max(1, min(k, n))
    block_m = min(block_m, max(8, m))
    block_n = min(block_n, max(8, n))
    dp = (-d) % 128
    if dp:  # zero-pad the contraction dim: adds 0 to every distance
        q = jnp.concatenate([q, jnp.zeros((m, dp), q.dtype)], axis=1)
        x = jnp.concatenate([x, jnp.zeros((n, dp), x.dtype)], axis=1)
    qp, _ = _pad_rows(q, block_m)
    xp, _ = _pad_rows(x, block_n)  # zero rows; the sentinel lives in xn2
    sp, _ = _pad_rows(scale, block_n, fill=1.0)
    xn2p, _ = _pad_rows(xn2, block_n, fill=BIG_NORM2)
    vals, idxs, qn2 = screen_select_quant_pallas(
        qp, xp, sp, xn2p, kk, block_m=block_m, block_n=block_n,
        interpret=INTERPRET
    )
    vals, idxs, qn2 = vals[:m], idxs[:m], qn2[:m]
    invalid = idxs >= n  # row-pad candidates and never-filled (inf) slots
    vals = jnp.where(invalid, jnp.inf, vals)
    idxs = jnp.where(invalid, -1, idxs)
    if kk < k:  # fewer candidates than requested slate slots
        vals = jnp.concatenate(
            [vals, jnp.full((m, k - kk), jnp.inf, vals.dtype)], axis=1)
        idxs = jnp.concatenate(
            [idxs, jnp.full((m, k - kk), -1, idxs.dtype)], axis=1)
    return vals, idxs, qn2


def mindist(
    q_paa: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    cfg: SummarizationConfig,
    *,
    block_b: int = 1024,
) -> jnp.ndarray:
    """Blocked MINDIST_PAA_SAX lower bounds. lo/hi: (B, w) -> (B,) f32."""
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    b = lo.shape[0]
    if b == 0:  # empty batch: no kernel launch, no row padding
        return jnp.zeros((0,), jnp.float32)
    block_b = min(block_b, max(8, b))
    lop, _ = _pad_rows(lo, block_b, fill=0.0)
    hip, _ = _pad_rows(hi, block_b, fill=0.0)
    out = mindist_pallas(
        jnp.asarray(q_paa, jnp.float32), lop, hip, cfg.segment_len,
        block_b=block_b, interpret=INTERPRET,
    )
    return out[:b]
