# Pallas TPU kernels for the Coconut hot paths (validated interpret=True on
# CPU): PAA summarize, SAX quantize + bit-interleave (sortable keys), blocked
# min-ED scan (MXU form), and the MINDIST lower-bound filter.
from . import ops, ref
