# Pallas TPU kernels for the Coconut hot paths (validated interpret=True on
# CPU): PAA summarize, SAX quantize + bit-interleave (sortable keys), blocked
# min-ED scan and its running top-k generalization topk_ed (MXU form, (bm, k)
# VMEM accumulator — the device path of the batched knn_batch query engine),
# and the MINDIST lower-bound filter.
from . import ops, ref

__all__ = ["ops", "ref"]
