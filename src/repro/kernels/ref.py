"""Pure-jnp oracles for every Pallas kernel.

Each function is the semantic ground truth its kernel twin is tested against
(tests/test_kernels.py sweeps shapes/dtypes with assert_allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paa_ref(x: jnp.ndarray, n_segments: int) -> jnp.ndarray:
    """PAA segment means. x: (B, n) -> (B, w) float32."""
    b, n = x.shape
    seg = n // n_segments
    return x.reshape(b, n_segments, seg).mean(axis=-1).astype(jnp.float32)


def sax_ref(p: jnp.ndarray, bps: jnp.ndarray) -> jnp.ndarray:
    """Quantize PAA values against sorted breakpoints. (B, w) -> (B, w) int32."""
    return jnp.sum(p[..., None] >= bps, axis=-1).astype(jnp.int32)


def pack_keys_ref(sym: jnp.ndarray, card_bits: int, n_words: int = 4) -> jnp.ndarray:
    """Bit-interleave SAX symbols into big-endian uint32 key words.

    sym: (B, w) int32 -> (B, n_words) uint32. Key bit p = b*w + s (b = bit
    index from MSB of each symbol, s = segment); bit 0 is the MSB of word 0.
    """
    b_, w = sym.shape
    c = card_bits
    shifts = jnp.arange(c - 1, -1, -1, dtype=sym.dtype)
    bits = (sym[:, None, :] >> shifts[:, None]) & 1  # (B, c, w)
    flat = bits.reshape(b_, c * w)
    pad = n_words * 32 - c * w
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((b_, pad), flat.dtype)], axis=-1)
    words = flat.reshape(b_, n_words, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(31, -1, -1, dtype=jnp.uint32)
    return (words * weights).sum(axis=-1).astype(jnp.uint32)


def min_ed_ref(q: jnp.ndarray, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query min squared-ED and argmin over candidates.

    q: (m, d), x: (n, d) -> ((m,) f32, (m,) int32)."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    d2 = (
        jnp.sum(q * q, -1)[:, None]
        + jnp.sum(x * x, -1)[None, :]
        - 2.0 * q @ x.T
    )
    return jnp.min(d2, axis=1), jnp.argmin(d2, axis=1).astype(jnp.int32)


def topk_ed_ref(q: jnp.ndarray, x: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query k smallest squared EDs and candidate rows, ties broken
    toward the smaller candidate index (lexicographic (d2, index) sort —
    the exact semantics of the topk_ed Pallas kernel).

    q: (m, d), x: (n, d), 1 <= k <= n -> ((m, k) f32 ascending, (m, k) int32)."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    d2 = (
        jnp.sum(q * q, -1)[:, None]
        + jnp.sum(x * x, -1)[None, :]
        - 2.0 * q @ x.T
    )  # (m, n)
    idx = jnp.broadcast_to(
        jnp.arange(x.shape[0], dtype=jnp.int32)[None, :], d2.shape
    )
    sv, si = jax.lax.sort((d2, idx), num_keys=2, dimension=1)
    return sv[:, :k], si[:, :k]


def screen_select_ref(
    q: jnp.ndarray, x: jnp.ndarray, xn2: jnp.ndarray, k: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused screen+select oracle: matmul-form d2 with PRECOMPUTED candidate
    norms (the verification engine's cached |x|^2), lexicographic (d2,
    index) top-k, plus the per-query |q|^2 certificate term.

    q: (m, d), x: (n, d), xn2: (n,), 1 <= k <= n ->
    ((m, k) f32 ascending, (m, k) int32, (m,) f32)."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qn2 = jnp.sum(q * q, -1)
    d2 = qn2[:, None] + xn2.astype(jnp.float32)[None, :] - 2.0 * q @ x.T
    idx = jnp.broadcast_to(
        jnp.arange(x.shape[0], dtype=jnp.int32)[None, :], d2.shape
    )
    sv, si = jax.lax.sort((d2, idx), num_keys=2, dimension=1)
    return sv[:, :k], si[:, :k], qn2


def screen_select_quant_ref(
    q: jnp.ndarray, x: jnp.ndarray, scale: jnp.ndarray, xn2: jnp.ndarray,
    k: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for the int8 fused screen: upcast the stored values to f32,
    apply the per-row scale to the cross term AFTER the matmul (exactly the
    kernel's dequantization order, so results are bit-comparable), and use
    the precomputed dequantized norms.

    q: (m, d) f32, x: (n, d) int8, scale: (n,) f32, xn2: (n,), 1 <= k <= n
    -> ((m, k) f32 ascending, (m, k) int32, (m,) f32)."""
    q = q.astype(jnp.float32)
    g = (q @ x.astype(jnp.float32).T) * scale.astype(jnp.float32)[None, :]
    qn2 = jnp.sum(q * q, -1)
    d2 = qn2[:, None] + xn2.astype(jnp.float32)[None, :] - 2.0 * g
    idx = jnp.broadcast_to(
        jnp.arange(x.shape[0], dtype=jnp.int32)[None, :], d2.shape
    )
    sv, si = jax.lax.sort((d2, idx), num_keys=2, dimension=1)
    return sv[:, :k], si[:, :k], qn2


def mindist_ref(q_paa: jnp.ndarray, lo: jnp.ndarray, hi: jnp.ndarray, seg_len: int) -> jnp.ndarray:
    """Squared MINDIST between a query PAA (w,) and candidate regions (B, w)."""
    below = jnp.maximum(lo - q_paa[None, :], 0.0)
    above = jnp.maximum(q_paa[None, :] - hi, 0.0)
    d = jnp.maximum(below, above)
    return (seg_len * jnp.sum(d * d, axis=-1)).astype(jnp.float32)
