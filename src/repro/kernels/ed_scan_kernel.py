"""Pallas kernels: blocked squared-Euclidean-distance scans (MXU form).

The paper's "sequential scan of a contiguous leaf range" re-thought for the
TPU: instead of early-abandoned scalar loops (a disk/CPU idiom), distances
are computed in the matmul form  d2 = |q|^2 + |x|^2 - 2 q.x  on (bm x bn)
tiles streaming through VMEM, with a fused running reduction so the full
(m x n) distance matrix is never materialized in HBM.

Three reductions share the tile pipeline:

* :func:`min_ed_pallas` — per-query running min/argmin (k = 1);
* :func:`topk_ed_pallas` — per-query running top-k: a (bm, k) VMEM
  accumulator of (distance, candidate index) pairs, sorted ascending, is
  merged with each candidate tile by k rounds of min-extraction (pure VPU
  min/where work — no generic sort, so the body also lowers on Mosaic).
  Ties break toward the smaller candidate index, which makes the result
  bit-identical to the lexicographic (d2, index) reference in ref.py.
* :func:`screen_select_pallas` — the verification engine's fused
  screen+select: same running top-k, but the candidate |x|^2 term comes in
  as a precomputed input (the engine's device arena caches centered norms,
  so nothing table-sized is recomputed per pass) and the per-query |q|^2
  needed by the error-bound certificate is emitted alongside the slate —
  one launch replaces the host einsum + argpartition + gather round-trip.

Grid: (m/bm, n/bn) with the candidate axis iterating fastest; the output
tile (the per-query accumulator) is revisited across the candidate axis —
the canonical Pallas accumulation pattern. Block shapes keep the
MXU-aligned contraction (d is zero-padded to a multiple of 128 by ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INT_MAX = 2**31 - 1  # plain int: jnp scalars would be captured as consts


def _ed_scan_body(q_ref, x_ref, min_ref, arg_ref, *, block_n: int, n_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, jnp.inf)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    d2 = _tile_d2(q_ref, x_ref)  # (bm, bn)
    blk_min = jnp.min(d2, axis=1)
    blk_arg = jnp.argmin(d2, axis=1).astype(jnp.int32) + j * block_n
    cur = min_ref[...]
    take = blk_min < cur
    min_ref[...] = jnp.where(take, blk_min, cur)
    arg_ref[...] = jnp.where(take, blk_arg, arg_ref[...])


def _tile_d2(q_ref, x_ref) -> jnp.ndarray:
    """Squared ED of one (bm, d) x (bn, d) tile: MXU contraction + VPU
    rank-1 corrections."""
    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    return (
        jnp.sum(q * q, axis=-1, keepdims=True)  # (bm, 1)
        + jnp.sum(x * x, axis=-1)[None, :]  # (1, bn)
        - 2.0 * jax.lax.dot_general(
            q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
    )  # (bm, bn)


def _merge_topk_tile(vals_ref, idxs_ref, d2, tile_idx, k: int) -> None:
    """Merge the sorted (bm, k) accumulator with a fresh (bm, bn) distance
    tile: k rounds of min-extraction over the (bm, k + bn) candidate pool.
    Candidate indices are globally unique within a launch, so masking by
    (value, index) removes exactly one real entry per round; empty slots
    (inf, INT_MAX) collapse together harmlessly."""
    bm = d2.shape[0]
    cand_v = jnp.concatenate([vals_ref[...], d2], axis=1)
    cand_i = jnp.concatenate([idxs_ref[...], tile_idx], axis=1)
    slot = jax.lax.broadcasted_iota(jnp.int32, (bm, k), 1)  # (bm, k)

    def extract(t, carry):
        cv, ov, oi = carry
        best_v = jnp.min(cv, axis=1)  # (bm,)
        tie = cv == best_v[:, None]
        best_i = jnp.min(jnp.where(tie, cand_i, _INT_MAX), axis=1)  # (bm,)
        hit = tie & (cand_i == best_i[:, None])
        cv = jnp.where(hit, jnp.inf, cv)
        write = slot == t
        ov = jnp.where(write, best_v[:, None], ov)
        oi = jnp.where(write, best_i[:, None], oi)
        return cv, ov, oi

    _, out_v, out_i = jax.lax.fori_loop(
        0, k, extract, (cand_v, vals_ref[...], idxs_ref[...])
    )
    vals_ref[...] = out_v
    idxs_ref[...] = out_i


def _topk_ed_body(q_ref, x_ref, vals_ref, idxs_ref, *, k: int, block_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, jnp.inf)
        idxs_ref[...] = jnp.full_like(idxs_ref, _INT_MAX)

    d2 = _tile_d2(q_ref, x_ref)  # (bm, bn)
    tile_idx = (
        jax.lax.broadcasted_iota(jnp.int32, (d2.shape[0], block_n), 1)
        + j * block_n
    )
    _merge_topk_tile(vals_ref, idxs_ref, d2, tile_idx, k)


def _screen_select_body(
    q_ref, x_ref, xn2_ref, vals_ref, idxs_ref, qn2_ref, *, k: int, block_n: int
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, jnp.inf)
        idxs_ref[...] = jnp.full_like(idxs_ref, _INT_MAX)

    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    qn2 = jnp.sum(q * q, axis=-1)  # (bm,) — the certificate's |q|^2 term
    qn2_ref[...] = qn2  # idempotent across the candidate axis
    # matmul-form screen with the PRECOMPUTED candidate norms: the arena
    # caches |x|^2 once per table, so the tile pays one MXU contraction and
    # two rank-1 corrections — never a second pass over x
    d2 = (
        qn2[:, None]
        + xn2_ref[...][None, :]
        - 2.0 * jax.lax.dot_general(
            q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
    )  # (bm, bn)
    tile_idx = (
        jax.lax.broadcasted_iota(jnp.int32, (d2.shape[0], block_n), 1)
        + j * block_n
    )
    _merge_topk_tile(vals_ref, idxs_ref, d2, tile_idx, k)


def _screen_select_quant_body(
    q_ref, x_ref, s_ref, xn2_ref, vals_ref, idxs_ref, qn2_ref, *, k: int,
    block_n: int
):
    """The int8 screen body: identical to :func:`_screen_select_body`
    except the candidate tile arrives as int8 values with per-row f32
    scales. The tile upcasts in-register and the scale is applied AFTER
    the MXU contraction (``<q, s*v> = s * <q, v>`` — one (bm, bn) VPU
    multiply instead of rescaling the whole (bn, d) tile); ``xn2`` already
    holds the dequantized norms, so no |x|^2 rescale is needed."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, jnp.inf)
        idxs_ref[...] = jnp.full_like(idxs_ref, _INT_MAX)

    q = q_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)  # in-register int8 -> f32 upcast
    qn2 = jnp.sum(q * q, axis=-1)  # (bm,) — the certificate's |q|^2 term
    qn2_ref[...] = qn2  # idempotent across the candidate axis
    g = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * s_ref[...][None, :]  # (bm, bn) dequantized cross term
    d2 = qn2[:, None] + xn2_ref[...][None, :] - 2.0 * g
    tile_idx = (
        jax.lax.broadcasted_iota(jnp.int32, (d2.shape[0], block_n), 1)
        + j * block_n
    )
    _merge_topk_tile(vals_ref, idxs_ref, d2, tile_idx, k)


@functools.partial(
    jax.jit, static_argnames=("k", "block_m", "block_n", "interpret")
)
def topk_ed_pallas(
    q: jnp.ndarray,
    x: jnp.ndarray,
    k: int,
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query k smallest squared EDs over candidates, fused into the scan.

    q: (m, d), x: (n, d); m % block_m == 0, n % block_n == 0, 1 <= k <= n.
    Returns (d2 (m, k) f32 ascending, candidate rows (m, k) int32), ties
    broken toward the smaller candidate index. Slots beyond the number of
    candidates come back as (inf, INT32_MAX) — ops.py maps them to (inf, -1).
    """
    m, d = q.shape
    n, d2_ = x.shape
    assert d == d2_ and m % block_m == 0 and n % block_n == 0, (q.shape, x.shape)
    assert 1 <= k <= n, (k, n)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        functools.partial(_topk_ed_body, k=k, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((m, k), jnp.int32),
        ],
        interpret=interpret,
    )(q, x)


@functools.partial(
    jax.jit, static_argnames=("k", "block_m", "block_n", "interpret")
)
def screen_select_pallas(
    q: jnp.ndarray,
    x: jnp.ndarray,
    xn2: jnp.ndarray,
    k: int,
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused verification pass: f32 matmul-form screen + in-kernel top-k
    slate selection + the per-query |q|^2 certificate term, in ONE launch.

    q: (m, d), x: (n, d), xn2: (n,) precomputed candidate squared norms
    (the device arena's cache; pad rows carry a huge sentinel norm so they
    never enter a slate). m % block_m == 0, n % block_n == 0, 1 <= k <= n.
    Returns (d2 (m, k) f32 ascending, candidate rows (m, k) int32,
    |q|^2 (m,) f32). Tie/sentinel semantics match :func:`topk_ed_pallas`;
    the error-bound certificate is d2_true >= d2_screen - 2 * (4 n u
    |q| |x|_max), checked by the engine against the slate's worst entry.
    """
    m, d = q.shape
    n, d2_ = x.shape
    assert d == d2_ and m % block_m == 0 and n % block_n == 0, (q.shape, x.shape)
    assert xn2.shape == (n,), (xn2.shape, n)
    assert 1 <= k <= n, (k, n)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        functools.partial(_screen_select_body, k=k, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((m, k), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=interpret,
    )(q, x, xn2)


@functools.partial(
    jax.jit, static_argnames=("k", "block_m", "block_n", "interpret")
)
def screen_select_quant_pallas(
    q: jnp.ndarray,
    x: jnp.ndarray,
    scale: jnp.ndarray,
    xn2: jnp.ndarray,
    k: int,
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """:func:`screen_select_pallas` over an int8-quantized candidate table.

    q: (m, d) f32, x: (n, d) int8, scale: (n,) f32 per-row dequantization
    scales, xn2: (n,) f32 squared norms of the dequantized rows. Tiles
    upcast to f32 in-register; the scale lands on the contraction output,
    so the screen computes exactly ``|q|^2 + |s v|^2 - 2 s <q, v>`` — the
    f32 distance to the dequantized candidate. Shapes, tie semantics, and
    sentinel behavior match :func:`screen_select_pallas`."""
    m, d = q.shape
    n, d2_ = x.shape
    assert d == d2_ and m % block_m == 0 and n % block_n == 0, (q.shape, x.shape)
    assert scale.shape == (n,), (scale.shape, n)
    assert xn2.shape == (n,), (xn2.shape, n)
    assert 1 <= k <= n, (k, n)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        functools.partial(_screen_select_quant_body, k=k, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((m, k), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=interpret,
    )(q, x, scale, xn2)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def min_ed_pallas(
    q: jnp.ndarray,
    x: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """q: (m, d), x: (n, d); m % block_m == 0, n % block_n == 0.

    Returns (min_d2 (m,) f32, argmin (m,) int32)."""
    m, d = q.shape
    n, d2_ = x.shape
    assert d == d2_ and m % block_m == 0 and n % block_n == 0, (q.shape, x.shape)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        functools.partial(_ed_scan_body, block_n=block_n, n_blocks=n // block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
        ],
        interpret=interpret,
    )(q, x)
