"""Pallas kernel: blocked min squared-Euclidean-distance scan (MXU form).

The paper's "sequential scan of a contiguous leaf range" re-thought for the
TPU: instead of early-abandoned scalar loops (a disk/CPU idiom), distances
are computed in the matmul form  d2 = |q|^2 + |x|^2 - 2 q.x  on (bm x bn)
tiles streaming through VMEM, with a fused running min/argmin so the full
(m x n) distance matrix is never materialized in HBM.

Grid: (m/bm, n/bn) with the candidate axis iterating fastest; the output
tile (per-query running min + argmin) is revisited across the candidate
axis — the canonical Pallas accumulation pattern. Block shapes keep the
MXU-aligned contraction (d is zero-padded to a multiple of 128 by ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ed_scan_body(q_ref, x_ref, min_ref, arg_ref, *, block_n: int, n_blocks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        min_ref[...] = jnp.full_like(min_ref, jnp.inf)
        arg_ref[...] = jnp.zeros_like(arg_ref)

    q = q_ref[...].astype(jnp.float32)  # (bm, d)
    x = x_ref[...].astype(jnp.float32)  # (bn, d)
    # MXU contraction + VPU rank-1 corrections
    d2 = (
        jnp.sum(q * q, axis=-1, keepdims=True)  # (bm, 1)
        + jnp.sum(x * x, axis=-1)[None, :]  # (1, bn)
        - 2.0 * jax.lax.dot_general(
            q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
    )  # (bm, bn)
    blk_min = jnp.min(d2, axis=1)
    blk_arg = jnp.argmin(d2, axis=1).astype(jnp.int32) + j * block_n
    cur = min_ref[...]
    take = blk_min < cur
    min_ref[...] = jnp.where(take, blk_min, cur)
    arg_ref[...] = jnp.where(take, blk_arg, arg_ref[...])


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def min_ed_pallas(
    q: jnp.ndarray,
    x: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """q: (m, d), x: (n, d); m % block_m == 0, n % block_n == 0.

    Returns (min_d2 (m,) f32, argmin (m,) int32)."""
    m, d = q.shape
    n, d2_ = x.shape
    assert d == d2_ and m % block_m == 0 and n % block_n == 0, (q.shape, x.shape)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        functools.partial(_ed_scan_body, block_n=block_n, n_blocks=n // block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
            pl.BlockSpec((block_m,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
        ],
        interpret=interpret,
    )(q, x)
