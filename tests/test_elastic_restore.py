"""Elastic checkpoint restore: save under one mesh shape, restore under
another (scale up), continue training — values preserved exactly.

Subprocess-based (device count pins at first jax init).
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os, sys, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh
from repro.train import checkpoint as ckpt

ckdir = tempfile.mkdtemp()

# "cluster A": 4 devices (2x2 mesh), params sharded (data, model)
mesh_a = make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
w = jnp.arange(64 * 64, dtype=jnp.float32).reshape(64, 64)
w_a = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
ckpt.save(ckdir, 7, {"w": w_a})

# "cluster B": all 8 devices (8x1), different sharding
mesh_b = make_mesh((8,), ("data",))
sh_b = {"w": NamedSharding(mesh_b, P("data", None))}
like = {"w": jax.ShapeDtypeStruct((64, 64), jnp.float32)}
restored, _ = ckpt.restore(ckdir, 7, like, shardings=sh_b)
assert restored["w"].sharding.mesh.devices.size == 8
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
# restored array is usable in computation under the new mesh
out = jax.jit(lambda x: (x @ x.T).sum())(restored["w"])
assert np.isfinite(float(out))
print("ELASTIC_OK")
"""


@pytest.mark.slow
def test_elastic_restore_across_mesh_shapes():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ELASTIC_OK" in r.stdout
