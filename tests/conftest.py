import os
import sys

# src-layout import without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_walks(n, length, seed=0):
    r = np.random.default_rng(seed)
    return r.standard_normal((n, length)).astype(np.float32).cumsum(axis=1)
