"""Compiled-mode Pallas validation (the ``REPRO_PALLAS_COMPILED=1`` CI leg).

Everywhere else the suite runs the Pallas kernels in interpret mode (the
XLA twin serves the hot path off-TPU); this file is the one place that
launches them through the REAL Mosaic lowering pipeline, so TPU-breaking
kernel edits are caught by an opt-in leg instead of a TPU deploy. Off-TPU
the lowering itself is expected to be unavailable: each test skips
gracefully when compilation raises, and the leg is allowed-to-skip in CI.
"""
import os

import numpy as np
import pytest

import jax

pytestmark = pytest.mark.skipif(
    os.environ.get("REPRO_PALLAS_COMPILED") != "1",
    reason="compiled-mode Pallas validation runs only under "
           "REPRO_PALLAS_COMPILED=1")


def _compiled(fn, *args, **kw):
    """Run a kernel launch, skipping when the backend can't lower it."""
    try:
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        return out
    except Exception as e:  # noqa: BLE001 — lowering errors vary by backend
        if jax.default_backend() == "tpu":
            raise  # on real TPU hardware a failure is a kernel bug
        pytest.skip(f"Pallas compiled lowering unavailable off-TPU: "
                    f"{type(e).__name__}")


def test_interpret_flag_is_off():
    from repro.kernels import ops
    # the env var must actually flip the dispatch constant
    assert ops.INTERPRET is False or jax.default_backend() == "tpu"


def test_topk_ed_pallas_compiled_matches_oracle():
    from repro.kernels import ref
    from repro.kernels.ed_scan_kernel import topk_ed_pallas

    rng = np.random.default_rng(0)
    q = rng.standard_normal((8, 128)).astype(np.float32)
    x = rng.standard_normal((512, 128)).astype(np.float32)
    v, i = _compiled(topk_ed_pallas, q, x, 5, block_m=8, block_n=128,
                     interpret=False)
    rv, ri = ref.topk_ed_ref(q, x, 5)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))


def test_screen_select_pallas_compiled_matches_oracle():
    from repro.kernels import ref
    from repro.kernels.ed_scan_kernel import screen_select_pallas

    rng = np.random.default_rng(1)
    q = rng.standard_normal((8, 128)).astype(np.float32)
    x = rng.standard_normal((512, 128)).astype(np.float32)
    xn2 = np.einsum("nd,nd->n", x, x).astype(np.float32)
    v, i, qn2 = _compiled(screen_select_pallas, q, x, xn2, 7,
                          block_m=8, block_n=128, interpret=False)
    rv, ri, rqn2 = ref.screen_select_ref(q, x, xn2, 7)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(qn2), np.asarray(rqn2), rtol=1e-6)


def test_screen_select_quant_pallas_compiled_matches_oracle():
    from repro.kernels import ref
    from repro.kernels.ed_scan_kernel import screen_select_quant_pallas

    rng = np.random.default_rng(2)
    q = rng.standard_normal((8, 128)).astype(np.float32)
    xf = rng.standard_normal((512, 128)).astype(np.float32)
    amax = np.abs(xf).max(axis=1)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    x = np.clip(np.rint(xf / scale[:, None]), -127, 127).astype(np.int8)
    deq = x.astype(np.float64) * scale[:, None]
    xn2 = np.einsum("nd,nd->n", deq, deq).astype(np.float32)
    v, i, qn2 = _compiled(screen_select_quant_pallas, q, x, scale, xn2, 7,
                          block_m=8, block_n=128, interpret=False)
    rv, ri, _ = ref.screen_select_quant_ref(q, x, scale, xn2, 7)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv),
                               rtol=1e-5, atol=1e-3)
