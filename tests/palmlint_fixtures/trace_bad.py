"""Bad fixture: host side effects reachable from a jit root."""
import functools
import threading
import time

import jax
import numpy as np

_CALLS = [0]
_lock = threading.Lock()


@functools.partial(jax.jit, static_argnames=("k",))
def screen_pass(x, k, disk):
    _CALLS[0] += 1  # BAD: nonlocal Python state
    with _lock:  # BAD: lock under trace
        pass
    t0 = time.time()  # BAD: trace-time timestamp
    rng = np.random.default_rng(0)  # BAD: host RNG
    disk.read_seq(x.size * 4)  # BAD: DiskModel accounting
    return helper(x), t0, rng


def helper(x):
    time.sleep(0.01)  # BAD: reachable from the jit root via the call graph
    return x
