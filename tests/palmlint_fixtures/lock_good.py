"""Good fixture: every guarded-state write is under the lock, in a
constructor, or in a `_locked`-suffixed caller-holds-lock helper."""
import threading


class RunRegistry:
    def __init__(self):
        self._lock = threading.RLock()
        self.published = 0
        self.log = []

    def publish(self, snap):
        with self._lock:
            self.published += 1
            self.log.append(snap)
            self._install_locked(snap)

    def _install_locked(self, snap):
        self.current = snap  # caller holds the lock by convention

    def peek(self):
        return self.published  # reads are never flagged
