"""Good fixture: snapshots are replaced, never mutated; contents only
grow idempotent underscore lazy caches."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RunSet:
    epoch: int = 0
    levels: tuple = ()


def bump(snap: RunSet) -> RunSet:
    return dataclasses.replace(snap, epoch=snap.epoch + 1)


def widen(plan: "QueryPlan", extra):
    return [*plan.sources, extra]  # new list, plan untouched


def warm_caches(snap: RunSet):
    for run in snap.levels[0]:
        run._norms2 = None  # underscore lazy cache: sanctioned
        total = run.t_max - run.t_min  # reads are fine
    return total
