"""Good fixture: the traced function stays pure; impure host code is fine
as long as no traced root reaches it."""
import threading
import time

import jax
import jax.numpy as jnp

_lock = threading.Lock()


@jax.jit
def screen_pass(x, q):
    key = jax.random.PRNGKey(0)  # functional RNG is allowed
    noise = jax.random.normal(key, x.shape)
    return pure_helper(x + noise, q)


def pure_helper(x, q):
    return jnp.dot(x, q.T)


def host_driver(x):
    with _lock:  # fine: not reachable from any traced root
        t0 = time.time()
    return x, t0
