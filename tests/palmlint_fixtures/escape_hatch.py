"""Escape-hatch fixture: violations silenced by per-line annotations."""
import threading


class RunRegistry:
    def __init__(self):
        self._lock = threading.RLock()
        self.approx_published = 0

    def bump_estimate(self):
        # a deliberately racy statistics counter: off-by-a-few is fine
        self.approx_published += 1  # palmlint: ignore[lock-discipline]

    def bump_everything(self):
        self.approx_published += 1  # palmlint: ignore[*] — wildcard hatch
