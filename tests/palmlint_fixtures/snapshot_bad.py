"""Bad fixture: published snapshots / plans mutated after construction.
Includes the PR 3 PP hack shape: patching t_min/t_max on runs drawn out
of a pinned snapshot."""
import dataclasses


@dataclasses.dataclass
class RunSet:  # BAD: the catalog requires RunSet frozen=True
    epoch: int = 0
    levels: tuple = ()


def pp_window_hack(snap: RunSet, t0, t1):
    for run in snap.levels[0]:
        run.t_min = t0  # BAD: mutates contents of a pinned snapshot
        run.t_max = t1  # BAD: mutates contents of a pinned snapshot


def widen(plan: "QueryPlan", extra):
    plan.k = plan.k + extra  # BAD: attribute write on a plan
    plan.sources.append(extra)  # BAD: in-place mutation of a plan field


def bump(snap: RunSet):
    snap.epoch += 1  # BAD: attribute write on a snapshot
    object.__setattr__(snap, "epoch", 9)  # BAD: frozen bypass
