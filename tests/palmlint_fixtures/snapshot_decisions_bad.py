"""Bad fixture: the PR 10 decision-surface types (recommender verdicts,
autotuner records, gateway stats) mutated or declared unfrozen."""
import dataclasses


@dataclasses.dataclass
class TierDecision:  # BAD: catalog requires TierDecision frozen=True
    tier: str = "exact"
    n_blocks: int = 0


def tweak(rec: "Recommendation", dec: TierDecision):
    rec.materialized = True  # BAD: attribute write on a published verdict
    dec.n_blocks = 4  # BAD: attribute write on a tier decision


def relabel(entry: "RationaleEntry", d: "DecisionRecord"):
    entry.text = "edited"  # BAD: rationale entries are append-only history
    d.knobs = None  # BAD: decision records are immutable once traced


def inflate(st: "GatewayStats"):
    st.served += 1  # BAD: stats snapshots are point-in-time copies
