"""Bad fixture (lives under core/: the dtype rule is path-scoped)."""
import jax.numpy as jnp


def make_buffers(n):
    a = jnp.zeros((n, 4))  # BAD: dtype-less constructor in core/
    b = jnp.arange(n)  # BAD: dtype-less constructor in core/
    return a, b


def screen_pass(q, x):
    q64 = q.astype(jnp.float64)
    return q64 @ x.T  # BAD: f64 operand in a screen-side matmul


def rerank_slate(q, x):
    return jnp.einsum("md,nd->mn", q, x)  # BAD: no f64 cast on certify path
