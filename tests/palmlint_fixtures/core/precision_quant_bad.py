"""Bad fixture: quantized storage leaking into the exact side, and
dynamic dtypes inside quantization helpers."""
import jax.numpy as jnp
import numpy as np


def rerank_quantized(q, table):
    q64 = q.astype(np.float64)
    stored = table.astype(jnp.bfloat16)
    return jnp.einsum("md,nd->mn", q64, stored)  # BAD: bf16 into re-rank


def certify_int8_direct(q, x):
    q64 = q.astype(np.float64)
    return q64 @ x.astype(np.int8).T  # BAD: int8 operand, no f64 upcast


def quantize_rows(rows, dt):
    stored = rows.astype(dt)  # BAD: dynamic dtype in a quant helper
    return stored


def quantize_like(rows, ref):
    return rows.astype(ref.dtype)  # BAD: dtype inherited at runtime
