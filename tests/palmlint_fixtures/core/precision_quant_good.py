"""Good fixture: quantized values stay on the screen side (upcast to f32
in-register), the re-rank reads the f32 host mirror through explicit f64
casts, and every cast in the quantization helper spells its dtype."""
import jax.numpy as jnp
import numpy as np


def screen_quantized(q, table, scale):
    g = q @ table.astype(jnp.float32).T  # in-register upcast: f32 screen
    return g * scale[None, :]


def rerank_from_host(q, host):
    q64 = q.astype(np.float64)
    x64 = host.astype(np.float64)  # re-rank reads the f32 host mirror
    return jnp.einsum("md,nd->mn", q64, x64)


def quantize_rows(rows):
    scale = np.abs(rows).max(axis=1) / 127.0
    stored = np.clip(np.rint(rows / scale[:, None]), -127, 127).astype(np.int8)
    deq = stored.astype(np.float64) * scale[:, None]
    return stored, scale, deq
