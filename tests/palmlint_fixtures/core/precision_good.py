"""Good fixture: explicit dtypes, f32 screen, f64-cast re-rank."""
import jax.numpy as jnp
import numpy as np


def make_buffers(n):
    a = jnp.zeros((n, 4), dtype=jnp.float32)
    b = jnp.arange(n, dtype=jnp.int32)
    return a, b


def screen_pass(q, x):
    q32 = q.astype(jnp.float32)
    return q32 @ x.T  # f32 screen: the contract


def rerank_slate(q, x):
    q64 = q.astype(np.float64)
    x64 = x.astype(np.float64)
    return jnp.einsum("md,nd->mn", q64, x64)


def rerank_slate_kwarg(q, x):
    return np.einsum("md,nd->mn", q, x, dtype=np.float64)
