"""Good fixture: decision types declared frozen, replaced instead of
mutated — and containers OF protected types are ordinary variables (the
container is not itself the protected object)."""
import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class TierDecision:
    tier: str = "exact"
    n_blocks: int = 0


def retier(dec: TierDecision) -> TierDecision:
    return dataclasses.replace(dec, tier="approx", n_blocks=2)


def collect(entries: List["RationaleEntry"],
            arms: Dict["Knobs", float],
            pending: Optional["DecisionRecord"]):
    entries.append(None)  # a list OF entries may grow; entries may not
    arms.clear()  # dict keyed by Knobs is plain mutable state
    pending = None  # rebinding a local is never a mutation
    return entries, arms, pending


def read(st: "GatewayStats") -> int:
    return st.served + st.batches  # reads are always fine
