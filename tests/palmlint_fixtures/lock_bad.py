"""Bad fixture: guarded-class state mutated outside the instance lock."""
import threading


class RunRegistry:
    def __init__(self):
        self._lock = threading.RLock()
        self.published = 0  # constructor writes are exempt
        self.log = []

    def publish(self, snap):
        self.published += 1  # BAD: unlocked counter bump
        self.log.append(snap)  # BAD: unlocked container mutation
        with self._lock:
            self.current = snap  # fine: under the lock

    def tidy(self):
        del self.log[:]  # BAD: unlocked delete
