import numpy as np
import pytest

from repro.core import SummarizationConfig, breakpoints, paa, sax, sax_from_paa
from repro.core.summarization import sax_region, znormalize


def test_breakpoints_monotone_and_symmetric():
    for c in (2, 4, 6, 8):
        bp = breakpoints(c)
        assert bp.shape == ((1 << c) - 1,)
        assert (np.diff(bp) > 0).all()
        np.testing.assert_allclose(bp, -bp[::-1], atol=1e-5)


def test_breakpoints_match_normal_quantiles():
    # median breakpoint of card 2 is 0; quartiles of card 4 are +-0.6745
    np.testing.assert_allclose(breakpoints(1), [0.0], atol=1e-6)
    np.testing.assert_allclose(breakpoints(2), [-0.6745, 0.0, 0.6745], atol=1e-3)


def test_paa_means(rng):
    cfg = SummarizationConfig(series_len=64, n_segments=8, card_bits=4)
    x = rng.standard_normal((10, 64)).astype(np.float32)
    p = paa(x, cfg)
    np.testing.assert_allclose(p[:, 0], x[:, :8].mean(axis=1), rtol=1e-5)
    np.testing.assert_allclose(p[:, -1], x[:, -8:].mean(axis=1), rtol=1e-5)


def test_sax_symbols_in_range(rng):
    cfg = SummarizationConfig(series_len=64, n_segments=8, card_bits=6)
    x = rng.standard_normal((100, 64)).astype(np.float32) * 3
    s = sax(x, cfg)
    assert s.min() >= 0 and s.max() < 64


def test_sax_region_contains_paa(rng):
    cfg = SummarizationConfig(series_len=64, n_segments=8, card_bits=8)
    x = rng.standard_normal((50, 64)).astype(np.float32)
    p = np.asarray(paa(x, cfg))
    s = sax_from_paa(p, cfg)
    lo, hi = sax_region(s.astype(np.int64), cfg)
    assert (p >= lo - 1e-6).all() and (p <= hi + 1e-6).all()


def test_invalid_config_raises():
    with pytest.raises(ValueError):
        SummarizationConfig(series_len=100, n_segments=16)
    with pytest.raises(ValueError):
        SummarizationConfig(card_bits=9)


def test_znormalize(rng):
    x = rng.standard_normal((5, 128)).astype(np.float32) * 7 + 3
    z = znormalize(x)
    np.testing.assert_allclose(z.mean(axis=1), 0, atol=1e-4)
    np.testing.assert_allclose(z.std(axis=1), 1, atol=1e-3)
