"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import SummarizationConfig, interleave, sax_from_paa
from repro.core.summarization import paa as paa_np, sax_region
from repro.kernels import ops, ref


@pytest.mark.parametrize("b,n,w", [(64, 128, 16), (100, 256, 16), (8, 64, 8),
                                   (257, 96, 12)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_paa_kernel(b, n, w, dtype, rng):
    cfg = SummarizationConfig(series_len=n, n_segments=w, card_bits=8)
    x = rng.standard_normal((b, n)).astype(dtype)
    out = ops.paa(x, cfg)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(paa_np(x.astype(np.float32), cfg)), atol=1e-4
    )


@pytest.mark.parametrize("b,w,c", [(64, 16, 8), (100, 8, 4), (33, 12, 6), (8, 16, 2)])
def test_sax_pack_kernel(b, w, c, rng):
    cfg = SummarizationConfig(series_len=w * 4, n_segments=w, card_bits=c)
    p = rng.standard_normal((b, w)).astype(np.float32)
    sym, keys = ops.sax_and_keys(p, cfg)
    sym_np = sax_from_paa(p, cfg)
    np.testing.assert_array_equal(np.asarray(sym), sym_np)
    np.testing.assert_array_equal(
        np.asarray(keys), interleave(sym_np.astype(np.int32), cfg)
    )


@pytest.mark.parametrize("m,n,d", [(8, 512, 128), (7, 333, 64), (128, 1024, 256),
                                   (1, 100, 96)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_min_ed_kernel(m, n, d, dtype, rng):
    q = rng.standard_normal((m, d)).astype(dtype)
    x = rng.standard_normal((n, d)).astype(dtype)
    md, am = ops.min_ed(q, x, block_m=8, block_n=64)
    rd, ra = ref.min_ed_ref(jnp.asarray(q), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(md), np.asarray(rd), rtol=2e-4, atol=1e-3)
    # argmin may differ on near-ties; check the distances it picks
    d2 = ((x[np.asarray(am)] - q) ** 2).sum(-1)
    np.testing.assert_allclose(d2, np.asarray(rd), rtol=2e-4, atol=1e-3)


@pytest.mark.parametrize("k", [1, 5, 10])
@pytest.mark.parametrize("m,n,d", [(8, 512, 128), (7, 333, 64), (64, 1024, 128),
                                   (1, 100, 96), (3, 29, 160)])
def test_topk_ed_kernel_matches_ref_exactly(m, n, d, k, rng):
    """The running (bm, k) accumulator must reproduce the lexicographic
    (d2, index) reference bit-for-bit, including on odd shapes that exercise
    the ops.py padding (sentinel candidate rows, zero-padded contraction)."""
    q = rng.standard_normal((m, d)).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    v, i = ops.topk_ed(q, x, k, block_m=8, block_n=64)
    kk = min(k, n)
    rv, ri = ref.topk_ed_ref(jnp.asarray(q), jnp.asarray(x), kk)
    np.testing.assert_array_equal(np.asarray(i)[:, :kk], np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(v)[:, :kk], np.asarray(rv))
    # requested-but-unfillable slots are explicit (inf, -1) padding
    assert np.all(np.asarray(v)[:, kk:] == np.inf)
    assert np.all(np.asarray(i)[:, kk:] == -1)


def test_topk_ed_k1_agrees_with_min_ed(rng):
    q = rng.standard_normal((16, 64)).astype(np.float32)
    x = rng.standard_normal((256, 64)).astype(np.float32)
    v1, i1 = ops.topk_ed(q, x, 1, block_m=8, block_n=64)
    md, am = ops.min_ed(q, x, block_m=8, block_n=64)
    np.testing.assert_allclose(np.asarray(v1)[:, 0], np.asarray(md), rtol=2e-4, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(i1)[:, 0], np.asarray(am))


def test_topk_ed_ties_break_to_smaller_index(rng):
    q = rng.standard_normal((4, 64)).astype(np.float32)
    x = np.tile(rng.standard_normal((32, 64)).astype(np.float32), (2, 1))  # dup rows
    _, i = ops.topk_ed(q, x, 3, block_m=8, block_n=32)
    assert np.all(np.asarray(i)[:, 0] < 32)  # duplicate at j and j+32: j wins


@pytest.mark.parametrize("b,w", [(512, 16), (100, 8), (2048, 16)])
def test_mindist_kernel(b, w, rng):
    cfg = SummarizationConfig(series_len=w * 8, n_segments=w, card_bits=8)
    sym = rng.integers(0, 256, (b, w)).astype(np.int64)
    lo, hi = sax_region(sym, cfg)
    qp = rng.standard_normal(w).astype(np.float32)
    out = ops.mindist(qp, lo, hi, cfg, block_b=128)
    expect = ref.mindist_ref(jnp.asarray(qp), jnp.asarray(lo), jnp.asarray(hi),
                             cfg.segment_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-4)


def test_summarize_pipeline_matches_host(rng):
    cfg = SummarizationConfig(series_len=128, n_segments=16, card_bits=8)
    x = rng.standard_normal((120, 128)).astype(np.float32)
    p, sym, keys = ops.summarize(x, cfg)
    from repro.core import sax
    np.testing.assert_array_equal(np.asarray(sym), sax(x, cfg))


def test_ops_empty_batch_returns_empty(rng):
    """0-row batches must return cleanly shaped empty results instead of
    tripping the ``_pad_rows`` / ``min(block_b, max(8, 0))`` corner."""
    cfg = SummarizationConfig(series_len=64, n_segments=8, card_bits=6)
    out = ops.paa(np.zeros((0, 64), np.float32), cfg)
    assert out.shape == (0, 8)
    sym, keys = ops.sax_and_keys(np.zeros((0, 8), np.float32), cfg)
    assert sym.shape == (0, 8) and keys.shape == (0, cfg.key_words)
    assert sym.dtype == jnp.int32 and keys.dtype == jnp.uint32
    p, sym, keys = ops.summarize(np.zeros((0, 64), np.float32), cfg)
    assert p.shape == (0, 8) and sym.shape == (0, 8)
    out = ops.mindist(rng.standard_normal(8).astype(np.float32),
                      np.zeros((0, 8), np.float32),
                      np.zeros((0, 8), np.float32), cfg)
    assert out.shape == (0,)


def test_topk_ed_empty_queries_and_empty_candidates(rng):
    x = rng.standard_normal((32, 64)).astype(np.float32)
    q = rng.standard_normal((4, 64)).astype(np.float32)
    v, i = ops.topk_ed(np.zeros((0, 64), np.float32), x, 3)
    assert v.shape == (0, 3) and i.shape == (0, 3)
    v, i = ops.topk_ed(q, np.zeros((0, 64), np.float32), 3)
    assert np.all(np.asarray(v) == np.inf) and np.all(np.asarray(i) == -1)
    md, am = ops.min_ed(np.zeros((0, 64), np.float32), x)
    assert md.shape == (0,) and am.shape == (0,)
    md, am = ops.min_ed(q, np.zeros((0, 64), np.float32))
    assert np.all(np.asarray(md) == np.inf) and np.all(np.asarray(am) == -1)


@pytest.mark.parametrize("m,n", [(1, 3), (5, 1), (13, 67)])
def test_topk_ed_non_block_multiple_batches(m, n, rng):
    """Batch sizes far from block multiples (and below the min block) go
    through the same padding path and still match the oracle."""
    q = rng.standard_normal((m, 64)).astype(np.float32)
    x = rng.standard_normal((n, 64)).astype(np.float32)
    k = 4
    v, i = ops.topk_ed(q, x, k, block_m=8, block_n=64)
    kk = min(k, n)
    rv, ri = ref.topk_ed_ref(jnp.asarray(q), jnp.asarray(x), kk)
    np.testing.assert_array_equal(np.asarray(v)[:, :kk], np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(i)[:, :kk], np.asarray(ri))
    assert np.all(np.asarray(v)[:, kk:] == np.inf)
    assert np.all(np.asarray(i)[:, kk:] == -1)


def test_min_ed_kernel_argmin_is_exact_on_separated_data(rng):
    q = rng.standard_normal((4, 64)).astype(np.float32)
    x = rng.standard_normal((256, 64)).astype(np.float32) + 10.0
    x[17] = q[0]; x[42] = q[1]; x[200] = q[2]; x[3] = q[3]
    md, am = ops.min_ed(q, x, block_m=8, block_n=64)
    np.testing.assert_array_equal(np.asarray(am), [17, 42, 200, 3])
    np.testing.assert_allclose(np.asarray(md), 0.0, atol=1e-3)


# ---------------------------------------------------------------------------
# the fused screen+select kernel (the verification engine's device pass)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k", [1, 5, 18])
@pytest.mark.parametrize("m,n,d", [(8, 512, 128), (7, 333, 64), (1, 100, 96),
                                   (16, 64, 128)])
def test_screen_select_matches_ref(m, n, d, k, rng):
    """One fused launch == matmul-form screen with PRECOMPUTED norms +
    lexicographic top-k + the per-query |q|^2 certificate term."""
    q = rng.standard_normal((m, d)).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    xn2 = np.einsum("nd,nd->n", x, x).astype(np.float32)
    v, i, qn2 = ops.screen_select(q, x, xn2, k, block_m=8, block_n=64)
    kk = min(k, n)
    rv, ri, rqn2 = ref.screen_select_ref(
        jnp.asarray(q), jnp.asarray(x), jnp.asarray(xn2), kk)
    np.testing.assert_array_equal(np.asarray(i)[:, :kk], np.asarray(ri))
    np.testing.assert_allclose(np.asarray(v)[:, :kk], np.asarray(rv),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(qn2), np.asarray(rqn2), rtol=1e-6)
    # requested-but-unfillable slots are explicit (inf, -1) padding
    assert np.all(np.asarray(v)[:, kk:] == np.inf)
    assert np.all(np.asarray(i)[:, kk:] == -1)


def test_screen_select_sentinel_norm_keeps_pads_out(rng):
    """Candidate pads carry a BIG_NORM2 sentinel in the norms input (the
    rows themselves are zeros): they must never displace a real candidate
    and must not overflow the f32 screen arithmetic."""
    q = rng.standard_normal((4, 64)).astype(np.float32)
    x = rng.standard_normal((70, 64)).astype(np.float32)  # pads to 128 rows
    xn2 = np.einsum("nd,nd->n", x, x).astype(np.float32)
    v, i, _ = ops.screen_select(q, x, xn2, 70, block_m=8, block_n=64)
    assert np.isfinite(np.asarray(v)).all()
    assert (np.asarray(i) >= 0).all() and (np.asarray(i) < 70).all()


@pytest.mark.parametrize("k", [1, 5, 18])
@pytest.mark.parametrize("m,n,d", [(8, 512, 128), (7, 333, 64), (1, 100, 96),
                                   (16, 64, 128)])
def test_screen_select_quant_matches_ref(m, n, d, k, rng):
    """The int8 fused screen (per-row scales applied AFTER the contraction)
    must reproduce its lexicographic oracle bit-for-bit, including on odd
    shapes that exercise the scale/norm padding (fill 1.0 / sentinel)."""
    q = rng.standard_normal((m, d)).astype(np.float32)
    xf = rng.standard_normal((n, d)).astype(np.float32)
    amax = np.abs(xf).max(axis=1)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    x = np.clip(np.rint(xf / scale[:, None]), -127, 127).astype(np.int8)
    deq = x.astype(np.float64) * scale[:, None]
    xn2 = np.einsum("nd,nd->n", deq, deq).astype(np.float32)
    v, i, qn2 = ops.screen_select_quant(q, x, scale, xn2, k,
                                        block_m=8, block_n=64)
    kk = min(k, n)
    rv, ri, rqn2 = ref.screen_select_quant_ref(
        jnp.asarray(q), jnp.asarray(x), jnp.asarray(scale),
        jnp.asarray(xn2), kk)
    np.testing.assert_array_equal(np.asarray(i)[:, :kk], np.asarray(ri))
    np.testing.assert_allclose(np.asarray(v)[:, :kk], np.asarray(rv),
                               rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(np.asarray(qn2), np.asarray(rqn2), rtol=1e-6)
    assert np.all(np.asarray(v)[:, kk:] == np.inf)
    assert np.all(np.asarray(i)[:, kk:] == -1)


def test_screen_select_bf16_candidates_match_f32_of_dequantized(rng):
    """bf16 candidate tables ride through ``screen_select`` in their storage
    dtype (half the HBM traffic) with an in-register f32 upcast: the result
    must equal an f32 launch over the DEQUANTIZED values exactly."""
    q = rng.standard_normal((8, 64)).astype(np.float32)
    xb = jnp.asarray(rng.standard_normal((200, 64)).astype(np.float32)
                     ).astype(jnp.bfloat16)
    x32 = np.asarray(xb.astype(jnp.float32))
    xn2 = np.einsum("nd,nd->n", x32, x32).astype(np.float32)
    vb, ib, _ = ops.screen_select(q, xb, xn2, 7, block_m=8, block_n=64)
    v32, i32, _ = ops.screen_select(q, x32, xn2, 7, block_m=8, block_n=64)
    np.testing.assert_array_equal(np.asarray(ib), np.asarray(i32))
    np.testing.assert_array_equal(np.asarray(vb), np.asarray(v32))


def test_screen_select_quant_all_zero_rows_use_unit_scale(rng):
    """All-zero candidates quantize to scale 1.0 / zero codes: they must
    surface with plain |q|^2 distances, not NaN/overflow."""
    q = rng.standard_normal((4, 64)).astype(np.float32)
    x = np.zeros((70, 64), np.int8)
    scale = np.ones(70, np.float32)
    xn2 = np.zeros(70, np.float32)
    v, i, qn2 = ops.screen_select_quant(q, x, scale, xn2, 3,
                                        block_m=8, block_n=64)
    np.testing.assert_allclose(np.asarray(v),
                               np.asarray(qn2)[:, None].repeat(3, 1),
                               rtol=1e-6)
    assert (np.asarray(i) >= 0).all() and (np.asarray(i) < 70).all()


# ---------------------------------------------------------------------------
# bucketed launcher boundaries (the e == bucket fast path)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("e", [63, 64, 65, 127, 128])
def test_topk_ed_bucketed_boundaries_match_ref(e, rng):
    """Across the bucket boundary (64 = min bucket, 128 = next) — including
    the exactly-bucket-sized tables that take the no-copy fast path — the
    launcher must be indistinguishable from an unpadded launch."""
    q = rng.standard_normal((5, 32)).astype(np.float32)
    x = rng.standard_normal((e, 32)).astype(np.float32)
    k = 7
    v, i = ops.topk_ed_bucketed(q, x, k)
    kk = min(k, e)
    rv, ri = ref.topk_ed_ref(jnp.asarray(q), jnp.asarray(x), kk)
    np.testing.assert_array_equal(i[:, :kk], np.asarray(ri))
    np.testing.assert_allclose(v[:, :kk], np.asarray(rv), rtol=1e-5, atol=1e-3)
    from repro.kernels.ops import candidate_bucket

    assert candidate_bucket(64) == 64  # the fast-path boundary itself
    assert candidate_bucket(63) == 64 and candidate_bucket(65) == 128


def test_topk_ed_bucketed_empty_candidates():
    q = np.zeros((3, 32), np.float32)
    v, i = ops.topk_ed_bucketed(q, np.zeros((0, 32), np.float32), 4)
    assert v.shape == (3, 4) and (i == -1).all() and np.isinf(v).all()
