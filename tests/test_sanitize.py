"""Runtime sanitizer tests: ranked locks, snapshot seals, and a small
end-to-end run proving the real pipeline is sanitizer-clean."""
import threading

import numpy as np
import pytest

from repro.analysis import sanitize
from repro.core import (
    RunRegistry, SortedRun, StreamConfig, StreamingIndex,
    SummarizationConfig,
)
from repro.core.verify_engine import get_engine


@pytest.fixture
def sanitizer():
    sanitize.install()
    try:
        yield
    finally:
        sanitize.uninstall()


def scfg(n=32):
    return SummarizationConfig(series_len=n, n_segments=4, card_bits=4)


# ------------------------------------------------------------ ranked locks
def test_legal_lock_order_registry_then_engine(sanitizer):
    reg = RunRegistry()
    eng = get_engine()
    with reg._lock:
        with eng._lock:  # pin-epilogue shape: reap -> release_view
            pass
    # both fully released: the held stack is empty again
    assert reg._lock.owner is None and eng._lock.owner is None


def test_lock_order_inversion_raises(sanitizer):
    reg = RunRegistry()
    eng = get_engine()
    with pytest.raises(sanitize.SanitizerError, match="inversion"):
        with eng._lock:
            with reg._lock:
                pass
    assert eng._lock.owner is None  # the with-block unwound cleanly


def test_ranked_lock_is_reentrant_and_tracks_owner(sanitizer):
    reg = RunRegistry()
    with reg._lock:
        assert reg._lock.owner == threading.current_thread().name
        with reg._lock:  # RLock semantics preserved
            pass
        assert reg._lock.owner == threading.current_thread().name
    assert reg._lock.owner is None


def test_inversion_names_both_locks(sanitizer):
    reg = RunRegistry()
    eng = get_engine()
    try:
        with eng._lock:
            with reg._lock:
                pass
        raise AssertionError("inversion not caught")
    except sanitize.SanitizerError as e:
        msg = str(e)
        assert "RunRegistry._lock" in msg and "VerifyEngine._lock" in msg


# ---------------------------------------------------------- snapshot seals
def test_sorted_run_seal_trips_on_public_attr(sanitizer, rng):
    run, _ = SortedRun.build(
        rng.standard_normal((64, 32)).astype(np.float32), np.arange(64),
        scfg())
    with pytest.raises(sanitize.SanitizerError, match="sealed SortedRun"):
        run.t_min = 0
    with pytest.raises(sanitize.SanitizerError, match="sealed SortedRun"):
        run.block_size = 1


def test_sorted_run_underscore_lazy_caches_stay_writable(sanitizer, rng):
    run, _ = SortedRun.build(
        rng.standard_normal((64, 32)).astype(np.float32), np.arange(64),
        scfg(), materialized=True)
    n2 = run.entry_norms2()  # sets run._norms2 through the seal
    assert n2.shape == (64,)


def test_runset_mutation_rebranded(sanitizer):
    reg = RunRegistry()
    snap = reg.current()
    with pytest.raises(sanitize.SanitizerError, match="immutable"):
        snap.epoch = 99


def test_registry_publish_path_clean_under_seal(sanitizer, rng):
    """The real mutation path (replace-and-swap) must NOT trip the seal —
    only in-place patching does."""
    from repro.core import BufferChunk

    reg = RunRegistry()
    chunk = BufferChunk(rng.standard_normal((8, 32)).astype(np.float32),
                        np.arange(8))
    snap = reg.append_buffer(chunk)
    assert snap.epoch == 1 and snap.buffer_n == 8


def test_uninstall_restores_classes(rng):
    sanitize.install()
    sanitize.uninstall()
    run, _ = SortedRun.build(
        rng.standard_normal((16, 32)).astype(np.float32), np.arange(16),
        scfg())
    run.t_min = -1  # plain dataclass again: no seal
    assert not sanitize.installed()


# ------------------------------------------------------------- end to end
def test_streaming_index_end_to_end_under_sanitizer(sanitizer, rng):
    """Ingest + serve + drain with seals and ranked locks armed: the
    pipeline itself must be invariant-clean."""
    idx = StreamingIndex(StreamConfig(
        scheme="BTP", summarization=scfg(), buffer_entries=64,
        growth_factor=4, block_size=32))
    for b in range(4):
        x = rng.standard_normal((48, 32)).astype(np.float32)
        idx.ingest(x, np.full(48, b, np.int64))
        if b:
            qs = rng.standard_normal((4, 32)).astype(np.float32)
            d2, ids, _ = idx.window_knn_batch(qs, 0, b, k=3)
            assert ids.shape == (4, 3)
