"""Per-arch smoke tests (reduced same-family configs): forward shapes, no
NaNs, one train step, decode-vs-full-forward equivalence."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models.steps import TrainConfig, loss_fn, make_train_step
from repro.models.transformer import (
    decode_step,
    forward,
    init_params,
    logits_fn,
    prefill,
)
from repro.train.optimizer import AdamW, AdamWConfig


def _batch(cfg, B, S, rng):
    if cfg.frontend == "audio":
        return {
            "features": jnp.asarray(rng.standard_normal((B, S, cfg.d_frontend)), jnp.float32),
            "targets": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
            "mask": jnp.ones((B, S), bool),
        }
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.frontend == "vision":
        return {
            "tokens": toks,
            "patches": jnp.asarray(
                rng.standard_normal((B, cfg.n_vis_tokens, cfg.d_frontend)), jnp.float32
            ),
        }
    return {"tokens": toks}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S, rng)
    h, lb, _ = forward(params, cfg, batch)
    exp_s = S + (cfg.n_vis_tokens if cfg.frontend == "vision" else 0)
    assert h.shape == (B, exp_s, cfg.d_model)
    assert not bool(jnp.isnan(h.astype(jnp.float32)).any())

    opt = AdamW(AdamWConfig(learning_rate=1e-3, warmup_steps=1))
    tcfg = TrainConfig(grad_accum=2, remat=True)
    step = make_train_step(cfg, tcfg, opt)
    ostate = opt.init(params)
    p2, o2, metrics = jax.jit(step)(params, ostate, batch, jnp.int32(1))
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = sum(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if not get_config(a, smoke=True).encoder_only])
def test_decode_matches_full_forward(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S, P = 2, 24, 16
    toks = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    batch = _batch(cfg, B, S, rng)
    if "tokens" in batch:
        batch["tokens"] = jnp.asarray(toks)
    h, _, _ = forward(params, cfg, batch)
    off = cfg.n_vis_tokens if cfg.frontend == "vision" else 0
    pre = dict(batch)
    pre["tokens"] = jnp.asarray(toks[:, :P])
    lg, cache = prefill(params, cfg, pre)
    errs = [float(jnp.max(jnp.abs(lg - logits_fn(params, cfg, h[:, off + P - 1]))))]
    for t in range(P, P + 3):
        lg, cache = decode_step(params, cfg, cache, jnp.asarray(toks[:, t : t + 1]))
        errs.append(float(jnp.max(jnp.abs(lg - logits_fn(params, cfg, h[:, off + t])))))
    assert max(errs) < 0.35, errs


def test_moe_aux_losses_present(rng):
    cfg = get_config("deepseek-moe-16b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 32, rng)
    loss, aux = loss_fn(params, cfg, batch)
    assert float(aux["lb"]) > 0.0  # load-balance aux wired through the scan


def test_vocab_padding_masked(rng):
    cfg = get_config("hubert-xlarge", smoke=True)  # vocab 56 -> padded 128
    assert cfg.vocab_padded > cfg.vocab
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 1, 16, rng)
    h, _, _ = forward(params, cfg, batch)
    lg = logits_fn(params, cfg, h)
    assert float(lg[..., cfg.vocab :].max()) < -1e8  # padded ids unreachable
