"""Mixed-precision screen tier: bf16/int8 device arenas must stay bitwise
device==numpy on every index x tier (the widened certificate + f64 re-rank
from the f32 host mirror absorb the quantization error), the bucket ladder
and in-place extends must work under quantized dtypes (existing int8 scales
never rewritten), and the engine's footprint accounting must show the
promised compression."""
import numpy as np
import pytest

from repro.core import (
    ADSConfig,
    ADSIndex,
    CLSM,
    CLSMConfig,
    CTree,
    CTreeConfig,
    RawStore,
    StreamConfig,
    StreamingIndex,
    SummarizationConfig,
    ed2,
)
from repro.core.verify_engine import (
    _bucket_batch, _bucket_rows, _quantize_rows, get_engine,
    resolve_screen_dtype,
)

CFG = SummarizationConfig(series_len=64, n_segments=8, card_bits=6)
QDTYPES = ("bf16", "int8")


def _data(n=6000, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, 64)).astype(np.float32).cumsum(axis=1)


def _queries(m=32, seed=99):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((m, 64)).astype(np.float32).cumsum(axis=1)


def _adversarial(n, seed=0, offset=3000.0, spread=0.01):
    rng = np.random.default_rng(seed)
    return (offset + spread * rng.standard_normal((n, 64))).astype(np.float32)


# ---------------------------------------------------------------------------
# dtype selector resolution
# ---------------------------------------------------------------------------
def test_resolve_screen_dtype_aliases_and_env(monkeypatch):
    monkeypatch.delenv("REPRO_SCREEN_DTYPE", raising=False)
    assert resolve_screen_dtype(None) == "f32"
    assert resolve_screen_dtype("auto") == "f32"
    for alias in ("f32", "float32", "fp32"):
        assert resolve_screen_dtype(alias) == "f32"
    for alias in ("bf16", "bfloat16", "BF16"):
        assert resolve_screen_dtype(alias) == "bf16"
    for alias in ("int8", "i8"):
        assert resolve_screen_dtype(alias) == "int8"
    monkeypatch.setenv("REPRO_SCREEN_DTYPE", "int8")
    assert resolve_screen_dtype(None) == "int8"
    assert resolve_screen_dtype("auto") == "int8"
    assert resolve_screen_dtype("bf16") == "bf16"  # explicit beats env
    with pytest.raises(ValueError, match="screen dtype"):
        resolve_screen_dtype("fp8")


def test_quantize_rows_contract():
    rng = np.random.default_rng(3)
    rows = rng.standard_normal((100, 64)).astype(np.float32)
    stored, scale, xn2, qerr = _quantize_rows(rows, "f32")
    assert stored is rows and scale is None and qerr == 0.0
    stored, scale, xn2, qerr = _quantize_rows(rows, "int8")
    assert stored.dtype == np.int8 and scale.dtype == np.float32
    assert np.abs(stored).max() <= 127
    deq = stored.astype(np.float64) * scale[:, None].astype(np.float64)
    # xn2 is the norms of what the device actually holds, not the originals
    np.testing.assert_allclose(xn2, np.einsum("nd,nd->n", deq, deq),
                               rtol=1e-6)
    err = np.sqrt(((deq - rows) ** 2).sum(axis=1)).max()
    assert qerr == pytest.approx(err) and qerr > 0.0
    # all-zero rows: scale pins to 1.0 instead of dividing by zero
    z, zs, zn2, zq = _quantize_rows(np.zeros((3, 64), np.float32), "int8")
    assert (z == 0).all() and (zs == 1.0).all() and zq == 0.0


# ---------------------------------------------------------------------------
# satellite: bucket-ladder boundaries, directly
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,want", [
    (1, 64), (63, 64), (64, 64), (65, 96),       # the min bucket edge
    (95, 96), (96, 96), (97, 128),               # mid-rung (3*2^(k-1)) edge
    (127, 128), (128, 128), (129, 192),          # power-of-two edge
    (3000, 3072), (3072, 3072), (3073, 4096),    # the arena-test sizes
])
def test_bucket_rows_ladder_boundaries(n, want):
    assert _bucket_rows(n) == want


@pytest.mark.parametrize("m,want", [
    (1, 8), (7, 8), (8, 8), (9, 16), (16, 16), (17, 32), (64, 64),
])
def test_bucket_batch_boundaries(m, want):
    assert _bucket_batch(m) == want


# ---------------------------------------------------------------------------
# device == numpy, bitwise, under quantized storage, every index x tier
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", QDTYPES)
@pytest.mark.parametrize("mat", [True, False])
def test_ctree_quantized_device_matches_numpy_bitwise(mat, dtype):
    X, Q = _data(), _queries()
    raw = RawStore(64, screen_dtype=dtype)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=512,
                           materialized=mat, screen_dtype=dtype))
    ct.bulk_build(X, raw.append(X))
    calls0 = get_engine().stats["calls"]
    vd, gd, sd = ct.knn_batch(Q, k=10, raw=raw)
    vn, gn, sn = ct.knn_batch(Q, k=10, raw=raw, backend="numpy")
    np.testing.assert_array_equal(vd, vn)
    np.testing.assert_array_equal(gd, gn)
    assert (sd.entries_verified, sd.blocks_visited) == (
        sn.entries_verified, sn.blocks_visited)
    assert get_engine().stats["calls"] > calls0
    va, ga, _ = ct.knn_approx_batch(Q, k=10, n_blocks=3, raw=raw)
    vb, gb, _ = ct.knn_approx_batch(Q, k=10, n_blocks=3, raw=raw,
                                    backend="numpy")
    np.testing.assert_array_equal(va, vb)
    np.testing.assert_array_equal(ga, gb)


@pytest.mark.parametrize("dtype", QDTYPES)
def test_clsm_quantized_device_matches_numpy_bitwise(dtype):
    X, Q = _data(5000, seed=3), _queries(24, seed=7)
    raw = RawStore(64, screen_dtype=dtype)
    lsm = CLSM(CLSMConfig(summarization=CFG, buffer_entries=1024,
                          growth_factor=3, block_size=256, materialized=True,
                          screen_dtype=dtype))
    lsm.insert(X, raw.append(X), np.arange(len(X), dtype=np.int64))
    vd, gd, _ = lsm.knn_batch(Q, k=7, raw=raw)
    vn, gn, _ = lsm.knn_batch(Q, k=7, raw=raw, backend="numpy")
    np.testing.assert_array_equal(vd, vn)
    np.testing.assert_array_equal(gd, gn)


@pytest.mark.parametrize("dtype", QDTYPES)
@pytest.mark.parametrize("mode", ["full", "adaptive"])
def test_ads_quantized_device_matches_numpy_bitwise(mode, dtype):
    X, Q = _data(4000, seed=4), _queries(16, seed=9)
    raw = RawStore(64, screen_dtype=dtype)
    ids = raw.append(X)

    def build():
        ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=2048,
                                 mode=mode, query_leaf_size=256,
                                 screen_dtype=dtype))
        ads.insert_batch(X, ids)
        return ads

    vd, gd, _ = build().knn_batch(Q, k=5, raw=raw)
    vn, gn, _ = build().knn_batch(Q, k=5, raw=raw, backend="numpy")
    np.testing.assert_array_equal(vd, vn)
    np.testing.assert_array_equal(gd, gn)


@pytest.mark.parametrize("dtype", QDTYPES)
def test_streaming_quantized_device_matches_numpy_bitwise(dtype):
    rng = np.random.default_rng(11)
    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                      buffer_entries=1024, growth_factor=3,
                                      block_size=256, materialized=False,
                                      screen_dtype=dtype))
    assert idx.raw.screen_dtype == dtype  # config reached the raw arena
    for b in range(8):
        x = rng.standard_normal((600, 64)).astype(np.float32).cumsum(axis=1)
        idx.ingest(x, np.full(600, b, np.int64))
    Q = _queries(16, seed=13)
    vd, gd, _ = idx.window_knn_batch(Q, 2, 6, k=4)
    vn, gn, _ = idx.window_knn_batch(Q, 2, 6, k=4, backend="numpy")
    np.testing.assert_array_equal(vd, vn)
    np.testing.assert_array_equal(gd, gn)


# ---------------------------------------------------------------------------
# the widened certificate: ill-conditioned data forces the host fallback,
# and the answers are STILL exact
# ---------------------------------------------------------------------------
def _build_ctree(X, dtype):
    raw = RawStore(64, screen_dtype=dtype)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=512,
                           materialized=True, screen_dtype=dtype))
    ct.bulk_build(X, raw.append(X))
    return ct, raw


def _assert_fallback_and_exact(X, Q, dtype, k=5):
    ct, raw = _build_ctree(X, dtype)
    eng = get_engine()
    fb0 = eng.stats["fallbacks"]
    vals, gids, _ = ct.knn_batch(Q, k=k, raw=raw)
    # the screen cannot be certified here: the engine must take the
    # provably exact host path instead of returning silently wrong ids
    assert eng.stats["fallbacks"] > fb0
    vn, gn, _ = ct.knn_batch(Q, k=k, raw=raw, backend="numpy")
    np.testing.assert_array_equal(vals, vn)
    np.testing.assert_array_equal(gids, gn)
    X64 = X.astype(np.float64)
    for i in range(len(Q)):
        bf = ed2(Q[i].astype(np.float64), X64)
        np.testing.assert_allclose(vals[i], np.sort(bf)[:k], rtol=1e-5)


def test_int8_widened_term_fires_where_f32_certifies():
    """On the PR 3 cancellation set the f32 eps term certifies every query,
    but int8's quantization error dwarfs the tiny true distances — the
    WIDENED term (2(|q|+|x|)qerr) is what forces the fallback."""
    X = _adversarial(4000)
    rng = np.random.default_rng(1)
    Q = np.stack([X[i] + 0.001 * rng.standard_normal(64).astype(np.float32)
                  for i in range(16)])
    # control: the same data under f32 storage certifies (no new fallbacks)
    ct, raw = _build_ctree(X, "f32")
    eng = get_engine()
    fb0 = eng.stats["fallbacks"]
    ct.knn_batch(Q, k=5, raw=raw)
    assert eng.stats["fallbacks"] == fb0
    _assert_fallback_and_exact(X, Q, "int8")


@pytest.mark.parametrize("dtype", QDTYPES)
def test_near_duplicate_families_defeat_the_certificate(dtype):
    """Near-duplicate families wider than the slate (16 copies > k + slack)
    put sub-quantization-error gaps at the slack boundary: no storage dtype
    can certify, and the host fallback still answers bitwise-exactly (the
    1e-6 jitter keeps the f64 order unique, so tie-breaking is well
    defined)."""
    rng0 = np.random.default_rng(2)
    base = _adversarial(250, seed=2)
    X = (np.tile(base, (16, 1))
         + 1e-6 * rng0.standard_normal((4000, 64))).astype(np.float32)
    rng = np.random.default_rng(1)
    Q = np.stack([X[i] + 0.001 * rng.standard_normal(64).astype(np.float32)
                  for i in range(16)])
    _assert_fallback_and_exact(X, Q, dtype)


# ---------------------------------------------------------------------------
# arena lifecycle under quantized dtypes: in-place extend across the chunk
# ladder, scale-prefix reuse, rebuild past capacity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", QDTYPES)
def test_quantized_arena_extends_in_place_and_rebuilds(dtype):
    X = _data(3000, seed=8)
    raw = RawStore(64, screen_dtype=dtype)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=512,
                           materialized=False, screen_dtype=dtype))
    ct.bulk_build(X, raw.append(X))
    Q = _queries(16, seed=3)
    eng = get_engine()
    ct.knn_batch(Q, k=5, raw=raw)
    up0 = eng.stats["uploads"]
    view0 = raw.device_view()
    assert view0.dtype == dtype and view0.qerr > 0.0
    assert view0.nbytes > 0
    scale0 = None if view0.scale is None else np.asarray(view0.scale)
    # growth that fits the bucketed capacity: in-place donated update
    raw.append(_data(48, seed=12))
    view1 = raw.device_view()
    assert view1.n == 3048 and view1.cap == view0.cap
    assert eng.stats["uploads"] == up0 + 1
    assert view1.qerr >= view0.qerr  # the error bound only widens
    if dtype == "int8":
        # existing rows' scales are never rewritten by an extend
        np.testing.assert_array_equal(np.asarray(view1.scale)[:3000],
                                      scale0[:3000])
    # growth past capacity: rebuild at the next ladder rung
    raw.append(_data(500, seed=14))
    view2 = raw.device_view()
    assert view2.n == 3548 and view2.cap > view0.cap
    assert view2.dtype == dtype  # the rebuild keeps the storage dtype
    # the original index still answers exactly over its 3000 entries
    q = Q[0]
    res, _ = ct.knn_exact(q, k=3, raw=raw)
    bf = np.sort(ed2(q, X))[:3]
    np.testing.assert_allclose([d for d, _ in res], bf, rtol=1e-5)


# ---------------------------------------------------------------------------
# footprint accounting: the stats must show the promised compression
# ---------------------------------------------------------------------------
def test_arena_bytes_accounting_and_compression_ratios():
    eng = get_engine()
    assert "arena_dtype" in eng.stats  # engine default is visible
    X = _data(2000, seed=21)
    views = {}
    for dt in ("f32", "bf16", "int8"):
        b0 = eng.stats["arena_bytes"]
        h0 = eng.stats["h2d_bytes"]
        v = eng.build_view(X, dtype=dt)
        assert v.dtype == dt
        assert eng.stats["arena_bytes"] - b0 == v.nbytes
        assert eng.stats["h2d_bytes"] - h0 == v.nbytes  # upload == footprint
        views[dt] = v
    # same table, same ladder capacity: the ratios are pure dtype wins
    assert views["f32"].nbytes / views["bf16"].nbytes >= 1.9
    assert views["f32"].nbytes / views["int8"].nbytes >= 3.5
    for v in views.values():
        b0 = eng.stats["arena_bytes"]
        eng.release_view(v)
        assert b0 - eng.stats["arena_bytes"] == v.nbytes


# ---------------------------------------------------------------------------
# persistence: screen_dtype survives the file backend's meta roundtrip
# ---------------------------------------------------------------------------
def test_screen_dtype_survives_file_backend_recovery(tmp_path):
    cfg = StreamConfig(scheme="BTP", summarization=CFG, buffer_entries=64,
                       growth_factor=2, block_size=32, storage="file",
                       storage_dir=str(tmp_path), screen_dtype="bf16")
    idx = StreamingIndex(cfg)
    assert idx.raw.screen_dtype == "bf16"  # FileStore raw inherits the cfg
    rng = np.random.default_rng(5)
    for b in range(4):  # enough to flush published runs
        x = rng.standard_normal((64, 64)).astype(np.float32).cumsum(axis=1)
        idx.ingest(x, np.arange(b * 64, (b + 1) * 64, dtype=np.int64))
    runs = list(idx.lsm.registry.current().runs_newest_first())
    assert runs and all(r.screen_dtype == "bf16" for r in runs)
    idx.close()
    rec = StreamingIndex.recover(
        StreamConfig(scheme="BTP", summarization=CFG, buffer_entries=64,
                     growth_factor=2, block_size=32, storage="file",
                     screen_dtype="bf16"), str(tmp_path))
    rruns = list(rec.lsm.registry.current().runs_newest_first())
    assert rruns and all(r.screen_dtype == "bf16" for r in rruns)
    rec.close()
