"""Optimizer, grad compression, checkpointing, data pipeline, fault tolerance."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.configs import get_config
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamW, AdamWConfig


def _quadratic_losses(compression, steps=60):
    opt = AdamW(AdamWConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=steps, compression=compression))
    target = jnp.asarray(np.random.default_rng(0).standard_normal(32), jnp.float32)
    params = {"w": jnp.zeros(32, jnp.float32)}
    state = opt.init(params)
    losses = []
    for s in range(steps):
        g = {"w": 2 * (params["w"] - target)}
        losses.append(float(jnp.sum((params["w"] - target) ** 2)))
        params, state, _ = opt.update(params, g, state, jnp.int32(s))
    return losses


@pytest.mark.parametrize("compression", [None, "int8", "topk"])
def test_adamw_converges_with_and_without_compression(compression):
    losses = _quadratic_losses(compression)
    assert losses[-1] < 0.05 * losses[0]


def test_grad_clip_bounds_update():
    opt = AdamW(AdamWConfig(learning_rate=1.0, grad_clip=1e-3, warmup_steps=1))
    params = {"w": jnp.zeros(4, jnp.float32)}
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e9, jnp.float32)}
    _, _, gnorm = opt.update(params, huge, state, jnp.int32(0))
    assert float(gnorm) > 1e8  # norm reported pre-clip


def test_schedule_warmup_and_decay():
    opt = AdamW(AdamWConfig(learning_rate=1.0, warmup_steps=10, total_steps=100))
    assert float(opt.schedule(jnp.int32(0))) == 0.0
    assert float(opt.schedule(jnp.int32(10))) == pytest.approx(1.0)
    assert float(opt.schedule(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones(4, jnp.bfloat16), {"c": jnp.int32(7)}]}
    ckpt.save(str(tmp_path), 3, tree, extra={"foo": 1})
    assert ckpt.latest_step(str(tmp_path)) == 3
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), tree)
    restored, extra = ckpt.restore(str(tmp_path), 3, like)
    assert extra == {"foo": 1}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    tree = {"a": jnp.ones(3)}
    ckpt.save(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_00000002.tmp")  # simulated crashed save
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.ones(3)})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, {"a": jax.ShapeDtypeStruct((4,), jnp.float32)})


def test_pipeline_deterministic_and_host_sharded():
    mc = get_config("smollm-360m", smoke=True)
    p1 = TokenPipeline(PipelineConfig(global_batch=8, seq_len=32, seed=5), mc)
    p2 = TokenPipeline(PipelineConfig(global_batch=8, seq_len=32, seed=5), mc)
    np.testing.assert_array_equal(p1.batch(17)["tokens"], p2.batch(17)["tokens"])
    assert not np.array_equal(p1.batch(17)["tokens"], p1.batch(18)["tokens"])
    h0 = TokenPipeline(PipelineConfig(global_batch=8, seq_len=32, seed=5,
                                      n_hosts=2, host_id=0), mc)
    h1 = TokenPipeline(PipelineConfig(global_batch=8, seq_len=32, seed=5,
                                      n_hosts=2, host_id=1), mc)
    b0, b1 = h0.batch(3)["tokens"], h1.batch(3)["tokens"]
    assert b0.shape == (4, 32)
    assert not np.array_equal(b0, b1)


def test_crash_resume_is_bitwise_identical(tmp_path):
    """Train 6 steps straight vs crash-at-3 + restore + 3 more — identical
    (deterministic pipeline + checkpointed optimizer state)."""
    from repro.models.steps import TrainConfig, make_train_step
    from repro.models.transformer import init_params

    cfg = get_config("smollm-360m", smoke=True)
    pipe = TokenPipeline(PipelineConfig(global_batch=4, seq_len=24, seed=1), cfg)
    opt = AdamW(AdamWConfig(learning_rate=1e-3, warmup_steps=1))
    tcfg = TrainConfig(grad_accum=1, remat=False)
    step_fn = jax.jit(make_train_step(cfg, tcfg, opt))

    def run(params, state, s0, s1):
        for s in range(s0, s1):
            batch = {k: jnp.asarray(v) for k, v in pipe.batch(s).items()}
            params, state, _ = step_fn(params, state, batch, jnp.int32(s))
        return params, state

    p0 = init_params(cfg, jax.random.PRNGKey(0))
    s0 = opt.init(p0)
    p_straight, _ = run(p0, s0, 0, 6)

    p3, st3 = run(p0, s0, 0, 3)
    ckpt.save(str(tmp_path), 3, {"params": p3, "opt": st3})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype),
                        {"params": p3, "opt": st3})
    restored, _ = ckpt.restore(str(tmp_path), 3, like)
    p_resumed, _ = run(restored["params"], restored["opt"], 3, 6)

    for a, b in zip(jax.tree.leaves(p_straight), jax.tree.leaves(p_resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
