"""Dry-run support machinery: flop/byte counters, skip rules, specs."""

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, cell_is_skipped, get_config
from repro.launch.hlo_analysis import count_jaxpr_bytes, count_jaxpr_flops


def test_flops_exact_for_matmul():
    f = lambda a, b: a @ b
    jx = jax.make_jaxpr(f)(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 32), jnp.float32),
    )
    assert count_jaxpr_flops(jx) == 2 * 64 * 128 * 32


def test_flops_multiply_scan_trips():
    def f(w, x):
        def body(x, _):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, None, length=12)[0]

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    flops = count_jaxpr_flops(jax.make_jaxpr(f)(w, x))
    matmul = 2 * 8 * 64 * 64
    assert flops >= 12 * matmul
    assert flops < 12 * matmul * 1.5  # elementwise overhead stays small


def test_flops_recurse_remat():
    def layer(x, w):
        return jnp.tanh(x @ w)

    def f(w, x):
        y = jax.checkpoint(layer)(x, w)
        return jnp.sum(y)

    g = jax.grad(f, argnums=0)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    flops = count_jaxpr_flops(jax.make_jaxpr(g)(w, x))
    # fwd + remat recompute + 1 bwd matmul >= 3 matmuls
    assert flops >= 3 * 2 * 8 * 64 * 64


def test_bytes_scan_linear_in_trips():
    def mk(n):
        def f(w, x):
            def body(x, _):
                return jnp.tanh(x @ w), None
            return jax.lax.scan(body, x, None, length=n)[0]
        return f

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    b4 = count_jaxpr_bytes(jax.make_jaxpr(mk(4))(w, x))
    b16 = count_jaxpr_bytes(jax.make_jaxpr(mk(16))(w, x))
    assert 3.0 < (b16 - 17000) / max(b4 - 17000, 1) < 5.0  # ~4x body traffic


def test_dus_counts_update_only():
    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0,))

    buf = jax.ShapeDtypeStruct((1_000_000,), jnp.float32)
    upd = jax.ShapeDtypeStruct((8,), jnp.float32)
    b = count_jaxpr_bytes(jax.make_jaxpr(f)(buf, upd))
    assert b < 4_100_000  # args once, not 2x the big buffer


def test_skip_rules():
    assert cell_is_skipped("hubert-xlarge", "decode_32k")
    assert cell_is_skipped("hubert-xlarge", "long_500k")
    assert cell_is_skipped("gemma3-27b", "long_500k")
    assert cell_is_skipped("smollm-360m", "long_500k")
    assert cell_is_skipped("rwkv6-3b", "long_500k") is None
    assert cell_is_skipped("recurrentgemma-9b", "long_500k") is None
    n = sum(1 for a in ARCH_IDS for s in SHAPES if not cell_is_skipped(a, s))
    assert n == 31


def test_model_flops_formula_sane():
    from repro.launch.dryrun import model_flops

    cfg = get_config("smollm-360m")
    n_act = 360e6  # order of magnitude
    tr = model_flops(cfg, SHAPES["train_4k"], int(n_act))
    assert 2.0e15 < tr < 4.5e15  # ~6ND + attention for 1M tokens
    dec = model_flops(cfg, SHAPES["decode_32k"], int(n_act))
    assert dec < tr / 1000


def test_n_params_counts():
    cfg = get_config("smollm-360m")
    n = cfg.n_params()
    assert 3.4e8 < n < 5.5e8  # ~360M + untied head
    moe = get_config("deepseek-moe-16b")
    assert moe.n_params_active() < 0.3 * moe.n_params()
