"""Direct unit coverage for the I/O accounting layer (DiskModel,
coalesce_ranges, read_seq_ranges, heatmap) — previously exercised only
indirectly through the indexes."""
import numpy as np
import pytest

from repro.core.io_model import DiskModel, IOStats, coalesce_ranges, render_heatmap


# ---------------------------------------------------------------------------
# coalesce_ranges
# ---------------------------------------------------------------------------
def test_coalesce_empty_and_degenerate():
    assert coalesce_ranges([]) == []
    assert coalesce_ranges([(3, 3)]) == []  # empty range drops out
    assert coalesce_ranges([(9, 2)]) == []  # inverted range drops out
    assert coalesce_ranges([(0, 0), (5, 4), (7, 7)]) == []


def test_coalesce_overlapping_nested_and_touching():
    # overlap, containment, back-to-back all fuse; gaps stay separate
    assert coalesce_ranges([(0, 4), (2, 6)]) == [(0, 6)]
    assert coalesce_ranges([(0, 10), (2, 3)]) == [(0, 10)]  # nested
    assert coalesce_ranges([(0, 4), (4, 8)]) == [(0, 8)]  # touching
    assert coalesce_ranges([(0, 2), (5, 7)]) == [(0, 2), (5, 7)]


def test_coalesce_unsorted_input_and_duplicates():
    got = coalesce_ranges([(10, 12), (0, 4), (10, 12), (3, 5)])
    assert got == [(0, 5), (10, 12)]


def test_coalesce_is_idempotent_and_minimal():
    rng = np.random.default_rng(0)
    spans = [(int(a), int(a + w)) for a, w in
             zip(rng.integers(0, 100, 50), rng.integers(0, 10, 50))]
    once = coalesce_ranges(spans)
    assert coalesce_ranges(once) == once
    # disjoint, ascending, non-empty
    for (a0, a1), (b0, b1) in zip(once, once[1:]):
        assert a0 < a1 and b0 < b1 and a1 < b0


# ---------------------------------------------------------------------------
# read_seq_ranges
# ---------------------------------------------------------------------------
def test_read_seq_ranges_accounts_bytes_and_ops():
    d = DiskModel()
    d.read_seq_ranges([(0, 4), (10, 12)], unit_bytes=8)
    assert d.stats.seq_read_bytes == (4 + 2) * 8
    assert d.stats.seq_ops == 2  # one sequential read per range
    assert d.stats.rand_read_bytes == 0


def test_read_seq_ranges_empty_and_unit_bytes_default():
    d = DiskModel()
    d.read_seq_ranges([])
    assert d.stats == IOStats()
    d.read_seq_ranges([(5, 9)])  # unit_bytes=1
    assert d.stats.seq_read_bytes == 4


def test_read_seq_ranges_offsets_land_in_log():
    d = DiskModel(keep_log=True, page_bytes=16)
    d.read_seq_ranges([(4, 8)], unit_bytes=16)  # offset 4*16 = page 4
    assert d.log == [(4, 4, "rs")]


# ---------------------------------------------------------------------------
# heatmap
# ---------------------------------------------------------------------------
def test_heatmap_empty_log_is_all_zero():
    d = DiskModel(keep_log=True)
    assert d.heatmap(n_bins=8) == [0] * 8


def test_heatmap_bins_accesses_and_respects_max_page():
    d = DiskModel(keep_log=True, page_bytes=1)
    d.read_seq(4, offset=0)  # pages [0, 4)
    d.read_rand(2, offset=6)  # pages [6, 8)
    bins = d.heatmap(n_bins=8, max_page=8)
    assert bins[0] > 0 and bins[6] > 0
    assert sum(bins) >= 2
    # a span covering everything touches every bin
    d2 = DiskModel(keep_log=True, page_bytes=1)
    d2.read_seq(64, offset=0)
    assert all(v == 1 for v in d2.heatmap(n_bins=8, max_page=64))


def test_heatmap_clamps_out_of_range_pages():
    d = DiskModel(keep_log=True, page_bytes=1)
    d.read_rand(1, offset=1000)  # beyond max_page
    bins = d.heatmap(n_bins=4, max_page=10)
    assert bins[-1] == 1  # clamped into the final bin


def test_render_heatmap_shades_scale():
    s = render_heatmap([0, 1, 10], width=3)
    assert len(s) == 3 and s[0] == " " and s[2] == "@"


def test_seq_and_rand_page_counts_agree():
    """All four access paths ceil-divide bytes into heat-map pages: a
    4097-byte access touches 2 pages whether it was sequential or random
    (the seq paths used to floor-divide, under-counting every partial
    page and skewing seq-vs-rand heat comparisons)."""
    for nbytes, pages in ((4096, 1), (4097, 2), (1, 1), (8192, 2)):
        d = DiskModel(keep_log=True)  # default page_bytes=4096
        d.read_seq(nbytes)
        d.write_seq(nbytes)
        d.read_rand(nbytes)
        d.write_rand(nbytes)
        assert [n for _, n, _ in d.log] == [pages] * 4, (nbytes, d.log)


def test_heatmap_halfopen_boundary_does_not_bleed():
    # pages [0, 2) under 4 bins over 8 pages live entirely in bin 0; the
    # old end-bin computation (off + n) spilled one count into bin 1
    d = DiskModel(keep_log=True, page_bytes=1)
    d.read_seq(2, offset=0)
    assert d.heatmap(n_bins=4, max_page=8) == [1, 0, 0, 0]


def test_heatmap_binning_property():
    """A logged span marks exactly the bins its pages fall into — no more
    (end off-by-one), no fewer (start clamping)."""
    rng = np.random.default_rng(7)
    for _ in range(50):
        off = int(rng.integers(0, 100))
        n = int(rng.integers(1, 40))
        d = DiskModel(keep_log=True, page_bytes=1)
        d.read_rand(n, offset=off)
        n_bins, mp = 8, 100
        bins = d.heatmap(n_bins=n_bins, max_page=mp)
        expect = {min(n_bins - 1, min(p, mp - 1) * n_bins // mp)
                  for p in range(off, off + n)}
        assert {i for i, v in enumerate(bins) if v} == expect, (off, n, bins)


# ---------------------------------------------------------------------------
# modeled cost
# ---------------------------------------------------------------------------
def test_modeled_seconds_seq_vs_rand():
    seq = DiskModel()
    seq.read_seq(500_000_000)  # 1 s at 500 MB/s
    rand = DiskModel()
    rand.read_rand(500_000_000)  # ~122k page ops at 10k IOPS >> 1 s
    assert seq.modeled_seconds() == pytest.approx(1.0)
    assert rand.modeled_seconds() > 10 * seq.modeled_seconds()


# ---------------------------------------------------------------------------
# unaccounted(): thread-local accounting suspension
# ---------------------------------------------------------------------------
def test_unaccounted_suspends_calling_thread_only():
    """The recall oracle's reads vanish while a concurrent ingest worker's
    I/O keeps landing in the shared stats (the property the old in-place
    stats save/restore could not provide)."""
    import threading

    d = DiskModel(keep_log=True)
    d.read_seq(4096)
    with d.unaccounted():
        d.read_seq(1 << 20)   # oracle-side: must not account
        d.write_rand(4096)
        t = threading.Thread(target=lambda: d.write_seq(8192))
        t.start()
        t.join()
    assert d.stats.seq_read_bytes == 4096       # only the pre-oracle read
    assert d.stats.seq_write_bytes == 8192      # the worker still accounted
    assert d.stats.rand_write_bytes == 0
    # the access log is suppressed too: no phantom heat-map stripes
    assert [kind for _, _, kind in d.log] == ["rs", "ws"]


def test_unaccounted_is_reentrant():
    d = DiskModel()
    with d.unaccounted():
        with d.unaccounted():
            d.read_seq(100)
        d.read_seq(100)  # still suspended at depth 1
    d.read_seq(100)
    assert d.stats.seq_read_bytes == 100
    assert d.stats.seq_ops == 1


def test_unaccounted_covers_range_reads():
    d = DiskModel()
    with d.unaccounted():
        d.read_seq_ranges([(0, 4), (10, 12)], unit_bytes=4096)
    assert d.stats.total_bytes == 0
