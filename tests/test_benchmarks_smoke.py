"""Benchmark drivers are exercised by CI via ``benchmarks.run --smoke``
(tiny sizes, output-schema assertions) instead of only by hand."""
import json
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
ROW_RE = re.compile(r"^[^,\s][^,]*,\d+(\.\d+)?,[^,]*(;[^,]*)*$")

LEGAL_NB = {0, 1, 2, 4, 8, 16}


def _assert_adaptation_traces(payload):
    """The serving artifact carries the autotuner's decision/observation
    traces (DECISION_SCHEMA=1): per scenario, monotone seq, non-decreasing
    epoch, legal knob values, recall in [0, 1] — the machine-readable
    adaptation record downstream perf diffs consume."""
    rows = {rec["name"] for rec in payload["rows"]}
    adapt = {n for n in rows if n.startswith("serving/adapt_")}
    assert adapt, f"adaptation sweep rows missing from {sorted(rows)}"
    traces = payload["adaptation_traces"]
    assert set(traces) == {n.split("serving/adapt_", 1)[1] for n in adapt}
    for name, t in traces.items():
        assert t["fixed"], f"{name}: fixed-arm baselines missing"
        for metrics in t["fixed"].values():
            assert 0.0 <= metrics["recall"] <= 1.0
            assert metrics["p99_ms"] > 0.0
        entries = t["adapted"]
        assert entries, f"{name}: empty adaptation trace"
        seqs = [e["seq"] for e in entries]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs), name
        epochs = [e["epoch"] for e in entries]
        assert epochs == sorted(epochs), f"{name}: epoch went backwards"
        for e in entries:
            assert e["schema"] == 1, e
            assert e["kind"] in ("decide", "observe"), e
            assert e["tier"] in ("exact", "approx"), e
            assert e["n_blocks"] in LEGAL_NB, e
            if e["tier"] == "exact":
                assert e["n_blocks"] == 0, e
            if e["kind"] == "observe":
                assert isinstance(e["served"], bool), e
                rec = e["observed_recall"]
                assert rec is None or 0.0 <= rec <= 1.0, e
        kinds = {e["kind"] for e in entries}
        assert kinds == {"decide", "observe"}, f"{name}: {kinds}"


@pytest.mark.slow
def test_benchmarks_run_smoke_mode(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke",
         "--out-dir", str(tmp_path)],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    lines = proc.stdout.strip().splitlines()
    assert lines[0] == "name,us_per_call,derived"
    assert len(lines) > 20  # every driver emitted rows
    for line in lines[1:]:
        assert ROW_RE.match(line), f"schema violation: {line!r}"
        assert "/ERROR," not in line, f"driver crashed: {line!r}"
    # the approximate tier sweep is present with both recall columns equal
    approx = [l for l in lines if "_knn_approx_batch_" in l]
    assert approx, "approx-tier sweep missing from query driver"
    for line in approx:
        m = re.search(r"recall_at10=([\d.]+);loop_recall_at10=([\d.]+)", line)
        assert m and m.group(1) == m.group(2), line
    # machine-readable perf-trajectory artifacts are emitted per module
    # (smoke suffix so CI never clobbers the committed trajectory)
    for mod in ("query", "streaming", "serving"):
        path = tmp_path / f"BENCH_{mod}.smoke.json"
        assert path.exists(), f"missing artifact {path}"
        payload = json.loads(path.read_text())
        assert payload["benchmark"] == mod and payload["smoke"] is True
        assert payload["rows"], "artifact has no rows"
        for rec in payload["rows"]:
            assert "name" in rec and "us_per_call" in rec
        # the verification engine's compile/transfer counters ride along so
        # compile-churn regressions fail fast in CI
        eng = payload["verify_engine"]
        assert all(key in eng for key in
                   ("traces", "hits", "h2d_bytes", "d2h_bytes"))
        if mod == "query":
            assert any("recall_at10" in rec for rec in payload["rows"])
            assert any("modeled_io_s" in rec for rec in payload["rows"])
            # the exact-tier batched sweep only: the screen-dtype sweep's
            # *_knn_batch_b* rows carry fallback/compression columns, not
            # the engine-accounting trio
            batch_rows = [rec for rec in payload["rows"]
                          if "_knn_batch_b" in rec["name"]
                          and not rec["name"].startswith("query/screen_")]
            assert batch_rows, "batched exact sweep missing"
            for rec in batch_rows:  # per-config engine accounting
                assert all(key in rec for key in
                           ("trace_count", "h2d_bytes", "d2h_bytes")), rec
        if mod == "serving":
            _assert_adaptation_traces(payload)
        if mod == "streaming":
            # the storage-backend sweep: one row per backend, each with
            # modeled columns; the file row also has real measured bytes
            store = {rec["name"]: rec for rec in payload["rows"]
                     if rec["name"].startswith("streaming/storage_")}
            assert set(store) == {"streaming/storage_model_ingest_query",
                                  "streaming/storage_file_ingest_query"}
            for rec in store.values():
                assert all(key in rec for key in
                           ("modeled_io_s", "modeled_mb", "measured_write_mb",
                            "measured_read_mb", "wal_mb", "prefetch_spans")), rec
            frec = store["streaming/storage_file_ingest_query"]
            assert float(frec["measured_write_mb"]) > 0, frec
            assert float(frec["wal_mb"]) > 0, frec
            # the modeled backend measures nothing (there is no file)
            mrec = store["streaming/storage_model_ingest_query"]
            assert float(mrec["measured_write_mb"]) == 0, mrec
