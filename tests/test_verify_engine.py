"""The device-resident verification engine (the executor's default
backend): bitwise parity with the retained host path across every index x
tier x scalar/batch on well-conditioned data, error-bound-certified
exactness on adversarially conditioned data, the steady-state zero-retrace
guarantee of the shape-bucketed compile cache, and the arena lifecycle
(one upload per table, in-place extends for append-only stores)."""
import numpy as np
import pytest

from repro.core import (
    ADSConfig,
    ADSIndex,
    CLSM,
    CLSMConfig,
    CTree,
    CTreeConfig,
    RawStore,
    StreamConfig,
    StreamingIndex,
    SummarizationConfig,
    ed2,
)
from repro.core.verify_engine import get_engine

CFG = SummarizationConfig(series_len=64, n_segments=8, card_bits=6)


def _data(n=6000, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, 64)).astype(np.float32).cumsum(axis=1)


def _queries(m=32, seed=99):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((m, 64)).astype(np.float32).cumsum(axis=1)


def _adversarial(n, seed=0, offset=3000.0, spread=0.01):
    """Large common offset + tiny relative distances: the f32
    |q|^2 + |x|^2 - 2<q, x> cancellation trap (PR 3's hardening suite)."""
    rng = np.random.default_rng(seed)
    return (offset + spread * rng.standard_normal((n, 64))).astype(np.float32)


def _ctree(mat, X, raw):
    ct = CTree(CTreeConfig(summarization=CFG, block_size=512,
                           materialized=mat))
    ct.bulk_build(X, raw.append(X))
    return ct


# ---------------------------------------------------------------------------
# device == host, bitwise, on every index x tier x scalar/batch
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mat", [True, False])
def test_ctree_device_matches_host_bitwise(mat):
    X, Q = _data(), _queries()
    raw = RawStore(64)
    ct = _ctree(mat, X, raw)
    calls0 = get_engine().stats["calls"]
    vd, gd, sd = ct.knn_batch(Q, k=10, raw=raw)  # device is the default
    vn, gn, sn = ct.knn_batch(Q, k=10, raw=raw, backend="numpy")
    np.testing.assert_array_equal(vd, vn)
    np.testing.assert_array_equal(gd, gn)
    # identical pruning accounting too — the device pass is a drop-in
    assert (sd.entries_verified, sd.blocks_visited) == (
        sn.entries_verified, sn.blocks_visited)
    assert get_engine().stats["calls"] > calls0  # device actually engaged
    # approximate tier
    va, ga, _ = ct.knn_approx_batch(Q, k=10, n_blocks=3, raw=raw)
    vb, gb, _ = ct.knn_approx_batch(Q, k=10, n_blocks=3, raw=raw,
                                    backend="numpy")
    np.testing.assert_array_equal(va, vb)
    np.testing.assert_array_equal(ga, gb)


def test_ctree_scalar_is_batch_of_one_on_device():
    X, Q = _data(3000, seed=2), _queries(1, seed=5)
    raw = RawStore(64)
    ct = _ctree(True, X, raw)
    res, _ = ct.knn_exact(Q[0], k=5, raw=raw)
    vals, gids, _ = ct.knn_batch(Q, k=5, raw=raw)
    assert [d for d, _ in res] == [float(v) for v in vals[0]]
    assert [g for _, g in res] == [int(g) for g in gids[0]]


def test_clsm_device_matches_host_bitwise():
    X, Q = _data(5000, seed=3), _queries(24, seed=7)
    raw = RawStore(64)
    lsm = CLSM(CLSMConfig(summarization=CFG, buffer_entries=1024,
                          growth_factor=3, block_size=256, materialized=True))
    lsm.insert(X, raw.append(X), np.arange(len(X), dtype=np.int64))
    vd, gd, _ = lsm.knn_batch(Q, k=7, raw=raw)
    vn, gn, _ = lsm.knn_batch(Q, k=7, raw=raw, backend="numpy")
    np.testing.assert_array_equal(vd, vn)
    np.testing.assert_array_equal(gd, gn)


@pytest.mark.parametrize("mode", ["full", "adaptive"])
def test_ads_device_matches_host_bitwise(mode):
    X, Q = _data(4000, seed=4), _queries(16, seed=9)
    raw = RawStore(64)
    ids = raw.append(X)

    def build():
        ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=2048,
                                 mode=mode, query_leaf_size=256))
        ads.insert_batch(X, ids)
        return ads

    # adaptive splits mutate the tree during queries, so each backend gets
    # a fresh build (same data -> same refinement decisions)
    vd, gd, _ = build().knn_batch(Q, k=5, raw=raw)
    vn, gn, _ = build().knn_batch(Q, k=5, raw=raw, backend="numpy")
    np.testing.assert_array_equal(vd, vn)
    np.testing.assert_array_equal(gd, gn)
    ads = build()
    va, ga, _ = ads.knn_approx_batch(Q, k=5, raw=raw)
    vb, gb, _ = build().knn_approx_batch(Q, k=5, raw=raw, backend="numpy")
    np.testing.assert_array_equal(va, vb)
    np.testing.assert_array_equal(ga, gb)


def test_streaming_window_device_matches_host_bitwise():
    rng = np.random.default_rng(11)
    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                      buffer_entries=1024, growth_factor=3,
                                      block_size=256, materialized=False))
    for b in range(8):
        x = rng.standard_normal((600, 64)).astype(np.float32).cumsum(axis=1)
        idx.ingest(x, np.full(600, b, np.int64))
    Q = _queries(16, seed=13)
    vd, gd, _ = idx.window_knn_batch(Q, 2, 6, k=4)
    vn, gn, _ = idx.window_knn_batch(Q, 2, 6, k=4, backend="numpy")
    np.testing.assert_array_equal(vd, vn)
    np.testing.assert_array_equal(gd, gn)


def test_approx_tier_shared_span_group_takes_device_path():
    """Queries that seek into the same neighborhood share one span group —
    the case where the approximate tier's verification clears the device
    floors. Answers must still match the host path bitwise."""
    X = _data(8000, seed=6)
    raw = RawStore(64)
    ct = _ctree(True, X, raw)
    q = _queries(1, seed=17)
    Q = np.repeat(q, 16, axis=0)  # one shared span, 16-query group
    calls0 = get_engine().stats["calls"]
    vd, gd, _ = ct.knn_approx_batch(Q, k=5, n_blocks=4, raw=raw)
    assert get_engine().stats["calls"] > calls0
    vn, gn, _ = ct.knn_approx_batch(Q, k=5, n_blocks=4, raw=raw,
                                    backend="numpy")
    np.testing.assert_array_equal(vd, vn)
    np.testing.assert_array_equal(gd, gn)


# ---------------------------------------------------------------------------
# adversarial conditioning: the certificate keeps the device path exact
# ---------------------------------------------------------------------------
def test_device_exact_under_f32_cancellation():
    X = _adversarial(4000)
    rng = np.random.default_rng(1)
    Q = np.stack([X[i] + 0.001 * rng.standard_normal(64).astype(np.float32)
                  for i in range(16)])
    raw = RawStore(64)
    ct = _ctree(True, X, raw)
    vals, gids, _ = ct.knn_batch(Q, k=5, raw=raw)
    X64 = X.astype(np.float64)
    for i in range(len(Q)):
        bf = ed2(Q[i].astype(np.float64), X64)  # (n,) exact oracle
        want = np.sort(bf)[:5]
        np.testing.assert_allclose(vals[i], want, rtol=1e-5)
        np.testing.assert_allclose(np.sort(bf[gids[i]]), want, rtol=1e-12)


# ---------------------------------------------------------------------------
# steady state: zero retraces after warm-up
# ---------------------------------------------------------------------------
def test_steady_state_serving_never_retraces():
    rng = np.random.default_rng(21)
    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                      buffer_entries=2048, growth_factor=4,
                                      block_size=512))
    for b in range(6):
        x = rng.standard_normal((1500, 64)).astype(np.float32).cumsum(axis=1)
        idx.ingest(x, np.full(1500, b, np.int64))
    eng = get_engine()
    # warm up the way serving does: pre-compile the bucket ladder for the
    # store's arena capacity, then one live batch
    eng.prewarm(64, m=16, k=5, caps=[idx.raw.n])
    idx.knn_batch(_queries(16, seed=0), k=5)
    traces0 = eng.stats["traces"]
    calls0 = eng.stats["calls"]
    hits0 = eng.stats["hits"]
    for b in range(10):  # 10 serving batches, varying content + batch size
        m = 16 if b % 2 else 13
        idx.knn_batch(_queries(m, seed=100 + b), k=5)
    # deltas, not the raw counters: the engine is a process singleton, so
    # earlier tests' prewarms (traces without calls) live in the totals
    d_calls = eng.stats["calls"] - calls0
    assert d_calls > 0  # the device path served them
    assert eng.stats["traces"] == traces0  # ...from cached traces only
    assert eng.stats["hits"] - hits0 >= d_calls > 0


def test_prewarm_compiles_the_ladder_once():
    eng = get_engine()
    compiled = eng.prewarm(96, m=16, k=5, caps=[3000])
    again = eng.prewarm(96, m=16, k=5, caps=[3000])
    assert again == 0  # everything already compiled
    assert compiled >= 0  # first call may share traces with earlier tests


# ---------------------------------------------------------------------------
# arena lifecycle
# ---------------------------------------------------------------------------
def test_arena_uploads_once_and_extends_in_place():
    X = _data(3000, seed=8)
    raw = RawStore(64)
    ct = _ctree(False, X, raw)  # non-materialized: verifies via raw arena
    Q = _queries(16, seed=3)
    eng = get_engine()
    ct.knn_batch(Q, k=5, raw=raw)
    up0 = eng.stats["uploads"]
    ct.knn_batch(_queries(16, seed=4), k=5, raw=raw)
    assert eng.stats["uploads"] == up0  # immutable store: no re-upload
    view0 = raw.device_view()
    raw.append(_data(48, seed=12))
    # growth that fits the bucketed capacity: the view extends in place
    # (donated update), keeping the same buffers' capacity
    view1 = raw.device_view()
    assert view1.n == 3048 and view1.cap == view0.cap
    assert eng.stats["uploads"] == up0 + 1
    # growth past the capacity: the arena rebuilds at the next bucket
    raw.append(_data(500, seed=14))
    view2 = raw.device_view()
    assert view2.n == 3548 and view2.cap > view0.cap
    # the original index still answers exactly over its 3000 entries
    q = Q[0]
    res, _ = ct.knn_exact(q, k=3, raw=raw)
    bf = np.sort(ed2(q, X))[:3]
    np.testing.assert_allclose([d for d, _ in res], bf, rtol=1e-5)


def test_device_backend_rejected_names_still_error():
    X = _data(500)
    raw = RawStore(64)
    ct = _ctree(True, X, raw)
    with pytest.raises(ValueError, match="backend"):
        ct.knn_batch(_queries(2), k=3, raw=raw, backend="cuda")
