"""Online autotuner: seeded determinism, model refit convergence,
profile isolation, epoch forgetting, trace schema, and the gateway
integration (tier selection consults the fitted model; observed outcomes
feed back; answers stay bitwise-equal to the direct engine calls)."""
import numpy as np
import pytest

from repro.core.autotune import (
    DECISION_SCHEMA, N_BLOCKS_GRID, AutoTuner, AutoTunerConfig, Knobs,
    knob_grid, workload_key,
)
from repro.core.recommender import Scenario, serving_tier

K9 = dict(target_recall=0.9, k=5, batch_rung=16)


def _env(knobs: Knobs):
    """Synthetic ground truth: approx latency grows with n_blocks, exact
    is expensive; recall follows a saturating curve steeper than the
    static prior (the mismatch the tuner must discover)."""
    if knobs.tier == "exact":
        return 9.0, 1.0
    return 0.8 + 0.45 * knobs.n_blocks, min(1.0, 0.82 + 0.05 * knobs.n_blocks)


def _drive(tuner, key, n=250, epoch=0, n_series=10**6, jitter=None):
    for i in range(n):
        d = tuner.decide(key, epoch=epoch, n_series=n_series)
        for kn in filter(None, (d.knobs, d.shadow)):
            lat, rec = _env(kn)
            if jitter is not None:
                lat *= 1.0 + jitter * ((i % 7) - 3) / 10.0
            tuner.observe(key, kn, lat_ms=lat, epoch=epoch, recall=rec)


def test_knob_grid_shape():
    arms = knob_grid()
    assert arms[0] == Knobs("exact", 0)
    assert tuple(a.n_blocks for a in arms[1:]) == N_BLOCKS_GRID
    assert all(a.tier == "approx" for a in arms[1:])


def test_workload_key_buckets_windows_pow2():
    a = workload_key(target_recall=0.9, k=5, window=(0, 5), batch_rung=8)
    b = workload_key(target_recall=0.9, k=5, window=(2, 7), batch_rung=8)
    c = workload_key(target_recall=0.9, k=5, window=(0, 99), batch_rung=8)
    assert a == b  # same width bucket -> same profile
    assert a != c
    assert workload_key(k=5, batch_rung=8).window_bucket == -1


def test_seeded_determinism():
    """Same seed + same observation sequence -> identical decision and
    observation traces, bit for bit."""
    runs = []
    for _ in range(2):
        t = AutoTuner(AutoTunerConfig(seed=42))
        key = workload_key(**K9)
        _drive(t, key, n=120)
        runs.append(t.trace())
    assert runs[0] == runs[1]


def test_refit_converges_to_truly_best_arm():
    """The static priors rank exact as expensive and shallow approx as
    low-recall; the injected ground truth says approx@2 already clears
    the target cheaply. The fitted models must converge there."""
    t = AutoTuner(AutoTunerConfig(seed=7, epsilon=0.3))
    key = workload_key(**K9)
    _drive(t, key, n=300)
    last = [e for e in t.trace() if e["kind"] == "decide"][-40:]
    exploit = [e for e in last if not e["explore"]]
    picks = {(e["tier"], e["n_blocks"]) for e in exploit}
    assert picks == {("approx", 2)}, picks


def test_refit_estimates_near_ground_truth():
    t = AutoTuner(AutoTunerConfig(seed=1, epsilon=0.3))
    key = workload_key(**K9)
    _drive(t, key, n=300, jitter=0.1)
    prof = t._profiles[key]
    for kn, arm in prof.arms.items():
        lat, rec = _env(kn)
        if arm.lat_w < 6.0:  # unexplored arms keep their priors
            continue
        assert arm.lat_ms == pytest.approx(lat, rel=0.25)
        assert arm.recall == pytest.approx(rec, abs=0.05)


def test_profile_isolation():
    """A misbehaving tenant's observations must not move another request
    shape's fitted model."""
    t = AutoTuner(AutoTunerConfig(seed=0))
    good = workload_key(**K9)
    bad = workload_key(target_recall=0.5, k=3, batch_rung=8)
    _drive(t, good, n=150)
    snap_before = {kn: (a.lat_ms, a.recall, a.lat_w, a.recall_w)
                   for kn, a in t._profiles[good].arms.items()}
    for _ in range(200):  # pathological outcomes on the OTHER profile
        d = t.decide(bad, epoch=0, n_series=10**6)
        t.observe(bad, d.knobs, lat_ms=500.0, epoch=0, recall=0.01)
    snap_after = {kn: (a.lat_ms, a.recall, a.lat_w, a.recall_w)
                  for kn, a in t._profiles[good].arms.items()}
    assert snap_before == snap_after


def test_strict_recall_is_always_exact():
    """target_recall >= 1.0 is contractually exact: never bandit-routed,
    never explored, even at epsilon=1."""
    t = AutoTuner(AutoTunerConfig(seed=0, epsilon=1.0))
    key = workload_key(target_recall=1.0, k=5, batch_rung=16)
    for _ in range(50):
        d = t.decide(key, epoch=0, n_series=10**6)
        assert (d.knobs.tier, d.knobs.n_blocks, d.explore,
                d.shadow) == ("exact", 0, False, None)
    assert t.counters()["explores"] == 0


def test_untargeted_workload_is_exact():
    t = AutoTuner(AutoTunerConfig(seed=0, epsilon=1.0))
    d = t.decide(workload_key(k=5, batch_rung=8), epoch=0, n_series=10**6)
    assert d.knobs == Knobs("exact", 0)


def test_forced_arm_pins_every_decision():
    arm = Knobs("approx", 2)
    t = AutoTuner(AutoTunerConfig(seed=0, forced=arm))
    key = workload_key(**K9)
    for _ in range(30):
        d = t.decide(key, epoch=0, n_series=10**6)
        assert d.knobs == arm and not d.explore and d.shadow is None


def test_priors_match_static_tree_at_zero_observations():
    """Before any measurement the tuner IS the static recommender: for a
    store where exact is priced out, the first greedy decision lands on
    the same n_blocks the frozen rule tree picks."""
    s = Scenario(streaming=True, n_series=10**6, series_len=128,
                 uses_windows=True, target_recall=0.9, query_batch=16)
    dec = serving_tier(s)
    t = AutoTuner(AutoTunerConfig(seed=0, epsilon=0.0))
    d = t.decide(workload_key(**K9), epoch=0, n_series=10**6)
    assert (d.knobs.tier, d.knobs.n_blocks) == (dec.tier, dec.n_blocks)


def test_epoch_advance_decays_evidence():
    t = AutoTuner(AutoTunerConfig(seed=0, epoch_forget=0.5))
    key = workload_key(**K9)
    _drive(t, key, n=100, epoch=3)
    w_before = {kn: (a.lat_w, a.recall_w)
                for kn, a in t._profiles[key].arms.items()}
    t.decide(key, epoch=4, n_series=10**6)  # epoch moved -> refit decay
    prof = t._profiles[key]
    assert t.counters()["epoch_refits"] == 1
    assert prof.last_epoch == 4
    for kn, (lw, rw) in w_before.items():
        assert prof.arms[kn].lat_w == pytest.approx(0.5 * lw)
        assert prof.arms[kn].recall_w == pytest.approx(0.5 * rw)
    # same epoch again: no further decay
    t.decide(key, epoch=4, n_series=10**6)
    assert t.counters()["epoch_refits"] == 1


def test_exponential_forgetting_tracks_drift():
    """After the environment shifts, the fitted latency walks to the new
    level — old observations wash out at rate ``forget``."""
    t = AutoTuner(AutoTunerConfig(seed=0, forget=0.8))
    key = workload_key(**K9)
    arm = Knobs("approx", 2)
    for _ in range(50):
        t.observe(key, arm, lat_ms=2.0, epoch=0, recall=0.95, n_series=10**6)
    for _ in range(50):
        t.observe(key, arm, lat_ms=20.0, epoch=0, recall=0.6, n_series=10**6)
    fitted = t._profiles[key].arms[arm]
    assert fitted.lat_ms == pytest.approx(20.0, rel=0.05)
    assert fitted.recall == pytest.approx(0.6, abs=0.02)


def test_conflict_when_nothing_feasible():
    """Recall target above every arm's fitted recall except exact, budget
    below exact's fitted cost -> the decision carries conflict=True (the
    caller sheds/flags), mirroring the static tree's contract."""
    t = AutoTuner(AutoTunerConfig(seed=0, epsilon=0.0))
    key = workload_key(target_recall=0.99, latency_budget_ms=0.01, k=5,
                       batch_rung=16)
    d = t.decide(key, epoch=0, n_series=10**6)
    assert d.conflict


def test_trace_schema():
    t = AutoTuner(AutoTunerConfig(seed=5))
    key = workload_key(**K9)
    _drive(t, key, n=80, epoch=2)
    trace = t.trace()
    assert trace, "trace must not be empty"
    legal_nb = {0} | set(N_BLOCKS_GRID)
    seqs = [e["seq"] for e in trace]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    epochs = [e["epoch"] for e in trace]
    assert epochs == sorted(epochs)
    for e in trace:
        assert e["schema"] == DECISION_SCHEMA
        assert e["kind"] in ("decide", "observe")
        assert e["tier"] in ("exact", "approx")
        assert e["n_blocks"] in legal_nb
        if e["tier"] == "exact":
            assert e["n_blocks"] == 0
        if e["kind"] == "observe":
            assert isinstance(e["served"], bool)
            if e["observed_recall"] is not None:
                assert 0.0 <= e["observed_recall"] <= 1.0
    assert any(e["kind"] == "observe" for e in trace)


def test_trace_is_bounded():
    t = AutoTuner(AutoTunerConfig(seed=0, max_trace=32))
    key = workload_key(**K9)
    _drive(t, key, n=100)
    assert len(t.trace()) == 32


def test_snapshot_is_jsonable():
    import json

    t = AutoTuner(AutoTunerConfig(seed=0))
    _drive(t, workload_key(**K9), n=40)
    json.dumps(t.snapshot())


def test_advise_global_flags_lagging_ingest():
    t = AutoTuner()
    lagging = {"lag_entries": 5000, "runs_pending_merge": 3}
    ids = [e.node_id for e in t.advise_global(lagging, n_series=1 << 21)]
    assert "advise/ingest-async" in ids and "advise/shard-mesh" in ids
    ids = [e.node_id for e in t.advise_global(
        {"lag_entries": 0, "runs_pending_merge": 0}, n_series=1000)]
    assert ids == ["advise/ingest-ok"]


# ---------------------------------------------------------------- gateway
@pytest.fixture(scope="module")
def small_index():
    from repro.core import StreamConfig, StreamingIndex, SummarizationConfig

    scfg = SummarizationConfig(series_len=32, n_segments=8, card_bits=8)
    idx = StreamingIndex(StreamConfig(
        scheme="BTP", summarization=scfg, buffer_entries=256,
        growth_factor=4, block_size=64))
    rng = np.random.default_rng(0)
    for b in range(3):
        x = np.cumsum(rng.normal(size=(200, 32)), axis=1,
                      dtype=np.float64).astype(np.float32)
        idx.ingest(x, np.full(200, b, np.int64))
    yield idx
    idx.close()


def test_gateway_autotune_parity_and_feedback(small_index):
    """With the tuner routing, gateway answers stay bitwise-equal to the
    direct engine call at whatever tier was served, and every served
    batch feeds observations back into the tuner."""
    from repro.core import Gateway, GatewayConfig

    gw = Gateway(small_index, GatewayConfig(
        deadline_ms=2.0, max_batch=8, k=3, autotune=True,
        autotune_cfg=AutoTunerConfig(seed=0)))
    try:
        rng = np.random.default_rng(9)
        Q = np.cumsum(rng.normal(size=(24, 32)), axis=1,
                      dtype=np.float64).astype(np.float32)
        resps = [gw.submit(Q[i], target_recall=0.9).result(timeout=60)
                 for i in range(Q.shape[0])]
        for i, r in enumerate(resps):
            if r.tier_served == "exact":
                vals, gids, _ = small_index.knn_batch(Q[i][None], k=3)
            else:
                vals, gids, _ = small_index.knn_approx_batch(
                    Q[i][None], k=3, n_blocks=max(r.n_blocks, 1))
            np.testing.assert_array_equal(r.ids, gids[0])
            np.testing.assert_array_equal(r.vals, vals[0])
        st = gw.snapshot()
        assert st.autotune
        assert st.tuner_decisions >= len(resps)
        assert st.tuner_observations >= len(resps)
        trace = gw.tuner.trace()
        assert any(e["kind"] == "observe" for e in trace)
        assert any(e["kind"] == "decide" for e in trace)
    finally:
        gw.close()


def test_gateway_strict_requests_stay_exact_under_autotune(small_index):
    from repro.core import Gateway, GatewayConfig

    gw = Gateway(small_index, GatewayConfig(
        deadline_ms=2.0, max_batch=8, k=3, autotune=True,
        autotune_cfg=AutoTunerConfig(seed=0, epsilon=1.0)))
    try:
        rng = np.random.default_rng(2)
        Q = np.cumsum(rng.normal(size=(10, 32)), axis=1,
                      dtype=np.float64).astype(np.float32)
        for i in range(Q.shape[0]):
            r = gw.submit(Q[i], target_recall=1.0).result(timeout=60)
            assert r.tier_served == "exact" and not r.shed
    finally:
        gw.close()
