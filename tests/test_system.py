"""End-to-end behaviour tests mirroring the paper's two demo scenarios."""
import numpy as np

from repro.core import (
    ADSConfig,
    ADSIndex,
    CTree,
    CTreeConfig,
    DiskModel,
    RawStore,
    Scenario,
    StreamConfig,
    StreamingIndex,
    SummarizationConfig,
    ed2,
    recommend,
)
from repro.data.synthetic import astronomy, seismic

CFG = SummarizationConfig(series_len=128, n_segments=16, card_bits=6)


def test_scenario1_static_exploration():
    """Big static series: recommender picks non-mat CTree; it matches ADS+
    answers exactly while doing strictly less random I/O."""
    X = astronomy(4000, 128, seed=3)
    queries = astronomy(4, 128, seed=77)

    rec = recommend(Scenario(streaming=False, n_series=len(X), series_len=128,
                             expected_queries=4))
    assert rec.index == "ctree" and not rec.materialized

    d_ct = DiskModel()
    raw_ct = RawStore(128, d_ct)
    ids = raw_ct.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=256,
                           materialized=rec.materialized,
                           mem_budget_entries=rec.mem_budget_entries), d_ct)
    ct.bulk_build(X, ids)

    d_ads = DiskModel()
    raw_ads = RawStore(128, d_ads)
    ids2 = raw_ads.append(X)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=256), d_ads)
    ads.insert_batch(X, ids2)

    build_rand_ct = d_ct.stats.rand_ops
    build_rand_ads = d_ads.stats.rand_ops
    assert build_rand_ct == 0 and build_rand_ads > len(X)

    for q in queries:
        r1, _ = ct.knn_exact(q, k=3, raw=raw_ct)
        r2, _ = ads.knn_exact(q, k=3, raw=raw_ads)
        np.testing.assert_allclose([d for d, _ in r1], [d for d, _ in r2], rtol=1e-5)
        bf = np.sort(ed2(q, X))[:3]
        np.testing.assert_allclose([d for d, _ in r1], bf, rtol=1e-4)


def test_scenario2_streaming_exploration():
    """Seismic stream with window queries: recommender picks CLSM+BTP; the
    index keeps answering exactly while ingesting."""
    rec = recommend(Scenario(streaming=True, n_series=10**5, uses_windows=True,
                             ingest_rate=1e4))
    assert (rec.index, rec.scheme) == ("clsm", "BTP")

    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                      buffer_entries=1024,
                                      growth_factor=rec.growth_factor,
                                      block_size=128))
    xs, ts = [], []
    for b in range(20):
        x = seismic(300, 128, seed=b)
        t = np.full(300, b, np.int64)
        idx.ingest(x, t)
        xs.append(x)
        ts.append(t)
        if b in (5, 19):  # query mid-stream
            q = seismic(1, 128, seed=1000 + b)[0]
            res, _ = idx.window_knn(q, max(0, b - 3), b, k=2)
            X = np.concatenate(xs)
            T = np.concatenate(ts)
            m = (T >= max(0, b - 3)) & (T <= b)
            bf = np.sort(ed2(q, X[m]))[:2]
            np.testing.assert_allclose([d for d, _ in res], bf, rtol=1e-4)
    assert idx.n_partitions <= idx.lsm.n_flushes


def test_heatmap_shows_contiguous_ctree_access():
    """The demo's heat map: CTree approximate query touches one contiguous
    region; ADS+ random descent scatters."""
    X = astronomy(3000, 128, seed=9)
    disk = DiskModel(keep_log=True)
    raw = RawStore(128, disk)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=128, materialized=True), disk)
    ct.bulk_build(X, ids)
    disk.log.clear()
    q = astronomy(1, 128, seed=321)[0]
    ct.knn_approx(q, k=1, n_blocks=2, raw=raw)
    kinds = {k for _, _, k in disk.log}
    assert "rs" in kinds and "rr" not in kinds  # sequential only


def test_pipeline_series_view_feeds_streaming_index():
    """Framework integration: the LM data pipeline tees a series view of its
    stream into a Coconut index (the §Arch-applicability hook)."""
    from repro.configs import get_config
    from repro.data.pipeline import PipelineConfig, TokenPipeline

    mc = get_config("hubert-xlarge", smoke=True)
    pipe = TokenPipeline(PipelineConfig(global_batch=4, seq_len=64, seed=0), mc)
    scfg = SummarizationConfig(series_len=32, n_segments=8, card_bits=4)
    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=scfg,
                                      buffer_entries=64, block_size=32))
    for step in range(5):
        batch = pipe.batch(step)
        view = pipe.series_view(batch, 32)
        assert view is not None and view.shape[1] == 32
        idx.ingest(view.astype(np.float32), np.full(len(view), step, np.int64))
    q = pipe.series_view(pipe.batch(99), 32)[0].astype(np.float32)
    res, _ = idx.window_knn(q, 0, 4, k=1)
    assert len(res) == 1
