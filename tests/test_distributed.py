"""Distributed Coconut (shard_map sample-sort + query) on 8 CPU devices.

Runs in a subprocess because jax pins the device count at first init.
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import SummarizationConfig, ed2
from repro.core.distributed import DistBuildConfig, make_build_fn, make_query_fn

mesh = make_mesh((8,), ("data",))
scfg = SummarizationConfig(series_len=64, n_segments=8, card_bits=8)
cfg = DistBuildConfig(summarization=scfg, capacity_slack=3.0)
rng = np.random.default_rng(0)
N = 8 * 256
X = rng.standard_normal((N, 64)).astype(np.float32).cumsum(axis=1)
ids = np.arange(N, dtype=np.int32)
build = make_build_fn(mesh, ("data",), cfg)
idx = build(jnp.asarray(X), jnp.asarray(ids))
assert int(idx["overflow"]) == 0, "bucket overflow"
keys = np.asarray(idx["keys"]); inval = np.asarray(idx["invalid"])
assert int(np.asarray(idx["n_valid"]).sum()) == N
valid = [tuple(r) for r in keys[inval == 0]]
assert valid == sorted(valid), "global sort order violated"

query = make_query_fn(mesh, ("data",), cfg, k=5, verify_budget=N)
Q = rng.standard_normal((3, 64)).astype(np.float32).cumsum(axis=1)
d2, qids = query(idx, jnp.asarray(Q))
for i in range(3):
    bf = np.sort(ed2(Q[i], X))[:5]
    np.testing.assert_allclose(np.sort(np.asarray(d2)[i]), bf, rtol=1e-4)
# ids must point at the right series
for i in range(3):
    got = np.sort(np.asarray(d2)[i])
    via_ids = np.sort(ed2(Q[i], X[np.asarray(qids)[i]]))
    np.testing.assert_allclose(got, via_ids, rtol=1e-4)
print("DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_distributed_build_and_query_8dev():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=420,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "DISTRIBUTED_OK" in r.stdout
