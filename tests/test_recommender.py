"""The recommender must reproduce the paper's demo narratives."""
from repro.core import Scenario, recommend


def test_scenario1_static_few_queries_nonmat_ctree_pp():
    rec = recommend(Scenario(streaming=False, n_series=10**6, expected_queries=5,
                             uses_windows=True))
    assert rec.index == "ctree" and not rec.materialized and rec.scheme == "PP"
    assert rec.rationale


def test_scenario1_many_queries_flips_to_materialized():
    few = recommend(Scenario(streaming=False, n_series=10**6, expected_queries=5))
    many = recommend(Scenario(streaming=False, n_series=10**6, expected_queries=10**7))
    assert not few.materialized and many.materialized


def test_scenario2_streaming_clsm_btp():
    rec = recommend(Scenario(streaming=True, n_series=10**6, uses_windows=True,
                             ingest_rate=1e4))
    assert rec.index == "clsm" and rec.scheme == "BTP" and not rec.materialized


def test_streaming_without_windows_uses_pp():
    rec = recommend(Scenario(streaming=True, n_series=10**5, uses_windows=False))
    assert rec.scheme == "PP"


def test_write_heavy_stream_gets_larger_growth_factor():
    writey = recommend(Scenario(streaming=True, n_series=10**6, uses_windows=True,
                                ingest_rate=1e6, expected_queries=10))
    ready = recommend(Scenario(streaming=True, n_series=10**6, uses_windows=True,
                               ingest_rate=1.0, expected_queries=10**5))
    assert writey.growth_factor > ready.growth_factor


def test_memory_budget_reflected_in_rationale():
    rec = recommend(Scenario(streaming=False, n_series=10**7, series_len=256,
                             memory_budget_bytes=64 << 20))
    assert any("two-pass" in r for r in rec.rationale)
    assert rec.mem_budget_entries * 256 * 4 <= (64 << 20) + 2**20


def test_describe_renders():
    rec = recommend(Scenario(streaming=True, n_series=1000, uses_windows=True))
    text = rec.describe()
    assert "CLSM" in text and "because" in text


# ---------------------------------------------------------------------------
# serving-tier node: tier + n_blocks from target recall / latency budget
# ---------------------------------------------------------------------------
def test_no_targets_keeps_exact_tier():
    rec = recommend(Scenario(streaming=False, n_series=10**6))
    assert rec.tier == "exact" and rec.n_blocks == 0


def test_target_recall_one_requires_exact_tier():
    rec = recommend(Scenario(streaming=False, n_series=10**6, target_recall=1.0))
    assert rec.tier == "exact"
    assert any("exact tier" in r for r in rec.rationale)


def test_relaxed_recall_picks_approx_tier():
    rec = recommend(Scenario(streaming=True, n_series=10**7, uses_windows=True,
                             target_recall=0.8))
    assert rec.tier == "approx" and rec.n_blocks >= 1


def test_higher_target_recall_needs_more_blocks():
    lo = recommend(Scenario(streaming=False, n_series=10**6, target_recall=0.5))
    hi = recommend(Scenario(streaming=False, n_series=10**6, target_recall=0.95))
    assert lo.tier == hi.tier == "approx"
    assert hi.n_blocks > lo.n_blocks


def test_tight_latency_budget_flips_to_approx_and_caps_blocks():
    # exact modeled cost for 10M series >> 0.05 ms -> approx tier
    tight = recommend(Scenario(streaming=False, n_series=10**7,
                               latency_budget_ms=0.05))
    assert tight.tier == "approx"
    # and the budget caps the sequential read depth
    loose = recommend(Scenario(streaming=False, n_series=10**7,
                               target_recall=0.95, latency_budget_ms=100.0))
    capped = recommend(Scenario(streaming=False, n_series=10**7,
                                target_recall=0.95, latency_budget_ms=0.3))
    assert capped.n_blocks <= loose.n_blocks


def test_conflicting_recall_and_latency_targets_warn():
    """When the latency cap pushes n_blocks below what the recall target
    needs, the rationale must say so instead of silently citing the
    pre-cap recall."""
    rec = recommend(Scenario(streaming=False, n_series=10**7,
                             target_recall=0.95, latency_budget_ms=0.3))
    from repro.core.recommender import _approx_recall_model
    if _approx_recall_model(rec.n_blocks) < 0.95:
        assert any("WARNING" in r for r in rec.rationale)


def test_generous_latency_budget_keeps_exact():
    rec = recommend(Scenario(streaming=False, n_series=10**4,
                             latency_budget_ms=100.0))
    assert rec.tier == "exact"


def test_query_batch_amortization_in_rationale():
    rec = recommend(Scenario(streaming=True, n_series=10**6, uses_windows=True,
                             target_recall=0.7, query_batch=64))
    assert rec.tier == "approx"
    assert any("coalesced" in r or "amortiz" in r for r in rec.rationale)


def test_approx_tier_renders_in_describe():
    rec = recommend(Scenario(streaming=True, n_series=10**6, uses_windows=True,
                             target_recall=0.8))
    assert "approx tier" in rec.describe()


# ----------------------------------------------- structured tier decisions
def test_conflict_is_a_structured_flag_not_just_a_string():
    """The recall/latency conflict must surface as ``Recommendation.conflict``
    (and ``TierDecision.conflict``) so admission layers can act on it — the
    WARNING rationale line and the flag must agree."""
    rec = recommend(Scenario(streaming=False, n_series=10**7,
                             target_recall=0.95, latency_budget_ms=0.3))
    warned = any("WARNING" in r for r in rec.rationale)
    assert rec.conflict == warned
    clean = recommend(Scenario(streaming=False, n_series=10**7,
                               target_recall=0.9))
    assert not clean.conflict
    assert not any("WARNING" in r for r in clean.rationale)


def test_serving_tier_standalone_matches_recommend():
    from repro.core import serving_tier

    s = Scenario(streaming=True, n_series=10**6, uses_windows=True,
                 target_recall=0.95, latency_budget_ms=0.3)
    dec = serving_tier(s)
    rec = recommend(s)
    assert (dec.tier, dec.n_blocks, dec.conflict) == \
        (rec.tier, rec.n_blocks, rec.conflict)
    assert dec.conflict == any("WARNING" in r for r in dec.rationale)


def test_serving_tier_is_deterministic_per_profile():
    """Mixed-tenant admission caches decisions per request profile: the
    same Scenario must always produce the identical TierDecision."""
    from repro.core import serving_tier

    profiles = [
        Scenario(streaming=True, n_series=10**6, target_recall=1.0),
        Scenario(streaming=True, n_series=10**6, target_recall=0.9,
                 latency_budget_ms=0.05),
        Scenario(streaming=True, n_series=10**6, target_recall=0.8),
        Scenario(streaming=True, n_series=10**6),
    ]
    first = [serving_tier(p) for p in profiles]
    for _ in range(3):
        assert [serving_tier(p) for p in profiles] == first
    # strict recall is never conflicted; tight budgets under a recall
    # target are — that split is what the gateway sheds on
    assert first[0].tier == "exact" and not first[0].conflict
    assert first[1].tier == "approx" and first[1].conflict
    assert first[3] == serving_tier(profiles[3])


# --------------------------------------------- structured decision surface
def test_rationale_entries_carry_stable_node_ids():
    rec = recommend(Scenario(streaming=True, n_series=10**6, uses_windows=True,
                             target_recall=0.9, latency_budget_ms=0.3))
    ids = [e.node_id for e in rec.rationale]
    assert all("/" in i for i in ids), ids
    assert "ingest/streaming" in ids
    assert any(i.startswith("serve/") for i in ids)
    # node ids render in describe() so logs stay greppable by machine key
    assert f"[{ids[0]}]" in rec.describe()


def test_decision_objects_are_frozen():
    import dataclasses as dc

    import pytest

    rec = recommend(Scenario(streaming=False, n_series=10**6,
                             target_recall=0.8))
    with pytest.raises(dc.FrozenInstanceError):
        rec.index = "clsm"
    with pytest.raises(dc.FrozenInstanceError):
        rec.decision.tier = "exact"
    with pytest.raises(dc.FrozenInstanceError):
        rec.rationale[0].text = "x"


def test_embedded_decision_matches_standalone_serving_tier():
    from repro.core import serving_tier

    s = Scenario(streaming=True, n_series=10**7, uses_windows=True,
                 target_recall=0.85, query_batch=16)
    assert recommend(s).decision == serving_tier(s)


def test_embedded_decision_rationale_is_the_serving_slice():
    """The embedded TierDecision carries ONLY its own serve/* steps, not
    the whole tree's chain."""
    rec = recommend(Scenario(streaming=True, n_series=10**6,
                             uses_windows=True, target_recall=0.8))
    assert rec.decision.rationale
    assert all(e.node_id.startswith("serve/")
               for e in rec.decision.rationale)
    assert len(rec.decision.rationale) < len(rec.rationale)


def test_rationale_entry_back_compat_reads_as_string():
    e = recommend(Scenario(streaming=False, n_series=10**6)).rationale[0]
    assert str(e) == e.text
    assert e.text[:4] in e  # __contains__ matches the text


def test_exact_fits_budget_beats_approx_regression():
    """Regression (serving-tier bugfix): with a sub-1.0 recall target AND
    a budget the exact tier fits, exact must win — the old tree jumped to
    approx whenever target_recall < 1.0 and then flagged a phantom
    conflict."""
    from repro.core import serving_tier

    dec = serving_tier(Scenario(streaming=False, n_series=10**4,
                                target_recall=0.9, latency_budget_ms=100.0))
    assert dec.tier == "exact" and dec.n_blocks == 0
    assert not dec.conflict
    assert "serve/exact-fits-budget" in [e.node_id for e in dec.rationale]
