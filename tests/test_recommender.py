"""The recommender must reproduce the paper's demo narratives."""
from repro.core import Scenario, recommend


def test_scenario1_static_few_queries_nonmat_ctree_pp():
    rec = recommend(Scenario(streaming=False, n_series=10**6, expected_queries=5,
                             uses_windows=True))
    assert rec.index == "ctree" and not rec.materialized and rec.scheme == "PP"
    assert rec.rationale


def test_scenario1_many_queries_flips_to_materialized():
    few = recommend(Scenario(streaming=False, n_series=10**6, expected_queries=5))
    many = recommend(Scenario(streaming=False, n_series=10**6, expected_queries=10**7))
    assert not few.materialized and many.materialized


def test_scenario2_streaming_clsm_btp():
    rec = recommend(Scenario(streaming=True, n_series=10**6, uses_windows=True,
                             ingest_rate=1e4))
    assert rec.index == "clsm" and rec.scheme == "BTP" and not rec.materialized


def test_streaming_without_windows_uses_pp():
    rec = recommend(Scenario(streaming=True, n_series=10**5, uses_windows=False))
    assert rec.scheme == "PP"


def test_write_heavy_stream_gets_larger_growth_factor():
    writey = recommend(Scenario(streaming=True, n_series=10**6, uses_windows=True,
                                ingest_rate=1e6, expected_queries=10))
    ready = recommend(Scenario(streaming=True, n_series=10**6, uses_windows=True,
                               ingest_rate=1.0, expected_queries=10**5))
    assert writey.growth_factor > ready.growth_factor


def test_memory_budget_reflected_in_rationale():
    rec = recommend(Scenario(streaming=False, n_series=10**7, series_len=256,
                             memory_budget_bytes=64 << 20))
    assert any("two-pass" in r for r in rec.rationale)
    assert rec.mem_budget_entries * 256 * 4 <= (64 << 20) + 2**20


def test_describe_renders():
    rec = recommend(Scenario(streaming=True, n_series=1000, uses_windows=True))
    text = rec.describe()
    assert "CLSM" in text and "because" in text
