"""Sortable-key interleaving invariants.

Property tests run under hypothesis when it is installed; a deterministic
seed sweep over the same bodies keeps tier-1 coverage when it is not.
"""
import numpy as np
import pytest

from repro.core import SummarizationConfig, interleave, deinterleave, sort_by_keys
from repro.core.sortable import (
    keys_less,
    keys_less_equal,
    searchsorted_keys,
    searchsorted_keys_batch,
)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dependency; deterministic sweeps below cover tier-1
    given = None

CFGS = [
    SummarizationConfig(64, 8, 4),
    SummarizationConfig(64, 8, 8),
    SummarizationConfig(128, 16, 8),
    SummarizationConfig(96, 12, 6),
    SummarizationConfig(64, 16, 2),
]


def _check_interleave_roundtrip(cfg, seed):
    rng = np.random.default_rng(seed)
    sym = rng.integers(0, cfg.cardinality, (32, cfg.n_segments)).astype(np.int32)
    keys = interleave(sym, cfg)
    assert keys.dtype == np.uint32 and keys.shape == (32, cfg.key_words)
    back = deinterleave(keys, cfg)
    np.testing.assert_array_equal(back, sym)


def _check_key_order_is_msb_first(cfg, seed):
    """The paper's core property: flipping a MORE significant bit of any
    segment moves the key further than flipping a less significant bit of
    any other segment — similarity in all segments' high bits dominates."""
    rng = np.random.default_rng(seed)
    sym = rng.integers(0, cfg.cardinality, (cfg.n_segments,)).astype(np.int32)
    if cfg.card_bits < 2:
        return
    base = interleave(sym[None], cfg)[0]
    hi_seg = int(rng.integers(cfg.n_segments))
    lo_seg = int(rng.integers(cfg.n_segments))
    hi = sym.copy()
    hi[hi_seg] ^= 1 << (cfg.card_bits - 1)  # flip MSB of one segment
    lo = sym.copy()
    lo[lo_seg] ^= 1  # flip LSB of another
    k_hi = interleave(hi[None], cfg)[0]
    k_lo = interleave(lo[None], cfg)[0]

    def key_int(k):
        v = 0
        for w in k:
            v = (v << 32) | int(w)
        return v

    assert abs(key_int(k_hi) - key_int(base)) > abs(key_int(k_lo) - key_int(base))


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: f"w{c.n_segments}c{c.card_bits}")
@pytest.mark.parametrize("seed", [0, 1, 12345, 2**31 - 1])
def test_interleave_roundtrip(cfg, seed):
    _check_interleave_roundtrip(cfg, seed)


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: f"w{c.n_segments}c{c.card_bits}")
@pytest.mark.parametrize("seed", [0, 7, 999, 2**30])
def test_key_order_is_msb_first(cfg, seed):
    _check_key_order_is_msb_first(cfg, seed)


if given is not None:

    @given(st.sampled_from(CFGS), st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_interleave_roundtrip_hypothesis(cfg, seed):
        _check_interleave_roundtrip(cfg, seed)

    @given(st.sampled_from(CFGS), st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_key_order_is_msb_first_hypothesis(cfg, seed):
        _check_key_order_is_msb_first(cfg, seed)


def test_sort_by_keys_sorts_lexicographically(rng):
    cfg = SummarizationConfig(64, 8, 8)
    sym = rng.integers(0, 256, (500, 8)).astype(np.int32)
    keys = interleave(sym, cfg)
    payload = np.arange(500)
    skeys, spay, order = sort_by_keys(keys, payload)
    as_tuples = [tuple(r) for r in skeys]
    assert as_tuples == sorted(as_tuples)
    np.testing.assert_array_equal(keys[order], skeys)
    np.testing.assert_array_equal(payload[order], spay)


def test_sorted_order_clusters_similar_series(rng):
    """Sorting by interleaved keys keeps near-duplicate series adjacent —
    plain concatenated-SAX order does not (the motivating example)."""
    cfg = SummarizationConfig(64, 8, 8)
    base = rng.standard_normal((100, 64)).astype(np.float32).cumsum(axis=1)
    near = base + 0.01 * rng.standard_normal((100, 64)).astype(np.float32)
    from repro.core import sax
    all_series = np.concatenate([base, near])
    sym = sax(all_series, cfg).astype(np.int32)
    keys = interleave(sym, cfg)
    _, ids, _ = sort_by_keys(keys, np.arange(200))
    pos = np.empty(200, int)
    pos[ids] = np.arange(200)
    dist = np.abs(pos[:100] - pos[100:])
    assert np.median(dist) <= 8  # near-duplicates land close in sorted order


def test_keys_less_equal_and_searchsorted(rng):
    cfg = SummarizationConfig(64, 8, 8)
    sym = rng.integers(0, 256, (200, 8)).astype(np.int32)
    keys = interleave(sym, cfg)
    skeys = sort_by_keys(keys)[0]
    q = keys[13]
    pos = searchsorted_keys(skeys, q)
    if pos > 0:
        assert keys_less_equal(skeys[pos - 1][None], q[None])[0]
    tq = tuple(q)
    assert tuple(skeys[pos]) >= tq


def test_searchsorted_keys_batch_agrees_with_scalar(rng):
    """The vectorized lockstep binary search is the scalar oracle, m-wide
    (exhaustive parity on duplicates, hits, misses and both boundaries)."""
    cfg = SummarizationConfig(64, 8, 4)
    sym = rng.integers(0, 16, (300, 8)).astype(np.int32)  # small alphabet => dups
    skeys = sort_by_keys(interleave(sym, cfg))[0]
    qsym = rng.integers(0, 16, (150, 8)).astype(np.int32)
    qkeys = interleave(qsym, cfg)
    qkeys[:40] = skeys[rng.integers(0, 300, 40)]  # exact (duplicate) hits
    qkeys[40] = 0  # below everything
    qkeys[41] = 0xFFFFFFFF  # above everything
    got = searchsorted_keys_batch(skeys, qkeys)
    want = np.array([searchsorted_keys(skeys, q) for q in qkeys])
    np.testing.assert_array_equal(got, want)


def test_keys_less_is_strict_lexicographic():
    a = np.array([[1, 5], [1, 5], [1, 5], [2, 0]], np.uint32)
    b = np.array([[1, 5], [1, 6], [2, 0], [1, 9]], np.uint32)
    np.testing.assert_array_equal(keys_less(a, b), [False, True, True, False])
    np.testing.assert_array_equal(keys_less(b, a), [False, False, False, True])
