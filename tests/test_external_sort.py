import numpy as np
import pytest

from repro.core import DiskModel, SummarizationConfig, external_sort_order, interleave


def _keys(n, seed=0):
    cfg = SummarizationConfig(64, 8, 8)
    rng = np.random.default_rng(seed)
    sym = rng.integers(0, 256, (n, 8)).astype(np.int32)
    return interleave(sym, cfg)


@pytest.mark.parametrize("budget", [10_000, 1000, 137, 32])
def test_order_matches_full_sort(budget):
    keys = _keys(1000)
    order, report = external_sort_order(keys, budget)
    ref = np.lexsort(tuple(keys[:, i] for i in range(keys.shape[1] - 1, -1, -1)))
    skeys = keys[order]
    as_tuples = [tuple(r) for r in skeys]
    assert as_tuples == sorted(as_tuples)
    np.testing.assert_array_equal(keys[ref], skeys)  # same stable order
    assert report.n_passes == (1 if budget >= 1000 else 2)


def test_io_accounting_two_pass():
    keys = _keys(1000)
    disk = DiskModel()
    _, report = external_sort_order(keys, 100, disk, payload_bytes_per_entry=256)
    entry = keys.shape[1] * 4 + 256
    # pass 1 reads + writes everything, merge pass reads + writes again
    assert disk.stats.seq_read_bytes == 2 * 1000 * entry
    assert disk.stats.seq_write_bytes == 2 * 1000 * entry
    assert disk.stats.rand_read_bytes == 0  # the paper's headline: no random I/O
    assert report.n_runs == 10


def test_single_pass_when_fits():
    keys = _keys(500)
    disk = DiskModel()
    _, report = external_sort_order(keys, 1000, disk, payload_bytes_per_entry=0)
    assert report.n_passes == 1
    assert disk.stats.seq_read_bytes == 500 * keys.shape[1] * 4
