"""Numerics of the attention/recurrence implementations against references."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.models.attention import flash_attention, naive_attention, sliding_attention
from repro.models.rglru import rglru_block, rglru_decode, rglru_init
from repro.models.rwkv6 import rwkv6_init, rwkv6_time_mix, rwkv6_time_mix_decode
from repro.models.common import KeyGen


def test_flash_equals_naive_causal(rng):
    B, S, H, KV, HD = 2, 2048, 8, 4, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, HD)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, KV, HD)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, KV, HD)), jnp.bfloat16)
    o1 = naive_attention(q, k, v, causal=True)
    o2 = flash_attention(q, k, v, causal=True, q_chunk=512, k_chunk=256)
    assert float(jnp.max(jnp.abs((o1 - o2).astype(jnp.float32)))) < 0.03


def test_flash_equals_naive_bidirectional(rng):
    B, S, H, KV, HD = 1, 1024, 4, 4, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, HD)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, S, KV, HD)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, KV, HD)), jnp.bfloat16)
    o1 = naive_attention(q, k, v, causal=False)
    o2 = flash_attention(q, k, v, causal=False, q_chunk=256, k_chunk=256)
    assert float(jnp.max(jnp.abs((o1 - o2).astype(jnp.float32)))) < 0.03


def test_sliding_window_equals_masked_naive(rng):
    B, S, H, KV, HD, W = 2, 256, 4, 2, 32, 64
    q = jnp.asarray(rng.standard_normal((B, S, H, HD)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, HD)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, HD)), jnp.float32)
    o = sliding_attention(q, k, v, W)
    # reference: naive with banded causal mask
    from repro.models.attention import _gqa_scores, _gqa_out, _softmax, NEG_INF
    s = _gqa_scores(q, k)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    mask = (kj <= qi) & (kj > qi - W)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    o_ref = _gqa_out(_softmax(s), v)
    assert float(jnp.max(jnp.abs((o - o_ref).astype(jnp.float32)))) < 0.01


def _rwkv_naive(p, x, head_dim, state, x_prev):
    """Token-by-token recurrence oracle built from the decode step."""
    outs = []
    for t in range(x.shape[1]):
        o, state, x_prev = rwkv6_time_mix_decode(p, x[:, t : t + 1], head_dim, state, x_prev)
        outs.append(o)
    return jnp.concatenate(outs, axis=1), state


def test_rwkv6_chunked_equals_sequential(rng):
    D, HD, B, S = 32, 16, 2, 40  # S not a chunk multiple on purpose
    p = rwkv6_init(KeyGen(jax.random.PRNGKey(0)), D, HD, 64)
    x = jnp.asarray(rng.standard_normal((B, S, D)) * 0.5, jnp.float32)
    state0 = jnp.zeros((B, D // HD, HD, HD), jnp.float32)
    xprev0 = jnp.zeros((B, D), jnp.float32)
    o_chunk, s_chunk, _ = rwkv6_time_mix(p, x, HD, state0, xprev0)
    o_seq, s_seq = _rwkv_naive(p, x, HD, state0, xprev0)
    np.testing.assert_allclose(np.asarray(o_chunk, np.float32),
                               np.asarray(o_seq, np.float32), atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s_seq), atol=2e-3)


def test_rglru_scan_equals_sequential(rng):
    D, R, B, S = 24, 32, 2, 17
    p = rglru_init(KeyGen(jax.random.PRNGKey(0)), D, R)
    x = jnp.asarray(rng.standard_normal((B, S, D)) * 0.5, jnp.float32)
    h0 = jnp.zeros((B, R), jnp.float32)
    tail = jnp.zeros((B, 3, R), jnp.float32)
    o_scan, h_scan, _ = rglru_block(p, x, h0, tail)
    outs = []
    h, tl = h0, tail
    for t in range(S):
        o, h, tl = rglru_decode(p, x[:, t : t + 1], h, tl)
        outs.append(o)
    o_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(o_scan), np.asarray(o_seq), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h), atol=2e-4)
