"""The batched approximate serving tier.

Three layers of guarantees:

1. sortable-key invariants: the vectorized ``searchsorted_keys_batch`` agrees
   with the scalar oracle on random AND adversarial key sets, and
   ``interleave`` is order-preserving (componentwise SAX order maps into
   lexicographic key order — the property that makes one key seek find the
   whole neighborhood).
2. parity: ``knn_approx_batch`` on every index returns the same
   (distance, id) sets as a loop of per-query ``knn_approx`` at equal
   ``n_blocks``.
3. recall: batched recall@10 against the exact oracle equals (hence is >=)
   the per-query baseline on the synthetic random-walk dataset.

Property tests run under hypothesis when installed; deterministic seed
sweeps over the same bodies keep tier-1 coverage when it is not (the
``tests/conftest.py`` convention).
"""
import numpy as np
import pytest

from repro.core import (
    ADSConfig,
    ADSIndex,
    CLSM,
    CLSMConfig,
    CTree,
    CTreeConfig,
    RawStore,
    StreamConfig,
    StreamingIndex,
    SummarizationConfig,
    interleave,
    searchsorted_keys,
    searchsorted_keys_batch,
    sort_by_keys,
)
from repro.core.io_model import coalesce_ranges
from repro.core.sortable import keys_less, pack_u64

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dependency; deterministic sweeps below cover tier-1
    given = None

CFG = SummarizationConfig(series_len=64, n_segments=8, card_bits=6)

KEY_CFGS = [
    SummarizationConfig(64, 8, 4),
    SummarizationConfig(128, 16, 8),
    SummarizationConfig(96, 12, 6),
    SummarizationConfig(64, 16, 2),
]


def _random_walks(n, length=64, seed=0):
    r = np.random.default_rng(seed)
    return r.standard_normal((n, length)).astype(np.float32).cumsum(axis=1)


# ---------------------------------------------------------------------------
# 1. sortable-key invariants
# ---------------------------------------------------------------------------
def _check_searchsorted_batch_matches_scalar(cfg, seed, n=1000, m=64):
    rng = np.random.default_rng(seed)
    sym = rng.integers(0, cfg.cardinality, (n, cfg.n_segments)).astype(np.int32)
    skeys = sort_by_keys(interleave(sym, cfg))[0]
    qsym = rng.integers(0, cfg.cardinality, (m, cfg.n_segments)).astype(np.int32)
    qkeys = interleave(qsym, cfg)
    # mix in exact hits so left-insertion semantics are exercised
    qkeys[: m // 4] = skeys[rng.integers(0, n, m // 4)]
    got = searchsorted_keys_batch(skeys, qkeys)
    want = np.array([searchsorted_keys(skeys, q) for q in qkeys])
    np.testing.assert_array_equal(got, want)


def _check_interleave_preserves_sax_order(cfg, seed):
    """Componentwise symbol order maps into lexicographic key order: if
    a[s] <= b[s] for every segment, key(a) <= key(b). This is why a key
    seek lands inside the query's SAX neighborhood."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, cfg.cardinality, (64, cfg.n_segments)).astype(np.int32)
    delta = rng.integers(0, 3, (64, cfg.n_segments))
    b = np.minimum(a + delta, cfg.cardinality - 1).astype(np.int32)
    ka, kb = interleave(a, cfg), interleave(b, cfg)
    # not (kb < ka), elementwise over the batch
    assert not keys_less(kb, ka).any()
    # strict somewhere => strictly greater key
    strict = (b > a).any(axis=1)
    assert np.array_equal(keys_less(ka, kb)[strict],
                          np.ones(int(strict.sum()), bool))


@pytest.mark.parametrize("cfg", KEY_CFGS, ids=lambda c: f"w{c.n_segments}c{c.card_bits}")
@pytest.mark.parametrize("seed", [0, 1, 12345])
def test_searchsorted_batch_matches_scalar(cfg, seed):
    _check_searchsorted_batch_matches_scalar(cfg, seed)


@pytest.mark.parametrize("cfg", KEY_CFGS, ids=lambda c: f"w{c.n_segments}c{c.card_bits}")
@pytest.mark.parametrize("seed", [0, 7, 999])
def test_interleave_preserves_sax_order(cfg, seed):
    _check_interleave_preserves_sax_order(cfg, seed)


if given is not None:

    @given(st.sampled_from(KEY_CFGS), st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_searchsorted_batch_matches_scalar_hypothesis(cfg, seed):
        _check_searchsorted_batch_matches_scalar(cfg, seed, n=257, m=32)

    @given(st.sampled_from(KEY_CFGS), st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_interleave_preserves_sax_order_hypothesis(cfg, seed):
        _check_interleave_preserves_sax_order(cfg, seed)


def test_searchsorted_batch_adversarial_duplicates():
    """Duplicate keys: left insertion point must point at the FIRST equal
    row, exactly like the scalar oracle."""
    cfg = SummarizationConfig(64, 8, 4)
    rng = np.random.default_rng(3)
    sym = rng.integers(0, 4, (400, 8)).astype(np.int32)  # tiny alphabet => dups
    skeys = sort_by_keys(interleave(sym, cfg))[0]
    qkeys = skeys[rng.integers(0, 400, 128)]  # every query is a duplicate hit
    got = searchsorted_keys_batch(skeys, qkeys)
    want = np.array([searchsorted_keys(skeys, q) for q in qkeys])
    np.testing.assert_array_equal(got, want)
    # left semantics: predecessor (if any) is strictly less
    for p, q in zip(got, qkeys):
        assert tuple(skeys[p]) == tuple(q)
        if p > 0:
            assert tuple(skeys[p - 1]) <= tuple(q)


def test_searchsorted_batch_all_equal_words():
    """All rows identical: every probe falls through every word comparison."""
    skeys = np.tile(np.array([[7, 7]], np.uint32), (100, 1))
    below = np.array([[7, 6]], np.uint32)
    equal = np.array([[7, 7]], np.uint32)
    above = np.array([[7, 8]], np.uint32)
    q = np.concatenate([below, equal, above])
    got = searchsorted_keys_batch(skeys, q)
    np.testing.assert_array_equal(got, [0, 0, 100])
    want = [searchsorted_keys(skeys, x) for x in q]
    np.testing.assert_array_equal(got, want)


def test_searchsorted_batch_boundaries_and_empty():
    cfg = SummarizationConfig(64, 8, 8)
    rng = np.random.default_rng(4)
    sym = rng.integers(1, 255, (300, 8)).astype(np.int32)
    skeys = sort_by_keys(interleave(sym, cfg))[0]
    lo_q = np.zeros((1, cfg.key_words), np.uint32)  # below everything
    hi_q = np.full((1, cfg.key_words), 0xFFFFFFFF, np.uint32)  # above everything
    got = searchsorted_keys_batch(skeys, np.concatenate([lo_q, hi_q]))
    np.testing.assert_array_equal(got, [0, 300])
    # empty haystack and empty batch
    np.testing.assert_array_equal(
        searchsorted_keys_batch(np.zeros((0, 2), np.uint32), hi_q[:, :2]), [0]
    )
    assert searchsorted_keys_batch(skeys, skeys[:0]).shape == (0,)


def test_searchsorted_batch_odd_word_count():
    """n_words odd exercises the pack_u64 zero-pad column."""
    rng = np.random.default_rng(5)
    keys = rng.integers(0, 2**32, (500, 3), dtype=np.uint64).astype(np.uint32)
    skeys = sort_by_keys(keys)[0]
    q = rng.integers(0, 2**32, (64, 3), dtype=np.uint64).astype(np.uint32)
    q[:16] = skeys[rng.integers(0, 500, 16)]
    got = searchsorted_keys_batch(skeys, q)
    want = np.array([searchsorted_keys(skeys, x) for x in q])
    np.testing.assert_array_equal(got, want)
    assert pack_u64(skeys).shape == (500, 2)


def test_coalesce_ranges():
    assert coalesce_ranges([]) == []
    assert coalesce_ranges([(5, 5), (9, 3)]) == []  # empty/inverted drop out
    assert coalesce_ranges([(0, 4), (2, 6), (6, 8), (10, 12)]) == [(0, 8), (10, 12)]
    assert coalesce_ranges([(10, 12), (0, 4)]) == [(0, 4), (10, 12)]
    assert coalesce_ranges([(0, 4), (1, 2)]) == [(0, 4)]


# ---------------------------------------------------------------------------
# 2. batch vs per-query parity + 3. recall vs the exact oracle
# ---------------------------------------------------------------------------
def _assert_same_result_sets(vals, gids, per_query, tag=""):
    """Batched (m, k) rows match per-query [(d2, id)] lists as sets."""
    for i, res in enumerate(per_query):
        bd = vals[i][np.isfinite(vals[i])]
        bi = sorted(int(g) for g in gids[i] if g >= 0)
        sd = sorted(d for d, _ in res)
        si = sorted(i2 for _, i2 in res)
        assert len(sd) == len(bd), f"{tag} q{i}: {len(sd)} vs {len(bd)}"
        np.testing.assert_allclose(sorted(bd), sd, rtol=1e-5, err_msg=f"{tag} q{i}")
        assert bi == si, f"{tag} q{i}: ids {bi} vs {si}"


def _recall(approx_ids, exact_ids):
    hits = sum(
        len(set(map(int, a[a >= 0])) & set(map(int, e[e >= 0])))
        for a, e in zip(approx_ids, exact_ids)
    )
    want = sum(int((e >= 0).sum()) for e in exact_ids)
    return hits / max(1, want)


@pytest.mark.parametrize("materialized", [False, True])
@pytest.mark.parametrize("n_blocks", [1, 3])
def test_ctree_knn_approx_batch_parity(materialized, n_blocks):
    X, Q = _random_walks(3000), _random_walks(10, seed=99)
    raw = RawStore(64)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=256,
                           materialized=materialized))
    ct.bulk_build(X, ids)
    vals, gids, stats = ct.knn_approx_batch(Q, k=10, n_blocks=n_blocks, raw=raw)
    per_q = [ct.knn_approx(q, k=10, n_blocks=n_blocks, raw=raw)[0] for q in Q]
    _assert_same_result_sets(vals, gids, per_q, f"ctree mat={materialized}")
    assert stats.blocks_visited > 0


def test_ctree_approx_recall_at_10_vs_exact():
    """Batched recall@10 equals the per-query baseline (same sets) and is
    therefore >= the seed's single-query recall on the RW dataset."""
    X, Q = _random_walks(4000), _random_walks(16, seed=5)
    raw = RawStore(64)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=256, materialized=True))
    ct.bulk_build(X, ids)
    _, exact_ids, _ = ct.knn_batch(Q, k=10, raw=raw)
    _, batch_ids, _ = ct.knn_approx_batch(Q, k=10, n_blocks=2, raw=raw)
    loop_ids = np.full_like(batch_ids, -1)
    for i, q in enumerate(Q):
        res, _ = ct.knn_approx(q, k=10, n_blocks=2, raw=raw)
        loop_ids[i, : len(res)] = [g for _, g in res]
    r_batch = _recall(batch_ids, exact_ids)
    r_loop = _recall(loop_ids, exact_ids)
    assert r_batch == pytest.approx(r_loop)  # identical candidate sets
    assert r_batch >= r_loop  # never below the single-query baseline
    assert r_batch > 0.2  # the seek actually lands in the neighborhood
    # more blocks read sequentially => recall can only improve
    _, wide_ids, _ = ct.knn_approx_batch(Q, k=10, n_blocks=8, raw=raw)
    assert _recall(wide_ids, exact_ids) >= r_batch


def test_ctree_knn_approx_batch_kernel_backend_parity():
    X, Q = _random_walks(2000), _random_walks(8, seed=11)
    raw = RawStore(64)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=256, materialized=True))
    ct.bulk_build(X, ids)
    v_np, g_np, _ = ct.knn_approx_batch(Q, k=5, n_blocks=2, raw=raw, backend="numpy")
    v_kr, g_kr, _ = ct.knn_approx_batch(Q, k=5, n_blocks=2, raw=raw, backend="kernel")
    np.testing.assert_allclose(v_np, v_kr, rtol=1e-5)
    np.testing.assert_array_equal(g_np, g_kr)


def test_knn_approx_batch_rejects_unknown_backend():
    X = _random_walks(300)
    raw = RawStore(64)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=128, materialized=True))
    ct.bulk_build(X, raw.append(X))
    with pytest.raises(ValueError, match="backend"):
        ct.knn_approx_batch(_random_walks(2, seed=1), k=3, raw=raw, backend="cuda")


def test_knn_approx_batch_empty_batch_and_k_exceeds_range():
    X = _random_walks(500)
    raw = RawStore(64)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=128, materialized=True))
    ct.bulk_build(X, raw.append(X))
    vals, gids, _ = ct.knn_approx_batch(np.zeros((0, 64), np.float32), k=3, raw=raw)
    assert vals.shape == (0, 3) and gids.shape == (0, 3)
    # k larger than one block's worth of candidates: tail is (inf, -1)
    vals, gids, _ = ct.knn_approx_batch(_random_walks(3, seed=2), k=200,
                                        n_blocks=1, raw=raw)
    assert vals.shape == (3, 200)
    filled = np.isfinite(vals)
    assert filled.sum(axis=1).max() <= 128  # at most one block each
    assert (gids[~filled] == -1).all()
    per_q = [ct.knn_approx(q, k=200, n_blocks=1, raw=raw)[0]
             for q in _random_walks(3, seed=2)]
    _assert_same_result_sets(vals, gids, per_q, "k>range")


def test_knn_approx_extreme_key_probes_tail_block():
    """A query whose key sorts above every stored key must probe the tail
    block, not fall off the end into an empty range (pos == n clamp) —
    scalar and batched paths together."""
    X = _random_walks(1024)  # n is an exact block_size multiple
    raw = RawStore(64)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=256, materialized=True))
    ct.bulk_build(X, raw.append(X))
    q_hi = np.full((1, 64), 100.0, np.float32)  # beyond every stored key
    q_lo = np.full((1, 64), -100.0, np.float32)
    for q in (q_hi, q_lo):
        res, st = ct.knn_approx(q[0], k=3, n_blocks=1, raw=raw)
        assert len(res) == 3 and st.blocks_visited == 1
        vals, gids, _ = ct.knn_approx_batch(q, k=3, n_blocks=1, raw=raw)
        assert np.isfinite(vals).all() and (gids >= 0).all()
        _assert_same_result_sets(vals, gids, [res], "extreme key")


def test_knn_approx_batch_coalesces_into_sequential_reads():
    """Identical queries must collapse to ONE sequential index read; the
    DiskModel sees the dedup win, not m copies of the same block."""
    X = _random_walks(2000)
    from repro.core import DiskModel
    disk = DiskModel()
    raw = RawStore(64, disk)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=256, materialized=True),
               disk)
    ct.bulk_build(X, raw.append(X))
    q = _random_walks(1, seed=8)
    Q = np.repeat(q, 32, axis=0)  # 32 copies of the same query
    disk.reset()
    ct.knn_approx_batch(Q, k=5, n_blocks=2, raw=raw)
    batched = disk.stats.seq_read_bytes
    seq_ops = disk.stats.seq_ops
    disk.reset()
    ct.knn_approx(q[0], k=5, n_blocks=2, raw=raw)
    single = disk.stats.seq_read_bytes
    assert batched == single  # 32 identical seeks -> one sequential range
    assert seq_ops <= 2  # one index-range read (+ one materialized fetch)
    assert disk.stats.rand_read_bytes == 0


def test_clsm_knn_approx_batch_parity_including_buffer():
    X = _random_walks(3900)
    lsm = CLSM(CLSMConfig(summarization=CFG, buffer_entries=512, growth_factor=3,
                          block_size=128, materialized=True))
    raw = RawStore(64)
    for i in range(0, 3900, 300):
        chunk = X[i : i + 300]
        lsm.insert(chunk, raw.append(chunk), np.full(len(chunk), i // 300, np.int64))
    assert lsm._buf_n > 0
    Q = _random_walks(8, seed=21)
    for window in (None, (2, 8)):
        vals, gids, _ = lsm.knn_approx_batch(Q, k=5, n_blocks=2, raw=raw,
                                             window=window)
        per_q = [lsm.knn_approx(q, k=5, n_blocks=2, raw=raw, window=window)[0]
                 for q in Q]
        _assert_same_result_sets(vals, gids, per_q, f"clsm win={window}")


@pytest.mark.parametrize("mode", ["full", "adaptive"])
def test_ads_knn_approx_batch_parity(mode):
    X = _random_walks(3000)
    raw = RawStore(64)
    ids = raw.append(X)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=512, mode=mode))
    ads.insert_batch(X, ids)
    Q = _random_walks(12, seed=31)
    vals, gids, stats = ads.knn_approx_batch(Q, k=5, raw=raw)
    per_q = [ads.knn_approx(q, k=5, raw=raw)[0] for q in Q]
    _assert_same_result_sets(vals, gids, per_q, f"ads {mode}")
    assert stats.blocks_visited == len(Q) - sum(1 for r in per_q if not r)


@pytest.mark.parametrize("scheme", ["PP", "TP", "BTP"])
def test_streaming_window_knn_approx_batch_parity(scheme):
    idx = StreamingIndex(StreamConfig(scheme=scheme, summarization=CFG,
                                      buffer_entries=1024, growth_factor=3,
                                      block_size=128))
    rng = np.random.default_rng(7)
    for b in range(15):
        x = rng.standard_normal((200, 64)).astype(np.float32).cumsum(axis=1)
        idx.ingest(x, np.full(200, b, np.int64))
    Q = _random_walks(8, seed=41)
    for t0, t1 in ((3, 9), (0, 14), (12, 14)):
        vals, gids, _ = idx.window_knn_approx_batch(Q, t0, t1, k=4, n_blocks=2)
        per_q = [idx.window_knn(q, t0, t1, k=4, exact=False, n_blocks=2)[0]
                 for q in Q]
        _assert_same_result_sets(vals, gids, per_q, f"{scheme} ({t0},{t1})")


def test_streaming_approx_recall_vs_exact_oracle():
    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                      buffer_entries=512, growth_factor=3,
                                      block_size=128))
    rng = np.random.default_rng(9)
    for b in range(12):
        x = rng.standard_normal((250, 64)).astype(np.float32).cumsum(axis=1)
        idx.ingest(x, np.full(250, b, np.int64))
    Q = _random_walks(10, seed=51)
    _, exact_ids, _ = idx.window_knn_batch(Q, 2, 10, k=10)
    _, approx_ids, _ = idx.window_knn_approx_batch(Q, 2, 10, k=10, n_blocks=2)
    loop_ids = np.full_like(approx_ids, -1)
    for i, q in enumerate(Q):
        res, _ = idx.window_knn(q, 2, 10, k=10, exact=False, n_blocks=2)
        loop_ids[i, : len(res)] = [g for _, g in res]
    r_batch, r_loop = _recall(approx_ids, exact_ids), _recall(loop_ids, exact_ids)
    assert r_batch == pytest.approx(r_loop)
    assert r_batch >= r_loop
    assert r_batch > 0.2
