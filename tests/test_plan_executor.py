"""The plan/execute layer: ADS+'s new batched exact tier, the PP
side-effect-free window path (regression for the old t_min/t_max
save/restore mutation hack), and cross-index executor invariants."""
import threading

import numpy as np
import pytest

from repro.core import (
    ADSConfig,
    ADSIndex,
    CTree,
    CTreeConfig,
    RawStore,
    StreamConfig,
    StreamingIndex,
    SummarizationConfig,
    ed2,
)

CFG = SummarizationConfig(series_len=64, n_segments=8, card_bits=6)


def _data(n=3000, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, 64)).astype(np.float32).cumsum(axis=1)


def _queries(m=10, seed=99):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((m, 64)).astype(np.float32).cumsum(axis=1)


# ---------------------------------------------------------------------------
# ADS+ batched exact tier (the index x tier matrix gap)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["full", "adaptive"])
@pytest.mark.parametrize("k", [1, 7])
def test_ads_knn_batch_exact_matches_brute_force(mode, k):
    X, Q = _data(), _queries()
    raw = RawStore(64)
    ids = raw.append(X)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=256, mode=mode,
                             query_leaf_size=64))
    ads.insert_batch(X, ids)
    vals, gids, stats = ads.knn_batch(Q, k=k, raw=raw)
    for i, q in enumerate(Q):
        bf = np.sort(ed2(q, X))[:k]
        np.testing.assert_allclose(vals[i], bf, rtol=1e-4)
        np.testing.assert_allclose(np.sort(ed2(q, X[gids[i]])), bf, rtol=1e-4)
    assert stats.blocks_visited > 0


@pytest.mark.parametrize("mode", ["full", "adaptive"])
def test_ads_knn_batch_matches_scalar_loop(mode):
    """Batch-vs-scalar parity: the batched path returns exactly the scalar
    answers (both are batch-of-N/1 plans over the same executor)."""
    X, Q = _data(2500, seed=3), _queries(8, seed=11)
    raw = RawStore(64)
    ids = raw.append(X)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=512, mode=mode,
                             query_leaf_size=128))
    ads.insert_batch(X, ids)
    vals, gids, _ = ads.knn_batch(Q, k=6, raw=raw)
    for i, q in enumerate(Q):
        res, _ = ads.knn_exact(q, k=6, raw=raw)
        np.testing.assert_allclose([d for d, _ in res], vals[i], rtol=1e-6)
        assert [g for _, g in res] == [int(g) for g in gids[i]]


def test_ads_knn_batch_window_filters_entries():
    X = _data(2000, seed=5)
    T = np.repeat(np.arange(20), 100).astype(np.int64)
    raw = RawStore(64)
    ids = raw.append(X)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=256))
    ads.insert_batch(X, ids, ts=T)
    Q = _queries(6, seed=7)
    vals, gids, _ = ads.knn_batch(Q, k=3, raw=raw, window=(4, 9))
    mask = (T >= 4) & (T <= 9)
    for i, q in enumerate(Q):
        bf = np.sort(ed2(q, X[mask]))[:3]
        np.testing.assert_allclose(vals[i], bf, rtol=1e-4)
        assert all(mask[g] for g in gids[i] if g >= 0)


def test_ads_adaptive_batch_splits_touched_leaves():
    """The plan's refine hook keeps ADS+'s query-time refinement: a batched
    query over a skeletal tree splits the oversized leaves it touches."""
    X = _data(3000)
    raw = RawStore(64)
    ids = raw.append(X)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=4096, mode="adaptive",
                             query_leaf_size=128))
    ads.insert_batch(X, ids)
    before = ads.n_splits
    vals, gids, _ = ads.knn_batch(_queries(4), k=3, raw=raw)
    assert ads.n_splits > before
    for i, q in enumerate(_queries(4)):
        bf = np.sort(ed2(q, X))[:3]
        np.testing.assert_allclose(vals[i], bf, rtol=1e-4)


def test_ads_knn_batch_empty_index_and_empty_batch():
    ads = ADSIndex(ADSConfig(summarization=CFG))
    vals, gids, _ = ads.knn_batch(_queries(3), k=4)
    assert (vals == np.inf).all() and (gids == -1).all()
    X = _data(200)
    raw = RawStore(64)
    ads.insert_batch(X, raw.append(X))
    vals, gids, _ = ads.knn_batch(np.zeros((0, 64), np.float32), k=4, raw=raw)
    assert vals.shape == (0, 4) and gids.shape == (0, 4)


def test_ads_knn_batch_kernel_backend_parity():
    X, Q = _data(1500), _queries(5)
    raw = RawStore(64)
    ids = raw.append(X)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=256))
    ads.insert_batch(X, ids)
    v_np, g_np, _ = ads.knn_batch(Q, k=5, raw=raw, backend="numpy")
    v_kr, g_kr, _ = ads.knn_batch(Q, k=5, raw=raw, backend="kernel")
    np.testing.assert_allclose(v_np, v_kr, rtol=1e-6)
    np.testing.assert_array_equal(g_np, g_kr)


# ---------------------------------------------------------------------------
# PP window queries are side-effect-free (regression: the old path saved,
# overwrote and restored run.t_min/t_max around every scalar PP query)
# ---------------------------------------------------------------------------
def _build_pp(seed=1, n_batches=12, bsz=200):
    idx = StreamingIndex(StreamConfig(scheme="PP", summarization=CFG,
                                      buffer_entries=512, growth_factor=3,
                                      block_size=128))
    rng = np.random.default_rng(seed)
    xs, ts = [], []
    for b in range(n_batches):
        x = rng.standard_normal((bsz, 64)).astype(np.float32).cumsum(axis=1)
        t = np.full(bsz, b, np.int64)
        idx.ingest(x, t)
        xs.append(x)
        ts.append(t)
    return idx, np.concatenate(xs), np.concatenate(ts)


def test_pp_window_knn_never_touches_run_metadata():
    idx, X, T = _build_pp()
    runs = idx.lsm.runs_newest_first()
    saved = [(r.t_min, r.t_max) for r in runs]
    q = _queries(1)[0]
    for exact in (True, False):
        res, _ = idx.window_knn(q, 3, 7, k=4, exact=exact)
        assert res
    idx.window_knn_batch(_queries(4), 3, 7, k=4)
    idx.window_knn_approx_batch(_queries(4), 3, 7, k=4, n_blocks=2)
    assert [(r.t_min, r.t_max) for r in runs] == saved
    # and the answers are still exact under PP entry-level filtering
    res, _ = idx.window_knn(q, 3, 7, k=4)
    mask = (T >= 3) & (T <= 7)
    bf = np.sort(ed2(q, X[mask]))[:4]
    np.testing.assert_allclose([d for d, _ in res], bf, rtol=1e-4)


def test_pp_concurrent_window_queries_do_not_corrupt_each_other():
    """Two PP window queries with different windows running concurrently:
    under the old mutation hack one thread's save/restore could clobber the
    other's forced time range; plan-level flags make this race-free."""
    idx, X, T = _build_pp(seed=2)
    Q = _queries(6, seed=8)
    windows = [(0, 4), (7, 11)]
    results = {}
    errors = []

    def worker(wi):
        try:
            t0, t1 = windows[wi]
            out = []
            for q in Q:
                res, _ = idx.window_knn(q, t0, t1, k=3)
                out.append(res)
            results[wi] = out
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    for wi, (t0, t1) in enumerate(windows):
        mask = (T >= t0) & (T <= t1)
        for q, res in zip(Q, results[wi]):
            bf = np.sort(ed2(q, X[mask]))[:3]
            np.testing.assert_allclose([d for d, _ in res], bf, rtol=1e-4)


# ---------------------------------------------------------------------------
# numerical hardening (review regressions)
# ---------------------------------------------------------------------------
def _adversarial(n, seed=0, offset=3000.0, spread=0.01):
    """Large common offset + tiny relative distances: the f32
    |q|^2 + |x|^2 - 2<q, x> cancellation trap."""
    rng = np.random.default_rng(seed)
    return (offset + spread * rng.standard_normal((n, 64))).astype(np.float32)


def test_exact_tier_is_exact_under_f32_cancellation():
    """knn_exact through the unflushed CLSM buffer (DenseSource) and a
    built CTree run (BlockSource) must return the true neighbors even when
    the f32 matmul-form distance cancels catastrophically — the slack-8
    screen is an approximate-tier tool only."""
    from repro.core import CLSM, CLSMConfig

    X = _adversarial(500)
    rng = np.random.default_rng(1)
    q = X[17] + 0.001 * rng.standard_normal(64).astype(np.float32)
    bf = ed2(q.astype(np.float64), X.astype(np.float64))
    want_ids = set(map(int, np.argsort(bf)[:5]))
    want_d = np.sort(bf)[:5]

    # buffered (DenseSource) path
    lsm = CLSM(CLSMConfig(summarization=CFG, buffer_entries=4096,
                          materialized=True))
    raw = RawStore(64)
    lsm.insert(X, raw.append(X), np.zeros(500, np.int64))
    assert lsm._buf_n == 500
    res, _ = lsm.knn_exact(q, k=5, raw=raw)
    assert set(g for _, g in res) == want_ids
    np.testing.assert_allclose([d for d, _ in res], want_d, rtol=1e-5)

    # built-run (BlockSource) path
    raw2 = RawStore(64)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=128, materialized=True))
    ct.bulk_build(X, raw2.append(X))
    res, _ = ct.knn_exact(q, k=5, raw=raw2)
    assert set(g for _, g in res) == want_ids
    np.testing.assert_allclose([d for d, _ in res], want_d, rtol=1e-5)


def test_ads_adaptive_split_patches_flat_cache_in_place():
    """Query-time splits must refine the cached leaf partition, not throw
    it away — the next query plans over the children without an O(N)
    rebuild."""
    X = _data(3000)
    raw = RawStore(64)
    ids = raw.append(X)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=4096, mode="adaptive",
                             query_leaf_size=128))
    ads.insert_batch(X, ids)
    flat_before = ads._flat()
    ads.knn_exact(_queries(1)[0], k=1, raw=raw)
    assert ads.n_splits > 0
    assert ads._flat_cache is flat_before  # same cache object, patched
    blocks = ads._flat_blocks(flat_before)
    assert all(n.is_leaf for n, _ in blocks)  # split parents dropped
    # position partition is still a disjoint cover of all entries
    allpos = np.sort(np.concatenate([p for _, p in blocks]))
    np.testing.assert_array_equal(allpos, np.arange(3000))
    # and a fresh query over the patched cache stays exact
    q = _queries(2, seed=17)[1]
    res, _ = ads.knn_exact(q, k=3, raw=raw)
    bf = np.sort(ed2(q, X))[:3]
    np.testing.assert_allclose([d for d, _ in res], bf, rtol=1e-4)
    # inserts DO invalidate (arrays grow)
    extra = _data(50, seed=9)
    ads.insert_batch(extra, raw.append(extra))
    assert ads._flat_cache is None


# ---------------------------------------------------------------------------
# executor invariants
# ---------------------------------------------------------------------------
def test_executor_rejects_unknown_shard_mode():
    X = _data(300)
    raw = RawStore(64)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=128, materialized=True))
    ct.bulk_build(X, raw.append(X))
    with pytest.raises(ValueError, match="shard"):
        ct.knn_batch(_queries(2), k=3, raw=raw, shard="tpu-pod")


def test_scalar_wrappers_share_executor_answers():
    """Scalar knn_exact == row 0 of a batch-of-1 knn_batch, bit for bit,
    on every index (they are the same plan)."""
    X = _data(1200, seed=4)
    raw = RawStore(64)
    ids = raw.append(X)
    q = _queries(1, seed=13)[0]

    ct = CTree(CTreeConfig(summarization=CFG, block_size=256, materialized=True))
    ct.bulk_build(X, ids)
    ads = ADSIndex(ADSConfig(summarization=CFG, leaf_size=256))
    ads.insert_batch(X, ids)
    for index in (ct, ads):
        res, _ = index.knn_exact(q, k=5, raw=raw)
        vals, gids, _ = index.knn_batch(q[None], k=5, raw=raw)
        assert [d for d, _ in res] == [float(v) for v in vals[0]]
        assert [g for _, g in res] == [int(g) for g in gids[0]]


# ---------------------------------------------------------------------------
# Range-path device routing (the BENCH_streaming b64/nb2 collapse guard)
# ---------------------------------------------------------------------------
def _range_fixture(n=8192, m=64, seed=17):
    """A RangeSource shaped like the collapsed bench cell: one device-ready
    span group (>= MIN_DEVICE_CANDIDATES entries shared by >=
    MIN_DEVICE_BATCH queries) plus many 1-query groups below the batch
    floor. Counting closures record every host fetch."""
    from repro.core.verify_engine import (MIN_DEVICE_BATCH,
                                          MIN_DEVICE_CANDIDATES, get_engine)

    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, 64)).astype(np.float32).cumsum(axis=1)
    xsq = np.einsum("ij,ij->i", X.astype(np.float64), X.astype(np.float64))
    Q = rng.standard_normal((m, 64)).astype(np.float32).cumsum(axis=1)
    big = MIN_DEVICE_CANDIDATES
    spans = np.empty((m, 2), np.int64)
    nbig = MIN_DEVICE_BATCH + 3
    spans[:nbig] = (0, big)  # ONE device-ready group
    for i in range(nbig, m):  # singleton groups: small, distinct spans
        lo = big + ((i - nbig) * 96) % (n - big - 256)
        spans[i] = (lo, lo + 192)
    calls = {"fetch": 0, "rows": 0, "acct": 0, "acct_rows": 0}

    def fetch(pos):
        calls["fetch"] += 1
        calls["rows"] += int(pos.size)
        return X[pos]

    def fetch_account(pos):
        calls["acct"] += 1
        calls["acct_rows"] += int(pos.size)

    from repro.core import RangeSource, SourceOps

    view = get_engine().build_view(X)
    ops = SourceOps(ids=np.arange(n, dtype=np.int64), fetch=fetch,
                    norms2=lambda pos: xsq[pos],
                    device_view=lambda: view,
                    table_rows=lambda pos: pos,
                    table_ids=lambda rows: rows.astype(np.int64),
                    fetch_account=fetch_account)
    src = RangeSource(ops=ops, spans=spans, logical_blocks=1)
    return X, Q, src, calls


def test_range_path_mixed_groups_share_one_host_fetch():
    """Per-group device routing: when one span group goes to the device,
    the remaining (small) groups must share ONE union host fetch — the old
    whole-pass `use_dev` flag stranded every small group on its own
    arena-mirror gather, collapsing b64/nb2 throughput 7x."""
    from repro.core import QueryPlan, execute

    X, Q, src, calls = _range_fixture()
    (vals, gids), _ = execute(QueryPlan(m=Q.shape[0], sources=[src]), Q, k=5,
                              backend="device")
    # one shared fetch for every host-tail group, not one per group
    assert calls["fetch"] == 1, calls
    # the union fetch covers only host-group rows; the device group's rows
    # are accounted (not gathered) exactly once
    m = Q.shape[0]
    host_rows = {p for i in range(12, m)
                 for p in range(*src.spans[i])}
    assert calls["rows"] == len(host_rows), calls
    assert calls["acct"] == 1 and calls["acct_rows"] > 0, calls
    # answers equal the pure-host reference
    X2, Q2, src2, _ = _range_fixture()
    src2.ops.device_view = None
    (hv, hg), _ = execute(QueryPlan(m=Q2.shape[0], sources=[src2]), Q2, k=5,
                          backend="device")
    np.testing.assert_array_equal(gids, hg)
    np.testing.assert_allclose(vals, hv, rtol=0, atol=0)


def test_range_path_all_host_groups_single_fetch():
    """No device-ready group at all: the pass keeps the single shared
    union fetch (nb=1 behavior unchanged)."""
    from repro.core import QueryPlan, execute

    X, Q, src, calls = _range_fixture(m=8)  # every group under the floor
    src.spans[:] = src.spans[len(src.spans) - 8:]
    (vals, gids), _ = execute(QueryPlan(m=Q.shape[0], sources=[src]), Q, k=5,
                              backend="device")
    assert calls["fetch"] == 1 and calls["acct"] == 0, calls
    assert (gids >= 0).all()
