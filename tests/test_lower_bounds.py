"""Lower-bound invariants (MINDIST <= ED, zone-map <= entry bound).

Property tests run under hypothesis when it is installed; a deterministic
seed sweep over the same bodies keeps tier-1 coverage when it is not.
"""
import numpy as np
import pytest

from repro.core import SummarizationConfig, ed2, mindist_paa_sax2, mindist_region2, sax
from repro.core.summarization import paa

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dependency; deterministic sweeps below cover tier-1
    given = None

CFGS = [
    SummarizationConfig(64, 8, 4),
    SummarizationConfig(64, 8, 8),
    SummarizationConfig(128, 16, 8),
    SummarizationConfig(64, 16, 3),
]


def _check_mindist_lower_bounds_ed(cfg, seed, scale):
    """THE correctness invariant of exact search: MINDIST_PAA_SAX <= ED."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((64, cfg.series_len)) * scale).astype(np.float32)
    q = (rng.standard_normal(cfg.series_len) * scale).astype(np.float32)
    qp = np.asarray(paa(q, cfg))
    sym = sax(x, cfg).astype(np.int64)
    lb2 = mindist_paa_sax2(qp, sym, cfg)
    d2 = ed2(q, x)
    assert (lb2 <= d2 * (1 + 1e-4) + 1e-3).all()


def _check_region_bound_lower_bounds_entry_bound(seed):
    """Zone-map (block) MINDIST <= every member entry's MINDIST."""
    cfg = SummarizationConfig(64, 8, 8)
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((128, 64)).astype(np.float32).cumsum(axis=1)
    q = rng.standard_normal(64).astype(np.float32).cumsum()
    qp = np.asarray(paa(q.astype(np.float32), cfg))
    sym = sax(x, cfg).astype(np.int64)
    blk_lb = mindist_region2(qp, sym.min(axis=0), sym.max(axis=0), cfg)
    entry_lb = mindist_paa_sax2(qp, sym, cfg)
    assert (blk_lb <= entry_lb + 1e-3).all()


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: f"n{c.series_len}w{c.n_segments}c{c.card_bits}")
@pytest.mark.parametrize("seed,scale", [(0, 1.0), (1, 0.1), (77, 5.0), (2**31 - 1, 20.0)])
def test_mindist_lower_bounds_ed(cfg, seed, scale):
    _check_mindist_lower_bounds_ed(cfg, seed, scale)


@pytest.mark.parametrize("seed", [0, 3, 1234, 2**31 - 1])
def test_region_bound_lower_bounds_entry_bound(seed):
    _check_region_bound_lower_bounds_entry_bound(seed)


if given is not None:

    @given(st.sampled_from(CFGS), st.integers(0, 2**31 - 1), st.floats(0.1, 20.0))
    @settings(max_examples=40, deadline=None)
    def test_mindist_lower_bounds_ed_hypothesis(cfg, seed, scale):
        _check_mindist_lower_bounds_ed(cfg, seed, scale)

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_region_bound_lower_bounds_entry_bound_hypothesis(seed):
        _check_region_bound_lower_bounds_entry_bound(seed)


def test_mindist_zero_for_own_region(rng):
    cfg = SummarizationConfig(64, 8, 8)
    x = rng.standard_normal((10, 64)).astype(np.float32)
    qp = np.asarray(paa(x, cfg))
    sym = sax(x, cfg).astype(np.int64)
    for i in range(10):
        assert float(mindist_paa_sax2(qp[i], sym[i][None], cfg)[0]) == 0.0
