"""Epoch-based run registry + background ingest pipeline (concurrent
ingest/query semantics).

The contract under test: every query answers from ONE pinned immutable
snapshot — brute force over that snapshot's entries — no matter how many
flushes/merges publish concurrently; runs a merge replaces are retired
only after the last pinned epoch that could see them drops; and the
cascading-merge driver is iterative (a deep cascade must not scale the
Python stack with the level count)."""
import sys
import threading

import numpy as np
import pytest

from repro.core import (
    CLSM,
    CLSMConfig,
    RawStore,
    StreamConfig,
    StreamingIndex,
    SummarizationConfig,
)
from repro.core.run_registry import BufferChunk, RunRegistry

CFG = SummarizationConfig(series_len=64, n_segments=8, card_bits=6)


def _series(n, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, 64)).astype(np.float32).cumsum(axis=1)


def _chunk(n, seed, t=0, id0=0):
    return BufferChunk(
        series=_series(n, seed),
        ids=np.arange(id0, id0 + n, dtype=np.int64),
        ts=np.full(n, t, np.int64),
    )


# ---------------------------------------------------------------------------
# registry mechanics
# ---------------------------------------------------------------------------
def test_registry_snapshots_are_immutable_and_epochs_advance():
    reg = RunRegistry()
    s0 = reg.current()
    assert s0.epoch == 0 and s0.buffer_n == 0
    s1 = reg.append_buffer(_chunk(10, seed=0))
    assert s1.epoch == 1 and s1.buffer_n == 10
    assert s0.buffer_n == 0  # the old snapshot did not change
    taken, s2 = reg.take_for_flush(6)
    assert taken.n == 6 and s2.epoch == 2
    assert s2.buffer_n == 4 and s2.flushing_n == 6
    # entries live in exactly one place at every epoch
    for snap in (s1, s2):
        total = snap.buffer_n + snap.flushing_n
        assert total == 10
    reg.publish_flush(taken, run=object())
    s3 = reg.current()
    assert s3.epoch == 3 and s3.flushing_n == 0 and s3.n_runs == 1
    assert s3.buffer_n == 4


def test_registry_take_preserves_fifo_ids():
    reg = RunRegistry()
    reg.append_buffer(_chunk(5, seed=1, id0=0))
    reg.append_buffer(_chunk(5, seed=2, id0=5))
    taken, _ = reg.take_for_flush(7)
    np.testing.assert_array_equal(taken.ids, np.arange(7))
    snap = reg.current()
    np.testing.assert_array_equal(
        np.concatenate([c.ids for c in snap.buffer]), np.arange(7, 10))


class _FakeRun:
    """A run stand-in that records arena releases."""

    def __init__(self):
        self.released = 0

    def release_device_view(self):
        self.released += 1


def test_retired_runs_survive_until_last_pin_drops():
    reg = RunRegistry()
    victims = [_FakeRun(), _FakeRun()]
    merged = _FakeRun()
    for v in victims:
        c = _chunk(1, seed=3)
        reg.append_buffer(c)
        t, _ = reg.take_for_flush(1)
        reg.publish_flush(t, v)
    with reg.pin() as snap:
        assert [r is v for r, v in zip(snap.level_runs(0), victims)]
        reg.publish_merge(0, victims, merged)
        new = reg.current()
        assert list(new.level_runs(0)) == [] and new.level_runs(1) == (merged,)
        # pinned epoch still references the victims: nothing released
        assert reg.retired_pending == 2
        assert all(v.released == 0 for v in victims)
        # the pinned snapshot still sees the pre-merge world
        assert snap.level_runs(0) == tuple(victims)
    # pin dropped -> deferred retirement fires
    assert reg.retired_pending == 0
    assert all(v.released == 1 for v in victims)
    assert reg.released_runs == 2


def test_unpinned_retirement_is_immediate():
    reg = RunRegistry()
    v = _FakeRun()
    c = _chunk(1, seed=4)
    reg.append_buffer(c)
    t, _ = reg.take_for_flush(1)
    reg.publish_flush(t, v)
    reg.publish_merge(0, [v], _FakeRun())
    assert v.released == 1 and reg.retired_pending == 0


def test_overlapping_pins_release_once_all_drop():
    reg = RunRegistry()
    v = _FakeRun()
    c = _chunk(1, seed=5)
    reg.append_buffer(c)
    t, _ = reg.take_for_flush(1)
    reg.publish_flush(t, v)
    with reg.pin():
        with reg.pin():
            reg.publish_merge(0, [v], _FakeRun())
            assert v.released == 0
        assert v.released == 0  # the outer (older) pin still holds it
    assert v.released == 1


# ---------------------------------------------------------------------------
# CLSM on the registry
# ---------------------------------------------------------------------------
def test_clsm_plan_records_epoch_and_is_snapshot_stable():
    raw = RawStore(64)
    lsm = CLSM(CLSMConfig(summarization=CFG, buffer_entries=64,
                          growth_factor=2, block_size=32), disk=raw.disk)
    x = _series(200, seed=6)
    lsm.insert(x, raw.append(x), np.zeros(200, np.int64))
    snap = lsm.registry.current()
    plan = lsm.plan(_series(3, seed=7), raw=raw, snapshot=snap)
    assert plan.epoch == snap.epoch
    # more ingest bumps the epoch; a plan built from the old snapshot
    # keeps planning the old run set
    x2 = _series(200, seed=8)
    lsm.insert(x2, raw.append(x2), np.ones(200, np.int64))
    assert lsm.registry.current().epoch > snap.epoch
    plan_old = lsm.plan(_series(3, seed=7), raw=raw, snapshot=snap)
    assert len(plan_old.sources) == len(plan.sources)


def test_maybe_merge_is_iterative_on_deep_cascades(monkeypatch):
    """128 level-0 runs at growth_factor=2 cascade through 7 levels in one
    _maybe_merge call: the driver must not re-enter itself (worklist, not
    recursion) and the whole cascade must fit in a near-flat stack."""
    raw = RawStore(64)
    lsm = CLSM(CLSMConfig(summarization=CFG, buffer_entries=8,
                          growth_factor=2, block_size=8, merge=False),
               disk=raw.disk)
    for i in range(128):
        x = _series(8, seed=100 + i)
        lsm.insert(x, raw.append(x), np.full(8, i, np.int64))
    assert lsm.n_runs == 128
    lsm.cfg.merge = True

    depth = {"cur": 0, "max": 0}
    orig = CLSM._maybe_merge

    def wrapped(self, level):
        depth["cur"] += 1
        depth["max"] = max(depth["max"], depth["cur"])
        try:
            return orig(self, level)
        finally:
            depth["cur"] -= 1

    monkeypatch.setattr(CLSM, "_maybe_merge", wrapped)
    limit = sys.getrecursionlimit()
    try:
        # a recursive cascade would add O(levels) frames; the iterative
        # driver adds O(1), so a tight headroom still completes
        def _frames():
            f, n = sys._getframe(), 0
            while f is not None:
                f, n = f.f_back, n + 1
            return n

        sys.setrecursionlimit(_frames() + 40)
        lsm._maybe_merge(0)
    finally:
        sys.setrecursionlimit(limit)
    assert depth["max"] == 1  # never re-entered: the worklist did the cascade
    assert lsm.n_runs == 1 and lsm.n_merges == 127
    # the collapsed index still answers exactly
    q = _series(1, seed=9)[0]
    res, _ = lsm.knn_exact(q, k=3, raw=raw)
    from repro.core import ed2

    bf = np.sort(ed2(q, raw._all()))[:3]
    np.testing.assert_allclose([d for d, _ in res], bf, rtol=1e-5)


def test_async_ingest_matches_sync_after_drain():
    out = {}
    for mode in ("sync", "async"):
        idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                          buffer_entries=256, growth_factor=3,
                                          block_size=64, ingest=mode))
        for b in range(12):
            idx.ingest(_series(150, seed=20 + b), np.full(150, b, np.int64))
        assert idx.drain(timeout=120)
        vals, gids, _ = idx.window_knn_batch(_series(4, seed=50), 2, 9, k=5)
        out[mode] = (vals, gids, idx.n_partitions,
                     sorted((lv, len(runs)) for lv, runs
                            in idx.lsm.registry.current().levels))
        idx.close()
    np.testing.assert_array_equal(out["sync"][0], out["async"][0])
    np.testing.assert_array_equal(out["sync"][1], out["async"][1])
    assert out["sync"][2] == out["async"][2]  # same run count
    assert out["sync"][3] == out["async"][3]  # same level structure


def test_ingest_lag_reports_backlog_and_drains():
    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                      buffer_entries=512, growth_factor=2,
                                      block_size=64, ingest="async"))
    for b in range(8):
        idx.ingest(_series(300, seed=60 + b), np.full(300, b, np.int64))
    lag = idx.ingest_lag()
    assert set(lag) >= {"epoch", "lag_entries", "runs_pending_merge",
                        "snapshot_age_s"}
    assert idx.drain(timeout=120)
    lag = idx.ingest_lag()
    assert lag["lag_entries"] < 512  # only the sub-threshold tail remains
    assert lag["runs_pending_merge"] == 0
    idx.close()


def test_backpressure_below_flush_threshold_is_rejected():
    with pytest.raises(ValueError):
        StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                    buffer_entries=2048, ingest="async",
                                    max_lag_entries=1024))


def test_insert_after_close_raises():
    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                      buffer_entries=64, ingest="async"))
    idx.ingest(_series(32, seed=80), np.zeros(32, np.int64))
    idx.close()
    with pytest.raises(RuntimeError):
        idx.ingest(_series(32, seed=81), np.zeros(32, np.int64))


def test_drain_flush_buffer_flushes_the_subthreshold_tail():
    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                      buffer_entries=4096, growth_factor=2,
                                      block_size=64, ingest="async"))
    idx.ingest(_series(300, seed=82), np.zeros(300, np.int64))
    assert idx.drain(flush_buffer=True, timeout=120)
    snap = idx.lsm.registry.current()
    assert snap.buffer_n == 0 and snap.flushing_n == 0
    assert idx.n_partitions >= 1  # the 300-entry tail became a run
    idx.close()


def test_insert_racing_close_raises(monkeypatch):
    """An insert blocked on backpressure while close() stops the worker must
    raise, not return as if the data will ever be flushed. The worker is
    pinned idle (``_work_available`` forced False) so the backlog genuinely
    strands: before the fix the waiter either hung forever or returned
    success for data nothing would ever flush."""
    import time

    from repro.core import IngestPipeline

    lsm = CLSM(CLSMConfig(summarization=CFG, buffer_entries=64, block_size=32))
    pipe = IngestPipeline(lsm, max_lag_entries=64)
    monkeypatch.setattr(pipe, "_work_available", lambda: False)
    errs = []

    def submit():
        try:
            for b in range(2):  # second batch pushes backlog past the cap
                pipe.insert(_series(64, seed=90 + b),
                            np.arange(b * 64, (b + 1) * 64, dtype=np.int64),
                            np.full(64, b, np.int64))
        except RuntimeError as e:
            errs.append(e)

    th = threading.Thread(target=submit)
    th.start()
    deadline = time.time() + 10
    while pipe._backlog() <= pipe.max_lag_entries and time.time() < deadline:
        time.sleep(0.01)  # wait until the insert is really blocked
    assert pipe._backlog() > pipe.max_lag_entries
    pipe.close(timeout=10)
    th.join(timeout=10)
    assert not th.is_alive()
    assert errs and "closed" in str(errs[0])


def test_worker_errors_surface_on_the_submitting_thread(monkeypatch):
    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                      buffer_entries=64, growth_factor=2,
                                      block_size=32, ingest="async"))

    def boom(self):
        raise RuntimeError("flush exploded")

    monkeypatch.setattr(CLSM, "_flush", boom)
    with pytest.raises(RuntimeError):
        for b in range(8):
            idx.ingest(_series(64, seed=70 + b), np.full(64, b, np.int64))
            idx.drain(timeout=30)


# ---------------------------------------------------------------------------
# concurrent stress: queries racing background flush/merge
# ---------------------------------------------------------------------------
def _snapshot_bruteforce(snap, X_all, window, Q, k):
    """Exact top-k over exactly the pinned snapshot's entries (f64 diff
    form, cast f32 like the engine's re-rank)."""
    ids = [c.ids for c in snap.buffer + snap.flushing]
    ts = [c.ts for c in snap.buffer + snap.flushing]
    for r in snap.runs_newest_first():
        ids.append(r.ids)
        ts.append(r.ts)
    if ids and any(i.size for i in ids):
        gids = np.concatenate(ids)
        gts = np.concatenate(ts)
    else:
        gids = np.zeros(0, np.int64)
        gts = np.zeros(0, np.int64)
    if window is not None:
        keep = (gts >= window[0]) & (gts <= window[1])
        gids = gids[keep]
    vals = np.full((len(Q), k), np.inf, np.float32)
    out = np.full((len(Q), k), -1, np.int64)
    if gids.size == 0:
        return vals, out
    X = X_all[gids].astype(np.float64)
    d2 = ((X[None, :, :] - Q[:, None, :].astype(np.float64)) ** 2).sum(-1)
    d2 = d2.astype(np.float32)
    kk = min(k, gids.size)
    order = np.argsort(d2, axis=1, kind="stable")[:, :kk]
    vals[:, :kk] = np.take_along_axis(d2, order, axis=1)
    out[:, :kk] = gids[order]
    return vals, out


@pytest.mark.slow
def test_queries_racing_ingest_are_snapshot_consistent():
    """Thread-pool stress: batched window queries race background
    flush/merge publishes; every answer must equal brute force over that
    query's pinned snapshot."""
    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                      buffer_entries=128, growth_factor=2,
                                      block_size=32, ingest="async"))
    n_ingest, bsz = 24, 100
    X_parts = [_series(bsz, seed=200 + b) for b in range(n_ingest)]
    X_all = np.concatenate(X_parts)
    errors: list = []
    stop = threading.Event()

    def worker(wid):
        rng = np.random.default_rng(wid)
        n_checked = 0
        try:
            while not stop.is_set() or n_checked < 5:
                Q = _series(4, seed=int(rng.integers(1 << 30)))
                window = None
                if rng.random() < 0.5:
                    t0 = int(rng.integers(0, n_ingest))
                    window = (t0, int(rng.integers(t0, n_ingest)))
                with idx.lsm.registry.pin() as snap:
                    vals, gids, _ = idx.lsm.knn_batch(
                        Q, k=5, raw=idx.raw, window=window, snapshot=snap)
                    bv, _ = _snapshot_bruteforce(snap, X_all, window, Q, 5)
                # distances must match brute force over the pinned epoch
                np.testing.assert_allclose(vals, bv, rtol=1e-5, atol=1e-4)
                # every returned id must come from the snapshot and carry
                # its true exact distance (no phantom/stale entries)
                for qi in range(len(Q)):
                    for vj, gj in zip(vals[qi], gids[qi]):
                        if gj < 0:
                            continue
                        true = float(((X_all[gj] - Q[qi]).astype(np.float64)
                                      ** 2).sum())
                        assert abs(true - float(vj)) <= 1e-4 + 1e-5 * true
                n_checked += 1
        except Exception as e:  # noqa: BLE001 - surfaced on the main thread
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(4)]
    for t in threads:
        t.start()
    for b in range(n_ingest):
        idx.ingest(X_parts[b], np.full(bsz, b, np.int64))
    idx.drain(timeout=300)
    stop.set()
    for t in threads:
        t.join(timeout=120)
    idx.close()
    assert not errors, errors[0]
    assert idx.lsm.n_merges > 0  # the race actually exercised merges


def test_no_arena_released_while_pinned_end_to_end():
    """Materialized runs own device arenas; a merge must not release a
    victim's arena while an older epoch is pinned."""
    from repro.core.verify_engine import get_engine

    eng = get_engine()
    raw = RawStore(64)
    lsm = CLSM(CLSMConfig(summarization=CFG, buffer_entries=64,
                          growth_factor=2, block_size=32, materialized=True,
                          merge=False), disk=raw.disk)
    for i in range(2):
        x = _series(64, seed=300 + i)
        lsm.insert(x, raw.append(x), np.full(64, i, np.int64))
    runs = lsm.registry.current().runs_newest_first()
    assert len(runs) == 2
    for r in runs:
        r.device_view()  # force the arenas into existence
    lsm.cfg.merge = True
    before = eng.stats["released_arenas"]
    with lsm.registry.pin():
        lsm._maybe_merge(0)
        assert lsm.registry.retired_pending == 2
        assert eng.stats["released_arenas"] == before  # pinned: kept warm
    assert lsm.registry.retired_pending == 0
    assert eng.stats["released_arenas"] == before + 2
