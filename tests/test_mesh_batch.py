"""Mesh-sharded batch serving (queries x runs 2-D shard_map) on 8 CPU
devices: the executor's ``shard="mesh"`` mode must match the single-device
engine exactly, and must compose with the sample-sorted distributed build.

Runs in a subprocess because jax pins the device count at first init.
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import (CTree, CTreeConfig, RawStore, StreamConfig,
                        StreamingIndex, SummarizationConfig, ed2)
from repro.core.distributed import (DistBuildConfig, default_batch_mesh,
                                    make_build_fn, mesh_topk_candidates,
                                    valid_entries)
from repro.core.execute import _rerank_slate

mesh = default_batch_mesh()
assert dict(zip(mesh.axis_names, mesh.devices.shape)) == {"q": 2, "r": 4}

CFG = SummarizationConfig(series_len=64, n_segments=8, card_bits=6)
rng = np.random.default_rng(0)

# --- 1. CTree: mesh answers == single-device answers, exactly -------------
X = rng.standard_normal((3000, 64)).astype(np.float32).cumsum(axis=1)
Q = rng.standard_normal((13, 64)).astype(np.float32).cumsum(axis=1)
raw = RawStore(64)
ids = raw.append(X)
ct = CTree(CTreeConfig(summarization=CFG, block_size=256, materialized=True))
ct.bulk_build(X, ids)
v1, g1, _ = ct.knn_batch(Q, k=5, raw=raw)
v2, g2, _ = ct.knn_batch(Q, k=5, raw=raw, shard="mesh")
np.testing.assert_array_equal(g1, g2)
np.testing.assert_array_equal(v1, v2)

# --- 2. streaming window query over many live runs (batch not divisible
#        by the q axis; k slots partially unfillable) ----------------------
idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                  buffer_entries=512, growth_factor=3,
                                  block_size=128))
for b in range(10):
    x = rng.standard_normal((300, 64)).astype(np.float32).cumsum(axis=1)
    idx.ingest(x, np.full(300, b, np.int64))
Qw = rng.standard_normal((7, 64)).astype(np.float32).cumsum(axis=1)
v1, g1, _ = idx.window_knn_batch(Qw, 2, 8, k=4)
v2, g2, _ = idx.window_knn_batch(Qw, 2, 8, k=4, shard="mesh")
np.testing.assert_array_equal(g1, g2)
np.testing.assert_array_equal(v1, v2)

# --- 2b. adversarial conditioning: large common offset + near-ties, where
#         an uncertified f32 screen would mis-rank — the certification +
#         host-exact fallback must keep mesh ids AND distances identical --
Xa = (3000.0 + 0.01 * rng.standard_normal((3000, 64))).astype(np.float32)
rawa = RawStore(64)
cta = CTree(CTreeConfig(summarization=CFG, block_size=256, materialized=True))
cta.bulk_build(Xa, rawa.append(Xa))
Qa = Xa[rng.integers(0, 3000, 9)] + 0.001 * rng.standard_normal((9, 64)).astype(np.float32)
v1, g1, _ = cta.knn_batch(Qa, k=5, raw=rawa)
v2, g2, _ = cta.knn_batch(Qa, k=5, raw=rawa, shard="mesh")
np.testing.assert_array_equal(g1, g2)
np.testing.assert_array_equal(v1, v2)
for i in range(9):
    bf = np.sort(ed2(Qa[i].astype(np.float64), Xa.astype(np.float64)))[:5]
    np.testing.assert_allclose(v1[i], bf, rtol=1e-5)

# --- 3. composes with the sample-sorted distributed build -----------------
mesh1d = make_mesh((8,), ("data",))
dcfg = DistBuildConfig(summarization=SummarizationConfig(64, 8, 8),
                       capacity_slack=3.0)
N = 8 * 256
Xd = rng.standard_normal((N, 64)).astype(np.float32).cumsum(axis=1)
idxd = make_build_fn(mesh1d, ("data",), dcfg)(
    jnp.asarray(Xd), jnp.asarray(np.arange(N, dtype=np.int32)))
series, gids = valid_entries(idxd)
assert series.shape[0] == N
Qd = rng.standard_normal((5, 64)).astype(np.float32).cumsum(axis=1)
_, rows = mesh_topk_candidates(Qd, series, 5 + 8)
nv, nrows = _rerank_slate(Qd, series, rows, 5)
for i in range(5):
    bf = np.sort(ed2(Qd[i], Xd))[:5]
    np.testing.assert_allclose(nv[i], bf, rtol=1e-6)
    np.testing.assert_allclose(np.sort(ed2(Qd[i], Xd[gids[nrows[i]]])), bf,
                               rtol=1e-6)
print("MESH_BATCH_OK")
"""


@pytest.mark.slow
def test_mesh_sharded_batch_matches_single_device_8dev():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=420,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MESH_BATCH_OK" in r.stdout
