"""Serving-driver regressions: async ingest + approx tier is a supported
combination (it used to be rejected at argparse because the recall oracle's
stats save/restore raced the background worker), and the oracle's exact
reads stay out of the approx tier's modeled-I/O figures."""
import argparse
import copy

import numpy as np
import pytest

from repro.core import StreamConfig, StreamingIndex, SummarizationConfig
from repro.launch import serve


# ------------------------------------------------------------- flag parsing
def test_argparse_accepts_async_ingest_with_approx_tier(monkeypatch):
    seen = {}
    monkeypatch.setattr(serve, "serve_coconut",
                        lambda args: seen.setdefault("args", args))
    monkeypatch.setattr("sys.argv",
                        ["serve", "--ingest", "async", "--tier", "approx"])
    serve.main()
    assert seen["args"].ingest == "async"
    assert seen["args"].tier == "approx"


def test_argparse_still_rejects_mesh_with_approx_tier(monkeypatch):
    monkeypatch.setattr("sys.argv",
                        ["serve", "--shard", "mesh", "--tier", "approx"])
    with pytest.raises(SystemExit):
        serve.main()


# ------------------------------------------------------------ oracle purity
def test_recall_oracle_leaves_approx_io_stats_untouched(rng):
    """Exact-tier oracle reads under ``unaccounted()`` must not move the
    disk stats the approx tier is being measured on."""
    scfg = SummarizationConfig(series_len=32, n_segments=4, card_bits=4)
    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=scfg,
                                      buffer_entries=64, growth_factor=4,
                                      block_size=32))
    for b in range(4):
        x = rng.standard_normal((48, 32)).astype(np.float32)
        idx.ingest(x, np.full(48, b, np.int64))
    qs = rng.standard_normal((4, 32)).astype(np.float32)
    _, approx_ids, _ = idx.window_knn_approx_batch(qs, 0, 3, k=3, n_blocks=1)
    before = copy.deepcopy(idx.raw.disk.stats)
    with idx.raw.disk.unaccounted():
        _, exact_ids, _ = idx.window_knn_batch(qs, 0, 3, k=3)
    assert idx.raw.disk.stats == before  # the oracle was invisible
    assert exact_ids.shape == approx_ids.shape == (4, 3)
    # ...and the same query accounts normally outside the suspension
    idx.window_knn_batch(qs, 0, 3, k=3)
    assert idx.raw.disk.stats != before


# ------------------------------------------------------------- end to end
def test_serve_async_approx_end_to_end(capsys):
    """The previously rejected combination runs the full serving loop:
    background ingest, approx-tier answers, per-batch recall vs the exact
    oracle, clean drain."""
    args = argparse.Namespace(
        mode="coconut", scheme="BTP", batches=10, batch_size=480,
        series_len=32, query_batch=4, window=5, k=3, tier="approx",
        n_blocks=2, shard="none", ingest="async", approx=False,
        prewarm=False)
    serve.serve_coconut(args)
    out = capsys.readouterr().out
    assert "recall@3=" in out          # the oracle scored every served batch
    assert "drained ingest backlog" in out
