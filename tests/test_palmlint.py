"""palmlint framework tests: fixtures pin exact rule IDs and line numbers,
seeded-regression sources prove the gate catches the bug classes it was
built for, and the clean-tree test keeps `python -m repro.analysis src`
green."""
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis import CHECKERS, RULES, build_project, collect_files, lint_source, run_project
from repro.analysis.cli import main as palmlint_main

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "palmlint_fixtures"


def lint_fixture(name, select=None):
    """(live, suppressed) findings for one fixture file."""
    project, errors = build_project([FIXTURES / name], root=REPO)
    assert not errors, errors
    return run_project(project, select)


def as_tuples(findings):
    return [(f.rule, f.line) for f in findings]


# ---------------------------------------------------------------- registry
def test_all_four_rules_registered():
    assert set(CHECKERS) == {
        "lock-discipline", "snapshot-immutability", "trace-safety",
        "precision-discipline",
    }
    for name in CHECKERS:
        assert RULES[name]  # every rule carries a catalog description


# ----------------------------------------------------------- lock-discipline
def test_lock_bad_fixture_exact_findings():
    live, _ = lint_fixture("lock_bad.py")
    assert as_tuples(live) == [
        ("lock-discipline", 12),  # unlocked `self.published += 1`
        ("lock-discipline", 13),  # unlocked `self.log.append(...)`
        ("lock-discipline", 18),  # unlocked `del self.log[:]`
    ]


def test_lock_good_fixture_is_clean():
    live, suppressed = lint_fixture("lock_good.py")
    assert live == [] and suppressed == []


# ----------------------------------------------------- snapshot-immutability
def test_snapshot_bad_fixture_exact_findings():
    live, _ = lint_fixture("snapshot_bad.py")
    assert as_tuples(live) == [
        ("snapshot-immutability", 8),   # RunSet declared without frozen=True
        ("snapshot-immutability", 15),  # run.t_min = ... (snapshot contents)
        ("snapshot-immutability", 16),  # run.t_max = ...
        ("snapshot-immutability", 20),  # plan.k = ...
        ("snapshot-immutability", 21),  # plan.sources.append(...)
        ("snapshot-immutability", 25),  # snap.epoch += 1
        ("snapshot-immutability", 26),  # object.__setattr__ bypass
    ]


def test_snapshot_good_fixture_is_clean():
    live, suppressed = lint_fixture("snapshot_good.py")
    assert live == [] and suppressed == []


# ------------------------------------------------------------- trace-safety
def test_trace_bad_fixture_exact_findings():
    live, _ = lint_fixture("trace_bad.py")
    assert as_tuples(live) == [
        ("trace-safety", 15),  # _CALLS[0] += 1 (nonlocal state)
        ("trace-safety", 16),  # with _lock
        ("trace-safety", 18),  # time.time()
        ("trace-safety", 19),  # np.random.default_rng
        ("trace-safety", 20),  # disk.read_seq (accounting)
        ("trace-safety", 25),  # time.sleep in helper, via the call graph
    ]
    # the call-graph hop is attributed to the root it is reachable from
    assert "reachable from traced root `screen_pass`" in live[-1].message


def test_trace_good_fixture_is_clean():
    live, suppressed = lint_fixture("trace_good.py")
    assert live == [] and suppressed == []


# ------------------------------------------------------ precision-discipline
def test_precision_bad_fixture_exact_findings():
    live, _ = lint_fixture("core/precision_bad.py")
    assert as_tuples(live) == [
        ("precision-discipline", 6),   # dtype-less jnp.zeros
        ("precision-discipline", 7),   # dtype-less jnp.arange
        ("precision-discipline", 13),  # f64 operand in screen matmul
        ("precision-discipline", 17),  # certify-path matmul without f64
    ]


def test_precision_good_fixture_is_clean():
    live, suppressed = lint_fixture("core/precision_good.py")
    assert live == [] and suppressed == []


def test_precision_quant_bad_fixture_exact_findings():
    live, _ = lint_fixture("core/precision_quant_bad.py")
    assert as_tuples(live) == [
        ("precision-discipline", 10),  # bf16-tainted operand into re-rank
        ("precision-discipline", 15),  # int8 operand in certify matmul
        ("precision-discipline", 19),  # .astype(dt) in a quant helper
        ("precision-discipline", 24),  # .astype(ref.dtype) in quant helper
    ]
    # the lowp findings are the new rule, not a re-fire of rule 2
    assert "bf16/int8 operand" in live[0].message
    assert "dtype-less cast in a quantization helper" in live[2].message


def test_precision_quant_good_fixture_is_clean():
    live, suppressed = lint_fixture("core/precision_quant_good.py")
    assert live == [] and suppressed == []


def test_precision_dtype_rule_is_path_scoped():
    # identical source outside core//kernels/: the dtype rule stays quiet
    src = "import jax.numpy as jnp\n\ndef f(n):\n    return jnp.zeros((n,))\n"
    assert lint_source(src, path="tools/helper.py") == []
    assert [f.rule for f in lint_source(src, path="src/repro/core/x.py")] \
        == ["precision-discipline"]


# ------------------------------------------------------------- escape hatch
def test_escape_hatch_suppresses_and_is_counted():
    live, suppressed = lint_fixture("escape_hatch.py")
    assert live == []
    assert as_tuples(suppressed) == [
        ("lock-discipline", 12),  # ignore[lock-discipline]
        ("lock-discipline", 15),  # ignore[*]
    ]


def test_escape_hatch_is_rule_specific():
    src = (
        "import threading\n"
        "class RunRegistry:\n"
        "    def bump(self):\n"
        "        self.n += 1  # palmlint: ignore[trace-safety]\n"
    )
    # annotation names the WRONG rule: the finding stays live
    assert [f.rule for f in lint_source(src)] == ["lock-discipline"]


# ------------------------------------------------------- seeded regressions
def test_seeded_regression_pp_tmin_tmax_hack_fails_the_gate():
    """Reintroducing the PR 3 PP hack — patching t_min/t_max on runs in a
    pinned snapshot around a window query — must fail the gate."""
    src = (
        "def window_query(reg, q, t0, t1):\n"
        "    snap = reg.current()\n"
        "    saved = []\n"
        "    for run in snap.levels[0]:\n"
        "        saved.append((run.t_min, run.t_max))\n"
        "        run.t_min = t0\n"
        "        run.t_max = t1\n"
        "    return snap\n"
    )
    rules = [(f.rule, f.line) for f in lint_source(src)]
    assert ("snapshot-immutability", 6) in rules
    assert ("snapshot-immutability", 7) in rules


def test_seeded_regression_unlocked_registry_mutation_fails_the_gate():
    src = (
        "class RunRegistry:\n"
        "    def publish_merge(self, snap):\n"
        "        self._current = snap\n"
        "        self.publish_time = 0.0\n"
    )
    rules = [(f.rule, f.line) for f in lint_source(src)]
    assert ("lock-discipline", 3) in rules
    assert ("lock-discipline", 4) in rules


def test_locked_suffix_convention_is_honored():
    src = (
        "class RunRegistry:\n"
        "    def _install_locked(self, snap):\n"
        "        self._current = snap\n"
    )
    assert lint_source(src) == []


# --------------------------------------------------------------- clean tree
def test_src_tree_is_clean():
    """The merge gate: zero unannotated findings on the real tree."""
    files = collect_files([str(REPO / "src")])
    assert len(files) > 40  # sanity: the whole tree, not a subset
    project, errors = build_project(files, root=REPO)
    assert not errors
    live, suppressed = run_project(project)
    assert live == [], "\n".join(f.render() for f in live)
    # the deliberate, annotated exceptions stay visible as suppressed
    assert suppressed, "expected annotated exceptions on the tree"


# ---------------------------------------------------------------------- CLI
def test_cli_exit_codes_and_list_rules(capsys):
    assert palmlint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in CHECKERS:
        assert rule in out
    assert palmlint_main([str(FIXTURES / "lock_good.py")]) == 0
    assert palmlint_main([str(FIXTURES / "lock_bad.py")]) == 1
    capsys.readouterr()


def test_cli_select_runs_only_named_rules(capsys):
    rc = palmlint_main([str(FIXTURES / "lock_bad.py"),
                        "--select", "trace-safety"])
    assert rc == 0  # lock findings exist, but only trace-safety ran
    rc = palmlint_main([str(FIXTURES / "lock_bad.py"),
                        "--select", "no-such-rule"])
    assert rc == 2
    capsys.readouterr()


def test_module_entry_point_runs_without_jax_or_numpy_imports(tmp_path):
    """The CI lint job installs only ruff: importing repro.analysis must
    not drag in numpy/jax. Run the real module entry point with imports
    of both poisoned."""
    poison = "raise ImportError('palmlint must stay stdlib-only')\n"
    for name in ("numpy.py", "jax.py"):
        (tmp_path / name).write_text(poison)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join([str(tmp_path), str(REPO / "src")])
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         str(FIXTURES / "lock_good.py")],
        capture_output=True, text=True, env=env, cwd=str(REPO))
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_github_format_renders_error_annotations(capsys):
    rc = palmlint_main([str(FIXTURES / "lock_bad.py"), "--format", "github"])
    assert rc == 1
    out = capsys.readouterr().out
    assert "::error file=tests/palmlint_fixtures/lock_bad.py" in out


def test_snapshot_decision_types_bad_fixture_exact_findings():
    """PR 10 decision surface: recommender verdicts, autotuner records,
    and gateway stats snapshots are protected like run-set snapshots."""
    live, _ = lint_fixture("snapshot_decisions_bad.py")
    assert as_tuples(live) == [
        ("snapshot-immutability", 7),   # TierDecision without frozen=True
        ("snapshot-immutability", 13),  # rec.materialized = True
        ("snapshot-immutability", 14),  # dec.n_blocks = 4
        ("snapshot-immutability", 18),  # entry.text = "edited"
        ("snapshot-immutability", 19),  # d.knobs = None
        ("snapshot-immutability", 23),  # st.served += 1
    ]


def test_snapshot_decision_types_good_fixture_is_clean():
    """Containers OF protected types (List[RationaleEntry],
    Dict[Knobs, ...], Optional[DecisionRecord]) are not themselves
    protected — only the outermost annotation name counts."""
    live, suppressed = lint_fixture("snapshot_decisions_good.py")
    assert live == [] and suppressed == []
