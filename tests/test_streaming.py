"""PP / TP / BTP window-query schemes: all three must return identical,
brute-force-correct results; their physical behaviour must differ as the
paper describes (TP partition count unbounded, BTP bounded, PP minimal)."""
import numpy as np
import pytest

from repro.core import StreamConfig, StreamingIndex, SummarizationConfig, ed2

CFG = SummarizationConfig(series_len=64, n_segments=8, card_bits=6)


def _ingest(scheme, n_batches=30, bsz=200, seed=1, **kw):
    idx = StreamingIndex(StreamConfig(scheme=scheme, summarization=CFG,
                                      buffer_entries=1024, growth_factor=3,
                                      block_size=128, **kw))
    rng = np.random.default_rng(seed)
    xs, ts = [], []
    for b in range(n_batches):
        x = rng.standard_normal((bsz, 64)).astype(np.float32).cumsum(axis=1)
        t = np.full(bsz, b, np.int64)
        idx.ingest(x, t)
        xs.append(x)
        ts.append(t)
    return idx, np.concatenate(xs), np.concatenate(ts)


@pytest.fixture(scope="module")
def built():
    out = {}
    for scheme in ("PP", "TP", "BTP"):
        out[scheme] = _ingest(scheme)
    return out


@pytest.mark.parametrize("window", [(3, 9), (0, 29), (25, 29), (12, 12)])
def test_all_schemes_agree_and_are_exact(built, window):
    rng = np.random.default_rng(5)
    q = rng.standard_normal(64).astype(np.float32).cumsum()
    t0, t1 = window
    results = {}
    for scheme in ("PP", "TP", "BTP"):
        idx, X, T = built[scheme]
        res, _ = idx.window_knn(q.astype(np.float32), t0, t1, k=4)
        results[scheme] = np.array([d for d, _ in res])
        m = (T >= t0) & (T <= t1)
        bf = np.sort(ed2(q.astype(np.float32), X[m]))[:4]
        np.testing.assert_allclose(results[scheme], bf, rtol=1e-4)
    np.testing.assert_allclose(results["PP"], results["TP"], rtol=1e-6)
    np.testing.assert_allclose(results["PP"], results["BTP"], rtol=1e-6)


def test_partition_counts(built):
    pp, tp, btp = (built[s][0] for s in ("PP", "TP", "BTP"))
    assert tp.n_partitions >= btp.n_partitions  # BTP bounds partitions
    assert pp.n_partitions <= btp.n_partitions  # PP merges hardest


def test_tp_small_window_touches_fewer_blocks(built):
    """TP's advantage: a small window query skips non-overlapping partitions."""
    rng = np.random.default_rng(6)
    q = rng.standard_normal(64).astype(np.float32).cumsum().astype(np.float32)
    _, st_tp = built["TP"][0].window_knn(q, 27, 29, k=1)
    _, st_pp = built["PP"][0].window_knn(q, 27, 29, k=1)
    assert st_tp.blocks_visited <= st_pp.blocks_visited


def test_btp_merge_keeps_time_ranges_contiguous(built):
    btp = built["BTP"][0]
    for runs in btp.lsm.levels.values():
        for r in runs:
            assert r.t_min <= r.t_max


def test_whole_history_query(built):
    rng = np.random.default_rng(7)
    q = rng.standard_normal(64).astype(np.float32).cumsum().astype(np.float32)
    idx, X, _ = built["BTP"]
    res, _ = idx.knn(q, k=3)
    bf = np.sort(ed2(q, X))[:3]
    np.testing.assert_allclose([d for d, _ in res], bf, rtol=1e-4)


def test_approximate_window_query(built):
    rng = np.random.default_rng(8)
    q = rng.standard_normal(64).astype(np.float32).cumsum().astype(np.float32)
    idx, X, T = built["BTP"]
    res, st = idx.window_knn(q, 0, 29, k=1, exact=False)
    assert len(res) == 1
    m = (T >= 0) & (T <= 29)
    bf = np.sort(ed2(q, X[m]))[0]
    assert res[0][0] <= 25 * bf + 1e-3  # approximate but sane
