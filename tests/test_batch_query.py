"""Batched top-k query engine: knn_batch through SortedRun / CTree / CLSM /
StreamingIndex must agree with brute force and with the per-query scalar
path, across materialization variants, windows, and both verify backends."""
import numpy as np
import pytest

from repro.core import (
    CLSM,
    CLSMConfig,
    CTree,
    CTreeConfig,
    RawStore,
    StreamConfig,
    StreamingIndex,
    SummarizationConfig,
    ed2,
    topk_ed2,
)

CFG = SummarizationConfig(series_len=64, n_segments=8, card_bits=6)


def _data(n=3000, seed=0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, 64)).astype(np.float32).cumsum(axis=1)


def _queries(m=12, seed=99):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((m, 64)).astype(np.float32).cumsum(axis=1)


def _assert_batch_exact(vals, gids, Q, X, k):
    for i, q in enumerate(Q):
        bf = np.sort(ed2(q, X))[: k]
        np.testing.assert_allclose(vals[i], bf, rtol=1e-4)
        np.testing.assert_allclose(np.sort(ed2(q, X[gids[i]])), bf, rtol=1e-4)


def test_topk_ed2_host_twin(rng):
    q = rng.standard_normal((6, 64)).astype(np.float32)
    x = rng.standard_normal((300, 64)).astype(np.float32)
    v, i = topk_ed2(q, x, 5)
    full = ed2(q[:, None, :], x[None, :, :])
    np.testing.assert_allclose(v, np.sort(full, axis=1)[:, :5], rtol=1e-6)
    np.testing.assert_allclose(
        np.take_along_axis(full, i, axis=1), v, rtol=1e-6
    )


@pytest.mark.parametrize("materialized", [False, True])
@pytest.mark.parametrize("k", [1, 7])
def test_ctree_knn_batch_exact(materialized, k):
    X, Q = _data(), _queries()
    raw = RawStore(64)
    ids = raw.append(X)
    ct = CTree(
        CTreeConfig(summarization=CFG, block_size=256, materialized=materialized)
    )
    ct.bulk_build(X, ids)
    vals, gids, stats = ct.knn_batch(Q, k=k, raw=raw)
    _assert_batch_exact(vals, gids, Q, X, k)
    assert stats.blocks_visited > 0


def test_ctree_knn_batch_matches_scalar_path():
    X, Q = _data(), _queries(5)
    raw = RawStore(64)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=256, materialized=True))
    ct.bulk_build(X, ids)
    vals, gids, _ = ct.knn_batch(Q, k=6, raw=raw)
    for i, q in enumerate(Q):
        res, _ = ct.knn_exact(q, k=6, raw=raw)
        np.testing.assert_allclose([d for d, _ in res], vals[i], rtol=1e-6)


def test_ctree_knn_batch_kernel_backend_parity():
    X, Q = _data(1500), _queries(6)
    raw = RawStore(64)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=256, materialized=True))
    ct.bulk_build(X, ids)
    v_np, g_np, _ = ct.knn_batch(Q, k=5, raw=raw, backend="numpy")
    v_kr, g_kr, _ = ct.knn_batch(Q, k=5, raw=raw, backend="kernel")
    # both backends re-rank their slates in f64, so results are identical
    np.testing.assert_allclose(v_np, v_kr, rtol=1e-6)
    np.testing.assert_array_equal(g_np, g_kr)


def test_knn_batch_rejects_unknown_backend():
    X = _data(300)
    raw = RawStore(64)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=128, materialized=True))
    ct.bulk_build(X, ids)
    with pytest.raises(ValueError, match="backend"):
        ct.knn_batch(_queries(2), k=3, raw=raw, backend="cuda")


def test_knn_batch_empty_query_batch():
    X = _data(300)
    raw = RawStore(64)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=128, materialized=True))
    ct.bulk_build(X, ids)
    vals, gids, _ = ct.knn_batch(np.zeros((0, 64), np.float32), k=3, raw=raw)
    assert vals.shape == (0, 3) and gids.shape == (0, 3)


def test_ctree_knn_batch_sees_gap_inserts():
    X = _data(2000)
    extra = _data(60, seed=7)
    raw = RawStore(64)
    ids = raw.append(X)
    ct = CTree(
        CTreeConfig(summarization=CFG, block_size=128, fill_factor=0.75,
                    materialized=True)
    )
    ct.bulk_build(X, ids)
    ct.insert(extra, raw.append(extra))
    Q = _queries(4)
    vals, gids, _ = ct.knn_batch(Q, k=3, raw=raw)
    _assert_batch_exact(vals, gids, Q, np.concatenate([X, extra]), 3)


def test_clsm_knn_batch_exact_including_buffer():
    X = _data(3900)
    lsm = CLSM(CLSMConfig(summarization=CFG, buffer_entries=512, growth_factor=3,
                          block_size=128, materialized=True))
    raw = RawStore(64)
    for i in range(0, 3900, 300):  # leaves a non-empty in-memory buffer
        chunk = X[i : i + 300]
        lsm.insert(chunk, raw.append(chunk), np.full(len(chunk), i, np.int64))
    assert lsm._buf_n > 0
    Q = _queries(8)
    vals, gids, _ = lsm.knn_batch(Q, k=5, raw=raw)
    _assert_batch_exact(vals, gids, Q, X, 5)


@pytest.mark.parametrize("scheme", ["PP", "TP", "BTP"])
@pytest.mark.parametrize("window", [(3, 9), (0, 19), (15, 19), (7, 7)])
def test_streaming_window_knn_batch_exact(scheme, window):
    rng = np.random.default_rng(1)
    idx = StreamingIndex(StreamConfig(scheme=scheme, summarization=CFG,
                                      buffer_entries=1024, growth_factor=3,
                                      block_size=128))
    xs, ts = [], []
    for b in range(20):
        x = rng.standard_normal((200, 64)).astype(np.float32).cumsum(axis=1)
        t = np.full(200, b, np.int64)
        idx.ingest(x, t)
        xs.append(x)
        ts.append(t)
    X, T = np.concatenate(xs), np.concatenate(ts)
    Q = _queries(6)
    t0, t1 = window
    vals, gids, _ = idx.window_knn_batch(Q, t0, t1, k=4)
    mask = (T >= t0) & (T <= t1)
    for i, q in enumerate(Q):
        bf = np.sort(ed2(q, X[mask]))[:4]
        np.testing.assert_allclose(vals[i], bf, rtol=1e-4)
    # agrees with the per-query scalar window path
    res, _ = idx.window_knn(Q[0], t0, t1, k=4)
    np.testing.assert_allclose([d for d, _ in res], vals[0], rtol=1e-6)


def test_streaming_whole_history_batch():
    rng = np.random.default_rng(2)
    idx = StreamingIndex(StreamConfig(scheme="BTP", summarization=CFG,
                                      buffer_entries=512, growth_factor=3,
                                      block_size=128))
    xs = []
    for b in range(10):
        x = rng.standard_normal((150, 64)).astype(np.float32).cumsum(axis=1)
        idx.ingest(x, np.full(150, b, np.int64))
        xs.append(x)
    X = np.concatenate(xs)
    Q = _queries(5)
    vals, gids, _ = idx.knn_batch(Q, k=3)
    _assert_batch_exact(vals, gids, Q, X, 3)


def test_knn_batch_single_query_and_odd_batch_sizes():
    """Batch sizes that are not verify-pass block multiples (1, 3, 13) give
    the same answers as any other batching of the same queries."""
    X = _data(1200)
    raw = RawStore(64)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=256, materialized=True))
    ct.bulk_build(X, ids)
    Q = _queries(13)
    full_v, full_i, _ = ct.knn_batch(Q, k=4, raw=raw)
    for m in (1, 3, 13):
        v, i, _ = ct.knn_batch(Q[:m], k=4, raw=raw)
        np.testing.assert_allclose(v, full_v[:m], rtol=1e-6)
        np.testing.assert_array_equal(i, full_i[:m])


def test_knn_batch_k_exceeds_n_pads_with_inf():
    X = _data(5)
    raw = RawStore(64)
    ids = raw.append(X)
    ct = CTree(CTreeConfig(summarization=CFG, block_size=256, materialized=True))
    ct.bulk_build(X, ids)
    vals, gids, _ = ct.knn_batch(_queries(3), k=8, raw=raw)
    assert np.isfinite(vals[:, :5]).all()
    assert (vals[:, 5:] == np.inf).all() and (gids[:, 5:] == -1).all()
